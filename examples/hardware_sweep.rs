//! Hardware sweep: every experiment on both Jetson devices (Xavier vs
//! Orin), showing the Orin advantage the paper's §III.A quotes, plus the
//! subgraph-limit failure mode from §II.C — and the same device sweep
//! through the serving pipeline itself, by pointing the session API at
//! `SimBackend` (no artifacts needed).

use edgepipe::config::{GanVariant, Workload};
use edgepipe::dla::{planner, DlaVersion};
use edgepipe::hw::{orin, xavier, EngineKind};
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::pipeline::SimBackend;
use edgepipe::sched::haxconn;
use edgepipe::session::Session;
use edgepipe::sim::{simulate, SimConfig};
use std::sync::Arc;

fn main() -> edgepipe::Result<()> {
    for (soc, version) in [(xavier(), DlaVersion::V1), (orin(), DlaVersion::V2)] {
        println!("== {} ==", soc.name);
        for v in GanVariant::all() {
            let g = generator(&Pix2PixConfig::paper(), v)?;
            let (sched, _) = haxconn::two_gans(&g, &soc, version)?;
            let r = simulate(&[&g], &sched, &SimConfig::new(soc.clone(), 128))?;
            println!(
                "  {:<14} two-GAN: GPU-home {:>7.1} fps  DLA-home {:>7.1} fps",
                v.name(),
                r.fps_of_home(EngineKind::Gpu).unwrap_or(0.0),
                r.fps_of_home(EngineKind::Dla).unwrap_or(0.0)
            );
        }
    }

    // Subgraph-limit failure mode (§II.C): the original model's fragmented
    // engine plan exceeds a tightened loadable budget.
    println!("== DLA subgraph limit (paper §II.C) ==");
    let g = generator(&Pix2PixConfig::paper(), GanVariant::Original)?;
    for limit in [16usize, 8, 4] {
        match planner::plan(&g, DlaVersion::V2, limit) {
            Ok(p) => println!("  limit {:>2}: plan OK ({} DLA subgraphs)", limit, p.dla_subgraphs),
            Err(e) => println!("  limit {:>2}: {}", limit, e),
        }
    }
    let fixed = generator(&Pix2PixConfig::paper(), GanVariant::Cropping)?;
    let p = planner::plan(&fixed, DlaVersion::V2, 4)?;
    println!(
        "  cropping variant under limit 4: OK ({} subgraph, fully resident: {})",
        p.dla_subgraphs,
        p.fully_dla_resident()
    );

    // Serving-pipeline sweep: the production coordinator (session API)
    // priced per device by the latency-model backend.
    println!("== Serving pipeline on SimBackend (GAN+YOLO, 64 frames) ==");
    for soc in [xavier(), orin()] {
        let session = Session::builder()
            .workload(Workload::GanPlusYolo, GanVariant::Cropping)
            .frames(64)
            .backend(Arc::new(SimBackend::new(soc.clone())))
            .build()?;
        let rep = session.run()?;
        println!(
            "  {:<18} total {:>6.1} fps ({} frames, {} dropped)",
            soc.name,
            rep.total_fps(),
            rep.total_frames,
            rep.dropped
        );
    }
    Ok(())
}
