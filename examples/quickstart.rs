//! Quickstart: load the AOT artifacts, reconstruct an MRI from one CT
//! phantom, diagnose it with the detector, save the images (Fig 7), then
//! serve the same two models as a streaming pipeline through the session
//! API:
//!
//! ```text
//! Session::builder()
//!     .instance(InstanceSpec::new("gan", "gen_cropping").scored(true))
//!     .instance(InstanceSpec::new("yolo", "yolo_lite"))
//!     .route(RoutePolicy::Fanout)
//!     .build()?
//!     .run()?
//! ```
//!
//! (The historical `Workload` enum arms are sugar: presets that lower
//! into the same `PipelineSpec`s.)
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use edgepipe::imaging::metrics::fidelity;
use edgepipe::imaging::phantom::{paired_sample, PhantomConfig};
use edgepipe::imaging::Image;
use edgepipe::pipeline::router::RoutePolicy;
use edgepipe::pipeline::spec::InstanceSpec;
use edgepipe::postproc;
use edgepipe::runtime::{Artifact, RuntimeClient};
use edgepipe::session::Session;
use edgepipe::util::rng::Rng;
use std::path::Path;

fn main() -> edgepipe::Result<()> {
    let dir = Path::new("artifacts");
    let client = RuntimeClient::cpu()?;
    println!("PJRT platform: {} ({} devices)", client.platform(), client.device_count());

    let gan = Artifact::load(&client, dir, "gen_cropping")?;
    let yolo = Artifact::load(&client, dir, "yolo_lite")?;
    println!(
        "loaded gen_cropping ({} weight tensors) and yolo_lite ({})",
        gan.weight_count(),
        yolo.weight_count()
    );

    // One synthetic CT slice with ground truth.
    let sample = paired_sample(&PhantomConfig::default(), &mut Rng::new(7));
    let ct_pm1: Vec<f32> = sample.ct.data.iter().map(|&v| v * 2.0 - 1.0).collect();

    // --- MRI reconstruction (the paper's GAN path) ---
    let t0 = std::time::Instant::now();
    let mri_out = gan.run_image(&ct_pm1)?;
    let gan_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mri01: Vec<f32> = mri_out[0].data.iter().map(|&v| (v + 1.0) / 2.0).collect();
    let mri_img = Image::from_data(64, 64, mri01)?;
    let fid = fidelity(&sample.mri, &mri_img)?;
    println!(
        "GAN reconstruction: {:.1} ms — PSNR {:.2} dB, SSIM {:.2}, MSE {:.2}",
        gan_ms, fid.psnr, fid.ssim_pct, fid.mse
    );

    // --- Stroke diagnosis (the paper's YOLO path) ---
    let t0 = std::time::Instant::now();
    let head = yolo.run_image(&ct_pm1)?;
    let yolo_ms = t0.elapsed().as_secs_f64() * 1e3;
    let scales: Vec<(Vec<f32>, usize, f32)> = head
        .iter()
        .map(|o| (o.data.clone(), o.dims[1], 64.0 / o.dims[1] as f32))
        .collect();
    let dets = postproc::postprocess(&scales, 4, 1, 0.55, 0.5);
    println!(
        "YOLO diagnosis: {:.1} ms — {} candidate regions (ground truth has {} lesions)",
        yolo_ms,
        dets.len(),
        sample.lesions.len()
    );
    for d in dets.iter().take(4) {
        println!(
            "  box ({:5.1},{:5.1})-({:5.1},{:5.1}) score {:.2}",
            d.x0, d.y0, d.x1, d.y1, d.score
        );
    }

    // --- Save the Fig 7 style images ---
    std::fs::create_dir_all("target/quickstart")?;
    sample.ct.save_pgm(Path::new("target/quickstart/ct_input.pgm"))?;
    sample.mri.save_pgm(Path::new("target/quickstart/mri_ground_truth.pgm"))?;
    mri_img.save_pgm(Path::new("target/quickstart/mri_reconstructed.pgm"))?;
    println!("wrote target/quickstart/{{ct_input,mri_ground_truth,mri_reconstructed}}.pgm");

    // --- The same two models as a served pipeline (session API) ---
    let session = Session::builder()
        .instance(InstanceSpec::new("gan", "gen_cropping").scored(true))
        .instance(InstanceSpec::new("yolo", "yolo_lite"))
        .route(RoutePolicy::Fanout)
        .frames(32)
        .build()?;
    let rep = session.run()?;
    println!(
        "served 32 CT frames: total {:.1} fps, {} dropped, gan psnr {:.2}",
        rep.total_fps(),
        rep.dropped,
        rep.instances[0].psnr_mean
    );
    Ok(())
}
