//! Minimal auto-placement walkthrough: **plan → spec → session**.
//!
//! The planner searches the pipeline configuration space (GAN surgery
//! variant, engine unit per instance, `max_batch`, route policy) for the
//! allocation predicted to maximize throughput, pricing every candidate
//! from the cost model — no backend runs during planning. The winning
//! spec then serves through the ordinary session API, where the engine
//! arbiter *enforces* the placement the planner predicted.
//!
//! Runs on the sim backend with no artifacts:
//!
//! ```text
//! cargo run --release --no-default-features --example auto_place
//! ```

use edgepipe::dla::DlaVersion;
use edgepipe::hw;
use edgepipe::pipeline::SimBackend;
use edgepipe::placement::{self, PlacementRequest};
use edgepipe::session::Session;
use std::sync::Arc;

fn main() -> edgepipe::Result<()> {
    // The paper's dual-GAN shape on the Xavier testbed: two DLA-resident
    // reconstruction GANs (GPU reserved for the detector stream).
    let mut req = PlacementRequest::new(hw::xavier(), DlaVersion::V1).dla_resident_gans();
    req.frames = 48;

    // Plan: enumerate + prune + score, entirely in virtual time.
    let outcome = placement::plan(&req)?;
    println!(
        "planned: {} — {:.1} predicted fps, {:.2} ms total idle, {} transition(s)",
        outcome.best_key(),
        outcome.eval.predicted_fps,
        outcome.eval.idle_gap_total_ms,
        outcome.eval.transitions
    );
    for u in &outcome.eval.units {
        println!("  {:<5} predicted util {:>5.1}%", u.label, u.utilization * 100.0);
    }
    for (key, reason) in outcome.rejected.iter().take(3) {
        println!("  rejected {key}: {reason}");
    }

    // Serve the planned spec on the sim backend (time-scaled so the
    // example finishes quickly; placement semantics are unchanged).
    let report = Session::builder()
        .auto_place(&req)?
        .frames(64)
        .backend(Arc::new(SimBackend::new(hw::xavier()).with_time_scale(0.05)))
        .build()?
        .run()?;
    println!(
        "served: {:.1} fps total, {} dropped",
        report.total_fps(),
        report.dropped
    );
    for e in &report.engines {
        println!("  {:<5} served util {:>5.1}%", e.label, e.utilization * 100.0);
    }
    Ok(())
}
