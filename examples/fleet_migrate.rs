//! A fleet of virtual-clock Jetson nodes surviving a node degradation.
//!
//! Six mixed Orin/Xavier nodes boot, each running the placement planner
//! against its own SoC profile, and serve 48 ramping client streams
//! hashed onto them by the consistent-hash front door. Two seconds in,
//! one node is throttled 10× (a thermal event); its backlog builds, the
//! migration controller notices at the next checkpoint, and drains the
//! node's streams to the least-loaded healthy peers with the
//! drain-and-switch barrier — no frame lost, duplicated, or reordered.
//! The final rollup ranks nodes by FPS-per-watt through the per-profile
//! power rail model.
//!
//! Everything runs on one thread in virtual time (no sleeps):
//!
//! ```text
//! cargo run --release --no-default-features --example fleet_migrate
//! ```

use edgepipe::fleet::{run_fleet, DegradationEvent, FleetOptions, NodeProfile};
use edgepipe::serve::{ArrivalProcess, ClientSpec};

fn main() -> edgepipe::Result<()> {
    let mut opts = FleetOptions::new(vec![
        NodeProfile::Orin,
        NodeProfile::Xavier,
        NodeProfile::Orin,
        NodeProfile::Xavier,
        NodeProfile::Orin,
        NodeProfile::Xavier,
    ]);
    opts.check_every = 256;
    for i in 0..48 {
        opts.clients.push(ClientSpec::new(
            format!("clinic-{i}"),
            160,
            ArrivalProcess::Ramp {
                start_fps: 5.0,
                end_fps: 40.0,
            },
        ));
    }
    // Thermal throttle on node 2, two virtual seconds in: every dispatch
    // it prices afterwards takes 10x longer.
    opts.degradations.push(DegradationEvent {
        at_seconds: 2.0,
        node: 2,
        slowdown: 10.0,
    });

    let rep = run_fleet(&opts)?;

    println!(
        "fleet of {}: {} offered -> {} completed, {} shed, {} migration(s)",
        rep.nodes.len(),
        rep.offered,
        rep.completed,
        rep.shed,
        rep.migrations.len()
    );
    println!(
        "{} streams at {:.1} virtual fps; p99 {:.2} ms; simulated in {:.2}s wall",
        rep.streams, rep.fps, rep.latency_ms_p99, rep.wall_seconds
    );

    println!("nodes by FPS-per-watt:");
    for &i in &rep.ranking() {
        let n = &rep.nodes[i];
        println!(
            "  node {} ({:<6}) {:>5} frames  {:>6.1} fps  {:>5.2} W  {:>5.2} fps/W  [{}]",
            n.node, n.profile, n.completed, n.fps, n.power_w, n.fps_per_watt, n.health
        );
    }
    for ev in &rep.migrations {
        println!(
            "  migrate @{:.3}s: stream {:>2}  node {} -> {}  [{}]",
            ev.at_seconds, ev.stream, ev.from_node, ev.to_node, ev.reason
        );
    }
    println!("windowed fleet throughput:");
    for w in &rep.windows {
        println!(
            "  [{:>6.2}s..{:>6.2}s] {:>7.1} fps  p99 {:>8.2} ms  shed {}",
            w.t0, w.t1, w.fps, w.latency_ms_p99, w.shed
        );
    }

    // The contract the fleet keeps through every migration.
    assert_eq!(rep.offered, rep.completed + rep.shed);
    assert!(
        !rep.migrations.is_empty(),
        "a 10x-throttled node under ramp load must shed streams to peers"
    );
    Ok(())
}
