//! Client-server scheme (Fig 1 B): several hospital CT streams multiplexed
//! into the reconstruction service under the naive schedule, with two GAN
//! instances sharing the load (ByStream routing) and dynamic batching.

use edgepipe::config::{GanVariant, PipelineConfig, Workload};
use edgepipe::pipeline::run_pipeline;

fn main() -> edgepipe::Result<()> {
    println!("== Client-server scheme: 4 hospital streams, two GAN instances ==");
    for variant in GanVariant::all() {
        let cfg = PipelineConfig {
            variant,
            workload: Workload::TwoGans,
            frames: 128,
            streams: 4,
            queue_depth: 16,
            max_batch: 4,
            batch_timeout_us: 2000,
            ..PipelineConfig::default()
        };
        let rep = run_pipeline(&cfg)?;
        println!(
            "{:<14} total {:>6.1} fps over {} frames ({} dropped)",
            variant.name(),
            rep.total_fps(),
            rep.total_frames,
            rep.dropped
        );
        for inst in &rep.instances {
            println!(
                "    {:<10} {:>6.1} fps  p50 {:>7.1} ms  p99 {:>7.1} ms  psnr {:>5.2}",
                inst.label, inst.fps, inst.latency_ms_p50, inst.latency_ms_p99, inst.psnr_mean
            );
        }
    }
    Ok(())
}
