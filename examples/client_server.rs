//! Client-server scheme (Fig 1 B): several hospital CT streams multiplexed
//! into the reconstruction service, composed explicitly with the session
//! API — two GAN instances sharing the load (ByStream routing) and dynamic
//! batching set per instance through `PipelineBuilder`.

use edgepipe::config::{GanVariant, Workload};
use edgepipe::pipeline::batcher::BatchPolicy;
use edgepipe::pipeline::router::RoutePolicy;
use edgepipe::session::Session;
use std::time::Duration;

fn main() -> edgepipe::Result<()> {
    println!("== Client-server scheme: 4 hospital streams, two GAN instances ==");
    for variant in GanVariant::all() {
        let session = Session::builder()
            .workload(Workload::TwoGans, variant)
            .route(RoutePolicy::ByStream)
            .batch(BatchPolicy {
                max_batch: 4,
                timeout: Duration::from_micros(2000),
            })
            .frames(128)
            .streams(4)
            .queue_depth(16)
            .build()?;
        let rep = session.run()?;
        println!(
            "{:<14} total {:>6.1} fps over {} frames ({} dropped)",
            variant.name(),
            rep.total_fps(),
            rep.total_frames,
            rep.dropped
        );
        for inst in &rep.instances {
            println!(
                "    {:<10} {:>6.1} fps  p50 {:>7.1} ms  p99 {:>7.1} ms  psnr {:>5.2}",
                inst.label, inst.fps, inst.latency_ms_p50, inst.latency_ms_p99, inst.psnr_mean
            );
        }
    }
    Ok(())
}
