//! Acceleration sweep over the k-space acquisition front-end: the
//! fidelity/throughput frontier of zero-filled vs GRAPPA reconstruction
//! at R = 2/4/8, measured through the full serving pipeline (recon →
//! GAN → YOLO) rather than on isolated slices.
//!
//! Every frame is undersampled multi-coil k-space; the source
//! reconstructs it before the model chain and scores the recon against
//! the fully-sampled slice it was acquired from, so the printed PSNR is
//! exactly what the downstream models actually received. The per-frame
//! recon cost rides on the report as `recon_ms_per_frame` — the same
//! figure the placement planner prices into admission pacing.
//!
//! Runs on the sim backend with no artifacts:
//!
//! ```text
//! cargo run --release --no-default-features --example kspace_sweep
//! ```

use edgepipe::config::{GanVariant, Workload};
use edgepipe::hw::orin;
use edgepipe::pipeline::{ReconMode, SimBackend, SourceSpec};
use edgepipe::session::Session;
use std::sync::Arc;

fn main() -> edgepipe::Result<()> {
    println!("== k-space front-end sweep: zero-filled vs GRAPPA ==");
    println!(
        "{:>3} {:>12} {:>10} {:>9} {:>14} {:>9}",
        "R", "recon", "psnr dB", "ssim %", "recon ms/frame", "fps"
    );
    for accel in [2usize, 4, 8] {
        for mode in [ReconMode::ZeroFilled, ReconMode::Grappa] {
            let session = Session::builder()
                .workload(Workload::GanPlusYolo, GanVariant::Cropping)
                .source(SourceSpec::kspace(accel, mode))
                .frames(64)
                .backend(Arc::new(SimBackend::new(orin()).with_time_scale(0.0)))
                .build()?;
            let rep = session.run()?;
            let r = rep
                .recon
                .as_ref()
                .expect("kspace runs always carry a recon report");
            println!(
                "{:>3} {:>12} {:>10.2} {:>9.2} {:>14.2} {:>9.0}",
                accel,
                r.recon,
                r.psnr_mean,
                r.ssim_pct_mean,
                r.recon_ms_per_frame,
                rep.total_fps()
            );
        }
    }
    println!(
        "\nGRAPPA recovers the aliased rows the zero-filled baseline leaves \
         empty, so its PSNR column dominates at every R; the gap narrows as \
         acceleration rises and fewer calibration-consistent neighbours \
         remain. The recon cost column is what `edgepipe plan` prices into \
         the latency budget for kspace sources."
    );
    Ok(())
}
