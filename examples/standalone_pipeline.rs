//! END-TO-END DRIVER (the standalone scheme, Fig 1 A).
//!
//! Exercises every layer of the stack on a real small workload:
//!
//! 1. builds the paper-scale model graphs and derives the HaX-CoNN
//!    schedule (L3 scheduling contribution);
//! 2. simulates the schedule on the calibrated Orin SoC model (the timing
//!    claim — Tables V/VI);
//! 3. streams 256 synthetic CT frames through the *real* coordinator via
//!    the composable session API —
//!    `Session::builder().workload(...).build()?.run()?` — with workers
//!    executing the AOT-compiled JAX/Pallas artifacts through PJRT
//!    (L1/L2 numerics), reporting measured latency/throughput and online
//!    reconstruction PSNR/SSIM. The `Workload` arms are presets lowering
//!    into `PipelineSpec`s; arbitrary instance mixes use
//!    `.instance(InstanceSpec::new(...))` instead.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use edgepipe::config::{GanVariant, Workload};
use edgepipe::dla::DlaVersion;
use edgepipe::hw::{orin, EngineKind};
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::models::yolov8::{yolov8, YoloConfig};
use edgepipe::sched::haxconn;
use edgepipe::session::Session;
use edgepipe::sim::{simulate, SimConfig};

fn main() -> edgepipe::Result<()> {
    let variant = GanVariant::Cropping;
    let soc = orin();

    // ---- 1. Schedule synthesis ----
    let gan = generator(&Pix2PixConfig::paper(), variant)?;
    let yolo = yolov8(&YoloConfig::nano())?;
    let (sched, ss) = haxconn::gan_plus_yolo(&gan, &yolo, &soc, DlaVersion::V2)?;
    println!("== HaX-CoNN schedule (GAN {} + YOLOv8) ==", variant.name());
    for inst in &sched.instances {
        let (d2g, g2d) = inst.partition_points();
        println!(
            "  {:<6} DLA->GPU at {:?}, GPU->DLA at {:?}",
            inst.label, d2g, g2d
        );
    }
    println!(
        "  steady state: period {:.2} ms ({:.1} fps/instance), busy gpu {:.2} ms dla {:.2} ms",
        ss.period * 1e3,
        1.0 / ss.period,
        ss.busy_gpu * 1e3,
        ss.busy_dla * 1e3
    );

    // ---- 2. Simulated deployment on the Jetson model ----
    let r = simulate(&[&gan, &yolo], &sched, &SimConfig::new(soc.clone(), 192))?;
    println!("== Simulated Orin deployment (Table VI row) ==");
    for inst in &r.instances {
        println!("  {:<6} home {:<4} {:>7.1} fps", inst.label, inst.home_engine, inst.fps);
    }
    let gs = r.timeline.engine_stats(EngineKind::Gpu);
    let ds = r.timeline.engine_stats(EngineKind::Dla);
    println!(
        "  utilization gpu {:.0}% dla {:.0}%",
        gs.utilization * 100.0,
        ds.utilization * 100.0
    );

    // ---- 3. Real serving through PJRT (session API) ----
    println!("== Real PJRT serving (256 frames) ==");
    let session = Session::builder()
        .workload(Workload::GanPlusYolo, variant)
        .frames(256)
        .build()?;
    let rep = session.run()?;
    println!(
        "  processed {} frames in {:.2} s (total pipeline {:.1} fps, {} dropped)",
        rep.total_frames,
        rep.wall_seconds,
        rep.total_fps(),
        rep.dropped
    );
    for inst in &rep.instances {
        println!(
            "  {:<6} {:>7.1} fps  latency p50 {:>6.1} ms p99 {:>6.1} ms  psnr {:>5.2}  ssim {:>5.2}",
            inst.label,
            inst.fps,
            inst.latency_ms_p50,
            inst.latency_ms_p99,
            inst.psnr_mean,
            inst.ssim_pct_mean
        );
    }
    Ok(())
}
