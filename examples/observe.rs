//! The unified observability layer end to end: frame-lifecycle stage
//! breakdowns, a Chrome/Perfetto trace of every engine dispatch, and a
//! metrics registry with Prometheus-style exposition plus a JSONL
//! snapshot/event stream — all from one serve run.
//!
//! A bursty client mix streams into a deliberately naive placement
//! (both GANs on DLA0) with an [`edgepipe::obs::ObsHub`] attached, so
//! the run records per-copy stage stamps, bumps the admission counters,
//! snapshots the registry at every telemetry checkpoint, and logs the
//! forced drain-and-switch re-plans as structured events. Afterwards the
//! example writes `observe_trace.json` (load it at
//! <https://ui.perfetto.dev>) and `observe_metrics.jsonl`, and prints
//! the exposition text and the per-stage latency summary.
//!
//! Runs on the sim backend with no artifacts:
//!
//! ```text
//! cargo run --release --no-default-features --example observe
//! ```

use edgepipe::dla::DlaVersion;
use edgepipe::hw::{self, EngineKind};
use edgepipe::obs::{ChromeTrace, ObsHub};
use edgepipe::pipeline::router::RoutePolicy;
use edgepipe::pipeline::{InstanceSpec, SimBackend};
use edgepipe::serve::{self, ArrivalProcess, ClientSpec, ReplanPolicy, ServeOptions};
use edgepipe::session::Session;
use std::sync::Arc;

fn main() -> edgepipe::Result<()> {
    let time_scale = 0.02;
    let soc = hw::orin();

    // Naive initial placement the re-planner gets to fix mid-run, so the
    // trace shows a drain-and-switch boundary and the event log a replan.
    let session = Session::builder()
        .instance(InstanceSpec::new("g0", "gen_cropping").on_engine_unit(EngineKind::Dla, 0))
        .instance(InstanceSpec::new("g1", "gen_cropping").on_engine_unit(EngineKind::Dla, 0))
        .route(RoutePolicy::RoundRobin)
        .streams(2)
        .backend(Arc::new(SimBackend::new(soc.clone()).with_time_scale(time_scale)))
        .build()?;

    let hub = Arc::new(ObsHub::new());
    let mut opts = ServeOptions::new(soc, DlaVersion::V2);
    opts.time_scale = time_scale;
    opts.obs = Some(Arc::clone(&hub));
    opts.replan = ReplanPolicy {
        check_every_frames: 128,
        force_every_checks: Some(2),
        ..ReplanPolicy::default()
    };
    for i in 0..2 {
        opts.clients.push(ClientSpec::new(
            format!("scanner{i}"),
            256,
            ArrivalProcess::Burst {
                burst_fps: 400.0,
                burst_len: 32,
                idle_seconds: 0.05,
            },
        ));
    }

    let rep = serve::serve(session, opts)?;
    assert_eq!(rep.offered, rep.completed + rep.shed);

    // --- 1. Frame-lifecycle stage breakdown (per-copy histograms). ---
    let stages = rep.stages.as_ref().expect("observed serve reports stages");
    println!("stage breakdown over {} frame copies:", stages.frames);
    println!("  {}", stages.summary());

    // --- 2. Chrome trace: engine dispatch slices + replan markers. ---
    let mut tr = ChromeTrace::new();
    tr.process(0, "edgepipe observe example");
    tr.add_timeline(0, &rep.timeline, &[]);
    for ev in &rep.replans {
        tr.instant(0, "control", "replan", "replan", ev.at_seconds, ev.to_json());
    }
    for c in rep.completions.iter().take(5_000) {
        let id = ((c.instance as u64) << 56)
            | ((c.stream as u64) << 40)
            | (c.frame_id & ((1 << 40) - 1));
        let t0 = (c.t - c.latency_s).max(0.0);
        tr.flow(0, id, "frame", t0, c.t, edgepipe::config::json::Json::Null);
    }
    std::fs::write("observe_trace.json", tr.to_json().to_compact())?;
    println!(
        "wrote observe_trace.json ({} events) — load it at https://ui.perfetto.dev",
        tr.event_count()
    );

    // --- 3. Metrics registry: exposition text + JSONL stream (the
    // serve loop snapshots at every checkpoint and once at the end). ---
    std::fs::write("observe_metrics.jsonl", hub.to_jsonl())?;
    println!(
        "wrote observe_metrics.jsonl ({} events, {} snapshots)",
        hub.event_count(),
        hub.snapshot_count()
    );
    println!("exposition:");
    for line in hub.registry.expose().lines() {
        println!("  {line}");
    }
    Ok(())
}
