//! Long-running serving with QoS admission and online re-planning.
//!
//! Three synthetic hospital clients — steady Poisson, bursty scanner
//! batches, and a ramping load — stream into a deliberately naive
//! placement (both GANs pinned to DLA0). Per-class QoS admission
//! rate-limits the best-effort class and deadline-sheds when the backlog
//! estimate blows past its budget, while the re-plan controller watches
//! the rolling windows, re-invokes the placement search against the
//! observed load, and drain-and-switches to the better allocation at a
//! frame boundary — no frames lost, per-client order preserved.
//!
//! Runs on the sim backend with no artifacts:
//!
//! ```text
//! cargo run --release --no-default-features --example serve_qos
//! ```

use edgepipe::dla::DlaVersion;
use edgepipe::hw::{self, EngineKind};
use edgepipe::pipeline::router::RoutePolicy;
use edgepipe::pipeline::{InstanceSpec, SimBackend};
use edgepipe::serve::{self, ArrivalProcess, ClientSpec, QosClass, ServeOptions};
use edgepipe::session::Session;
use std::sync::Arc;

fn main() -> edgepipe::Result<()> {
    // Fast-forward: modeled latencies and the arrival schedule both run
    // at 5% wall speed, so a ~20 s load profile replays in about one.
    let time_scale = 0.05;
    let soc = hw::orin();

    // Naive initial placement: both reconstruction GANs share DLA0 while
    // the GPU and DLA1 idle — exactly what the re-planner should fix.
    let session = Session::builder()
        .instance(InstanceSpec::new("g0", "gen_cropping").on_engine_unit(EngineKind::Dla, 0))
        .instance(InstanceSpec::new("g1", "gen_cropping").on_engine_unit(EngineKind::Dla, 0))
        .route(RoutePolicy::RoundRobin)
        .streams(3)
        .backend(Arc::new(SimBackend::new(soc.clone()).with_time_scale(time_scale)))
        .build()?;

    let mut opts = ServeOptions::new(soc, DlaVersion::V2);
    opts.time_scale = time_scale;
    opts.qos = vec![
        // The reconstruction stream is lossless: no cap, no deadline.
        QosClass::unlimited("interactive", 0),
        // Best-effort research traffic: capped and deadline-shed.
        QosClass::unlimited("best-effort", 1)
            .rate_limited(60.0, 16.0)
            .with_deadline_ms(400.0),
    ];
    opts.clients = vec![
        ClientSpec::new("steady", 256, ArrivalProcess::Poisson { rate_fps: 60.0 }),
        ClientSpec::new(
            "scanner",
            256,
            ArrivalProcess::Burst {
                burst_fps: 400.0,
                burst_len: 32,
                idle_seconds: 0.4,
            },
        )
        .qos_class(1),
        ClientSpec::new(
            "ramp",
            256,
            ArrivalProcess::Ramp {
                start_fps: 20.0,
                end_fps: 200.0,
            },
        ),
    ];
    opts.replan.check_every_frames = 128;

    let rep = serve::serve(session, opts)?;

    println!(
        "served {} offered -> {} completed, {} shed ({} rate-limit, {} deadline) in {:.2}s",
        rep.offered, rep.completed, rep.shed, rep.shed_rate_limit, rep.shed_deadline,
        rep.wall_seconds
    );
    println!(
        "latency p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
        rep.latency_ms_p50, rep.latency_ms_p95, rep.latency_ms_p99
    );
    for ev in &rep.replans {
        println!(
            "re-plan @frame {} [{}]\n  {}  ->  {}\n  predicted {:.1} -> {:.1} fps",
            ev.at_frame, ev.reason, ev.from_key, ev.to_key,
            ev.predicted_fps_before, ev.predicted_fps_after
        );
    }
    println!("windowed trajectory:");
    for w in &rep.windows {
        println!(
            "  [{:>5.2}s..{:>5.2}s] {:>7.1} fps  p99 {:>7.2} ms  idle {:>3.0}%  shed {}",
            w.t0,
            w.t1,
            w.fps,
            w.latency_ms_p99,
            w.idle_frac() * 100.0,
            w.shed
        );
    }
    for (class, st) in &rep.classes {
        println!(
            "class {:<12} admitted {:>5}  shed {:>4} (rate) {:>4} (deadline)",
            class.name, st.admitted, st.shed_rate_limit, st.shed_deadline
        );
    }
    // Conservation across every drain-and-switch: nothing lost.
    assert_eq!(rep.offered, rep.completed + rep.shed);
    Ok(())
}
