"""AOT export: JAX/Pallas models -> HLO text + weights.bin artifacts.

The interchange format is HLO *text* (not serialized HloModuleProto): jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example.

Weights are *runtime parameters*, not baked constants: each artifact is
lowered as `fn(ct, w0, w1, ...)` and a side-car `<name>.weights.bin`
carries the trained values in parameter order. Format (little-endian):

    magic   b"EPW1"
    count   u32
    per tensor: rank u32, dims u32*rank, data f32*prod(dims)

`<name>.meta.json` records input shape and parameter names for
provenance. Python runs ONCE at build time; the rust binary is
self-contained afterwards.

Usage: python -m compile.aot --out ../artifacts [--skip-yolo]
"""

import argparse
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    GanConfig,
    VARIANTS,
    YoloConfig,
    generator_apply,
    init_generator,
    init_yolo,
    yolo_apply,
)


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_weights_bin(path, arrays):
    with open(path, "wb") as f:
        f.write(b"EPW1")
        f.write(struct.pack("<I", len(arrays)))
        for a in arrays:
            a = np.asarray(a, np.float32)
            f.write(struct.pack("<I", a.ndim))
            for d in a.shape:
                f.write(struct.pack("<I", d))
            f.write(a.tobytes())


def export_generator(out_dir, variant, cfg, params_list, use_pallas=True):
    """Lower generator(ct, *weights) -> mri to HLO text + weights."""
    names = [n for n, _ in params_list]
    arrays = [a for _, a in params_list]

    def fn(ct, *weights):
        params = dict(zip(names, weights))
        return (generator_apply(params, ct, cfg, variant, use_pallas=use_pallas),)

    ct_spec = jax.ShapeDtypeStruct((1, cfg.image_size, cfg.image_size, cfg.channels), jnp.float32)
    w_specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in arrays]
    lowered = jax.jit(fn).lower(ct_spec, *w_specs)
    hlo = to_hlo_text(lowered)

    base = os.path.join(out_dir, f"gen_{variant}")
    with open(base + ".hlo.txt", "w") as f:
        f.write(hlo)
    write_weights_bin(base + ".weights.bin", arrays)
    with open(base + ".meta.json", "w") as f:
        json.dump(
            {
                "model": f"pix2pix_{variant}",
                "input": list(ct_spec.shape),
                "params": names,
                "pallas": use_pallas,
            },
            f,
            indent=2,
        )
    return base


def export_yolo(out_dir, cfg, params_list, use_pallas=True):
    names = [n for n, _ in params_list]
    arrays = [a for _, a in params_list]

    def fn(img, *weights):
        params = dict(zip(names, weights))
        return yolo_apply(params, img, cfg, use_pallas=use_pallas)

    spec = jax.ShapeDtypeStruct((1, cfg.image_size, cfg.image_size, cfg.channels), jnp.float32)
    w_specs = [jax.ShapeDtypeStruct(a.shape, jnp.float32) for a in arrays]
    lowered = jax.jit(fn).lower(spec, *w_specs)
    hlo = to_hlo_text(lowered)

    base = os.path.join(out_dir, "yolo_lite")
    with open(base + ".hlo.txt", "w") as f:
        f.write(hlo)
    write_weights_bin(base + ".weights.bin", arrays)
    with open(base + ".meta.json", "w") as f:
        json.dump(
            {"model": "yolo_lite", "input": list(spec.shape), "params": names,
             "pallas": use_pallas},
            f,
            indent=2,
        )
    return base


def load_trained_or_init(out_dir, variant, cfg):
    """Prefer trained checkpoints (train.py); fall back to seeded init."""
    ckpt = os.path.join(out_dir, f"gen_{variant}.npz")
    order = [n for n, _ in init_generator(jax.random.PRNGKey(0), cfg, variant)]
    if os.path.exists(ckpt):
        z = np.load(ckpt)
        return [(n, jnp.asarray(z[n])) for n in order]
    print(f"warning: no checkpoint for {variant}; exporting seeded init")
    return init_generator(jax.random.PRNGKey(0), cfg, variant)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-yolo", action="store_true")
    ap.add_argument("--no-pallas", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    use_pallas = not args.no_pallas

    cfg = GanConfig()
    for variant in VARIANTS:
        params = load_trained_or_init(args.out, variant, cfg)
        base = export_generator(args.out, variant, cfg, params, use_pallas)
        print(f"wrote {base}.hlo.txt ({os.path.getsize(base + '.hlo.txt')} bytes)")

    if not args.skip_yolo:
        ycfg = YoloConfig()
        yparams = init_yolo(jax.random.PRNGKey(7), ycfg)
        base = export_yolo(args.out, ycfg, yparams, use_pallas)
        print(f"wrote {base}.hlo.txt ({os.path.getsize(base + '.hlo.txt')} bytes)")


if __name__ == "__main__":
    main()
