"""Paired CT/MRI brain-phantom generator (training data).

Mirrors `rust/src/imaging/phantom.rs`: skull ring, tissue ellipse,
ventricles, optional stroke lesions; the MRI is a deterministic tissue
contrast remap of the noise-free label map with a 3x3 box blur. The rust
pipeline generates phantoms with the same construction, so a model trained
here transfers to the rust-side evaluation.
"""

import numpy as np

CT_AIR, CT_TISSUE, CT_VENT, CT_BONE, CT_LESION = 0.05, 0.45, 0.30, 0.95, 0.38
MRI = {0: 0.02, 1: 0.62, 2: 0.88, 3: 0.10, 4: 0.82}


def box_blur3(img):
    p = np.pad(img, 1, mode="edge")
    out = np.zeros_like(img)
    for dy in range(3):
        for dx in range(3):
            out += p[dy : dy + img.shape[0], dx : dx + img.shape[1]]
    return out / 9.0


def paired_sample(rng, size=64, lesion_prob=0.7, noise_sigma=0.01):
    """One (ct, mri, lesions) sample; images (size, size) float32 in [0,1]."""
    labels = np.zeros((size, size), np.uint8)
    c = size / 2.0
    rx = rng.uniform(0.36, 0.44) * size
    ry = rng.uniform(0.40, 0.47) * size
    skull_t = rng.uniform(0.04, 0.07) * size
    tilt = rng.uniform(-0.2, 0.2)
    st, ct_ = np.sin(tilt), np.cos(tilt)

    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    dx, dy = xx - c, yy - c
    u = ct_ * dx + st * dy
    v = -st * dx + ct_ * dy

    def inside(rx_, ry_):
        return (u / rx_) ** 2 + (v / ry_) ** 2 <= 1.0

    labels[inside(rx, ry)] = 3
    labels[inside(rx - skull_t, ry - skull_t)] = 1

    for side in (-1.0, 1.0):
        vx = c + side * rng.uniform(0.08, 0.14) * size
        vy = c + rng.uniform(-0.05, 0.05) * size
        vrx = rng.uniform(0.04, 0.07) * size
        vry = rng.uniform(0.08, 0.13) * size
        mask = ((xx - vx) / vrx) ** 2 + ((yy - vy) / vry) ** 2 <= 1.0
        labels[mask & (labels == 1)] = 2

    lesions = []
    if rng.uniform() < lesion_prob:
        for _ in range(1 + rng.integers(0, 2)):
            lrx = rng.uniform(0.05, 0.12) * size
            lry = rng.uniform(0.05, 0.12) * size
            lx = c + rng.uniform(-0.22, 0.22) * size
            ly = c + rng.uniform(-0.25, 0.25) * size
            mask = ((xx - lx) / lrx) ** 2 + ((yy - ly) / lry) ** 2 <= 1.0
            hit = mask & (labels == 1)
            if hit.any():
                labels[hit] = 4
                lesions.append((lx, ly, 2 * lrx, 2 * lry))

    ct_img = np.select(
        [labels == 1, labels == 2, labels == 3, labels == 4],
        [CT_TISSUE, CT_VENT, CT_BONE, CT_LESION],
        CT_AIR,
    ).astype(np.float32)
    ct_img = np.clip(ct_img + noise_sigma * rng.standard_normal(ct_img.shape), 0, 1)

    mri_img = np.vectorize(MRI.get)(labels).astype(np.float32)
    mri_img = box_blur3(mri_img)
    return ct_img.astype(np.float32), mri_img, lesions


def batch(rng, n, size=64, **kw):
    """(ct, mri) batches scaled to [-1, 1], NHWC single channel."""
    cts, mris = [], []
    for _ in range(n):
        ct_img, mri_img, _ = paired_sample(rng, size=size, **kw)
        cts.append(ct_img)
        mris.append(mri_img)
    ct_b = np.stack(cts)[..., None] * 2.0 - 1.0
    mri_b = np.stack(mris)[..., None] * 2.0 - 1.0
    return ct_b.astype(np.float32), mri_b.astype(np.float32)
