"""Layer-2 JAX models: Pix2Pix (three variants) + YOLO-lite.

Functional-style: `init_*` builds a parameter pytree (a flat list of
(name, array) pairs so the AOT export and the rust weights loader agree on
ordering), `generator_apply` / `discriminator_apply` / `yolo_apply` run the
forward pass. `use_pallas=True` routes the compute through the Layer-1
kernels (the path that is AOT-lowered); `use_pallas=False` uses the ref
ops (identical math, used for training speed). pytest asserts the two
paths agree.

Scaled configuration (CPU-trainable): 64x64 single-channel phantoms,
ngf=16, 6 down / 5 up blocks -- the full-size 8/7 graph at 256x256 lives in
the rust IR (`models/pix2pix.rs`) for the timing experiments.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import conv as kconv
from .kernels import deconv as kdeconv
from .kernels import norm_act as knorm
from .kernels import ref as kref

VARIANTS = ("original", "cropping", "convolution")


@dataclasses.dataclass(frozen=True)
class GanConfig:
    image_size: int = 64
    channels: int = 1
    ngf: int = 16
    depth: int = 6  # number of down-sampling blocks

    def enc_filters(self, i):
        return self.ngf * [1, 2, 4, 8, 8, 8, 8, 8][min(i, 7)]


def _conv_init(key, kh, kw, cin, cout, scale=0.02):
    return scale * jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)


def init_generator(key, cfg: GanConfig, variant: str):
    """Parameter list for one generator variant.

    Returns a list of (name, array); ordering is the artifact ABI.
    """
    assert variant in VARIANTS, variant
    params = []
    keys = iter(jax.random.split(key, 64))

    c_in = cfg.channels
    for i in range(cfg.depth):
        c_out = cfg.enc_filters(i)
        params.append((f"enc{i}_w", _conv_init(next(keys), 4, 4, c_in, c_out)))
        if i > 0:
            params.append((f"enc{i}_scale", jnp.ones((c_out,), jnp.float32)))
            params.append((f"enc{i}_shift", jnp.zeros((c_out,), jnp.float32)))
        c_in = c_out

    for i in range(cfg.depth - 1):
        c_out = cfg.enc_filters(cfg.depth - 2 - i)
        params.append((f"dec{i}_w", _conv_init(next(keys), 4, 4, c_in, c_out)))
        params.append((f"dec{i}_scale", jnp.ones((c_out,), jnp.float32)))
        params.append((f"dec{i}_shift", jnp.zeros((c_out,), jnp.float32)))
        if variant == "convolution":
            params.append((f"dec{i}_fix_w", _conv_init(next(keys), 3, 3, c_out, c_out)))
        # after concat with the skip the channel count doubles
        c_in = c_out * 2

    params.append(("final_w", _conv_init(next(keys), 4, 4, c_in, cfg.channels)))
    params.append(("final_b", jnp.zeros((cfg.channels,), jnp.float32)))
    if variant == "convolution":
        params.append(
            ("final_fix_w", _conv_init(next(keys), 3, 3, cfg.channels, cfg.channels))
        )
    return params


def _ops(use_pallas):
    if use_pallas:
        return (
            lambda x, w, s, p: kconv.conv2d(x, w, stride=s, padding=p),
            lambda x, w, s, p: kdeconv.conv_transpose2d(x, w, stride=s, padding=p),
            lambda x: kdeconv.crop(x, 1),
            lambda x, sc, sh, act: knorm.bn_act(x, sc, sh, act=act),
        )
    return (
        lambda x, w, s, p: kref.conv2d_ref(x, w, stride=s, padding=p),
        lambda x, w, s, p: kref.conv_transpose2d_ref(x, w, stride=s, padding=p),
        lambda x: kref.crop_ref(x, 1),
        lambda x, sc, sh, act: kref.bn_act_ref(x, sc, sh, act=act),
    )


def generator_apply(params, x, cfg: GanConfig, variant: str, use_pallas=False):
    """Forward pass. x: (N, H, W, C) in [-1, 1]; returns same shape."""
    p = dict(params)
    conv, deconv, crop, bn_act = _ops(use_pallas)

    def up(x, w, fix_w):
        """One up-sampling step under the given variant (paper §V.A.2)."""
        if variant == "original":
            return deconv(x, w, 2, 1)  # Eq. 6: out = 2*in
        y = deconv(x, w, 2, 0)  # Eq. 5: out = 2*in + 2
        if variant == "cropping":
            return crop(y)  # Eq. 7: trim 1/side
        # convolution variant: stride-1 VALID 3x3, Eq. 9 (bias-free)
        return conv(y, fix_w, 1, 0)

    skips = []
    h = x
    for i in range(cfg.depth):
        h = conv(h, p[f"enc{i}_w"], 2, 1)
        if i > 0:
            h = bn_act(h, p[f"enc{i}_scale"], p[f"enc{i}_shift"], "leaky_relu")
        else:
            h = jnp.where(h >= 0, h, 0.2 * h)
        skips.append(h)

    for i in range(cfg.depth - 1):
        h = up(h, p[f"dec{i}_w"], p.get(f"dec{i}_fix_w"))
        h = bn_act(h, p[f"dec{i}_scale"], p[f"dec{i}_shift"], "relu")
        h = jnp.concatenate([h, skips[cfg.depth - 2 - i]], axis=-1)

    h = up(h, p["final_w"], p.get("final_fix_w"))
    h = h + p["final_b"]
    return jnp.tanh(h)


def init_discriminator(key, cfg: GanConfig):
    """70x70-style PatchGAN on (ct, mri) pairs (scaled widths)."""
    params = []
    keys = iter(jax.random.split(key, 16))
    c_in = cfg.channels * 2
    for i, mult in enumerate([1, 2, 4]):
        c_out = cfg.ngf * mult
        params.append((f"d{i}_w", _conv_init(next(keys), 4, 4, c_in, c_out)))
        if i > 0:
            params.append((f"d{i}_scale", jnp.ones((c_out,), jnp.float32)))
            params.append((f"d{i}_shift", jnp.zeros((c_out,), jnp.float32)))
        c_in = c_out
    params.append(("d3_w", _conv_init(next(keys), 4, 4, c_in, cfg.ngf * 8)))
    params.append(("d3_scale", jnp.ones((cfg.ngf * 8,), jnp.float32)))
    params.append(("d3_shift", jnp.zeros((cfg.ngf * 8,), jnp.float32)))
    params.append(("patch_w", _conv_init(next(keys), 4, 4, cfg.ngf * 8, 1)))
    params.append(("patch_b", jnp.zeros((1,), jnp.float32)))
    return params


def discriminator_apply(params, ct, mri, cfg: GanConfig):
    p = dict(params)
    h = jnp.concatenate([ct, mri], axis=-1)
    for i in range(3):
        h = kref.conv2d_ref(h, p[f"d{i}_w"], stride=2, padding=1)
        if i > 0:
            h = kref.bn_act_ref(h, p[f"d{i}_scale"], p[f"d{i}_shift"], "leaky_relu")
        else:
            h = jnp.where(h >= 0, h, 0.2 * h)
    h = jnp.pad(h, ((0, 0), (1, 1), (1, 1), (0, 0)))
    h = kref.conv2d_ref(h, p["d3_w"], stride=1, padding=0)
    h = kref.bn_act_ref(h, p["d3_scale"], p["d3_shift"], "leaky_relu")
    h = jnp.pad(h, ((0, 0), (1, 1), (1, 1), (0, 0)))
    h = kref.conv2d_ref(h, p["patch_w"], stride=1, padding=0) + p["patch_b"]
    return h  # logits patch map


# ---------------------------------------------------------------------------
# YOLO-lite detector (compiled to an artifact; weights are untrained — the
# stroke dataset [35] is private; see DESIGN.md substitution table).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class YoloConfig:
    image_size: int = 64
    channels: int = 1
    width: int = 8
    num_classes: int = 1
    reg_max: int = 4


def init_yolo(key, cfg: YoloConfig):
    params = []
    keys = iter(jax.random.split(key, 64))
    w = cfg.width

    def add_cbs(name, cin, cout, k):
        params.append((f"{name}_w", _conv_init(next(keys), k, k, cin, cout)))
        params.append((f"{name}_scale", jnp.ones((cout,), jnp.float32)))
        params.append((f"{name}_shift", jnp.zeros((cout,), jnp.float32)))

    add_cbs("stem", cfg.channels, w, 3)        # /2
    add_cbs("down1", w, w * 2, 3)              # /4
    add_cbs("b1", w * 2, w * 2, 3)
    add_cbs("down2", w * 2, w * 4, 3)          # /8
    add_cbs("b2", w * 4, w * 4, 3)
    add_cbs("down3", w * 4, w * 8, 3)          # /16
    add_cbs("b3", w * 8, w * 8, 3)
    add_cbs("down4", w * 8, w * 16, 3)         # /32
    nout = 4 * cfg.reg_max + cfg.num_classes
    for scale, cin in (("p3", w * 4), ("p4", w * 8), ("p5", w * 16)):
        add_cbs(f"head_{scale}_1", cin, w * 4, 3)
        params.append((f"head_{scale}_pred_w", _conv_init(next(keys), 1, 1, w * 4, nout)))
        params.append((f"head_{scale}_pred_b", jnp.zeros((nout,), jnp.float32)))
    return params


def yolo_apply(params, x, cfg: YoloConfig, use_pallas=False):
    """Returns three feature maps (N, s, s, 4*reg_max + classes) at /8 /16 /32."""
    p = dict(params)
    conv, _, _, bn_act = _ops(use_pallas)

    def cbs(name, h, stride):
        h = conv(h, p[f"{name}_w"], stride, 1)
        return bn_act(h, p[f"{name}_scale"], p[f"{name}_shift"], "silu")

    h = cbs("stem", x, 2)
    h = cbs("down1", h, 2)
    h = cbs("b1", h, 1)
    h = cbs("down2", h, 2)
    p3 = cbs("b2", h, 1)
    h = cbs("down3", p3, 2)
    p4 = cbs("b3", h, 1)
    p5 = cbs("down4", p4, 2)

    outs = []
    for scale, feat in (("p3", p3), ("p4", p4), ("p5", p5)):
        f = cbs(f"head_{scale}_1", feat, 1)
        pred = conv(f, p[f"head_{scale}_pred_w"], 1, 0) + p[f"head_{scale}_pred_b"]
        outs.append(pred)
    return tuple(outs)


def param_vector_names(params):
    return [name for name, _ in params]


def param_count(params):
    return sum(int(math.prod(a.shape)) for _, a in params)
