"""Layer-1 Pallas kernels (build-time only).

The paper's compute hot-spot is 4x4 / stride-2 (de)convolution on an edge
GPU. On the TPU-shaped target modelled here (see DESIGN.md
"Hardware-Adaptation"), the same work is expressed as im2col + MXU matmul
tiles: `conv.py` carries the GEMM kernel with VMEM-tiled BlockSpecs,
`deconv.py` expresses transposed convolution as zero-insertion + conv (the
identity behind the paper's Eqs. 4-7) plus the crop/VALID-conv padding
surgeries, and `norm_act.py` holds the fused pointwise kernels.

All kernels run with ``interpret=True`` -- the CPU PJRT plugin cannot
execute Mosaic custom-calls; real-TPU performance is estimated analytically
in DESIGN.md SPerf.
"""

from . import conv, deconv, norm_act, ref  # noqa: F401
