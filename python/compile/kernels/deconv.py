"""Transposed convolution and the paper's padding surgeries.

Deconvolution is expressed as zero-insertion (stride-1 dilation of the
input) followed by a VALID convolution with the spatially-flipped kernel --
the identity behind the paper's Eqs. 4-7. The padded variant (`padding=1`,
DLA-incompatible in the paper) trims the border of the unpadded result;
the two surgeries reproduce that trim with DLA-friendly ops:

  * ``crop``       -- remove `border` rows/cols per side (Eq. 7);
  * a stride-1 VALID 3x3 conv (built in the model from `conv.conv2d`)
    shrinks by the same amount (Eq. 9).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import conv


def zero_insert(x, stride):
    """Dilate (N, H, W, C) spatially by `stride` (zero-insertion)."""
    if stride == 1:
        return x
    n, h, w, c = x.shape
    out = jnp.zeros((n, h * stride - (stride - 1), w * stride - (stride - 1), c), x.dtype)
    return out.at[:, ::stride, ::stride, :].set(x)


def conv_transpose2d(x, w, b=None, stride=2, padding=0, interpret=True):
    """NHWC transposed conv, kernel (KH, KW, Cin, Cout).

    out_size = stride*(in-1) + k - 2*padding   (paper Eq. 4)
    """
    kh, kw, _, _ = w.shape
    # zero-insert, then full conv with flipped kernel
    xd = zero_insert(x, stride)
    wf = w[::-1, ::-1, :, :]
    y = conv.conv2d(xd, wf, b=None, stride=1, padding=kh - 1, interpret=interpret)
    if padding > 0:
        y = y[:, padding:-padding, padding:-padding, :]
    if b is not None:
        y = y + b
    return y


def _crop_kernel(x_ref, o_ref, *, border):
    o_ref[...] = x_ref[border:-border, border:-border, :]


def crop(x, border=1, interpret=True):
    """Crop `border` rows/cols from each side (paper Eq. 7) as a Pallas
    kernel (the DLA-compatible padding substitute)."""
    n, h, w, c = x.shape
    assert h > 2 * border and w > 2 * border, "crop larger than image"
    out_shape = jax.ShapeDtypeStruct((h - 2 * border, w - 2 * border, c), x.dtype)

    def one(img):
        return pl.pallas_call(
            lambda x_ref, o_ref: _crop_kernel(x_ref, o_ref, border=border),
            out_shape=out_shape,
            interpret=interpret,
        )(img)

    return jax.vmap(one)(x)
