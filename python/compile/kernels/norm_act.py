"""Fused pointwise Pallas kernels: batch-norm + activation.

At inference batch-norm is an affine transform; fusing it with the
following activation keeps the tensor in VMEM for a single pass -- the
pointwise-fusion trick every edge runtime (TensorRT included) applies.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bn_act_kernel(x_ref, scale_ref, shift_ref, o_ref, *, act, slope):
    y = x_ref[...] * scale_ref[...] + shift_ref[...]
    if act == "leaky_relu":
        y = jnp.where(y >= 0, y, slope * y)
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "tanh":
        y = jnp.tanh(y)
    elif act == "silu":
        y = y * jax.nn.sigmoid(y)
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("act", "slope", "interpret"))
def bn_act(x, scale, shift, act="leaky_relu", slope=0.2, interpret=True):
    """Fused `x * scale + shift` + activation over NHWC, per-channel affine."""
    n, h, w, c = x.shape
    scale_b = jnp.broadcast_to(scale, (h, w, c))
    shift_b = jnp.broadcast_to(shift, (h, w, c))

    def one(img):
        return pl.pallas_call(
            functools.partial(_bn_act_kernel, act=act, slope=slope),
            out_shape=jax.ShapeDtypeStruct((h, w, c), x.dtype),
            interpret=interpret,
        )(img, scale_b, shift_b)

    return jax.vmap(one)(x)


def batchnorm_params(mean, var, gamma, beta, eps=1e-3):
    """Fold BN statistics into the (scale, shift) affine pair."""
    scale = gamma / jnp.sqrt(var + eps)
    shift = beta - mean * scale
    return scale, shift
