"""Pallas conv2d as im2col + tiled MXU matmul.

The convolution is reshaped into a GEMM:

    patches : (N*OH*OW, KH*KW*Cin)   (im2col, computed in JAX)
    weights : (KH*KW*Cin, Cout)
    out     : (N*OH*OW, Cout)

and the GEMM itself is the Pallas kernel, tiled with BlockSpec so each
(BM, BK) x (BK, BN) product is VMEM-resident and lands on the MXU. This is
the HBM<->VMEM schedule a CUDA kernel would express with threadblocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned tile sizes (multiples of the 128x128 systolic array; smaller
# inputs fall back to the full-array tile).
BM = 128
BN = 128
BK = 128


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps):
    """One (BM, BN) output tile; accumulate over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(x, w, interpret=True):
    """Tiled Pallas matmul `x @ w` with fp32 accumulation."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    xp = _pad_to(_pad_to(x, BM, 0), BK, 1)
    wp = _pad_to(_pad_to(w, BK, 0), BN, 1)
    mp, kp = xp.shape
    _, np_ = wp.shape
    k_steps = kp // BK  # accumulation depth over the K axis
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // BM, np_ // BN, k_steps),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]


def im2col(x, kh, kw, stride, padding):
    """Extract convolution patches.

    x: (N, H, W, C) -> (N, OH, OW, KH*KW*C)
    """
    n, h, w, c = x.shape
    if padding > 0:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    # Gather patches via slicing (static unroll over the small kernel).
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            sl = x[:, dy : dy + stride * oh : stride, dx : dx + stride * ow : stride, :]
            cols.append(sl)
    patches = jnp.concatenate(cols, axis=-1)  # (N, OH, OW, KH*KW*C)
    return patches, oh, ow


def conv2d(x, w, b=None, stride=2, padding=1, interpret=True):
    """NHWC conv2d with an HWIO kernel via im2col + Pallas GEMM.

    x: (N, H, W, Cin); w: (KH, KW, Cin, Cout).
    """
    kh, kw, cin, cout = w.shape
    patches, oh, ow = im2col(x, kh, kw, stride, padding)
    n = x.shape[0]
    a = patches.reshape(n * oh * ow, kh * kw * cin)
    wm = w.reshape(kh * kw * cin, cout)
    y = matmul(a, wm, interpret=interpret)
    y = y.reshape(n, oh, ow, cout)
    if b is not None:
        y = y + b
    return y
