"""Pure-JAX correctness oracles for the Pallas kernels.

These use `lax.conv_general_dilated` / plain jnp -- an entirely different
code path from the im2col + Pallas GEMM kernels -- so agreement is a real
correctness signal (the CORE build-time check, run by pytest).
"""

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def conv2d_ref(x, w, b=None, stride=2, padding=1):
    """NHWC conv, HWIO kernel, via lax.conv_general_dilated."""
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b
    return y


def conv_transpose2d_ref(x, w, b=None, stride=2, padding=0):
    """Transposed conv via input-dilated lax conv (gradient trick)."""
    kh, kw, _, _ = w.shape
    y = lax.conv_general_dilated(
        x,
        w[::-1, ::-1, :, :],
        window_strides=(1, 1),
        padding=((kh - 1 - padding, kh - 1 - padding), (kw - 1 - padding, kw - 1 - padding)),
        lhs_dilation=(stride, stride),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b
    return y


def crop_ref(x, border=1):
    return x[:, border:-border, border:-border, :]


def bn_act_ref(x, scale, shift, act="leaky_relu", slope=0.2):
    y = x * scale + shift
    if act == "leaky_relu":
        return jnp.where(y >= 0, y, slope * y)
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "tanh":
        return jnp.tanh(y)
    if act == "silu":
        return y * jax.nn.sigmoid(y)
    return y
