"""Export the Pallas GEMM kernel as a standalone smoke artifact.

The full interpret-lowered Pallas models trigger a pathological slowdown in
xla_extension 0.5.1 (see DESIGN.md §Hardware-Adaptation note); the runtime
artifacts are therefore lowered through the ref ops (pytest proves the two
paths agree numerically), and this one-kernel artifact keeps the
Pallas -> HLO text -> rust PJRT path exercised end to end
(`integration_runtime::pallas_smoke_artifact_roundtrip`).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp

from . import aot
from .kernels import conv


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    def fn(x, w):
        # NHWC-rank input so the rust Artifact ABI (rank-4 frames) applies.
        return (conv.matmul(x.reshape(128, 128), w, interpret=True).reshape(1, 1, 128, 128),)

    x_spec = jax.ShapeDtypeStruct((1, 1, 128, 128), jnp.float32)
    w_spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    lowered = jax.jit(fn).lower(x_spec, w_spec)
    base = os.path.join(args.out, "pallas_matmul")
    with open(base + ".hlo.txt", "w") as f:
        f.write(aot.to_hlo_text(lowered))
    aot.write_weights_bin(base + ".weights.bin", [jnp.eye(128)])
    with open(base + ".meta.json", "w") as f:
        json.dump(
            {"model": "pallas_matmul", "input": [1, 1, 128, 128], "params": ["w"], "pallas": True},
            f,
        )
    print(f"wrote {base}.hlo.txt")


if __name__ == "__main__":
    main()
