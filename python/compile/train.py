"""Pix2Pix GAN training + Table II accuracy comparison.

Trains the three generator variants (original / cropping / convolution)
with the paper's objective (generator: BCE adversarial + 100 * L1; see
[27]) on paired synthetic phantoms, evaluates SSIM / PSNR / MSE on a
held-out set, and writes checkpoints + a table2.json summary.

Usage:  python -m compile.train --steps 300 --out ../artifacts
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import (
    GanConfig,
    VARIANTS,
    discriminator_apply,
    generator_apply,
    init_discriminator,
    init_generator,
)

L1_WEIGHT = 100.0


def bce_logits(logits, target):
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * target + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def adam_init(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "t": jnp.zeros((), jnp.int32),
    }


def adam_step(params, grads, state, lr=2e-4, b1=0.5, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    tf = t.astype(jnp.float32)
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    new_p = jax.tree.map(
        lambda p_, m_, v_: p_
        - lr * (m_ / (1 - b1**tf)) / (jnp.sqrt(v_ / (1 - b2**tf)) + eps),
        params,
        m,
        v,
    )
    return new_p, {"m": m, "v": v, "t": t}


def make_train_step(cfg, variant):
    def g_loss_fn(g_params, d_params, ct, mri):
        fake = generator_apply(g_params, ct, cfg, variant)
        d_fake = discriminator_apply(d_params, ct, fake, cfg)
        adv = bce_logits(d_fake, jnp.ones_like(d_fake))
        l1 = jnp.mean(jnp.abs(fake - mri))
        return adv + L1_WEIGHT * l1, (adv, l1)

    def d_loss_fn(d_params, g_params, ct, mri):
        fake = generator_apply(g_params, ct, cfg, variant)
        d_real = discriminator_apply(d_params, ct, mri, cfg)
        d_fake = discriminator_apply(d_params, ct, fake, cfg)
        return bce_logits(d_real, jnp.ones_like(d_real)) + bce_logits(
            d_fake, jnp.zeros_like(d_fake)
        )

    @jax.jit
    def step(g_params, d_params, g_opt, d_opt, ct, mri):
        (gl, (_adv, l1)), g_grads = jax.value_and_grad(g_loss_fn, has_aux=True)(
            g_params, d_params, ct, mri
        )
        g_params, g_opt = adam_step(g_params, g_grads, g_opt)

        dl, d_grads = jax.value_and_grad(d_loss_fn)(d_params, g_params, ct, mri)
        d_params, d_opt = adam_step(d_params, d_grads, d_opt)
        return g_params, d_params, g_opt, d_opt, gl, dl, l1

    return step


# --- evaluation metrics (match rust imaging/metrics.rs conventions) -------

def mse_8bit(a, b):
    return float(np.mean(((a - b) * 255.0) ** 2))


def psnr(a, b):
    m = mse_8bit(a, b)
    return float("inf") if m == 0 else 10.0 * np.log10(255.0 * 255.0 / m)


def ssim(a, b, win=8, stride=4):
    l = 255.0
    c1, c2 = (0.01 * l) ** 2, (0.03 * l) ** 2
    a = a * 255.0
    b = b * 255.0
    vals = []
    for y in range(0, a.shape[0] - win + 1, stride):
        for x in range(0, a.shape[1] - win + 1, stride):
            pa = a[y : y + win, x : x + win]
            pb = b[y : y + win, x : x + win]
            ma, mb = pa.mean(), pb.mean()
            va, vb = pa.var(), pb.var()
            cov = ((pa - ma) * (pb - mb)).mean()
            vals.append(
                ((2 * ma * mb + c1) * (2 * cov + c2))
                / ((ma * ma + mb * mb + c1) * (va + vb + c2))
            )
    return float(np.mean(vals))


def evaluate(g_params, cfg, variant, n=32, seed=999):
    rng = np.random.default_rng(seed)
    ct, mri = data.batch(rng, n, size=cfg.image_size)
    fake = np.array(generator_apply(g_params, jnp.asarray(ct), cfg, variant))
    # back to [0, 1]
    fake01 = (fake[..., 0] + 1.0) / 2.0
    mri01 = (mri[..., 0] + 1.0) / 2.0
    return {
        "ssim_pct": 100.0 * float(np.mean([ssim(mri01[i], fake01[i]) for i in range(n)])),
        "psnr": float(np.mean([psnr(mri01[i], fake01[i]) for i in range(n)])),
        "mse": float(np.mean([mse_8bit(mri01[i], fake01[i]) for i in range(n)])),
    }


def save_params(params, path):
    if isinstance(params, dict):
        np.savez(path, **{k: np.array(v) for k, v in params.items()})
    else:
        np.savez(path, **{name: np.array(a) for name, a in params})


def load_params(path):
    z = np.load(path)
    return [(name, jnp.asarray(z[name])) for name in z.files]


def train_variant(variant, steps, batch_size, cfg, seed=0, log_every=50):
    key = jax.random.PRNGKey(seed)
    gk, dk = jax.random.split(key)
    g_params = dict(init_generator(gk, cfg, variant))
    d_params = dict(init_discriminator(dk, cfg))
    g_opt, d_opt = adam_init(g_params), adam_init(d_params)
    step = make_train_step(cfg, variant)
    rng = np.random.default_rng(seed + 1)
    t0 = time.time()
    losses = []
    for i in range(steps):
        ct, mri = data.batch(rng, batch_size, size=cfg.image_size)
        g_params, d_params, g_opt, d_opt, gl, dl, l1 = step(
            g_params, d_params, g_opt, d_opt, jnp.asarray(ct), jnp.asarray(mri)
        )
        losses.append(float(l1))
        if (i + 1) % log_every == 0 or i == 0:
            print(
                f"[{variant}] step {i + 1:4d}/{steps} g={float(gl):7.3f} "
                f"d={float(dl):6.3f} L1={float(l1):6.4f} ({time.time() - t0:5.1f}s)",
                flush=True,
            )
    return g_params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variants", nargs="*", default=list(VARIANTS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = GanConfig()
    os.makedirs(args.out, exist_ok=True)
    table2 = {}
    for variant in args.variants:
        g_params, losses = train_variant(variant, args.steps, args.batch, cfg, args.seed)
        metrics = evaluate(g_params, cfg, variant)
        metrics["params"] = int(sum(int(np.prod(a.shape)) for a in g_params.values()))
        metrics["final_l1"] = losses[-1]
        table2[variant] = metrics
        save_params(g_params, os.path.join(args.out, f"gen_{variant}.npz"))
        print(f"[{variant}] {metrics}")

    # Merge with prior runs so per-variant retraining keeps the table whole.
    path = os.path.join(args.out, "table2.json")
    merged = {}
    if os.path.exists(path):
        with open(path) as f:
            merged = json.load(f)
    merged.update(table2)
    table2 = merged
    with open(path, "w") as f:
        json.dump(table2, f, indent=2)
    print(json.dumps(table2, indent=2))


if __name__ == "__main__":
    main()
