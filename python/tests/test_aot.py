"""AOT export tests: HLO text validity and weights.bin format."""

import os
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import GanConfig, init_generator, generator_apply


@pytest.fixture(scope="module")
def tiny_export(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = GanConfig(image_size=32, ngf=4, depth=4)
    params = init_generator(jax.random.PRNGKey(0), cfg, "cropping")
    base = aot.export_generator(out, "cropping", cfg, params, use_pallas=True)
    return base, cfg, params


def test_hlo_text_written(tiny_export):
    base, _, _ = tiny_export
    text = open(base + ".hlo.txt").read()
    assert text.startswith("HloModule")
    assert "f32[" in text
    # parameters: ct + every weight tensor
    assert "parameter(0)" in text


def test_weights_bin_roundtrip(tiny_export):
    base, _, params = tiny_export
    raw = open(base + ".weights.bin", "rb").read()
    assert raw[:4] == b"EPW1"
    (count,) = struct.unpack_from("<I", raw, 4)
    assert count == len(params)
    # walk the format and compare the first tensor
    off = 8
    (rank,) = struct.unpack_from("<I", raw, off)
    off += 4
    dims = struct.unpack_from(f"<{rank}I", raw, off)
    off += 4 * rank
    n = int(np.prod(dims))
    first = np.frombuffer(raw, np.float32, n, off).reshape(dims)
    np.testing.assert_allclose(first, np.array(params[0][1]), rtol=1e-6)


def test_meta_json(tiny_export):
    import json

    base, cfg, params = tiny_export
    meta = json.load(open(base + ".meta.json"))
    assert meta["input"] == [1, cfg.image_size, cfg.image_size, 1]
    assert meta["params"] == [n for n, _ in params]
    assert meta["pallas"] is True


def test_lowered_function_still_executes(tiny_export):
    """The exported computation must agree with direct evaluation."""
    base, cfg, params = tiny_export
    ct = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 1), jnp.float32)
    direct = generator_apply(dict(params), ct, cfg, "cropping", use_pallas=False)

    names = [n for n, _ in params]

    def fn(ct, *weights):
        return generator_apply(dict(zip(names, weights)), ct, cfg, "cropping", True)

    out = jax.jit(fn)(ct, *[a for _, a in params])
    np.testing.assert_allclose(np.array(out), np.array(direct), rtol=5e-5, atol=5e-5)
