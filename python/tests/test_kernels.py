"""Pallas kernels vs pure-JAX oracles — the core build-time correctness
signal. Hypothesis sweeps shapes/strides/paddings; assert_allclose against
ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv, deconv, norm_act, ref

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- matmul --

@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
)
def test_matmul_matches_ref(m, k, n):
    x = rand(m * 7 + k, (m, k))
    w = rand(n * 13 + k, (k, n), 0.1)
    np.testing.assert_allclose(
        np.array(conv.matmul(x, w)), np.array(ref.matmul_ref(x, w)), **TOL
    )


def test_matmul_tile_boundaries():
    # Exactly one tile, one tile + 1, and multi-tile shapes.
    for m, k, n in [(128, 128, 128), (129, 127, 130), (256, 384, 128), (1, 1, 1)]:
        x = rand(m + k, (m, k))
        w = rand(n + k, (k, n), 0.1)
        np.testing.assert_allclose(
            np.array(conv.matmul(x, w)), np.array(ref.matmul_ref(x, w)), **TOL
        )


# ------------------------------------------------------------------ conv --

@settings(max_examples=16, deadline=None)
@given(
    hw=st.integers(4, 24),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    stride=st.integers(1, 2),
    padding=st.integers(0, 2),
    kernel=st.sampled_from([1, 3, 4]),
)
def test_conv2d_matches_ref(hw, cin, cout, stride, padding, kernel):
    if hw + 2 * padding < kernel:
        return
    x = rand(hw * cin + cout, (2, hw, hw, cin))
    w = rand(hw + cin * cout, (kernel, kernel, cin, cout), 0.1)
    got = conv.conv2d(x, w, stride=stride, padding=padding)
    want = ref.conv2d_ref(x, w, stride=stride, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.array(got), np.array(want), **TOL)


def test_conv2d_with_bias():
    x = rand(1, (1, 8, 8, 4))
    w = rand(2, (3, 3, 4, 6), 0.1)
    b = rand(3, (6,))
    got = conv.conv2d(x, w, b=b, stride=1, padding=1)
    want = ref.conv2d_ref(x, w, b=b, stride=1, padding=1)
    np.testing.assert_allclose(np.array(got), np.array(want), **TOL)


# ---------------------------------------------------------------- deconv --

@settings(max_examples=12, deadline=None)
@given(
    hw=st.integers(2, 12),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    padding=st.integers(0, 1),
)
def test_deconv_matches_ref(hw, cin, cout, padding):
    x = rand(hw + cin, (1, hw, hw, cin))
    w = rand(cout + hw, (4, 4, cin, cout), 0.1)
    got = deconv.conv_transpose2d(x, w, stride=2, padding=padding)
    want = ref.conv_transpose2d_ref(x, w, stride=2, padding=padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.array(got), np.array(want), **TOL)


def test_deconv_output_sizes_paper_eqs():
    """Paper Eq. 5 (p=0: out = 2*in + 2) and Eq. 6 (p=1: out = 2*in)."""
    x = rand(1, (1, 8, 8, 4))
    w = rand(2, (4, 4, 4, 4), 0.1)
    assert deconv.conv_transpose2d(x, w, stride=2, padding=0).shape[1] == 18
    assert deconv.conv_transpose2d(x, w, stride=2, padding=1).shape[1] == 16


def test_padding_surgery_equivalence():
    """The paper's claim behind Table II: deconv(p=1) produces the same
    *interior* values as deconv(p=0) + crop(1)."""
    x = rand(1, (1, 8, 8, 4))
    w = rand(2, (4, 4, 4, 4), 0.1)
    padded = deconv.conv_transpose2d(x, w, stride=2, padding=1)
    cropped = deconv.crop(deconv.conv_transpose2d(x, w, stride=2, padding=0), 1)
    np.testing.assert_allclose(np.array(padded), np.array(cropped), **TOL)


def test_zero_insert():
    x = jnp.arange(4.0).reshape(1, 2, 2, 1)
    y = deconv.zero_insert(x, 2)
    assert y.shape == (1, 3, 3, 1)
    assert y[0, 0, 0, 0] == 0.0
    assert y[0, 2, 2, 0] == 3.0
    assert y[0, 1, 1, 0] == 0.0


def test_crop_matches_ref():
    x = rand(5, (2, 10, 10, 3))
    np.testing.assert_allclose(
        np.array(deconv.crop(x, 2)), np.array(ref.crop_ref(x, 2)), **TOL
    )


# --------------------------------------------------------------- norm_act --

@pytest.mark.parametrize("act", ["leaky_relu", "relu", "tanh", "silu"])
def test_bn_act_matches_ref(act):
    x = rand(11, (2, 8, 8, 6))
    scale = rand(12, (6,))
    shift = rand(13, (6,))
    got = norm_act.bn_act(x, scale, shift, act=act)
    want = ref.bn_act_ref(x, scale, shift, act=act)
    np.testing.assert_allclose(np.array(got), np.array(want), **TOL)


def test_batchnorm_fold():
    mean = rand(1, (4,))
    var = jnp.abs(rand(2, (4,))) + 0.5
    gamma = rand(3, (4,))
    beta = rand(4, (4,))
    scale, shift = norm_act.batchnorm_params(mean, var, gamma, beta)
    x = rand(5, (1, 4, 4, 4))
    direct = gamma * (x - mean) / jnp.sqrt(var + 1e-3) + beta
    np.testing.assert_allclose(
        np.array(x * scale + shift), np.array(direct), rtol=1e-4, atol=1e-4
    )
