"""Training smoke tests (kept tiny: a handful of steps on 32x32)."""

import jax
import numpy as np

from compile import data, train
from compile.model import GanConfig


def test_losses_decrease_over_few_steps():
    cfg = GanConfig(image_size=32, ngf=4, depth=4)
    g_params, losses = train.train_variant(
        "cropping", steps=8, batch_size=4, cfg=cfg, seed=3, log_every=100
    )
    assert len(losses) == 8
    # L1 should drop from the random-init level within a few steps
    assert losses[-1] < losses[0]


def test_metrics_functions():
    a = np.zeros((32, 32), np.float32)
    b = np.ones((32, 32), np.float32)
    assert train.mse_8bit(a, a) == 0.0
    assert train.psnr(a, a) == float("inf")
    assert abs(train.mse_8bit(a, b) - 255.0**2) < 1e-3
    assert train.ssim(a, a) > 0.99


def test_evaluate_returns_all_metrics():
    cfg = GanConfig(image_size=32, ngf=4, depth=4)
    g_params = dict(
        __import__("compile.model", fromlist=["init_generator"]).init_generator(
            jax.random.PRNGKey(0), cfg, "original"
        )
    )
    m = train.evaluate(g_params, cfg, "original", n=2, seed=1)
    assert set(m) == {"ssim_pct", "psnr", "mse"}
    assert 0 <= m["ssim_pct"] <= 100


def test_adam_moves_params():
    import jax.numpy as jnp

    params = {"w": jnp.ones((3,))}
    grads = {"w": jnp.ones((3,))}
    st = train.adam_init(params)
    new, st2 = train.adam_step(params, grads, st)
    assert not np.allclose(np.array(new["w"]), np.array(params["w"]))
    assert int(st2["t"]) == 1
