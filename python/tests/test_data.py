"""Phantom data generator tests (mirrors rust imaging/phantom.rs)."""

import numpy as np

from compile import data


def test_sample_shapes_and_range():
    rng = np.random.default_rng(1)
    ct, mri, lesions = data.paired_sample(rng, size=64)
    assert ct.shape == (64, 64)
    assert mri.shape == (64, 64)
    assert 0.0 <= ct.min() and ct.max() <= 1.0
    assert ct.max() > 0.8  # bright skull present


def test_mri_contrast_inverted_for_bone():
    """Bone is bright on CT, dark on the MRI remap."""
    rng = np.random.default_rng(2)
    ct, mri, _ = data.paired_sample(rng, size=64, noise_sigma=0.0)
    bone = ct > 0.9
    assert bone.any()
    assert mri[bone].mean() < 0.3


def test_lesion_probability_extremes():
    rng = np.random.default_rng(3)
    none = [data.paired_sample(rng, lesion_prob=0.0)[2] for _ in range(5)]
    assert all(len(l) == 0 for l in none)
    some = [data.paired_sample(rng, lesion_prob=1.0)[2] for _ in range(10)]
    assert sum(1 for l in some if l) >= 8


def test_batch_scaling():
    rng = np.random.default_rng(4)
    ct, mri = data.batch(rng, 3, size=32)
    assert ct.shape == (3, 32, 32, 1)
    assert mri.shape == (3, 32, 32, 1)
    assert -1.0 <= ct.min() and ct.max() <= 1.0


def test_deterministic_given_rng_state():
    a = data.paired_sample(np.random.default_rng(42))[0]
    b = data.paired_sample(np.random.default_rng(42))[0]
    np.testing.assert_array_equal(a, b)
