"""Model-level tests: shapes, variant structure, pallas/ref agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    GanConfig,
    VARIANTS,
    YoloConfig,
    discriminator_apply,
    generator_apply,
    init_discriminator,
    init_generator,
    init_yolo,
    param_count,
    yolo_apply,
)

CFG = GanConfig()


@pytest.fixture(scope="module")
def ct_batch():
    return jax.random.uniform(jax.random.PRNGKey(0), (2, 64, 64, 1), jnp.float32) * 2 - 1


@pytest.mark.parametrize("variant", VARIANTS)
def test_generator_shape(variant, ct_batch):
    params = init_generator(jax.random.PRNGKey(1), CFG, variant)
    out = generator_apply(dict(params), ct_batch, CFG, variant)
    assert out.shape == ct_batch.shape
    assert np.all(np.abs(np.array(out)) <= 1.0)


def test_cropping_same_params_as_original_table2():
    o = init_generator(jax.random.PRNGKey(1), CFG, "original")
    c = init_generator(jax.random.PRNGKey(1), CFG, "cropping")
    assert param_count(o) == param_count(c)


def test_convolution_more_params_table2():
    o = init_generator(jax.random.PRNGKey(1), CFG, "original")
    v = init_generator(jax.random.PRNGKey(1), CFG, "convolution")
    assert param_count(v) > param_count(o)
    # the extra params are exactly the bias-free 3x3 fix convs
    extra = sum(
        int(np.prod(a.shape)) for n, a in v if n.endswith("fix_w")
    )
    assert param_count(v) - param_count(o) == extra


@pytest.mark.parametrize("variant", VARIANTS)
def test_pallas_path_matches_ref_path(variant, ct_batch):
    """L1/L2 integration: the Pallas-kernel forward equals the ref forward."""
    params = dict(init_generator(jax.random.PRNGKey(2), CFG, variant))
    ref_out = generator_apply(params, ct_batch, CFG, variant, use_pallas=False)
    pallas_out = generator_apply(params, ct_batch, CFG, variant, use_pallas=True)
    np.testing.assert_allclose(
        np.array(ref_out), np.array(pallas_out), rtol=5e-5, atol=5e-5
    )


def test_discriminator_patch_output(ct_batch):
    params = dict(init_discriminator(jax.random.PRNGKey(3), CFG))
    patch = discriminator_apply(params, ct_batch, ct_batch, CFG)
    assert patch.shape[0] == ct_batch.shape[0]
    assert patch.shape[-1] == 1
    assert patch.shape[1] > 1  # a patch map, not a scalar


def test_yolo_three_scales():
    cfg = YoloConfig()
    params = dict(init_yolo(jax.random.PRNGKey(4), cfg))
    x = jnp.zeros((1, 64, 64, 1), jnp.float32)
    p3, p4, p5 = yolo_apply(params, x, cfg)
    assert p3.shape[1] == 8  # /8
    assert p4.shape[1] == 4  # /16
    assert p5.shape[1] == 2  # /32
    assert p3.shape[-1] == 4 * cfg.reg_max + cfg.num_classes


def test_yolo_pallas_matches_ref():
    cfg = YoloConfig()
    params = dict(init_yolo(jax.random.PRNGKey(5), cfg))
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 64, 64, 1), jnp.float32)
    a = yolo_apply(params, x, cfg, use_pallas=False)
    b = yolo_apply(params, x, cfg, use_pallas=True)
    for ra, rb in zip(a, b):
        np.testing.assert_allclose(np.array(ra), np.array(rb), rtol=5e-5, atol=5e-5)
