import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

# Suites with heavyweight optional deps are skipped (not failed) in slim
# environments — the CI python job installs only pytest + numpy. The
# phantom-data tests are numpy-only and always run.
collect_ignore = []
try:
    import jax  # noqa: F401
except Exception:
    collect_ignore += [
        "tests/test_aot.py",
        "tests/test_kernels.py",
        "tests/test_model.py",
        "tests/test_train.py",
    ]
try:
    import hypothesis  # noqa: F401
except Exception:
    if "tests/test_kernels.py" not in collect_ignore:
        collect_ignore.append("tests/test_kernels.py")
