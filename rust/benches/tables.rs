//! End-to-end benches regenerating the paper's throughput tables
//! (IV and VI) — run with `cargo bench --bench tables`.

mod bench_util;

use bench_util::Bench;
use edgepipe::config::GanVariant;
use edgepipe::dla::DlaVersion;
use edgepipe::hw::orin;
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::models::yolov8::{yolov8, YoloConfig};
use edgepipe::sched::haxconn;
use edgepipe::sim::{simulate, SimConfig};

fn main() {
    let soc = orin();

    let b = Bench::new("table4_two_gans");
    for v in GanVariant::all() {
        let g = generator(&Pix2PixConfig::paper(), v).unwrap();
        let (sched, _) = haxconn::two_gans(&g, &soc, DlaVersion::V2).unwrap();
        b.measure(v.name(), 300, || {
            let mut cfg = SimConfig::new(soc.clone(), 128);
            cfg.record_timeline = false;
            let r = simulate(&[&g], &sched, &cfg).unwrap();
            assert!(r.instances[0].fps > 0.0);
        });
    }

    let b = Bench::new("table6_gan_yolo");
    let y = yolov8(&YoloConfig::nano()).unwrap();
    for v in GanVariant::all() {
        let g = generator(&Pix2PixConfig::paper(), v).unwrap();
        let (sched, _) = haxconn::gan_plus_yolo(&g, &y, &soc, DlaVersion::V2).unwrap();
        b.measure(v.name(), 300, || {
            let mut cfg = SimConfig::new(soc.clone(), 128);
            cfg.record_timeline = false;
            let r = simulate(&[&g, &y], &sched, &cfg).unwrap();
            assert!(r.instances[0].fps > 0.0);
        });
    }

    let b = Bench::new("schedule_synthesis");
    let g = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
    b.measure("two_gans_search", 300, || {
        haxconn::two_gans(&g, &soc, DlaVersion::V2).unwrap();
    });
    b.measure("gan_plus_yolo_search", 500, || {
        haxconn::gan_plus_yolo(&g, &y, &soc, DlaVersion::V2).unwrap();
    });
}
