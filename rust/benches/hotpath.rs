//! Hot-path micro-benches for the performance pass (EXPERIMENTS.md §Perf):
//! simulator event throughput, scheduler search, NMS, JSON, PJRT execute.

mod bench_util;

use bench_util::Bench;
use edgepipe::config::json::Json;
use edgepipe::config::GanVariant;
use edgepipe::hw::orin;
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::models::yolov8::{yolov8, YoloConfig};
use edgepipe::postproc::{nms, Detection};
use edgepipe::sched::haxconn;
use edgepipe::sim::{simulate, SimConfig};
use edgepipe::util::rng::Rng;
use std::path::Path;

fn main() {
    let soc = orin();
    let b = Bench::new("hotpath");

    // Simulator job throughput: jobs/s over a long two-model run.
    let g = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
    let y = yolov8(&YoloConfig::nano()).unwrap();
    let (sched, _) = haxconn::gan_plus_yolo(&g, &y, &soc, edgepipe::dla::DlaVersion::V2).unwrap();
    let frames = 2048;
    let ms = b.measure("sim_2048_frames_no_trace", 500, || {
        let mut cfg = SimConfig::new(soc.clone(), frames);
        cfg.record_timeline = false;
        simulate(&[&g, &y], &sched, &cfg).unwrap();
    });
    // each frame ~6 steps across 2 instances
    println!(
        "{:<40} {:>10.0} jobs/s",
        "hotpath/sim_job_rate",
        (frames as f64 * 6.0) / (ms / 1e3)
    );
    let ms_tl = b.measure("sim_2048_frames_with_trace", 500, || {
        let cfg = SimConfig::new(soc.clone(), frames);
        simulate(&[&g, &y], &sched, &cfg).unwrap();
    });
    println!(
        "{:<40} {:>10.2}x",
        "hotpath/trace_overhead",
        ms_tl / ms
    );

    // NMS over 1k random boxes.
    let mut rng = Rng::new(3);
    let dets: Vec<Detection> = (0..1000)
        .map(|_| {
            let x0 = rng.range_f64(0.0, 500.0) as f32;
            let y0 = rng.range_f64(0.0, 500.0) as f32;
            Detection {
                x0,
                y0,
                x1: x0 + rng.range_f64(5.0, 60.0) as f32,
                y1: y0 + rng.range_f64(5.0, 60.0) as f32,
                score: rng.next_f32(),
                class: rng.below(2) as usize,
            }
        })
        .collect();
    b.measure("nms_1000_boxes", 200, || {
        nms(dets.clone(), 0.5);
    });

    // JSON parse/serialize of a trace-sized document.
    let doc = {
        let mut cfg = SimConfig::new(soc.clone(), 32);
        cfg.record_timeline = true;
        let r = simulate(&[&g, &y], &sched, &cfg).unwrap();
        r.timeline.to_json().to_compact()
    };
    println!("trace json bytes: {}", doc.len());
    b.measure("json_parse_trace", 200, || {
        Json::parse(&doc).unwrap();
    });

    // PJRT execute on the real artifact if available.
    if Path::new("artifacts/gen_cropping.hlo.txt").exists() {
        let client = edgepipe::runtime::RuntimeClient::cpu().unwrap();
        let a = edgepipe::runtime::Artifact::load(&client, Path::new("artifacts"), "gen_cropping")
            .unwrap();
        let frame = vec![0.2f32; 64 * 64];
        b.measure("pjrt_gen_cropping_execute", 1000, || {
            a.run_image(&frame).unwrap();
        });
        let ay = edgepipe::runtime::Artifact::load(&client, Path::new("artifacts"), "yolo_lite")
            .unwrap();
        b.measure("pjrt_yolo_lite_execute", 1000, || {
            ay.run_image(&frame).unwrap();
        });
    } else {
        println!("artifacts missing; skipping PJRT benches");
    }
}
