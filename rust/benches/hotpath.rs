//! Hot-path micro-benches for the performance pass (EXPERIMENTS.md §Perf):
//! simulator event throughput, scheduler search, NMS, JSON, frame routing,
//! block DCT, k-space FFT + GRAPPA recon, batched vs unbatched dispatch,
//! coordinator overhead, PJRT execute. Emits `BENCH_hotpath.json` (name → ns/op + derived rates) so
//! every run seeds the machine-readable perf trajectory; CI's
//! `bench-smoke` job runs this in short mode (`EDGEPIPE_BENCH_SMOKE=1`)
//! and archives the JSON.

mod bench_util;

use bench_util::Bench;
use edgepipe::config::json::Json;
use edgepipe::config::GanVariant;
use edgepipe::hw::{orin, xavier, EngineKind};
use edgepipe::imaging::dct::{dct8_block, idct8_block};
use edgepipe::imaging::{reference, Image};
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::models::yolov8::{yolov8, YoloConfig};
use edgepipe::pipeline::batcher::BatchPolicy;
use edgepipe::pipeline::plane::FramePlane;
use edgepipe::pipeline::router::{RoutePolicy, Router};
use edgepipe::pipeline::{Frame, InferenceBackend, InstanceSpec, SimBackend};
use edgepipe::postproc::{nms, Detection};
use edgepipe::sched::haxconn;
use edgepipe::session::Session;
use edgepipe::sim::{simulate, SimConfig};
use edgepipe::util::rng::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let soc = orin();
    let b = Bench::new("hotpath");

    // Simulator job throughput: jobs/s over a long two-model run.
    let g = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
    let y = yolov8(&YoloConfig::nano()).unwrap();
    let (sched, _) = haxconn::gan_plus_yolo(&g, &y, &soc, edgepipe::dla::DlaVersion::V2).unwrap();
    let frames = 2048;
    let ms = b.measure("sim_2048_frames_no_trace", 500, || {
        let mut cfg = SimConfig::new(soc.clone(), frames);
        cfg.record_timeline = false;
        simulate(&[&g, &y], &sched, &cfg).unwrap();
    });
    // each frame ~6 steps across 2 instances
    b.rate(
        "sim_2048_frames_no_trace",
        "jobs_per_s",
        (frames as f64 * 6.0) / (ms / 1e3),
    );
    let ms_tl = b.measure("sim_2048_frames_with_trace", 500, || {
        let cfg = SimConfig::new(soc.clone(), frames);
        simulate(&[&g, &y], &sched, &cfg).unwrap();
    });
    b.rate("sim_2048_frames_with_trace", "trace_overhead_x", ms_tl / ms);

    // Router hot path: `route` returns an allocation-free iterator and the
    // driver's fanout copies are Arc refcount bumps — zero pixel copies.
    // 100k routed frames per iteration.
    let rframe = Frame {
        id: 0,
        stream: 3,
        data: FramePlane::from_vec(Vec::new()),
        width: 0,
        height: 0,
        gt_mri: None,
        admitted: Instant::now(),
        stamps: Default::default(),
    };
    let mut route_sink = 0usize;
    for (policy, label) in [
        (RoutePolicy::Fanout, "route_fanout8_100k_frames"),
        (RoutePolicy::RoundRobin, "route_rr8_100k_frames"),
        (RoutePolicy::ByStream, "route_bystream8_100k_frames"),
    ] {
        let mut router = Router::new(policy, 8);
        let ms = b.measure(label, 200, || {
            for _ in 0..100_000 {
                route_sink = route_sink.wrapping_add(router.route(&rframe).sum::<usize>());
            }
        });
        b.rate(label, "routes_per_s", 100_000.0 / (ms / 1e3));
    }
    println!("route checksum: {route_sink}");

    // Fleet front door: consistent-hash placement of 4096 streams across
    // 8 nodes (64 ring points each) — one ring binary-search per lookup,
    // with a handful of migration overrides in place so the override map
    // probe is inside the measurement.
    let mut fleet_router = edgepipe::fleet::StreamRouter::new(8, 64);
    for s in 0..16 {
        let to = (fleet_router.home(s) + 1) % 8;
        fleet_router.migrate(s, to);
    }
    let mut hash_sink = 0usize;
    let ms = b.measure("fleet_router_hash_4096_streams", 500, || {
        for s in 0..4096 {
            hash_sink = hash_sink.wrapping_add(fleet_router.node_for(s));
        }
    });
    b.rate(
        "fleet_router_hash_4096_streams",
        "lookups_per_s",
        4096.0 / (ms / 1e3),
    );
    println!("fleet router checksum: {hash_sink}");

    // Block DCT throughput: the 8x8 basis table is memoized (was 64 `cos`
    // calls per block); 10k forward + inverse transforms per iteration.
    let mut rng = Rng::new(7);
    let mut block = [0f32; 64];
    for v in &mut block {
        *v = rng.next_f32() - 0.5;
    }
    let mut dct_sink = 0f32;
    let ms = b.measure("dct8_block_10k_blocks", 200, || {
        let mut blk = block;
        for _ in 0..5_000 {
            blk = idct8_block(&dct8_block(&blk));
        }
        dct_sink += blk[0];
    });
    b.rate("dct8_block_10k_blocks", "blocks_per_s", 10_000.0 / (ms / 1e3));
    println!("dct checksum: {dct_sink}");

    // Whole-image kernels, optimized vs the scalar reference oracles kept
    // in `imaging::reference`: 512x512 frames, per-megapixel throughput,
    // and the speedup the row-parallel + border-split restructuring buys
    // (`speedup_vs_scalar` = scalar ms / optimized ms). The `_scalar`
    // cases are single-threaded by construction, so they are core-count
    // independent and double as stable regression anchors for CI; the
    // optimized cases scale with the runner and get a looser gate.
    fn kernel_case(b: &Bench, label: &str, mpix: f64, opt: impl FnMut(), scalar: impl FnMut()) {
        let scalar_label = format!("{label}_scalar");
        let ms_opt = b.measure(label, 300, opt);
        let ms_ref = b.measure(&scalar_label, 300, scalar);
        b.rate(label, "mpix_per_s", mpix / (ms_opt / 1e3));
        b.rate(&scalar_label, "mpix_per_s", mpix / (ms_ref / 1e3));
        b.rate(label, "speedup_vs_scalar", ms_ref / ms_opt);
    }
    use std::hint::black_box;
    let (iw, ih) = (512usize, 512usize);
    let mpix = (iw * ih) as f64 / 1e6;
    let mut rng = Rng::new(11);
    // 8-bit-quantized pixels: representative of decoded frame data, and
    // what engages `median_k`'s sliding-histogram fast path.
    let bytes: Vec<u8> = (0..iw * ih).map(|_| rng.below(256) as u8).collect();
    let img = Image::from_u8(iw, ih, &bytes).unwrap();
    // A correlated second image for SSIM so window statistics stay
    // non-degenerate (noise against an affine remap of itself).
    let img2 = Image::from_data(
        iw,
        ih,
        img.data.iter().map(|v| (v * 0.9 + 0.05).min(1.0)).collect(),
    )
    .unwrap();
    kernel_case(
        &b,
        "img_dct_512",
        mpix,
        || {
            black_box(edgepipe::imaging::dct::dct_image(&img));
        },
        || {
            black_box(reference::dct_image(&img));
        },
    );
    kernel_case(
        &b,
        "img_sobel_512",
        mpix,
        || {
            black_box(edgepipe::imaging::sobel::sobel(&img));
        },
        || {
            black_box(reference::sobel(&img));
        },
    );
    kernel_case(
        &b,
        "img_median5_512",
        mpix,
        || {
            black_box(edgepipe::imaging::median::median_k(&img, 5));
        },
        || {
            black_box(reference::median_k(&img, 5));
        },
    );
    kernel_case(
        &b,
        "img_ssim_512",
        mpix,
        || {
            black_box(edgepipe::imaging::metrics::ssim(&img, &img2).unwrap());
        },
        || {
            black_box(reference::ssim(&img, &img2).unwrap());
        },
    );
    kernel_case(
        &b,
        "img_histeq_512",
        mpix,
        || {
            black_box(edgepipe::imaging::histeq::equalize(&img));
        },
        || {
            black_box(reference::equalize(&img));
        },
    );
    kernel_case(
        &b,
        "img_lzw_512",
        mpix,
        || {
            black_box(edgepipe::imaging::lzw::compress(&bytes));
        },
        || {
            black_box(reference::lzw_compress(&bytes));
        },
    );

    // k-space front-end kernels, same optimized-vs-oracle shape: the 2D
    // FFT pair on a 256x256 complex plane (the per-coil acquisition
    // transform at a clinical matrix size) and the GRAPPA fit+synthesis
    // at the serving geometry (64x64, 4 coils, R=4, 16 ACS rows).
    use edgepipe::imaging::fft::Fft2;
    use edgepipe::imaging::grappa::GrappaKernel;
    use edgepipe::imaging::kspace::{coil_maps, sample_mask, GRAPPA_LAMBDA_REL};
    let fft_n = 256usize;
    let fft = Fft2::new(fft_n).unwrap();
    let mut rng = Rng::new(17);
    let plane_re: Vec<f32> = (0..fft_n * fft_n).map(|_| rng.next_f32() - 0.5).collect();
    let plane_im: Vec<f32> = (0..fft_n * fft_n).map(|_| rng.next_f32() - 0.5).collect();
    let (mut opt_re, mut opt_im) = (plane_re.clone(), plane_im.clone());
    let (mut ref_re, mut ref_im) = (plane_re.clone(), plane_im.clone());
    kernel_case(
        &b,
        "img_fft2_256",
        (fft_n * fft_n) as f64 / 1e6,
        || {
            fft.fft2(&mut opt_re, &mut opt_im).unwrap();
            fft.ifft2(&mut opt_re, &mut opt_im).unwrap();
        },
        || {
            reference::fft2(fft_n, &mut ref_re, &mut ref_im).unwrap();
            reference::ifft2(fft_n, &mut ref_re, &mut ref_im).unwrap();
        },
    );

    // One undersampled multi-coil acquisition at the serving geometry,
    // built from the same public pieces `Acquisition` composes.
    let (gn, gc, gr) = (64usize, 4usize, 4usize);
    let gplane = gn * gn;
    let (gmap_re, gmap_im) = coil_maps(gn, gc);
    let gmask = sample_mask(gn, gr, 16);
    let gfft = Fft2::new(gn).unwrap();
    let slice: Vec<f32> = (0..gplane).map(|_| rng.next_f32()).collect();
    let mut gks_re = vec![0.0f32; gc * gplane];
    let mut gks_im = vec![0.0f32; gc * gplane];
    for c in 0..gc {
        let o = c * gplane;
        for p in 0..gplane {
            gks_re[o + p] = gmap_re[o + p] * slice[p];
            gks_im[o + p] = gmap_im[o + p] * slice[p];
        }
        gfft.fft2(&mut gks_re[o..o + gplane], &mut gks_im[o..o + gplane])
            .unwrap();
        for (row, &keep) in gmask.iter().enumerate() {
            if !keep {
                gks_re[o + row * gn..o + (row + 1) * gn].fill(0.0);
                gks_im[o + row * gn..o + (row + 1) * gn].fill(0.0);
            }
        }
    }
    let mut gkern = GrappaKernel::new(gc, gr).unwrap();
    let (mut gwork_re, mut gwork_im) = (gks_re.clone(), gks_im.clone());
    kernel_case(
        &b,
        "img_grappa_fit_r4",
        gplane as f64 / 1e6,
        || {
            gkern
                .fit(&gks_re, &gks_im, &gmask, GRAPPA_LAMBDA_REL)
                .unwrap();
            gwork_re.copy_from_slice(&gks_re);
            gwork_im.copy_from_slice(&gks_im);
            gkern.apply(&mut gwork_re, &mut gwork_im, &gmask).unwrap();
            black_box(gwork_re[0]);
        },
        || {
            black_box(
                reference::grappa_recon(
                    gn,
                    gc,
                    gr,
                    &gks_re,
                    &gks_im,
                    &gmask,
                    GRAPPA_LAMBDA_REL,
                )
                .unwrap(),
            );
        },
    );

    // Batched vs unbatched dispatch through the sim backend's roofline
    // pricing: execute_batch(4) is ONE dispatch that amortizes launch
    // overhead and weight traffic, so it must cost less than 4 single
    // dispatches. time_scale shrinks the modeled sleeps to keep the bench
    // quick while preserving the ratio.
    let dispatch_backend = SimBackend::new(orin()).with_time_scale(0.05);
    let dispatch_spec = InstanceSpec::new("gan", "gen_cropping").with_batch(BatchPolicy {
        max_batch: 4,
        timeout: Duration::from_micros(500),
    });
    let mut dispatch_runner = dispatch_backend.open(&dispatch_spec).unwrap();
    let dispatch_frames: Vec<Frame> = (0..4)
        .map(|i| Frame {
            id: i,
            stream: 0,
            data: FramePlane::from_vec(vec![0.1; 64 * 64]),
            width: 64,
            height: 64,
            gt_mri: None,
            admitted: Instant::now(),
            stamps: Default::default(),
        })
        .collect();
    let ms_single4 = b.measure("sim_dispatch_single_x4", 150, || {
        for f in &dispatch_frames {
            dispatch_runner.run(f).unwrap();
        }
    });
    let ms_batch4 = b.measure("sim_dispatch_batched_4", 150, || {
        dispatch_runner.execute_batch(&dispatch_frames).unwrap();
    });
    b.rate(
        "sim_dispatch_batched_4",
        "speedup_vs_4x_single",
        ms_single4 / ms_batch4,
    );

    // Coordinator overhead: a full 2-instance fanout session on the sim
    // backend with latencies zeroed and fidelity scoring off, so the
    // measurement is source synthesis + pooled planes + channels + router
    // + batcher + metrics + thread handoff (phantom generation is part of
    // the serving loop and stays in; per-frame SSIM would otherwise
    // dominate). Built once outside the loop to keep build/prepare graph
    // pricing out.
    let backend: Arc<dyn InferenceBackend> =
        Arc::new(SimBackend::new(orin()).with_time_scale(0.0));
    let session_frames = 256usize;
    let session = Session::builder()
        .instance(InstanceSpec::new("gan", "gen_cropping"))
        .instance(InstanceSpec::new("yolo", "yolo_lite"))
        .route(RoutePolicy::Fanout)
        .frames(session_frames)
        .backend(Arc::clone(&backend))
        .build()
        .unwrap();
    let ms = b.measure("session_sim_fanout_256_frames", 1000, || {
        session.run().unwrap();
    });
    b.rate(
        "session_sim_fanout_256_frames",
        "frames_per_s",
        session_frames as f64 / (ms / 1e3),
    );

    // The same coordinator with batch-4 policies: fewer dispatches for the
    // same frame count (the session-level view of batched execution).
    let batch4 = BatchPolicy {
        max_batch: 4,
        timeout: Duration::from_micros(500),
    };
    let session_b4 = Session::builder()
        .instance(InstanceSpec::new("gan", "gen_cropping").with_batch(batch4))
        .instance(InstanceSpec::new("yolo", "yolo_lite").with_batch(batch4))
        .route(RoutePolicy::Fanout)
        .frames(session_frames)
        .backend(Arc::clone(&backend))
        .build()
        .unwrap();
    let ms_b4 = b.measure("session_sim_fanout_256_frames_batch4", 1000, || {
        session_b4.run().unwrap();
    });
    b.rate(
        "session_sim_fanout_256_frames_batch4",
        "frames_per_s",
        session_frames as f64 / (ms_b4 / 1e3),
    );

    // Engine-arbitrated serving: GAN pinned to DLA0 next to YOLO on the
    // GPU with real (scaled) modeled engine holds. The per-engine
    // utilization figures from the arbiter's serving timeline ride into
    // the bench JSON — CI's bench-smoke job validates them.
    let engines_backend: Arc<dyn InferenceBackend> =
        Arc::new(SimBackend::new(orin()).with_time_scale(0.02));
    let engines_frames = 64usize;
    let engines_session = Session::builder()
        .instance(InstanceSpec::new("gan", "gen_cropping").on_engine(EngineKind::Dla))
        .instance(InstanceSpec::new("yolo", "yolo_lite").on_engine(EngineKind::Gpu))
        .route(RoutePolicy::Fanout)
        .frames(engines_frames)
        .backend(engines_backend)
        .build()
        .unwrap();
    let mut engine_stats = Vec::new();
    let ms_eng = b.measure("session_sim_engines_dla_gpu_64", 300, || {
        engine_stats = engines_session.run().unwrap().engines;
    });
    b.rate(
        "session_sim_engines_dla_gpu_64",
        "frames_per_s",
        engines_frames as f64 / (ms_eng / 1e3),
    );
    for e in &engine_stats {
        b.rate(
            "session_sim_engines_dla_gpu_64",
            &format!("{}_utilization_pct", e.label.to_ascii_lowercase()),
            e.utilization * 100.0,
        );
    }

    // Auto-placement search cost: the full two-GAN + detector plan on the
    // Xavier profile — candidate enumeration with DLA-fallback pruning
    // plus the virtual-time scoring of every survivor. Tracked so search
    // cost stays visible in the perf trajectory as the candidate space
    // grows.
    let plan_req = {
        let mut r = edgepipe::placement::PlacementRequest::new(
            xavier(),
            edgepipe::dla::DlaVersion::V1,
        );
        r.frames = 32;
        r
    };
    let mut plan_fps = 0.0;
    let ms_plan = b.measure("plan_search_two_gan", 300, || {
        plan_fps = edgepipe::placement::plan(&plan_req).unwrap().eval.predicted_fps;
    });
    b.rate("plan_search_two_gan", "plans_per_s", 1e3 / ms_plan);
    b.rate("plan_search_two_gan", "predicted_fps", plan_fps);

    // Serve-loop overhead: the long-running front-end (arrival schedule,
    // QoS admission, rolling windows, forced drain-and-switch handoffs)
    // on zeroed latencies — what serving adds on top of the coordinator.
    // 512 frames across two bursty clients with a handoff every other
    // checkpoint, so the spec-swap machinery is inside the measurement.
    use edgepipe::serve::{self, ArrivalProcess, ClientSpec, ReplanPolicy, ServeOptions};
    let serve_frames = 512usize;
    let mut serve_replans = 0usize;
    let ms_serve = b.measure("serve_burst_512_frames", 300, || {
        let session = Session::builder()
            .instance(InstanceSpec::new("gan", "gen_cropping"))
            .instance(InstanceSpec::new("yolo", "yolo_lite"))
            .route(RoutePolicy::Fanout)
            .frames(16)
            .backend(Arc::clone(&backend))
            .build()
            .unwrap();
        let mut opts = ServeOptions::new(orin(), edgepipe::dla::DlaVersion::V2);
        opts.time_scale = 0.0; // no pacing: pure front-end overhead
        opts.replan = ReplanPolicy {
            check_every_frames: 128,
            force_every_checks: Some(2),
            ..ReplanPolicy::default()
        };
        for i in 0..2 {
            opts.clients.push(ClientSpec::new(
                format!("c{i}"),
                serve_frames / 2,
                ArrivalProcess::Burst {
                    burst_fps: 2000.0,
                    burst_len: 64,
                    idle_seconds: 0.01,
                },
            ));
        }
        let rep = serve::serve(session, opts).unwrap();
        serve_replans = rep.replans.len();
        assert_eq!(rep.offered, rep.completed + rep.shed);
    });
    b.rate(
        "serve_burst_512_frames",
        "frames_per_s",
        serve_frames as f64 / (ms_serve / 1e3),
    );
    b.rate("serve_burst_512_frames", "replans", serve_replans as f64);

    // The same serve loop with the observability hub attached: stage
    // stamps folded per copy, registry counters bumped per admission
    // decision, and a checkpoint-aligned snapshot stream. The rate is
    // the only thing CI gates (`overhead_vs_untraced < 1.05`): tracing
    // must stay within a few percent of the untraced hot path.
    use edgepipe::obs::ObsHub;
    let ms_traced = b.measure("serve_traced_512_frames", 300, || {
        let session = Session::builder()
            .instance(InstanceSpec::new("gan", "gen_cropping"))
            .instance(InstanceSpec::new("yolo", "yolo_lite"))
            .route(RoutePolicy::Fanout)
            .frames(16)
            .backend(Arc::clone(&backend))
            .build()
            .unwrap();
        let mut opts = ServeOptions::new(orin(), edgepipe::dla::DlaVersion::V2);
        opts.time_scale = 0.0;
        opts.replan = ReplanPolicy {
            check_every_frames: 128,
            force_every_checks: Some(2),
            ..ReplanPolicy::default()
        };
        opts.obs = Some(Arc::new(ObsHub::new()));
        for i in 0..2 {
            opts.clients.push(ClientSpec::new(
                format!("c{i}"),
                serve_frames / 2,
                ArrivalProcess::Burst {
                    burst_fps: 2000.0,
                    burst_len: 64,
                    idle_seconds: 0.01,
                },
            ));
        }
        let rep = serve::serve(session, opts).unwrap();
        assert_eq!(rep.offered, rep.completed + rep.shed);
        let st = rep.stages.expect("observed serve reports stages");
        assert_eq!(st.non_monotone, 0);
    });
    b.rate(
        "serve_traced_512_frames",
        "frames_per_s",
        serve_frames as f64 / (ms_traced / 1e3),
    );
    b.rate(
        "serve_traced_512_frames",
        "overhead_vs_untraced",
        ms_traced / ms_serve,
    );

    // NMS over 1k random boxes.
    let mut rng = Rng::new(3);
    let dets: Vec<Detection> = (0..1000)
        .map(|_| {
            let x0 = rng.range_f64(0.0, 500.0) as f32;
            let y0 = rng.range_f64(0.0, 500.0) as f32;
            Detection {
                x0,
                y0,
                x1: x0 + rng.range_f64(5.0, 60.0) as f32,
                y1: y0 + rng.range_f64(5.0, 60.0) as f32,
                score: rng.next_f32(),
                class: rng.below(2) as usize,
            }
        })
        .collect();
    b.measure("nms_1000_boxes", 200, || {
        nms(dets.clone(), 0.5);
    });

    // JSON parse/serialize of a trace-sized document.
    let doc = {
        let mut cfg = SimConfig::new(soc.clone(), 32);
        cfg.record_timeline = true;
        let r = simulate(&[&g, &y], &sched, &cfg).unwrap();
        r.timeline.to_json().to_compact()
    };
    println!("trace json bytes: {}", doc.len());
    b.measure("json_parse_trace", 200, || {
        Json::parse(&doc).unwrap();
    });

    // PJRT execute on the real artifact if available.
    pjrt_benches(&b);

    b.write_json("BENCH_hotpath.json");
}

#[cfg(feature = "pjrt")]
fn pjrt_benches(b: &Bench) {
    use std::path::Path;
    if Path::new("artifacts/gen_cropping.hlo.txt").exists() {
        let client = edgepipe::runtime::RuntimeClient::cpu().unwrap();
        let a = edgepipe::runtime::Artifact::load(&client, Path::new("artifacts"), "gen_cropping")
            .unwrap();
        let frame = vec![0.2f32; 64 * 64];
        b.measure("pjrt_gen_cropping_execute", 1000, || {
            a.run_image(&frame).unwrap();
        });
        let ay = edgepipe::runtime::Artifact::load(&client, Path::new("artifacts"), "yolo_lite")
            .unwrap();
        b.measure("pjrt_yolo_lite_execute", 1000, || {
            ay.run_image(&frame).unwrap();
        });
    } else {
        println!("artifacts missing; skipping PJRT benches");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_benches(_b: &Bench) {
    println!("pjrt feature disabled; skipping PJRT benches");
}
