//! Hot-path micro-benches for the performance pass (EXPERIMENTS.md §Perf):
//! simulator event throughput, scheduler search, NMS, JSON, frame routing,
//! coordinator overhead, PJRT execute.

mod bench_util;

use bench_util::Bench;
use edgepipe::config::json::Json;
use edgepipe::config::GanVariant;
use edgepipe::hw::orin;
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::models::yolov8::{yolov8, YoloConfig};
use edgepipe::pipeline::router::{RoutePolicy, Router};
use edgepipe::pipeline::{Frame, InferenceBackend, InstanceSpec, SimBackend};
use edgepipe::postproc::{nms, Detection};
use edgepipe::sched::haxconn;
use edgepipe::session::Session;
use edgepipe::sim::{simulate, SimConfig};
use edgepipe::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let soc = orin();
    let b = Bench::new("hotpath");

    // Simulator job throughput: jobs/s over a long two-model run.
    let g = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
    let y = yolov8(&YoloConfig::nano()).unwrap();
    let (sched, _) = haxconn::gan_plus_yolo(&g, &y, &soc, edgepipe::dla::DlaVersion::V2).unwrap();
    let frames = 2048;
    let ms = b.measure("sim_2048_frames_no_trace", 500, || {
        let mut cfg = SimConfig::new(soc.clone(), frames);
        cfg.record_timeline = false;
        simulate(&[&g, &y], &sched, &cfg).unwrap();
    });
    // each frame ~6 steps across 2 instances
    println!(
        "{:<40} {:>10.0} jobs/s",
        "hotpath/sim_job_rate",
        (frames as f64 * 6.0) / (ms / 1e3)
    );
    let ms_tl = b.measure("sim_2048_frames_with_trace", 500, || {
        let cfg = SimConfig::new(soc.clone(), frames);
        simulate(&[&g, &y], &sched, &cfg).unwrap();
    });
    println!(
        "{:<40} {:>10.2}x",
        "hotpath/trace_overhead",
        ms_tl / ms
    );

    // Router hot path: `route` returns an allocation-free iterator (was a
    // Vec<usize> per frame). 100k routed frames per iteration; the fanout
    // case is the one that used to allocate an 8-element Vec every frame.
    let rframe = Frame {
        id: 0,
        stream: 3,
        data: Vec::new(),
        width: 0,
        height: 0,
        gt_mri: None,
        admitted: Instant::now(),
    };
    let mut route_sink = 0usize;
    for (policy, label) in [
        (RoutePolicy::Fanout, "route_fanout8_100k_frames"),
        (RoutePolicy::RoundRobin, "route_rr8_100k_frames"),
        (RoutePolicy::ByStream, "route_bystream8_100k_frames"),
    ] {
        let mut router = Router::new(policy, 8);
        let ms = b.measure(label, 200, || {
            for _ in 0..100_000 {
                route_sink = route_sink.wrapping_add(router.route(&rframe).sum::<usize>());
            }
        });
        println!(
            "{:<40} {:>10.0} routes/s",
            format!("hotpath/{label}_rate"),
            100_000.0 / (ms / 1e3)
        );
    }
    println!("route checksum: {route_sink}");

    // Coordinator overhead: a full 2-instance fanout session on the sim
    // backend with latencies zeroed and fidelity scoring off, so the
    // measurement is source synthesis + channels + router + batcher +
    // metrics + thread handoff (phantom generation is part of the serving
    // loop and stays in; per-frame SSIM would otherwise dominate). Built
    // once outside the loop to keep build/prepare graph pricing out.
    let backend: Arc<dyn InferenceBackend> =
        Arc::new(SimBackend::new(orin()).with_time_scale(0.0));
    let session_frames = 256usize;
    let session = Session::builder()
        .instance(InstanceSpec::new("gan", "gen_cropping"))
        .instance(InstanceSpec::new("yolo", "yolo_lite"))
        .route(RoutePolicy::Fanout)
        .frames(session_frames)
        .backend(Arc::clone(&backend))
        .build()
        .unwrap();
    let ms = b.measure("session_sim_fanout_256_frames", 1000, || {
        session.run().unwrap();
    });
    println!(
        "{:<40} {:>10.0} frames/s",
        "hotpath/session_overhead_rate",
        session_frames as f64 / (ms / 1e3)
    );

    // NMS over 1k random boxes.
    let mut rng = Rng::new(3);
    let dets: Vec<Detection> = (0..1000)
        .map(|_| {
            let x0 = rng.range_f64(0.0, 500.0) as f32;
            let y0 = rng.range_f64(0.0, 500.0) as f32;
            Detection {
                x0,
                y0,
                x1: x0 + rng.range_f64(5.0, 60.0) as f32,
                y1: y0 + rng.range_f64(5.0, 60.0) as f32,
                score: rng.next_f32(),
                class: rng.below(2) as usize,
            }
        })
        .collect();
    b.measure("nms_1000_boxes", 200, || {
        nms(dets.clone(), 0.5);
    });

    // JSON parse/serialize of a trace-sized document.
    let doc = {
        let mut cfg = SimConfig::new(soc.clone(), 32);
        cfg.record_timeline = true;
        let r = simulate(&[&g, &y], &sched, &cfg).unwrap();
        r.timeline.to_json().to_compact()
    };
    println!("trace json bytes: {}", doc.len());
    b.measure("json_parse_trace", 200, || {
        Json::parse(&doc).unwrap();
    });

    // PJRT execute on the real artifact if available.
    pjrt_benches(&b);
}

#[cfg(feature = "pjrt")]
fn pjrt_benches(b: &Bench) {
    use std::path::Path;
    if Path::new("artifacts/gen_cropping.hlo.txt").exists() {
        let client = edgepipe::runtime::RuntimeClient::cpu().unwrap();
        let a = edgepipe::runtime::Artifact::load(&client, Path::new("artifacts"), "gen_cropping")
            .unwrap();
        let frame = vec![0.2f32; 64 * 64];
        b.measure("pjrt_gen_cropping_execute", 1000, || {
            a.run_image(&frame).unwrap();
        });
        let ay = edgepipe::runtime::Artifact::load(&client, Path::new("artifacts"), "yolo_lite")
            .unwrap();
        b.measure("pjrt_yolo_lite_execute", 1000, || {
            ay.run_image(&frame).unwrap();
        });
    } else {
        println!("artifacts missing; skipping PJRT benches");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_benches(_b: &Bench) {
    println!("pjrt feature disabled; skipping PJRT benches");
}
