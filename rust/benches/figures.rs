//! Benches regenerating the paper's figures (9-12: standalone + naive
//! concurrent throughput) and the Table I classical algorithms.

mod bench_util;

use bench_util::Bench;
use edgepipe::config::GanVariant;
use edgepipe::hw::{orin, EngineKind};
use edgepipe::imaging::{self, Image};
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::models::yolov8::{yolov8, YoloConfig};
use edgepipe::sched::naive;
use edgepipe::sim::{simulate, SimConfig};
use edgepipe::util::rng::Rng;

fn main() {
    let soc = orin();

    let b = Bench::new("fig9_standalone");
    for v in GanVariant::all() {
        let g = generator(&Pix2PixConfig::paper(), v).unwrap();
        let sched = naive::standalone(&g, EngineKind::Dla);
        b.measure(v.name(), 200, || {
            let mut cfg = SimConfig::new(soc.clone(), 64);
            cfg.max_inflight = 1;
            cfg.record_timeline = false;
            simulate(&[&g], &sched, &cfg).unwrap();
        });
    }

    let b = Bench::new("fig11_naive_concurrent");
    let y = yolov8(&YoloConfig::nano()).unwrap();
    for v in GanVariant::all() {
        let g = generator(&Pix2PixConfig::paper(), v).unwrap();
        let sched = naive::gan_dla_yolo_gpu(&g, &y);
        b.measure(v.name(), 200, || {
            let mut cfg = SimConfig::new(soc.clone(), 64);
            cfg.record_timeline = false;
            simulate(&[&g, &y], &sched, &cfg).unwrap();
        });
    }

    // Table I classical algorithm kernels on real pixels.
    let b = Bench::new("table1_algorithms");
    let mut rng = Rng::new(7);
    let mut img = Image::zeros(512, 512);
    for v in &mut img.data {
        *v = rng.next_f32();
    }
    b.measure("median3_512", 200, || {
        imaging::median::median3(&img);
    });
    b.measure("histeq_512", 200, || {
        imaging::histeq::equalize(&img);
    });
    b.measure("sobel_512", 200, || {
        imaging::sobel::sobel_edges(&img, 0.5);
    });
    b.measure("canny_512", 200, || {
        imaging::canny::canny(&img, 0.1, 0.3);
    });
    let bytes = img.to_u8();
    b.measure("lzw_512", 200, || {
        imaging::lzw::compress(&bytes);
    });
    b.measure("dct_512", 200, || {
        imaging::dct::dct_image(&img);
    });
}
