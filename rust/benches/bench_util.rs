//! Tiny shared bench harness (criterion is not in the offline vendor
//! set). Measures wall-clock over enough iterations for stability, prints
//! mean / throughput lines that `cargo bench` surfaces, and records every
//! measurement so a bench main can emit a machine-readable
//! `BENCH_<name>.json` (name → ns/op plus derived rates) for the perf
//! trajectory and the CI `bench-smoke` artifact.
//!
//! Short mode: set `EDGEPIPE_BENCH_SMOKE=1` to cap each measurement's
//! wall-clock budget so CI can validate the bench + JSON cheaply.

// Each bench main uses a different subset of the harness.
#![allow(dead_code)]

use edgepipe::config::json::{num, obj, Json};
use std::cell::RefCell;
use std::time::Instant;

struct Entry {
    label: String,
    ns_per_op: f64,
    iters: u64,
    /// Derived throughput figures, e.g. `("frames_per_s", 1234.0)`.
    rates: Vec<(String, f64)>,
}

pub struct Bench {
    pub name: &'static str,
    smoke: bool,
    entries: RefCell<Vec<Entry>>,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        let smoke =
            matches!(std::env::var("EDGEPIPE_BENCH_SMOKE"), Ok(v) if !v.is_empty() && v != "0");
        if smoke {
            println!("== bench: {name} (smoke mode) ==");
        } else {
            println!("== bench: {name} ==");
        }
        Bench {
            name,
            smoke,
            entries: RefCell::new(Vec::new()),
        }
    }

    /// Time `f` for at least `min_ms` of wall clock (capped in smoke
    /// mode); report and record mean ms/iter.
    pub fn measure<F: FnMut()>(&self, label: &str, min_ms: u64, mut f: F) -> f64 {
        let min_ms = if self.smoke { min_ms.min(25) } else { min_ms };
        // warmup
        f();
        let t0 = Instant::now();
        let mut iters = 0u64;
        while t0.elapsed().as_millis() < min_ms as u128 {
            f();
            iters += 1;
        }
        let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        println!(
            "{:<40} {:>10.3} ms/iter  ({} iters)",
            format!("{}/{label}", self.name),
            mean_ms,
            iters
        );
        self.entries.borrow_mut().push(Entry {
            label: label.to_string(),
            ns_per_op: mean_ms * 1e6,
            iters,
            rates: Vec::new(),
        });
        mean_ms
    }

    /// Print a derived throughput figure and attach it to `label`'s
    /// recorded entry (creating one when the figure has no timed entry).
    pub fn rate(&self, label: &str, unit: &str, value: f64) {
        println!(
            "{:<40} {:>12.1} {unit}",
            format!("{}/{label}", self.name),
            value
        );
        let mut entries = self.entries.borrow_mut();
        match entries.iter_mut().find(|e| e.label == label) {
            Some(e) => e.rates.push((unit.to_string(), value)),
            None => entries.push(Entry {
                label: label.to_string(),
                ns_per_op: 0.0,
                iters: 0,
                rates: vec![(unit.to_string(), value)],
            }),
        }
    }

    /// Write everything recorded so far as `BENCH_<name>.json`-style
    /// machine-readable output: `entries` maps each label to its ns/op,
    /// iteration count, and derived rates.
    pub fn write_json(&self, path: &str) {
        let entries = self.entries.borrow();
        let entry_objs: Vec<(&str, Json)> = entries
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("ns_per_op", num(e.ns_per_op)),
                    ("iters", num(e.iters as f64)),
                ];
                for (unit, value) in &e.rates {
                    fields.push((unit.as_str(), num(*value)));
                }
                (e.label.as_str(), obj(fields))
            })
            .collect();
        let doc = obj(vec![
            ("bench", edgepipe::config::json::s(self.name)),
            ("smoke", Json::Bool(self.smoke)),
            ("entries", obj(entry_objs)),
        ]);
        std::fs::write(path, doc.to_pretty()).expect("write bench json");
        println!("wrote {path} ({} entries)", entries.len());
    }
}
