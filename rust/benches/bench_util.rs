//! Tiny shared bench harness (criterion is not in the offline vendor
//! set). Measures wall-clock over enough iterations for stability and
//! prints mean / throughput lines that `cargo bench` surfaces.

use std::time::Instant;

pub struct Bench {
    pub name: &'static str,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        println!("== bench: {name} ==");
        Bench { name }
    }

    /// Time `f` for at least `min_ms` of wall clock; report mean ms/iter.
    pub fn measure<F: FnMut()>(&self, label: &str, min_ms: u64, mut f: F) -> f64 {
        // warmup
        f();
        let t0 = Instant::now();
        let mut iters = 0u64;
        while t0.elapsed().as_millis() < min_ms as u128 {
            f();
            iters += 1;
        }
        let mean_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
        println!(
            "{:<40} {:>10.3} ms/iter  ({} iters)",
            format!("{}/{label}", self.name),
            mean_ms,
            iters
        );
        mean_ms
    }
}
