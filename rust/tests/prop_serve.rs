//! Property: the serve loop's drain-and-switch handoff preserves
//! per-client frame ordering and never loses or double-executes a frame
//! across the old/new spec — under randomized client mixes, arrival
//! shapes, and forced switch cadences (the `util::prop` harness reports
//! the failing seed for deterministic replay).

use edgepipe::dla::DlaVersion;
use edgepipe::hw;
use edgepipe::pipeline::router::RoutePolicy;
use edgepipe::pipeline::{InstanceSpec, SimBackend};
use edgepipe::prop_assert;
use edgepipe::serve::{self, ArrivalProcess, ClientSpec, ReplanPolicy, ServeOptions};
use edgepipe::session::Session;
use edgepipe::util::prop;
use edgepipe::util::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

fn random_arrivals(rng: &mut Rng) -> ArrivalProcess {
    match rng.below(3) {
        0 => ArrivalProcess::Poisson {
            rate_fps: rng.range_f64(100.0, 2000.0),
        },
        1 => ArrivalProcess::Burst {
            burst_fps: rng.range_f64(500.0, 5000.0),
            burst_len: rng.range_i64(4, 32) as usize,
            idle_seconds: rng.range_f64(0.0, 0.01),
        },
        _ => ArrivalProcess::Ramp {
            start_fps: rng.range_f64(50.0, 300.0),
            end_fps: rng.range_f64(300.0, 3000.0),
        },
    }
}

#[test]
fn drain_and_switch_preserves_order_and_never_double_executes() {
    // Fanout with the GAN first: instance 0 is the lossless primary in
    // every phase, so its completion stream is the ordering witness.
    prop::check_with("serve_drain_switch", 6, |rng| {
        let n_clients = 1 + rng.below(3) as usize;
        let mut opts = ServeOptions::new(hw::orin(), DlaVersion::V2);
        opts.time_scale = 0.0; // no pacing: stress the handoff, not the clock
        opts.seed = rng.next_u64();
        opts.replan = ReplanPolicy {
            // small enough that every case hits several checkpoints
            check_every_frames: 16 + rng.below(8) as usize,
            // unconditional drain-and-switch at every checkpoint
            force_every_checks: Some(1),
            ..ReplanPolicy::default()
        };
        let mut expected_total = 0usize;
        for i in 0..n_clients {
            let frames = 60 + rng.below(90) as usize;
            expected_total += frames;
            opts.clients.push(ClientSpec::new(
                format!("c{i}"),
                frames,
                random_arrivals(rng),
            ));
        }
        let session = Session::builder()
            .instance(InstanceSpec::new("gan", "gen_cropping"))
            .instance(InstanceSpec::new("yolo", "yolo_lite"))
            .route(RoutePolicy::Fanout)
            .streams(n_clients)
            .queue_depth(2)
            .backend(Arc::new(SimBackend::new(hw::orin()).with_time_scale(0.0)))
            .build()
            .map_err(|e| e.to_string())?;
        let rep = serve::serve(session, opts).map_err(|e| e.to_string())?;

        prop_assert!(
            !rep.replans.is_empty(),
            "forced cadence must have produced at least one switch"
        );
        prop_assert!(
            rep.offered == expected_total && rep.shed == 0,
            "offered {} != expected {} (shed {})",
            rep.offered,
            expected_total,
            rep.shed
        );
        prop_assert!(
            rep.completed == expected_total,
            "drain-and-switch lost frames: completed {} of {}",
            rep.completed,
            expected_total
        );

        // Per-client ordering + uniqueness at the primary instance: ids
        // must be strictly increasing in completion order — a regression
        // (re-execution on the new spec, or an old-core frame finishing
        // after a new-core one) would show up as a repeat or a decrease.
        let mut last_seen: HashMap<usize, u64> = HashMap::new();
        let mut primary_count = 0usize;
        for ev in rep.completions.iter().filter(|c| c.instance == 0) {
            primary_count += 1;
            if let Some(prev) = last_seen.get(&ev.stream) {
                prop_assert!(
                    ev.frame_id > *prev,
                    "stream {} completed frame {} after frame {} (reorder or double execution)",
                    ev.stream,
                    ev.frame_id,
                    prev
                );
            }
            last_seen.insert(ev.stream, ev.frame_id);
        }
        prop_assert!(
            primary_count == expected_total,
            "primary completions {} != admitted {}",
            primary_count,
            expected_total
        );
        Ok(())
    });
}
