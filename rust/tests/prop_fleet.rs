//! Property: the fleet preserves per-client frame order and conserves
//! every offered frame (`offered == completed + shed`) under randomized
//! node mixes, arrival shapes, injected degradations, and *forced*
//! cross-node stream migrations — the drain-and-switch barrier must hold
//! no matter when or where streams move (the `util::prop` harness
//! reports the failing seed for deterministic replay).

use edgepipe::fleet::{run_fleet, DegradationEvent, FleetOptions, NodeProfile};
use edgepipe::prop_assert;
use edgepipe::serve::{ArrivalProcess, ClientSpec};
use edgepipe::util::prop;
use edgepipe::util::rng::Rng;
use std::collections::{HashMap, HashSet};

fn random_arrivals(rng: &mut Rng) -> ArrivalProcess {
    match rng.below(3) {
        0 => ArrivalProcess::Poisson {
            rate_fps: rng.range_f64(100.0, 2000.0),
        },
        1 => ArrivalProcess::Burst {
            burst_fps: rng.range_f64(500.0, 5000.0),
            burst_len: rng.range_i64(4, 32) as usize,
            idle_seconds: rng.range_f64(0.0, 0.01),
        },
        _ => ArrivalProcess::Ramp {
            start_fps: rng.range_f64(50.0, 300.0),
            end_fps: rng.range_f64(300.0, 3000.0),
        },
    }
}

#[test]
fn fleet_preserves_order_and_conserves_through_migrations() {
    prop::check_with("fleet_migration", 6, |rng| {
        let n_nodes = 2 + rng.below(3) as usize;
        let profiles: Vec<NodeProfile> = (0..n_nodes)
            .map(|_| {
                if rng.chance(0.5) {
                    NodeProfile::Orin
                } else {
                    NodeProfile::Xavier
                }
            })
            .collect();
        let mut opts = FleetOptions::new(profiles);
        opts.seed = rng.next_u64();
        opts.plan_frames = 16;
        opts.check_every = 16 + rng.below(32) as usize;
        // sometimes capped (exercises shed), sometimes lossless
        opts.max_backlog = if rng.chance(0.4) {
            8 + rng.below(24) as usize
        } else {
            0
        };
        // unconditional migration attempt every 1-2 checkpoints
        opts.migration.force_every_checks = Some(1 + rng.below(2) as usize);
        opts.migration.backlog_threshold = 16 + rng.below(64) as usize;
        let n_clients = 3 + rng.below(6) as usize;
        let mut expected = 0usize;
        for i in 0..n_clients {
            let frames = 40 + rng.below(80) as usize;
            expected += frames;
            opts.clients.push(ClientSpec::new(
                format!("c{i}"),
                frames,
                random_arrivals(rng),
            ));
        }
        for _ in 0..rng.below(3) {
            opts.degradations.push(DegradationEvent {
                at_seconds: rng.range_f64(0.0, 0.2),
                node: rng.below(n_nodes as u64) as usize,
                slowdown: rng.range_f64(2.0, 16.0),
            });
        }

        let rep = run_fleet(&opts).map_err(|e| e.to_string())?;

        // Fleet-wide conservation, whole run and per window.
        prop_assert!(
            rep.offered == expected,
            "offered {} != scheduled {}",
            rep.offered,
            expected
        );
        prop_assert!(
            rep.offered == rep.completed + rep.shed,
            "conservation broke: {} offered, {} completed, {} shed",
            rep.offered,
            rep.completed,
            rep.shed
        );
        let w_done: usize = rep.windows.iter().map(|w| w.completed).sum();
        let w_shed: usize = rep.windows.iter().map(|w| w.shed).sum();
        prop_assert!(
            w_done == rep.completed && w_shed == rep.shed,
            "windowed ledgers must sum to the run ledger"
        );

        // Forced cadence on a multi-node fleet must actually migrate.
        prop_assert!(
            !rep.migrations.is_empty(),
            "forced cadence produced no migration across {} checkpoints",
            rep.windows.len()
        );

        // The delivery log is complete (capacity is far above the load),
        // so it is the order/uniqueness witness.
        prop_assert!(
            rep.deliveries_truncated == 0 && rep.deliveries.len() == rep.completed,
            "delivery log must be complete: {} retained, {} truncated, {} completed",
            rep.deliveries.len(),
            rep.deliveries_truncated,
            rep.completed
        );
        let mut seen: HashSet<(usize, u64)> = HashSet::new();
        let mut per_stream: HashMap<usize, Vec<(u64, u64)>> = HashMap::new();
        for d in &rep.deliveries {
            prop_assert!(
                seen.insert((d.stream, d.frame_id)),
                "frame {} of stream {} delivered twice",
                d.frame_id,
                d.stream
            );
            prop_assert!(
                d.latency_s >= 0.0 && d.t.is_finite(),
                "bad delivery stamp on stream {}",
                d.stream
            );
            per_stream
                .entry(d.stream)
                .or_default()
                .push((d.t.to_bits(), d.frame_id));
        }
        // Client-visible order: sort each stream's deliveries by release
        // time (ties by id — the barrier can pin several releases to the
        // same instant); ids must be strictly increasing. A migration
        // that released a frame on the target before the source's last
        // release would show up here as a decrease.
        for (stream, mut log) in per_stream {
            log.sort_unstable();
            for pair in log.windows(2) {
                prop_assert!(
                    pair[1].1 > pair[0].1,
                    "stream {stream}: frame {} released after frame {} (reorder across migration)",
                    pair[1].1,
                    pair[0].1
                );
            }
        }
        Ok(())
    });
}
