//! Integration: the auto-placement planner end-to-end — plan → spec →
//! JSON → config parser → session on the deterministic `SimBackend`, no
//! artifacts on disk. Covers the PR's acceptance criteria: the two-GAN
//! Xavier request places the GANs on distinct DLA units with predicted
//! FPS ≥ the `dual_gan` preset, the emitted spec reloads through the
//! existing config loader, and planning is byte-deterministic.

use edgepipe::config::{GanVariant, PipelineConfig, Workload};
use edgepipe::dla::DlaVersion;
use edgepipe::hw::{self, EngineKind};
use edgepipe::pipeline::spec::PipelineSpec;
use edgepipe::pipeline::{InferenceBackend, SimBackend};
use edgepipe::placement::{self, PlacementRequest};
use edgepipe::session::Session;
use std::sync::Arc;

/// The paper's dual-GAN shape on the Xavier profile: two DLA-resident
/// GANs (GPU reserved for the detector stream), DLA rule set v1.
fn xavier_two_gan() -> PlacementRequest {
    let mut req = PlacementRequest::new(hw::xavier(), DlaVersion::V1).dla_resident_gans();
    req.frames = 48;
    req
}

fn sim() -> Arc<dyn InferenceBackend> {
    Arc::new(SimBackend::new(hw::xavier()).with_time_scale(0.0))
}

/// Acceptance: the planner recovers a DLA0/DLA1 split (not same-unit)
/// for the two-GAN Xavier request, and its predicted FPS is at least the
/// hand-written `dual_gan` preset's under the same scorer.
#[test]
fn planner_recovers_dla_split_and_beats_the_preset() {
    let req = xavier_two_gan();
    let outcome = placement::plan(&req).unwrap();

    let gan_units: Vec<(EngineKind, usize)> = outcome
        .spec
        .instances
        .iter()
        .filter(|i| i.artifact.starts_with("gen_"))
        .map(|i| (i.engine, i.engine_index))
        .collect();
    assert_eq!(gan_units.len(), 2, "two GAN instances placed");
    assert!(
        gan_units.iter().all(|(e, _)| *e == EngineKind::Dla),
        "GANs must be DLA-resident: {gan_units:?}"
    );
    assert_ne!(
        gan_units[0], gan_units[1],
        "planner must split the GANs across distinct DLA units"
    );
    let yolo = outcome
        .spec
        .instances
        .iter()
        .find(|i| i.artifact == "yolo_lite")
        .expect("detector placed");
    assert_eq!(
        yolo.engine,
        EngineKind::Gpu,
        "yolo_lite uses SiLU: DLA v1 placement must have been rejected"
    );

    let preset = Workload::DualGan.spec(GanVariant::Cropping);
    let preset_eval = placement::evaluate(&preset, &req.soc, req.frames).unwrap();
    assert!(
        outcome.eval.predicted_fps >= preset_eval.predicted_fps,
        "planned {:.2} fps must be >= dual_gan preset {:.2} fps",
        outcome.eval.predicted_fps,
        preset_eval.predicted_fps
    );

    // Satellite: fallback reasons are surfaced as structured rejection
    // data, not silently swallowed.
    assert!(
        outcome
            .rejected
            .iter()
            .any(|(k, r)| k.starts_with("gen_original") && r.contains("padding must be zero")),
        "{:?}",
        outcome.rejected
    );
    assert!(
        outcome
            .rejected
            .iter()
            .any(|(k, r)| k.starts_with("yolo_lite") && r.contains("SiLU")),
        "{:?}",
        outcome.rejected
    );
}

/// Acceptance: same request + seed ⇒ byte-identical emitted spec JSON.
#[test]
fn planning_is_byte_deterministic_under_a_seed() {
    let a = placement::plan(&xavier_two_gan()).unwrap();
    let b = placement::plan(&xavier_two_gan()).unwrap();
    assert_eq!(
        a.spec.to_json().to_pretty(),
        b.spec.to_json().to_pretty(),
        "same request + seed must emit byte-identical spec JSON"
    );
    // the seed rides into the emitted spec
    let mut req = xavier_two_gan();
    req.seed = 7;
    let c = placement::plan(&req).unwrap();
    assert_eq!(c.spec.seed, 7);
    assert_ne!(a.spec.to_json().to_pretty(), c.spec.to_json().to_pretty());
}

/// Acceptance: the emitted spec JSON reloads through the *existing*
/// config parser and serves on `SimBackend` with no artifacts.
#[test]
fn emitted_spec_reloads_through_the_config_parser_and_serves() {
    let outcome = placement::plan(&xavier_two_gan()).unwrap();
    let text = outcome.spec.to_json().to_pretty();

    // Through the config loader, exactly as `run --config` would.
    let cfg = PipelineConfig::from_json_str(&text).unwrap();
    let spec = cfg.spec();
    assert_eq!(spec.route, outcome.spec.route);
    assert_eq!(spec.instances.len(), outcome.spec.instances.len());
    for (a, b) in spec.instances.iter().zip(outcome.spec.instances.iter()) {
        assert_eq!(a.engine, b.engine);
        assert_eq!(a.engine_index, b.engine_index);
        assert_eq!(a.batch.max_batch, b.batch.max_batch);
    }

    // And it actually serves.
    let rep = Session::builder()
        .instance(spec.instances[0].clone())
        .instance(spec.instances[1].clone())
        .instance(spec.instances[2].clone())
        .route(spec.route)
        .frames(16)
        .backend(sim())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.total_frames, 16);
    // the two DLA GANs shard the stream losslessly under rr+fanout
    let gan_frames: usize = rep.instances[0].frames + rep.instances[1].frames;
    assert_eq!(gan_frames, 16);
}

/// `PipelineSpec::from_json_str` is the exact inverse of `to_json` for
/// the fields the planner controls (engine_index, route, max_batch).
#[test]
fn spec_json_roundtrip_preserves_planner_fields() {
    let outcome = placement::plan(&xavier_two_gan()).unwrap();
    let back = PipelineSpec::from_json_str(&outcome.spec.to_json().to_pretty()).unwrap();
    assert_eq!(back.to_json().to_pretty(), outcome.spec.to_json().to_pretty());
}

/// `Session::builder().auto_place(...)` serves a planned spec end-to-end.
#[test]
fn auto_place_session_serves_the_planned_spec() {
    let rep = Session::builder()
        .auto_place(&xavier_two_gan())
        .unwrap()
        .frames(12)
        .backend(sim())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.total_frames, 12);
    assert!(rep.instances.len() >= 3);
    // all three engine units surface in the serving report
    let labels: Vec<&str> = rep.engines.iter().map(|e| e.label.as_str()).collect();
    assert!(labels.contains(&"DLA0") && labels.contains(&"DLA1"), "{labels:?}");
}
