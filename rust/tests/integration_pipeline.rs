//! Integration: the full serving pipeline over real artifacts (the PJRT
//! backend). These skip without `make artifacts`; the artifact-free
//! equivalents on `SimBackend` live in `integration_session.rs`.
#![cfg(feature = "pjrt")]

use edgepipe::config::{GanVariant, PipelineConfig, Workload};
use edgepipe::pipeline::run_pipeline;
use std::path::Path;

fn artifacts_ready() -> bool {
    Path::new("artifacts/gen_cropping.hlo.txt").exists()
        && Path::new("artifacts/yolo_lite.hlo.txt").exists()
}

#[test]
fn standalone_pipeline_reconstructs_accurately() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = PipelineConfig {
        variant: GanVariant::Cropping,
        workload: Workload::GanStandalone,
        frames: 24,
        ..PipelineConfig::default()
    };
    let rep = run_pipeline(&cfg).unwrap();
    assert_eq!(rep.instances[0].frames, 24);
    assert_eq!(rep.dropped, 0);
    // trained model quality bar (well above the ~13 dB of an untrained net)
    assert!(
        rep.instances[0].psnr_mean > 25.0,
        "psnr {}",
        rep.instances[0].psnr_mean
    );
    assert!(rep.instances[0].ssim_pct_mean > 80.0);
}

#[test]
fn gan_plus_yolo_pipeline_processes_both() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = PipelineConfig {
        variant: GanVariant::Cropping,
        workload: Workload::GanPlusYolo,
        frames: 16,
        ..PipelineConfig::default()
    };
    let rep = run_pipeline(&cfg).unwrap();
    assert_eq!(rep.instances.len(), 2);
    // primary (gan) copy is lossless; fanout copies to yolo may shed on
    // overload but every copy is accounted for: processed + dropped = 16
    assert_eq!(rep.instances[0].frames, 16);
    assert_eq!(rep.instances[0].dropped, 0);
    assert_eq!(rep.instances[1].frames + rep.instances[1].dropped, 16);
    assert!(rep.instances[0].latency_ms_p50 > 0.0);
}

#[test]
fn two_gans_round_robin_splits_frames() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = PipelineConfig {
        variant: GanVariant::Cropping,
        workload: Workload::TwoGans,
        frames: 20,
        ..PipelineConfig::default()
    };
    let rep = run_pipeline(&cfg).unwrap();
    assert_eq!(rep.instances[0].frames + rep.instances[1].frames, 20);
    assert_eq!(rep.instances[0].frames, 10);
}

#[test]
fn multi_stream_client_server() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = PipelineConfig {
        variant: GanVariant::Cropping,
        workload: Workload::TwoGans,
        frames: 16,
        streams: 4,
        max_batch: 4,
        batch_timeout_us: 2000,
        ..PipelineConfig::default()
    };
    let rep = run_pipeline(&cfg).unwrap();
    // 4 streams x 4 frames, split across instances by stream
    assert_eq!(rep.instances[0].frames + rep.instances[1].frames, 16);
    assert_eq!(rep.dropped, 0);
}

#[test]
fn missing_artifacts_fail_fast() {
    let cfg = PipelineConfig {
        artifact_dir: "/nonexistent".into(),
        frames: 1,
        ..PipelineConfig::default()
    };
    let err = run_pipeline(&cfg).unwrap_err();
    assert!(err.to_string().contains("make artifacts"));
}
