//! Integration: scheduler -> simulator over the paper's three experiment
//! families, asserting the *shape* results of the evaluation section.

use edgepipe::config::GanVariant;
use edgepipe::dla::DlaVersion;
use edgepipe::hw::{orin, EngineKind};
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::models::yolov8::{yolov8, YoloConfig};
use edgepipe::sched::{haxconn, naive};
use edgepipe::sim::{simulate, SimConfig};

fn gan(v: GanVariant) -> edgepipe::graph::Graph {
    generator(&Pix2PixConfig::paper(), v).unwrap()
}

#[test]
fn fig9_standalone_ordering() {
    // original > cropping > convolution standalone.
    let soc = orin();
    let mut fps = Vec::new();
    for v in GanVariant::all() {
        let g = gan(v);
        let sched = naive::standalone(&g, EngineKind::Dla);
        let mut cfg = SimConfig::new(soc.clone(), 48);
        cfg.max_inflight = 1;
        let r = simulate(&[&g], &sched, &cfg).unwrap();
        fps.push(r.instances[0].fps);
    }
    assert!(fps[0] > fps[1], "original {} vs crop {}", fps[0], fps[1]);
    assert!(fps[1] > fps[2], "crop {} vs conv {}", fps[1], fps[2]);
}

#[test]
fn fig11_naive_concurrent_gpu_uplift() {
    // Hardware-aware models lift concurrent GPU (YOLO) throughput.
    let soc = orin();
    let y = yolov8(&YoloConfig::nano()).unwrap();
    let run = |v: GanVariant| {
        let g = gan(v);
        let sched = naive::gan_dla_yolo_gpu(&g, &y);
        let r = simulate(&[&g, &y], &sched, &SimConfig::new(soc.clone(), 96)).unwrap();
        (r.instances[1].fps, r.instances[0].fps) // (gpu yolo, dla gan)
    };
    let (gpu_orig, _) = run(GanVariant::Original);
    let (gpu_crop, dla_crop) = run(GanVariant::Cropping);
    let (gpu_conv, dla_conv) = run(GanVariant::Convolution);
    assert!(
        gpu_crop > gpu_orig * 1.05,
        "crop must lift GPU throughput: {gpu_crop} vs {gpu_orig}"
    );
    assert!(gpu_conv > gpu_orig * 1.05);
    // Fig 12: DLA throughput of crop beats conv (fewer layers).
    assert!(dla_crop > dla_conv);
}

#[test]
fn table4_two_gans_balance() {
    let soc = orin();
    // Modified variants: balanced FPS between the two instances.
    for v in [GanVariant::Cropping, GanVariant::Convolution] {
        let g = gan(v);
        let (sched, _) = haxconn::two_gans(&g, &soc, DlaVersion::V2).unwrap();
        let r = simulate(&[&g], &sched, &SimConfig::new(soc.clone(), 128)).unwrap();
        let a = r.instances[0].fps;
        let b = r.instances[1].fps;
        let ratio = a.max(b) / a.min(b);
        assert!(ratio < 1.15, "{v:?} unbalanced: {a} vs {b}");
    }
    // Original: unbalanced (GPU-dominant instance much faster).
    let g = gan(GanVariant::Original);
    let (sched, _) = haxconn::two_gans(&g, &soc, DlaVersion::V2).unwrap();
    let r = simulate(&[&g], &sched, &SimConfig::new(soc.clone(), 128)).unwrap();
    let gpu = r.fps_of_home(EngineKind::Gpu).unwrap();
    let dla = r.fps_of_home(EngineKind::Dla).unwrap();
    assert!(gpu > dla * 1.2, "original should be unbalanced: {gpu} vs {dla}");
}

#[test]
fn fig13_fragmentation() {
    // Original: many small DLA blocks; modified: few large blocks.
    let soc = orin();
    let stats = |v: GanVariant| {
        let g = gan(v);
        let (sched, _) = haxconn::two_gans(&g, &soc, DlaVersion::V2).unwrap();
        let r = simulate(&[&g], &sched, &SimConfig::new(soc.clone(), 64)).unwrap();
        let ds = r.timeline.engine_stats(EngineKind::Dla);
        (ds.span_count, ds.mean_block)
    };
    let (blocks_orig, mean_orig) = stats(GanVariant::Original);
    let (blocks_crop, mean_crop) = stats(GanVariant::Cropping);
    assert!(
        blocks_orig > 2 * blocks_crop,
        "fragmentation: {blocks_orig} vs {blocks_crop}"
    );
    assert!(mean_crop > 2.0 * mean_orig, "block size: {mean_crop} vs {mean_orig}");
}

#[test]
fn table6_gan_yolo_balance() {
    let soc = orin();
    let y = yolov8(&YoloConfig::nano()).unwrap();
    for v in [GanVariant::Cropping, GanVariant::Convolution] {
        let g = gan(v);
        let (sched, _) = haxconn::gan_plus_yolo(&g, &y, &soc, DlaVersion::V2).unwrap();
        let r = simulate(&[&g, &y], &sched, &SimConfig::new(soc.clone(), 128)).unwrap();
        let a = r.instances[0].fps;
        let b = r.instances[1].fps;
        assert!((a.max(b) / a.min(b)) < 1.15, "{v:?}: {a} vs {b}");
        // ~150 fps class on the calibrated Orin
        assert!(a > 100.0 && a < 260.0, "{v:?} fps {a}");
    }
}

#[test]
fn haxconn_beats_naive_for_modified_models() {
    // The headline: partitioned scheduling outperforms naive pinning in
    // total throughput for the DLA-compatible models.
    let soc = orin();
    let y = yolov8(&YoloConfig::nano()).unwrap();
    let g = gan(GanVariant::Cropping);
    let naive_sched = naive::gan_dla_yolo_gpu(&g, &y);
    let rn = simulate(&[&g, &y], &naive_sched, &SimConfig::new(soc.clone(), 96)).unwrap();
    let (hax, _) = haxconn::gan_plus_yolo(&g, &y, &soc, DlaVersion::V2).unwrap();
    let rh = simulate(&[&g, &y], &hax, &SimConfig::new(soc.clone(), 96)).unwrap();
    let naive_total: f64 = rn.instances.iter().map(|i| i.fps).sum();
    let hax_total: f64 = rh.instances.iter().map(|i| i.fps).sum();
    assert!(
        hax_total > naive_total,
        "haxconn {hax_total} should beat naive {naive_total}"
    );
}
