//! Integration: the composable Session/PipelineBuilder API end-to-end on
//! the deterministic `SimBackend` — the full coordinator (router, batcher,
//! backpressure, metrics, drop accounting) with **no AOT artifacts on
//! disk**, so this file runs in CI after a bare checkout.

use edgepipe::config::{GanVariant, PipelineConfig, Workload};
use edgepipe::hw::{self, EngineKind};
use edgepipe::imaging::phantom::PhantomConfig;
use edgepipe::pipeline::batcher::BatchPolicy;
use edgepipe::pipeline::driver::PipelineReport;
use edgepipe::pipeline::router::{RoutePolicy, Router};
use edgepipe::pipeline::source::PhantomSource;
use edgepipe::pipeline::spec::InstanceSpec;
use edgepipe::pipeline::{Frame, InferenceBackend, SimBackend};
use edgepipe::session::{PipelineBuilder, Session};
use std::sync::Arc;
use std::time::Duration;

/// Sim backend with latencies zeroed: conservation and routing semantics
/// are what these tests measure, not timing.
fn sim() -> Arc<dyn InferenceBackend> {
    Arc::new(SimBackend::new(hw::orin()).with_time_scale(0.0))
}

fn two_instance_session(
    route: RoutePolicy,
    max_batch: usize,
    frames: usize,
    streams: usize,
) -> Session {
    let batch = BatchPolicy {
        max_batch,
        timeout: Duration::from_micros(500),
    };
    Session::builder()
        .instance(
            InstanceSpec::new("gan", "gen_cropping")
                .with_batch(batch)
                .scored(true),
        )
        .instance(InstanceSpec::new("yolo", "yolo_lite").with_batch(batch))
        .route(route)
        .frames(frames)
        .streams(streams)
        .queue_depth(2)
        .backend(sim())
        .build()
        .unwrap()
}

/// produced = processed + dropped, per instance and in aggregate.
fn assert_conservation(rep: &PipelineReport, copies_per_instance: usize) {
    for inst in &rep.instances {
        assert_eq!(
            inst.frames + inst.dropped,
            copies_per_instance,
            "instance `{}` leaks frames ({} processed + {} dropped != {})",
            inst.label,
            inst.frames,
            inst.dropped,
            copies_per_instance
        );
    }
    let dropped: usize = rep.instances.iter().map(|i| i.dropped).sum();
    assert_eq!(dropped, rep.dropped, "per-instance drops disagree with total");
}

/// Fanout routing is zero-copy: every routed copy of a frame aliases the
/// SAME pixel plane (`Arc` pointer equality), and materialising the copies
/// only grows the plane's refcount — no pixel memory moves.
#[test]
fn fanout_routing_shares_planes_zero_copy() {
    let mut src = PhantomSource::new(PhantomConfig::default(), 7, 0, 1);
    let frame = src.next().unwrap();
    let mut router = Router::new(RoutePolicy::Fanout, 4);

    let base = Arc::strong_count(&frame.data);
    // materialise one copy per routed target, exactly as the driver does
    let copies: Vec<Frame> = router.route(&frame).map(|_target| frame.clone()).collect();
    assert_eq!(copies.len(), 4);
    assert_eq!(
        Arc::strong_count(&frame.data),
        base + 4,
        "each routed copy must be a refcount bump, not a plane copy"
    );
    for c in &copies {
        assert!(
            Arc::ptr_eq(&c.data, &frame.data),
            "routed copy must alias the original pixel plane"
        );
    }
    drop(copies);
    assert_eq!(Arc::strong_count(&frame.data), base);
}

/// Batched execution with `max_batch = 4` is one dispatch per batch but
/// must process exactly the same frame population as batch-1.
#[test]
fn batched_execution_matches_batch1_frame_counts() {
    let rep1 = two_instance_session(RoutePolicy::Fanout, 1, 48, 1)
        .run()
        .unwrap();
    let rep4 = two_instance_session(RoutePolicy::Fanout, 4, 48, 1)
        .run()
        .unwrap();
    for rep in [&rep1, &rep4] {
        assert_eq!(rep.total_frames, 48);
        assert_conservation(rep, 48);
        // the primary instance is lossless regardless of batching
        assert_eq!(rep.instances[0].frames, 48);
        assert_eq!(rep.instances[0].dropped, 0);
    }
    // batching changes dispatch count, never the processed population
    assert_eq!(
        rep1.instances[0].frames + rep1.instances[0].dropped,
        rep4.instances[0].frames + rep4.instances[0].dropped,
    );
}

#[test]
fn fanout_conserves_frames_across_batch_policies() {
    for max_batch in [1, 4] {
        let rep = two_instance_session(RoutePolicy::Fanout, max_batch, 64, 1)
            .run()
            .unwrap();
        assert_eq!(rep.total_frames, 64);
        // fanout: every instance sees one copy of every frame
        assert_conservation(&rep, 64);
        // the primary (first) instance is lossless by contract
        assert_eq!(rep.instances[0].frames, 64);
        assert_eq!(rep.instances[0].dropped, 0);
    }
}

#[test]
fn round_robin_conserves_and_splits_frames() {
    for max_batch in [1, 4] {
        let rep = two_instance_session(RoutePolicy::RoundRobin, max_batch, 20, 1)
            .run()
            .unwrap();
        assert_eq!(rep.total_frames, 20);
        // single-copy routes block (lossless): nothing may drop
        assert_eq!(rep.dropped, 0);
        assert_eq!(rep.instances[0].frames, 10);
        assert_eq!(rep.instances[1].frames, 10);
    }
}

#[test]
fn by_stream_conserves_frames_under_multi_stream_load() {
    let rep = two_instance_session(RoutePolicy::ByStream, 4, 64, 4)
        .run()
        .unwrap();
    assert_eq!(rep.total_frames, 64);
    assert_eq!(rep.dropped, 0);
    // 4 streams x 16 frames; streams 0,2 -> instance 0; streams 1,3 -> 1
    assert_eq!(rep.instances[0].frames, 32);
    assert_eq!(rep.instances[1].frames, 32);
}

#[test]
fn sim_backend_scores_fidelity_without_artifacts() {
    let rep = two_instance_session(RoutePolicy::Fanout, 1, 16, 1)
        .run()
        .unwrap();
    // gan instance is scored: identity "reconstruction" vs ground truth
    // gives a finite, positive PSNR
    assert!(rep.instances[0].psnr_mean > 0.0, "psnr {}", rep.instances[0].psnr_mean);
    assert!(rep.instances[0].psnr_mean.is_finite());
    // yolo instance is unscored
    assert_eq!(rep.instances[1].psnr_mean, 0.0);
    assert!(rep.wall_seconds > 0.0);
}

#[test]
fn three_instance_pipeline_beyond_the_enum_arms() {
    // A mix no `Workload` arm could express: two GAN variants round-robin
    // plus nothing hardcoded about N=2.
    let rep = Session::builder()
        .instance(InstanceSpec::new("g-crop", "gen_cropping").scored(true))
        .instance(InstanceSpec::new("g-conv", "gen_convolution").scored(true))
        .instance(InstanceSpec::new("g-orig", "gen_original").scored(true))
        .route(RoutePolicy::RoundRobin)
        .frames(27)
        .backend(sim())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.instances.len(), 3);
    let processed: usize = rep.instances.iter().map(|i| i.frames).sum();
    assert_eq!(processed + rep.dropped, 27);
    assert_eq!(rep.instances[0].frames, 9);
}

#[test]
fn workload_presets_match_prerefactor_report_semantics() {
    // TwoGans round-robin splits evenly, nothing drops (old driver
    // behavior), via the config-lowering path the CLI uses.
    let cfg = PipelineConfig {
        workload: Workload::TwoGans,
        variant: GanVariant::Cropping,
        frames: 20,
        ..PipelineConfig::default()
    };
    let rep = PipelineBuilder::from_config(&cfg)
        .backend(sim())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.instances.len(), 2);
    assert_eq!(rep.instances[0].frames, 10);
    assert_eq!(rep.instances[0].frames + rep.instances[1].frames, 20);
    assert_eq!(rep.dropped, 0);
    assert_eq!(rep.instances[0].label, "gan-inst1");

    // GanStandalone: one lossless instance.
    let cfg = PipelineConfig {
        workload: Workload::GanStandalone,
        frames: 24,
        ..PipelineConfig::default()
    };
    let rep = PipelineBuilder::from_config(&cfg)
        .backend(sim())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.instances.len(), 1);
    assert_eq!(rep.instances[0].frames, 24);
    assert_eq!(rep.dropped, 0);

    // GanPlusYolo: primary gan lossless; yolo copies conserved.
    let cfg = PipelineConfig {
        workload: Workload::GanPlusYolo,
        frames: 16,
        ..PipelineConfig::default()
    };
    let rep = PipelineBuilder::from_config(&cfg)
        .backend(sim())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.instances.len(), 2);
    assert_eq!(rep.instances[0].frames, 16);
    assert_eq!(rep.instances[1].frames + rep.instances[1].dropped, 16);
}

#[test]
fn config_instances_array_runs_end_to_end() {
    let cfg = PipelineConfig::from_json_str(
        r#"{
            "frames": 32,
            "route": "round-robin",
            "instances": [
                {"artifact": "gen_cropping", "label": "g0"},
                {"artifact": "gen_cropping", "label": "g1", "engine": "dla"}
            ]
        }"#,
    )
    .unwrap();
    let rep = PipelineBuilder::from_config(&cfg)
        .backend(sim())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.total_frames, 32);
    assert_eq!(rep.instances[0].label, "g0");
    assert_eq!(rep.instances[0].frames + rep.instances[1].frames, 32);
    assert_eq!(rep.dropped, 0);
}

/// `count` GAN instances pinned to the given DLA units, served with REAL
/// (time-scaled) modeled engine occupancy so placement shows up in FPS.
fn dla_gan_cluster(units: &[usize], frames: usize) -> PipelineReport {
    let mut builder = Session::builder();
    for (i, &u) in units.iter().enumerate() {
        builder = builder.instance(
            InstanceSpec::new(format!("gan{i}"), "gen_cropping")
                .on_engine_unit(EngineKind::Dla, u),
        );
    }
    let route = if units.len() == 1 {
        RoutePolicy::Fanout
    } else {
        RoutePolicy::RoundRobin
    };
    builder
        .route(route)
        .frames(frames)
        .queue_depth(4)
        .backend(Arc::new(SimBackend::new(hw::orin()).with_time_scale(0.1)))
        .build()
        .unwrap()
        .run()
        .unwrap()
}

/// The paper's Fig 13 claim, enforced end-to-end in the serving path: two
/// GANs pinned to the SAME DLA core serialize (aggregate ≈ 1× a single
/// instance), while splitting them across DLA0/DLA1 approaches 2×.
#[test]
fn engine_placement_is_load_bearing_in_serving() {
    let frames = 64;
    let single = dla_gan_cluster(&[0], frames);
    let same = dla_gan_cluster(&[0, 0], frames);
    let split = dla_gan_cluster(&[0, 1], frames);
    let f1 = single.total_fps();
    let f_same = same.total_fps();
    let f_split = split.total_fps();
    assert!(f1 > 0.0);
    assert!(
        f_same <= 1.15 * f1,
        "same-DLA pair must serialize: {f_same:.1} fps vs single {f1:.1} fps"
    );
    assert!(
        f_split >= 1.7 * f1,
        "DLA0/DLA1 split must approach 2x: {f_split:.1} fps vs single {f1:.1} fps"
    );

    // Exclusivity is structural, not statistical: the shared unit's spans
    // never overlap in the serving timeline.
    let mut spans: Vec<_> = same
        .timeline
        .spans
        .iter()
        .filter(|s| !s.is_transition)
        .collect();
    assert_eq!(spans.len(), frames, "one compute span per batch-1 dispatch");
    spans.sort_by(|a, b| a.t0.partial_cmp(&b.t0).unwrap());
    for w in spans.windows(2) {
        assert!(
            w[1].t0 >= w[0].t1 - 1e-9,
            "exclusive engine overlapped: {:?} vs {:?}",
            w[0],
            w[1]
        );
    }

    // The split run reports both DLA cores, each genuinely busy.
    let labels: Vec<&str> = split.engines.iter().map(|e| e.label.as_str()).collect();
    assert!(labels.contains(&"DLA0") && labels.contains(&"DLA1"), "{labels:?}");
    for e in &split.engines {
        assert!(
            e.utilization > 0.5 && e.utilization <= 1.0,
            "{} utilization {}",
            e.label,
            e.utilization
        );
        assert!(e.dispatches > 0);
    }
}

/// Acceptance: a streams-indivisible frame count is produced exactly
/// (remainder distributed across the first streams), and the report
/// carries per-engine utilization / idle-gap statistics in its JSON.
#[test]
fn report_exposes_engine_stats_and_conserves_indivisible_frames() {
    let rep = two_instance_session(RoutePolicy::Fanout, 1, 100, 3)
        .run()
        .unwrap();
    assert_eq!(rep.total_frames, 100, "frames % streams must not be dropped");
    assert_conservation(&rep, 100);
    let json = rep.to_json();
    let engines = json.get("engines").unwrap().as_arr().unwrap();
    assert!(!engines.is_empty());
    for e in engines {
        let util = e.get("utilization").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&util), "utilization {util}");
        assert!(e.get("idle_gap_ms_mean").unwrap().as_f64().is_some());
        assert!(e.get("idle_gap_ms_p99").unwrap().as_f64().is_some());
        assert!(e.get("dispatches").is_some());
        assert!(e.get("engine").unwrap().as_str().is_some());
    }
}

#[test]
fn dual_gan_preset_runs_end_to_end() {
    let cfg = PipelineConfig {
        workload: Workload::DualGan,
        frames: 24,
        ..PipelineConfig::default()
    };
    let rep = PipelineBuilder::from_config(&cfg)
        .backend(sim())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(rep.instances.len(), 3);
    assert_eq!(rep.total_frames, 24);
    // the two DLA-resident GANs shard the stream losslessly
    assert_eq!(rep.instances[0].frames, 12);
    assert_eq!(rep.instances[1].frames, 12);
    // the GPU detector sees every frame (droppable fanout copies)
    assert_eq!(rep.instances[2].frames + rep.instances[2].dropped, 24);
    // three distinct engine units surface in the report
    let labels: Vec<&str> = rep.engines.iter().map(|e| e.label.as_str()).collect();
    assert_eq!(labels.len(), 3);
    assert!(labels.contains(&"DLA0") && labels.contains(&"DLA1") && labels.contains(&"GPU"));
}

#[test]
fn report_json_carries_per_instance_drops() {
    let rep = two_instance_session(RoutePolicy::Fanout, 1, 8, 1)
        .run()
        .unwrap();
    let json = rep.to_json();
    let instances = json.get("instances").unwrap().as_arr().unwrap();
    assert_eq!(instances.len(), 2);
    for inst in instances {
        assert!(inst.get("dropped").is_some());
        assert!(inst.get("fps").is_some());
    }
    assert_eq!(
        json.get("total_frames").unwrap().as_u64().unwrap(),
        8
    );
}
