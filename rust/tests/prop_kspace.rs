//! Property-based tests for the k-space acquisition front-end: the
//! radix-2 FFT pair against its analytic inverse and the scalar oracle,
//! the R=1 fully-sampled fast path, GRAPPA-vs-zero-filled fidelity
//! ordering at the paper's acceleration factors, and the banded GRAPPA
//! fit against the serial reference solver.
//!
//! Like `prop_imaging`, the suite runs with the `parallel` feature on,
//! pinned to one thread (`EDGEPIPE_THREADS=1`), and compiled without the
//! feature: the FFT band-splits one chunk per row and the GRAPPA fold is
//! band-ordered, so the FFT comparisons are bit-exact in every
//! configuration while the fit (which legitimately reassociates f64
//! partial sums across bands) gets a relative bound.

use edgepipe::imaging::fft::Fft2;
use edgepipe::imaging::grappa::GrappaKernel;
use edgepipe::imaging::kspace::{coil_maps, sample_mask, Acquisition, GRAPPA_LAMBDA_REL};
use edgepipe::imaging::phantom::{paired_sample, PhantomConfig};
use edgepipe::imaging::{metrics, reference, Image};
use edgepipe::prop_assert;
use edgepipe::util::prop::{check, check_with};
use edgepipe::util::rng::Rng;

fn random_plane(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.next_f32() - 0.5).collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

fn psnr01(a: &[f32], b: &[f32], n: usize) -> f64 {
    let ia = Image::from_data(n, n, a.to_vec()).unwrap();
    let ib = Image::from_data(n, n, b.to_vec()).unwrap();
    metrics::psnr(&ia, &ib).unwrap()
}

/// One undersampled multi-coil acquisition built from the public pieces
/// (maps → per-coil FFT → masked rows), shared by the oracle props.
fn synth_kspace(
    rng: &mut Rng,
    n: usize,
    coils: usize,
    accel: usize,
    acs: usize,
) -> (Vec<f32>, Vec<f32>, Vec<bool>) {
    let plane = n * n;
    let (map_re, map_im) = coil_maps(n, coils);
    let mask = sample_mask(n, accel, acs);
    let fft = Fft2::new(n).unwrap();
    let slice = random_plane(rng, plane);
    let mut ks_re = vec![0.0f32; coils * plane];
    let mut ks_im = vec![0.0f32; coils * plane];
    for c in 0..coils {
        let o = c * plane;
        for p in 0..plane {
            ks_re[o + p] = map_re[o + p] * slice[p];
            ks_im[o + p] = map_im[o + p] * slice[p];
        }
        fft.fft2(&mut ks_re[o..o + plane], &mut ks_im[o..o + plane])
            .unwrap();
        for (row, &keep) in mask.iter().enumerate() {
            if !keep {
                ks_re[o + row * n..o + (row + 1) * n].fill(0.0);
                ks_im[o + row * n..o + (row + 1) * n].fill(0.0);
            }
        }
    }
    (ks_re, ks_im, mask)
}

#[test]
fn prop_fft2_ifft2_round_trip() {
    check("fft2 -> ifft2 round trip", |rng: &mut Rng| {
        let n = 1usize << (2 + rng.below(4)); // 4..=32
        let src_re = random_plane(rng, n * n);
        let src_im = random_plane(rng, n * n);
        let fft = Fft2::new(n).unwrap();
        let mut re = src_re.clone();
        let mut im = src_im.clone();
        fft.fft2(&mut re, &mut im).unwrap();
        fft.ifft2(&mut re, &mut im).unwrap();
        let dr = max_abs_diff(&re, &src_re);
        let di = max_abs_diff(&im, &src_im);
        prop_assert!(
            dr < 1e-4 && di < 1e-4,
            "round trip drifted {dr}/{di} on n={n}"
        );
        Ok(())
    });
}

#[test]
fn prop_fft2_matches_reference_bitexact() {
    check("fft2/ifft2 == reference", |rng: &mut Rng| {
        let n = 1usize << (2 + rng.below(4));
        let src_re = random_plane(rng, n * n);
        let src_im = random_plane(rng, n * n);
        let fft = Fft2::new(n).unwrap();
        let (mut or, mut oi) = (src_re.clone(), src_im.clone());
        let (mut rr, mut ri) = (src_re.clone(), src_im.clone());
        fft.fft2(&mut or, &mut oi).unwrap();
        reference::fft2(n, &mut rr, &mut ri).unwrap();
        let same = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
        };
        prop_assert!(same(&or, &rr) && same(&oi, &ri), "forward diverged on n={n}");
        fft.ifft2(&mut or, &mut oi).unwrap();
        reference::ifft2(n, &mut rr, &mut ri).unwrap();
        prop_assert!(same(&or, &rr) && same(&oi, &ri), "inverse diverged on n={n}");
        Ok(())
    });
}

#[test]
fn prop_r1_recon_is_the_fully_sampled_slice() {
    check_with("R=1 recon is bit-exact", 16, |rng: &mut Rng| {
        let cfg = PhantomConfig::default();
        let s = paired_sample(&cfg, rng);
        let n = cfg.size;
        let mut acq = Acquisition::new(n, 1, 0, 4).unwrap();
        acq.acquire(&s.ct).unwrap();
        let mut zf = vec![0.0f32; n * n];
        let mut gr = vec![0.0f32; n * n];
        acq.recon_zero_filled(&mut zf).unwrap();
        acq.recon_grappa(&mut gr).unwrap();
        prop_assert!(zf == s.ct.data, "zero-filled R=1 is not the source slice");
        prop_assert!(gr == s.ct.data, "grappa R=1 is not the source slice");
        Ok(())
    });
}

#[test]
fn prop_grappa_beats_zero_filled_at_r2_and_r4() {
    check_with("grappa > zero-filled PSNR", 6, |rng: &mut Rng| {
        let cfg = PhantomConfig::default();
        let n = cfg.size;
        for accel in [2usize, 4] {
            let s = paired_sample(&cfg, rng);
            let mut acq = Acquisition::new(n, accel, 16, 4).unwrap();
            acq.acquire(&s.ct).unwrap();
            let mut zf = vec![0.0f32; n * n];
            let mut gr = vec![0.0f32; n * n];
            acq.recon_zero_filled(&mut zf).unwrap();
            acq.recon_grappa(&mut gr).unwrap();
            let p_zf = psnr01(&s.ct.data, &zf, n);
            let p_gr = psnr01(&s.ct.data, &gr, n);
            prop_assert!(
                p_gr > p_zf + 3.0,
                "R={accel}: grappa {p_gr:.2} dB vs zero-filled {p_zf:.2} dB"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_grappa_fit_matches_reference() {
    check_with("grappa fit+apply ~= reference", 8, |rng: &mut Rng| {
        let (n, coils, accel, acs) = (32usize, 3usize, 2usize, 12usize);
        let (ks_re, ks_im, mask) = synth_kspace(rng, n, coils, accel, acs);
        let mut kern = GrappaKernel::new(coils, accel).unwrap();
        kern.fit(&ks_re, &ks_im, &mask, GRAPPA_LAMBDA_REL).unwrap();
        let (mut opt_re, mut opt_im) = (ks_re.clone(), ks_im.clone());
        kern.apply(&mut opt_re, &mut opt_im, &mask).unwrap();
        let (ref_re, ref_im) =
            reference::grappa_recon(n, coils, accel, &ks_re, &ks_im, &mask, GRAPPA_LAMBDA_REL)
                .unwrap();
        // Banded f64 fold vs serial sum: allow a tiny relative bound on
        // the synthesized samples (sampled rows are untouched copies).
        let scale = ks_re
            .iter()
            .chain(ks_im.iter())
            .map(|v| v.abs())
            .fold(0.0f32, f32::max)
            .max(1.0);
        let dr = max_abs_diff(&opt_re, &ref_re) / scale;
        let di = max_abs_diff(&opt_im, &ref_im) / scale;
        prop_assert!(
            dr < 1e-4 && di < 1e-4,
            "synthesis diverged from the serial oracle: {dr}/{di}"
        );
        Ok(())
    });
}
