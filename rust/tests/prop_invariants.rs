//! Property-based tests over coordinator invariants (routing, batching,
//! scheduling, simulation, codecs) using the in-tree harness.

use edgepipe::config::GanVariant;
use edgepipe::dla::planner::assign_engines;
use edgepipe::dla::DlaVersion;
use edgepipe::hw::{orin, EngineKind};
use edgepipe::imaging::lzw;
use edgepipe::models::pix2pix::{generator, Pix2PixConfig};
use edgepipe::postproc::{iou, nms, Detection};
use edgepipe::prop_assert;
use edgepipe::sched::{expand_fallback_with, SegmentPlan};
use edgepipe::sim::{simulate, SimConfig};
use edgepipe::util::prop::check;
use edgepipe::util::rng::Rng;

#[test]
fn prop_lzw_roundtrip() {
    check("lzw roundtrip", |rng: &mut Rng| {
        let len = rng.below(4000) as usize;
        // mixed entropy: runs + random
        let mut data = Vec::with_capacity(len);
        while data.len() < len {
            if rng.chance(0.5) {
                let b = rng.below(256) as u8;
                for _ in 0..rng.below(20) + 1 {
                    data.push(b);
                }
            } else {
                data.push(rng.below(256) as u8);
            }
        }
        data.truncate(len);
        let back = lzw::decompress(&lzw::compress(&data), data.len())
            .map_err(|e| e.to_string())?;
        prop_assert!(back == data, "roundtrip mismatch at len {len}");
        Ok(())
    });
}

#[test]
fn prop_fallback_expansion_partitions() {
    // For any segment range, fallback expansion covers exactly that range
    // in order, regardless of min_island.
    let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
    let n = g.compute_layers().len();
    check("fallback partition", |rng: &mut Rng| {
        let a = rng.below(n as u64) as usize;
        let b = a + 1 + rng.below((n - a) as u64) as usize;
        let min_island = 1 + rng.below(6) as usize;
        let seg = SegmentPlan { engine: EngineKind::Dla, start: a, end: b };
        let steps = expand_fallback_with(&g, &seg, DlaVersion::V2, min_island);
        let flat: Vec<_> = steps.iter().flat_map(|(_, v)| v.clone()).collect();
        let expect = &g.compute_layers()[a..b];
        prop_assert!(flat == expect, "range [{a},{b}) not covered");
        // consecutive steps alternate engines
        for w in steps.windows(2) {
            prop_assert!(w[0].0 != w[1].0, "adjacent steps share an engine");
        }
        Ok(())
    });
}

#[test]
fn prop_assign_engines_no_small_islands() {
    check("island merge", |rng: &mut Rng| {
        let n = 1 + rng.below(64) as usize;
        let flags: Vec<bool> = (0..n).map(|_| rng.chance(0.6)).collect();
        let min_island = 1 + rng.below(5) as usize;
        let engines = assign_engines(&flags, min_island);
        prop_assert!(engines.len() == n);
        // no DLA island shorter than min_island may touch a GPU run
        let mut i = 0;
        while i < n {
            if engines[i] == EngineKind::Dla {
                let start = i;
                while i < n && engines[i] == EngineKind::Dla {
                    i += 1;
                }
                let len = i - start;
                let touches_gpu = start > 0 || i < n;
                if touches_gpu && min_island > 1 {
                    prop_assert!(
                        len >= min_island,
                        "island of {len} survived (min {min_island})"
                    );
                }
            } else {
                i += 1;
            }
        }
        // incompatible layers never land on DLA
        for (f, e) in flags.iter().zip(engines.iter()) {
            if !f {
                prop_assert!(*e == EngineKind::Gpu, "incompatible layer on DLA");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nms_output_is_antichain() {
    check("nms antichain", |rng: &mut Rng| {
        let n = rng.below(40) as usize;
        let dets: Vec<Detection> = (0..n)
            .map(|_| {
                let x0 = rng.range_f64(0.0, 50.0) as f32;
                let y0 = rng.range_f64(0.0, 50.0) as f32;
                Detection {
                    x0,
                    y0,
                    x1: x0 + rng.range_f64(1.0, 20.0) as f32,
                    y1: y0 + rng.range_f64(1.0, 20.0) as f32,
                    score: rng.next_f32(),
                    class: rng.below(3) as usize,
                }
            })
            .collect();
        let thr = 0.3 + 0.4 * rng.next_f32();
        let kept = nms(dets.clone(), thr);
        prop_assert!(kept.len() <= dets.len());
        // no two kept boxes of the same class overlap above threshold
        for (i, a) in kept.iter().enumerate() {
            for b in kept.iter().skip(i + 1) {
                if a.class == b.class {
                    prop_assert!(iou(a, b) < thr, "kept boxes overlap");
                }
            }
        }
        // scores are sorted descending
        for w in kept.windows(2) {
            prop_assert!(w[0].score >= w[1].score);
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_conservation() {
    // All admitted frames complete; per-engine spans never overlap; the
    // makespan bounds every span.
    let g = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
    let soc = orin();
    check("sim conservation", |rng: &mut Rng| {
        let frames = 4 + rng.below(24) as usize;
        let n = g.compute_layers().len();
        let p = 1 + rng.below(n as u64 - 1) as usize;
        let sched = edgepipe::sched::Schedule {
            instances: vec![edgepipe::sched::InstanceSchedule {
                model: 0,
                label: "x".into(),
                segments: vec![
                    SegmentPlan { engine: EngineKind::Dla, start: 0, end: p },
                    SegmentPlan { engine: EngineKind::Gpu, start: p, end: n },
                ],
            }],
        };
        let mut cfg = SimConfig::new(soc.clone(), frames);
        cfg.max_inflight = 1 + rng.below(4) as usize;
        let r = simulate(&[&g], &sched, &cfg).map_err(|e| e.to_string())?;
        prop_assert!(r.instances[0].frames == frames, "lost frames");
        let makespan = r.makespan;
        for sp in &r.timeline.spans {
            prop_assert!(sp.t1 <= makespan + 1e-9);
            prop_assert!(sp.t0 <= sp.t1);
        }
        for engine in [EngineKind::Gpu, EngineKind::Dla] {
            let mut spans: Vec<_> = r
                .timeline
                .spans
                .iter()
                .filter(|s| s.engine == engine && !s.is_transition)
                .collect();
            spans.sort_by(|a, b| a.t0.partial_cmp(&b.t0).unwrap());
            for w in spans.windows(2) {
                prop_assert!(w[1].t0 >= w[0].t1 - 1e-9, "engine overlap");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_validation_rejects_gaps() {
    check("schedule gaps", |rng: &mut Rng| {
        let n = 10 + rng.below(50) as usize;
        let a = 1 + rng.below(n as u64 - 2) as usize;
        // gap: second segment starts past `a`
        let gap_start = a + 1 + rng.below((n - a) as u64) as usize;
        if gap_start >= n {
            return Ok(());
        }
        let inst = edgepipe::sched::InstanceSchedule {
            model: 0,
            label: "g".into(),
            segments: vec![
                SegmentPlan { engine: EngineKind::Dla, start: 0, end: a },
                SegmentPlan { engine: EngineKind::Gpu, start: gap_start, end: n },
            ],
        };
        prop_assert!(inst.validate(n).is_err(), "gap accepted");
        Ok(())
    });
}
