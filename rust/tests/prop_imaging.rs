//! Property-based equivalence tests: the optimized (row-parallel,
//! border-split, histogram/SAT-restructured) imaging kernels against the
//! scalar reference oracles kept in `edgepipe::imaging::reference`.
//!
//! The restructured kernels preserve the reference's exact f32
//! accumulation order in their interior fast paths, so most comparisons
//! are **bit-exact**, not tolerance-based; only the SSIM/MSE reductions
//! (which legitimately reassociate sums) get a 1e-5 bound. The same suite
//! runs with the `parallel` feature on (CI `rust` job), pinned to one
//! thread (`EDGEPIPE_THREADS=1` step), and compiled without the feature
//! (CI `rust-scalar` job) — band-partitioned writes are disjoint, so the
//! outputs must be identical in all three configurations.

use edgepipe::imaging::{canny, dct, histeq, lzw, median, metrics, reference, sobel, Image};
use edgepipe::prop_assert;
use edgepipe::util::prop::{check, check_with, default_cases};
use edgepipe::util::rng::Rng;

/// Random float image with arbitrary (non-quantized) pixel values.
fn random_image(rng: &mut Rng, max_dim: u64) -> Image {
    let w = 1 + rng.below(max_dim) as usize;
    let h = 1 + rng.below(max_dim) as usize;
    let data = (0..w * h).map(|_| rng.next_f32()).collect();
    Image::from_data(w, h, data).unwrap()
}

/// Random 8-bit-quantized image (every pixel is `b / 255.0`), the form
/// that engages `median_k`'s sliding-histogram fast path.
fn random_u8_image(rng: &mut Rng, max_dim: u64) -> Image {
    let w = 1 + rng.below(max_dim) as usize;
    let h = 1 + rng.below(max_dim) as usize;
    let bytes: Vec<u8> = (0..w * h).map(|_| rng.below(256) as u8).collect();
    Image::from_u8(w, h, &bytes).unwrap()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prop_sobel_matches_reference_bitexact() {
    check("sobel == reference", |rng: &mut Rng| {
        let img = random_image(rng, 24);
        let opt = sobel::sobel(&img);
        let refr = reference::sobel(&img);
        prop_assert!(
            bits(&opt.magnitude.data) == bits(&refr.magnitude.data),
            "magnitude diverged on {}x{}",
            img.width,
            img.height
        );
        prop_assert!(
            bits(&opt.direction) == bits(&refr.direction),
            "direction diverged on {}x{}",
            img.width,
            img.height
        );
        Ok(())
    });
}

#[test]
fn prop_gaussian5_matches_reference_bitexact() {
    check("gaussian5 == reference", |rng: &mut Rng| {
        let img = random_image(rng, 24);
        let opt = canny::gaussian5(&img);
        let refr = reference::gaussian5(&img);
        prop_assert!(
            bits(&opt.data) == bits(&refr.data),
            "gaussian5 diverged on {}x{}",
            img.width,
            img.height
        );
        Ok(())
    });
}

#[test]
fn prop_canny_matches_reference_bitexact() {
    check("canny == reference", |rng: &mut Rng| {
        let img = random_image(rng, 24);
        // thresholds spanning degenerate (low==high) and ordinary cases
        let low = rng.next_f32() * 0.3;
        let high = if rng.chance(0.2) { low } else { low + rng.next_f32() * 0.4 };
        let opt = canny::canny(&img, low, high);
        let refr = reference::canny(&img, low, high);
        prop_assert!(
            bits(&opt.data) == bits(&refr.data),
            "canny diverged on {}x{} (low {low}, high {high})",
            img.width,
            img.height
        );
        Ok(())
    });
}

#[test]
fn prop_median_float_matches_reference_bitexact() {
    // Arbitrary f32 pixels: the sorted-sliding-window path (and the k=3
    // exchange network) against the per-pixel partial-sort oracle.
    check("median_k float == reference", |rng: &mut Rng| {
        let img = random_image(rng, 20);
        for k in [1usize, 3, 5, 7] {
            let opt = median::median_k(&img, k);
            let refr = reference::median_k(&img, k);
            prop_assert!(
                bits(&opt.data) == bits(&refr.data),
                "median_k({k}) diverged on {}x{}",
                img.width,
                img.height
            );
        }
        Ok(())
    });
}

#[test]
fn prop_median_quantized_matches_reference_bitexact() {
    // 8-bit-quantized pixels: the Huang sliding-histogram path must still
    // reproduce the oracle bit-for-bit (bin -> f32 round-trips exactly).
    check("median_k u8 == reference", |rng: &mut Rng| {
        let img = random_u8_image(rng, 20);
        for k in [3usize, 5, 7, 9] {
            let opt = median::median_k(&img, k);
            let refr = reference::median_k(&img, k);
            prop_assert!(
                bits(&opt.data) == bits(&refr.data),
                "median_k({k}) diverged on quantized {}x{}",
                img.width,
                img.height
            );
        }
        Ok(())
    });
}

#[test]
fn prop_histeq_matches_reference_bitexact() {
    check("equalize == reference", |rng: &mut Rng| {
        let img = if rng.chance(0.5) {
            random_image(rng, 24)
        } else {
            random_u8_image(rng, 24)
        };
        let opt = histeq::equalize(&img);
        let refr = reference::equalize(&img);
        prop_assert!(
            bits(&opt.data) == bits(&refr.data),
            "equalize diverged on {}x{}",
            img.width,
            img.height
        );
        Ok(())
    });
}

#[test]
fn prop_dct_matches_reference_bitexact() {
    // Block transform requires 8-aligned dimensions.
    check("dct_image == reference", |rng: &mut Rng| {
        let w = 8 * (1 + rng.below(4) as usize);
        let h = 8 * (1 + rng.below(4) as usize);
        let data = (0..w * h).map(|_| rng.next_f32() - 0.5).collect();
        let img = Image::from_data(w, h, data).unwrap();
        let opt = dct::dct_image(&img);
        let refr = reference::dct_image(&img);
        prop_assert!(
            bits(&opt.data) == bits(&refr.data),
            "dct_image diverged on {w}x{h}"
        );
        Ok(())
    });
}

#[test]
fn prop_ssim_matches_reference_within_1e5() {
    // The summed-area-table SSIM reassociates the window sums, so the
    // comparison is tolerance-based: 1e-5 on a [0,1]-bounded score.
    check("ssim ~= reference", |rng: &mut Rng| {
        let w = 8 + rng.below(24) as usize;
        let h = 8 + rng.below(24) as usize;
        let a: Vec<f32> = (0..w * h).map(|_| rng.next_f32()).collect();
        // correlated pair: an affine remap plus small noise, so window
        // statistics are non-degenerate
        let b: Vec<f32> = a
            .iter()
            .map(|v| (v * 0.85 + 0.05 + 0.1 * rng.next_f32()).clamp(0.0, 1.0))
            .collect();
        let ia = Image::from_data(w, h, a).unwrap();
        let ib = Image::from_data(w, h, b).unwrap();
        let opt = metrics::ssim(&ia, &ib).map_err(|e| e.to_string())?;
        let refr = reference::ssim(&ia, &ib).map_err(|e| e.to_string())?;
        prop_assert!(
            (opt - refr).abs() < 1e-5,
            "ssim diverged on {w}x{h}: {opt} vs {refr}"
        );
        Ok(())
    });
}

#[test]
fn prop_lzw_matches_reference_bitexact_and_roundtrips() {
    // Reduced case count: each case compresses three payloads twice.
    check_with("lzw == reference", default_cases().min(32), |rng: &mut Rng| {
        let len = rng.below(6000) as usize;
        // mixed entropy: runs (dictionary-friendly) + random bytes
        let mut data = Vec::with_capacity(len);
        while data.len() < len {
            if rng.chance(0.6) {
                let b = rng.below(256) as u8;
                for _ in 0..rng.below(24) + 1 {
                    data.push(b);
                }
            } else {
                data.push(rng.below(256) as u8);
            }
        }
        data.truncate(len);
        let opt = lzw::compress(&data);
        let refr = reference::lzw_compress(&data);
        prop_assert!(opt == refr, "compressed stream diverged at len {len}");
        let back = lzw::decompress(&opt, data.len()).map_err(|e| e.to_string())?;
        prop_assert!(back == data, "roundtrip mismatch at len {len}");
        Ok(())
    });
}
