//! Fixture tests for the `edgepipe-lint` rules: each rule must fire on
//! a violating snippet, stay silent on a clean one, and respect the
//! `// lint:allow(rule)` escape hatch.
//!
//! Fixtures are analyzed under hot-path file names (e.g. `serve/mod.rs`)
//! so the module-scoped rules apply; the same snippet under a cold path
//! must stay silent, which pins the scoping logic too.

use edgepipe::analysis::{analyze_source, analyze_tree, Rule};
use std::path::Path;

fn rules_fired(rel: &str, src: &str) -> Vec<Rule> {
    analyze_source(rel, src).into_iter().map(|d| d.rule).collect()
}

fn fires(rel: &str, src: &str, rule: Rule) -> bool {
    rules_fired(rel, src).contains(&rule)
}

// ---------------------------------------------------------------- rule 1

#[test]
fn panic_freedom_fires_on_unwrap_in_hot_module() {
    let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
    assert!(fires("serve/mod.rs", bad, Rule::PanicFreedom));
    assert!(fires("fleet/vclock.rs", bad, Rule::PanicFreedom));
    let expect = r#"fn f(x: Option<u32>) -> u32 { x.expect("set") }"#;
    assert!(fires("pipeline/driver.rs", expect, Rule::PanicFreedom));
    let macros = r#"fn f() { panic!("boom") }"#;
    assert!(fires("imaging/sobel.rs", macros, Rule::PanicFreedom));
}

#[test]
fn panic_freedom_is_silent_on_clean_and_cold_code() {
    let clean = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) }";
    assert!(!fires("serve/mod.rs", clean, Rule::PanicFreedom));
    // same violation outside the hot-path scope: not this rule's business
    let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
    assert!(!fires("placement/score.rs", bad, Rule::PanicFreedom));
    assert!(
        !fires("imaging/reference.rs", bad, Rule::PanicFreedom),
        "the scalar oracle file is exempt"
    );
    // violations inside #[cfg(test)] mods are ignored
    let in_tests = "fn ok() {}\n#[cfg(test)]\nmod tests {\n fn f(x: Option<u32>) -> u32 { x.unwrap() }\n}";
    assert!(!fires("serve/mod.rs", in_tests, Rule::PanicFreedom));
}

#[test]
fn panic_freedom_allow_hatch_suppresses() {
    let allowed = "fn f(x: Option<u32>) -> u32 {\n // lint:allow(panic-freedom) — justified\n x.unwrap()\n}";
    assert!(!fires("serve/mod.rs", allowed, Rule::PanicFreedom));
    // the hatch is rule-specific: allowing another rule changes nothing
    let wrong_rule = "fn f(x: Option<u32>) -> u32 {\n // lint:allow(hot-path-alloc)\n x.unwrap()\n}";
    assert!(fires("serve/mod.rs", wrong_rule, Rule::PanicFreedom));
}

#[test]
fn panic_freedom_flags_indexing_only_in_manifest_fns() {
    let indexed = "impl R { pub fn route(&self, i: usize) -> u32 { self.q[i] } }";
    assert!(fires("pipeline/router.rs", indexed, Rule::PanicFreedom));
    // same indexing in a non-manifest fn of the same file: allowed
    let elsewhere = "impl R { pub fn new(&self, i: usize) -> u32 { self.q[i] } }";
    assert!(!fires("pipeline/router.rs", elsewhere, Rule::PanicFreedom));
}

// ---------------------------------------------------------------- rule 2

#[test]
fn lock_discipline_fires_on_rank_inversion() {
    // telemetry `inner` (rank 4) held while taking arbiter `state` (rank 0)
    let bad = "fn f(&self) {\n let g = self.inner.lock();\n let h = self.state.lock();\n}";
    assert!(fires("serve/telemetry.rs", bad, Rule::LockDiscipline));
    // relock form is classified the same way
    let bad_relock = "fn f(&self) {\n let g = relock(&self.inner);\n let h = relock(&self.state);\n}";
    assert!(fires("serve/telemetry.rs", bad_relock, Rule::LockDiscipline));
}

#[test]
fn lock_discipline_accepts_declared_order_and_scoped_guards() {
    // increasing rank is the declared order
    let ordered = "fn f(&self) {\n let g = relock(&self.state);\n let h = relock(&self.inner);\n}";
    assert!(!fires("pipeline/engines.rs", ordered, Rule::LockDiscipline));
    // a guard dropped at block end no longer constrains later code
    let scoped = "fn f(&self) {\n { let g = relock(&self.inner); }\n let h = relock(&self.state);\n}";
    assert!(!fires("serve/telemetry.rs", scoped, Rule::LockDiscipline));
}

#[test]
fn lock_discipline_fires_on_guard_across_dispatch() {
    let bad = "fn f(&self) {\n let g = relock(&self.inner);\n self.arbiter.dispatch(0);\n}";
    assert!(fires("serve/mod.rs", bad, Rule::LockDiscipline));
    let clean = "fn f(&self) {\n { let g = relock(&self.inner); }\n self.arbiter.dispatch(0);\n}";
    assert!(!fires("serve/mod.rs", clean, Rule::LockDiscipline));
}

#[test]
fn lock_discipline_flags_undeclared_lock_receivers() {
    let unknown = "fn f(&self) { let g = self.mystery.lock(); }";
    assert!(fires("fleet/mod.rs", unknown, Rule::LockDiscipline));
    let allowed = "fn f(&self) {\n // lint:allow(lock-discipline) — local, never nested\n let g = self.mystery.lock();\n}";
    assert!(!fires("fleet/mod.rs", allowed, Rule::LockDiscipline));
}

// ---------------------------------------------------------------- rule 3

#[test]
fn hot_path_alloc_fires_inside_manifest_fns() {
    let cloning = "impl C { pub fn submit(&mut self, f: Frame) -> bool { let g = f.clone(); true } }";
    assert!(fires("pipeline/driver.rs", cloning, Rule::HotPathAlloc));
    let vec_new = "impl A { pub fn dispatch(&self) { let v: Vec<u32> = Vec::new(); } }";
    assert!(fires("pipeline/engines.rs", vec_new, Rule::HotPathAlloc));
    let fmt = r#"impl A { pub fn dispatch(&self) { let s = format!("x"); } }"#;
    assert!(fires("pipeline/engines.rs", fmt, Rule::HotPathAlloc));
}

#[test]
fn hot_path_alloc_silent_outside_manifest_fns_and_with_allow() {
    // allocation in a non-manifest fn of a hot file is fine
    let in_new =
        "impl C { pub fn submit(&self) {} pub fn new() -> Self { let v: Vec<u32> = Vec::new(); C { v } } }";
    assert!(!fires("pipeline/driver.rs", in_new, Rule::HotPathAlloc));
    // manifest fn in another file entirely: out of scope
    let other_file = "impl C { pub fn submit(&self) { let v: Vec<u32> = Vec::new(); } }";
    assert!(!fires("placement/mod.rs", other_file, Rule::HotPathAlloc));
    let allowed = "impl C { pub fn submit(&mut self, f: Frame) -> bool {\n // lint:allow(hot-path-alloc) — Arc bump\n let g = f.clone(); true } }";
    assert!(!fires("pipeline/driver.rs", allowed, Rule::HotPathAlloc));
}

#[test]
fn hot_path_alloc_reports_rotted_manifest_entries() {
    // driver.rs without a `submit` fn: the manifest entry itself rots
    let no_submit = "impl C { pub fn other(&self) {} }";
    assert!(fires("pipeline/driver.rs", no_submit, Rule::HotPathAlloc));
}

// ---------------------------------------------------------------- rule 4

#[test]
fn counter_conservation_fires_on_unreported_counter() {
    let missing = r#"
pub struct WindowStats { pub completed: usize, pub shed: usize }
impl WindowStats {
    pub fn to_json(&self) -> Json { obj(vec![("completed", num(self.completed as f64))]) }
}
"#;
    assert!(fires("serve/telemetry.rs", missing, Rule::CounterConservation));
}

#[test]
fn counter_conservation_accepts_full_coverage_and_non_counters() {
    let full = r#"
pub struct WindowStats { pub completed: usize, pub shed: usize, pub tag: String }
impl WindowStats {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("completed", num(self.completed as f64)),
            ("shed", num(self.shed as f64)),
        ])
    }
}
"#;
    // `tag: String` is not a counter; its absence from to_json is fine
    assert!(!fires("serve/telemetry.rs", full, Rule::CounterConservation));
    // an uncontracted struct in an uncontracted file is out of scope
    let elsewhere = "pub struct WindowStats { pub completed: usize }";
    assert!(!fires("sched/mod.rs", elsewhere, Rule::CounterConservation));
}

#[test]
fn counter_conservation_fires_when_a_declared_writer_vanishes() {
    let no_writer = "pub struct WindowStats { pub completed: usize }";
    assert!(fires("serve/telemetry.rs", no_writer, Rule::CounterConservation));
}

// ---------------------------------------------------------------- rule 5

#[test]
fn unit_suffix_fires_on_silent_ms_s_mixing() {
    let bad = "fn f(lat_ms: f64, wall_s: f64) -> f64 { let x = lat_ms + wall_s; x }";
    assert!(fires("cost/mod.rs", bad, Rule::UnitSuffix));
}

#[test]
fn unit_suffix_accepts_explicit_conversions_and_single_units() {
    let converted = "fn f(lat_ms: f64, wall_s: f64) -> f64 { let x = lat_ms + wall_s * 1e3; x }";
    assert!(!fires("cost/mod.rs", converted, Rule::UnitSuffix));
    let named = "fn f(lat_ms: f64, wall_s: f64) -> f64 { let x = lat_ms + s_to_ms(wall_s); x }";
    assert!(!fires("cost/mod.rs", named, Rule::UnitSuffix));
    let single = "fn f(a_ms: f64, b_ms: f64) -> f64 { let x = a_ms + b_ms; x }";
    assert!(!fires("cost/mod.rs", single, Rule::UnitSuffix));
    let allowed = "fn f(lat_ms: f64, wall_s: f64) -> f64 {\n // lint:allow(unit-suffix)\n let x = lat_ms + wall_s; x\n}";
    assert!(!fires("cost/mod.rs", allowed, Rule::UnitSuffix));
}

// ---------------------------------------------------------------- rule 6

#[test]
fn feature_hygiene_fires_on_parallel_only_code() {
    let bad = r#"
#[cfg(feature = "parallel")]
fn run() { threads() }
"#;
    assert!(fires("util/parallel.rs", bad, Rule::FeatureHygiene));
}

#[test]
fn feature_hygiene_accepts_paired_cfgs_and_other_features() {
    let paired = r#"
#[cfg(feature = "parallel")]
fn run() { threads() }
#[cfg(not(feature = "parallel"))]
fn run() { serial() }
"#;
    assert!(!fires("util/parallel.rs", paired, Rule::FeatureHygiene));
    let other = r#"
#[cfg(feature = "pjrt")]
fn run() {}
"#;
    assert!(!fires("runtime/mod.rs", other, Rule::FeatureHygiene));
    let allowed = r#"
// lint:allow(feature-hygiene)
#[cfg(feature = "parallel")]
fn run() { threads() }
"#;
    assert!(!fires("util/parallel.rs", allowed, Rule::FeatureHygiene));
}

// ----------------------------------------------------------- whole tree

#[test]
fn the_crate_itself_is_lint_clean() {
    // Mirrors CI's `cargo run --bin lint -- rust/src`: the analyzer must
    // pass over the very tree it ships in, from either launch directory.
    let root = if Path::new("src/lib.rs").exists() {
        Path::new("src")
    } else {
        Path::new("rust/src")
    };
    let diags = analyze_tree(root).expect("tree walk");
    let listing: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(
        diags.is_empty(),
        "edgepipe-lint found violations in the shipped tree:\n{}",
        listing.join("\n")
    );
}
