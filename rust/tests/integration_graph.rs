//! Integration: model surgery -> DLA planning -> cost model, end to end
//! over the paper-scale graphs.

use edgepipe::config::GanVariant;
use edgepipe::cost::latency::LatencyModel;
use edgepipe::dla::{plan, planner::plan_with_island, DlaVersion};
use edgepipe::hw::{orin, EngineKind};
use edgepipe::models::pix2pix::{discriminator, generator, Pix2PixConfig};
use edgepipe::models::resnet::{resnet101, resnet50};
use edgepipe::models::vgg::vgg19;
use edgepipe::models::yolov8::{yolov8, YoloConfig};

#[test]
fn all_models_build_and_validate() {
    let cfg = Pix2PixConfig::paper();
    for v in GanVariant::all() {
        generator(&cfg, v).unwrap().validate().unwrap();
    }
    discriminator(&cfg).unwrap().validate().unwrap();
    yolov8(&YoloConfig::nano()).unwrap().validate().unwrap();
    resnet50(224).unwrap().validate().unwrap();
    resnet101(224).unwrap().validate().unwrap();
    vgg19(224).unwrap().validate().unwrap();
}

#[test]
fn surgery_to_planning_pipeline() {
    // The full contribution chain: original model falls back; surgery
    // makes it resident; the planner agrees; latency reflects it.
    let cfg = Pix2PixConfig::paper();
    let soc = orin();
    let m = LatencyModel::new(soc);

    let orig = generator(&cfg, GanVariant::Original).unwrap();
    let orig_plan = plan(&orig, DlaVersion::V2, 16).unwrap();
    assert!(!orig_plan.fully_dla_resident());
    assert_eq!(orig_plan.fallback_reasons.len(), 8); // the 8 padded deconvs

    for v in [GanVariant::Cropping, GanVariant::Convolution] {
        let g = generator(&cfg, v).unwrap();
        let p = plan(&g, DlaVersion::V2, 16).unwrap();
        assert!(p.fully_dla_resident(), "{v:?}");
        // standalone: modified slower than the island-merged original plan
        let orig_eff = plan_with_island(&orig, DlaVersion::V2, 16, 3).unwrap();
        assert!(m.plan_latency(&g, &p) > m.plan_latency(&orig, &orig_eff));
    }
}

#[test]
fn interface_preserved_across_variants() {
    let cfg = Pix2PixConfig::paper();
    let reference = generator(&cfg, GanVariant::Original).unwrap();
    let out_ref = reference.node(reference.outputs()[0]).shape;
    for v in GanVariant::all() {
        let g = generator(&cfg, v).unwrap();
        assert_eq!(g.node(g.outputs()[0]).shape, out_ref, "{v:?}");
        let input = g.node(g.inputs()[0]).shape;
        assert_eq!((input.c, input.h, input.w), (3, 256, 256));
    }
}

#[test]
fn dla_latency_ordering_consistent() {
    // DLA is slower than GPU for each full variant, both engines are
    // faster than CPU.
    let soc = orin();
    let m = LatencyModel::new(soc);
    for v in GanVariant::all() {
        let g = generator(&Pix2PixConfig::paper(), v).unwrap();
        let gpu = m.graph_latency(&g, EngineKind::Gpu);
        let dla = m.graph_latency(&g, EngineKind::Dla);
        let cpu = m.graph_latency(&g, EngineKind::Cpu);
        assert!(gpu < dla, "{v:?}");
        assert!(dla < cpu, "{v:?}");
    }
}
