//! Integration: the long-running serve front-end on the `SimBackend` —
//! QoS admission, rolling telemetry windows, and **online re-planning**
//! with drain-and-switch spec handoff. No artifacts on disk; runs in CI
//! after a bare checkout.

use edgepipe::dla::DlaVersion;
use edgepipe::hw::{self, EngineKind};
use edgepipe::pipeline::router::RoutePolicy;
use edgepipe::pipeline::{InstanceSpec, SimBackend};
use edgepipe::serve::{self, ArrivalProcess, ClientSpec, QosClass, ReplanPolicy, ServeOptions};
use edgepipe::session::Session;
use std::sync::Arc;

/// A deliberately naive placement: both reconstruction GANs pinned to
/// DLA0 (serialized), the GPU and DLA1 idle — the allocation the online
/// re-planner exists to fix.
fn naive_same_dla_session(time_scale: f64) -> Session {
    Session::builder()
        .instance(InstanceSpec::new("g0", "gen_cropping").on_engine_unit(EngineKind::Dla, 0))
        .instance(InstanceSpec::new("g1", "gen_cropping").on_engine_unit(EngineKind::Dla, 0))
        .route(RoutePolicy::RoundRobin)
        .streams(2)
        .backend(Arc::new(SimBackend::new(hw::orin()).with_time_scale(time_scale)))
        .build()
        .unwrap()
}

/// The acceptance scenario: a ramp load profile over the naive placement
/// must trigger at least one online re-plan, and the windowed FPS after
/// the switch must beat the windows served on the initial spec.
#[test]
fn ramp_load_triggers_replan_that_lifts_windowed_fps() {
    let time_scale = 0.05;
    let session = naive_same_dla_session(time_scale);
    let mut opts = ServeOptions::new(hw::orin(), DlaVersion::V2);
    opts.time_scale = time_scale;
    opts.replan = ReplanPolicy {
        check_every_frames: 128,
        ..ReplanPolicy::default()
    };
    for i in 0..2 {
        opts.clients.push(ClientSpec::new(
            format!("hospital-{i}"),
            320,
            ArrivalProcess::Ramp {
                start_fps: 30.0,
                end_fps: 250.0,
            },
        ));
    }
    let rep = serve::serve(session, opts).unwrap();

    assert!(
        !rep.replans.is_empty(),
        "idle GPU/DLA1 under a ramp must trigger at least one re-plan"
    );
    let first = &rep.replans[0];
    assert_ne!(first.from_key, first.to_key, "a real switch changes the spec");
    assert!(
        first.predicted_fps_after > first.predicted_fps_before,
        "the planner only switches for a predicted gain ({} -> {})",
        first.predicted_fps_before,
        first.predicted_fps_after
    );

    // Windowed FPS: post-switch windows must beat pre-switch windows.
    let pre: Vec<f64> = rep
        .windows
        .iter()
        .filter(|w| w.t1 <= first.at_seconds && w.completed > 0)
        .map(|w| w.fps)
        .collect();
    let post: Vec<f64> = rep
        .windows
        .iter()
        .filter(|w| w.t0 >= first.at_seconds && w.completed > 0)
        .map(|w| w.fps)
        .collect();
    assert!(
        !pre.is_empty() && !post.is_empty(),
        "need windows on both sides of the switch: {} pre, {} post",
        pre.len(),
        post.len()
    );
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        mean(&post) > mean(&pre) * 1.2,
        "re-planned windows must serve faster: pre {:.1} fps, post {:.1} fps",
        mean(&pre),
        mean(&post)
    );

    // Conservation across the handoff: nothing lost, nothing doubled.
    assert_eq!(rep.offered, 640);
    assert_eq!(rep.shed, 0, "unlimited class never sheds");
    assert_eq!(rep.completed, 640, "drain-and-switch must not lose frames");
    assert!(rep.phases.len() >= 2, "a switch opens a new phase");

    // Switch events are recorded in the merged serving timeline as
    // zero-width transition markers on every unit.
    let markers = rep
        .timeline
        .spans
        .iter()
        .filter(|sp| sp.is_transition && sp.t0 == sp.t1)
        .count();
    assert_eq!(
        markers,
        rep.replans.len() * 3,
        "one marker per SoC unit (GPU, DLA0, DLA1) per switch"
    );
}

/// QoS admission: a rate-limited bursty class sheds, the lossless class
/// does not, and offered == completed + shed holds exactly.
#[test]
fn burst_overload_sheds_by_class_and_conserves_frames() {
    let time_scale = 0.02;
    let session = naive_same_dla_session(time_scale);
    let mut opts = ServeOptions::new(hw::orin(), DlaVersion::V2);
    opts.time_scale = time_scale;
    opts.replan = ReplanPolicy::disabled();
    opts.qos = vec![
        QosClass::unlimited("recon", 0),
        QosClass::unlimited("bulk", 1).rate_limited(40.0, 4.0),
    ];
    opts.clients = vec![
        ClientSpec::new("steady", 200, ArrivalProcess::Poisson { rate_fps: 80.0 }),
        ClientSpec::new(
            "blaster",
            200,
            ArrivalProcess::Burst {
                burst_fps: 2000.0,
                burst_len: 50,
                idle_seconds: 0.2,
            },
        )
        .qos_class(1),
    ];
    let rep = serve::serve(session, opts).unwrap();

    assert_eq!(rep.offered, 400);
    assert_eq!(
        rep.offered,
        rep.completed + rep.shed,
        "admission sheds + completions must account for every offered frame"
    );
    assert!(rep.shed > 0, "a 2000 fps burst into a 40 fps bucket must shed");
    assert_eq!(rep.shed, rep.shed_rate_limit + rep.shed_deadline);
    // shed is attributed to the bulk class only
    let (_, recon_stats) = &rep.classes[0];
    let (_, bulk_stats) = &rep.classes[1];
    assert_eq!(recon_stats.shed_rate_limit + recon_stats.shed_deadline, 0);
    assert!(bulk_stats.shed_rate_limit > 0);
    // the pipeline's own overload counter is a different ledger entirely
    for phase in &rep.phases {
        assert_eq!(
            phase.report.shed,
            rep.shed,
            "admission sheds surface on the phase report's shed field"
        );
        // round-robin routes have no droppable fanout copies: overload
        // drops stay zero even while admission sheds hundreds
        assert_eq!(phase.report.dropped, 0);
    }
    // serialized JSON is parseable and finite
    let txt = rep.to_json().to_compact();
    let doc = edgepipe::config::json::Json::parse(&txt).unwrap();
    assert!(doc.get("latency_ms_p99").unwrap().as_f64().unwrap().is_finite());
}

/// The serve report's JSON carries the fields the CI smoke job asserts
/// on (replans, conservation counters, finite latency percentiles).
#[test]
fn serve_report_json_has_smoke_contract_fields() {
    let session = naive_same_dla_session(0.0);
    let mut opts = ServeOptions::new(hw::orin(), DlaVersion::V2);
    opts.time_scale = 0.0;
    opts.replan = ReplanPolicy {
        check_every_frames: 64,
        force_every_checks: Some(1),
        ..ReplanPolicy::default()
    };
    opts.clients = vec![ClientSpec::new(
        "c",
        200,
        ArrivalProcess::Poisson { rate_fps: 500.0 },
    )];
    let rep = serve::serve(session, opts).unwrap();
    let doc = edgepipe::config::json::Json::parse(&rep.to_json().to_compact()).unwrap();
    for key in [
        "offered",
        "accepted",
        "completed",
        "shed",
        "latency_ms_p99",
        "wall_seconds",
    ] {
        assert!(doc.get(key).is_some(), "missing `{key}`");
    }
    let replans = doc.get("replans").unwrap().as_arr().unwrap();
    assert!(!replans.is_empty(), "forced switches must be reported");
    assert!(doc.get("windows").unwrap().as_arr().is_some());
    assert!(doc.get("switch_markers").unwrap().as_f64().unwrap() >= 3.0);
}
