//! Integration: the fleet layer end to end on the virtual clock — a
//! mixed Xavier/Orin cluster under ramp load migrates streams off a
//! degraded node and beats the no-migration baseline, conserving every
//! frame; and the event-driven executor carries >1000 concurrent
//! streams in one process. No threads, no artifacts, CI-safe.

use edgepipe::fleet::{
    run_fleet, DegradationEvent, FleetOptions, MigrationPolicy, NodeProfile, StreamRouter,
};
use edgepipe::serve::{ArrivalProcess, ClientSpec};
use std::collections::HashSet;

/// The acceptance scenario: 4 mixed nodes, 12 ramping clients, one node
/// throttled 12x mid-run. With migration on, streams drain off the
/// degraded node and post-migration windowed FPS beats the frozen
/// baseline; nothing is lost or duplicated either way.
fn scenario(migrate: bool, degraded_node: usize) -> FleetOptions {
    let mut opts = FleetOptions::new(vec![
        NodeProfile::Orin,
        NodeProfile::Xavier,
        NodeProfile::Orin,
        NodeProfile::Xavier,
    ]);
    opts.seed = 11;
    opts.check_every = 256;
    opts.plan_frames = 16;
    opts.migration = if migrate {
        MigrationPolicy {
            backlog_threshold: 64,
            ..MigrationPolicy::default()
        }
    } else {
        MigrationPolicy::disabled()
    };
    opts.degradations.push(DegradationEvent {
        at_seconds: 0.8,
        node: degraded_node,
        slowdown: 12.0,
    });
    for i in 0..12 {
        opts.clients.push(ClientSpec::new(
            format!("hospital-{i}"),
            200,
            ArrivalProcess::Ramp {
                start_fps: 20.0,
                end_fps: 120.0,
            },
        ));
    }
    opts
}

#[test]
fn migration_off_a_degraded_node_beats_the_frozen_baseline() {
    // Degrade the node the front door loads most heavily, so the
    // throttle actually bites (assignment is deterministic).
    let router = StreamRouter::new(4, 64);
    let mut counts = [0usize; 4];
    for s in 0..12 {
        counts[router.node_for(s)] += 1;
    }
    let degraded = (0..4).max_by_key(|&n| counts[n]).unwrap();

    let with = run_fleet(&scenario(true, degraded)).unwrap();
    let without = run_fleet(&scenario(false, degraded)).unwrap();

    // Conservation + uniqueness in BOTH runs: zero frames lost or
    // duplicated across every migration.
    for (name, rep) in [("migrating", &with), ("frozen", &without)] {
        assert_eq!(rep.offered, 2400, "{name}: every scheduled frame offered");
        assert_eq!(rep.shed, 0, "{name}: unlimited backlog never sheds");
        assert_eq!(rep.completed, 2400, "{name}: every frame delivered");
        assert_eq!(rep.deliveries.len(), 2400);
        assert_eq!(rep.deliveries_truncated, 0);
        let unique: HashSet<(usize, u64)> = rep
            .deliveries
            .iter()
            .map(|d| (d.stream, d.frame_id))
            .collect();
        assert_eq!(unique.len(), 2400, "{name}: a frame was duplicated");
    }

    assert!(
        !with.migrations.is_empty(),
        "a 12x-degraded node under ramp load must shed streams to peers"
    );
    assert!(without.migrations.is_empty(), "disabled policy must not move");
    let moved_off: usize = with.nodes[degraded].migrations_out;
    assert!(moved_off >= 1, "the degraded node must be the source");
    let t_mig = with.migrations[0].at_seconds;

    // Windowed FPS after the first migration: checkpoints are pinned to
    // the (identical) arrival schedule in both runs, so every non-drain
    // window aligns exactly; compare completions in the post-migration
    // windows. The final (drain) window is excluded — the frozen run
    // parks the degraded node's frames there.
    let post = |rep: &edgepipe::fleet::FleetReport| -> (usize, f64) {
        let mut completed = 0usize;
        let mut span = 0.0f64;
        for w in &rep.windows[..rep.windows.len() - 1] {
            if w.t0 >= t_mig {
                completed += w.completed;
                span += w.t1 - w.t0;
            }
        }
        (completed, span)
    };
    let (done_with, span_with) = post(&with);
    let (done_without, span_without) = post(&without);
    assert!(span_with > 0.0, "need post-migration windows to compare");
    assert!(
        (span_with - span_without).abs() < 1e-9,
        "windows must align across runs: {span_with} vs {span_without}"
    );
    let fps_with = done_with as f64 / span_with;
    let fps_without = done_without as f64 / span_without;
    assert!(
        fps_with > fps_without,
        "post-migration windowed FPS must beat the frozen baseline: \
         {fps_with:.1} vs {fps_without:.1}"
    );
    // And the whole run finishes sooner when the fleet rebalances.
    assert!(
        with.virtual_seconds < without.virtual_seconds,
        "migrating run must drain earlier: {:.3}s vs {:.3}s",
        with.virtual_seconds,
        without.virtual_seconds
    );
}

/// The virtual-clock executor's scale contract: >1000 concurrent client
/// streams served by one process, one thread, inside the test budget.
#[test]
fn virtual_clock_serves_over_1000_concurrent_streams() {
    let t0 = std::time::Instant::now();
    let mut opts = FleetOptions::new(vec![
        NodeProfile::Orin,
        NodeProfile::Xavier,
        NodeProfile::Orin,
        NodeProfile::Xavier,
        NodeProfile::Orin,
        NodeProfile::Xavier,
        NodeProfile::Orin,
        NodeProfile::Xavier,
    ]);
    opts.check_every = 512;
    opts.plan_frames = 16;
    for i in 0..1200 {
        opts.clients.push(ClientSpec::new(
            format!("s{i}"),
            3,
            ArrivalProcess::Poisson { rate_fps: 30.0 },
        ));
    }
    let rep = run_fleet(&opts).unwrap();
    assert_eq!(rep.streams, 1200);
    assert_eq!(rep.offered, 3600);
    assert_eq!(rep.offered, rep.completed + rep.shed);
    assert_eq!(rep.shed, 0);
    assert!(rep.latency_ms_p99.is_finite() && rep.latency_ms_p99 > 0.0);
    // every stream got service
    let served: HashSet<usize> = rep.deliveries.iter().map(|d| d.stream).collect();
    assert_eq!(served.len(), 1200);
    // the point of the executor: this is cheap (no thread-per-worker,
    // no sleeps) — generous debug-build budget, typically milliseconds
    // past the two plan-on-boot searches
    assert!(
        t0.elapsed().as_secs_f64() < 60.0,
        "1200 virtual streams must fit the time budget, took {:.1}s",
        t0.elapsed().as_secs_f64()
    );
}

/// JSON contract the fleet-smoke CI job asserts on.
#[test]
fn fleet_report_json_has_smoke_contract_fields() {
    let mut opts = FleetOptions::new(vec![NodeProfile::Orin, NodeProfile::Xavier]);
    opts.check_every = 64;
    opts.plan_frames = 16;
    opts.migration.force_every_checks = Some(1);
    for i in 0..4 {
        opts.clients.push(ClientSpec::new(
            format!("c{i}"),
            80,
            ArrivalProcess::Poisson { rate_fps: 400.0 },
        ));
    }
    let rep = run_fleet(&opts).unwrap();
    let doc = edgepipe::config::json::Json::parse(&rep.to_json().to_compact()).unwrap();
    for key in [
        "offered",
        "completed",
        "shed",
        "streams",
        "fps",
        "latency_ms_p99",
        "virtual_seconds",
        "migration_count",
    ] {
        assert!(doc.get(key).is_some(), "missing `{key}`");
    }
    assert!(doc.get("migration_count").unwrap().as_f64().unwrap() >= 1.0);
    assert!(doc.get("latency_ms_p99").unwrap().as_f64().unwrap().is_finite());
    let nodes = doc.get("nodes").unwrap().as_arr().unwrap();
    assert_eq!(nodes.len(), 2);
    for n in nodes {
        assert!(n.get("power_w").unwrap().as_f64().unwrap() > 0.0);
        assert!(n.get("fps_per_watt").unwrap().as_f64().is_some());
    }
    assert!(doc.get("windows").unwrap().as_arr().is_some());
    assert!(doc.get("migrations").unwrap().as_arr().is_some());
}
