//! Property: the unified observability layer is a faithful witness of
//! the serve loop — per-engine-unit execution spans never overlap (the
//! arbiter's leases are exclusive), frame-lifecycle stage stamps stay
//! monotone, the span ledger reconciles with the arbiter's dispatch
//! counters, and the metrics registry's admission ledger balances —
//! under randomized client mixes, arrival shapes, and forced
//! drain-and-switch cadences. Feature-agnostic: CI runs it with the
//! `parallel` feature on (default) and off (rust-scalar job).

use edgepipe::dla::DlaVersion;
use edgepipe::hw;
use edgepipe::obs::ObsHub;
use edgepipe::pipeline::router::RoutePolicy;
use edgepipe::pipeline::{InstanceSpec, SimBackend};
use edgepipe::prop_assert;
use edgepipe::serve::{self, ArrivalProcess, ClientSpec, ReplanPolicy, ServeOptions};
use edgepipe::session::Session;
use edgepipe::util::prop;
use edgepipe::util::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

fn random_arrivals(rng: &mut Rng) -> ArrivalProcess {
    match rng.below(3) {
        0 => ArrivalProcess::Poisson {
            rate_fps: rng.range_f64(100.0, 2000.0),
        },
        1 => ArrivalProcess::Burst {
            burst_fps: rng.range_f64(500.0, 5000.0),
            burst_len: rng.range_i64(4, 32) as usize,
            idle_seconds: rng.range_f64(0.0, 0.01),
        },
        _ => ArrivalProcess::Ramp {
            start_fps: rng.range_f64(50.0, 300.0),
            end_fps: rng.range_f64(300.0, 3000.0),
        },
    }
}

#[test]
fn observed_serve_spans_reconcile_and_stamps_stay_monotone() {
    prop::check_with("obs_serve_witness", 6, |rng| {
        let n_clients = 1 + rng.below(3) as usize;
        let mut opts = ServeOptions::new(hw::orin(), DlaVersion::V2);
        opts.time_scale = 0.0; // no pacing: stress bookkeeping, not the clock
        opts.seed = rng.next_u64();
        opts.replan = ReplanPolicy {
            check_every_frames: 16 + rng.below(16) as usize,
            force_every_checks: Some(1 + rng.below(2) as usize),
            ..ReplanPolicy::default()
        };
        let hub = Arc::new(ObsHub::new());
        opts.obs = Some(Arc::clone(&hub));
        let mut expected_total = 0usize;
        for i in 0..n_clients {
            let frames = 48 + rng.below(80) as usize;
            expected_total += frames;
            opts.clients.push(ClientSpec::new(
                format!("c{i}"),
                frames,
                random_arrivals(rng),
            ));
        }
        let session = Session::builder()
            .instance(InstanceSpec::new("gan", "gen_cropping"))
            .instance(InstanceSpec::new("yolo", "yolo_lite"))
            .route(RoutePolicy::Fanout)
            .streams(n_clients)
            .queue_depth(2)
            .backend(Arc::new(SimBackend::new(hw::orin()).with_time_scale(0.0)))
            .build()
            .map_err(|e| e.to_string())?;
        let rep = serve::serve(session, opts).map_err(|e| e.to_string())?;
        prop_assert!(
            rep.offered == expected_total && rep.completed == expected_total,
            "conservation broke before obs checks: {} offered / {} completed of {}",
            rep.offered,
            rep.completed,
            expected_total
        );

        // 1. Frame-lifecycle stage stamps: every recorded copy monotone.
        let stages = rep
            .stages
            .as_ref()
            .ok_or("observed serve must report a stage breakdown")?;
        prop_assert!(stages.frames > 0, "no stage records folded");
        prop_assert!(
            hub.stages.non_monotone() == 0,
            "{} non-monotone stage-stamp records",
            hub.stages.non_monotone()
        );

        // 2. Exclusive leases: execution spans on one physical unit
        // never overlap, across every drain-and-switch phase.
        let mut per_unit: HashMap<(hw::EngineKind, usize), Vec<(f64, f64)>> = HashMap::new();
        for sp in rep.timeline.spans.iter().filter(|sp| !sp.is_transition) {
            per_unit
                .entry((sp.engine, sp.unit))
                .or_default()
                .push((sp.t0, sp.t1));
        }
        prop_assert!(!per_unit.is_empty(), "timeline recorded no execution spans");
        for ((engine, unit), mut spans) in per_unit {
            spans.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in spans.windows(2) {
                prop_assert!(
                    w[0].1 <= w[1].0 + 1e-9,
                    "{engine:?}{unit} spans overlap: [{:.9}, {:.9}] then [{:.9}, {:.9}]",
                    w[0].0,
                    w[0].1,
                    w[1].0,
                    w[1].1
                );
            }
        }

        // 3. Span/dispatch conservation: one execution span per arbiter
        // dispatch (exact only when the merged timeline wasn't capped).
        if !rep.timeline_truncated {
            let exec_spans = rep
                .timeline
                .spans
                .iter()
                .filter(|sp| !sp.is_transition)
                .count();
            let dispatches: usize = rep
                .phases
                .iter()
                .map(|p| p.report.engines.iter().map(|e| e.dispatches).sum::<usize>())
                .sum();
            prop_assert!(
                exec_spans == dispatches,
                "{exec_spans} execution spans != {dispatches} arbiter dispatches"
            );
        }

        // 4. The registry's admission ledger mirrors the report's.
        let offered = hub.registry.counter("serve_offered_total", "").get() as usize;
        let accepted = hub.registry.counter("serve_accepted_total", "").get() as usize;
        let shed = hub.registry.counter("serve_shed_total", "").get() as usize;
        let completed = hub.registry.counter("serve_completed_total", "").get() as usize;
        prop_assert!(
            offered == rep.offered && offered == accepted + shed,
            "registry ledger off: {offered} offered != {accepted} accepted + {shed} shed \
             (report offered {})",
            rep.offered
        );
        // `serve_completed_total` counts per-instance copies (one sink
        // call per completed copy) — exactly what the stage accumulator
        // records, and never fewer than the unique-frame ledger.
        prop_assert!(
            completed as u64 == stages.frames,
            "registry completed {completed} != {} stage records",
            stages.frames
        );
        prop_assert!(
            completed >= rep.completed,
            "per-copy completions {completed} < unique completed {}",
            rep.completed
        );
        Ok(())
    });
}
