//! Integration: PJRT runtime against the real artifacts (skipped when
//! `make artifacts` has not run).
#![cfg(feature = "pjrt")]

use edgepipe::runtime::{Artifact, RuntimeClient, WeightsFile};
use std::path::Path;

fn artifacts_ready() -> bool {
    Path::new("artifacts/gen_cropping.hlo.txt").exists()
}

#[test]
fn weights_files_parse_and_match_meta() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    for name in ["gen_original", "gen_cropping", "gen_convolution", "yolo_lite"] {
        let w = WeightsFile::load(Path::new(&format!("artifacts/{name}.weights.bin"))).unwrap();
        assert!(w.tensors.len() > 10, "{name}");
        assert!(w.param_count() > 100_000, "{name}");
    }
    // cropping and original share the identical parameter structure
    let a = WeightsFile::load(Path::new("artifacts/gen_original.weights.bin")).unwrap();
    let b = WeightsFile::load(Path::new("artifacts/gen_cropping.weights.bin")).unwrap();
    assert_eq!(a.param_count(), b.param_count());
    let c = WeightsFile::load(Path::new("artifacts/gen_convolution.weights.bin")).unwrap();
    assert!(c.param_count() > a.param_count());
}

#[test]
fn generator_artifact_runs_and_is_deterministic() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let client = RuntimeClient::cpu().unwrap();
    let a = Artifact::load(&client, Path::new("artifacts"), "gen_cropping").unwrap();
    assert_eq!(a.input_shape, [1, 64, 64, 1]);
    let frame = vec![0.25f32; 64 * 64];
    let out1 = a.run_image(&frame).unwrap();
    let out2 = a.run_image(&frame).unwrap();
    assert_eq!(out1[0].dims, vec![1, 64, 64, 1]);
    assert_eq!(out1[0].data, out2[0].data, "PJRT execution must be deterministic");
    // tanh output range
    assert!(out1[0].data.iter().all(|v| v.abs() <= 1.0));
}

#[test]
fn generator_variants_agree_on_interface_not_values() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let client = RuntimeClient::cpu().unwrap();
    let frame = vec![0.1f32; 64 * 64];
    let mut outs = Vec::new();
    for name in ["gen_original", "gen_cropping", "gen_convolution"] {
        let a = Artifact::load(&client, Path::new("artifacts"), name).unwrap();
        let o = a.run_image(&frame).unwrap();
        assert_eq!(o[0].dims, vec![1, 64, 64, 1], "{name}");
        outs.push(o[0].data.clone());
    }
    // independently trained models must differ
    assert_ne!(outs[0], outs[1]);
}

#[test]
fn pallas_smoke_artifact_roundtrip() {
    // The Pallas-lowered GEMM kernel loaded and executed through the
    // rust PJRT path: identity weights => output == input.
    if !Path::new("artifacts/pallas_matmul.hlo.txt").exists() {
        eprintln!("skipping: pallas smoke artifact not built");
        return;
    }
    let client = RuntimeClient::cpu().unwrap();
    let a = Artifact::load(&client, Path::new("artifacts"), "pallas_matmul").unwrap();
    let x: Vec<f32> = (0..128 * 128).map(|i| (i % 97) as f32 * 0.01).collect();
    let out = a.run_image(&x).unwrap();
    for (i, (got, want)) in out[0].data.iter().zip(x.iter()).enumerate() {
        assert!((got - want).abs() < 1e-4, "idx {i}: {got} vs {want}");
    }
}

#[test]
fn bad_frame_size_rejected() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let client = RuntimeClient::cpu().unwrap();
    let a = Artifact::load(&client, Path::new("artifacts"), "gen_cropping").unwrap();
    assert!(a.run_image(&vec![0.0; 100]).is_err());
}

#[test]
fn missing_artifact_fails_cleanly() {
    let client = RuntimeClient::cpu().unwrap();
    let err = match Artifact::load(&client, Path::new("artifacts"), "nonexistent") {
        Err(e) => e,
        Ok(_) => panic!("expected missing-artifact error"),
    };
    assert!(err.to_string().contains("make artifacts"));
}
