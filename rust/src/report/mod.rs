//! Report generators — one function per table/figure of the paper.
//!
//! Each generator runs the corresponding experiment on the simulator (or
//! the real PJRT pipeline for accuracy numbers) and prints the same rows /
//! series the paper reports, plus a JSON blob for EXPERIMENTS.md. See
//! DESIGN.md §4 for the experiment index.

use crate::config::json::{arr, num, obj, s, Json};
use crate::config::GanVariant;
use crate::cost::flops::node_cost;
use crate::cost::latency::{layer_latency, LatencyModel};
use crate::dla::DlaVersion;
use crate::error::Result;
use crate::graph::Graph;
use crate::hw::{self, EngineKind, SocSpec};
use crate::imaging::{self, Image};
use crate::models::pix2pix::{generator, Pix2PixConfig};
use crate::models::resnet::resnet50;
use crate::models::yolov8::{yolov8, YoloConfig};
use crate::sched::{haxconn, naive};
use crate::sim::{simulate, SimConfig};
use crate::util::rng::Rng;
use std::time::Instant;

fn gan(v: GanVariant) -> Graph {
    generator(&Pix2PixConfig::paper(), v).expect("paper pix2pix builds")
}

fn yolo() -> Graph {
    yolov8(&YoloConfig::nano()).expect("yolov8 builds")
}

/// Table I — best engine pairing per medical-imaging algorithm.
///
/// Classical algorithms are *really executed* on the CPU (wall-clock); the
/// GPU/FPGA/NPU latencies come from the engine models (roofline over each
/// algorithm's flops/bytes profile). ResNet50 uses the graph cost model.
pub fn table1(soc: &SocSpec) -> Json {
    let size = 512usize;
    let frame_px = (size * size) as f64;

    // Measure CPU wall-clock on real implementations.
    let mut rng = Rng::new(42);
    let mut img = Image::zeros(size, size);
    for v in &mut img.data {
        *v = rng.next_f32();
    }
    let cpu_ms = |f: &dyn Fn(&Image)| -> f64 {
        let t0 = Instant::now();
        let mut n = 0;
        while t0.elapsed().as_millis() < 120 {
            f(&img);
            n += 1;
        }
        t0.elapsed().as_secs_f64() * 1e3 / n as f64
    };

    struct Algo {
        name: &'static str,
        cpu_ms: f64,
        flops: f64,
        bytes: f64,
        /// suits massively-parallel engines (GPU)
        parallel: bool,
        /// suits pipelined fixed-function fabric (FPGA)
        streaming: bool,
    }

    let algos = vec![
        Algo {
            name: "Median Filter",
            cpu_ms: cpu_ms(&|i| {
                imaging::median::median3(i);
            }),
            flops: frame_px * 30.0,
            bytes: frame_px * 8.0,
            parallel: true,
            // data-dependent compare network: poor fit for shallow pipelines
            streaming: false,
        },
        Algo {
            name: "Histogram Equalization",
            cpu_ms: cpu_ms(&|i| {
                imaging::histeq::equalize(i);
            }),
            flops: frame_px * 4.0,
            bytes: frame_px * 8.0,
            parallel: true,
            streaming: true, // two linear passes: ideal stream pipeline
        },
        Algo {
            name: "Sobel for Image Segmentation",
            cpu_ms: cpu_ms(&|i| {
                imaging::sobel::sobel_edges(i, 0.5);
            }),
            flops: frame_px * 14.0,
            bytes: frame_px * 8.0,
            parallel: false, // tiny stencil: launch overhead dominates on GPU
            streaming: true,
        },
        Algo {
            name: "Canny for Image Segmentation",
            cpu_ms: cpu_ms(&|i| {
                imaging::canny::canny(i, 0.1, 0.3);
            }),
            flops: frame_px * 60.0,
            bytes: frame_px * 24.0,
            parallel: true,
            streaming: false, // hysteresis BFS is irregular
        },
        Algo {
            name: "Lempel-Ziv-Welch",
            cpu_ms: {
                let bytes = img.to_u8();
                let t0 = Instant::now();
                let mut n = 0;
                while t0.elapsed().as_millis() < 120 {
                    imaging::lzw::compress(&bytes);
                    n += 1;
                }
                t0.elapsed().as_secs_f64() * 1e3 / n as f64
            },
            flops: frame_px * 12.0,
            bytes: frame_px * 10.0,
            parallel: true, // block-parallel dictionary coding
            streaming: false,
        },
        Algo {
            name: "Discrete Cosine Transform",
            cpu_ms: cpu_ms(&|i| {
                imaging::dct::dct_image(i);
            }),
            flops: frame_px * 32.0,
            bytes: frame_px * 8.0,
            parallel: true,
            streaming: false,
        },
    ];

    let mut rows = Vec::new();
    println!("Table I: ideal hardware per medical-imaging algorithm (512x512)");
    println!(
        "{:<32} {:>9} {:>9} {:>9} {:>9}  {}",
        "Algorithm", "CPU ms", "GPU ms", "FPGA ms", "NPU ms", "Best pairing"
    );
    let model_ms = |flops: f64, bytes: f64, e: &hw::EngineSpec, eff_mult: f64| -> f64 {
        let compute = flops / (e.elementwise_rate * eff_mult);
        let mem = bytes / e.mem_bw;
        (compute.max(mem) + e.launch_overhead) * 1e3
    };
    for a in algos {
        let gpu = model_ms(a.flops, a.bytes, &soc.gpu, if a.parallel { 1.0 } else { 0.12 });
        let fpga = model_ms(a.flops, a.bytes, &hw::fpga(), if a.streaming { 1.0 } else { 0.2 });
        let npu = model_ms(a.flops, a.bytes, &hw::npu(), 0.25); // poor fit for pixel algorithms
        let mut best = ("CPU and GPU", gpu);
        if fpga < best.1 {
            best = ("CPU and FPGA", fpga);
        }
        if npu < best.1 {
            best = ("CPU and NPU", npu);
        }
        if a.cpu_ms < best.1 {
            best = ("CPU", a.cpu_ms);
        }
        println!(
            "{:<32} {:>9.3} {:>9.3} {:>9.3} {:>9.3}  {}",
            a.name, a.cpu_ms, gpu, fpga, npu, best.0
        );
        rows.push(obj(vec![
            ("algorithm", s(a.name)),
            ("cpu_ms", num(a.cpu_ms)),
            ("gpu_ms", num(gpu)),
            ("fpga_ms", num(fpga)),
            ("npu_ms", num(npu)),
            ("best", s(best.0)),
        ]));
    }
    // ResNet50: DNN workload through the graph cost model on each engine.
    let rn = resnet50(224).expect("resnet50 builds");
    let m = LatencyModel::new(soc.clone());
    let cpu_ms_rn = m.graph_latency(&rn, EngineKind::Cpu) * 1e3;
    let gpu_ms_rn = m.graph_latency(&rn, EngineKind::Gpu) * 1e3;
    let layers = rn.compute_layers();
    let npu_spec = hw::npu();
    let npu_ms_rn: f64 = layers
        .iter()
        .map(|&id| layer_latency(&node_cost(&rn, id), &npu_spec))
        .sum::<f64>()
        * 1e3;
    let fpga_spec = hw::fpga();
    let fpga_ms_rn: f64 = layers
        .iter()
        .map(|&id| layer_latency(&node_cost(&rn, id), &fpga_spec))
        .sum::<f64>()
        * 1e3;
    let mut best = ("CPU and GPU", gpu_ms_rn);
    if npu_ms_rn < best.1 {
        best = ("CPU and NPU", npu_ms_rn);
    }
    if fpga_ms_rn < best.1 {
        best = ("CPU and FPGA", fpga_ms_rn);
    }
    println!(
        "{:<32} {:>9.3} {:>9.3} {:>9.3} {:>9.3}  {}",
        "ResNet50", cpu_ms_rn, gpu_ms_rn, fpga_ms_rn, npu_ms_rn, best.0
    );
    rows.push(obj(vec![
        ("algorithm", s("ResNet50")),
        ("cpu_ms", num(cpu_ms_rn)),
        ("gpu_ms", num(gpu_ms_rn)),
        ("fpga_ms", num(fpga_ms_rn)),
        ("npu_ms", num(npu_ms_rn)),
        ("best", s(best.0)),
    ]));
    arr(rows)
}

/// Table II — parameter counts from the full-scale IR plus (when
/// available) the measured accuracy of the trained scaled models from
/// `artifacts/table2.json`.
pub fn table2(artifact_dir: &str) -> Json {
    println!("Table II: original vs modified Pix2Pix");
    println!(
        "{:<16} {:>14} {:>10} {:>10} {:>10}",
        "Variant", "Params(256px)", "SSIM", "PSNR", "MSE"
    );
    let trained = std::fs::read_to_string(format!("{artifact_dir}/table2.json"))
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    let mut rows = Vec::new();
    for v in GanVariant::all() {
        let g = gan(v);
        let params = g.param_count();
        let (ssim, psnr, mse) = trained
            .as_ref()
            .and_then(|t| t.get(v.name()))
            .map(|m| {
                (
                    m.get("ssim_pct").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
                    m.get("psnr").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
                    m.get("mse").and_then(|x| x.as_f64()).unwrap_or(f64::NAN),
                )
            })
            .unwrap_or((f64::NAN, f64::NAN, f64::NAN));
        println!(
            "{:<16} {:>14} {:>10.2} {:>10.2} {:>10.2}",
            v.name(),
            params,
            ssim,
            psnr,
            mse
        );
        rows.push(obj(vec![
            ("variant", s(v.name())),
            ("params_paper_scale", num(params as f64)),
            ("ssim_pct", num(ssim)),
            ("psnr", num(psnr)),
            ("mse", num(mse)),
        ]));
    }
    arr(rows)
}

/// Figs 8–10 — standalone execution: throughput per variant and GPU
/// utilization (single-stream, trtexec-style).
pub fn fig9_fig10(soc: &SocSpec) -> Json {
    println!("Fig 9/10: standalone DLA execution per variant");
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>12}",
        "Variant", "FPS", "GPUutil%", "DLAutil%", "DLA blocks"
    );
    let mut rows = Vec::new();
    for v in GanVariant::all() {
        let g = gan(v);
        let sched = naive::standalone(&g, EngineKind::Dla);
        let mut cfg = SimConfig::new(soc.clone(), 96);
        cfg.max_inflight = 1; // trtexec profiles single-stream
        let r = simulate(&[&g], &sched, &cfg).expect("sim");
        let gs = r.timeline.engine_stats(EngineKind::Gpu);
        let ds = r.timeline.engine_stats(EngineKind::Dla);
        println!(
            "{:<16} {:>10.1} {:>10.1} {:>10.1} {:>12}",
            v.name(),
            r.instances[0].fps,
            gs.utilization * 100.0,
            ds.utilization * 100.0,
            ds.span_count
        );
        rows.push(obj(vec![
            ("variant", s(v.name())),
            ("fps", num(r.instances[0].fps)),
            ("gpu_util_pct", num(gs.utilization * 100.0)),
            ("dla_util_pct", num(ds.utilization * 100.0)),
        ]));
    }
    arr(rows)
}

/// Figs 11/12 — naive scheduling (client-server): GAN on DLA + YOLO on
/// GPU concurrently.
pub fn fig11_fig12(soc: &SocSpec) -> Json {
    println!("Fig 11/12: naive concurrent scheduling (GAN->DLA, YOLO->GPU)");
    println!(
        "{:<16} {:>14} {:>14}",
        "Variant", "GPU(yolo) FPS", "DLA(gan) FPS"
    );
    let y = yolo();
    let mut rows = Vec::new();
    for v in GanVariant::all() {
        let g = gan(v);
        let sched = naive::gan_dla_yolo_gpu(&g, &y);
        let r = simulate(&[&g, &y], &sched, &SimConfig::new(soc.clone(), 192)).expect("sim");
        println!(
            "{:<16} {:>14.1} {:>14.1}",
            v.name(),
            r.instances[1].fps,
            r.instances[0].fps
        );
        rows.push(obj(vec![
            ("variant", s(v.name())),
            ("gpu_yolo_fps", num(r.instances[1].fps)),
            ("dla_gan_fps", num(r.instances[0].fps)),
        ]));
    }
    arr(rows)
}

/// Tables III/IV + Fig 13 — two GAN instances under HaX-CoNN.
pub fn table3_table4_fig13(soc: &SocSpec) -> Json {
    println!("Table III/IV + Fig 13: two GAN instances, HaX-CoNN");
    println!(
        "{:<16} {:>8} {:>8} {:>12} {:>12} {:>11} {:>13}",
        "Variant", "DLA>GPU", "GPU>DLA", "GPU FPS", "DLA FPS", "DLA blocks", "meanblock ms"
    );
    let mut rows = Vec::new();
    for v in GanVariant::all() {
        let g = gan(v);
        let (sched, _ss) = haxconn::two_gans(&g, soc, DlaVersion::V2).expect("sched");
        let r = simulate(&[&g], &sched, &SimConfig::new(soc.clone(), 192)).expect("sim");
        let p1 = sched.instances[0].partition_points().0;
        let p2 = sched.instances[1].partition_points().1;
        let gpu_fps = r.fps_of_home(EngineKind::Gpu).unwrap_or(0.0);
        let dla_fps = r.fps_of_home(EngineKind::Dla).unwrap_or(0.0);
        let ds = r.timeline.engine_stats(EngineKind::Dla);
        println!(
            "{:<16} {:>8} {:>8} {:>12.2} {:>12.2} {:>11} {:>13.2}",
            v.name(),
            p1.map(|x| x.to_string()).unwrap_or_else(|| "-".into()),
            p2.map(|x| x.to_string()).unwrap_or_else(|| "-".into()),
            gpu_fps,
            dla_fps,
            ds.span_count,
            ds.mean_block * 1e3,
        );
        rows.push(obj(vec![
            ("variant", s(v.name())),
            ("dla_to_gpu", num(p1.unwrap_or(0) as f64)),
            ("gpu_to_dla", num(p2.unwrap_or(0) as f64)),
            ("gpu_fps", num(gpu_fps)),
            ("dla_fps", num(dla_fps)),
            ("dla_blocks", num(ds.span_count as f64)),
            ("dla_mean_block_ms", num(ds.mean_block * 1e3)),
            ("dla_idle_gap_ms_mean", num(ds.idle_gaps.mean() * 1e3)),
        ]));
    }
    arr(rows)
}

/// Tables V/VI + Fig 14 — GAN + YOLOv8 under HaX-CoNN.
pub fn table5_table6_fig14(soc: &SocSpec) -> Json {
    println!("Table V/VI + Fig 14: GAN + YOLOv8, HaX-CoNN");
    println!(
        "{:<16} {:>8} {:>8} {:>12} {:>12}",
        "Variant", "DLA>GPU", "GPU>DLA", "GPU FPS", "DLA FPS"
    );
    let y = yolo();
    let mut rows = Vec::new();
    for v in GanVariant::all() {
        let g = gan(v);
        let (sched, _ss) = haxconn::gan_plus_yolo(&g, &y, soc, DlaVersion::V2).expect("sched");
        let r = simulate(&[&g, &y], &sched, &SimConfig::new(soc.clone(), 192)).expect("sim");
        let (p1, p2) = sched.instances[0].partition_points();
        // Columns by dominant engine (paper convention).
        let gpu_fps = r.fps_of_home(EngineKind::Gpu).unwrap_or(0.0);
        let dla_fps = r.fps_of_home(EngineKind::Dla).unwrap_or(gpu_fps);
        println!(
            "{:<16} {:>8} {:>8} {:>12.2} {:>12.2}",
            v.name(),
            p1.map(|x| x.to_string()).unwrap_or_else(|| "-".into()),
            p2.map(|x| x.to_string()).unwrap_or_else(|| "-".into()),
            gpu_fps,
            dla_fps
        );
        rows.push(obj(vec![
            ("variant", s(v.name())),
            ("gan_dla_to_gpu", num(p1.unwrap_or(0) as f64)),
            ("gan_gpu_to_dla", num(p2.unwrap_or(0) as f64)),
            ("gpu_fps", num(gpu_fps)),
            ("dla_fps", num(dla_fps)),
        ]));
    }
    arr(rows)
}

/// Fig 13/14 ASCII timelines for one variant (the Nsight-figure stand-in).
pub fn timeline_ascii(soc: &SocSpec, variant: GanVariant, with_yolo: bool) -> Result<String> {
    let g = gan(variant);
    let y;
    let (models, sched): (Vec<&Graph>, _) = if with_yolo {
        y = yolo();
        let (sched, _) = haxconn::gan_plus_yolo(&g, &y, soc, DlaVersion::V2)?;
        (vec![&g, &y], sched)
    } else {
        let (sched, _) = haxconn::two_gans(&g, soc, DlaVersion::V2)?;
        (vec![&g], sched)
    };
    let mut cfg = SimConfig::new(soc.clone(), 12);
    cfg.record_timeline = true;
    let r = simulate(&models, &sched, &cfg)?;
    Ok(r.timeline.ascii(100))
}

/// Structured DLA-plan diagnostics for one graph: residency, subgraph /
/// transition counts, and the per-layer [`fallback
/// details`](crate::dla::EnginePlan::fallback_details) that were
/// previously collected but write-only for users.
fn engine_plan_json(g: &Graph, version: DlaVersion) -> Json {
    match crate::dla::planner::plan(g, version, usize::MAX) {
        Ok(p) => {
            let details = p.fallback_details(g);
            obj(vec![
                ("model", s(&g.name)),
                ("fully_dla_resident", Json::Bool(p.fully_dla_resident())),
                ("dla_subgraphs", num(p.dla_subgraphs as f64)),
                ("transitions", num(p.transitions as f64)),
                (
                    "fallback_reasons",
                    arr(details
                        .iter()
                        .map(|(id, name, reason)| {
                            obj(vec![
                                ("node", num(*id as f64)),
                                ("layer", s(name)),
                                ("reason", s(reason)),
                            ])
                        })
                        .collect()),
                ),
            ])
        }
        Err(e) => obj(vec![("model", s(&g.name)), ("error", s(&e.to_string()))]),
    }
}

/// Serving-pipeline summary: every `Workload` preset lowered to a
/// `PipelineSpec` and run through the real coordinator (router, batcher,
/// backpressure, engine arbiter, metrics) on the latency-model backend —
/// the artifact-free companion to the PJRT accuracy numbers. Placement is
/// enforced: the per-engine utilization column comes from the serving
/// arbiter's timeline, the Nsight-style numbers of Figs 10/13. The
/// `dla_plans` section carries the per-variant fallback diagnostics as
/// structured data (node / layer / reason), not just counts.
pub fn pipeline_report(soc: &SocSpec) -> Json {
    use crate::config::Workload;
    use crate::pipeline::SimBackend;
    use crate::session::Session;
    use std::sync::Arc;

    println!("Pipeline: workload presets on the sim backend ({})", soc.name);
    println!(
        "{:<18} {:>10} {:>8} {:>8}  engines (util%)",
        "workload", "total fps", "frames", "dropped"
    );
    let mut rows = Vec::new();
    for w in Workload::all() {
        let session = Session::builder()
            .workload(w, GanVariant::Cropping)
            .frames(96)
            .backend(Arc::new(SimBackend::new(soc.clone())))
            .build()
            .expect("sim session builds for every preset");
        let rep = session.run().expect("sim session runs");
        let engines = rep
            .engines
            .iter()
            .map(|e| format!("{} {:.0}%", e.label, e.utilization * 100.0))
            .collect::<Vec<_>>()
            .join("  ");
        println!(
            "{:<18} {:>10.1} {:>8} {:>8}  {engines}",
            w.name(),
            rep.total_fps(),
            rep.total_frames,
            rep.dropped
        );
        rows.push(obj(vec![
            ("workload", s(w.name())),
            ("report", rep.to_json()),
        ]));
    }
    println!("DLA plans (v2): per-variant GPU-fallback diagnostics");
    let mut plans = Vec::new();
    for v in GanVariant::all() {
        let g = gan(v);
        let j = engine_plan_json(&g, DlaVersion::V2);
        let resident = j
            .get("fully_dla_resident")
            .and_then(|x| x.as_bool())
            .unwrap_or(false);
        let fallbacks = j
            .get("fallback_reasons")
            .and_then(|x| x.as_arr())
            .map(|a| a.len())
            .unwrap_or(0);
        println!(
            "  {:<14} resident {:<5}  {} fallback layer(s)",
            v.name(),
            resident,
            fallbacks
        );
        if let Some(reasons) = j.get("fallback_reasons").and_then(|x| x.as_arr()) {
            for r in reasons.iter().take(3) {
                println!(
                    "    node {:>3} {:<22} {}",
                    r.get("node").and_then(|x| x.as_u64()).unwrap_or(0),
                    r.get("layer").and_then(|x| x.as_str()).unwrap_or("?"),
                    r.get("reason").and_then(|x| x.as_str()).unwrap_or("?")
                );
            }
        }
        plans.push(obj(vec![("variant", s(v.name())), ("plan", j)]));
    }
    obj(vec![("workloads", arr(rows)), ("dla_plans", arr(plans))])
}

/// `report placement` — the planner vs the hand-written preset: the
/// auto-placement search's winning spec for the two-GAN + detector
/// request on this SoC, compared against the `dual_gan` preset's
/// predicted FPS under the same virtual-time scorer.
pub fn placement_report(soc: &SocSpec) -> Json {
    use crate::config::Workload;
    use crate::placement::{self, PlacementRequest};

    let version = if soc.name.contains("xavier") {
        DlaVersion::V1
    } else {
        DlaVersion::V2
    };
    let req = PlacementRequest::new(soc.clone(), version).dla_resident_gans();
    let outcome = placement::plan(&req).expect("two-GAN placement plans on every SoC profile");
    let preset = Workload::DualGan.spec(GanVariant::Cropping);
    let preset_eval =
        placement::evaluate(&preset, soc, req.frames).expect("dual_gan preset scores");

    println!("Placement: planned vs dual_gan preset ({})", soc.name);
    println!(
        "  planned {:<44} {:>8.1} fps  idle {:>8.2} ms  {}",
        outcome.best_key(),
        outcome.eval.predicted_fps,
        outcome.eval.idle_gap_total_ms,
        outcome.eval.unit_summary()
    );
    println!(
        "  preset  {:<44} {:>8.1} fps  idle {:>8.2} ms  {}",
        "dual_gan(cropping)",
        preset_eval.predicted_fps,
        preset_eval.idle_gap_total_ms,
        preset_eval.unit_summary()
    );
    for (key, reason) in outcome.rejected.iter().take(4) {
        println!("  rejected {key}: {reason}");
    }
    obj(vec![
        ("planned", outcome.to_json()),
        ("preset_dual_gan", preset_eval.to_json()),
        (
            "planned_minus_preset_fps",
            num(outcome.eval.predicted_fps - preset_eval.predicted_fps),
        ),
    ])
}

/// `report serve` — the long-running front-end under a shifting load: a
/// deliberately naive initial placement (both GANs on DLA0) serves a
/// ramping multi-client profile on the sim backend; the online
/// re-planner watches the windowed idle/backlog signals, re-invokes the
/// placement search, and the drain-and-switch handoff swaps the better
/// allocation in mid-run. The section reports the switch events and the
/// windowed-FPS trajectory around them.
pub fn serve_report(soc: &SocSpec) -> Json {
    use crate::pipeline::{InstanceSpec, SimBackend};
    use crate::serve::{self, ArrivalProcess, ClientSpec, ReplanPolicy, ServeOptions};
    use crate::session::Session;
    use std::sync::Arc;

    let time_scale = 0.02;
    let session = Session::builder()
        .instance(InstanceSpec::new("g0", "gen_cropping").on_engine_unit(EngineKind::Dla, 0))
        .instance(InstanceSpec::new("g1", "gen_cropping").on_engine_unit(EngineKind::Dla, 0))
        .route(crate::pipeline::router::RoutePolicy::RoundRobin)
        .streams(2)
        .backend(Arc::new(SimBackend::new(soc.clone()).with_time_scale(time_scale)))
        .build()
        .expect("serve-report session builds");
    let version = if soc.name.contains("xavier") {
        DlaVersion::V1
    } else {
        DlaVersion::V2
    };
    let mut opts = ServeOptions::new(soc.clone(), version);
    opts.time_scale = time_scale;
    opts.replan = ReplanPolicy {
        check_every_frames: 128,
        ..ReplanPolicy::default()
    };
    for i in 0..2 {
        opts.clients.push(ClientSpec::new(
            format!("hospital-{i}"),
            256,
            ArrivalProcess::Ramp {
                start_fps: 30.0,
                end_fps: 250.0,
            },
        ));
    }
    let rep = serve::serve(session, opts).expect("serve-report run");

    println!("Serve: ramp load over a naive same-DLA placement ({})", soc.name);
    println!(
        "  {} offered, {} completed, {} shed; p99 {:.2} ms; {} re-plan(s)",
        rep.offered,
        rep.completed,
        rep.shed,
        rep.latency_ms_p99,
        rep.replans.len()
    );
    for ev in &rep.replans {
        println!(
            "  re-plan @frame {}: {} -> {} [{}]",
            ev.at_frame, ev.from_key, ev.to_key, ev.reason
        );
    }
    for w in &rep.windows {
        println!(
            "  window [{:>6.2}s, {:>6.2}s]  {:>7.1} fps  p99 {:>8.2} ms  idle {:>4.0}%",
            w.t0,
            w.t1,
            w.fps,
            w.latency_ms_p99,
            w.idle_frac() * 100.0
        );
    }
    rep.to_json()
}

/// The `report fleet` section: a 4-node mixed Orin/Xavier cluster on the
/// virtual clock — ramp load saturates one node, a degradation is
/// injected, and migrations rebalance. Prints the FPS-per-watt ranking
/// and returns the fleet report JSON.
pub fn fleet_report() -> Json {
    use crate::fleet::{run_fleet, DegradationEvent, FleetOptions, NodeProfile};
    use crate::serve::{ArrivalProcess, ClientSpec};

    let mut opts = FleetOptions::new(vec![
        NodeProfile::Orin,
        NodeProfile::Xavier,
        NodeProfile::Orin,
        NodeProfile::Xavier,
    ]);
    opts.check_every = 128;
    opts.plan_frames = 16;
    for i in 0..12 {
        opts.clients.push(ClientSpec::new(
            format!("hospital-{i}"),
            128,
            ArrivalProcess::Ramp {
                start_fps: 10.0,
                end_fps: 80.0,
            },
        ));
    }
    opts.degradations.push(DegradationEvent {
        at_seconds: 1.0,
        node: 0,
        slowdown: 8.0,
    });
    let rep = run_fleet(&opts).expect("fleet-report run");

    println!("Fleet: 4 mixed Orin/Xavier nodes, ramp load, node 0 degraded @1.0s");
    println!(
        "  {} offered, {} completed, {} shed; {} migration(s); fleet {:.1} fps; p99 {:.2} ms",
        rep.offered,
        rep.completed,
        rep.shed,
        rep.migrations.len(),
        rep.fps,
        rep.latency_ms_p99
    );
    for &i in &rep.ranking() {
        let n = &rep.nodes[i];
        println!(
            "  node {} ({:<6}) {:>5} completed  {:>6.1} fps  {:>5.2} W  {:>6.2} fps/W  {}",
            n.node, n.profile, n.completed, n.fps, n.power_w, n.fps_per_watt, n.health
        );
    }
    for ev in &rep.migrations {
        println!(
            "  migrate @{:.3}s: stream {} node {} -> {} [{}]",
            ev.at_seconds, ev.stream, ev.from_node, ev.to_node, ev.reason
        );
    }
    rep.to_json()
}

/// Everything at once (the `report all` subcommand).
pub fn all_reports(artifact_dir: &str) -> Json {
    let soc = hw::orin();
    obj(vec![
        ("table1", table1(&soc)),
        ("table2", table2(artifact_dir)),
        ("fig9_fig10", fig9_fig10(&soc)),
        ("fig11_fig12", fig11_fig12(&soc)),
        ("table3_table4_fig13", table3_table4_fig13(&soc)),
        ("table5_table6_fig14", table5_table6_fig14(&soc)),
        ("pipeline", pipeline_report(&soc)),
        ("placement", placement_report(&soc)),
        ("serve", serve_report(&soc)),
        ("fleet", fleet_report()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_param_columns() {
        let j = table2("artifacts");
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(
            rows[0].get("params_paper_scale").unwrap().as_u64().unwrap(),
            54_425_859
        );
        assert_eq!(
            rows[2].get("params_paper_scale").unwrap().as_u64().unwrap(),
            64_637_268
        );
    }

    #[test]
    fn fig9_order_matches_paper() {
        let soc = hw::orin();
        let j = fig9_fig10(&soc);
        let rows = j.as_arr().unwrap();
        let fps: Vec<f64> = rows
            .iter()
            .map(|r| r.get("fps").unwrap().as_f64().unwrap())
            .collect();
        // original > cropping > convolution (Fig 9)
        assert!(fps[0] > fps[1]);
        assert!(fps[1] > fps[2]);
        // GPU util: original nonzero, modified zero (Fig 10)
        let util: Vec<f64> = rows
            .iter()
            .map(|r| r.get("gpu_util_pct").unwrap().as_f64().unwrap())
            .collect();
        assert!(util[0] > 5.0);
        assert!(util[1].abs() < 1e-9);
        assert!(util[2].abs() < 1e-9);
    }

    #[test]
    fn engine_plan_json_surfaces_structured_fallbacks() {
        let j = engine_plan_json(&gan(GanVariant::Original), DlaVersion::V2);
        assert_eq!(j.get("fully_dla_resident").unwrap().as_bool(), Some(false));
        let reasons = j.get("fallback_reasons").unwrap().as_arr().unwrap();
        assert_eq!(reasons.len(), 8, "all 8 padded deconvs must be listed");
        for r in reasons {
            assert!(r
                .get("reason")
                .unwrap()
                .as_str()
                .unwrap()
                .contains("padding must be zero"));
            assert!(r.get("layer").unwrap().as_str().is_some());
            assert!(r.get("node").unwrap().as_u64().is_some());
        }
        let ok = engine_plan_json(&gan(GanVariant::Cropping), DlaVersion::V2);
        assert_eq!(ok.get("fully_dla_resident").unwrap().as_bool(), Some(true));
        assert!(ok.get("fallback_reasons").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn timeline_ascii_renders() {
        let soc = hw::orin();
        let a = timeline_ascii(&soc, GanVariant::Cropping, false).unwrap();
        assert!(a.contains("GPU"));
        assert!(a.contains("DLA"));
    }
}
