//! VGG-19 (Simonyan & Zisserman) — the HaX-CoNN illustration workload
//! (paper Fig 4 partitions VGG-19 at layers 28 / 43) and a Table I-style
//! classification reference.

use crate::error::Result;
use crate::graph::layer::LayerKind;
use crate::graph::shape::{DType, Shape};
use crate::graph::Graph;

/// Build VGG-19 for `size`×`size` RGB input (224 in the reference).
pub fn vgg19(size: usize) -> Result<Graph> {
    let mut g = Graph::new("vgg19");
    let mut cur = g.add(
        "input",
        LayerKind::Input {
            shape: Shape::new(3, size, size, DType::F16),
        },
        &[],
    )?;
    // (convs per stage, out channels)
    let stages: [(usize, usize); 5] = [(2, 64), (2, 128), (4, 256), (4, 512), (4, 512)];
    for (s, (convs, ch)) in stages.iter().enumerate() {
        for c in 0..*convs {
            cur = g.add(
                &format!("conv{}_{}", s + 1, c + 1),
                LayerKind::conv(*ch, 3, 1, 1),
                &[cur],
            )?;
            cur = g.add(&format!("relu{}_{}", s + 1, c + 1), LayerKind::ReLU, &[cur])?;
        }
        cur = g.add(
            &format!("pool{}", s + 1),
            LayerKind::MaxPool { kernel: 2, stride: 2 },
            &[cur],
        )?;
    }
    cur = g.add("fc6", LayerKind::Dense { out_features: 4096 }, &[cur])?;
    cur = g.add("relu6", LayerKind::ReLU, &[cur])?;
    cur = g.add("fc7", LayerKind::Dense { out_features: 4096 }, &[cur])?;
    cur = g.add("relu7", LayerKind::ReLU, &[cur])?;
    cur = g.add("fc8", LayerKind::Dense { out_features: 1000 }, &[cur])?;
    cur = g.add("softmax", LayerKind::Softmax, &[cur])?;
    g.add("out", LayerKind::Output, &[cur])?;
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg19_parameter_count() {
        // Reference VGG-19: 143,667,240 parameters at 224x224.
        let g = vgg19(224).unwrap();
        assert_eq!(g.param_count(), 143_667_240);
    }

    #[test]
    fn vgg19_structure() {
        let g = vgg19(224).unwrap();
        let convs = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Conv2d { .. }))
            .count();
        let denses = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Dense { .. }))
            .count();
        assert_eq!(convs, 16);
        assert_eq!(denses, 3);
        let out = g.node(g.outputs()[0]).shape;
        assert_eq!(out.c, 1000);
    }
}
