//! Model zoo.
//!
//! Layer-for-layer graph definitions of every network the paper uses:
//!
//! * [`pix2pix`] — the CT→MRI GAN (generator + PatchGAN discriminator) in
//!   all three variants. At 256×256/`ngf=64` the original generator has
//!   exactly the 54,425,859 parameters of Table II, the cropping variant
//!   the same, and the convolution variant 64,637,268.
//! * [`yolov8`] — a YOLOv8-style anchor-free detector (C2f backbone, SPPF,
//!   PAN neck, decoupled head) for the stroke-diagnosis stream.
//! * [`resnet`] / [`vgg`] — ResNet-50/101 and VGG-19, the workloads of
//!   Table I and of the HaX-CoNN scheduling illustration (Fig 4).

pub mod pix2pix;
pub mod resnet;
pub mod vgg;
pub mod yolov8;
