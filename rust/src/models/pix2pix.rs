//! Pix2Pix (Isola et al., CVPR 2017) — the paper's MRI-reconstruction GAN.
//!
//! Generator: U-Net with 8 down-sampling blocks and 7 up-sampling blocks
//! plus the final deconvolution (paper §V.A.1, Fig 5). Every up-sampling
//! layer is a `ConvTranspose2d(k=4, s=2, p=1)` — the padding that makes
//! the whole model DLA-incompatible and that the paper's surgery replaces.
//!
//! Discriminator: 70×70 PatchGAN — three down-sampling blocks followed by
//! zero-pad / conv / batch-norm / leaky-relu / zero-pad / conv (paper
//! §V.A.1).
//!
//! Parameter counts at 256×256, `ngf = 64`, 3-channel I/O reproduce
//! Table II exactly (54,425,859 / 54,425,859 / 64,637,268).

use crate::config::GanVariant;
use crate::error::Result;
use crate::graph::layer::LayerKind;
use crate::graph::shape::{DType, Shape};
use crate::graph::surgeon;
use crate::graph::Graph;

/// Structural hyper-parameters of the Pix2Pix pair.
#[derive(Debug, Clone, Copy)]
pub struct Pix2PixConfig {
    /// Input/output spatial resolution (must be a power of two ≥ 2^depth).
    pub image_size: usize,
    /// Input/output channels (3 in the paper; 1 for the 64×64 phantoms).
    pub channels: usize,
    /// Base generator width (`ngf`, 64 in the paper).
    pub ngf: usize,
    /// Encoder depth (8 in the paper: 256 → 1).
    pub depth: usize,
}

impl Pix2PixConfig {
    /// The paper's configuration (Table II parameter counts).
    pub fn paper() -> Self {
        Pix2PixConfig {
            image_size: 256,
            channels: 3,
            ngf: 64,
            depth: 8,
        }
    }

    /// The scaled-down configuration actually trained on this testbed
    /// (matches `python/compile/model.py`).
    pub fn tiny() -> Self {
        Pix2PixConfig {
            image_size: 64,
            channels: 1,
            ngf: 16,
            depth: 6,
        }
    }

    /// Encoder filter count at down-sampling block `i` (0-based):
    /// ngf, 2ngf, 4ngf, then 8ngf for the remainder (Isola's C64-C128-
    /// C256-C512-C512-C512-C512-C512).
    pub fn enc_filters(&self, i: usize) -> usize {
        self.ngf * [1, 2, 4, 8, 8, 8, 8, 8][i.min(7)]
    }
}

/// Build the generator for `variant`.
///
/// The original variant is built directly; the cropping / convolution
/// variants are produced by [`surgeon::apply_variant`] — i.e. the library
/// really performs the paper's model surgery rather than hand-writing the
/// modified networks.
pub fn generator(cfg: &Pix2PixConfig, variant: GanVariant) -> Result<Graph> {
    let original = generator_original(cfg)?;
    surgeon::apply_variant(&original, variant)
}

/// Stock Pix2Pix generator (padded deconvolutions).
fn generator_original(cfg: &Pix2PixConfig) -> Result<Graph> {
    assert!(cfg.image_size >= (1 << cfg.depth), "image too small for depth");
    let mut g = Graph::new(&format!("pix2pix_gen_{}", cfg.image_size));
    let x = g.add(
        "ct_in",
        LayerKind::Input {
            shape: Shape::new(cfg.channels, cfg.image_size, cfg.image_size, DType::F16),
        },
        &[],
    )?;

    // ---- Encoder: depth × [conv k4 s2 p1 (+BN) + LeakyReLU] ----
    let mut skips = Vec::new();
    let mut cur = x;
    for i in 0..cfg.depth {
        let out_c = cfg.enc_filters(i);
        cur = g.add(
            &format!("enc{}_conv", i),
            LayerKind::conv_nobias(out_c, 4, 2, 1),
            &[cur],
        )?;
        if i > 0 {
            // Every encoder block except the first has batch-norm
            // (TF pix2pix reference implementation [27]).
            cur = g.add(&format!("enc{}_bn", i), LayerKind::BatchNorm, &[cur])?;
        }
        cur = g.add(
            &format!("enc{}_lrelu", i),
            LayerKind::LeakyReLU { slope: 0.2 },
            &[cur],
        )?;
        skips.push(cur);
    }

    // ---- Decoder: (depth-1) up blocks with skip concats + final deconv ----
    // Up block i (i = 0 .. depth-2): deconv k4 s2 p1 + BN (+Dropout for the
    // first three) + ReLU, then concat with encoder skip.
    for i in 0..cfg.depth - 1 {
        // Mirror of encoder filters: at up step i the target resolution
        // matches encoder block (depth-2-i).
        let out_c = cfg.enc_filters(cfg.depth - 2 - i);
        cur = g.add(
            &format!("dec{}_deconv", i),
            LayerKind::deconv(out_c, 4, 2, 1),
            &[cur],
        )?;
        cur = g.add(&format!("dec{}_bn", i), LayerKind::BatchNorm, &[cur])?;
        if i < 3 {
            cur = g.add(
                &format!("dec{}_dropout", i),
                LayerKind::Dropout { p: 0.5 },
                &[cur],
            )?;
        }
        cur = g.add(&format!("dec{}_relu", i), LayerKind::ReLU, &[cur])?;
        // Skip connection from the mirrored encoder block.
        let skip = skips[cfg.depth - 2 - i];
        cur = g.add(&format!("dec{}_concat", i), LayerKind::Concat, &[cur, skip])?;
    }
    // Final up-sampling deconvolution to the output image + tanh.
    cur = g.add(
        "final_deconv",
        LayerKind::deconv_bias(cfg.channels, 4, 2, 1),
        &[cur],
    )?;
    cur = g.add("tanh", LayerKind::Tanh, &[cur])?;
    g.add("mri_out", LayerKind::Output, &[cur])?;
    g.validate()?;
    Ok(g)
}

/// 70×70 PatchGAN discriminator (paper §V.A.1): three down-sampling blocks
/// followed by zero-pad, conv, batch-norm, leaky-relu, zero-pad, conv.
pub fn discriminator(cfg: &Pix2PixConfig) -> Result<Graph> {
    let mut g = Graph::new(&format!("pix2pix_disc_{}", cfg.image_size));
    // Conditional GAN: discriminator sees CT and (real|generated) MRI.
    let ct = g.add(
        "ct_in",
        LayerKind::Input {
            shape: Shape::new(cfg.channels, cfg.image_size, cfg.image_size, DType::F16),
        },
        &[],
    )?;
    let mri = g.add(
        "mri_in",
        LayerKind::Input {
            shape: Shape::new(cfg.channels, cfg.image_size, cfg.image_size, DType::F16),
        },
        &[],
    )?;
    let mut cur = g.add("concat_in", LayerKind::Concat, &[ct, mri])?;

    // Three down-sampling blocks C64-C128-C256 (first without BN).
    for (i, mult) in [1usize, 2, 4].iter().enumerate() {
        cur = g.add(
            &format!("d{}_conv", i),
            LayerKind::conv_nobias(cfg.ngf * mult, 4, 2, 1),
            &[cur],
        )?;
        if i > 0 {
            cur = g.add(&format!("d{}_bn", i), LayerKind::BatchNorm, &[cur])?;
        }
        cur = g.add(
            &format!("d{}_lrelu", i),
            LayerKind::LeakyReLU { slope: 0.2 },
            &[cur],
        )?;
    }
    // zero-pad + conv(512, s1) + BN + LeakyReLU + zero-pad + conv(1, s1)
    cur = g.add("pad0", LayerKind::ZeroPad { border: 1 }, &[cur])?;
    cur = g.add("d3_conv", LayerKind::conv_nobias(cfg.ngf * 8, 4, 1, 0), &[cur])?;
    cur = g.add("d3_bn", LayerKind::BatchNorm, &[cur])?;
    cur = g.add("d3_lrelu", LayerKind::LeakyReLU { slope: 0.2 }, &[cur])?;
    cur = g.add("pad1", LayerKind::ZeroPad { border: 1 }, &[cur])?;
    cur = g.add("patch_conv", LayerKind::conv(1, 4, 1, 0), &[cur])?;
    g.add("patch_out", LayerKind::Output, &[cur])?;
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_generator_parameter_count_table2() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        assert_eq!(g.param_count(), 54_425_859, "Table II original Pix2Pix");
    }

    #[test]
    fn cropping_variant_same_params_table2() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
        assert_eq!(g.param_count(), 54_425_859, "Table II cropping variant");
    }

    #[test]
    fn convolution_variant_params_table2() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Convolution).unwrap();
        assert_eq!(g.param_count(), 64_637_268, "Table II convolution variant");
    }

    #[test]
    fn generator_output_shape_matches_input() {
        for variant in GanVariant::all() {
            let g = generator(&Pix2PixConfig::paper(), variant).unwrap();
            let out = g.node(g.outputs()[0]).shape;
            assert_eq!((out.c, out.h, out.w), (3, 256, 256), "{variant:?}");
        }
    }

    #[test]
    fn encoder_reaches_1x1_bottleneck() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        let bottleneck = g
            .nodes
            .iter()
            .find(|n| n.name == "enc7_conv")
            .expect("8 encoder blocks");
        assert_eq!((bottleneck.shape.h, bottleneck.shape.w), (1, 1));
    }

    #[test]
    fn eight_downs_seven_ups_plus_final() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        let downs = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Conv2d { stride: 2, .. }))
            .count();
        let ups = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::ConvTranspose2d { .. }))
            .count();
        assert_eq!(downs, 8, "paper: eight down-sampling blocks");
        assert_eq!(ups, 8, "seven up-sampling blocks + final deconv");
    }

    #[test]
    fn modified_variants_have_no_padded_deconv() {
        for variant in [GanVariant::Cropping, GanVariant::Convolution] {
            let g = generator(&Pix2PixConfig::paper(), variant).unwrap();
            assert!(
                !g.nodes.iter().any(|n| matches!(
                    n.kind,
                    LayerKind::ConvTranspose2d { padding, .. } if padding > 0
                )),
                "{variant:?} must be padding-free"
            );
        }
    }

    #[test]
    fn modified_variants_are_longer() {
        // The paper attributes the standalone slowdown of the modified
        // models to their extra layers.
        let o = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        let c = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
        assert!(c.len() > o.len());
    }

    #[test]
    fn tiny_config_builds_all_variants() {
        for variant in GanVariant::all() {
            let g = generator(&Pix2PixConfig::tiny(), variant).unwrap();
            let out = g.node(g.outputs()[0]).shape;
            assert_eq!((out.c, out.h, out.w), (1, 64, 64));
        }
    }

    #[test]
    fn discriminator_patch_output() {
        let d = discriminator(&Pix2PixConfig::paper()).unwrap();
        let out = d.node(d.outputs()[0]).shape;
        // 70x70 PatchGAN on 256 input -> 30x30 patch map
        assert_eq!((out.c, out.h, out.w), (1, 30, 30));
        assert_eq!(d.inputs().len(), 2);
    }
}
