//! ResNet-50 / ResNet-101 (He et al.) — Table I's AI workload and the
//! second HaX-CoNN illustration network (Fig 4 partitions ResNet-101 at
//! layers 95 / 448).

use crate::error::Result;
use crate::graph::layer::LayerKind;
use crate::graph::shape::{DType, Shape};
use crate::graph::{Graph, NodeId};

fn bottleneck(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    mid_c: usize,
    out_c: usize,
    stride: usize,
    project: bool,
) -> Result<NodeId> {
    let mut cur = g.add(
        &format!("{name}_conv1"),
        LayerKind::conv_nobias(mid_c, 1, 1, 0),
        &[input],
    )?;
    cur = g.add(&format!("{name}_bn1"), LayerKind::BatchNorm, &[cur])?;
    cur = g.add(&format!("{name}_relu1"), LayerKind::ReLU, &[cur])?;
    cur = g.add(
        &format!("{name}_conv2"),
        LayerKind::conv_nobias(mid_c, 3, stride, 1),
        &[cur],
    )?;
    cur = g.add(&format!("{name}_bn2"), LayerKind::BatchNorm, &[cur])?;
    cur = g.add(&format!("{name}_relu2"), LayerKind::ReLU, &[cur])?;
    cur = g.add(
        &format!("{name}_conv3"),
        LayerKind::conv_nobias(out_c, 1, 1, 0),
        &[cur],
    )?;
    cur = g.add(&format!("{name}_bn3"), LayerKind::BatchNorm, &[cur])?;
    let shortcut = if project {
        let s = g.add(
            &format!("{name}_proj"),
            LayerKind::conv_nobias(out_c, 1, stride, 0),
            &[input],
        )?;
        g.add(&format!("{name}_proj_bn"), LayerKind::BatchNorm, &[s])?
    } else {
        input
    };
    let add = g.add(&format!("{name}_add"), LayerKind::Add, &[cur, shortcut])?;
    g.add(&format!("{name}_relu3"), LayerKind::ReLU, &[add])
}

/// Build a bottleneck ResNet. `blocks` per stage: ResNet-50 = [3,4,6,3],
/// ResNet-101 = [3,4,23,3].
pub fn resnet(size: usize, blocks: [usize; 4]) -> Result<Graph> {
    let depth: usize = 2 + blocks.iter().map(|b| b * 3).sum::<usize>();
    let mut g = Graph::new(&format!("resnet{}", depth));
    let mut cur = g.add(
        "input",
        LayerKind::Input {
            shape: Shape::new(3, size, size, DType::F16),
        },
        &[],
    )?;
    cur = g.add("stem_conv", LayerKind::conv_nobias(64, 7, 2, 3), &[cur])?;
    cur = g.add("stem_bn", LayerKind::BatchNorm, &[cur])?;
    cur = g.add("stem_relu", LayerKind::ReLU, &[cur])?;
    cur = g.add(
        "stem_pool",
        LayerKind::MaxPool { kernel: 3, stride: 2 },
        &[cur],
    )?;
    let widths = [64usize, 128, 256, 512];
    for (s, (&n, &mid)) in blocks.iter().zip(widths.iter()).enumerate() {
        for b in 0..n {
            let stride = if s > 0 && b == 0 { 2 } else { 1 };
            cur = bottleneck(
                &mut g,
                &format!("s{}b{}", s + 1, b),
                cur,
                mid,
                mid * 4,
                stride,
                b == 0,
            )?;
        }
    }
    cur = g.add("gap", LayerKind::GlobalAvgPool, &[cur])?;
    cur = g.add("fc", LayerKind::Dense { out_features: 1000 }, &[cur])?;
    cur = g.add("softmax", LayerKind::Softmax, &[cur])?;
    g.add("out", LayerKind::Output, &[cur])?;
    g.validate()?;
    Ok(g)
}

/// ResNet-50 at `size`×`size`.
pub fn resnet50(size: usize) -> Result<Graph> {
    resnet(size, [3, 4, 6, 3])
}

/// ResNet-101 at `size`×`size`.
pub fn resnet101(size: usize) -> Result<Graph> {
    resnet(size, [3, 4, 23, 3])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_parameter_count() {
        // torchvision resnet50: 25,557,032 params; our BatchNorm counts
        // 4/channel (TF convention) instead of 2 trainable -> higher by
        // the moving-stat count (53,120 BN channels * 2 = 106,240... the
        // check is structural: within 1% of the reference).
        let g = resnet50(224).unwrap();
        let p = g.param_count() as f64;
        assert!(
            (p - 25_557_032.0).abs() / 25_557_032.0 < 0.01,
            "resnet50 params {p}"
        );
    }

    #[test]
    fn resnet_output_and_stage_shapes() {
        let g = resnet50(224).unwrap();
        let out = g.node(g.outputs()[0]).shape;
        assert_eq!(out.c, 1000);
        // stage-4 output is 7x7x2048
        let s4 = g
            .nodes
            .iter()
            .filter(|n| n.name.contains("s4") && n.name.ends_with("_relu3"))
            .next_back()
            .unwrap();
        assert_eq!((s4.shape.c, s4.shape.h, s4.shape.w), (2048, 7, 7));
    }

    #[test]
    fn resnet101_is_deeper() {
        let g50 = resnet50(224).unwrap();
        let g101 = resnet101(224).unwrap();
        assert!(g101.len() > g50.len());
        assert!(g101.param_count() > 40_000_000);
    }

    #[test]
    fn residual_adds_present() {
        let g = resnet50(224).unwrap();
        let adds = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Add))
            .count();
        assert_eq!(adds, 3 + 4 + 6 + 3);
    }
}
