//! YOLOv8-style anchor-free detector — the stroke-diagnosis model.
//!
//! Faithful to the architecture the paper describes (§V.B): C2f blocks in
//! the backbone, a PAN/FPN neck for multi-scale fusion, SPPF, and an
//! anchor-free decoupled head predicting box distances + class scores at
//! three scales. Width/depth follow the `n` (nano) scaling used on edge
//! devices; [`yolo_lite`] is the reduced variant actually compiled to an
//! artifact for the CPU testbed.

use crate::error::Result;
use crate::graph::layer::LayerKind;
use crate::graph::shape::{DType, Shape};
use crate::graph::{Graph, NodeId};

/// YOLOv8 structural hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct YoloConfig {
    pub image_size: usize,
    pub in_channels: usize,
    /// Base width (16 for `n` at width_mult 0.25 of 64).
    pub width: usize,
    /// Bottlenecks per C2f block (1 for `n`).
    pub depth: usize,
    pub num_classes: usize,
    /// DFL bins per box side (16 in ultralytics).
    pub reg_max: usize,
}

impl YoloConfig {
    /// YOLOv8n-like at CT-native 512×512 (the paper's diagnostic stream).
    pub fn nano() -> Self {
        YoloConfig {
            image_size: 512,
            in_channels: 3,
            width: 16,
            depth: 1,
            num_classes: 2, // stroke / no-stroke lesion classes
            reg_max: 16,
        }
    }

    /// Further reduced variant compiled to a PJRT artifact (64×64 CT).
    pub fn lite() -> Self {
        YoloConfig {
            image_size: 64,
            in_channels: 1,
            width: 8,
            depth: 1,
            num_classes: 1,
            reg_max: 4,
        }
    }
}

/// Conv + BN + SiLU (ultralytics `Conv`).
fn cbs(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    out_c: usize,
    k: usize,
    s: usize,
) -> Result<NodeId> {
    let p = k / 2;
    let c = g.add(
        &format!("{name}_conv"),
        LayerKind::conv_nobias(out_c, k, s, p),
        &[input],
    )?;
    let b = g.add(&format!("{name}_bn"), LayerKind::BatchNorm, &[c])?;
    g.add(&format!("{name}_silu"), LayerKind::SiLU, &[b])
}

/// C2f block: 1×1 conv → split channels → `n` bottlenecks on the second
/// half (each contributing its output to the final concat) → 1×1 conv.
fn c2f(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    out_c: usize,
    n: usize,
    shortcut: bool,
) -> Result<NodeId> {
    let hidden = out_c / 2;
    let pre = cbs(g, &format!("{name}_cv1"), input, out_c, 1, 1)?;
    let a = g.add(
        &format!("{name}_split_a"),
        LayerKind::SliceChannels {
            begin: 0,
            end: hidden,
        },
        &[pre],
    )?;
    let b = g.add(
        &format!("{name}_split_b"),
        LayerKind::SliceChannels {
            begin: hidden,
            end: out_c,
        },
        &[pre],
    )?;
    let mut parts = vec![a, b];
    let mut cur = b;
    for i in 0..n {
        let c1 = cbs(g, &format!("{name}_m{i}_cv1"), cur, hidden, 3, 1)?;
        let c2 = cbs(g, &format!("{name}_m{i}_cv2"), c1, hidden, 3, 1)?;
        cur = if shortcut {
            g.add(&format!("{name}_m{i}_add"), LayerKind::Add, &[c2, cur])?
        } else {
            c2
        };
        parts.push(cur);
    }
    let cat = g.add(&format!("{name}_cat"), LayerKind::Concat, &parts)?;
    cbs(g, &format!("{name}_cv2"), cat, out_c, 1, 1)
}

/// SPPF: conv → 3× maxpool(5, s1, same) chained → concat → conv.
/// (Stride-1 same-padded pooling is expressed as ZeroPad + MaxPool.)
fn sppf(g: &mut Graph, name: &str, input: NodeId, out_c: usize) -> Result<NodeId> {
    let hidden = out_c / 2;
    let pre = cbs(g, &format!("{name}_cv1"), input, hidden, 1, 1)?;
    let mut pools = vec![pre];
    let mut cur = pre;
    for i in 0..3 {
        let padded = g.add(
            &format!("{name}_pad{i}"),
            LayerKind::ZeroPad { border: 2 },
            &[cur],
        )?;
        cur = g.add(
            &format!("{name}_pool{i}"),
            LayerKind::MaxPool { kernel: 5, stride: 1 },
            &[padded],
        )?;
        pools.push(cur);
    }
    let cat = g.add(&format!("{name}_cat"), LayerKind::Concat, &pools)?;
    cbs(g, &format!("{name}_cv2"), cat, out_c, 1, 1)
}

/// Detection head for one scale: two 3×3 conv stacks (box / cls branches)
/// + 1×1 prediction convs, concatenated to `4*reg_max + num_classes`.
fn detect_head(
    g: &mut Graph,
    name: &str,
    input: NodeId,
    cfg: &YoloConfig,
    head_c: usize,
) -> Result<NodeId> {
    // box branch
    let b1 = cbs(g, &format!("{name}_box1"), input, head_c, 3, 1)?;
    let b2 = cbs(g, &format!("{name}_box2"), b1, head_c, 3, 1)?;
    let box_pred = g.add(
        &format!("{name}_box_pred"),
        LayerKind::conv(4 * cfg.reg_max, 1, 1, 0),
        &[b2],
    )?;
    // cls branch
    let c1 = cbs(g, &format!("{name}_cls1"), input, head_c, 3, 1)?;
    let c2 = cbs(g, &format!("{name}_cls2"), c1, head_c, 3, 1)?;
    let cls_pred = g.add(
        &format!("{name}_cls_pred"),
        LayerKind::conv(cfg.num_classes, 1, 1, 0),
        &[c2],
    )?;
    g.add(
        &format!("{name}_out"),
        LayerKind::Concat,
        &[box_pred, cls_pred],
    )
}

/// Build the detector graph.
pub fn yolov8(cfg: &YoloConfig) -> Result<Graph> {
    let w = cfg.width;
    let mut g = Graph::new(&format!("yolov8_{}", cfg.image_size));
    let x = g.add(
        "image_in",
        LayerKind::Input {
            shape: Shape::new(cfg.in_channels, cfg.image_size, cfg.image_size, DType::F16),
        },
        &[],
    )?;

    // ---- Backbone ----
    let s1 = cbs(&mut g, "stem", x, w, 3, 2)?; // /2
    let s2 = cbs(&mut g, "down1", s1, w * 2, 3, 2)?; // /4
    let p2 = c2f(&mut g, "c2f_1", s2, w * 2, cfg.depth, true)?;
    let s3 = cbs(&mut g, "down2", p2, w * 4, 3, 2)?; // /8
    let p3 = c2f(&mut g, "c2f_2", s3, w * 4, cfg.depth * 2, true)?;
    let s4 = cbs(&mut g, "down3", p3, w * 8, 3, 2)?; // /16
    let p4 = c2f(&mut g, "c2f_3", s4, w * 8, cfg.depth * 2, true)?;
    let s5 = cbs(&mut g, "down4", p4, w * 16, 3, 2)?; // /32
    let p5 = c2f(&mut g, "c2f_4", s5, w * 16, cfg.depth, true)?;
    let p5 = sppf(&mut g, "sppf", p5, w * 16)?;

    // ---- PAN/FPN neck ----
    // top-down
    let up1 = g.add("neck_up1", LayerKind::Upsample { factor: 2 }, &[p5])?;
    let cat1 = g.add("neck_cat1", LayerKind::Concat, &[up1, p4])?;
    let n4 = c2f(&mut g, "neck_c2f1", cat1, w * 8, cfg.depth, false)?;
    let up2 = g.add("neck_up2", LayerKind::Upsample { factor: 2 }, &[n4])?;
    let cat2 = g.add("neck_cat2", LayerKind::Concat, &[up2, p3])?;
    let n3 = c2f(&mut g, "neck_c2f2", cat2, w * 4, cfg.depth, false)?; // /8 head in
    // bottom-up
    let d1 = cbs(&mut g, "neck_down1", n3, w * 4, 3, 2)?;
    let cat3 = g.add("neck_cat3", LayerKind::Concat, &[d1, n4])?;
    let n4b = c2f(&mut g, "neck_c2f3", cat3, w * 8, cfg.depth, false)?; // /16 head in
    let d2 = cbs(&mut g, "neck_down2", n4b, w * 8, 3, 2)?;
    let cat4 = g.add("neck_cat4", LayerKind::Concat, &[d2, p5])?;
    let n5 = c2f(&mut g, "neck_c2f4", cat4, w * 16, cfg.depth, false)?; // /32 head in

    // ---- Decoupled anchor-free heads at /8, /16, /32 ----
    let h3 = detect_head(&mut g, "head_p3", n3, cfg, w * 4)?;
    let h4 = detect_head(&mut g, "head_p4", n4b, cfg, w * 4)?;
    let h5 = detect_head(&mut g, "head_p5", n5, cfg, w * 4)?;
    g.add("out_p3", LayerKind::Output, &[h3])?;
    g.add("out_p4", LayerKind::Output, &[h4])?;
    g.add("out_p5", LayerKind::Output, &[h5])?;
    g.validate()?;
    Ok(g)
}

/// The reduced detector compiled to a PJRT artifact.
pub fn yolo_lite() -> Result<Graph> {
    yolov8(&YoloConfig::lite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nano_builds_and_has_three_scales() {
        let g = yolov8(&YoloConfig::nano()).unwrap();
        let outs = g.outputs();
        assert_eq!(outs.len(), 3);
        let shapes: Vec<_> = outs.iter().map(|&o| g.node(o).shape).collect();
        // /8, /16, /32 of 512 with 4*16+2 = 66 channels
        assert_eq!((shapes[0].c, shapes[0].h), (66, 64));
        assert_eq!((shapes[1].c, shapes[1].h), (66, 32));
        assert_eq!((shapes[2].c, shapes[2].h), (66, 16));
    }

    #[test]
    fn lite_builds() {
        let g = yolo_lite().unwrap();
        let outs = g.outputs();
        assert_eq!(outs.len(), 3);
        // 64/8 = 8
        assert_eq!(g.node(outs[0]).shape.h, 8);
        // 4*4+1 = 17 channels
        assert_eq!(g.node(outs[0]).shape.c, 17);
    }

    #[test]
    fn backbone_is_substantial() {
        let g = yolov8(&YoloConfig::nano()).unwrap();
        assert!(g.len() > 150, "yolov8 should be deep, got {}", g.len());
        assert!(g.param_count() > 500_000);
    }

    #[test]
    fn c2f_has_split_and_concat() {
        let g = yolov8(&YoloConfig::nano()).unwrap();
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.kind, LayerKind::SliceChannels { .. })));
        let concats = g
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Concat))
            .count();
        assert!(concats >= 12);
    }

    #[test]
    fn sppf_pools_preserve_resolution() {
        let g = yolov8(&YoloConfig::nano()).unwrap();
        let pre = g.nodes.iter().find(|n| n.name == "sppf_cv1_silu").unwrap();
        let post = g.nodes.iter().find(|n| n.name == "sppf_pool2").unwrap();
        assert_eq!(pre.shape.h, post.shape.h);
    }
}
