//! Configuration system.
//!
//! Experiments and the pipeline launcher are driven by a typed
//! [`PipelineConfig`] that can be loaded from a JSON file (see
//! `examples/configs/`) or assembled programmatically. JSON handling is the
//! in-tree [`json`] module (the offline vendor set has no serde).

pub mod json;

use crate::error::{Error, Result};
use crate::hw::EngineKind;
use crate::pipeline::batcher::BatchPolicy;
use crate::pipeline::router::RoutePolicy;
use crate::pipeline::spec::{check_artifact_name, InstanceSpec, PipelineSpec, SourceSpec};
use json::Json;
use std::path::Path;
use std::time::Duration;

/// Which Jetson device the simulator models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    /// NVIDIA Jetson AGX Xavier (Volta GPU, DLA v1).
    Xavier,
    /// NVIDIA Jetson AGX Orin (Ampere GPU, DLA v2).
    Orin,
}

impl DeviceKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "xavier" => Ok(DeviceKind::Xavier),
            "orin" => Ok(DeviceKind::Orin),
            other => Err(Error::Config(format!("unknown device `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::Xavier => "xavier",
            DeviceKind::Orin => "orin",
        }
    }
}

/// Pix2Pix generator variant (the paper's model-surgery axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GanVariant {
    /// Stock Pix2Pix: deconv layers with `padding=1` (DLA-incompatible).
    Original,
    /// Padding replaced by a Cropping layer (DLA-compatible).
    Cropping,
    /// Padding replaced by a stride-1 3x3 VALID convolution (DLA-compatible).
    Convolution,
}

impl GanVariant {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "original" | "orig" => Ok(GanVariant::Original),
            "cropping" | "crop" => Ok(GanVariant::Cropping),
            "convolution" | "conv" => Ok(GanVariant::Convolution),
            other => Err(Error::Config(format!("unknown GAN variant `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GanVariant::Original => "original",
            GanVariant::Cropping => "cropping",
            GanVariant::Convolution => "convolution",
        }
    }

    pub fn all() -> [GanVariant; 3] {
        [
            GanVariant::Original,
            GanVariant::Cropping,
            GanVariant::Convolution,
        ]
    }
}

/// Scheduling policy for concurrent execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Each model statically pinned to one engine (client-server scheme).
    Naive,
    /// HaX-CoNN-style partitioned streaming schedule (standalone scheme).
    HaxConn,
    /// Jedi-style pipelined layer-group distribution.
    Jedi,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(SchedulerKind::Naive),
            "haxconn" | "hax-conn" | "hax" => Ok(SchedulerKind::HaxConn),
            "jedi" => Ok(SchedulerKind::Jedi),
            other => Err(Error::Config(format!("unknown scheduler `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Naive => "naive",
            SchedulerKind::HaxConn => "haxconn",
            SchedulerKind::Jedi => "jedi",
        }
    }
}

/// The workload the pipeline runs (which models run concurrently).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// One GAN instance alone (standalone profiling, Figs 8-10).
    GanStandalone,
    /// GAN on DLA + YOLOv8 on GPU (naive / client-server, Figs 11-12).
    GanPlusYoloNaive,
    /// Two GAN instances, HaX-CoNN partitioned (Tables III/IV, Fig 13).
    TwoGans,
    /// GAN + YOLOv8, HaX-CoNN partitioned (Tables V/VI, Fig 14).
    GanPlusYolo,
    /// Two DLA-resident GANs (one per DLA core) splitting the
    /// reconstruction load, plus YOLOv8 on the GPU seeing every frame —
    /// the paper's doubled-throughput dual-GAN deployment.
    DualGan,
}

impl Workload {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gan-standalone" | "standalone" => Ok(Workload::GanStandalone),
            "gan+yolo-naive" | "naive" => Ok(Workload::GanPlusYoloNaive),
            "two-gans" | "2gan" => Ok(Workload::TwoGans),
            "gan+yolo" => Ok(Workload::GanPlusYolo),
            "dual-gan" | "dual_gan" | "dualgan" => Ok(Workload::DualGan),
            other => Err(Error::Config(format!("unknown workload `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Workload::GanStandalone => "gan-standalone",
            Workload::GanPlusYoloNaive => "gan+yolo-naive",
            Workload::TwoGans => "two-gans",
            Workload::GanPlusYolo => "gan+yolo",
            Workload::DualGan => "dual-gan",
        }
    }

    pub fn all() -> [Workload; 5] {
        [
            Workload::GanStandalone,
            Workload::GanPlusYoloNaive,
            Workload::TwoGans,
            Workload::GanPlusYolo,
            Workload::DualGan,
        ]
    }

    /// Lower this preset into an open [`PipelineSpec`] — the historical
    /// arms are sugar over the composable pipeline API. Engine placements
    /// follow the paper's deployments (GAN on the DLA next to YOLO on the
    /// GPU; two GANs split across engines; the dual-GAN pair split across
    /// the two DLA cores) and are *enforced* by the serving-path
    /// [`crate::pipeline::engines::EngineArbiter`].
    pub fn spec(self, variant: GanVariant) -> PipelineSpec {
        let gan = format!("gen_{}", variant.name());
        let (instances, route) = match self {
            Workload::GanStandalone => (
                vec![InstanceSpec::new("gan", gan)
                    .on_engine(EngineKind::Gpu)
                    .scored(true)],
                RoutePolicy::Fanout,
            ),
            Workload::GanPlusYoloNaive | Workload::GanPlusYolo => (
                vec![
                    InstanceSpec::new("gan", gan)
                        .on_engine(EngineKind::Dla)
                        .scored(true),
                    InstanceSpec::new("yolo", "yolo_lite").on_engine(EngineKind::Gpu),
                ],
                RoutePolicy::Fanout,
            ),
            Workload::TwoGans => (
                vec![
                    InstanceSpec::new("gan-inst1", gan.clone())
                        .on_engine(EngineKind::Gpu)
                        .scored(true),
                    InstanceSpec::new("gan-inst2", gan)
                        .on_engine(EngineKind::Dla)
                        .scored(true),
                ],
                RoutePolicy::RoundRobin,
            ),
            Workload::DualGan => (
                vec![
                    InstanceSpec::new("gan-dla0", gan.clone())
                        .on_engine_unit(EngineKind::Dla, 0)
                        .scored(true),
                    InstanceSpec::new("gan-dla1", gan)
                        .on_engine_unit(EngineKind::Dla, 1)
                        .scored(true),
                    InstanceSpec::new("yolo", "yolo_lite").on_engine(EngineKind::Gpu),
                ],
                RoutePolicy::RrFanoutLast,
            ),
        };
        PipelineSpec {
            instances,
            route,
            ..PipelineSpec::default()
        }
    }
}

/// Top-level pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub device: DeviceKind,
    pub variant: GanVariant,
    pub scheduler: SchedulerKind,
    pub workload: Workload,
    /// Number of CT frames to stream through the pipeline.
    pub frames: usize,
    /// Number of concurrent input streams (client-server scheme > 1).
    pub streams: usize,
    /// Maximum in-flight frames per stream before backpressure blocks.
    pub queue_depth: usize,
    /// Dynamic batcher: max batch size (1 = no batching, paper's setting).
    pub max_batch: usize,
    /// Dynamic batcher: max wait for a batch to fill, in microseconds.
    pub batch_timeout_us: u64,
    /// RNG seed for workload generation.
    pub seed: u64,
    /// Acquisition front-end: direct phantom slices, or undersampled
    /// k-space reconstructed in-pipeline (zero-filled / GRAPPA).
    pub source: SourceSpec,
    /// Directory containing AOT artifacts (HLO text + weights).
    pub artifact_dir: String,
    /// Run real PJRT inference for every frame (vs timing-only simulation).
    pub execute_numerics: bool,
    /// Explicit instance set (the open `instances: [...]` config array).
    /// When non-empty it overrides the `workload` preset entirely.
    pub instances: Vec<InstanceSpec>,
    /// Explicit route policy; `None` derives it from the workload and
    /// stream count (the pre-refactor behavior).
    pub route: Option<RoutePolicy>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            device: DeviceKind::Orin,
            variant: GanVariant::Cropping,
            scheduler: SchedulerKind::HaxConn,
            workload: Workload::GanPlusYolo,
            frames: 256,
            streams: 1,
            // Perf pass iteration 1: depth 8 only buys queueing delay on
            // this testbed (p50 104 ms -> 40 ms at depth 2, +4% fps).
            queue_depth: 4,
            max_batch: 1,
            batch_timeout_us: 500,
            seed: 0xED6E,
            source: SourceSpec::default(),
            artifact_dir: "artifacts".to_string(),
            execute_numerics: false,
            instances: Vec::new(),
            route: None,
        }
    }
}

impl PipelineConfig {
    /// Load from a JSON file; unknown keys are rejected to catch typos.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("read {}: {e}", path.display())))?;
        Self::from_json_str(&text)
    }

    /// Parse from a JSON string.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let doc = Json::parse(text).map_err(|e| Error::Config(e.to_string()))?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| Error::Config("config root must be an object".into()))?;
        let mut cfg = PipelineConfig::default();
        // `instances` entries default their batch policy to the top-level
        // `max_batch`/`batch_timeout_us`, so parse them after the scalar
        // keys (BTreeMap order would otherwise make this order-dependent).
        let mut instances_json: Option<&Json> = None;
        for (key, val) in obj {
            match key.as_str() {
                "instances" => instances_json = Some(val),
                "route" => cfg.route = Some(RoutePolicy::parse(req_str(val, key)?)?),
                "device" => cfg.device = DeviceKind::parse(req_str(val, key)?)?,
                "variant" => cfg.variant = GanVariant::parse(req_str(val, key)?)?,
                "scheduler" => cfg.scheduler = SchedulerKind::parse(req_str(val, key)?)?,
                "workload" => cfg.workload = Workload::parse(req_str(val, key)?)?,
                "frames" => cfg.frames = req_u64(val, key)? as usize,
                "streams" => cfg.streams = req_u64(val, key)? as usize,
                "queue_depth" => cfg.queue_depth = req_u64(val, key)? as usize,
                "max_batch" => cfg.max_batch = req_u64(val, key)? as usize,
                "batch_timeout_us" => cfg.batch_timeout_us = req_u64(val, key)?,
                "seed" => cfg.seed = req_u64(val, key)?,
                "source" => cfg.source = SourceSpec::from_json(val)?,
                "artifact_dir" => cfg.artifact_dir = req_str(val, key)?.to_string(),
                "execute_numerics" => {
                    cfg.execute_numerics = val
                        .as_bool()
                        .ok_or_else(|| Error::Config(format!("`{key}` must be a bool")))?
                }
                other => return Err(Error::Config(format!("unknown config key `{other}`"))),
            }
        }
        if let Some(list) = instances_json {
            let default_batch = BatchPolicy {
                max_batch: cfg.max_batch,
                timeout: Duration::from_micros(cfg.batch_timeout_us),
            };
            let entries = list
                .as_arr()
                .ok_or_else(|| Error::Config("`instances` must be an array".into()))?;
            for entry in entries {
                cfg.instances.push(parse_instance(entry, default_batch)?);
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.frames == 0 {
            return Err(Error::Config("frames must be > 0".into()));
        }
        if self.streams == 0 {
            return Err(Error::Config("streams must be > 0".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("queue_depth must be > 0".into()));
        }
        if self.max_batch == 0 {
            return Err(Error::Config("max_batch must be > 0".into()));
        }
        self.source.validate()?;
        if !self.instances.is_empty() {
            // Surface structural problems (duplicate labels, zero batch)
            // at config-parse time rather than at session build.
            self.spec().validate()?;
        }
        Ok(())
    }

    /// Lower this config into the open [`PipelineSpec`]: explicit
    /// `instances` win over the `workload` preset; the route defaults to
    /// the pre-refactor derivation (`TwoGans` goes `ByStream` under
    /// multi-stream load, everything else keeps its preset policy).
    pub fn spec(&self) -> PipelineSpec {
        let mut spec = if self.instances.is_empty() {
            let mut spec = self.workload.spec(self.variant);
            // Preset instances inherit the config-level batch policy.
            let batch = BatchPolicy {
                max_batch: self.max_batch,
                timeout: Duration::from_micros(self.batch_timeout_us),
            };
            for inst in &mut spec.instances {
                inst.batch = batch;
            }
            if self.workload == Workload::TwoGans && self.streams > 1 {
                spec.route = RoutePolicy::ByStream;
            }
            spec
        } else {
            PipelineSpec {
                instances: self.instances.clone(),
                ..PipelineSpec::default()
            }
        };
        if let Some(route) = self.route {
            spec.route = route;
        }
        spec.frames = self.frames;
        spec.streams = self.streams;
        spec.queue_depth = self.queue_depth;
        spec.seed = self.seed;
        spec.source = self.source.clone();
        spec
    }

    /// Serialize back to JSON (for experiment provenance records).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("device", json::s(self.device.name())),
            ("variant", json::s(self.variant.name())),
            ("scheduler", json::s(self.scheduler.name())),
            ("workload", json::s(self.workload.name())),
            ("frames", json::num(self.frames as f64)),
            ("streams", json::num(self.streams as f64)),
            ("queue_depth", json::num(self.queue_depth as f64)),
            ("max_batch", json::num(self.max_batch as f64)),
            ("batch_timeout_us", json::num(self.batch_timeout_us as f64)),
            ("seed", json::num(self.seed as f64)),
            // always written (like the other scalars) so provenance
            // records pin the acquisition mode explicitly
            ("source", self.source.to_json()),
            ("artifact_dir", json::s(&self.artifact_dir)),
            ("execute_numerics", Json::Bool(self.execute_numerics)),
        ];
        if let Some(route) = self.route {
            pairs.push(("route", json::s(route.name())));
        }
        if !self.instances.is_empty() {
            // Single writer for the instance schema: InstanceSpec::to_json
            // (shared with `PipelineSpec::to_json` / `plan --emit-spec`).
            let entries = self.instances.iter().map(|inst| inst.to_json()).collect();
            pairs.push(("instances", json::arr(entries)));
        }
        json::obj(pairs)
    }
}

/// `EngineKind::parse` with the config-flavored error. All engine kinds
/// are accepted so provenance records round-trip; the sim backend rejects
/// placements its SoC model lacks with its own clear error.
fn parse_engine(s: &str) -> Result<EngineKind> {
    EngineKind::parse(s).ok_or_else(|| {
        let known = EngineKind::ALL
            .iter()
            .map(|e| e.name().to_ascii_lowercase())
            .collect::<Vec<_>>()
            .join(", ");
        Error::Config(format!("unknown engine `{s}` (known: {known})"))
    })
}

/// Parse one entry of the `instances` config array into an [`InstanceSpec`].
fn parse_instance(entry: &Json, default_batch: BatchPolicy) -> Result<InstanceSpec> {
    let obj = entry
        .as_obj()
        .ok_or_else(|| Error::Config("each `instances` entry must be an object".into()))?;
    let mut label: Option<String> = None;
    let mut artifact: Option<String> = None;
    let mut engine = EngineKind::Gpu;
    let mut engine_index = 0usize;
    let mut batch = default_batch;
    let mut score: Option<bool> = None;
    for (key, val) in obj {
        match key.as_str() {
            "label" => label = Some(req_str(val, key)?.to_string()),
            "artifact" => artifact = Some(req_str(val, key)?.to_string()),
            "engine" => engine = parse_engine(req_str(val, key)?)?,
            "engine_index" => engine_index = req_u64(val, key)? as usize,
            "max_batch" => batch.max_batch = req_u64(val, key)? as usize,
            "batch_timeout_us" => batch.timeout = Duration::from_micros(req_u64(val, key)?),
            "score_fidelity" => {
                score = Some(
                    val.as_bool()
                        .ok_or_else(|| Error::Config(format!("`{key}` must be a bool")))?,
                )
            }
            other => {
                return Err(Error::Config(format!(
                    "unknown instance key `{other}` (known: label, artifact, engine, \
                     engine_index, max_batch, batch_timeout_us, score_fidelity)"
                )))
            }
        }
    }
    let artifact =
        artifact.ok_or_else(|| Error::Config("`instances` entry missing `artifact`".into()))?;
    check_artifact_name(&artifact)?;
    let label = label.unwrap_or_else(|| artifact.clone());
    // GAN-style reconstructions score fidelity by default.
    let score_fidelity = score.unwrap_or_else(|| artifact.starts_with("gen_"));
    Ok(InstanceSpec {
        label,
        artifact,
        engine,
        engine_index,
        batch,
        score_fidelity,
    })
}

fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.as_str()
        .ok_or_else(|| Error::Config(format!("`{key}` must be a string")))
}

fn req_u64(v: &Json, key: &str) -> Result<u64> {
    v.as_u64()
        .ok_or_else(|| Error::Config(format!("`{key}` must be a non-negative integer")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        PipelineConfig::default().validate().unwrap();
    }

    #[test]
    fn roundtrip_json() {
        let cfg = PipelineConfig::default();
        let text = cfg.to_json().to_pretty();
        let back = PipelineConfig::from_json_str(&text).unwrap();
        assert_eq!(back.device, cfg.device);
        assert_eq!(back.variant, cfg.variant);
        assert_eq!(back.frames, cfg.frames);
        assert_eq!(back.seed, cfg.seed);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = PipelineConfig::from_json_str(r#"{"framez": 10}"#).unwrap_err();
        assert!(err.to_string().contains("unknown config key"));
    }

    #[test]
    fn source_roundtrips_and_lowers_into_spec() {
        let cfg = PipelineConfig {
            source: SourceSpec::kspace(4, crate::pipeline::spec::ReconMode::Grappa),
            ..PipelineConfig::default()
        };
        let text = cfg.to_json().to_pretty();
        let back = PipelineConfig::from_json_str(&text).unwrap();
        assert_eq!(back.source, cfg.source);
        assert_eq!(back.spec().source, cfg.source);
        // byte-identical re-serialization (the --emit-spec reload contract)
        assert_eq!(back.to_json().to_pretty(), text);
        // default stays phantom and keeps older configs loading unchanged
        let old = PipelineConfig::from_json_str(r#"{"frames": 8}"#).unwrap();
        assert_eq!(old.source, SourceSpec::Phantom);
    }

    #[test]
    fn invalid_source_rejected_at_parse() {
        let err = PipelineConfig::from_json_str(r#"{"source": {"kind": "dicom"}}"#).unwrap_err();
        assert!(err.to_string().contains("unknown source kind"), "{err}");
        let err = PipelineConfig::from_json_str(
            r#"{"source": {"kind": "kspace", "accel": 3, "acs_lines": 16, "coils": 4, "recon": "grappa"}}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("divide"), "{err}");
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(PipelineConfig::from_json_str(r#"{"frames": 0}"#).is_err());
        assert!(PipelineConfig::from_json_str(r#"{"device": "tx2"}"#).is_err());
        assert!(PipelineConfig::from_json_str(r#"{"device": 5}"#).is_err());
    }

    #[test]
    fn enum_parsing_aliases() {
        assert_eq!(GanVariant::parse("crop").unwrap(), GanVariant::Cropping);
        assert_eq!(
            SchedulerKind::parse("hax-conn").unwrap(),
            SchedulerKind::HaxConn
        );
        assert_eq!(Workload::parse("2gan").unwrap(), Workload::TwoGans);
    }

    #[test]
    fn workload_presets_lower_to_specs() {
        for (w, n, route) in [
            (Workload::GanStandalone, 1, RoutePolicy::Fanout),
            (Workload::GanPlusYoloNaive, 2, RoutePolicy::Fanout),
            (Workload::TwoGans, 2, RoutePolicy::RoundRobin),
            (Workload::GanPlusYolo, 2, RoutePolicy::Fanout),
            (Workload::DualGan, 3, RoutePolicy::RrFanoutLast),
        ] {
            let spec = w.spec(GanVariant::Cropping);
            assert_eq!(spec.instances.len(), n, "{w:?}");
            assert_eq!(spec.route, route, "{w:?}");
            spec.validate().unwrap();
        }
        let spec = Workload::TwoGans.spec(GanVariant::Original);
        assert_eq!(spec.instances[0].artifact, "gen_original");
        assert!(spec.instances[0].score_fidelity);
    }

    #[test]
    fn dual_gan_preset_splits_the_dla_cores() {
        let spec = Workload::DualGan.spec(GanVariant::Cropping);
        assert_eq!(spec.instances[0].engine, EngineKind::Dla);
        assert_eq!(spec.instances[0].engine_index, 0);
        assert_eq!(spec.instances[1].engine, EngineKind::Dla);
        assert_eq!(spec.instances[1].engine_index, 1);
        assert_eq!(spec.instances[2].engine, EngineKind::Gpu);
        assert!(!spec.instances[2].score_fidelity);
        assert_eq!(Workload::parse("dual-gan").unwrap(), Workload::DualGan);
    }

    #[test]
    fn config_lowering_matches_prerefactor_routes() {
        // TwoGans: RoundRobin single-stream, ByStream multi-stream.
        let mut cfg = PipelineConfig {
            workload: Workload::TwoGans,
            ..PipelineConfig::default()
        };
        assert_eq!(cfg.spec().route, RoutePolicy::RoundRobin);
        cfg.streams = 4;
        assert_eq!(cfg.spec().route, RoutePolicy::ByStream);
        // Explicit route wins.
        cfg.route = Some(RoutePolicy::Fanout);
        assert_eq!(cfg.spec().route, RoutePolicy::Fanout);
        // Preset instances inherit the config-level batch policy.
        cfg.max_batch = 4;
        let spec = cfg.spec();
        assert_eq!(spec.instances[0].batch.max_batch, 4);
        assert_eq!(spec.frames, cfg.frames);
        assert_eq!(spec.streams, 4);
    }

    #[test]
    fn instances_array_parses_to_specs() {
        let cfg = PipelineConfig::from_json_str(
            r#"{
                "frames": 32,
                "route": "round-robin",
                "max_batch": 2,
                "instances": [
                    {"artifact": "gen_cropping", "label": "g0"},
                    {"artifact": "gen_cropping", "label": "g1", "engine": "dla",
                     "max_batch": 8, "score_fidelity": false}
                ]
            }"#,
        )
        .unwrap();
        let spec = cfg.spec();
        assert_eq!(spec.instances.len(), 2);
        assert_eq!(spec.route, RoutePolicy::RoundRobin);
        assert_eq!(spec.frames, 32);
        // defaults: top-level batch policy, gen_* scored
        assert_eq!(spec.instances[0].batch.max_batch, 2);
        assert!(spec.instances[0].score_fidelity);
        // overrides
        assert_eq!(spec.instances[1].engine, EngineKind::Dla);
        assert_eq!(spec.instances[1].batch.max_batch, 8);
        assert!(!spec.instances[1].score_fidelity);
        // instances survive the provenance round-trip
        let back = PipelineConfig::from_json_str(&cfg.to_json().to_pretty()).unwrap();
        assert_eq!(back.instances.len(), 2);
        assert_eq!(back.instances[1].batch.max_batch, 8);
        assert_eq!(back.route, Some(RoutePolicy::RoundRobin));
    }

    #[test]
    fn engine_index_parses_and_roundtrips() {
        let cfg = PipelineConfig::from_json_str(
            r#"{
                "frames": 8,
                "route": "rr+fanout",
                "instances": [
                    {"artifact": "gen_cropping", "label": "g0", "engine": "dla"},
                    {"artifact": "gen_cropping", "label": "g1", "engine": "dla",
                     "engine_index": 1},
                    {"artifact": "yolo_lite"}
                ]
            }"#,
        )
        .unwrap();
        let spec = cfg.spec();
        assert_eq!(spec.route, RoutePolicy::RrFanoutLast);
        assert_eq!(spec.instances[0].engine_index, 0);
        assert_eq!(spec.instances[1].engine_index, 1);
        let back = PipelineConfig::from_json_str(&cfg.to_json().to_pretty()).unwrap();
        assert_eq!(back.instances[1].engine_index, 1);
        // out-of-range unit rejected at parse time (spec validation)
        let err = PipelineConfig::from_json_str(
            r#"{"instances": [{"artifact": "gen_cropping", "engine": "dla",
                "engine_index": 2}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("out of range"));
    }

    #[test]
    fn instances_array_errors_are_clear() {
        let err = PipelineConfig::from_json_str(
            r#"{"instances": [{"artifact": "resnet999"}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown artifact"));

        let err = PipelineConfig::from_json_str(
            r#"{"instances": [{"artifact": "yolo_lite", "engine": "tpu"}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown engine"));

        let err =
            PipelineConfig::from_json_str(r#"{"instances": [{"label": "x"}]}"#).unwrap_err();
        assert!(err.to_string().contains("missing `artifact`"));

        let err = PipelineConfig::from_json_str(r#"{"route": "hash"}"#).unwrap_err();
        assert!(err.to_string().contains("unknown route policy"));

        let err = PipelineConfig::from_json_str(
            r#"{"instances": [{"artifact": "yolo_lite", "engin": "gpu"}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("unknown instance key"));

        // duplicate labels caught at parse time
        let err = PipelineConfig::from_json_str(
            r#"{"instances": [{"artifact": "yolo_lite"}, {"artifact": "yolo_lite"}]}"#,
        )
        .unwrap_err();
        assert!(err.to_string().contains("duplicate instance label"));
    }
}
