//! Configuration system.
//!
//! Experiments and the pipeline launcher are driven by a typed
//! [`PipelineConfig`] that can be loaded from a JSON file (see
//! `examples/configs/`) or assembled programmatically. JSON handling is the
//! in-tree [`json`] module (the offline vendor set has no serde).

pub mod json;

use crate::error::{Error, Result};
use json::Json;
use std::path::Path;

/// Which Jetson device the simulator models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    /// NVIDIA Jetson AGX Xavier (Volta GPU, DLA v1).
    Xavier,
    /// NVIDIA Jetson AGX Orin (Ampere GPU, DLA v2).
    Orin,
}

impl DeviceKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "xavier" => Ok(DeviceKind::Xavier),
            "orin" => Ok(DeviceKind::Orin),
            other => Err(Error::Config(format!("unknown device `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::Xavier => "xavier",
            DeviceKind::Orin => "orin",
        }
    }
}

/// Pix2Pix generator variant (the paper's model-surgery axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GanVariant {
    /// Stock Pix2Pix: deconv layers with `padding=1` (DLA-incompatible).
    Original,
    /// Padding replaced by a Cropping layer (DLA-compatible).
    Cropping,
    /// Padding replaced by a stride-1 3x3 VALID convolution (DLA-compatible).
    Convolution,
}

impl GanVariant {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "original" | "orig" => Ok(GanVariant::Original),
            "cropping" | "crop" => Ok(GanVariant::Cropping),
            "convolution" | "conv" => Ok(GanVariant::Convolution),
            other => Err(Error::Config(format!("unknown GAN variant `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GanVariant::Original => "original",
            GanVariant::Cropping => "cropping",
            GanVariant::Convolution => "convolution",
        }
    }

    pub fn all() -> [GanVariant; 3] {
        [
            GanVariant::Original,
            GanVariant::Cropping,
            GanVariant::Convolution,
        ]
    }
}

/// Scheduling policy for concurrent execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Each model statically pinned to one engine (client-server scheme).
    Naive,
    /// HaX-CoNN-style partitioned streaming schedule (standalone scheme).
    HaxConn,
    /// Jedi-style pipelined layer-group distribution.
    Jedi,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(SchedulerKind::Naive),
            "haxconn" | "hax-conn" | "hax" => Ok(SchedulerKind::HaxConn),
            "jedi" => Ok(SchedulerKind::Jedi),
            other => Err(Error::Config(format!("unknown scheduler `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SchedulerKind::Naive => "naive",
            SchedulerKind::HaxConn => "haxconn",
            SchedulerKind::Jedi => "jedi",
        }
    }
}

/// The workload the pipeline runs (which models run concurrently).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// One GAN instance alone (standalone profiling, Figs 8-10).
    GanStandalone,
    /// GAN on DLA + YOLOv8 on GPU (naive / client-server, Figs 11-12).
    GanPlusYoloNaive,
    /// Two GAN instances, HaX-CoNN partitioned (Tables III/IV, Fig 13).
    TwoGans,
    /// GAN + YOLOv8, HaX-CoNN partitioned (Tables V/VI, Fig 14).
    GanPlusYolo,
}

impl Workload {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gan-standalone" | "standalone" => Ok(Workload::GanStandalone),
            "gan+yolo-naive" | "naive" => Ok(Workload::GanPlusYoloNaive),
            "two-gans" | "2gan" => Ok(Workload::TwoGans),
            "gan+yolo" => Ok(Workload::GanPlusYolo),
            other => Err(Error::Config(format!("unknown workload `{other}`"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Workload::GanStandalone => "gan-standalone",
            Workload::GanPlusYoloNaive => "gan+yolo-naive",
            Workload::TwoGans => "two-gans",
            Workload::GanPlusYolo => "gan+yolo",
        }
    }
}

/// Top-level pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub device: DeviceKind,
    pub variant: GanVariant,
    pub scheduler: SchedulerKind,
    pub workload: Workload,
    /// Number of CT frames to stream through the pipeline.
    pub frames: usize,
    /// Number of concurrent input streams (client-server scheme > 1).
    pub streams: usize,
    /// Maximum in-flight frames per stream before backpressure blocks.
    pub queue_depth: usize,
    /// Dynamic batcher: max batch size (1 = no batching, paper's setting).
    pub max_batch: usize,
    /// Dynamic batcher: max wait for a batch to fill, in microseconds.
    pub batch_timeout_us: u64,
    /// RNG seed for workload generation.
    pub seed: u64,
    /// Directory containing AOT artifacts (HLO text + weights).
    pub artifact_dir: String,
    /// Run real PJRT inference for every frame (vs timing-only simulation).
    pub execute_numerics: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            device: DeviceKind::Orin,
            variant: GanVariant::Cropping,
            scheduler: SchedulerKind::HaxConn,
            workload: Workload::GanPlusYolo,
            frames: 256,
            streams: 1,
            // Perf pass iteration 1: depth 8 only buys queueing delay on
            // this testbed (p50 104 ms -> 40 ms at depth 2, +4% fps).
            queue_depth: 4,
            max_batch: 1,
            batch_timeout_us: 500,
            seed: 0xED6E,
            artifact_dir: "artifacts".to_string(),
            execute_numerics: false,
        }
    }
}

impl PipelineConfig {
    /// Load from a JSON file; unknown keys are rejected to catch typos.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("read {}: {e}", path.display())))?;
        Self::from_json_str(&text)
    }

    /// Parse from a JSON string.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let doc = Json::parse(text).map_err(|e| Error::Config(e.to_string()))?;
        let obj = doc
            .as_obj()
            .ok_or_else(|| Error::Config("config root must be an object".into()))?;
        let mut cfg = PipelineConfig::default();
        for (key, val) in obj {
            match key.as_str() {
                "device" => cfg.device = DeviceKind::parse(req_str(val, key)?)?,
                "variant" => cfg.variant = GanVariant::parse(req_str(val, key)?)?,
                "scheduler" => cfg.scheduler = SchedulerKind::parse(req_str(val, key)?)?,
                "workload" => cfg.workload = Workload::parse(req_str(val, key)?)?,
                "frames" => cfg.frames = req_u64(val, key)? as usize,
                "streams" => cfg.streams = req_u64(val, key)? as usize,
                "queue_depth" => cfg.queue_depth = req_u64(val, key)? as usize,
                "max_batch" => cfg.max_batch = req_u64(val, key)? as usize,
                "batch_timeout_us" => cfg.batch_timeout_us = req_u64(val, key)?,
                "seed" => cfg.seed = req_u64(val, key)?,
                "artifact_dir" => cfg.artifact_dir = req_str(val, key)?.to_string(),
                "execute_numerics" => {
                    cfg.execute_numerics = val
                        .as_bool()
                        .ok_or_else(|| Error::Config(format!("`{key}` must be a bool")))?
                }
                other => return Err(Error::Config(format!("unknown config key `{other}`"))),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.frames == 0 {
            return Err(Error::Config("frames must be > 0".into()));
        }
        if self.streams == 0 {
            return Err(Error::Config("streams must be > 0".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("queue_depth must be > 0".into()));
        }
        if self.max_batch == 0 {
            return Err(Error::Config("max_batch must be > 0".into()));
        }
        Ok(())
    }

    /// Serialize back to JSON (for experiment provenance records).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("device", json::s(self.device.name())),
            ("variant", json::s(self.variant.name())),
            ("scheduler", json::s(self.scheduler.name())),
            ("workload", json::s(self.workload.name())),
            ("frames", json::num(self.frames as f64)),
            ("streams", json::num(self.streams as f64)),
            ("queue_depth", json::num(self.queue_depth as f64)),
            ("max_batch", json::num(self.max_batch as f64)),
            ("batch_timeout_us", json::num(self.batch_timeout_us as f64)),
            ("seed", json::num(self.seed as f64)),
            ("artifact_dir", json::s(&self.artifact_dir)),
            ("execute_numerics", Json::Bool(self.execute_numerics)),
        ])
    }
}

fn req_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.as_str()
        .ok_or_else(|| Error::Config(format!("`{key}` must be a string")))
}

fn req_u64(v: &Json, key: &str) -> Result<u64> {
    v.as_u64()
        .ok_or_else(|| Error::Config(format!("`{key}` must be a non-negative integer")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        PipelineConfig::default().validate().unwrap();
    }

    #[test]
    fn roundtrip_json() {
        let cfg = PipelineConfig::default();
        let text = cfg.to_json().to_pretty();
        let back = PipelineConfig::from_json_str(&text).unwrap();
        assert_eq!(back.device, cfg.device);
        assert_eq!(back.variant, cfg.variant);
        assert_eq!(back.frames, cfg.frames);
        assert_eq!(back.seed, cfg.seed);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = PipelineConfig::from_json_str(r#"{"framez": 10}"#).unwrap_err();
        assert!(err.to_string().contains("unknown config key"));
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(PipelineConfig::from_json_str(r#"{"frames": 0}"#).is_err());
        assert!(PipelineConfig::from_json_str(r#"{"device": "tx2"}"#).is_err());
        assert!(PipelineConfig::from_json_str(r#"{"device": 5}"#).is_err());
    }

    #[test]
    fn enum_parsing_aliases() {
        assert_eq!(GanVariant::parse("crop").unwrap(), GanVariant::Cropping);
        assert_eq!(
            SchedulerKind::parse("hax-conn").unwrap(),
            SchedulerKind::HaxConn
        );
        assert_eq!(Workload::parse("2gan").unwrap(), Workload::TwoGans);
    }
}
