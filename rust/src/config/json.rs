//! Minimal JSON value model, parser and serializer.
//!
//! `serde`/`serde_json` are not in the offline vendor set, so the config
//! system and trace exporters use this self-contained implementation. It
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null) and pretty printing.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Compact single-line serialization.
    pub fn to_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with two-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no inf/NaN literal; emitting one would make
                    // the whole document unparseable. Degrade to null.
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builders for terse construction in code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00));
                            char::from_u32(combined).ok_or_else(|| self.err("bad surrogate"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // A bare `inf`/`NaN` token would make the document unparseable.
        assert_eq!(num(f64::INFINITY).to_compact(), "null");
        assert_eq!(num(f64::NEG_INFINITY).to_compact(), "null");
        assert_eq!(num(f64::NAN).to_compact(), "null");
        let doc = obj(vec![("x", num(f64::INFINITY))]).to_compact();
        Json::parse(&doc).unwrap();
    }

    #[test]
    fn parse_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": true}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap(),
            &Json::Null
        );
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let doc = r#"{"k":[1,2.5,"s",false,null],"o":{"n":-3}}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(Json::parse(&v.to_compact()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // Serializer round-trips raw UTF-8.
        let back = Json::parse(&v.to_compact()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn errors_have_offsets() {
        let e = Json::parse("{\"a\" 1}").unwrap_err();
        assert!(e.offset > 0);
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(num(150.0).to_compact(), "150");
        assert_eq!(num(1.5).to_compact(), "1.5");
    }
}
