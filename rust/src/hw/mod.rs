//! Hardware descriptions of the simulated SoCs.
//!
//! The paper's testbeds are NVIDIA Jetson AGX Xavier (Volta GPU + DLA v1)
//! and AGX Orin (Ampere GPU + DLA v2) — §III.A. Since no physical Jetson is
//! available (see DESIGN.md §2), these specs parameterize the cost model
//! and discrete-event simulator. Raw capability numbers follow the public
//! datasheets; the `efficiency` factors are *calibrated* so the original
//! Pix2Pix generator reaches the paper's measured 172.59 FPS on the Orin
//! GPU (Table IV) — everything else then emerges from the model.
//!
//! Table I additionally compares CPU, FPGA and NPU engines; those specs
//! live here too.

use std::fmt;

/// Engine classes available across the paper's hardware discussion.
/// (`Ord` follows declaration order; the placement planner uses it for
/// canonical unit multisets.)
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EngineKind {
    Cpu,
    Gpu,
    Dla,
    Fpga,
    Npu,
}

impl EngineKind {
    pub const ALL: [EngineKind; 5] = [
        EngineKind::Cpu,
        EngineKind::Gpu,
        EngineKind::Dla,
        EngineKind::Fpga,
        EngineKind::Npu,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Cpu => "CPU",
            EngineKind::Gpu => "GPU",
            EngineKind::Dla => "DLA",
            EngineKind::Fpga => "FPGA",
            EngineKind::Npu => "NPU",
        }
    }

    /// Parse a case-insensitive engine name (the config/JSON form).
    pub fn parse(s: &str) -> Option<EngineKind> {
        Self::ALL.into_iter().find(|e| e.name().eq_ignore_ascii_case(s))
    }

    /// Number of physical units of this engine class on the paper's
    /// testbeds: the Jetson AGX Xavier and Orin both carry **two** DLA
    /// cores next to the single GPU (§III.A) — the dual-GAN deployments
    /// pin one instance per DLA core. Everything else is a single unit.
    pub fn units(&self) -> usize {
        match self {
            EngineKind::Dla => 2,
            _ => 1,
        }
    }

    /// Display label for one unit of this engine class (`GPU`, `DLA0`,
    /// `DLA1`, ...). Single-unit classes keep the bare name.
    pub fn unit_label(&self, index: usize) -> String {
        if self.units() > 1 {
            format!("{}{}", self.name(), index)
        } else {
            self.name().to_string()
        }
    }
}

impl fmt::Display for EngineKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Performance description of one engine.
#[derive(Debug, Clone)]
pub struct EngineSpec {
    pub kind: EngineKind,
    /// Peak dense FP16 throughput in FLOP/s.
    pub peak_flops: f64,
    /// Sustained fraction of peak achievable on conv workloads
    /// (calibrated — see module docs).
    pub efficiency: f64,
    /// Achievable memory bandwidth for this engine, bytes/s.
    pub mem_bw: f64,
    /// Fixed per-layer launch/setup overhead, seconds. The DLA's
    /// fixed-function scheduling makes this larger than the GPU's.
    pub launch_overhead: f64,
    /// Elementwise/activation throughput in elements/s (non-MAC ops are
    /// not limited by the MAC array).
    pub elementwise_rate: f64,
    /// Relative efficiency of transposed convolution vs normal conv:
    /// GPUs run stride-2 deconvs as implicit GEMM (> 1 thanks to better
    /// data reuse at the larger output tile); the DLA's fixed-function
    /// core zero-inserts, wasting MAC slots (< 1).
    pub deconv_boost: f64,
}

impl EngineSpec {
    /// Effective FLOP/s after the efficiency derate.
    pub fn effective_flops(&self) -> f64 {
        self.peak_flops * self.efficiency
    }
}

/// Cost of moving an intermediate tensor between two engines (the
/// TensorRT "reformat" penalty the paper's fallback analysis hinges on).
#[derive(Debug, Clone, Copy)]
pub struct TransitionCost {
    /// Fixed handoff latency, seconds (driver + DLA fence).
    pub fixed: f64,
    /// Effective copy bandwidth through shared DRAM, bytes/s.
    pub bandwidth: f64,
}

impl TransitionCost {
    pub fn latency(&self, bytes: usize) -> f64 {
        self.fixed + bytes as f64 / self.bandwidth
    }
}

/// A heterogeneous SoC: engines plus the shared-memory fabric.
#[derive(Debug, Clone)]
pub struct SocSpec {
    pub name: String,
    pub gpu: EngineSpec,
    pub dla: EngineSpec,
    pub cpu: EngineSpec,
    /// Shared DRAM bandwidth, bytes/s (the contended resource of the
    /// PCCS model).
    pub dram_bw: f64,
    pub transition: TransitionCost,
    /// Memory-contention sensitivity (PCCS γ): fractional slowdown per
    /// unit of concurrent bandwidth share demanded by the other engine.
    pub contention_gamma: f64,
    /// TensorRT subgraph limit per engine plan (the paper cites 16).
    pub max_dla_subgraphs: usize,
}

/// Jetson AGX Orin: Ampere GPU (16 SM × 128 CUDA + 64 tensor cores),
/// DLA v2, 204.8 GB/s LPDDR5.
pub fn orin() -> SocSpec {
    SocSpec {
        name: "jetson-agx-orin".to_string(),
        gpu: EngineSpec {
            kind: EngineKind::Gpu,
            // ~42.5 FP16 TFLOPS dense (85 INT8 sparse TOPS datasheet)
            peak_flops: 42.5e12,
            // Calibrated: original Pix2Pix @256 => 172.59 FPS (Table IV).
            efficiency: 0.0455,
            mem_bw: 180.0e9,
            launch_overhead: 6.0e-6,
            elementwise_rate: 1.6e11,
            deconv_boost: 1.6,
        },
        dla: EngineSpec {
            kind: EngineKind::Dla,
            // DLA v2: ~20 FP16 TFLOP/s class fixed-function conv core.
            peak_flops: 20.0e12,
            // Calibrated: cropping Pix2Pix DLA-resident ≈ 130 FPS class.
            efficiency: 0.114,
            mem_bw: 120.0e9,
            launch_overhead: 18.0e-6,
            elementwise_rate: 6.0e10,
            deconv_boost: 0.85,
        },
        cpu: EngineSpec {
            kind: EngineKind::Cpu,
            // 12-core Cortex-A78AE, ~0.4 FP32 TFLOPS with NEON.
            peak_flops: 0.4e12,
            efficiency: 0.35,
            mem_bw: 40.0e9,
            launch_overhead: 0.5e-6,
            elementwise_rate: 2.0e10,
            deconv_boost: 1.0,
        },
        dram_bw: 204.8e9,
        transition: TransitionCost {
            fixed: 55.0e-6,
            bandwidth: 60.0e9,
        },
        contention_gamma: 0.55,
        max_dla_subgraphs: 16,
    }
}

/// Jetson AGX Xavier: Volta GPU (8 SM), DLA v1, 137 GB/s LPDDR4x.
/// The Orin delivers ~8× Xavier's AI throughput (paper §III.A).
pub fn xavier() -> SocSpec {
    SocSpec {
        name: "jetson-agx-xavier".to_string(),
        gpu: EngineSpec {
            kind: EngineKind::Gpu,
            peak_flops: 11.0e12,
            efficiency: 0.060,
            mem_bw: 110.0e9,
            launch_overhead: 8.0e-6,
            elementwise_rate: 0.8e11,
            deconv_boost: 1.5,
        },
        dla: EngineSpec {
            kind: EngineKind::Dla,
            // DLA v1: local buffer 9× smaller than Orin's (paper §III.A.2)
            // => much lower sustained efficiency.
            peak_flops: 5.7e12,
            efficiency: 0.085,
            mem_bw: 60.0e9,
            launch_overhead: 30.0e-6,
            elementwise_rate: 3.0e10,
            deconv_boost: 0.8,
        },
        cpu: EngineSpec {
            kind: EngineKind::Cpu,
            peak_flops: 0.25e12,
            efficiency: 0.35,
            mem_bw: 30.0e9,
            launch_overhead: 0.5e-6,
            elementwise_rate: 1.5e10,
            deconv_boost: 1.0,
        },
        dram_bw: 137.0e9,
        transition: TransitionCost {
            fixed: 80.0e-6,
            bandwidth: 40.0e9,
        },
        contention_gamma: 0.65,
        max_dla_subgraphs: 16,
    }
}

/// Auxiliary engines for the Table I comparison (typical embedded-class
/// parts: a mid-range FPGA pipeline and an NPU similar to the one in
/// [19]'s CPU-NPU pairing).
pub fn fpga() -> EngineSpec {
    EngineSpec {
        kind: EngineKind::Fpga,
        // Systolic/pipelined kernels: modest MACs but near-perfect
        // streaming efficiency for fixed-function pixel pipelines.
        peak_flops: 1.2e12,
        efficiency: 0.85,
        mem_bw: 19.0e9,
        launch_overhead: 2.0e-6,
        elementwise_rate: 1.9e10,
        deconv_boost: 1.0,
    }
}

pub fn npu() -> EngineSpec {
    EngineSpec {
        kind: EngineKind::Npu,
        // Dedicated tensor engine: excellent for dense DNN inference
        // (weight-stationary dataflow keeps it off the memory wall),
        // unsuited to irregular pixel algorithms.
        // INT8-native: 26 TOPS class at high sustained efficiency.
        peak_flops: 26.0e12,
        efficiency: 0.55,
        mem_bw: 130.0e9,
        launch_overhead: 10.0e-6,
        elementwise_rate: 2.0e10,
        deconv_boost: 1.0,
    }
}

impl SocSpec {
    pub fn engine(&self, kind: EngineKind) -> &EngineSpec {
        match kind {
            EngineKind::Gpu => &self.gpu,
            EngineKind::Dla => &self.dla,
            EngineKind::Cpu => &self.cpu,
            _ => panic!("engine {kind} not part of SoC {}", self.name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orin_outclasses_xavier() {
        let o = orin();
        let x = xavier();
        assert!(o.gpu.effective_flops() > 2.5 * x.gpu.effective_flops());
        assert!(o.dla.effective_flops() > x.dla.effective_flops());
        assert!(o.dram_bw > x.dram_bw);
    }

    #[test]
    fn dla_and_gpu_comparable() {
        // The premise of balanced HaX-CoNN schedules: the engines are
        // within ~2x of each other on conv workloads.
        let o = orin();
        let ratio = o.dla.effective_flops() / o.gpu.effective_flops();
        assert!((0.5..2.5).contains(&ratio), "dla/gpu ratio {ratio}");
    }

    #[test]
    fn transition_cost_scales_with_bytes() {
        let t = orin().transition;
        let small = t.latency(1024);
        let large = t.latency(8 * 1024 * 1024);
        assert!(large > small);
        assert!(small >= t.fixed);
    }

    #[test]
    fn engine_lookup() {
        let o = orin();
        assert_eq!(o.engine(EngineKind::Gpu).kind, EngineKind::Gpu);
        assert_eq!(o.engine(EngineKind::Dla).kind, EngineKind::Dla);
    }

    #[test]
    fn engine_units_and_labels() {
        assert_eq!(EngineKind::Dla.units(), 2);
        assert_eq!(EngineKind::Gpu.units(), 1);
        assert_eq!(EngineKind::Dla.unit_label(1), "DLA1");
        assert_eq!(EngineKind::Gpu.unit_label(0), "GPU");
    }

    #[test]
    fn engine_names_roundtrip_through_parse() {
        for e in EngineKind::ALL {
            assert_eq!(EngineKind::parse(e.name()), Some(e));
            assert_eq!(EngineKind::parse(&e.name().to_ascii_lowercase()), Some(e));
        }
        assert_eq!(EngineKind::parse("tpu"), None);
    }

    #[test]
    #[should_panic(expected = "not part of SoC")]
    fn foreign_engine_panics() {
        orin().engine(EngineKind::Fpga);
    }
}
