//! Auto-placement planner: search engine/layer allocations that minimize
//! inter-engine idle time.
//!
//! The paper's headline result is an *allocation*, not a model: layers
//! and model instances are assigned across GPU/DLA0/DLA1 "in such a way
//! that the idle time between the hardware engines is reduced", doubling
//! throughput with two DLA-resident GANs. PR 3 made placement
//! load-bearing at serving time ([`crate::pipeline::engines::EngineArbiter`]);
//! this module makes it *searchable*: given a [`PlacementRequest`], the
//! planner enumerates the pruned space of pipeline configurations
//! (GAN-surgery variant, engine unit per instance, `max_batch`, route
//! policy), prices every candidate **without running a real backend**,
//! and returns the [`PipelineSpec`](crate::pipeline::spec::PipelineSpec)
//! predicted to maximize throughput subject to a per-frame latency
//! budget and a no-GPU-fallback constraint.
//!
//! ## Planning vs serving
//!
//! ```text
//! PlacementRequest ──plan()──► PlacementOutcome { spec, eval, rejected }
//!                                      │
//!                                      ▼  (spec.to_json / auto_place)
//!                              Session::builder() ──run()──► PipelineReport
//! ```
//!
//! Planning is pure prediction: [`candidates`] rejects DLA placements of
//! graphs with GPU fallback via [`crate::dla::planner::EnginePlan`],
//! [`score`] replays a short synthetic frame window in virtual time over
//! the [`crate::pipeline::backend::SimBackend`] pricing tables (the same
//! [`crate::cost::latency`]/[`crate::cost::contention`] model the serving
//! arbiter charges), and [`search`] ranks by (predicted FPS, then total
//! inter-engine idle time, then transitions). Serving then consumes the
//! winning spec unchanged — through `plan --emit-spec` + the config
//! loader, or directly via
//! [`crate::session::PipelineBuilder::auto_place`].

pub mod candidates;
pub mod score;
pub mod search;

pub use candidates::Candidate;
pub use score::{evaluate, PlacementEval, UnitEval};
pub use search::{rank_order, ScoredCandidate};

use crate::config::json::{arr, num, obj, s, Json};
use crate::config::GanVariant;
use crate::dla::DlaVersion;
use crate::error::Result;
use crate::hw::{EngineKind, SocSpec};
use crate::pipeline::spec::{PipelineSpec, SourceSpec};

/// What to place: the workload shape, the device, and the constraints.
#[derive(Debug, Clone)]
pub struct PlacementRequest {
    /// Device profile the candidates are priced on (Orin vs Xavier — the
    /// adapt-per-generation axis of arXiv:2509.06365).
    pub soc: SocSpec,
    /// DLA rule set of that device (Xavier = v1, Orin = v2) — drives the
    /// no-GPU-fallback constraint.
    pub dla_version: DlaVersion,
    /// Number of GAN (reconstruction) instances to place.
    pub gans: usize,
    /// Place a full-rate `yolo_lite` detector alongside the GANs.
    pub with_yolo: bool,
    /// Engine classes admissible for GAN placement. Defaults to GPU +
    /// DLA (the full space); the paper's dual-GAN deployments reserve
    /// the GPU for the detector stream, expressed as `vec![Dla]`.
    pub gan_engines: Vec<EngineKind>,
    /// GAN-surgery variants to consider (the `GanVariant` search axis).
    pub variants: Vec<GanVariant>,
    /// `max_batch` values to consider per candidate.
    pub max_batches: Vec<usize>,
    /// Synthetic frame window the dry-run scorer replays (also the
    /// emitted spec's `frames`).
    pub frames: usize,
    /// Reject candidates whose predicted per-frame latency exceeds this.
    pub latency_budget_ms: Option<f64>,
    /// Seed carried into the emitted spec (same request + seed ⇒
    /// byte-identical spec JSON).
    pub seed: u64,
    /// Acquisition source carried into every emitted spec. A `kspace`
    /// source also prices its per-frame recon cost into admission pacing
    /// and the latency budget (see [`crate::placement::score`]).
    pub source: SourceSpec,
    /// Candidates fully scored on the greedy/beam path.
    pub beam_width: usize,
    /// Above this many candidates the search switches from exhaustive to
    /// the beam path.
    pub max_candidates: usize,
}

impl PlacementRequest {
    /// The default two-GAN + detector request on `soc`.
    pub fn new(soc: SocSpec, dla_version: DlaVersion) -> Self {
        PlacementRequest {
            soc,
            dla_version,
            gans: 2,
            with_yolo: true,
            gan_engines: vec![EngineKind::Gpu, EngineKind::Dla],
            variants: GanVariant::all().to_vec(),
            max_batches: vec![1, 2, 4],
            frames: 64,
            latency_budget_ms: None,
            seed: 0xED6E,
            source: SourceSpec::default(),
            beam_width: 32,
            max_candidates: 512,
        }
    }

    /// The paper's dual-GAN deployment shape: DLA-resident reconstruction
    /// (GPU reserved for the detector stream).
    pub fn dla_resident_gans(mut self) -> Self {
        self.gan_engines = vec![EngineKind::Dla];
        self
    }

    /// Derive a request matching the workload shape of a *running* spec —
    /// the serve front-end's online re-planning entry point: the search
    /// keeps what the deployment is committed to (GAN count, detector
    /// presence, the surgery variants already compiled/served) and
    /// re-opens everything that can change at a frame boundary (engine
    /// units, batching, route). Returns `None` when the spec carries no
    /// GAN instance (nothing for the planner to place).
    pub fn for_spec(
        spec: &PipelineSpec,
        soc: SocSpec,
        dla_version: DlaVersion,
    ) -> Option<PlacementRequest> {
        let mut variants: Vec<GanVariant> = Vec::new();
        let mut gans = 0usize;
        let mut with_yolo = false;
        for inst in &spec.instances {
            if let Some(name) = inst.artifact.strip_prefix("gen_") {
                gans += 1;
                if let Ok(v) = GanVariant::parse(name) {
                    if !variants.contains(&v) {
                        variants.push(v);
                    }
                }
            } else {
                with_yolo = true;
            }
        }
        if gans == 0 || variants.is_empty() {
            return None;
        }
        let mut req = PlacementRequest::new(soc, dla_version);
        req.gans = gans;
        req.with_yolo = with_yolo;
        req.variants = variants;
        req.seed = spec.seed;
        req.source = spec.source.clone();
        Some(req)
    }
}

/// The planner's answer: the winning spec, its predicted statistics, the
/// full ranked table, and everything rejected with reasons.
#[derive(Debug)]
pub struct PlacementOutcome {
    /// The best candidate lowered to a runnable spec — feed it to
    /// [`crate::session::Session`] or emit it with
    /// [`PipelineSpec::to_json`].
    pub spec: PipelineSpec,
    /// Predicted statistics of `spec`.
    pub eval: PlacementEval,
    /// Every fully scored candidate, best first (see
    /// [`search::rank_order`]).
    pub ranked: Vec<ScoredCandidate>,
    /// `(candidate class, reason)` for everything excluded before or
    /// during scoring (DLA fallback, latency budget).
    pub rejected: Vec<(String, String)>,
    /// Candidates dropped unscored by the beam path (0 on the exhaustive
    /// path).
    pub pruned: usize,
}

impl PlacementOutcome {
    /// Identity key of the winning candidate.
    pub fn best_key(&self) -> &str {
        self.ranked
            .first()
            .map(|sc| sc.candidate_key.as_str())
            .unwrap_or("")
    }

    /// JSON form for `plan --json` and the `report placement` section.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("spec", self.spec.to_json()),
            ("eval", self.eval.to_json()),
            (
                "ranked",
                arr(self
                    .ranked
                    .iter()
                    .map(|sc| {
                        obj(vec![
                            ("candidate", s(&sc.candidate_key)),
                            ("predicted_fps", num(sc.eval.predicted_fps)),
                            ("idle_gap_total_ms", num(sc.eval.idle_gap_total_ms)),
                            ("transitions", num(sc.eval.transitions as f64)),
                            ("latency_ms", num(sc.eval.latency_ms)),
                        ])
                    })
                    .collect()),
            ),
            (
                "rejected",
                arr(self
                    .rejected
                    .iter()
                    .map(|(key, reason)| {
                        obj(vec![("candidate", s(key)), ("reason", s(reason))])
                    })
                    .collect()),
            ),
            ("pruned", num(self.pruned as f64)),
        ])
    }
}

/// Search the placement space for `req` and return the winning spec plus
/// the full ranked/rejected picture. Deterministic: same request + seed
/// ⇒ identical outcome (and byte-identical emitted spec JSON).
pub fn plan(req: &PlacementRequest) -> Result<PlacementOutcome> {
    search::search(req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Workload;
    use crate::pipeline::spec::InstanceSpec;

    #[test]
    fn for_spec_mirrors_the_running_workload_shape() {
        let spec = Workload::DualGan.spec(GanVariant::Cropping);
        let req = PlacementRequest::for_spec(&spec, crate::hw::orin(), DlaVersion::V2).unwrap();
        assert_eq!(req.gans, 2);
        assert!(req.with_yolo);
        assert_eq!(req.variants, vec![GanVariant::Cropping]);
        assert_eq!(req.seed, spec.seed);
        assert_eq!(req.source, spec.source);
        // a detector-only spec has nothing for the planner to place
        let yolo_only = PipelineSpec {
            instances: vec![InstanceSpec::new("y", "yolo_lite")],
            ..PipelineSpec::default()
        };
        assert!(PlacementRequest::for_spec(&yolo_only, crate::hw::orin(), DlaVersion::V2)
            .is_none());
    }
}
