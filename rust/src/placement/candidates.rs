//! Candidate enumeration and pruning for the auto-placement search.
//!
//! A [`Candidate`] is one point of the pipeline configuration space: a
//! [`GanVariant`] (the paper's model-surgery axis), a physical engine
//! unit per GAN instance, the detector's unit, a `max_batch`, and a
//! route policy. [`enumerate`] generates the pruned space:
//!
//! * **no-GPU-fallback constraint** — a variant whose
//!   [`crate::dla::planner::EnginePlan`] is not fully DLA-resident is
//!   rejected for DLA placement *before* any scoring, with the plan's
//!   structured [`fallback_details`](crate::dla::EnginePlan::fallback_details)
//!   in the rejection reason (stock Pix2Pix's padded deconvs; SiLU on
//!   DLA v1 for the detector);
//! * **symmetry pruning** — GAN instances of one candidate are
//!   interchangeable, so unit assignments are enumerated as sorted
//!   multisets (placing `{DLA0, DLA1}` once, not twice);
//! * **route validity** — only policies meaningful for the instance
//!   shape are generated (`rr+fanout` needs a broadcast tail, round-robin
//!   needs ≥ 2 reconstruction instances).

use super::PlacementRequest;
use crate::config::GanVariant;
use crate::dla::planner;
use crate::error::Result;
use crate::graph::Graph;
use crate::hw::EngineKind;
use crate::models::pix2pix::{generator, Pix2PixConfig};
use crate::models::yolov8::yolo_lite;
use crate::pipeline::batcher::BatchPolicy;
use crate::pipeline::router::RoutePolicy;
use crate::pipeline::spec::{InstanceSpec, PipelineSpec};
use std::time::Duration;

/// One point of the placement search space.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub variant: GanVariant,
    /// Sorted unit multiset, one entry per GAN instance.
    pub gan_units: Vec<(EngineKind, usize)>,
    pub yolo_unit: Option<(EngineKind, usize)>,
    pub max_batch: usize,
    pub route: RoutePolicy,
}

impl Candidate {
    /// Stable display/identity key (also the deterministic final
    /// tiebreak of the ranking).
    pub fn key(&self) -> String {
        let gans = self
            .gan_units
            .iter()
            .map(|(e, i)| e.unit_label(*i))
            .collect::<Vec<_>>()
            .join("+");
        let yolo = match self.yolo_unit {
            Some((e, i)) => format!("|yolo:{}", e.unit_label(i)),
            None => String::new(),
        };
        format!(
            "{}|gan:{gans}{yolo}|b{}|{}",
            self.variant.name(),
            self.max_batch,
            self.route.name()
        )
    }

    /// Lower this candidate into a runnable [`PipelineSpec`] (frames and
    /// seed from the request; the detector is last so `rr+fanout`'s
    /// broadcast tail lands on it).
    pub fn to_spec(&self, req: &PlacementRequest) -> PipelineSpec {
        let batch = BatchPolicy {
            max_batch: self.max_batch,
            timeout: Duration::from_micros(500),
        };
        let artifact = format!("gen_{}", self.variant.name());
        let mut instances: Vec<InstanceSpec> = self
            .gan_units
            .iter()
            .enumerate()
            .map(|(i, &(engine, index))| {
                InstanceSpec::new(format!("gan{i}"), artifact.clone())
                    .on_engine_unit(engine, index)
                    .with_batch(batch)
                    .scored(true)
            })
            .collect();
        if let Some((engine, index)) = self.yolo_unit {
            instances.push(
                InstanceSpec::new("yolo", "yolo_lite")
                    .on_engine_unit(engine, index)
                    .with_batch(batch),
            );
        }
        PipelineSpec {
            instances,
            route: self.route,
            frames: req.frames,
            seed: req.seed,
            source: req.source.clone(),
            ..PipelineSpec::default()
        }
    }
}

/// The pruned candidate space plus every class of configuration rejected
/// before scoring, with its reason.
#[derive(Debug)]
pub struct Enumeration {
    pub candidates: Vec<Candidate>,
    /// `(candidate class, reason)` — surfaced by `plan` so a user can see
    /// *why* e.g. no DLA placement of the stock generator exists.
    pub rejected: Vec<(String, String)>,
}

/// Compress an engine plan's structured fallback diagnostics into one
/// rejection reason line.
fn fallback_reason(graph: &Graph, plan: &planner::EnginePlan) -> String {
    let details = plan.fallback_details(graph);
    let mut shown: Vec<String> = details
        .iter()
        .take(3)
        .map(|(id, name, reason)| format!("node {id} {name}: {reason}"))
        .collect();
    if details.len() > 3 {
        shown.push(format!("(+{} more)", details.len() - 3));
    }
    format!(
        "GPU fallback on DLA ({} fallback layer(s)): {}",
        details.len(),
        shown.join("; ")
    )
}

/// Is this graph admissible for DLA placement under the request's rule
/// set? Returns the rejection reason otherwise.
fn dla_admissible(graph: &Graph, req: &PlacementRequest) -> std::result::Result<(), String> {
    // Unbounded subgraph limit: only fully-resident graphs (1 subgraph)
    // are accepted, so the loadable limit can never bind — and this way a
    // fragmented plan reports its per-layer fallback reasons instead of
    // dying on the limit error.
    match planner::plan(graph, req.dla_version, usize::MAX) {
        Ok(plan) if plan.fully_dla_resident() => Ok(()),
        Ok(plan) => Err(fallback_reason(graph, &plan)),
        Err(e) => Err(e.to_string()),
    }
}

/// Sorted multisets of size `n` drawn from `units` (combinations with
/// repetition, non-decreasing indices — the symmetry pruning).
fn unit_multisets(units: &[(EngineKind, usize)], n: usize) -> Vec<Vec<(EngineKind, usize)>> {
    fn rec(
        units: &[(EngineKind, usize)],
        n: usize,
        from: usize,
        cur: &mut Vec<(EngineKind, usize)>,
        out: &mut Vec<Vec<(EngineKind, usize)>>,
    ) {
        if cur.len() == n {
            out.push(cur.clone());
            return;
        }
        for i in from..units.len() {
            cur.push(units[i]);
            rec(units, n, i, cur, out);
            cur.pop();
        }
    }
    let mut out = Vec::new();
    rec(units, n, 0, &mut Vec::new(), &mut out);
    out
}

/// Enumerate the pruned candidate space for `req`.
pub fn enumerate(req: &PlacementRequest) -> Result<Enumeration> {
    // Physical units of the SoC the sim can price: the GPU plus every DLA
    // core (the paper's testbeds carry two).
    let mut all_units: Vec<(EngineKind, usize)> = vec![(EngineKind::Gpu, 0)];
    for i in 0..EngineKind::Dla.units() {
        all_units.push((EngineKind::Dla, i));
    }

    let mut rejected: Vec<(String, String)> = Vec::new();

    // Deployment constraint: which engine classes may host a GAN at all
    // (the paper's dual-GAN scheme reserves the GPU for the detector).
    let allowed_units: Vec<(EngineKind, usize)> = all_units
        .iter()
        .copied()
        .filter(|(e, _)| req.gan_engines.contains(e))
        .collect();

    // No-GPU-fallback constraint, decided once per variant/model, not per
    // candidate: a non-resident graph never reaches a DLA unit.
    let mut gan_units_of: Vec<(GanVariant, Vec<(EngineKind, usize)>)> = Vec::new();
    for &variant in &req.variants {
        let graph = generator(&Pix2PixConfig::paper(), variant)?;
        let units = match dla_admissible(&graph, req) {
            Ok(()) => allowed_units.clone(),
            Err(reason) => {
                rejected.push((format!("gen_{}@DLA*", variant.name()), reason));
                allowed_units
                    .iter()
                    .copied()
                    .filter(|(e, _)| *e != EngineKind::Dla)
                    .collect()
            }
        };
        if units.is_empty() {
            rejected.push((
                format!("gen_{}", variant.name()),
                "no admissible engine units under the request's gan_engines constraint".into(),
            ));
            continue;
        }
        gan_units_of.push((variant, units));
    }
    let yolo_units: Vec<(EngineKind, usize)> = if req.with_yolo {
        match dla_admissible(&yolo_lite()?, req) {
            Ok(()) => all_units.clone(),
            Err(reason) => {
                rejected.push(("yolo_lite@DLA*".into(), reason));
                vec![(EngineKind::Gpu, 0)]
            }
        }
    } else {
        Vec::new()
    };

    let routes: Vec<RoutePolicy> = match (req.gans > 1, req.with_yolo) {
        (true, true) => vec![RoutePolicy::RrFanoutLast, RoutePolicy::Fanout],
        (true, false) => vec![RoutePolicy::RoundRobin, RoutePolicy::Fanout],
        (false, _) => vec![RoutePolicy::Fanout],
    };

    let mut candidates = Vec::new();
    for (variant, units) in &gan_units_of {
        for gan_units in unit_multisets(units, req.gans) {
            let yolo_options: Vec<Option<(EngineKind, usize)>> = if req.with_yolo {
                yolo_units.iter().map(|&u| Some(u)).collect()
            } else {
                vec![None]
            };
            for yolo_unit in yolo_options {
                for &max_batch in &req.max_batches {
                    for &route in &routes {
                        candidates.push(Candidate {
                            variant: *variant,
                            gan_units: gan_units.clone(),
                            yolo_unit,
                            max_batch,
                            route,
                        });
                    }
                }
            }
        }
    }
    Ok(Enumeration {
        candidates,
        rejected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dla::DlaVersion;
    use crate::hw::xavier;

    fn req() -> PlacementRequest {
        PlacementRequest::new(xavier(), DlaVersion::V1)
    }

    #[test]
    fn original_variant_never_reaches_a_dla_unit() {
        let e = enumerate(&req()).unwrap();
        for c in &e.candidates {
            if c.variant == GanVariant::Original {
                assert!(
                    c.gan_units.iter().all(|(e, _)| *e == EngineKind::Gpu),
                    "{}",
                    c.key()
                );
            }
        }
        let (_, reason) = e
            .rejected
            .iter()
            .find(|(k, _)| k.starts_with("gen_original"))
            .expect("original rejected for DLA with a structured reason");
        assert!(reason.contains("padding must be zero"), "{reason}");
    }

    #[test]
    fn detector_falls_back_on_dla_v1_with_reason() {
        let e = enumerate(&req()).unwrap();
        let (_, reason) = e
            .rejected
            .iter()
            .find(|(k, _)| k.starts_with("yolo_lite"))
            .expect("yolo_lite rejected for DLA v1");
        assert!(reason.contains("SiLU"), "{reason}");
        for c in &e.candidates {
            assert_eq!(c.yolo_unit, Some((EngineKind::Gpu, 0)), "{}", c.key());
        }
    }

    #[test]
    fn gan_unit_assignments_are_canonical_multisets() {
        let e = enumerate(&req()).unwrap();
        for c in &e.candidates {
            let mut sorted = c.gan_units.clone();
            sorted.sort();
            assert_eq!(sorted, c.gan_units, "non-canonical: {}", c.key());
        }
        // resident variants cover the split-DLA placement
        assert!(e.candidates.iter().any(|c| {
            c.variant == GanVariant::Cropping
                && c.gan_units == vec![(EngineKind::Dla, 0), (EngineKind::Dla, 1)]
        }));
    }

    #[test]
    fn gan_engine_constraint_restricts_placement() {
        let r = req().dla_resident_gans();
        let e = enumerate(&r).unwrap();
        assert!(!e.candidates.is_empty());
        for c in &e.candidates {
            assert!(
                c.gan_units.iter().all(|(e, _)| *e == EngineKind::Dla),
                "{}",
                c.key()
            );
            // the GPU-only variant has no admissible units left
            assert_ne!(c.variant, GanVariant::Original);
        }
        assert!(e.rejected.iter().any(|(k, _)| k == "gen_original"));
    }

    #[test]
    fn candidates_lower_to_valid_specs() {
        let r = req();
        let e = enumerate(&r).unwrap();
        assert!(!e.candidates.is_empty());
        for c in e.candidates.iter().take(16) {
            let spec = c.to_spec(&r);
            spec.validate().unwrap();
            assert_eq!(spec.seed, r.seed);
            if c.yolo_unit.is_some() {
                assert_eq!(spec.instances.last().unwrap().artifact, "yolo_lite");
            }
        }
    }
}
