//! The placement search itself: exhaustive over the pruned candidate
//! space, with a greedy/beam path when the space outgrows the exhaustive
//! budget.
//!
//! Every surviving candidate is priced by the virtual-time dry run of
//! [`super::score`]; the ranking is lexicographic over the paper's
//! objectives: **predicted FPS** (desc), then **total inter-engine idle
//! time** (asc — the quantity the paper's allocation minimizes), then
//! **engine transitions** (asc), then the candidate key (a deterministic
//! final tiebreak, so the same request always emits byte-identical
//! specs). When the enumeration exceeds [`PlacementRequest::max_candidates`],
//! the beam path ranks all candidates by a cheap uncontended
//! bottleneck bound (per-unit busy time from
//! [`SimBackend::batch_latency`]) and fully scores only the top
//! [`PlacementRequest::beam_width`] — greedy, deterministic, and exact
//! whenever the cheap bound agrees with the full model on the top set.

use super::candidates::{self, Candidate};
use super::score::{self, PlacementEval};
use super::{PlacementOutcome, PlacementRequest};
use crate::error::{Error, Result};
use crate::pipeline::backend::SimBackend;
use crate::pipeline::router::RoutePolicy;
use std::cmp::Ordering;
use std::collections::HashMap;

/// One fully scored candidate of the ranked table.
#[derive(Debug, Clone)]
pub struct ScoredCandidate {
    pub candidate: Candidate,
    /// [`Candidate::key`], precomputed (display + deterministic tiebreak).
    pub candidate_key: String,
    pub eval: PlacementEval,
}

fn cmp_f64(a: f64, b: f64) -> Ordering {
    a.partial_cmp(&b).unwrap_or(Ordering::Equal)
}

/// The ranking order (see module docs). Public so `plan` output and the
/// tests can assert the exact policy.
pub fn rank_order(a: &ScoredCandidate, b: &ScoredCandidate) -> Ordering {
    cmp_f64(b.eval.predicted_fps, a.eval.predicted_fps)
        .then(cmp_f64(a.eval.idle_gap_total_ms, b.eval.idle_gap_total_ms))
        .then(a.eval.transitions.cmp(&b.eval.transitions))
        .then(a.candidate_key.cmp(&b.candidate_key))
}

/// Cheap admission-rate bound: the busiest unit's uncontended busy time
/// per unique frame over the *lossless* work only (no transitions, no
/// PCCS; droppable fanout copies don't pace serving, so they don't pace
/// the bound either — mirroring [`score::evaluate`]). Lower is better;
/// shares the sim's batch pricing so the beam pre-rank cannot drift far
/// from the full score.
fn cheap_bottleneck(
    c: &Candidate,
    req: &PlacementRequest,
    backend: &SimBackend,
    memo: &mut HashMap<(String, crate::hw::EngineKind, usize), f64>,
) -> Result<f64> {
    let spec = c.to_spec(req);
    let mut busy: HashMap<(crate::hw::EngineKind, usize), f64> = HashMap::new();
    let n = spec.instances.len();
    let primary = score::primary_instances(spec.route, n);
    for (i, inst) in spec.instances.iter().enumerate() {
        if !primary[i] {
            continue;
        }
        // Fraction of the unique frame stream this instance processes.
        let share = match spec.route {
            RoutePolicy::Fanout => 1.0,
            RoutePolicy::RoundRobin | RoutePolicy::ByStream => 1.0 / n as f64,
            RoutePolicy::RrFanoutLast => 1.0 / (n.saturating_sub(1)).max(1) as f64,
        };
        let b = inst.batch.max_batch.max(1);
        let key = (inst.artifact.clone(), inst.engine, b);
        let per_frame = match memo.get(&key) {
            Some(v) => *v,
            None => {
                let v = backend.batch_latency(inst, b)? / b as f64;
                memo.insert(key, v);
                v
            }
        };
        *busy.entry((inst.engine, inst.engine_index)).or_insert(0.0) += share * per_frame;
    }
    Ok(busy.values().cloned().fold(0.0f64, f64::max))
}

/// Fully score `pool`, appending survivors to `ranked` and
/// latency-budget violations to `rejected`.
fn score_candidates(
    req: &PlacementRequest,
    pool: Vec<Candidate>,
    ranked: &mut Vec<ScoredCandidate>,
    rejected: &mut Vec<(String, String)>,
) -> Result<()> {
    for candidate in pool {
        let candidate_key = candidate.key();
        let spec = candidate.to_spec(req);
        let eval = score::evaluate(&spec, &req.soc, req.frames)?;
        if let Some(budget) = req.latency_budget_ms {
            if eval.latency_ms > budget {
                rejected.push((
                    candidate_key,
                    format!(
                        "predicted per-frame latency {:.2} ms exceeds the {budget:.2} ms budget",
                        eval.latency_ms
                    ),
                ));
                continue;
            }
        }
        ranked.push(ScoredCandidate {
            candidate,
            candidate_key,
            eval,
        });
    }
    Ok(())
}

/// Run the full search for `req` (the engine behind
/// [`super::plan`]).
pub fn search(req: &PlacementRequest) -> Result<PlacementOutcome> {
    if req.gans == 0 {
        return Err(Error::Pipeline(
            "placement request needs at least one GAN instance".into(),
        ));
    }
    let enumeration = candidates::enumerate(req)?;
    let mut rejected = enumeration.rejected;
    let mut cands = enumeration.candidates;

    // Beam path for larger instance counts: cheap-bound pre-rank, full
    // scoring only for the surviving beam. The overflow is kept around —
    // see the rescue below.
    let mut overflow: Vec<Candidate> = Vec::new();
    if cands.len() > req.max_candidates {
        let backend = SimBackend::new(req.soc.clone());
        let mut memo = HashMap::new();
        let mut bounded: Vec<(f64, Candidate)> = Vec::with_capacity(cands.len());
        for c in cands {
            let bound = cheap_bottleneck(&c, req, &backend, &mut memo)?;
            bounded.push((bound, c));
        }
        bounded.sort_by(|a, b| cmp_f64(a.0, b.0).then(a.1.key().cmp(&b.1.key())));
        let tail = bounded.split_off(req.beam_width.max(1).min(bounded.len()));
        overflow = tail.into_iter().map(|(_, c)| c).collect();
        cands = bounded.into_iter().map(|(_, c)| c).collect();
    }

    let mut ranked: Vec<ScoredCandidate> = Vec::with_capacity(cands.len());
    score_candidates(req, cands, &mut ranked, &mut rejected)?;
    let mut pruned = overflow.len();
    if ranked.is_empty() && !overflow.is_empty() {
        // Beam rescue: the cheap bound ranks by throughput only, so a
        // tight latency budget can reject the entire beam while feasible
        // (e.g. batch-1) candidates sit in the overflow. Score the
        // remainder before declaring the request infeasible.
        pruned = 0;
        score_candidates(req, std::mem::take(&mut overflow), &mut ranked, &mut rejected)?;
    }
    ranked.sort_by(rank_order);

    let best = ranked.first().ok_or_else(|| {
        let reasons: Vec<&str> = rejected.iter().take(3).map(|(_, r)| r.as_str()).collect();
        Error::Pipeline(format!(
            "auto-placement found no feasible candidate ({} rejected; e.g. {})",
            rejected.len(),
            reasons.join(" / ")
        ))
    })?;
    Ok(PlacementOutcome {
        spec: best.candidate.to_spec(req),
        eval: best.eval.clone(),
        ranked,
        rejected,
        pruned,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dla::DlaVersion;
    use crate::hw::{xavier, EngineKind};

    fn req() -> PlacementRequest {
        let mut r = PlacementRequest::new(xavier(), DlaVersion::V1);
        r.frames = 32;
        r
    }

    #[test]
    fn impossible_latency_budget_fails_with_rejections() {
        let mut r = req();
        r.latency_budget_ms = Some(1e-6);
        let err = search(&r).unwrap_err();
        assert!(err.to_string().contains("no feasible candidate"), "{err}");
        assert!(err.to_string().contains("exceeds"), "{err}");
    }

    #[test]
    fn zero_gans_rejected() {
        let mut r = req();
        r.gans = 0;
        assert!(search(&r).is_err());
    }

    #[test]
    fn beam_path_still_finds_the_dla_split() {
        // Force the greedy/beam path by shrinking the exhaustive budget;
        // the cheap bottleneck bound must keep the split-DLA placements
        // in the beam.
        let mut r = req();
        r.max_candidates = 8;
        r.beam_width = 16;
        let out = search(&r).unwrap();
        assert!(out.pruned > 0, "beam path must have pruned something");
        let gan_units: Vec<(EngineKind, usize)> = out
            .spec
            .instances
            .iter()
            .filter(|i| i.artifact.starts_with("gen_"))
            .map(|i| (i.engine, i.engine_index))
            .collect();
        assert_eq!(gan_units.len(), 2);
        assert_ne!(gan_units[0], gan_units[1], "GANs must not share a unit");
    }

    #[test]
    fn beam_rescue_scores_overflow_under_tight_budget() {
        // Budget calibrated to admit only batch-1 placements (batch-2/4
        // dispatches cost well over 1.2x a single-frame dispatch).
        let mut r = req();
        r.gan_engines = vec![EngineKind::Dla];
        let b1_latency = {
            let mut probe = r.clone();
            probe.max_batches = vec![1];
            search(&probe).unwrap().eval.latency_ms
        };
        r.latency_budget_ms = Some(b1_latency * 1.2);
        // Force the beam path with a beam so narrow the throughput-ranked
        // head is batch-4 candidates only — all over budget.
        r.max_candidates = 1;
        r.beam_width = 2;
        let out = search(&r).unwrap();
        assert_eq!(out.pruned, 0, "rescue must score the pruned overflow");
        assert!(
            out.spec.instances.iter().all(|i| i.batch.max_batch == 1),
            "only batch-1 fits the budget: {:?}",
            out.spec.instances
        );
        assert!(out
            .rejected
            .iter()
            .any(|(_, reason)| reason.contains("exceeds")));
    }

    #[test]
    fn ranking_is_total_and_deterministic() {
        let out1 = search(&req()).unwrap();
        let out2 = search(&req()).unwrap();
        let keys1: Vec<String> = out1.ranked.iter().map(|s| s.candidate_key.clone()).collect();
        let keys2: Vec<String> = out2.ranked.iter().map(|s| s.candidate_key.clone()).collect();
        assert_eq!(keys1, keys2);
        for w in out1.ranked.windows(2) {
            assert_ne!(rank_order(&w[0], &w[1]), Ordering::Greater);
        }
    }
}
