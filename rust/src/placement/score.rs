//! Placement scoring: a virtual-clock dry run of a [`PipelineSpec`] over
//! the [`SimBackend`] pricing tables.
//!
//! [`evaluate`] replays a short synthetic frame window through the same
//! hardware model the serving [`crate::pipeline::engines::EngineArbiter`]
//! enforces — exclusive engine units, PCCS contention between
//! concurrently busy units, reformat cost on occupant switches — but in
//! *virtual time*: no thread sleeps, no worker threads, so thousands of
//! candidate placements can be priced per second. The pricing inputs are
//! the arbiter's own ([`crate::pipeline::backend::InferenceBackend::dispatch_profile`]
//! from [`SimBackend`], which is built on [`crate::cost::latency`] and
//! [`crate::cost::contention`]), so a placement that scores well here is
//! predicted to serve well on the real coordinator, not on a divergent
//! model.
//!
//! The dry run models the serving data path:
//!
//! 1. frames are admitted at the steady-state cadence of the busiest
//!    *lossless* unit — the serving driver blocks the source only on
//!    primary copies and sheds non-primary fanout copies on overload, so
//!    droppable work (e.g. the `rr+fanout` detector tail) never paces
//!    admission; pacing is what makes *idle gaps on the other units*
//!    visible — the quantity the paper minimizes;
//! 2. each instance batches its assigned frames up to `max_batch` and
//!    dispatches them FIFO on its pinned unit;
//! 3. a dispatch pays the occupant-switch reformat cost and is stretched
//!    by the PCCS slowdown of whatever occupies the *other* units when it
//!    starts (arrival-order approximation of the arbiter's accounting);
//! 4. predicted FPS is gated by the lossless instances' completion
//!    (droppable copies still charge unit busy time and contention,
//!    mirroring the copies serving actually processes).

use crate::config::json::{arr, num, obj, s, Json};
use crate::error::{Error, Result};
use crate::hw::{EngineKind, SocSpec};
use crate::pipeline::backend::{InferenceBackend, SimBackend};
use crate::pipeline::engines::DispatchProfile;
use crate::pipeline::router::RoutePolicy;
use crate::pipeline::spec::PipelineSpec;
use crate::sim::timeline::{Span, Timeline};

/// Predicted serving statistics of one engine unit under a candidate
/// placement — the planner-side mirror of
/// [`crate::pipeline::engines::EngineSnapshot`].
#[derive(Debug, Clone)]
pub struct UnitEval {
    pub label: String,
    pub kind: EngineKind,
    pub index: usize,
    /// Predicted busy fraction of the dry-run window.
    pub utilization: f64,
    pub busy_seconds: f64,
    pub dispatches: usize,
    /// Occupant switches on this unit (each pays a reformat).
    pub transitions: usize,
    /// Total idle time between this unit's dispatches, seconds.
    pub idle_gap_seconds: f64,
}

/// The planner's objective bundle for one candidate placement.
#[derive(Debug, Clone)]
pub struct PlacementEval {
    /// Unique frames per second over the dry-run window (the ranking
    /// primary).
    pub predicted_fps: f64,
    /// Virtual time from first admission to last completion, seconds.
    pub makespan_seconds: f64,
    /// Unique frames replayed.
    pub frames: usize,
    /// Per-frame latency proxy: worst batch fill wait plus the worst
    /// single dispatch (reformat + contended execution), milliseconds —
    /// what the latency budget is checked against.
    pub latency_ms: f64,
    /// Sum of inter-dispatch idle time across all units, milliseconds
    /// (ranking tiebreak #1 — the paper's objective).
    pub idle_gap_total_ms: f64,
    /// Total occupant switches (ranking tiebreak #2).
    pub transitions: usize,
    /// Priced per-frame cost of the k-space recon front-end (`0` for
    /// phantom sources) — already folded into `latency_ms` and the
    /// admission cadence, surfaced so `plan`/`report` show what the
    /// acquisition stage costs at the requested R.
    pub recon_ms_per_frame: f64,
    pub units: Vec<UnitEval>,
    /// The dry run's dispatch spans, same schema as the serving
    /// timelines ([`crate::sim::timeline::Span`]) so planner predictions
    /// load into the same Chrome trace view as measured runs. Not
    /// serialized by [`PlacementEval::to_json`].
    pub timeline: Timeline,
}

impl PlacementEval {
    /// JSON form for `plan --json` output and the `report placement`
    /// section.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("predicted_fps", num(self.predicted_fps)),
            ("makespan_seconds", num(self.makespan_seconds)),
            ("frames", num(self.frames as f64)),
            ("latency_ms", num(self.latency_ms)),
            ("idle_gap_total_ms", num(self.idle_gap_total_ms)),
            ("transitions", num(self.transitions as f64)),
            ("recon_ms_per_frame", num(self.recon_ms_per_frame)),
            (
                "units",
                arr(self
                    .units
                    .iter()
                    .map(|u| {
                        obj(vec![
                            ("unit", s(&u.label)),
                            ("utilization", num(u.utilization)),
                            ("busy_seconds", num(u.busy_seconds)),
                            ("dispatches", num(u.dispatches as f64)),
                            ("transitions", num(u.transitions as f64)),
                            ("idle_gap_ms", num(u.idle_gap_seconds * 1e3)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    /// Compact one-line unit summary (`GPU 43% DLA0 91% DLA1 90%`).
    pub fn unit_summary(&self) -> String {
        self.units
            .iter()
            .map(|u| format!("{} {:.0}%", u.label, u.utilization * 100.0))
            .collect::<Vec<_>>()
            .join("  ")
    }
}

/// One batched dispatch of the dry run, in admission order.
struct VirtualDispatch {
    instance: usize,
    /// Batch size.
    len: usize,
    /// Id of the last frame in the batch (admission dependency).
    last_frame: usize,
}

/// Per-unit virtual state during the replay.
struct UnitState {
    label: String,
    kind: EngineKind,
    index: usize,
    free_at: f64,
    last_start: f64,
    /// Bandwidth demand of the dispatch currently occupying the unit.
    busy_bw: f64,
    occupant: Option<usize>,
    busy: f64,
    first_start: Option<f64>,
    dispatches: usize,
    transitions: usize,
    idle_gap: f64,
}

/// Which instances receive *primary* (lossless, backpressuring) copies
/// under a route policy — the planner-side mirror of the driver's
/// first-routed-copy-is-primary contract. Non-primary fanout copies are
/// droppable in serving: they never pace admission or gate throughput.
pub(crate) fn primary_instances(route: RoutePolicy, instances: usize) -> Vec<bool> {
    match route {
        // The first routed copy is the lossless one.
        RoutePolicy::Fanout => (0..instances).map(|i| i == 0).collect(),
        RoutePolicy::RoundRobin | RoutePolicy::ByStream => vec![true; instances],
        RoutePolicy::RrFanoutLast => {
            if instances == 1 {
                vec![true]
            } else {
                (0..instances).map(|i| i + 1 < instances).collect()
            }
        }
    }
}

/// Per-instance ordered frame-id assignment under a route policy — the
/// planner-side mirror of [`crate::pipeline::router::Router`] semantics.
fn assign_frames(
    route: RoutePolicy,
    instances: usize,
    streams: usize,
    frames: usize,
) -> Vec<Vec<usize>> {
    let mut per: Vec<Vec<usize>> = vec![Vec::new(); instances];
    for f in 0..frames {
        match route {
            RoutePolicy::Fanout => {
                for q in per.iter_mut() {
                    q.push(f);
                }
            }
            RoutePolicy::RoundRobin => per[f % instances].push(f),
            RoutePolicy::ByStream => per[(f % streams.max(1)) % instances].push(f),
            RoutePolicy::RrFanoutLast => {
                if instances == 1 {
                    per[0].push(f);
                } else {
                    per[f % (instances - 1)].push(f);
                    per[instances - 1].push(f);
                }
            }
        }
    }
    per
}

/// Price `spec` on `soc` by replaying `frames` synthetic frames in
/// virtual time. Deterministic: same spec + soc + window ⇒ identical
/// eval. Fails on placements the sim cannot price (unknown artifact,
/// engine outside the SoC).
pub fn evaluate(spec: &PipelineSpec, soc: &SocSpec, frames: usize) -> Result<PlacementEval> {
    if spec.instances.is_empty() {
        return Err(Error::Pipeline("cannot score an empty spec".into()));
    }
    let frames = frames.max(1);
    let backend = SimBackend::new(soc.clone());
    let profiles: Vec<DispatchProfile> = spec
        .instances
        .iter()
        .map(|inst| {
            backend.dispatch_profile(inst)?.ok_or_else(|| {
                Error::Pipeline(format!(
                    "sim backend produced no dispatch profile for `{}`",
                    inst.label
                ))
            })
        })
        .collect::<Result<_>>()?;

    // Dedup physical units exactly like the serving arbiter.
    let mut units: Vec<UnitState> = Vec::new();
    let mut unit_of: Vec<usize> = Vec::with_capacity(spec.instances.len());
    for inst in &spec.instances {
        let key = (inst.engine, inst.engine_index);
        let idx = match units.iter().position(|u| (u.kind, u.index) == key) {
            Some(i) => i,
            None => {
                units.push(UnitState {
                    label: inst.engine.unit_label(inst.engine_index),
                    kind: inst.engine,
                    index: inst.engine_index,
                    free_at: 0.0,
                    last_start: 0.0,
                    busy_bw: 0.0,
                    occupant: None,
                    busy: 0.0,
                    first_start: None,
                    dispatches: 0,
                    transitions: 0,
                    idle_gap: 0.0,
                });
                units.len() - 1
            }
        };
        unit_of.push(idx);
    }

    // Route the window and cut each instance's share into batches.
    let assigned = assign_frames(spec.route, spec.instances.len(), spec.streams, frames);
    let mut dispatches: Vec<VirtualDispatch> = Vec::new();
    for (i, queue) in assigned.iter().enumerate() {
        let b = spec.instances[i].batch.max_batch.max(1);
        for chunk in queue.chunks(b) {
            dispatches.push(VirtualDispatch {
                instance: i,
                len: chunk.len(),
                last_frame: *chunk.last().expect("non-empty chunk"),
            });
        }
    }
    // Serving arrival order: the frame that completes a batch admits it.
    dispatches.sort_by_key(|d| (d.last_frame, d.instance));

    // Pass 1 — uncontended bottleneck busy time of the LOSSLESS work
    // fixes the admission cadence: serving backpressures the source only
    // on primary copies (droppable fanout copies shed on overload), so
    // only primary dispatches pace admission — which is what exposes
    // idle gaps on the other units.
    let primary = primary_instances(spec.route, spec.instances.len());
    let mut busy_bound = vec![0.0f64; units.len()];
    for d in dispatches.iter().filter(|d| primary[d.instance]) {
        busy_bound[unit_of[d.instance]] +=
            profiles[d.instance].dispatch_duration(d.len).as_secs_f64();
    }
    let bottleneck = busy_bound.iter().cloned().fold(0.0f64, f64::max);
    // A k-space source reconstructs each frame before it can be admitted:
    // when the recon stage is slower than the serving bottleneck it paces
    // admission instead (phantom sources price at zero and change nothing).
    let recon_s = spec.source.recon_seconds();
    let admit_interval = (bottleneck / frames as f64).max(recon_s);

    // Pass 2 — virtual-clock replay with contention + transitions.
    let mut worst_dispatch = 0.0f64;
    let mut worst_fill = 0.0f64;
    let mut primary_end = 0.0f64;
    let mut timeline = Timeline::default();
    for d in &dispatches {
        let p = &profiles[d.instance];
        let u = unit_of[d.instance];
        let admitted = d.last_frame as f64 * admit_interval;
        let start = units[u].free_at.max(admitted);
        // PCCS: other units whose current dispatch spans `start` pull on
        // the shared DRAM.
        let corunner_bw: f64 = units
            .iter()
            .enumerate()
            .filter(|(j, o)| *j != u && o.last_start <= start && start < o.free_at)
            .map(|(_, o)| o.busy_bw)
            .sum();
        let switched = units[u].occupant.is_some() && units[u].occupant != Some(d.instance);
        let trans = if switched {
            p.transition.as_secs_f64()
        } else {
            0.0
        };
        let exec = p.dispatch_duration(d.len).as_secs_f64() * p.slowdown(corunner_bw);
        let end = start + trans + exec;

        if switched && trans > 0.0 {
            timeline.push(Span {
                engine: units[u].kind,
                unit: units[u].index,
                instance: d.instance,
                frame: d.last_frame,
                t0: start,
                t1: start + trans,
                is_transition: true,
            });
        }
        timeline.push(Span {
            engine: units[u].kind,
            unit: units[u].index,
            instance: d.instance,
            frame: d.last_frame,
            t0: start + trans,
            t1: end,
            is_transition: false,
        });

        let unit = &mut units[u];
        if unit.first_start.is_none() {
            unit.first_start = Some(start);
        } else if start > unit.free_at {
            // free_at is still the previous dispatch's end here: the gap
            // is genuine unit idle time, the paper's objective.
            unit.idle_gap += start - unit.free_at;
        }
        if switched {
            unit.transitions += 1;
        }
        unit.occupant = Some(d.instance);
        unit.last_start = start;
        unit.busy_bw = p.bw_demand;
        unit.busy += trans + exec;
        unit.dispatches += 1;
        unit.free_at = end;

        worst_dispatch = worst_dispatch.max(trans + exec);
        worst_fill = worst_fill.max((d.len.saturating_sub(1)) as f64 * admit_interval);
        if primary[d.instance] {
            primary_end = primary_end.max(end);
        }
    }

    let window_start = units
        .iter()
        .filter_map(|u| u.first_start)
        .fold(f64::INFINITY, f64::min);
    // Throughput is gated by the lossless instances' completion: serving
    // sheds non-primary copies rather than letting them stall the stream,
    // so a slow droppable tail must not deflate predicted FPS. Droppable
    // dispatches still count toward busy/contention/idle above.
    let makespan = if primary_end > 0.0 {
        primary_end
    } else {
        units.iter().map(|u| u.free_at).fold(0.0f64, f64::max)
    };
    let window = (makespan - window_start).max(f64::MIN_POSITIVE);
    let unit_evals: Vec<UnitEval> = units
        .iter()
        .map(|u| UnitEval {
            label: u.label.clone(),
            kind: u.kind,
            index: u.index,
            utilization: (u.busy / window).min(1.0),
            busy_seconds: u.busy,
            dispatches: u.dispatches,
            transitions: u.transitions,
            idle_gap_seconds: u.idle_gap,
        })
        .collect();
    Ok(PlacementEval {
        predicted_fps: frames as f64 / makespan.max(f64::MIN_POSITIVE),
        makespan_seconds: makespan,
        frames,
        // the recon stage is on the frame's critical path end to end
        latency_ms: (worst_fill + worst_dispatch + recon_s) * 1e3,
        idle_gap_total_ms: unit_evals.iter().map(|u| u.idle_gap_seconds).sum::<f64>() * 1e3,
        transitions: unit_evals.iter().map(|u| u.transitions).sum(),
        recon_ms_per_frame: recon_s * 1e3,
        units: unit_evals,
        timeline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GanVariant, Workload};
    use crate::hw::{orin, xavier};
    use crate::pipeline::spec::InstanceSpec;

    fn gan_pair(u0: usize, u1: usize) -> PipelineSpec {
        PipelineSpec {
            instances: vec![
                InstanceSpec::new("g0", "gen_cropping").on_engine_unit(EngineKind::Dla, u0),
                InstanceSpec::new("g1", "gen_cropping").on_engine_unit(EngineKind::Dla, u1),
            ],
            route: RoutePolicy::RoundRobin,
            ..PipelineSpec::default()
        }
    }

    #[test]
    fn split_dla_pair_doubles_same_unit_pair() {
        let same = evaluate(&gan_pair(0, 0), &orin(), 48).unwrap();
        let split = evaluate(&gan_pair(0, 1), &orin(), 48).unwrap();
        assert!(
            split.predicted_fps > 1.5 * same.predicted_fps,
            "split {:.1} fps vs same-unit {:.1} fps",
            split.predicted_fps,
            same.predicted_fps
        );
        // the same-unit pair alternates occupants: transitions pile up
        assert!(same.transitions > split.transitions);
    }

    #[test]
    fn kspace_source_prices_recon_into_latency_and_pacing() {
        use crate::pipeline::spec::{ReconMode, SourceSpec};
        let base = evaluate(&gan_pair(0, 1), &orin(), 48).unwrap();
        assert_eq!(base.recon_ms_per_frame, 0.0, "phantom sources are free");
        let mut ks = gan_pair(0, 1);
        ks.source = SourceSpec::kspace(4, ReconMode::Grappa);
        let ev = evaluate(&ks, &orin(), 48).unwrap();
        assert!(ev.recon_ms_per_frame > 0.0);
        assert!(
            ev.latency_ms > base.latency_ms,
            "recon cost must reach the latency budget: {} vs {}",
            ev.latency_ms,
            base.latency_ms
        );
        // recon is on the admission path, so it can only slow the plan
        assert!(ev.predicted_fps <= base.predicted_fps);
        // GRAPPA costs more than zero-filled at the same R
        let mut zf = gan_pair(0, 1);
        zf.source = SourceSpec::kspace(4, ReconMode::ZeroFilled);
        let ez = evaluate(&zf, &orin(), 48).unwrap();
        assert!(ev.recon_ms_per_frame > ez.recon_ms_per_frame);
    }

    #[test]
    fn round_robin_outscores_redundant_fanout() {
        let mut fanout = gan_pair(0, 1);
        fanout.route = RoutePolicy::Fanout;
        let rr = evaluate(&gan_pair(0, 1), &orin(), 48).unwrap();
        let fo = evaluate(&fanout, &orin(), 48).unwrap();
        // fanout reconstructs every frame twice: half the unique FPS
        assert!(rr.predicted_fps > 1.5 * fo.predicted_fps);
    }

    #[test]
    fn dual_gan_preset_scores_with_idle_gaps_and_utilization() {
        let spec = Workload::DualGan.spec(GanVariant::Cropping);
        let eval = evaluate(&spec, &xavier(), 48).unwrap();
        assert!(eval.predicted_fps > 0.0);
        assert_eq!(eval.units.len(), 3);
        let labels: Vec<&str> = eval.units.iter().map(|u| u.label.as_str()).collect();
        assert!(labels.contains(&"DLA0") && labels.contains(&"DLA1") && labels.contains(&"GPU"));
        for u in &eval.units {
            assert!(u.utilization > 0.0 && u.utilization <= 1.0, "{}", u.label);
            assert!(u.dispatches > 0);
        }
        // the cheap GPU detector idles between frames: gaps are visible
        let gpu = eval.units.iter().find(|u| u.kind == EngineKind::Gpu).unwrap();
        assert!(gpu.utilization < 1.0);
        // span/dispatch conservation: one exec span per virtual dispatch,
        // same schema the serving timelines use
        let dispatches: usize = eval.units.iter().map(|u| u.dispatches).sum();
        let exec_spans = eval
            .timeline
            .spans
            .iter()
            .filter(|sp| !sp.is_transition)
            .count();
        assert_eq!(exec_spans, dispatches);
        let trans_spans = eval.timeline.spans.len() - exec_spans;
        assert!(trans_spans <= eval.transitions);
        let doc = eval.to_json().to_compact();
        crate::config::json::Json::parse(&doc).unwrap();
    }

    #[test]
    fn droppable_fanout_tail_does_not_gate_throughput() {
        // rr+fanout with a deliberately expensive full-rate droppable
        // tail (a paper-scale GAN seeing every frame on the GPU): serving
        // sheds its copies on overload, so the planner must not let it
        // pace admission or gate predicted FPS.
        let mut spec = gan_pair(0, 1);
        spec.instances
            .push(InstanceSpec::new("tail", "gen_original"));
        spec.route = RoutePolicy::RrFanoutLast;
        let with_tail = evaluate(&spec, &orin(), 48).unwrap();
        let without = evaluate(&gan_pair(0, 1), &orin(), 48).unwrap();
        assert!(
            with_tail.predicted_fps > 0.8 * without.predicted_fps,
            "droppable tail gated throughput: {:.1} vs {:.1} fps",
            with_tail.predicted_fps,
            without.predicted_fps
        );
        // the tail still charges its unit's busy time
        let gpu = with_tail
            .units
            .iter()
            .find(|u| u.kind == EngineKind::Gpu)
            .unwrap();
        assert!(gpu.busy_seconds > 0.0);
    }

    #[test]
    fn batching_trades_latency_for_throughput() {
        let mut b1 = gan_pair(0, 1);
        let mut b4 = gan_pair(0, 1);
        for inst in &mut b4.instances {
            inst.batch.max_batch = 4;
        }
        b1.frames = 48;
        b4.frames = 48;
        let e1 = evaluate(&b1, &orin(), 48).unwrap();
        let e4 = evaluate(&b4, &orin(), 48).unwrap();
        assert!(e4.predicted_fps >= e1.predicted_fps);
        assert!(e4.latency_ms > e1.latency_ms);
    }

    #[test]
    fn deterministic_and_rejects_empty() {
        let a = evaluate(&gan_pair(0, 1), &xavier(), 32).unwrap();
        let b = evaluate(&gan_pair(0, 1), &xavier(), 32).unwrap();
        assert_eq!(a.to_json().to_compact(), b.to_json().to_compact());
        assert!(evaluate(&PipelineSpec::default(), &xavier(), 32).is_err());
    }
}
