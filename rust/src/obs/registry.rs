//! Metrics registry: counters, gauges, and log-bucketed histograms with
//! O(1) lock-free hot-path recording.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are `Arc`s handed out
//! once at registration time (cold path, under the registry lock) and
//! then recorded into with relaxed atomics only — a worker thread never
//! touches the registry lock per frame. Two read-side renderings:
//! Prometheus-style text exposition ([`Registry::expose`]) and a JSON
//! snapshot ([`Registry::snapshot_json`]) that `--metrics-out` appends
//! per checkpoint as JSONL.
#![deny(clippy::unwrap_used)]

use crate::config::json::{num, obj, s, Json};
use crate::util::lock::relock;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonically increasing event count.
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter {
            value: AtomicU64::new(0),
        }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

/// Last-write-wins instantaneous value (stored as f64 bits).
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn new() -> Gauge {
        Gauge {
            bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

/// Power-of-two log buckets over microseconds: bucket 0 holds `0 µs`,
/// bucket `i >= 1` holds values whose bit length is `i`, i.e.
/// `[2^(i-1), 2^i)` µs. 40 buckets reach ~2^39 µs (~6 days) — anything
/// above saturates into the last bucket.
const BUCKETS: usize = 40;

/// Lock-free latency histogram over seconds-valued samples.
///
/// Recording is O(1): one bit-length classification plus four relaxed
/// atomic ops, no branches on the registry. Percentiles are approximate
/// (geometric bucket midpoints, ≤ ~41% relative error by construction —
/// good enough to rank stages and spot regressions, not for SLO math).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one sample, given in seconds. Hot-path safe.
    pub fn record(&self, seconds: f64) {
        let us = (seconds.max(0.0) * 1e6) as u64;
        let idx = (64 - us.leading_zeros() as usize).min(BUCKETS - 1);
        if let Some(b) = self.buckets.get(idx) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Consistent-enough point-in-time read of all buckets (relaxed loads;
    /// concurrent recording may skew the tail by a few samples).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let count = counts.iter().sum::<u64>();
        let sum_ms = self.sum_us.load(Ordering::Relaxed) as f64 / 1e3;
        let mean_ms = if count > 0 { sum_ms / count as f64 } else { 0.0 };
        HistogramSnapshot {
            count,
            sum_ms,
            mean_ms,
            p50_ms: quantile_us(&counts, count, 50.0) / 1e3,
            p95_ms: quantile_us(&counts, count, 95.0) / 1e3,
            p99_ms: quantile_us(&counts, count, 99.0) / 1e3,
            max_ms: self.max_us.load(Ordering::Relaxed) as f64 / 1e3,
        }
    }

    /// Raw cumulative bucket counts paired with their upper edges in
    /// seconds, for text exposition.
    fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        self.buckets
            .iter()
            .enumerate()
            .map(|(i, b)| {
                acc += b.load(Ordering::Relaxed);
                let le = (1u64 << i) as f64 / 1e6;
                (le, acc)
            })
            .collect()
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Nearest-rank quantile over log buckets, returned in microseconds
/// (geometric bucket midpoint).
fn quantile_us(counts: &[u64], total: u64, q: f64) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let rank = ((q / 100.0) * total as f64).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        seen += c;
        if seen >= rank {
            if i == 0 {
                return 0.0;
            }
            let lo = 1u64 << (i - 1);
            let hi = 1u64 << i;
            return (lo + hi) as f64 / 2.0;
        }
    }
    0.0
}

/// Point-in-time histogram digest (milliseconds), the JSON-facing form.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum_ms: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl HistogramSnapshot {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("count", num(self.count as f64)),
            ("sum_ms", num(self.sum_ms)),
            ("mean_ms", num(self.mean_ms)),
            ("p50_ms", num(self.p50_ms)),
            ("p95_ms", num(self.p95_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("max_ms", num(self.max_ms)),
        ])
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn type_name(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

struct Entry {
    name: String,
    help: String,
    metric: Metric,
}

/// Name → metric table. Registration is idempotent by name: asking for
/// an existing name returns the existing handle (a name registered under
/// a different metric type returns a fresh detached handle rather than
/// panicking — the lint keeps serving code panic-free).
pub struct Registry {
    // Lock rank 5 (see `analysis::hotpath::LOCK_ORDER`): cold path only,
    // never held while recording or while another obs lock is held.
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            entries: Mutex::new(Vec::new()),
        }
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let mut entries = relock(&self.entries);
        for e in entries.iter() {
            if e.name == name {
                if let Metric::Counter(c) = &e.metric {
                    return Arc::clone(c);
                }
                return Arc::new(Counter::new());
            }
        }
        let c = Arc::new(Counter::new());
        entries.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            metric: Metric::Counter(Arc::clone(&c)),
        });
        c
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let mut entries = relock(&self.entries);
        for e in entries.iter() {
            if e.name == name {
                if let Metric::Gauge(g) = &e.metric {
                    return Arc::clone(g);
                }
                return Arc::new(Gauge::new());
            }
        }
        let g = Arc::new(Gauge::new());
        entries.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            metric: Metric::Gauge(Arc::clone(&g)),
        });
        g
    }

    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        let mut entries = relock(&self.entries);
        for e in entries.iter() {
            if e.name == name {
                if let Metric::Histogram(h) = &e.metric {
                    return Arc::clone(h);
                }
                return Arc::new(Histogram::new());
            }
        }
        let h = Arc::new(Histogram::new());
        entries.push(Entry {
            name: name.to_string(),
            help: help.to_string(),
            metric: Metric::Histogram(Arc::clone(&h)),
        });
        h
    }

    pub fn len(&self) -> usize {
        relock(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Prometheus-style text exposition (`# HELP` / `# TYPE` / samples;
    /// histograms as cumulative `_bucket{le="..."}` + `_sum`/`_count`).
    pub fn expose(&self) -> String {
        let mut out = String::new();
        let entries = relock(&self.entries);
        for e in entries.iter() {
            out.push_str(&format!("# HELP {} {}\n", e.name, e.help));
            out.push_str(&format!("# TYPE {} {}\n", e.name, e.metric.type_name()));
            match &e.metric {
                Metric::Counter(c) => out.push_str(&format!("{} {}\n", e.name, c.get())),
                Metric::Gauge(g) => out.push_str(&format!("{} {}\n", e.name, g.get())),
                Metric::Histogram(h) => {
                    let mut total = 0u64;
                    for (le, cum) in h.cumulative() {
                        out.push_str(&format!("{}_bucket{{le=\"{le}\"}} {cum}\n", e.name));
                        total = cum;
                    }
                    out.push_str(&format!("{}_bucket{{le=\"+Inf\"}} {total}\n", e.name));
                    let snap = h.snapshot();
                    // exposition convention: _sum in base unit (seconds)
                    out.push_str(&format!("{}_sum {}\n", e.name, snap.sum_ms / 1e3));
                    out.push_str(&format!("{}_count {total}\n", e.name));
                }
            }
        }
        out
    }

    /// One checkpoint-aligned snapshot of every registered metric, as a
    /// JSON object suitable for a JSONL metrics stream.
    pub fn snapshot_json(&self, t_s: f64) -> Json {
        let mut counters: BTreeMap<String, Json> = BTreeMap::new();
        let mut gauges: BTreeMap<String, Json> = BTreeMap::new();
        let mut hists: BTreeMap<String, Json> = BTreeMap::new();
        let entries = relock(&self.entries);
        for e in entries.iter() {
            match &e.metric {
                Metric::Counter(c) => {
                    counters.insert(e.name.clone(), num(c.get() as f64));
                }
                Metric::Gauge(g) => {
                    gauges.insert(e.name.clone(), num(g.get()));
                }
                Metric::Histogram(h) => {
                    hists.insert(e.name.clone(), h.snapshot().to_json());
                }
            }
        }
        obj(vec![
            ("t_s", num(t_s)),
            ("kind", s("metrics")),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
        ])
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let reg = Registry::new();
        let c = reg.counter("frames_total", "frames");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // idempotent registration returns the same handle
        let c2 = reg.counter("frames_total", "frames");
        c2.inc();
        assert_eq!(c.get(), 6);
        let g = reg.gauge("backlog", "in-flight");
        g.set(3.5);
        assert!((g.get() - 3.5).abs() < 1e-12);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn histogram_percentiles_are_order_of_magnitude_right() {
        let h = Histogram::new();
        for _ in 0..90 {
            h.record(0.001); // 1 ms
        }
        for _ in 0..10 {
            h.record(0.1); // 100 ms
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, 100);
        // log-bucket midpoints: p50 lands in the 1 ms bucket, p99 in the
        // 100 ms bucket; both within a factor of ~1.5
        assert!(snap.p50_ms > 0.4 && snap.p50_ms < 2.0, "p50 {}", snap.p50_ms);
        assert!(snap.p99_ms > 40.0 && snap.p99_ms < 200.0, "p99 {}", snap.p99_ms);
        assert!((snap.max_ms - 100.0).abs() < 1.0);
        assert!(snap.mean_ms > 5.0 && snap.mean_ms < 20.0);
    }

    #[test]
    fn zero_sample_histogram_is_all_zeroes() {
        let h = Histogram::new();
        h.record(0.0);
        let snap = h.snapshot();
        assert_eq!(snap.count, 1);
        assert_eq!(snap.p50_ms, 0.0);
        assert_eq!(snap.max_ms, 0.0);
    }

    #[test]
    fn exposition_and_snapshot_cover_every_metric() {
        let reg = Registry::new();
        reg.counter("offered_total", "offered frames").add(7);
        reg.gauge("backlog", "queued").set(2.0);
        reg.histogram("latency", "frame latency").record(0.004);
        let text = reg.expose();
        assert!(text.contains("# TYPE offered_total counter"));
        assert!(text.contains("offered_total 7"));
        assert!(text.contains("# TYPE backlog gauge"));
        assert!(text.contains("# TYPE latency histogram"));
        assert!(text.contains("latency_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("latency_count 1"));

        let snap = reg.snapshot_json(1.5);
        assert_eq!(snap.get("t_s").and_then(|v| v.as_f64()), Some(1.5));
        let counters = snap.get("counters").unwrap();
        assert_eq!(
            counters.get("offered_total").and_then(|v| v.as_f64()),
            Some(7.0)
        );
        let hists = snap.get("histograms").unwrap();
        assert_eq!(
            hists
                .get("latency")
                .and_then(|h| h.get("count"))
                .and_then(|v| v.as_u64()),
            Some(1)
        );
    }

    #[test]
    fn type_mismatch_returns_detached_handle() {
        let reg = Registry::new();
        reg.counter("x", "a counter").inc();
        let g = reg.gauge("x", "same name, wrong type");
        g.set(9.0);
        // the registered counter is untouched and still exposed
        assert!(reg.expose().contains("x 1"));
        assert_eq!(reg.len(), 1);
    }
}
