//! Chrome trace-event export: serialize arbiter/vclock timelines into
//! the Chrome/Perfetto "trace event format" JSON, loadable directly in
//! `chrome://tracing` or <https://ui.perfetto.dev>.
//!
//! Mapping: **process = node**, **thread = engine unit**, complete
//! (`"ph": "X"`) slices = dispatches and reformats, instant events =
//! control-plane markers (replan/migration/shed/degrade/switch), async
//! `b`/`e` pairs = frame lifecycles (flows). Timestamps are microseconds
//! as the format requires; all builder inputs are seconds.
#![deny(clippy::unwrap_used)]

use crate::config::json::{arr, num, obj, s, Json};
use crate::sim::timeline::Timeline;
use std::collections::BTreeMap;

/// Builder for one trace file.
pub struct ChromeTrace {
    events: Vec<Json>,
    /// `(pid, thread name)` → tid, with thread-name metadata emitted on
    /// first use.
    tids: BTreeMap<(u64, String), u64>,
    next_tid: u64,
}

impl ChromeTrace {
    pub fn new() -> ChromeTrace {
        ChromeTrace {
            events: Vec::new(),
            tids: BTreeMap::new(),
            next_tid: 1,
        }
    }

    /// Register a process (one per node) with a display name.
    pub fn process(&mut self, pid: u64, name: &str) {
        self.events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("process_name")),
            ("pid", num(pid as f64)),
            ("tid", num(0.0)),
            ("args", obj(vec![("name", s(name))])),
        ]));
    }

    fn tid(&mut self, pid: u64, thread: &str) -> u64 {
        let key = (pid, thread.to_string());
        if let Some(&t) = self.tids.get(&key) {
            return t;
        }
        let t = self.next_tid;
        self.next_tid += 1;
        self.tids.insert(key, t);
        self.events.push(obj(vec![
            ("ph", s("M")),
            ("name", s("thread_name")),
            ("pid", num(pid as f64)),
            ("tid", num(t as f64)),
            ("args", obj(vec![("name", s(thread))])),
        ]));
        t
    }

    /// Complete (`"X"`) slice on `(pid, thread)`, `[t0_s, t1_s]` seconds.
    pub fn complete(
        &mut self,
        pid: u64,
        thread: &str,
        name: &str,
        cat: &str,
        t0_s: f64,
        t1_s: f64,
        args: Json,
    ) {
        let tid = self.tid(pid, thread);
        self.events.push(obj(vec![
            ("ph", s("X")),
            ("name", s(name)),
            ("cat", s(cat)),
            ("pid", num(pid as f64)),
            ("tid", num(tid as f64)),
            ("ts", num(t0_s * 1e6)),
            ("dur", num((t1_s - t0_s).max(0.0) * 1e6)),
            ("args", args),
        ]));
    }

    /// Instant (`"i"`, process-scoped) marker.
    pub fn instant(&mut self, pid: u64, thread: &str, name: &str, cat: &str, t_s: f64, args: Json) {
        let tid = self.tid(pid, thread);
        self.events.push(obj(vec![
            ("ph", s("i")),
            ("s", s("p")),
            ("name", s(name)),
            ("cat", s(cat)),
            ("pid", num(pid as f64)),
            ("tid", num(tid as f64)),
            ("ts", num(t_s * 1e6)),
            ("args", args),
        ]));
    }

    /// Async begin/end pair (`"b"`/`"e"`) — one frame lifecycle rendered
    /// as a flow on the process's `frames` track. `id` must be unique
    /// per concurrent flow within the process.
    pub fn flow(&mut self, pid: u64, id: u64, name: &str, t0_s: f64, t1_s: f64, args: Json) {
        let tid = self.tid(pid, "frames");
        for (ph, at_s) in [("b", t0_s), ("e", t1_s.max(t0_s))] {
            self.events.push(obj(vec![
                ("ph", s(ph)),
                ("cat", s("frame")),
                ("name", s(name)),
                ("id", num(id as f64)),
                ("pid", num(pid as f64)),
                ("tid", num(tid as f64)),
                ("ts", num(at_s * 1e6)),
                ("args", args.clone()),
            ]));
        }
    }

    /// Map a [`Timeline`] onto this trace: one thread per engine unit;
    /// execution spans become `"dispatch"`-category slices named after
    /// the instance (`labels[instance]`, falling back to `inst{n}`),
    /// non-zero transitions become `"reformat"` slices, and zero-width
    /// transition markers (the serve loop's drain-and-switch stamps)
    /// become `"switch"` instants.
    pub fn add_timeline(&mut self, pid: u64, tl: &Timeline, labels: &[String]) {
        for sp in &tl.spans {
            let thread = sp.engine.unit_label(sp.unit);
            if sp.is_transition {
                if sp.t1 > sp.t0 {
                    self.complete(
                        pid,
                        &thread,
                        "reformat",
                        "reformat",
                        sp.t0,
                        sp.t1,
                        obj(vec![("instance", num(sp.instance as f64))]),
                    );
                } else {
                    self.instant(
                        pid,
                        &thread,
                        "switch",
                        "switch",
                        sp.t0,
                        obj(vec![("instance", num(sp.instance as f64))]),
                    );
                }
            } else {
                let name = labels
                    .get(sp.instance)
                    .cloned()
                    .unwrap_or_else(|| format!("inst{}", sp.instance));
                self.complete(
                    pid,
                    &thread,
                    &name,
                    "dispatch",
                    sp.t0,
                    sp.t1,
                    obj(vec![
                        ("instance", num(sp.instance as f64)),
                        ("frame", num(sp.frame as f64)),
                    ]),
                );
            }
        }
    }

    /// Trace events emitted so far (including metadata records).
    pub fn event_count(&self) -> usize {
        self.events.len()
    }

    /// The complete trace document (`traceEvents` + display unit).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("traceEvents", arr(self.events.clone())),
            ("displayTimeUnit", s("ms")),
        ])
    }
}

impl Default for ChromeTrace {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::hw::EngineKind;
    use crate::sim::timeline::Span;

    fn span(unit: usize, instance: usize, frame: usize, t0: f64, t1: f64, trans: bool) -> Span {
        Span {
            engine: EngineKind::Dla,
            unit,
            instance,
            frame,
            t0,
            t1,
            is_transition: trans,
        }
    }

    #[test]
    fn timeline_maps_to_threads_slices_and_markers() {
        let tl = Timeline {
            spans: vec![
                span(0, 0, 0, 0.0, 0.010, false),
                span(0, 1, 1, 0.010, 0.012, true), // reformat
                span(0, 1, 1, 0.012, 0.020, false),
                span(1, 2, 2, 0.0, 0.0, true), // zero-width switch marker
            ],
        };
        let mut tr = ChromeTrace::new();
        tr.process(0, "node0");
        tr.add_timeline(0, &tl, &["gan_a".to_string(), "gan_b".to_string()]);
        let doc = tr.to_json();
        let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let phase = |ph: &str| {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some(ph))
                .count()
        };
        assert_eq!(phase("X"), 3, "2 dispatch + 1 reformat slices");
        assert_eq!(phase("i"), 1, "zero-width transition → switch instant");
        // process + two unit threads named
        assert_eq!(phase("M"), 3);
        let dispatch_names: Vec<&str> = events
            .iter()
            .filter(|e| e.get("cat").and_then(|v| v.as_str()) == Some("dispatch"))
            .filter_map(|e| e.get("name").and_then(|v| v.as_str()))
            .collect();
        assert_eq!(dispatch_names, vec!["gan_a", "gan_b"]);
        // µs conversion on a known slice
        let first = events
            .iter()
            .find(|e| e.get("cat").and_then(|v| v.as_str()) == Some("dispatch"))
            .unwrap();
        assert_eq!(first.get("ts").and_then(|v| v.as_f64()), Some(0.0));
        assert!((first.get("dur").and_then(|v| v.as_f64()).unwrap() - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn flows_pair_begin_and_end() {
        let mut tr = ChromeTrace::new();
        tr.process(0, "p");
        tr.flow(0, 42, "frame", 0.001, 0.004, obj(vec![("stream", num(1.0))]));
        let doc = tr.to_json();
        let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        let b: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("b"))
            .collect();
        let e: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("e"))
            .collect();
        assert_eq!((b.len(), e.len()), (1, 1));
        assert_eq!(b[0].get("id").and_then(|v| v.as_f64()), Some(42.0));
        assert_eq!(e[0].get("id").and_then(|v| v.as_f64()), Some(42.0));
    }
}
