//! Unified observability layer: frame-lifecycle tracing, a metrics
//! registry with live exposition, Chrome/Perfetto trace export, and a
//! structured event log — dependency-free, shared by `run`, `serve`,
//! and `fleet`.
//!
//! ## Tracing vs telemetry vs reports
//!
//! The serving stack now has three distinct observation surfaces, with
//! distinct jobs:
//!
//! * **Telemetry** ([`crate::serve::telemetry`]) is the *control* input:
//!   rolling completion windows the re-plan controller and fleet health
//!   checks read online. It is windowed, lossy by design (ring buffer),
//!   and optimized for the decision loop, not for humans.
//! * **Reports** (`PipelineReport`/`ServeReport`/`FleetReport`) are
//!   end-of-run *aggregates*: percentiles, per-engine utilization,
//!   ranking tables. They summarize; they cannot show *when* things
//!   happened.
//! * **Tracing** (this module) is the *artifact* surface: per-event
//!   records with timestamps — engine-unit spans as a Chrome/Perfetto
//!   trace (`--trace-out`), per-stage frame-lifecycle histograms
//!   ([`stages`]), checkpoint-aligned metrics snapshots plus a
//!   structured event log as JSONL (`--metrics-out`), and Prometheus
//!   text exposition ([`Registry::expose`]) for scrape-style use.
//!
//! The hot path records into lock-free handles ([`Counter`], [`Gauge`],
//! [`Histogram`], [`StageAccum`]) — the [`ObsHub`] locks (event log,
//! snapshot buffer) are only touched at checkpoints and control-plane
//! events, so a traced serve run stays within a few percent of an
//! untraced one (bench-gated in CI by `serve_traced_512_frames`).
//!
//! Span records reuse the one schema the whole crate shares
//! ([`crate::sim::timeline::Span`]): the arbiter timeline, the fleet
//! virtual clock, and the placement scorer all emit it, so
//! [`ChromeTrace::add_timeline`] renders any of them.
#![deny(clippy::unwrap_used)]

pub mod events;
pub mod registry;
pub mod stages;
pub mod trace;

pub use events::{EventKind, ObsEvent};
pub use registry::{Counter, Gauge, Histogram, HistogramSnapshot, Registry};
pub use stages::{DispatchStamps, StageAccum, StageBreakdown, StageStamps};
pub use trace::ChromeTrace;

use crate::config::json::Json;
use crate::util::lock::relock;
use std::fmt;
use std::sync::{Arc, Mutex};

/// The per-run observability hub: a metrics [`Registry`], a shared
/// frame-stage accumulator, the structured event log, and the buffer of
/// checkpoint-aligned metrics snapshots.
///
/// Cloned as `Arc<ObsHub>` into `ServeOptions::obs` / `FleetOptions::obs`
/// (or threaded to the driver via `Session::run_observed`); `None` keeps
/// the stack fully untraced.
pub struct ObsHub {
    pub registry: Registry,
    pub stages: Arc<StageAccum>,
    // Lock ranks 6/7 (see `analysis::hotpath::LOCK_ORDER`): cold-path
    // leaves, taken one at a time in rank order, never per frame.
    events: Mutex<Vec<ObsEvent>>,
    snapshots: Mutex<Vec<Json>>,
}

impl ObsHub {
    pub fn new() -> ObsHub {
        ObsHub {
            registry: Registry::new(),
            stages: Arc::new(StageAccum::new()),
            events: Mutex::new(Vec::new()),
            snapshots: Mutex::new(Vec::new()),
        }
    }

    /// Append one structured event (replan/migration/degradation/shed).
    pub fn push_event(&self, ev: ObsEvent) {
        relock(&self.events).push(ev);
    }

    pub fn events(&self) -> Vec<ObsEvent> {
        relock(&self.events).clone()
    }

    pub fn event_count(&self) -> usize {
        relock(&self.events).len()
    }

    /// Count of logged events of one kind.
    pub fn events_of(&self, kind: EventKind) -> usize {
        relock(&self.events).iter().filter(|e| e.kind == kind).count()
    }

    /// Take one checkpoint-aligned snapshot of the whole registry at
    /// run-clock time `t_s` and buffer it for [`ObsHub::to_jsonl`].
    pub fn snapshot_at(&self, t_s: f64) {
        let snap = self.registry.snapshot_json(t_s);
        relock(&self.snapshots).push(snap);
    }

    pub fn snapshot_count(&self) -> usize {
        relock(&self.snapshots).len()
    }

    /// Render the metrics stream: one compact JSON object per line,
    /// snapshots (`"kind": "metrics"`) and events (`"kind": "event"`)
    /// merged in time order — the `--metrics-out` file format.
    pub fn to_jsonl(&self) -> String {
        let mut lines: Vec<(f64, String)> = Vec::new();
        {
            let events = relock(&self.events);
            for ev in events.iter() {
                lines.push((ev.t_s, ev.to_json().to_compact()));
            }
        }
        {
            let snaps = relock(&self.snapshots);
            for snap in snaps.iter() {
                let t = snap.get("t_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
                lines.push((t, snap.to_compact()));
            }
        }
        lines.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
        let mut out = String::new();
        for (_, line) in lines {
            out.push_str(&line);
            out.push('\n');
        }
        out
    }
}

impl Default for ObsHub {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for ObsHub {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ObsHub")
            .field("metrics", &self.registry.len())
            .field("frames", &self.stages.frames())
            .field("events", &self.event_count())
            .field("snapshots", &self.snapshot_count())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::config::json::{num, obj};

    #[test]
    fn jsonl_merges_snapshots_and_events_in_time_order() {
        let hub = ObsHub::new();
        hub.registry.counter("offered_total", "offered").add(3);
        hub.snapshot_at(1.0);
        hub.push_event(ObsEvent::replan(
            0.5,
            "a → b".to_string(),
            obj(vec![("gain", num(0.2))]),
        ));
        hub.registry.counter("offered_total", "offered").add(2);
        hub.snapshot_at(2.0);
        let text = hub.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("\"event\":\"replan\""), "{}", lines[0]);
        assert!(lines[1].contains("\"kind\":\"metrics\""));
        // counters are cumulative across snapshots
        assert!(lines[1].contains("\"offered_total\":3"));
        assert!(lines[2].contains("\"offered_total\":5"));
        assert_eq!(hub.snapshot_count(), 2);
        assert_eq!(hub.events_of(EventKind::Replan), 1);
    }
}
