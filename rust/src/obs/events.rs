//! Structured event log: one schema for the control-plane events that
//! were previously scattered across report fields (replan switches,
//! stream migrations, injected degradations, shed bursts).
//!
//! Events are appended to the [`crate::obs::ObsHub`] as they happen and
//! serialized into the `--metrics-out` JSONL stream interleaved with
//! metrics snapshots in time order (`"kind": "event"` vs `"metrics"`).
#![deny(clippy::unwrap_used)]

use crate::config::json::{num, obj, s, Json};

/// What happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// The serve loop drain-and-switched to a re-planned spec.
    Replan,
    /// The fleet moved a stream between nodes.
    Migration,
    /// An injected (or modeled) slowdown hit a node.
    Degradation,
    /// An admission window shed at least one frame.
    ShedBurst,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Replan => "replan",
            EventKind::Migration => "migration",
            EventKind::Degradation => "degradation",
            EventKind::ShedBurst => "shed_burst",
        }
    }
}

/// One structured event on the unified log.
#[derive(Debug, Clone)]
pub struct ObsEvent {
    /// Event time, seconds on the run's clock (wall seconds for the
    /// serve loop, virtual seconds for the fleet).
    pub t_s: f64,
    pub kind: EventKind,
    /// Node id for fleet events; `None` on single-node runs.
    pub node: Option<usize>,
    /// Short human-readable label (`"dual_gan → split_dla"`,
    /// `"stream 3: node 0 → 1"`).
    pub label: String,
    /// Kind-specific structured payload (usually the source report
    /// object, e.g. a `ReplanEvent`/`MigrationEvent` JSON).
    pub detail: Json,
}

impl ObsEvent {
    pub fn replan(t_s: f64, label: String, detail: Json) -> ObsEvent {
        ObsEvent {
            t_s,
            kind: EventKind::Replan,
            node: None,
            label,
            detail,
        }
    }

    pub fn migration(t_s: f64, node: usize, label: String, detail: Json) -> ObsEvent {
        ObsEvent {
            t_s,
            kind: EventKind::Migration,
            node: Some(node),
            label,
            detail,
        }
    }

    pub fn degradation(t_s: f64, node: usize, label: String, detail: Json) -> ObsEvent {
        ObsEvent {
            t_s,
            kind: EventKind::Degradation,
            node: Some(node),
            label,
            detail,
        }
    }

    pub fn shed_burst(t_s: f64, node: Option<usize>, label: String, detail: Json) -> ObsEvent {
        ObsEvent {
            t_s,
            kind: EventKind::ShedBurst,
            node,
            label,
            detail,
        }
    }

    /// JSONL line form. `"kind": "event"` discriminates from metrics
    /// snapshots in the same stream; the event type is under `"event"`.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("t_s", num(self.t_s)),
            ("kind", s("event")),
            ("event", s(self.kind.name())),
            ("label", s(&self.label)),
            ("detail", self.detail.clone()),
        ];
        if let Some(n) = self.node {
            pairs.push(("node", num(n as f64)));
        }
        obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn event_json_carries_kind_and_time() {
        let ev = ObsEvent::migration(
            2.5,
            1,
            "stream 3: node 1 → 0".to_string(),
            obj(vec![("stream", num(3.0))]),
        );
        let doc = ev.to_json();
        assert_eq!(doc.get("t_s").and_then(|v| v.as_f64()), Some(2.5));
        assert_eq!(doc.get("kind").and_then(|v| v.as_str()), Some("event"));
        assert_eq!(doc.get("event").and_then(|v| v.as_str()), Some("migration"));
        assert_eq!(doc.get("node").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(
            doc.get("detail").and_then(|d| d.get("stream")).and_then(|v| v.as_f64()),
            Some(3.0)
        );
    }

    #[test]
    fn kinds_have_stable_names() {
        assert_eq!(EventKind::Replan.name(), "replan");
        assert_eq!(EventKind::ShedBurst.name(), "shed_burst");
        assert_eq!(EventKind::Degradation.name(), "degradation");
    }
}
