//! Frame-lifecycle stage stamps and their aggregation.
//!
//! Every [`crate::pipeline::frame::Frame`] carries a [`StageStamps`]
//! record — cumulative seconds since admission at which the frame
//! crossed each pipeline stage boundary:
//!
//! ```text
//! source → admission → batcher queue → engine wait → reformat → dispatch → write-out
//! ```
//!
//! Stamps are written by the code that owns each boundary (the batcher
//! stamps queue exit, the engine arbiter returns a [`DispatchStamps`]
//! receipt, the stream worker seals and records) and folded into a
//! shared lock-free [`StageAccum`], whose [`StageBreakdown`] percentiles
//! surface in `PipelineReport`/`ServeReport`/fleet rollups.
#![deny(clippy::unwrap_used)]

use super::registry::{Counter, Histogram, HistogramSnapshot};
use crate::config::json::{arr, num, obj, s, Json};

/// Number of per-frame stages tracked.
pub const STAGE_COUNT: usize = 6;

/// Stage names, in pipeline order. Each entry is the *duration* ending
/// at the corresponding stamp: `source` is pre-admission slip, `queue`
/// is admission → batcher-queue exit, `engine_wait` is queue exit →
/// engine lease, `reformat` is the occupant-switch cost, `dispatch` is
/// model execution, `writeout` is completion bookkeeping.
pub const STAGE_NAMES: [&str; STAGE_COUNT] = [
    "source",
    "queue",
    "engine_wait",
    "reformat",
    "dispatch",
    "writeout",
];

/// Cumulative stage-crossing times for one frame, seconds since
/// admission. Monotone by construction: every sealing helper clamps
/// against the previous stamp.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageStamps {
    /// Seconds the frame spent upstream of admission (e.g. schedule slip
    /// between its modeled arrival and the moment the source admitted it).
    pub source_s: f64,
    /// Admission → batcher-queue exit (batch fill + queue wait).
    pub queue_exit_s: f64,
    /// Admission → engine lease won (adds the FIFO engine wait).
    pub engine_start_s: f64,
    /// Admission → model execution start (adds the reformat/transition).
    pub exec_start_s: f64,
    /// Admission → model execution end.
    pub exec_end_s: f64,
    /// Admission → completion write-out (metrics, sinks, fidelity).
    pub writeout_s: f64,
}

impl StageStamps {
    /// Stamp the batcher-queue exit.
    pub fn mark_queue_exit(&mut self, since_admission_s: f64) {
        self.queue_exit_s = since_admission_s.max(0.0);
    }

    /// Seal the engine-side stamps from a dispatch receipt: `end_s` is
    /// the cumulative time at which the batched dispatch returned, and
    /// the receipt's durations are subtracted backwards from it.
    pub fn seal_dispatch(&mut self, end_s: f64, receipt: &DispatchStamps) {
        self.exec_end_s = end_s.max(self.queue_exit_s);
        self.exec_start_s = (self.exec_end_s - receipt.exec_s.max(0.0)).max(self.queue_exit_s);
        self.engine_start_s =
            (self.exec_start_s - receipt.reformat_s.max(0.0)).max(self.queue_exit_s);
    }

    /// Stamp completion write-out (the final stage).
    pub fn mark_writeout(&mut self, since_admission_s: f64) {
        self.writeout_s = since_admission_s.max(self.exec_end_s);
    }

    /// True when every stamp respects pipeline order.
    pub fn is_monotone(&self) -> bool {
        0.0 <= self.source_s
            && 0.0 <= self.queue_exit_s
            && self.queue_exit_s <= self.engine_start_s
            && self.engine_start_s <= self.exec_start_s
            && self.exec_start_s <= self.exec_end_s
            && self.exec_end_s <= self.writeout_s
    }
}

/// Durations charged by one engine dispatch, the arbiter's receipt to
/// the stream worker (which turns them back into cumulative stamps).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DispatchStamps {
    /// Seconds spent waiting on the engine-unit FIFO before the lease.
    pub wait_s: f64,
    /// Reformat/transition seconds charged (occupant switch), 0 if warm.
    pub reformat_s: f64,
    /// Model execution seconds charged.
    pub exec_s: f64,
}

/// Shared lock-free accumulator of per-stage durations across every
/// worker thread: one log-bucketed [`Histogram`] per stage.
pub struct StageAccum {
    hists: [Histogram; STAGE_COUNT],
    frames: Counter,
    non_monotone: Counter,
}

impl StageAccum {
    pub fn new() -> StageAccum {
        StageAccum {
            hists: std::array::from_fn(|_| Histogram::new()),
            frames: Counter::new(),
            non_monotone: Counter::new(),
        }
    }

    /// Fold one completed frame's stamps in. Hot path: O(1) relaxed
    /// atomics only, no locks, no allocation.
    pub fn record(&self, st: &StageStamps) {
        if !st.is_monotone() {
            self.non_monotone.inc();
        }
        let durations = [
            st.source_s,
            st.queue_exit_s,
            st.engine_start_s - st.queue_exit_s,
            st.exec_start_s - st.engine_start_s,
            st.exec_end_s - st.exec_start_s,
            st.writeout_s - st.exec_end_s,
        ];
        for (h, d) in self.hists.iter().zip(durations) {
            h.record(d.max(0.0));
        }
        self.frames.inc();
    }

    /// Frames recorded so far.
    pub fn frames(&self) -> u64 {
        self.frames.get()
    }

    /// Frames whose stamps violated pipeline order (should stay 0; the
    /// clamps in [`StageStamps`] make violations a stamping bug, not a
    /// scheduling artifact).
    pub fn non_monotone(&self) -> u64 {
        self.non_monotone.get()
    }

    /// Digest every stage histogram into the report-facing breakdown.
    pub fn breakdown(&self) -> StageBreakdown {
        StageBreakdown {
            frames: self.frames.get(),
            non_monotone: self.non_monotone.get(),
            stages: STAGE_NAMES
                .iter()
                .zip(self.hists.iter())
                .map(|(name, h)| ((*name).to_string(), h.snapshot()))
                .collect(),
        }
    }
}

impl Default for StageAccum {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-stage latency digest for reports (`"stages"` in report JSON).
#[derive(Debug, Clone, PartialEq)]
pub struct StageBreakdown {
    pub frames: u64,
    pub non_monotone: u64,
    /// `(stage name, digest)` in pipeline order.
    pub stages: Vec<(String, HistogramSnapshot)>,
}

impl StageBreakdown {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("frames", num(self.frames as f64)),
            ("non_monotone", num(self.non_monotone as f64)),
            (
                "stages",
                arr(self
                    .stages
                    .iter()
                    .map(|(name, snap)| {
                        let mut o = snap.to_json();
                        if let Json::Obj(map) = &mut o {
                            map.insert("stage".to_string(), s(name));
                        }
                        o
                    })
                    .collect()),
            ),
        ])
    }

    /// Compact one-line summary (`queue p50 1.2ms | dispatch p50 8.4ms …`)
    /// for CLI output.
    pub fn summary(&self) -> String {
        self.stages
            .iter()
            .map(|(name, snap)| format!("{name} p50 {:.2}ms p99 {:.2}ms", snap.p50_ms, snap.p99_ms))
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn sealing_is_monotone_even_with_inconsistent_inputs() {
        let mut st = StageStamps::default();
        st.mark_queue_exit(0.010);
        // receipt claims more exec time than the whole window: clamps win
        st.seal_dispatch(
            0.012,
            &DispatchStamps {
                wait_s: 0.5,
                reformat_s: 0.5,
                exec_s: 0.5,
            },
        );
        st.mark_writeout(0.001); // earlier than exec end: clamped up
        assert!(st.is_monotone(), "{st:?}");
        assert_eq!(st.writeout_s, st.exec_end_s);
    }

    #[test]
    fn accum_counts_frames_and_breaks_down_stages() {
        let acc = StageAccum::new();
        for i in 0..10u32 {
            let mut st = StageStamps::default();
            st.mark_queue_exit(0.002);
            st.seal_dispatch(
                0.002 + 0.001 * f64::from(i + 1),
                &DispatchStamps {
                    wait_s: 0.0005,
                    reformat_s: 0.0,
                    exec_s: 0.001 * f64::from(i + 1),
                },
            );
            st.mark_writeout(st.exec_end_s + 0.0001);
            acc.record(&st);
        }
        assert_eq!(acc.frames(), 10);
        assert_eq!(acc.non_monotone(), 0);
        let bd = acc.breakdown();
        assert_eq!(bd.frames, 10);
        assert_eq!(bd.stages.len(), STAGE_COUNT);
        let names: Vec<&str> = bd.stages.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, STAGE_NAMES.to_vec());
        // every stage histogram saw every frame
        assert!(bd.stages.iter().all(|(_, s)| s.count == 10));
        let doc = bd.to_json();
        assert_eq!(doc.get("frames").and_then(|v| v.as_u64()), Some(10));
        assert_eq!(doc.get("non_monotone").and_then(|v| v.as_u64()), Some(0));
    }

    #[test]
    fn non_monotone_stamps_are_counted_not_dropped() {
        let acc = StageAccum::new();
        let st = StageStamps {
            source_s: 0.0,
            queue_exit_s: 0.5,
            engine_start_s: 0.1, // out of order on purpose
            exec_start_s: 0.1,
            exec_end_s: 0.1,
            writeout_s: 0.1,
        };
        acc.record(&st);
        assert_eq!(acc.frames(), 1);
        assert_eq!(acc.non_monotone(), 1);
    }
}
