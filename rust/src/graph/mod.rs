//! Model graph IR.
//!
//! A [`Graph`] is a DAG of layers in topological order (nodes may only
//! reference earlier nodes — enforced at construction). This is the
//! representation everything else consumes: the DLA compatibility checker,
//! the TensorRT-like subgraph planner, the cost model, the schedulers and
//! the surgeon passes.

pub mod layer;
pub mod shape;
pub mod surgeon;

use crate::error::{Error, Result};
use layer::LayerKind;
use shape::Shape;

/// Node index within a graph.
pub type NodeId = usize;

/// A single layer instance.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub kind: LayerKind,
    pub inputs: Vec<NodeId>,
    /// Inferred output shape.
    pub shape: Shape,
}

/// A model graph in topological order.
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new(name: &str) -> Self {
        Graph {
            name: name.to_string(),
            nodes: Vec::new(),
        }
    }

    /// Append a layer; inputs must reference existing nodes. Returns the
    /// new node's id. Output shape is inferred immediately.
    pub fn add(&mut self, name: &str, kind: LayerKind, inputs: &[NodeId]) -> Result<NodeId> {
        for &i in inputs {
            if i >= self.nodes.len() {
                return Err(Error::Graph(format!(
                    "node `{name}` references unknown input {i}"
                )));
            }
        }
        let in_shapes: Vec<Shape> = inputs.iter().map(|&i| self.nodes[i].shape).collect();
        let shape = kind.infer_shape(&in_shapes)?;
        let id = self.nodes.len();
        self.nodes.push(Node {
            id,
            name: name.to_string(),
            kind,
            inputs: inputs.to_vec(),
            shape,
        });
        Ok(id)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Input shapes of a node.
    pub fn input_shapes(&self, id: NodeId) -> Vec<Shape> {
        self.nodes[id]
            .inputs
            .iter()
            .map(|&i| self.nodes[i].shape)
            .collect()
    }

    /// Total learnable parameter count (Table II first row).
    pub fn param_count(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| n.kind.param_count(&self.input_shapes(n.id)))
            .sum()
    }

    /// Ids of `Input` nodes.
    pub fn inputs(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Input { .. }))
            .map(|n| n.id)
            .collect()
    }

    /// Ids of `Output` nodes (or terminal nodes if none marked).
    pub fn outputs(&self) -> Vec<NodeId> {
        let marked: Vec<NodeId> = self
            .nodes
            .iter()
            .filter(|n| matches!(n.kind, LayerKind::Output))
            .map(|n| n.id)
            .collect();
        if !marked.is_empty() {
            return marked;
        }
        // Fallback: nodes nobody consumes.
        let mut consumed = vec![false; self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                consumed[i] = true;
            }
        }
        self.nodes
            .iter()
            .filter(|n| !consumed[n.id])
            .map(|n| n.id)
            .collect()
    }

    /// Consumers of each node.
    pub fn consumers(&self) -> Vec<Vec<NodeId>> {
        let mut out = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                out[i].push(n.id);
            }
        }
        out
    }

    /// "Compute layers" — nodes that perform real work on an engine (excludes
    /// Input/Output markers and identity-likes). Partition points in the
    /// paper (Tables III/V) index into this sequence.
    pub fn compute_layers(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| {
                !matches!(n.kind, LayerKind::Input { .. } | LayerKind::Output)
                    && !n.kind.is_identity_like()
            })
            .map(|n| n.id)
            .collect()
    }

    /// Validate structural invariants: topological input references, single
    /// shape consistency, at least one input and output.
    pub fn validate(&self) -> Result<()> {
        if self.inputs().is_empty() {
            return Err(Error::Graph(format!("graph `{}` has no inputs", self.name)));
        }
        for n in &self.nodes {
            for &i in &n.inputs {
                if i >= n.id {
                    return Err(Error::Graph(format!(
                        "node {} `{}` references non-topological input {}",
                        n.id, n.name, i
                    )));
                }
            }
            let expect = n.kind.infer_shape(&self.input_shapes(n.id))?;
            if expect != n.shape {
                return Err(Error::Graph(format!(
                    "node {} `{}` shape {} inconsistent with inferred {}",
                    n.id, n.name, n.shape, expect
                )));
            }
        }
        if self.outputs().is_empty() {
            return Err(Error::Graph(format!(
                "graph `{}` has no outputs",
                self.name
            )));
        }
        Ok(())
    }

    /// One-line-per-layer textual dump (debugging / reports).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for n in &self.nodes {
            out.push_str(&format!(
                "{:4}  {:<18} {:<28} {:>16}  <- {:?}\n",
                n.id,
                n.kind.op_name(),
                n.name,
                format!("{}", n.shape),
                n.inputs
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::layer::LayerKind;
    use super::shape::{DType, Shape};
    use super::*;

    fn tiny_graph() -> Graph {
        let mut g = Graph::new("tiny");
        let x = g
            .add(
                "x",
                LayerKind::Input {
                    shape: Shape::new(1, 16, 16, DType::F16),
                },
                &[],
            )
            .unwrap();
        let c = g
            .add(
                "conv",
                LayerKind::conv(8, 3, 1, 1),
                &[x],
            )
            .unwrap();
        let r = g.add("relu", LayerKind::ReLU, &[c]).unwrap();
        g.add("out", LayerKind::Output, &[r]).unwrap();
        g
    }

    #[test]
    fn build_and_validate() {
        let g = tiny_graph();
        g.validate().unwrap();
        assert_eq!(g.len(), 4);
        assert_eq!(g.inputs(), vec![0]);
        assert_eq!(g.outputs(), vec![3]);
        assert_eq!(g.compute_layers(), vec![1, 2]);
    }

    #[test]
    fn param_count() {
        let g = tiny_graph();
        assert_eq!(g.param_count(), 1 * 8 * 9 + 8);
    }

    #[test]
    fn bad_input_reference_rejected() {
        let mut g = Graph::new("bad");
        assert!(g.add("r", LayerKind::ReLU, &[5]).is_err());
    }

    #[test]
    fn consumers_map() {
        let g = tiny_graph();
        let cons = g.consumers();
        assert_eq!(cons[0], vec![1]);
        assert_eq!(cons[1], vec![2]);
        assert_eq!(cons[3], Vec::<NodeId>::new());
    }

    #[test]
    fn dump_contains_layers() {
        let d = tiny_graph().dump();
        assert!(d.contains("Conv2d"));
        assert!(d.contains("ReLU"));
    }
}
