//! Tensor shapes and data types.
//!
//! Shapes are `C×H×W` feature maps with an implicit batch of 1 (the paper's
//! pipelines are latency-oriented, batch-1 streaming). Dtypes matter for
//! DLA compatibility: the DLA executes FP16/INT8 only.

use std::fmt;

/// Element type of a tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    I8,
    I64,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::F16 => 2,
            DType::I8 => 1,
            DType::I64 => 8,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::I8 => "i8",
            DType::I64 => "i64",
        }
    }
}

/// A `C×H×W` feature-map shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub dtype: DType,
}

impl Shape {
    pub fn new(c: usize, h: usize, w: usize, dtype: DType) -> Self {
        Shape { c, h, w, dtype }
    }

    pub fn chw(c: usize, h: usize, w: usize) -> Self {
        Shape::new(c, h, w, DType::F16)
    }

    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.c * self.h * self.w
    }

    /// Size in bytes.
    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype.bytes()
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}:{}", self.c, self.h, self.w, self.dtype.name())
    }
}

/// Conv output spatial size (paper Eq. 8):
/// `floor((in - k + 2p) / s) + 1`.
pub fn conv_out(input: usize, kernel: usize, stride: usize, padding: usize) -> usize {
    debug_assert!(stride > 0);
    (input + 2 * padding - kernel) / stride + 1
}

/// Deconv output spatial size (paper Eq. 4):
/// `s * (in - 1) + k - 2p`.
pub fn deconv_out(input: usize, kernel: usize, stride: usize, padding: usize) -> usize {
    debug_assert!(stride > 0);
    stride * (input - 1) + kernel - 2 * padding
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_bytes() {
        let s = Shape::new(3, 256, 256, DType::F16);
        assert_eq!(s.numel(), 3 * 256 * 256);
        assert_eq!(s.bytes(), 3 * 256 * 256 * 2);
        assert_eq!(format!("{s}"), "3x256x256:f16");
    }

    #[test]
    fn paper_eq4_eq5_deconv_without_padding() {
        // Paper Eq. 5: k=4, s=2, p=0 -> out = 2*in + 2
        for input in [4usize, 8, 16, 128] {
            assert_eq!(deconv_out(input, 4, 2, 0), 2 * input + 2);
        }
    }

    #[test]
    fn paper_eq6_deconv_with_padding() {
        // Paper Eq. 6: k=4, s=2, p=1 -> out = 2*in
        for input in [1usize, 2, 32, 128] {
            assert_eq!(deconv_out(input, 4, 2, 1), 2 * input);
        }
    }

    #[test]
    fn paper_eq9_valid_conv3() {
        // Paper Eq. 9: k=3, s=1, p=0 -> out = in - 2
        for input in [3usize, 10, 258] {
            assert_eq!(conv_out(input, 3, 1, 0), input - 2);
        }
    }

    #[test]
    fn conv_standard_cases() {
        // stride-2 4x4 same-ish conv used by pix2pix encoder: 256 -> 128
        assert_eq!(conv_out(256, 4, 2, 1), 128);
        assert_eq!(conv_out(2, 4, 2, 1), 1);
    }
}
