//! Layer (operator) definitions for the model IR.
//!
//! The vocabulary covers everything in the paper's models: Pix2Pix
//! (conv / deconv / batchnorm / LeakyReLU / tanh / concat / dropout /
//! zero-pad), the DLA-safe substitutions (cropping, VALID conv), YOLOv8
//! (C2f = conv + split + add + concat, SPPF = maxpool chain, SiLU,
//! upsample, detection head), and the classification backbones used by the
//! scheduling references (ResNet, VGG: pooling, FC, softmax, residual add).

use super::shape::{conv_out, deconv_out, DType, Shape};
use crate::error::{Error, Result};

/// Operator kind plus its static attributes.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// Graph input placeholder.
    Input { shape: Shape },
    /// 2-D convolution.
    Conv2d {
        out_c: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        dilation: usize,
        groups: usize,
        /// Whether the layer has a bias term (the paper's VALID-conv
        /// substitution is bias-free — see Table II parameter accounting).
        bias: bool,
    },
    /// 2-D transposed convolution (deconvolution).
    ConvTranspose2d {
        out_c: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        /// Bias term (the TF pix2pix reference uses bias-free deconvs
        /// except the final output layer).
        bias: bool,
    },
    /// Batch normalization (inference: fused scale+shift).
    BatchNorm,
    /// Instance normalization.
    InstanceNorm,
    ReLU,
    LeakyReLU { slope: f32 },
    SiLU,
    Tanh,
    Sigmoid,
    Softmax,
    /// Channel-wise concat of all inputs.
    Concat,
    /// Elementwise addition of two inputs (residual connection).
    Add,
    /// Crop `border` rows/cols from each side (the paper's DLA-safe
    /// substitute for deconv padding, Eq. 7).
    Crop { border: usize },
    /// Zero-pad `border` rows/cols on each side (PatchGAN discriminator).
    ZeroPad { border: usize },
    MaxPool { kernel: usize, stride: usize },
    AvgPool { kernel: usize, stride: usize },
    /// Global average pool to 1×1.
    GlobalAvgPool,
    /// Nearest-neighbour upsample by integer factor.
    Upsample { factor: usize },
    /// Take a channel sub-range `[begin, end)` (YOLO C2f split).
    SliceChannels { begin: usize, end: usize },
    /// Fully connected layer.
    Dense { out_features: usize },
    /// Dropout — inference no-op, kept in the graph because exported ONNX
    /// graphs contain it and the surgeon must remove it.
    Dropout { p: f32 },
    /// Identity / "unnamed" layer produced by export tooling; removed by
    /// the GraphSurgeon-equivalent pass.
    Identity,
    /// Dtype cast.
    Cast { to: DType },
    /// Graph output marker.
    Output,
}

impl LayerKind {
    /// Short operator name (used in reports and DLA diagnostics).
    pub fn op_name(&self) -> &'static str {
        use LayerKind::*;
        match self {
            Input { .. } => "Input",
            Conv2d { .. } => "Conv2d",
            ConvTranspose2d { .. } => "ConvTranspose2d",
            BatchNorm => "BatchNorm",
            InstanceNorm => "InstanceNorm",
            ReLU => "ReLU",
            LeakyReLU { .. } => "LeakyReLU",
            SiLU => "SiLU",
            Tanh => "Tanh",
            Sigmoid => "Sigmoid",
            Softmax => "Softmax",
            Concat => "Concat",
            Add => "Add",
            Crop { .. } => "Crop",
            ZeroPad { .. } => "ZeroPad",
            MaxPool { .. } => "MaxPool",
            AvgPool { .. } => "AvgPool",
            GlobalAvgPool => "GlobalAvgPool",
            Upsample { .. } => "Upsample",
            SliceChannels { .. } => "SliceChannels",
            Dense { .. } => "Dense",
            Dropout { .. } => "Dropout",
            Identity => "Identity",
            Cast { .. } => "Cast",
            Output => "Output",
        }
    }

    /// Is this a structural no-op at inference time?
    pub fn is_identity_like(&self) -> bool {
        matches!(self, LayerKind::Identity | LayerKind::Dropout { .. })
    }

    /// Infer the output shape given input shapes.
    pub fn infer_shape(&self, inputs: &[Shape]) -> Result<Shape> {
        use LayerKind::*;
        let one = |inputs: &[Shape]| -> Result<Shape> {
            if inputs.len() != 1 {
                return Err(Error::Shape(format!(
                    "{} expects 1 input, got {}",
                    self.op_name(),
                    inputs.len()
                )));
            }
            Ok(inputs[0])
        };
        match self {
            Input { shape } => Ok(*shape),
            Conv2d {
                out_c,
                kernel,
                stride,
                padding,
                dilation,
                groups,
                ..
            } => {
                let x = one(inputs)?;
                if x.c % groups != 0 || out_c % groups != 0 {
                    return Err(Error::Shape(format!(
                        "conv groups {groups} must divide channels {} and {out_c}",
                        x.c
                    )));
                }
                let eff_k = dilation * (kernel - 1) + 1;
                if x.h + 2 * padding < eff_k || x.w + 2 * padding < eff_k {
                    return Err(Error::Shape(format!(
                        "conv kernel {eff_k} larger than padded input {}x{}",
                        x.h + 2 * padding,
                        x.w + 2 * padding
                    )));
                }
                Ok(Shape::new(
                    *out_c,
                    conv_out(x.h, eff_k, *stride, *padding),
                    conv_out(x.w, eff_k, *stride, *padding),
                    x.dtype,
                ))
            }
            ConvTranspose2d {
                out_c,
                kernel,
                stride,
                padding,
                ..
            } => {
                let x = one(inputs)?;
                if *kernel + stride * (x.h - 1) < 2 * padding + 1 {
                    return Err(Error::Shape("deconv output would be empty".into()));
                }
                Ok(Shape::new(
                    *out_c,
                    deconv_out(x.h, *kernel, *stride, *padding),
                    deconv_out(x.w, *kernel, *stride, *padding),
                    x.dtype,
                ))
            }
            BatchNorm | InstanceNorm | ReLU | LeakyReLU { .. } | SiLU | Tanh | Sigmoid
            | Softmax | Dropout { .. } | Identity => one(inputs),
            Cast { to } => {
                let x = one(inputs)?;
                Ok(Shape::new(x.c, x.h, x.w, *to))
            }
            Concat => {
                if inputs.is_empty() {
                    return Err(Error::Shape("concat needs >= 1 input".into()));
                }
                let first = inputs[0];
                let mut c = 0;
                for s in inputs {
                    if s.h != first.h || s.w != first.w {
                        return Err(Error::Shape(format!(
                            "concat spatial mismatch: {s} vs {first}"
                        )));
                    }
                    c += s.c;
                }
                Ok(Shape::new(c, first.h, first.w, first.dtype))
            }
            Add => {
                if inputs.len() != 2 || inputs[0] != inputs[1] {
                    return Err(Error::Shape(format!(
                        "add expects two identical shapes, got {:?}",
                        inputs
                    )));
                }
                Ok(inputs[0])
            }
            Crop { border } => {
                let x = one(inputs)?;
                if x.h <= 2 * border || x.w <= 2 * border {
                    return Err(Error::Shape(format!(
                        "crop border {border} too large for {}x{}",
                        x.h, x.w
                    )));
                }
                Ok(Shape::new(x.c, x.h - 2 * border, x.w - 2 * border, x.dtype))
            }
            ZeroPad { border } => {
                let x = one(inputs)?;
                Ok(Shape::new(x.c, x.h + 2 * border, x.w + 2 * border, x.dtype))
            }
            MaxPool { kernel, stride } | AvgPool { kernel, stride } => {
                let x = one(inputs)?;
                if x.h < *kernel || x.w < *kernel {
                    return Err(Error::Shape("pool kernel larger than input".into()));
                }
                Ok(Shape::new(
                    x.c,
                    conv_out(x.h, *kernel, *stride, 0),
                    conv_out(x.w, *kernel, *stride, 0),
                    x.dtype,
                ))
            }
            GlobalAvgPool => {
                let x = one(inputs)?;
                Ok(Shape::new(x.c, 1, 1, x.dtype))
            }
            Upsample { factor } => {
                let x = one(inputs)?;
                Ok(Shape::new(x.c, x.h * factor, x.w * factor, x.dtype))
            }
            SliceChannels { begin, end } => {
                let x = one(inputs)?;
                if *begin >= *end || *end > x.c {
                    return Err(Error::Shape(format!(
                        "slice [{begin},{end}) out of range for {} channels",
                        x.c
                    )));
                }
                Ok(Shape::new(end - begin, x.h, x.w, x.dtype))
            }
            Dense { out_features } => {
                let x = one(inputs)?;
                Ok(Shape::new(*out_features, 1, 1, x.dtype))
            }
            Output => one(inputs),
        }
    }

    /// Learnable parameter count given the input shapes (weights + biases;
    /// batchnorm has scale+shift per channel).
    pub fn param_count(&self, inputs: &[Shape]) -> usize {
        use LayerKind::*;
        match self {
            Conv2d {
                out_c,
                kernel,
                groups,
                bias,
                ..
            } => {
                let in_c = inputs.first().map(|s| s.c).unwrap_or(0);
                (in_c / groups) * out_c * kernel * kernel + if *bias { *out_c } else { 0 }
            }
            ConvTranspose2d {
                out_c, kernel, bias, ..
            } => {
                let in_c = inputs.first().map(|s| s.c).unwrap_or(0);
                in_c * out_c * kernel * kernel + if *bias { *out_c } else { 0 }
            }
            // TF model.summary() convention (Table II): gamma, beta,
            // moving_mean, moving_variance all counted.
            BatchNorm => 4 * inputs.first().map(|s| s.c).unwrap_or(0),
            InstanceNorm => 2 * inputs.first().map(|s| s.c).unwrap_or(0),
            Dense { out_features } => {
                let in_f = inputs.first().map(|s| s.numel()).unwrap_or(0);
                in_f * out_features + out_features
            }
            _ => 0,
        }
    }
}


impl LayerKind {
    /// Standard biased convolution (dilation 1, groups 1).
    pub fn conv(out_c: usize, kernel: usize, stride: usize, padding: usize) -> LayerKind {
        LayerKind::Conv2d {
            out_c,
            kernel,
            stride,
            padding,
            dilation: 1,
            groups: 1,
            bias: true,
        }
    }

    /// Bias-free convolution (the paper's padding-fix substitution and
    /// batchnorm-fused backbones).
    pub fn conv_nobias(out_c: usize, kernel: usize, stride: usize, padding: usize) -> LayerKind {
        LayerKind::Conv2d {
            out_c,
            kernel,
            stride,
            padding,
            dilation: 1,
            groups: 1,
            bias: false,
        }
    }

    /// Bias-free transposed convolution (TF pix2pix convention).
    pub fn deconv(out_c: usize, kernel: usize, stride: usize, padding: usize) -> LayerKind {
        LayerKind::ConvTranspose2d {
            out_c,
            kernel,
            stride,
            padding,
            bias: false,
        }
    }

    /// Transposed convolution with bias (pix2pix final output layer).
    pub fn deconv_bias(out_c: usize, kernel: usize, stride: usize, padding: usize) -> LayerKind {
        LayerKind::ConvTranspose2d {
            out_c,
            kernel,
            stride,
            padding,
            bias: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(c: usize, hw: usize) -> Shape {
        Shape::chw(c, hw, hw)
    }

    #[test]
    fn conv_shape_and_params() {
        let conv = LayerKind::conv(64, 4, 2, 1);
        let out = conv.infer_shape(&[s(3, 256)]).unwrap();
        assert_eq!((out.c, out.h, out.w), (64, 128, 128));
        assert_eq!(conv.param_count(&[s(3, 256)]), 3 * 64 * 16 + 64);
    }

    #[test]
    fn deconv_padding_variants_match_paper() {
        let with_pad = LayerKind::deconv(64, 4, 2, 1);
        let no_pad = LayerKind::deconv(64, 4, 2, 0);
        assert_eq!(with_pad.infer_shape(&[s(128, 8)]).unwrap().h, 16); // Eq. 6
        assert_eq!(no_pad.infer_shape(&[s(128, 8)]).unwrap().h, 18); // Eq. 5
        // Crop(1) brings 18 back to 16 (Eq. 7)
        let crop = LayerKind::Crop { border: 1 };
        assert_eq!(crop.infer_shape(&[s(64, 18)]).unwrap().h, 16);
        // VALID 3x3 conv also brings 18 to 16 (Eq. 9)
        let conv3 = LayerKind::conv(64, 3, 1, 0);
        assert_eq!(conv3.infer_shape(&[s(64, 18)]).unwrap().h, 16);
    }

    #[test]
    fn concat_sums_channels() {
        let cat = LayerKind::Concat;
        let out = cat.infer_shape(&[s(64, 32), s(64, 32)]).unwrap();
        assert_eq!(out.c, 128);
        assert!(cat.infer_shape(&[s(64, 32), s(64, 16)]).is_err());
    }

    #[test]
    fn add_requires_matching_shapes() {
        let add = LayerKind::Add;
        assert!(add.infer_shape(&[s(64, 32), s(64, 32)]).is_ok());
        assert!(add.infer_shape(&[s(64, 32), s(32, 32)]).is_err());
        assert!(add.infer_shape(&[s(64, 32)]).is_err());
    }

    #[test]
    fn pooling_and_upsample() {
        let mp = LayerKind::MaxPool {
            kernel: 2,
            stride: 2,
        };
        assert_eq!(mp.infer_shape(&[s(32, 64)]).unwrap().h, 32);
        let up = LayerKind::Upsample { factor: 2 };
        assert_eq!(up.infer_shape(&[s(32, 8)]).unwrap().h, 16);
        let gap = LayerKind::GlobalAvgPool;
        assert_eq!(gap.infer_shape(&[s(512, 7)]).unwrap().numel(), 512);
    }

    #[test]
    fn slice_channels_bounds() {
        let sl = LayerKind::SliceChannels { begin: 0, end: 32 };
        assert_eq!(sl.infer_shape(&[s(64, 8)]).unwrap().c, 32);
        let bad = LayerKind::SliceChannels { begin: 32, end: 80 };
        assert!(bad.infer_shape(&[s(64, 8)]).is_err());
    }

    #[test]
    fn degenerate_conv_rejected() {
        let conv = LayerKind::conv(8, 7, 1, 0);
        assert!(conv.infer_shape(&[s(3, 4)]).is_err());
    }

    #[test]
    fn dense_param_count() {
        let d = LayerKind::Dense { out_features: 10 };
        assert_eq!(d.param_count(&[s(512, 1)]), 512 * 10 + 10);
        assert_eq!(d.infer_shape(&[s(512, 1)]).unwrap().c, 10);
    }
}
