//! Graph surgery passes.
//!
//! This module implements the paper's §V.A.2 contribution at the IR level:
//! replacing DLA-incompatible deconvolution padding with DLA-compatible
//! equivalents, plus the ONNX-GraphSurgeon-style cleanup pass the paper
//! uses to remove the "ten unnamed layers" that export tooling inserts.
//!
//! Passes rebuild the graph (ids are reassigned) and preserve shape
//! validity — every pass ends with `validate()`.

use super::layer::LayerKind;
use super::{Graph, NodeId};
use crate::config::GanVariant;
use crate::error::{Error, Result};

/// Strategy for making a padded deconvolution DLA-compatible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PaddingFix {
    /// `deconv(p=1)` → `deconv(p=0)` + `Crop(1)` (paper Eq. 5 + Eq. 7).
    Crop,
    /// `deconv(p=1)` → `deconv(p=0)` + `Conv2d(k=3, s=1, VALID)`
    /// (paper Eq. 5 + Eq. 9) — adds parameters, may improve accuracy.
    Conv,
}

impl PaddingFix {
    pub fn for_variant(v: GanVariant) -> Option<PaddingFix> {
        match v {
            GanVariant::Original => None,
            GanVariant::Cropping => Some(PaddingFix::Crop),
            GanVariant::Convolution => Some(PaddingFix::Conv),
        }
    }
}

/// Result of a surgery pass.
#[derive(Debug, Clone)]
pub struct SurgeryReport {
    pub graph: Graph,
    /// How many deconv layers were rewritten.
    pub deconvs_fixed: usize,
    /// How many identity-like layers were inserted (export artifacts).
    pub unnamed_inserted: usize,
}

/// Replace the padding of every padded `ConvTranspose2d` with the chosen
/// DLA-compatible construction.
///
/// Mirroring the paper's observation that the substitution "came with an
/// additional ten unnamed layers as a result of the dynamic inputs", this
/// pass also inserts an `Identity` node after each rewritten deconv when
/// `emulate_export_artifacts` is set; [`eliminate_identities`] (the
/// GraphSurgeon-equivalent) removes them again.
pub fn replace_deconv_padding(
    graph: &Graph,
    fix: PaddingFix,
    emulate_export_artifacts: bool,
) -> Result<SurgeryReport> {
    let mut out = Graph::new(&graph.name);
    // old id -> new id of the node producing the equivalent tensor
    let mut remap: Vec<NodeId> = Vec::with_capacity(graph.len());
    let mut fixed = 0usize;
    let mut unnamed = 0usize;

    for node in &graph.nodes {
        let new_inputs: Vec<NodeId> = node.inputs.iter().map(|&i| remap[i]).collect();
        match &node.kind {
            LayerKind::ConvTranspose2d {
                out_c,
                kernel,
                stride,
                padding,
                bias,
            } if *padding > 0 => {
                // Step 1: same deconv without padding (Eq. 5).
                let deconv = out.add(
                    &node.name,
                    LayerKind::ConvTranspose2d {
                        out_c: *out_c,
                        kernel: *kernel,
                        stride: *stride,
                        padding: 0,
                        bias: *bias,
                    },
                    &new_inputs,
                )?;
                // Step 2: trim `padding` rows/cols per side back off.
                let trimmed = match fix {
                    PaddingFix::Crop => out.add(
                        &format!("{}_crop", node.name),
                        LayerKind::Crop { border: *padding },
                        &[deconv],
                    )?,
                    PaddingFix::Conv => {
                        // A VALID k×k conv removes (k-1)/2 per side; for
                        // padding=1 that is the 3×3 of Eq. 9. General p
                        // uses k = 2p+1.
                        let k = 2 * padding + 1;
                        out.add(
                            &format!("{}_fixconv", node.name),
                            LayerKind::conv_nobias(*out_c, k, 1, 0),
                            &[deconv],
                        )?
                    }
                };
                let tail = if emulate_export_artifacts {
                    unnamed += 1;
                    out.add(
                        &format!("unnamed_{}", unnamed),
                        LayerKind::Identity,
                        &[trimmed],
                    )?
                } else {
                    trimmed
                };
                fixed += 1;
                remap.push(tail);
            }
            kind => {
                let id = out.add(&node.name, kind.clone(), &new_inputs)?;
                remap.push(id);
            }
        }
    }
    out.validate()?;
    Ok(SurgeryReport {
        graph: out,
        deconvs_fixed: fixed,
        unnamed_inserted: unnamed,
    })
}

/// Remove identity-like nodes (Identity, Dropout) by rewiring consumers —
/// the ONNX GraphSurgeon cleanup the paper applies. Returns the cleaned
/// graph and the number of nodes removed.
pub fn eliminate_identities(graph: &Graph) -> Result<(Graph, usize)> {
    let outputs = graph.outputs();
    let mut out = Graph::new(&graph.name);
    let mut remap: Vec<NodeId> = Vec::with_capacity(graph.len());
    let mut removed = 0usize;
    for node in &graph.nodes {
        if node.kind.is_identity_like() && node.inputs.len() == 1 && !outputs.contains(&node.id) {
            removed += 1;
            remap.push(remap[node.inputs[0]]);
            continue;
        }
        let new_inputs: Vec<NodeId> = node.inputs.iter().map(|&i| remap[i]).collect();
        let id = out.add(&node.name, node.kind.clone(), &new_inputs)?;
        remap.push(id);
    }
    out.validate()?;
    Ok((out, removed))
}

/// Dead-node elimination: drop nodes not reachable from any output.
pub fn eliminate_dead(graph: &Graph) -> Result<(Graph, usize)> {
    let mut live = vec![false; graph.len()];
    let mut stack = graph.outputs();
    while let Some(id) = stack.pop() {
        if live[id] {
            continue;
        }
        live[id] = true;
        stack.extend(graph.nodes[id].inputs.iter().copied());
    }
    // Inputs are always considered live (they are interface contracts).
    for id in graph.inputs() {
        live[id] = true;
    }
    let mut out = Graph::new(&graph.name);
    let mut remap: Vec<Option<NodeId>> = vec![None; graph.len()];
    let mut removed = 0usize;
    for node in &graph.nodes {
        if !live[node.id] {
            removed += 1;
            continue;
        }
        let new_inputs: Vec<NodeId> = node
            .inputs
            .iter()
            .map(|&i| remap[i].expect("live node depends on dead node"))
            .collect();
        let id = out.add(&node.name, node.kind.clone(), &new_inputs)?;
        remap[node.id] = Some(id);
    }
    out.validate()?;
    Ok((out, removed))
}

/// Apply the full variant pipeline the paper describes: padding surgery
/// (if the variant requires it) followed by GraphSurgeon cleanup.
pub fn apply_variant(graph: &Graph, variant: GanVariant) -> Result<Graph> {
    match PaddingFix::for_variant(variant) {
        None => Ok(graph.clone()),
        Some(fix) => {
            let report = replace_deconv_padding(graph, fix, true)?;
            if report.deconvs_fixed == 0 {
                return Err(Error::Graph(format!(
                    "variant {} requested but `{}` has no padded deconvs",
                    variant.name(),
                    graph.name
                )));
            }
            let (clean, removed) = eliminate_identities(&report.graph)?;
            // The cleanup removes at least the inserted export artifacts
            // (plus any inference-time no-ops like Dropout).
            debug_assert!(removed >= report.unnamed_inserted);
            Ok(clean)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::shape::{DType, Shape};

    /// input -> deconv(p=1) -> tanh -> output
    fn deconv_graph() -> Graph {
        let mut g = Graph::new("dg");
        let x = g
            .add(
                "x",
                LayerKind::Input {
                    shape: Shape::new(8, 8, 8, DType::F16),
                },
                &[],
            )
            .unwrap();
        let d = g
            .add("deconv", LayerKind::deconv(4, 4, 2, 1), &[x])
            .unwrap();
        let t = g.add("tanh", LayerKind::Tanh, &[d]).unwrap();
        g.add("out", LayerKind::Output, &[t]).unwrap();
        g
    }

    #[test]
    fn crop_fix_preserves_output_shape() {
        let g = deconv_graph();
        let before = g.node(g.outputs()[0]).shape;
        let rep = replace_deconv_padding(&g, PaddingFix::Crop, false).unwrap();
        assert_eq!(rep.deconvs_fixed, 1);
        let after = rep.graph.node(rep.graph.outputs()[0]).shape;
        assert_eq!(before, after, "surgery must preserve the model interface");
        // No padded deconv remains.
        assert!(!rep.graph.nodes.iter().any(|n| matches!(
            n.kind,
            LayerKind::ConvTranspose2d { padding, .. } if padding > 0
        )));
    }

    #[test]
    fn conv_fix_preserves_shape_and_adds_params() {
        let g = deconv_graph();
        let p0 = g.param_count();
        let rep = replace_deconv_padding(&g, PaddingFix::Conv, false).unwrap();
        let after = rep.graph.node(rep.graph.outputs()[0]).shape;
        assert_eq!(after, g.node(g.outputs()[0]).shape);
        assert!(
            rep.graph.param_count() > p0,
            "conv substitution adds parameters (paper Table II)"
        );
    }

    #[test]
    fn crop_fix_preserves_param_count() {
        // Paper Table II: cropping variant has *identical* parameter count.
        let g = deconv_graph();
        let rep = replace_deconv_padding(&g, PaddingFix::Crop, false).unwrap();
        assert_eq!(rep.graph.param_count(), g.param_count());
    }

    #[test]
    fn export_artifacts_inserted_then_removed() {
        let g = deconv_graph();
        let rep = replace_deconv_padding(&g, PaddingFix::Crop, true).unwrap();
        assert_eq!(rep.unnamed_inserted, 1);
        let ids_before = rep.graph.len();
        let (clean, removed) = eliminate_identities(&rep.graph).unwrap();
        assert_eq!(removed, 1);
        assert_eq!(clean.len(), ids_before - 1);
        clean.validate().unwrap();
    }

    #[test]
    fn apply_variant_original_is_clone() {
        let g = deconv_graph();
        let v = apply_variant(&g, GanVariant::Original).unwrap();
        assert_eq!(v.len(), g.len());
    }

    #[test]
    fn apply_variant_errors_without_deconvs() {
        let mut g = Graph::new("plain");
        let x = g
            .add(
                "x",
                LayerKind::Input {
                    shape: Shape::new(1, 8, 8, DType::F16),
                },
                &[],
            )
            .unwrap();
        g.add("relu", LayerKind::ReLU, &[x]).unwrap();
        assert!(apply_variant(&g, GanVariant::Cropping).is_err());
    }

    #[test]
    fn dead_elimination() {
        let mut g = deconv_graph();
        // Unconsumed branch; the graph has an explicit Output marker, so
        // this node is genuinely dead.
        g.add("dead_relu", LayerKind::ReLU, &[0]).unwrap();
        let (clean, removed) = eliminate_dead(&g).unwrap();
        assert_eq!(removed, 1);
        assert_eq!(clean.len(), g.len() - 1);
    }

    #[test]
    fn dead_elimination_with_explicit_outputs() {
        let mut g = Graph::new("dg2");
        let x = g
            .add(
                "x",
                LayerKind::Input {
                    shape: Shape::new(4, 8, 8, DType::F16),
                },
                &[],
            )
            .unwrap();
        let a = g.add("a", LayerKind::ReLU, &[x]).unwrap();
        let _dead = g.add("b_dead", LayerKind::Tanh, &[x]).unwrap();
        let _dead2 = g.add("c_dead", LayerKind::Sigmoid, &[2]).unwrap();
        g.add("out", LayerKind::Output, &[a]).unwrap();
        let (clean, removed) = eliminate_dead(&g).unwrap();
        assert_eq!(removed, 2);
        clean.validate().unwrap();
    }
}
