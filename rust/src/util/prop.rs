//! Minimal property-based testing harness.
//!
//! `proptest` is not available in the offline vendor set, so this module
//! provides the small subset the test suite needs: run a property over many
//! seeded random cases and, on failure, report the seed so the case can be
//! replayed deterministically.

use super::rng::Rng;

/// Number of cases per property (overridable via `EDGEPIPE_PROP_CASES`).
pub fn default_cases() -> u32 {
    std::env::var("EDGEPIPE_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` against `cases` seeded RNGs; panic with the failing seed on
/// the first violated case.
pub fn check_with<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, cases: u32, mut prop: F) {
    for case in 0..cases {
        let seed = 0xE06E_0000_0000_0000u64 ^ (case as u64).wrapping_mul(0x9E37_79B9);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Run with the default number of cases.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(name: &str, prop: F) {
    check_with(name, default_cases(), prop)
}

/// Convenience assertion for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check_with("count", 10, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property `fail`")]
    fn failing_property_panics_with_seed() {
        check_with("fail", 10, |r| {
            let x = r.below(100);
            prop_assert!(x < 50, "x={x} not < 50");
            Ok(())
        });
    }
}
