//! Small self-contained utilities.
//!
//! The build environment is offline and the vendored crate set does not
//! include `rand`, `proptest` or a stats crate, so this module provides the
//! minimal substrates the rest of the library needs: a deterministic PRNG
//! ([`rng::Rng`]), summary statistics ([`stats`]), a tiny property-testing
//! harness ([`prop`]) used by the test suite, scoped-thread data-parallel
//! helpers ([`parallel`]), a fast deterministic hasher ([`hash`]), and
//! poison-tolerant mutex helpers for the serving path ([`lock`]).

pub mod hash;
pub mod lock;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod stats;
