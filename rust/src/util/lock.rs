//! Poison-tolerant mutex helpers for the serving hot path.
//!
//! A poisoned `Mutex` means some thread panicked while holding the
//! guard. For the serving stack the right response is to keep serving
//! with whatever state the lock protects — counters may under-count one
//! frame, a telemetry ring may hold a torn entry — rather than to
//! cascade the panic into every worker that touches the same lock
//! (`lock().unwrap()` turns one panicked worker into a dead pipeline,
//! and inside a `Drop` impl it aborts the whole process). All counter
//! and telemetry state here is monotonic or ring-buffered, so a torn
//! write degrades one sample, never the serving loop.
//!
//! `edgepipe-lint`'s `panic-freedom` rule bans bare `lock().unwrap()`
//! in `pipeline/`, `serve/` and `fleet/`; these helpers are the
//! sanctioned replacement.

use std::sync::{Condvar, Mutex, MutexGuard};

/// Acquire `m`, recovering the guard if the mutex is poisoned.
///
/// Equivalent to `m.lock().unwrap()` on the happy path; on poison it
/// takes the inner guard and keeps going instead of panicking.
#[inline]
pub fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// `Condvar::wait` with the same poison-recovery policy as [`relock`].
#[inline]
pub fn cv_wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn relock_happy_path() {
        let m = Mutex::new(7);
        *relock(&m) += 1;
        assert_eq!(*relock(&m), 8);
    }

    #[test]
    fn relock_recovers_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        *relock(&m) += 1;
        assert_eq!(*relock(&m), 42, "state survives the poisoning thread");
    }

    #[test]
    fn cv_wait_roundtrip() {
        use std::sync::Condvar;
        use std::time::Duration;
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let (m, cv) = &*p2;
            *m.lock().unwrap() = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut done = relock(m);
        while !*done {
            done = cv_wait(cv, done);
        }
        t.join().unwrap();
        assert!(*done);
    }
}
