//! Deterministic fast hashing for hot-path maps.
//!
//! `std`'s default SipHash is DoS-resistant but costs real time on the
//! per-symbol / per-arrival paths (LZW dictionary probes, fleet routing
//! overrides) where keys are small integers under our own control. This
//! SplitMix64-based hasher is a few cycles per probe, deterministic across
//! runs and platforms, and well mixed for integer keys.

use std::hash::{BuildHasherDefault, Hasher};

/// SplitMix64 finalizer: cheap, well-mixed 64-bit integer hash.
#[inline]
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// [`Hasher`] over [`mix64`]. Intended for small integer keys; byte slices
/// are folded 8 bytes at a time.
#[derive(Default)]
pub struct Mix64Hasher {
    state: u64,
}

impl Hasher for Mix64Hasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut b = [0u8; 8];
            b[..chunk.len()].copy_from_slice(chunk);
            self.state = mix64(self.state ^ u64::from_le_bytes(b));
        }
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.state = mix64(self.state ^ u64::from(i));
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.state = mix64(self.state ^ i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.state = mix64(self.state ^ i as u64);
    }
}

/// `BuildHasher` for `HashMap<_, _, BuildMix64>`.
pub type BuildMix64 = BuildHasherDefault<Mix64Hasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn mix64_is_deterministic_and_spreads() {
        assert_eq!(mix64(0), mix64(0));
        assert_ne!(mix64(0), mix64(1));
        // low bits of consecutive keys should differ (used for bucketing)
        let buckets: std::collections::HashSet<u64> =
            (0..64u64).map(|i| mix64(i) % 64).collect();
        assert!(buckets.len() > 32, "poor low-bit spread: {}", buckets.len());
    }

    #[test]
    fn map_with_mix64_round_trips() {
        let mut m: HashMap<u32, u32, BuildMix64> = HashMap::default();
        for i in 0..1000u32 {
            m.insert(i, i * 7);
        }
        for i in 0..1000u32 {
            assert_eq!(m.get(&i), Some(&(i * 7)));
        }
    }
}
