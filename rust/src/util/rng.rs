//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through SplitMix64 — the standard construction for
//! fast, high-quality, reproducible streams. All randomness in the library
//! (phantom generation, workload jitter, property tests) flows through this
//! type so every experiment is seed-reproducible.

/// A `xoshiro256**` PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 step — used to expand a single seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection method (unbiased).
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a uniformly random element of `xs` (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_i64_inclusive() {
        let mut r = Rng::new(5);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            hit_lo |= v == -3;
            hit_hi |= v == 3;
        }
        assert!(hit_lo && hit_hi);
    }
}
