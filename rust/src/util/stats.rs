//! Summary statistics for latency/throughput measurements.

/// Online accumulator for scalar samples (Welford's algorithm) plus a
/// retained sample buffer for exact percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one sample.
    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            (self.m2 / (self.samples.len() as f64 - 1.0)).sqrt()
        }
    }

    /// Smallest sample; `0.0` when empty (consistent with [`Self::mean`],
    /// and keeps empty accumulators out of JSON as `±inf`).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample; `0.0` when empty (see [`Self::min`]).
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Exact percentile by nearest-rank (`q` in `[0,100]`).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        v[rank.min(v.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Harmonic-mean style throughput: total units / total seconds.
pub fn throughput(units: usize, total_seconds: f64) -> f64 {
    if total_seconds <= 0.0 {
        0.0
    } else {
        units as f64 / total_seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.count(), 8);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for x in 1..=99 {
            s.add(x as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 99.0);
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.p99(), 0.0);
        // min/max must agree with mean's empty-case convention: finite
        // zero, never ±inf (which would leak into report JSON)
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn throughput_basic() {
        assert!((throughput(150, 1.0) - 150.0).abs() < 1e-12);
        assert_eq!(throughput(10, 0.0), 0.0);
    }
}
