//! Minimal data-parallel helpers over scoped std threads.
//!
//! The vendored crate set has no `rayon`, so the imaging kernels get their
//! row/band parallelism from this module instead: disjoint `&mut` bands are
//! handed to `std::thread::scope` workers. With the `parallel` feature
//! disabled (or `EDGEPIPE_THREADS=1`) every helper degenerates to the plain
//! serial loop, so single-threaded determinism is preserved exactly.
//!
//! Guarantees:
//! - [`par_chunks_mut`] / [`par_chunks2_mut`] write each chunk exactly once
//!   from exactly one thread; per-chunk outputs are bit-identical to the
//!   serial order regardless of thread count.
//! - [`par_fold`] folds band partials **in band-index order**, so a given
//!   thread count always produces the same result; only the band split
//!   (thread count) can move floating-point rounding around.

use std::ops::Range;

/// Elements below this threshold are not worth a thread spawn.
const PAR_MIN_ELEMS: usize = 16 * 1024;

/// Worker-thread budget: `EDGEPIPE_THREADS` if set, else the machine's
/// available parallelism. Always 1 when the `parallel` feature is off.
pub fn max_threads() -> usize {
    #[cfg(not(feature = "parallel"))]
    {
        1
    }
    #[cfg(feature = "parallel")]
    {
        static THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
        *THREADS.get_or_init(|| {
            std::env::var("EDGEPIPE_THREADS")
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism()
                        .map(std::num::NonZeroUsize::get)
                        .unwrap_or(1)
                })
        })
    }
}

/// Split `0..n_chunks` into at most `threads` contiguous bands, each a whole
/// number of chunks.
fn band_len(n_chunks: usize, threads: usize) -> usize {
    n_chunks.div_ceil(threads)
}

/// Apply `f(chunk_index, chunk)` to every `chunk_len`-sized chunk of `data`
/// (the last chunk may be shorter), fanning bands of chunks out across
/// threads. Falls back to the serial loop for small inputs or one thread.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = max_threads().min(n_chunks);
    if threads <= 1 || data.len() < PAR_MIN_ELEMS {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let per_band = band_len(n_chunks, threads) * chunk_len;
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = data;
        let mut first_chunk = 0usize;
        while !rest.is_empty() {
            let take = per_band.min(rest.len());
            let (band, tail) = rest.split_at_mut(take);
            rest = tail;
            let base = first_chunk;
            s.spawn(move || {
                for (i, c) in band.chunks_mut(chunk_len).enumerate() {
                    f(base + i, c);
                }
            });
            first_chunk += take / chunk_len + usize::from(take % chunk_len != 0);
        }
    });
}

/// Two-slice variant of [`par_chunks_mut`]: `a` and `b` are chunked in
/// lockstep (`a` by `chunk_a`, `b` by `chunk_b`; both must yield the same
/// number of chunks) and `f(chunk_index, a_chunk, b_chunk)` runs once per
/// pair. Used where a kernel fills two parallel outputs (e.g. Sobel
/// magnitude + direction).
pub fn par_chunks2_mut<A, B, F>(a: &mut [A], b: &mut [B], chunk_a: usize, chunk_b: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(chunk_a > 0 && chunk_b > 0, "chunk lengths must be positive");
    let n_chunks = a.len().div_ceil(chunk_a);
    assert_eq!(
        n_chunks,
        b.len().div_ceil(chunk_b),
        "slices must split into the same number of chunks"
    );
    let threads = max_threads().min(n_chunks);
    if threads <= 1 || a.len() + b.len() < PAR_MIN_ELEMS {
        for (i, (ca, cb)) in a.chunks_mut(chunk_a).zip(b.chunks_mut(chunk_b)).enumerate() {
            f(i, ca, cb);
        }
        return;
    }
    let chunks_per_band = band_len(n_chunks, threads);
    std::thread::scope(|s| {
        let f = &f;
        let (mut rest_a, mut rest_b) = (a, b);
        let mut first_chunk = 0usize;
        while !rest_a.is_empty() {
            let take_a = (chunks_per_band * chunk_a).min(rest_a.len());
            let take_b = (chunks_per_band * chunk_b).min(rest_b.len());
            let (band_a, tail_a) = rest_a.split_at_mut(take_a);
            let (band_b, tail_b) = rest_b.split_at_mut(take_b);
            rest_a = tail_a;
            rest_b = tail_b;
            let base = first_chunk;
            s.spawn(move || {
                for (i, (ca, cb)) in band_a
                    .chunks_mut(chunk_a)
                    .zip(band_b.chunks_mut(chunk_b))
                    .enumerate()
                {
                    f(base + i, ca, cb);
                }
            });
            first_chunk += take_a / chunk_a + usize::from(take_a % chunk_a != 0);
        }
    });
}

/// Map contiguous index bands of `0..n` to partial results and fold them in
/// band order. `map_band` sees a whole `Range` so it can keep one local
/// accumulator (e.g. a histogram) per band; `min_items` gates the spawn so
/// trivial inputs stay serial (where the result is `map_band(0..n)` exactly).
pub fn par_fold<R, M, FD>(n: usize, min_items: usize, map_band: M, fold: FD) -> Option<R>
where
    R: Send,
    M: Fn(Range<usize>) -> R + Sync,
    FD: Fn(R, R) -> R,
{
    if n == 0 {
        return None;
    }
    let threads = max_threads().min(n);
    if threads <= 1 || n < min_items {
        return Some(map_band(0..n));
    }
    let per = band_len(n, threads);
    let mut partials: Vec<R> = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let map_band = &map_band;
        let mut handles = Vec::with_capacity(threads);
        let mut start = 0usize;
        while start < n {
            let end = (start + per).min(n);
            handles.push(s.spawn(move || map_band(start..end)));
            start = end;
        }
        for h in handles {
            partials.push(h.join().expect("parallel fold worker panicked"));
        }
    });
    let mut it = partials.into_iter();
    let mut acc = it.next()?;
    for p in it {
        acc = fold(acc, p);
    }
    Some(acc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_all_indices() {
        // Large enough to actually spawn when the feature is on.
        let mut data = vec![0u32; 64 * 1024 + 7];
        par_chunks_mut(&mut data, 100, |i, c| {
            for (j, v) in c.iter_mut().enumerate() {
                *v = (i * 100 + j) as u32;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v as usize, i);
        }
    }

    #[test]
    fn chunks_small_input_serial() {
        let mut data = vec![0u8; 10];
        par_chunks_mut(&mut data, 3, |i, c| c.iter_mut().for_each(|v| *v = i as u8));
        assert_eq!(data, [0, 0, 0, 1, 1, 1, 2, 2, 2, 3]);
    }

    #[test]
    fn two_slice_lockstep() {
        let w = 512;
        let h = 64;
        let mut a = vec![0u32; w * h];
        let mut b = vec![0u32; w * h / 2];
        par_chunks2_mut(&mut a, &mut b, w, w / 2, |row, ca, cb| {
            ca.iter_mut().for_each(|v| *v = row as u32);
            cb.iter_mut().for_each(|v| *v = row as u32 * 10);
        });
        for row in 0..h {
            assert!(a[row * w..(row + 1) * w].iter().all(|&v| v == row as u32));
            assert!(b[row * w / 2..(row + 1) * w / 2]
                .iter()
                .all(|&v| v == row as u32 * 10));
        }
    }

    #[test]
    fn fold_sums_exactly() {
        let n = 100_000usize;
        let got = par_fold(
            n,
            1,
            |band: Range<usize>| band.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        )
        .unwrap();
        assert_eq!(got, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn fold_empty_is_none() {
        assert_eq!(par_fold(0, 1, |_b| 0u64, |a, b| a + b), None);
    }

    #[test]
    fn fold_band_order_is_deterministic() {
        // Non-commutative fold: concatenation order must match band order.
        let got = par_fold(
            40_000,
            1,
            |band: Range<usize>| vec![band.start],
            |mut a, b| {
                a.extend(b);
                a
            },
        )
        .unwrap();
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(got, sorted, "band partials must fold in band order");
    }
}
