//! The six project-invariant rules.
//!
//! Every rule is a lexical pass over one file's token stream — no type
//! information, no cross-file analysis. That keeps the analyzer
//! dependency-free and fast, at the price of being *heuristic*: the
//! lock-discipline tracker, for instance, models guard lifetimes by
//! brace depth (a let-bound guard lives to the end of its block, a
//! temporary to the end of its statement) and cannot see a guard passed
//! across a function boundary. The rules are tuned so that everything
//! they flag is worth a human look, and anything intentional carries a
//! `// lint:allow(rule)` with a justification.

use super::hotpath::{
    self, CounterContract, COUNTER_CONTRACTS, COUNTER_TYPES, HOT_FNS,
};
use super::lexer::{LexOut, TokKind, Token};
use super::{Diagnostic, Rule};

/// Shared per-file context: emits diagnostics with allow-comment and
/// `#[cfg(test)]`-module filtering applied.
struct Ctx<'a> {
    rel: &'a str,
    lx: &'a LexOut,
    test_ranges: Vec<(u32, u32)>,
    diags: Vec<Diagnostic>,
}

impl Ctx<'_> {
    fn emit(&mut self, line: u32, rule: Rule, message: String) {
        if self
            .test_ranges
            .iter()
            .any(|&(a, b)| a <= line && line <= b)
        {
            return;
        }
        if self.lx.allowed(rule.name(), line) {
            return;
        }
        self.diags.push(Diagnostic {
            file: self.rel.to_string(),
            line,
            rule,
            message,
        });
    }
}

/// Run every rule over one lexed file. `rel` is the path relative to
/// the analyzed root (suffix-matched against the manifests).
pub fn run_all(rel: &str, lx: &LexOut) -> Vec<Diagnostic> {
    let mut ctx = Ctx {
        rel,
        lx,
        test_ranges: test_mod_ranges(&lx.tokens),
        diags: Vec::new(),
    };
    panic_freedom(&mut ctx);
    hot_fn_rules(&mut ctx);
    lock_discipline(&mut ctx);
    counter_conservation(&mut ctx);
    unit_suffix(&mut ctx);
    feature_hygiene(&mut ctx);
    ctx.diags
}

// ---------------------------------------------------------------- helpers

fn text<'t>(toks: &'t [Token], i: usize) -> &'t str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

/// Line ranges covered by `#[cfg(test)] mod … { … }` blocks.
fn test_mod_ranges(toks: &[Token]) -> Vec<(u32, u32)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i + 5 < toks.len() {
        let is_cfg_test = toks[i].is("#")
            && toks[i + 1].is("[")
            && toks[i + 2].is_ident("cfg")
            && toks[i + 3].is("(")
            && toks[i + 4].is_ident("test");
        if is_cfg_test {
            // close the attribute, skip any further attributes
            let mut j = i + 5;
            while j < toks.len() && !toks[j].is("]") {
                j += 1;
            }
            j += 1;
            while j + 1 < toks.len() && toks[j].is("#") && toks[j + 1].is("[") {
                while j < toks.len() && !toks[j].is("]") {
                    j += 1;
                }
                j += 1;
            }
            if j < toks.len() && toks[j].is_ident("mod") {
                while j < toks.len() && !toks[j].is("{") {
                    j += 1;
                }
                let start_line = toks.get(j).map(|t| t.line).unwrap_or(u32::MAX);
                let mut depth = 0i32;
                while j < toks.len() {
                    if toks[j].is("{") {
                        depth += 1;
                    } else if toks[j].is("}") {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    j += 1;
                }
                let end_line = toks.get(j).map(|t| t.line).unwrap_or(u32::MAX);
                ranges.push((start_line, end_line));
                i = j;
            }
        }
        i += 1;
    }
    ranges
}

/// Token-index range `(open_brace, close_brace)` of the body of `fn
/// name`, optionally restricted to an `impl <of> { … }` block.
fn fn_body_range(toks: &[Token], name: &str, impl_of: Option<&str>) -> Option<(usize, usize)> {
    let mut i = 0usize;
    let mut lim = toks.len();
    if let Some(ty) = impl_of {
        let mut found = false;
        while i + 2 < toks.len() {
            if toks[i].is_ident("impl") {
                let mut j = i + 1;
                let mut names_match = false;
                while j < toks.len() && !toks[j].is("{") {
                    if toks[j].kind == TokKind::Ident && toks[j].text == ty {
                        names_match = true;
                    }
                    j += 1;
                }
                if names_match {
                    let mut depth = 0i32;
                    let mut k = j;
                    while k < toks.len() {
                        if toks[k].is("{") {
                            depth += 1;
                        } else if toks[k].is("}") {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        k += 1;
                    }
                    i = j;
                    lim = k;
                    found = true;
                    break;
                }
            }
            i += 1;
        }
        if !found {
            return None;
        }
    }
    while i + 2 < lim {
        if toks[i].is_ident("fn") && toks[i + 1].text == name {
            // find the body's `{`: skip params / return type / generics
            let mut j = i + 2;
            let mut depth = 0i32;
            while j < lim {
                let t = text(toks, j);
                if t == "(" || t == "[" || t == "<" {
                    depth += 1;
                } else if t == ")" || t == "]" || t == ">" {
                    depth -= 1;
                } else if t == "{" && depth <= 0 {
                    break;
                }
                j += 1;
            }
            let start = j;
            let mut depth = 0i32;
            while j < lim {
                if toks[j].is("{") {
                    depth += 1;
                } else if toks[j].is("}") {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                j += 1;
            }
            return Some((start, j.min(lim)));
        }
        i += 1;
    }
    None
}

/// `(name, first type token, line)` of each field of `struct name`.
fn struct_fields(toks: &[Token], name: &str) -> Vec<(String, String, u32)> {
    let mut fields = Vec::new();
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if toks[i].is_ident("struct") && toks[i + 1].text == name {
            let mut j = i + 2;
            while j < toks.len() && !toks[j].is("{") && !toks[j].is(";") {
                j += 1;
            }
            if j >= toks.len() || toks[j].is(";") {
                return fields; // unit/tuple struct: no named fields
            }
            let mut depth = 0i32;
            let mut k = j;
            while k < toks.len() {
                let t = text(toks, k);
                if t == "{" {
                    depth += 1;
                } else if t == "}" {
                    depth -= 1;
                    if depth == 0 {
                        return fields;
                    }
                } else if depth == 1
                    && toks[k].kind == TokKind::Ident
                    && text(toks, k + 1) == ":"
                    && text(toks, k + 2) != ":"
                {
                    let fname = toks[k].text.clone();
                    if fname != "pub" && fname != "crate" {
                        fields.push((fname, toks[k + 2].text.clone(), toks[k].line));
                        // skip the type to the field-separating comma
                        let mut m = k + 2;
                        let mut d2 = 0i32;
                        while m < toks.len() {
                            let tt = text(toks, m);
                            if tt == "(" || tt == "[" || tt == "{" || tt == "<" {
                                d2 += 1;
                            } else if tt == ")" || tt == "]" || tt == "}" || tt == ">" {
                                d2 -= 1;
                            } else if tt == "," && d2 <= 0 {
                                break;
                            }
                            m += 1;
                        }
                        k = m;
                    }
                }
                k += 1;
            }
            return fields;
        }
        i += 1;
    }
    fields
}

// ------------------------------------------------------------------ rules

/// Rule 1 (module half): no `unwrap()` / `expect()` / panicking macros
/// in hot-path modules.
fn panic_freedom(ctx: &mut Ctx) {
    if !hotpath::is_hot(ctx.rel) {
        return;
    }
    let toks = &ctx.lx.tokens;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let prev_dot = i > 0 && toks[i - 1].is(".");
        let next_paren = text(toks, i + 1) == "(";
        if (t.text == "unwrap" || t.text == "expect") && prev_dot && next_paren {
            ctx.emit(
                t.line,
                Rule::PanicFreedom,
                format!("`{}()` in hot-path module (propagate or relock)", t.text),
            );
        }
        let next_bang = text(toks, i + 1) == "!";
        if next_bang
            && matches!(t.text.as_str(), "panic" | "unreachable" | "todo" | "unimplemented")
        {
            ctx.emit(
                t.line,
                Rule::PanicFreedom,
                format!("`{}!` in hot-path module", t.text),
            );
        }
    }
}

/// Rules 1 (indexing half) and 3: unchecked indexing and heap
/// allocation inside manifest per-frame functions.
fn hot_fn_rules(ctx: &mut Ctx) {
    let toks = &ctx.lx.tokens;
    for hf in HOT_FNS {
        if !ctx.rel.ends_with(hf.file) {
            continue;
        }
        let Some((a, b)) = fn_body_range(toks, hf.func, None) else {
            ctx.emit(
                1,
                Rule::HotPathAlloc,
                format!("manifest per-frame fn `{}` not found in {}", hf.func, hf.file),
            );
            continue;
        };
        for i in a..b {
            let t = &toks[i];
            let prev = i.checked_sub(1).and_then(|p| toks.get(p));
            let prev_text = prev.map(|p| p.text.as_str()).unwrap_or("");
            let prev_indexable = prev.map(|p| {
                p.kind == TokKind::Ident || p.text == ")" || p.text == "]"
            });
            if t.is("[") && prev_indexable == Some(true) {
                ctx.emit(
                    t.line,
                    Rule::PanicFreedom,
                    format!("indexing without `get()` in per-frame fn `{}`", hf.func),
                );
            }
            if t.kind != TokKind::Ident {
                continue;
            }
            let next = text(toks, i + 1);
            if prev_text == "."
                && next == "("
                && matches!(t.text.as_str(), "clone" | "to_vec" | "to_string" | "to_owned")
            {
                ctx.emit(
                    t.line,
                    Rule::HotPathAlloc,
                    format!("`.{}()` in per-frame fn `{}`", t.text, hf.func),
                );
            }
            if matches!(t.text.as_str(), "Vec" | "String" | "Box")
                && next == ":"
                && text(toks, i + 3) == "new"
            {
                ctx.emit(
                    t.line,
                    Rule::HotPathAlloc,
                    format!("`{}::new` in per-frame fn `{}`", t.text, hf.func),
                );
            }
            if matches!(t.text.as_str(), "format" | "vec") && next == "!" {
                ctx.emit(
                    t.line,
                    Rule::HotPathAlloc,
                    format!("`{}!` allocates in per-frame fn `{}`", t.text, hf.func),
                );
            }
        }
    }
}

/// One tracked lock guard for rule 2.
struct Guard {
    rank: u8,
    let_bound: bool,
    depth: i32,
    line: u32,
}

/// Rule 2: declared lock order, no nested acquisition out of rank, and
/// no guard held across `dispatch` / `execute_batch` outside the
/// arbiter itself. Guard lifetimes are lexical: a let-bound guard lives
/// to the end of its enclosing block, a temporary to the end of its
/// statement.
fn lock_discipline(ctx: &mut Ctx) {
    if !hotpath::is_hot(ctx.rel) {
        return;
    }
    let toks = &ctx.lx.tokens;
    let is_arbiter = ctx.rel.ends_with("pipeline/engines.rs");
    let mut held: Vec<Guard> = Vec::new();
    let mut depth = 0i32;
    let mut stmt_has_let = false;

    for i in 0..toks.len() {
        let t = &toks[i];
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                held.retain(|h| h.depth <= depth);
            }
            ";" => {
                stmt_has_let = false;
                held.retain(|h| h.let_bound);
            }
            "let" if t.kind == TokKind::Ident => stmt_has_let = true,
            _ => {}
        }

        let mut rank: Option<u8> = None;
        // receiver.lock(…) — classify by the receiver's field ident
        if t.is_ident("lock") && text(toks, i + 1) == "(" && i > 1 && toks[i - 1].is(".") {
            let recv = lock_receiver(toks, i - 2);
            match recv.and_then(|k| hotpath::lock_rank(&toks[k].text)) {
                Some(r) => rank = Some(r),
                None => {
                    let name = recv.map(|k| toks[k].text.clone()).unwrap_or_default();
                    ctx.emit(
                        t.line,
                        Rule::LockDiscipline,
                        format!(
                            "`.lock()` on receiver `{name}` not in the declared lock table \
                             (use util::lock::relock on a declared lock field)"
                        ),
                    );
                }
            }
        }
        // relock(&self.field) / cv_wait — classify by field ident in args
        if t.is_ident("relock") && text(toks, i + 1) == "(" {
            let mut j = i + 1;
            let mut d2 = 0i32;
            while j < toks.len() {
                let tt = text(toks, j);
                if tt == "(" {
                    d2 += 1;
                } else if tt == ")" {
                    d2 -= 1;
                    if d2 == 0 {
                        break;
                    }
                } else if toks[j].kind == TokKind::Ident {
                    if let Some(r) = hotpath::lock_rank(&toks[j].text) {
                        rank = Some(r);
                    }
                }
                j += 1;
            }
        }

        if let Some(r) = rank {
            if let Some(h) = held.iter().find(|h| h.rank >= r) {
                ctx.emit(
                    t.line,
                    Rule::LockDiscipline,
                    format!(
                        "acquiring rank-{r} lock while rank-{} guard from line {} is held \
                         (declared order: arbiter -> metrics -> pool -> telemetry)",
                        h.rank, h.line
                    ),
                );
            }
            held.push(Guard {
                rank: r,
                let_bound: stmt_has_let,
                depth,
                line: t.line,
            });
        }

        // no guard held across a dispatch boundary (the arbiter's own
        // dispatch body manages the unit lease itself)
        if !is_arbiter
            && t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "dispatch" | "execute_batch")
            && text(toks, i + 1) == "("
            && (i == 0 || !toks[i - 1].is_ident("fn"))
        {
            if let Some(h) = held.first() {
                ctx.emit(
                    t.line,
                    Rule::LockDiscipline,
                    format!(
                        "`{}()` called while the lock guard from line {} is held",
                        t.text, h.line
                    ),
                );
            }
        }
    }
}

/// Walk back from `k` (the token before `.lock`'s dot) over balanced
/// `[…]` / `(…)` and method chains to the receiver's field ident.
fn lock_receiver(toks: &[Token], mut k: usize) -> Option<usize> {
    loop {
        let t = text(toks, k);
        if t == "]" || t == ")" {
            let (close, open) = if t == "]" { ("]", "[") } else { (")", "(") };
            let mut d = 0i32;
            loop {
                let tt = text(toks, k);
                if tt == close {
                    d += 1;
                } else if tt == open {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                if k == 0 {
                    return None;
                }
                k -= 1;
            }
            if k == 0 {
                return None;
            }
            k -= 1; // token before the opener (the indexed/called expr)
            if text(toks, k) == "." && k > 0 {
                k -= 1; // hop over a method-chain dot
            }
        } else {
            break;
        }
    }
    (toks.get(k).map(|t| t.kind) == Some(TokKind::Ident)).then_some(k)
}

/// Rule 4: every numeric field of a contracted struct must be mentioned
/// in each of its declared writer functions.
fn counter_conservation(ctx: &mut Ctx) {
    let toks = &ctx.lx.tokens;
    for c in COUNTER_CONTRACTS {
        let CounterContract { file, strukt, writers } = c;
        if !ctx.rel.ends_with(file) {
            continue;
        }
        let fields: Vec<(String, u32)> = struct_fields(toks, strukt)
            .into_iter()
            .filter(|(_, ty, _)| COUNTER_TYPES.contains(&ty.as_str()))
            .map(|(f, _, l)| (f, l))
            .collect();
        for (wimpl, wfn) in *writers {
            let Some((a, b)) = fn_body_range(toks, wfn, Some(wimpl)) else {
                ctx.emit(
                    1,
                    Rule::CounterConservation,
                    format!("declared counter writer `{wimpl}::{wfn}` not found in {file}"),
                );
                continue;
            };
            for (f, line) in &fields {
                let mentioned = toks[a..b].iter().any(|t| {
                    (t.kind == TokKind::Ident || t.kind == TokKind::Str) && t.text == *f
                });
                if !mentioned {
                    ctx.emit(
                        *line,
                        Rule::CounterConservation,
                        format!("counter `{strukt}.{f}` is never written by `{wimpl}::{wfn}`"),
                    );
                }
            }
        }
    }
}

/// Unit class of an identifier per its `_ms` / `_ns` / `_us` / seconds
/// suffix segments.
fn unit_class(ident: &str) -> Option<&'static str> {
    let segs: Vec<&str> = ident.split('_').collect();
    if segs.len() < 2 {
        return None;
    }
    if segs.contains(&"ms") {
        return Some("ms");
    }
    if segs.contains(&"ns") {
        return Some("ns");
    }
    if segs.contains(&"us") {
        return Some("us");
    }
    match segs.last() {
        Some(&"s") | Some(&"secs") | Some(&"seconds") => Some("s"),
        _ => None,
    }
}

/// Rule 5: one statement mixing two unit suffixes without an explicit
/// conversion (a `*_per_*`/`to_*`/`from_*` call or a power-of-ten
/// literal) is a finding.
fn unit_suffix(ctx: &mut Ctx) {
    let toks = &ctx.lx.tokens;
    let mut stmt: Vec<usize> = Vec::new();
    for i in 0..=toks.len() {
        let boundary = i == toks.len()
            || matches!(toks[i].text.as_str(), ";" | "{" | "}" | ",");
        if !boundary {
            stmt.push(i);
            continue;
        }
        let mut classes: Vec<(&'static str, u32)> = Vec::new();
        let mut conversion = false;
        for &k in &stmt {
            let t = &toks[k];
            if t.kind == TokKind::Ident {
                if let Some(c) = unit_class(&t.text) {
                    if !classes.iter().any(|(cc, _)| *cc == c) {
                        classes.push((c, t.line));
                    }
                }
                let lower = t.text.to_lowercase();
                if lower
                    .split('_')
                    .any(|s| s == "per" || s == "to" || s == "from")
                {
                    conversion = true;
                }
            }
            if t.kind == TokKind::Num {
                let lit = t.text.to_lowercase().replace('_', "");
                if ["e3", "e6", "e9", "1000", "0.001", "e-3", "e-6", "e-9"]
                    .iter()
                    .any(|p| lit.contains(p))
                {
                    conversion = true;
                }
            }
        }
        if classes.len() > 1 && !conversion {
            let line = classes.iter().map(|(_, l)| *l).min().unwrap_or(1);
            let names: Vec<&str> = classes.iter().map(|(c, _)| *c).collect();
            ctx.emit(
                line,
                Rule::UnitSuffix,
                format!(
                    "statement mixes units [{}] without an explicit conversion",
                    names.join(", ")
                ),
            );
        }
        stmt.clear();
    }
}

/// Rule 6: `#[cfg(feature = "parallel")]` code needs a
/// `#[cfg(not(feature = "parallel"))]` serial counterpart in the same
/// file.
fn feature_hygiene(ctx: &mut Ctx) {
    let toks = &ctx.lx.tokens;
    let mut first_positive: Option<u32> = None;
    let mut has_negative = false;
    for i in 0..toks.len() {
        if !toks[i].is_ident("cfg") || text(toks, i + 1) != "(" {
            continue;
        }
        let window = &toks[i..toks.len().min(i + 10)];
        let has_feature = window.iter().any(|t| t.is_ident("feature"));
        let has_parallel = window
            .iter()
            .any(|t| t.kind == TokKind::Str && t.text == "parallel");
        if has_feature && has_parallel {
            if window.iter().any(|t| t.is_ident("not")) {
                has_negative = true;
            } else if first_positive.is_none() {
                first_positive = Some(toks[i].line);
            }
        }
    }
    if let Some(line) = first_positive {
        if !has_negative {
            ctx.emit(
                line,
                Rule::FeatureHygiene,
                "#[cfg(feature = \"parallel\")] without a serial \
                 #[cfg(not(feature = \"parallel\"))] counterpart in this file"
                    .to_string(),
            );
        }
    }
}
