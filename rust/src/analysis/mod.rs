//! `edgepipe-lint`: a dependency-free static analyzer enforcing the
//! project's serving-path invariants over the crate's own source.
//!
//! The paper's headline claims (~150 FPS real-time serving, no GPU
//! fallback) rest on invariants the type system cannot express: the
//! per-frame loop never panics or allocates, locks are acquired in one
//! global order, every counter a struct grows reaches the JSON report,
//! model-time and wall-clock values never mix silently, and every
//! `parallel` code path has a serial twin. This module machine-checks
//! them with six lexical rules (see [`Rule`]) over a token scan of
//! `rust/src` ([`lexer`]), driven by checked-in manifests ([`hotpath`])
//! and run in CI via `cargo run --bin lint -- rust/src` (exit code 1 on
//! any finding).
//!
//! Intentional exceptions carry an inline escape hatch — a comment
//! `// lint:allow(rule-name)` on the offending line or the line above,
//! with a justification — so the clean-run requirement stays meaningful.

pub mod hotpath;
pub mod lexer;
pub mod rules;

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

/// The six enforced invariants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// No `unwrap`/`expect`/panicking macros in hot-path modules; no
    /// unchecked indexing in manifest per-frame functions.
    PanicFreedom,
    /// Locks acquired in the declared global order; no guard held
    /// across `dispatch`/`execute_batch` outside the arbiter.
    LockDiscipline,
    /// No heap allocation (`clone`, `to_vec`, `Vec::new`, `format!`,
    /// `vec!`) in manifest per-frame functions.
    HotPathAlloc,
    /// Every numeric counter field of a contracted struct appears in
    /// its JSON/snapshot writers.
    CounterConservation,
    /// No statement mixes `_ms`/`_ns`/`_us`/seconds idents without an
    /// explicit conversion.
    UnitSuffix,
    /// `#[cfg(feature = "parallel")]` requires a serial counterpart in
    /// the same file.
    FeatureHygiene,
}

impl Rule {
    /// The kebab-case name used in diagnostics and `lint:allow(...)`.
    pub fn name(&self) -> &'static str {
        match self {
            Rule::PanicFreedom => "panic-freedom",
            Rule::LockDiscipline => "lock-discipline",
            Rule::HotPathAlloc => "hot-path-alloc",
            Rule::CounterConservation => "counter-conservation",
            Rule::UnitSuffix => "unit-suffix",
            Rule::FeatureHygiene => "feature-hygiene",
        }
    }

    pub fn all() -> &'static [Rule] {
        &[
            Rule::PanicFreedom,
            Rule::LockDiscipline,
            Rule::HotPathAlloc,
            Rule::CounterConservation,
            Rule::UnitSuffix,
            Rule::FeatureHygiene,
        ]
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One finding: `file:line: [rule] message`.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub file: String,
    pub line: u32,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Run every rule over one file's source. `rel` is the path relative to
/// the analyzed root; the manifests in [`hotpath`] suffix-match it.
pub fn analyze_source(rel: &str, src: &str) -> Vec<Diagnostic> {
    let lexed = lexer::lex(src);
    rules::run_all(rel, &lexed)
}

/// Walk `root` (deterministic order), analyze every `.rs` file, and
/// collect the findings sorted by file, line, then rule name.
pub fn analyze_tree(root: &Path) -> io::Result<Vec<Diagnostic>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut diags = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path)?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        diags.extend(analyze_source(&rel, &src));
    }
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.name()).cmp(&(b.file.as_str(), b.line, b.rule.name()))
    });
    Ok(diags)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    if dir.is_file() {
        if dir.extension().is_some_and(|e| e == "rs") {
            out.push(dir.to_path_buf());
        }
        return Ok(());
    }
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_names_round_trip_and_are_unique() {
        let names: Vec<&str> = Rule::all().iter().map(|r| r.name()).collect();
        let mut deduped = names.clone();
        deduped.sort();
        deduped.dedup();
        assert_eq!(deduped.len(), names.len());
        assert_eq!(Rule::all().len(), 6);
    }

    #[test]
    fn diagnostics_format_as_file_line_rule() {
        let d = Diagnostic {
            file: "serve/mod.rs".into(),
            line: 42,
            rule: Rule::PanicFreedom,
            message: "boom".into(),
        };
        assert_eq!(d.to_string(), "serve/mod.rs:42: [panic-freedom] boom");
    }
}
