//! A minimal Rust token scanner for [`crate::analysis`].
//!
//! This is not a parser: the lint rules work on flat token streams with
//! line numbers, which is enough to recognize `ident . lock (` shapes,
//! `struct` field lists, and `fn` body ranges. The scanner's one real
//! job is to never misclassify source: comments (line and nested block),
//! string literals (escaped and raw, `r#"…"#`), char literals and
//! lifetimes are consumed so that a `panic!` inside a doc string or a
//! `.lock()` in a comment never produces a token.
//!
//! `// lint:allow(rule-a, rule-b)` comments are collected during the
//! scan and suppress those rules on the comment's own line and the line
//! below it (so the directive can sit above the offending statement).

/// Token classes the rules distinguish.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Num,
    Str,
    CharLit,
    Lifetime,
}

/// One source token with its 1-based line number.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Token {
    /// Exact-text match (any kind).
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }

    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == TokKind::Ident && self.text == text
    }
}

/// Scan output: the token stream plus every `lint:allow` directive as
/// `(line, rule-name)` pairs.
#[derive(Debug, Default)]
pub struct LexOut {
    pub tokens: Vec<Token>,
    pub allows: Vec<(u32, String)>,
}

impl LexOut {
    /// Is `rule` allowed at `line` (directive on this line or the one
    /// above)?
    pub fn allowed(&self, rule: &str, line: u32) -> bool {
        self.allows
            .iter()
            .any(|(l, r)| r == rule && (*l == line || *l + 1 == line))
    }
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Parse `lint:allow(a, b)` directives out of one comment body.
fn collect_allows(body: &str, line: u32, allows: &mut Vec<(u32, String)>) {
    let Some(pos) = body.find("lint:allow(") else {
        return;
    };
    let rest = &body[pos + "lint:allow(".len()..];
    let Some(end) = rest.find(')') else {
        return;
    };
    for rule in rest[..end].split(',') {
        let rule = rule.trim();
        if !rule.is_empty() {
            allows.push((line, rule.to_string()));
        }
    }
}

/// Tokenize `src`. Never fails: unterminated constructs consume to EOF.
pub fn lex(src: &str) -> LexOut {
    let b = src.as_bytes();
    let n = b.len();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut out = LexOut::default();

    while i < n {
        let c = b[i];
        // whitespace
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let mut j = i;
            while j < n && b[j] != b'\n' {
                j += 1;
            }
            collect_allows(&src[i..j], line, &mut out.allows);
            i = j;
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == b'/' && j + 1 < n && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < n && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // raw string: r"…", r#"…"#, br#"…"# (byte-raw)
        if c == b'r' || c == b'b' {
            let mut j = i;
            if b[j] == b'b' {
                j += 1;
            }
            if j < n && b[j] == b'r' {
                j += 1;
                let hash_start = j;
                while j < n && b[j] == b'#' {
                    j += 1;
                }
                let hashes = j - hash_start;
                if j < n && b[j] == b'"' {
                    j += 1;
                    let body_start = j;
                    // find `"` followed by `hashes` hash marks
                    'scan: while j < n {
                        if b[j] == b'"' {
                            let mut k = j + 1;
                            let mut seen = 0usize;
                            while k < n && b[k] == b'#' && seen < hashes {
                                k += 1;
                                seen += 1;
                            }
                            if seen == hashes {
                                break 'scan;
                            }
                        }
                        j += 1;
                    }
                    let body = &src[body_start..j.min(n)];
                    let start_line = line;
                    line += body.matches('\n').count() as u32;
                    out.tokens.push(Token {
                        kind: TokKind::Str,
                        text: body.to_string(),
                        line: start_line,
                    });
                    i = (j + 1 + hashes).min(n);
                    continue;
                }
            }
            // not a raw string: fall through to ident handling below
        }
        // string literal
        if c == b'"' {
            let start_line = line;
            let mut j = i + 1;
            let mut body = String::new();
            while j < n {
                if b[j] == b'\\' && j + 1 < n {
                    body.push(b[j + 1] as char);
                    j += 2;
                    continue;
                }
                if b[j] == b'"' {
                    break;
                }
                if b[j] == b'\n' {
                    line += 1;
                }
                body.push(b[j] as char);
                j += 1;
            }
            out.tokens.push(Token {
                kind: TokKind::Str,
                text: body,
                line: start_line,
            });
            i = j + 1;
            continue;
        }
        // char literal or lifetime
        if c == b'\'' {
            let mut j = i + 1;
            if j < n && is_ident_start(b[j]) {
                let mut k = j;
                while k < n && is_ident_cont(b[k]) {
                    k += 1;
                }
                if k >= n || b[k] != b'\'' {
                    out.tokens.push(Token {
                        kind: TokKind::Lifetime,
                        text: src[j..k].to_string(),
                        line,
                    });
                    i = k;
                    continue;
                }
            }
            // char literal: 'x', '\n', '\'', '\\'
            if j < n && b[j] == b'\\' {
                j += 2;
            } else {
                j += 1;
            }
            while j < n && b[j] != b'\'' {
                j += 1;
            }
            out.tokens.push(Token {
                kind: TokKind::CharLit,
                text: src[(i + 1).min(j)..j.min(n)].to_string(),
                line,
            });
            i = j + 1;
            continue;
        }
        // identifier / keyword
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            out.tokens.push(Token {
                kind: TokKind::Ident,
                text: src[i..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        // number (suffixes and `1.5`/`1e-3` folded into one token)
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i + 1;
            while j < n {
                let d = b[j];
                if is_ident_cont(d) {
                    j += 1;
                } else if d == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                    j += 1;
                } else if (d == b'+' || d == b'-')
                    && (b[j - 1] == b'e' || b[j - 1] == b'E')
                    && !src[start..j].starts_with("0x")
                {
                    j += 1;
                } else {
                    break;
                }
            }
            out.tokens.push(Token {
                kind: TokKind::Num,
                text: src[start..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        // single-char punctuation
        out.tokens.push(Token {
            kind: TokKind::Punct,
            text: (c as char).to_string(),
            line,
        });
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).tokens.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_and_strings_produce_no_code_tokens() {
        let src = r##"
            // a .lock() in a comment
            /* panic! in /* a nested */ block */
            let s = "unwrap() in a string";
            let r = r#"expect() in a raw "string""#;
        "##;
        let toks = lex(src);
        assert!(!toks.tokens.iter().any(|t| t.kind == TokKind::Ident
            && (t.text == "lock" || t.text == "panic" || t.text == "unwrap" || t.text == "expect")));
        // but the string bodies are retained as Str tokens
        assert_eq!(
            toks.tokens.iter().filter(|t| t.kind == TokKind::Str).count(),
            2
        );
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lifetimes: Vec<_> = toks
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .collect();
        assert_eq!(lifetimes.len(), 2);
        assert!(toks
            .tokens
            .iter()
            .any(|t| t.kind == TokKind::CharLit && t.text == "x"));
    }

    #[test]
    fn line_numbers_track_newlines_everywhere() {
        let src = "a\n\"two\nline\"\nb";
        let toks = lex(src);
        assert_eq!(toks.tokens[0].line, 1);
        assert_eq!(toks.tokens[1].line, 2); // string starts on line 2
        assert_eq!(toks.tokens[2].line, 4); // b after the 2-line string
    }

    #[test]
    fn allow_directives_are_collected_with_lines() {
        let src = "// lint:allow(panic-freedom, lock-discipline)\nx.unwrap();\n";
        let toks = lex(src);
        assert!(toks.allowed("panic-freedom", 1));
        assert!(toks.allowed("panic-freedom", 2), "next line is covered");
        assert!(!toks.allowed("panic-freedom", 3));
        assert!(toks.allowed("lock-discipline", 2));
        assert!(!toks.allowed("hot-path-alloc", 2));
    }

    #[test]
    fn numbers_keep_exponents_and_suffixes_together() {
        assert_eq!(texts("1e-3 1.5f64 0x1f 1_000"), vec!["1e-3", "1.5f64", "0x1f", "1_000"]);
    }
}
