//! The checked-in invariant manifests the lint rules read.
//!
//! Everything the analyzer treats as project policy lives here as plain
//! `const` tables (no config files, no new deps): which modules are
//! hot-path, which functions run per frame, the global lock-acquisition
//! order, and the counter-conservation contracts. Changing policy is a
//! reviewed code change to this file, not an analyzer edit.

/// Files (suffix-matched) that are hot-path as a whole: the per-frame
/// serving loop runs through them, so `panic-freedom` and
/// `lock-discipline` apply to all their non-test code.
pub const HOT_MODULES: &[&str] = &[
    "pipeline/driver.rs",
    "pipeline/batcher.rs",
    "pipeline/router.rs",
    "pipeline/engines.rs",
    "pipeline/metrics.rs",
    "pipeline/plane.rs",
];

/// Directory prefixes that are hot-path wholesale. `obs/` is listed
/// because its recording primitives run once per served frame.
pub const HOT_PREFIXES: &[&str] = &["serve/", "fleet/", "imaging/", "obs/"];

/// Exemptions from [`HOT_PREFIXES`]: the scalar reference kernels are
/// equivalence oracles for tests/benches, never on the serving path.
pub const HOT_EXEMPT: &[&str] = &["imaging/reference.rs"];

/// Is this (repo-relative, suffix-matched) file subject to the hot-path
/// rules?
pub fn is_hot(rel: &str) -> bool {
    if HOT_EXEMPT.iter().any(|e| rel.ends_with(e)) {
        return false;
    }
    if HOT_MODULES.iter().any(|m| rel.ends_with(m)) {
        return true;
    }
    HOT_PREFIXES
        .iter()
        .any(|p| rel.starts_with(p) || rel.contains(&format!("/{p}")))
}

/// A function on the per-frame path: called once (or more) per served
/// frame, so heap allocation and unchecked indexing are banned inside
/// its body (`hot-path-alloc` and the indexing half of `panic-freedom`).
#[derive(Debug, Clone, Copy)]
pub struct HotFn {
    /// File suffix the function lives in.
    pub file: &'static str,
    pub func: &'static str,
}

/// The per-frame function manifest. A function listed here but missing
/// from its file is itself a finding (the manifest must not rot).
pub const HOT_FNS: &[HotFn] = &[
    HotFn { file: "pipeline/driver.rs", func: "submit" },
    HotFn { file: "pipeline/batcher.rs", func: "collect_batch_into" },
    HotFn { file: "pipeline/router.rs", func: "route" },
    HotFn { file: "pipeline/engines.rs", func: "dispatch" },
    HotFn { file: "pipeline/metrics.rs", func: "record_frame" },
    HotFn { file: "pipeline/metrics.rs", func: "record_drop" },
    HotFn { file: "pipeline/plane.rs", func: "acquire" },
    HotFn { file: "serve/telemetry.rs", func: "completed" },
    HotFn { file: "fleet/router.rs", func: "node_for" },
    HotFn { file: "fleet/vclock.rs", func: "pop_ready" },
    HotFn { file: "obs/registry.rs", func: "record" },
    HotFn { file: "obs/stages.rs", func: "record" },
    // k-space recon front-end: runs once per acquired frame
    HotFn { file: "imaging/fft.rs", func: "fft2" },
    HotFn { file: "imaging/fft.rs", func: "ifft2" },
    HotFn { file: "imaging/grappa.rs", func: "apply" },
];

/// One lock class in the global acquisition order. `field` is the name
/// of the `Mutex` struct field; the rule classifies an acquisition by
/// the receiver ident of `.lock()` / the field ident inside `relock(…)`.
#[derive(Debug, Clone, Copy)]
pub struct LockClass {
    pub field: &'static str,
    /// Position in the global order; acquire strictly increasing.
    pub rank: u8,
    pub owner: &'static str,
}

/// The declared lock order: arbiter unit state → arbiter timeline →
/// metrics counters → plane-pool shelf → telemetry sink → observability
/// leaves (registered only at setup / checkpoints, never per frame).
/// Holding a higher-rank lock while acquiring a lower-or-equal one is a
/// `lock-discipline` finding.
pub const LOCK_ORDER: &[LockClass] = &[
    LockClass { field: "state", rank: 0, owner: "pipeline::engines::Unit" },
    LockClass { field: "timeline", rank: 1, owner: "pipeline::engines::EngineArbiter" },
    LockClass { field: "instances", rank: 2, owner: "pipeline::metrics::Metrics" },
    LockClass { field: "free", rank: 3, owner: "pipeline::plane::Shelf" },
    LockClass { field: "inner", rank: 4, owner: "serve::telemetry::Telemetry" },
    LockClass { field: "entries", rank: 5, owner: "obs::registry::Registry" },
    LockClass { field: "events", rank: 6, owner: "obs::ObsHub" },
    LockClass { field: "snapshots", rank: 7, owner: "obs::ObsHub" },
];

/// Rank of a lock-field ident, if declared.
pub fn lock_rank(ident: &str) -> Option<u8> {
    LOCK_ORDER
        .iter()
        .find(|c| c.field == ident)
        .map(|c| c.rank)
}

/// A counter-conservation contract: every numeric field of `strukt`
/// (declared in `file`) must be mentioned inside each listed writer
/// function (`(impl type, fn name)`, same file) — so a counter added to
/// the struct cannot silently vanish from the JSON report or the
/// telemetry snapshot.
#[derive(Debug, Clone, Copy)]
pub struct CounterContract {
    pub file: &'static str,
    pub strukt: &'static str,
    pub writers: &'static [(&'static str, &'static str)],
}

pub const COUNTER_CONTRACTS: &[CounterContract] = &[
    CounterContract {
        file: "pipeline/metrics.rs",
        strukt: "InstanceCounters",
        writers: &[("Metrics", "snapshot")],
    },
    CounterContract {
        file: "serve/telemetry.rs",
        strukt: "WindowStats",
        writers: &[("WindowStats", "to_json")],
    },
    CounterContract {
        file: "serve/mod.rs",
        strukt: "ServeReport",
        writers: &[("ServeReport", "to_json")],
    },
    CounterContract {
        file: "fleet/report.rs",
        strukt: "FleetWindow",
        writers: &[("FleetWindow", "to_json")],
    },
    CounterContract {
        file: "fleet/report.rs",
        strukt: "NodeReport",
        writers: &[("NodeReport", "to_json")],
    },
    CounterContract {
        file: "obs/registry.rs",
        strukt: "HistogramSnapshot",
        writers: &[("HistogramSnapshot", "to_json")],
    },
    CounterContract {
        file: "obs/stages.rs",
        strukt: "StageBreakdown",
        writers: &[("StageBreakdown", "to_json")],
    },
    CounterContract {
        file: "obs/events.rs",
        strukt: "ObsEvent",
        writers: &[("ObsEvent", "to_json")],
    },
    CounterContract {
        file: "pipeline/source.rs",
        strukt: "ReconReport",
        writers: &[("ReconReport", "to_json")],
    },
];

/// Field types the conservation contract considers counters.
pub const COUNTER_TYPES: &[&str] = &["usize", "u32", "u64", "i32", "i64", "f32", "f64"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_scope_matches_policy() {
        assert!(is_hot("pipeline/driver.rs"));
        assert!(is_hot("serve/mod.rs"));
        assert!(is_hot("rust/src/fleet/vclock.rs"));
        assert!(is_hot("imaging/median.rs"));
        assert!(is_hot("rust/src/imaging/fft.rs"));
        assert!(is_hot("imaging/grappa.rs"));
        assert!(is_hot("imaging/kspace.rs"));
        assert!(is_hot("rust/src/obs/registry.rs"));
        assert!(is_hot("obs/stages.rs"));
        assert!(!is_hot("imaging/reference.rs"), "scalar oracle is exempt");
        assert!(!is_hot("placement/score.rs"));
        assert!(!is_hot("analysis/rules.rs"));
        assert!(!is_hot("pipeline/source.rs"), "sources allocate at frame synthesis");
    }

    #[test]
    fn lock_order_is_strictly_ranked_and_unique() {
        for (i, c) in LOCK_ORDER.iter().enumerate() {
            assert_eq!(c.rank as usize, i, "ranks are dense and ordered");
        }
        for c in LOCK_ORDER {
            assert_eq!(lock_rank(c.field), Some(c.rank));
        }
        assert_eq!(lock_rank("not_a_lock"), None);
    }
}
