//! Library-wide error type.

/// Unified error for all edgepipe subsystems.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("config error: {0}")]
    Config(String),

    #[error("graph error: {0}")]
    Graph(String),

    #[error("shape inference error: {0}")]
    Shape(String),

    #[error("DLA planning error: {0}")]
    Dla(String),

    #[error("scheduling error: {0}")]
    Sched(String),

    #[error("simulation error: {0}")]
    Sim(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("pipeline error: {0}")]
    Pipeline(String),

    #[error("imaging error: {0}")]
    Imaging(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
