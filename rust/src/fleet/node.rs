//! One simulated Jetson node: SoC profile, plan-on-boot placement, a
//! virtual-clock core, and health.
//!
//! A [`FleetNode`] is the fleet's unit of capacity. Booting a node runs
//! the auto-placement planner ([`crate::placement::plan`]) against its
//! SoC profile — exactly what a real node would do on startup — and
//! serves the planned spec on a [`VirtualCore`]. Health is derived, not
//! declared: a node whose backlog exceeds its planned per-checkpoint
//! capacity is `Saturated`; injected degradation (thermal throttle,
//! clock cap) makes it `Degraded` and stretches every subsequent
//! dispatch on its virtual clock.

// Fleet node serving state.
#![deny(clippy::unwrap_used)]

use crate::cost::power::PowerModel;
use crate::dla::DlaVersion;
use crate::error::Result;
use crate::fleet::vclock::{Delivery, UnitBusy, VirtualCore};
use crate::hw::{self, SocSpec};
use crate::pipeline::spec::PipelineSpec;
use crate::placement::{plan, PlacementRequest};

/// SoC generation a fleet node boots as.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeProfile {
    /// Jetson AGX Orin (DLA v2).
    Orin,
    /// Jetson AGX Xavier (DLA v1) — slower tables, hotter idle rails.
    Xavier,
}

impl NodeProfile {
    pub fn parse(s: &str) -> Option<NodeProfile> {
        match s.to_ascii_lowercase().as_str() {
            "orin" => Some(NodeProfile::Orin),
            "xavier" => Some(NodeProfile::Xavier),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            NodeProfile::Orin => "orin",
            NodeProfile::Xavier => "xavier",
        }
    }

    pub fn soc(&self) -> SocSpec {
        match self {
            NodeProfile::Orin => hw::orin(),
            NodeProfile::Xavier => hw::xavier(),
        }
    }

    pub fn dla_version(&self) -> DlaVersion {
        match self {
            NodeProfile::Orin => DlaVersion::V2,
            NodeProfile::Xavier => DlaVersion::V1,
        }
    }

    pub fn power_model(&self) -> PowerModel {
        PowerModel::for_soc(&self.soc())
    }
}

/// Derived node health, reported per checkpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeHealth {
    Healthy,
    /// Backlog beyond planned capacity — a migration source.
    Saturated,
    /// Degradation injected — serves, but slower.
    Degraded,
}

impl NodeHealth {
    pub fn name(&self) -> &'static str {
        match self {
            NodeHealth::Healthy => "healthy",
            NodeHealth::Saturated => "saturated",
            NodeHealth::Degraded => "degraded",
        }
    }
}

/// One booted node: planned spec + virtual core + rolling counters.
pub struct FleetNode {
    pub id: usize,
    pub profile: NodeProfile,
    /// The plan-on-boot placement this node serves.
    pub spec: PipelineSpec,
    /// The planner's throughput prediction — the node's capacity unit.
    pub capacity_fps: f64,
    pub core: VirtualCore,
    health: NodeHealth,
    /// Frames offered to this node (includes sheds), whole run.
    pub offered: usize,
    /// Frames admission-shed at this node, whole run.
    pub shed: usize,
    /// Deliveries completed on this node, whole run.
    pub completed: usize,
    /// Migration arrivals/departures, whole run.
    pub migrations_in: usize,
    pub migrations_out: usize,
}

impl FleetNode {
    /// Boot from an already-planned spec (nodes sharing a profile share
    /// one planner run — see [`boot`]).
    pub fn from_spec(
        id: usize,
        profile: NodeProfile,
        spec: PipelineSpec,
        capacity_fps: f64,
    ) -> Result<FleetNode> {
        let core = VirtualCore::new(&spec, &profile.soc())?;
        Ok(FleetNode {
            id,
            profile,
            spec,
            capacity_fps,
            core,
            health: NodeHealth::Healthy,
            offered: 0,
            shed: 0,
            completed: 0,
            migrations_in: 0,
            migrations_out: 0,
        })
    }

    /// Plan-on-boot: run the placement planner for this node's SoC and
    /// serve the winning spec. `plan_frames` sizes the planner's dry-run
    /// window (smaller = faster boot, coarser prediction).
    pub fn boot(id: usize, profile: NodeProfile, plan_frames: usize) -> Result<FleetNode> {
        let mut req = PlacementRequest::new(profile.soc(), profile.dla_version());
        req.frames = plan_frames.max(8);
        let outcome = plan(&req)?;
        FleetNode::from_spec(id, profile, outcome.spec, outcome.eval.predicted_fps)
    }

    pub fn health(&self) -> NodeHealth {
        self.health
    }

    /// Inject degradation: every dispatch priced from now on stretches by
    /// `slowdown` (≥ 1). The node's health pins to `Degraded` until the
    /// factor returns to 1.
    pub fn degrade(&mut self, slowdown: f64) {
        self.core.set_slowdown(slowdown);
        if self.core.slowdown() > 1.0 {
            self.health = NodeHealth::Degraded;
        }
    }

    /// Health transition driven by the fleet checkpoint loop: injected
    /// degradation outranks saturation, saturation outranks healthy.
    /// `saturation_backlog` is the frame count that counts as saturated
    /// (typically the migration policy's threshold; 0 disables).
    pub fn observe_backlog(&mut self, saturation_backlog: usize) {
        self.health = if self.core.slowdown() > 1.0 {
            NodeHealth::Degraded
        } else if saturation_backlog > 0 && self.core.backlog() >= saturation_backlog {
            NodeHealth::Saturated
        } else {
            NodeHealth::Healthy
        };
    }

    /// Offer one frame. Sheds (returns `false`) when the node's backlog
    /// is at `max_backlog` (0 = unlimited); admitted frames are conserved.
    pub fn offer(
        &mut self,
        stream: usize,
        frame_id: u64,
        class: usize,
        t: f64,
        max_backlog: usize,
    ) -> bool {
        self.offered += 1;
        if max_backlog > 0 && self.core.backlog() >= max_backlog {
            self.shed += 1;
            return false;
        }
        self.core.admit(stream, frame_id, class, t);
        true
    }

    /// Checkpoint: flush partial batches (floor `t`) and collect every
    /// delivery released by virtual time `t`.
    pub fn advance_to(&mut self, t: f64, out: &mut Vec<Delivery>) {
        let before = out.len();
        self.core.flush(t);
        self.core.pop_ready(t, out);
        self.completed += out.len() - before;
    }

    /// End of run: release everything still in flight.
    pub fn drain(&mut self, floor: f64, out: &mut Vec<Delivery>) {
        let before = out.len();
        self.core.drain(floor, out);
        self.completed += out.len() - before;
    }

    /// Per-unit busy accounting (power rollups divide by wall span).
    pub fn unit_stats(&self) -> Vec<UnitBusy> {
        self.core.unit_stats()
    }

    /// Estimated average power draw over `span_seconds` of serving:
    /// per-unit busy fractions through this profile's rail model.
    pub fn power_w(&self, span_seconds: f64) -> f64 {
        let span = span_seconds.max(f64::MIN_POSITIVE);
        let utils: Vec<_> = self
            .unit_stats()
            .iter()
            .map(|u| (u.kind, (u.busy_seconds / span).min(1.0)))
            .collect();
        self.profile.power_model().total_power(&utils)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn profiles_parse_and_map_to_hardware() {
        assert_eq!(NodeProfile::parse("orin"), Some(NodeProfile::Orin));
        assert_eq!(NodeProfile::parse("Xavier"), Some(NodeProfile::Xavier));
        assert_eq!(NodeProfile::parse("tx2"), None);
        assert_eq!(NodeProfile::Orin.dla_version(), DlaVersion::V2);
        assert_eq!(NodeProfile::Xavier.dla_version(), DlaVersion::V1);
        assert!(NodeProfile::Xavier.soc().name.contains("xavier"));
    }

    #[test]
    fn boot_plans_and_serves() {
        let mut node = FleetNode::boot(0, NodeProfile::Orin, 16).unwrap();
        assert!(node.capacity_fps > 0.0, "planner must predict throughput");
        assert_eq!(node.health(), NodeHealth::Healthy);
        for f in 0..32u64 {
            assert!(node.offer(0, f, 0, 0.0, 0));
        }
        let mut out = Vec::new();
        node.drain(0.0, &mut out);
        assert_eq!(out.len(), 32);
        assert_eq!(node.completed, 32);
        assert_eq!(node.offered, 32);
        assert_eq!(node.shed, 0);
    }

    #[test]
    fn backlog_cap_sheds_and_health_tracks_state() {
        let mut node = FleetNode::boot(1, NodeProfile::Xavier, 16).unwrap();
        // cap 4: the 5th+ un-drained offer sheds
        let mut admitted = 0;
        for f in 0..16u64 {
            if node.offer(0, f, 0, 0.0, 4) {
                admitted += 1;
            }
        }
        assert_eq!(admitted, 4);
        assert_eq!(node.shed, 12);
        assert_eq!(node.offered, 16);
        node.observe_backlog(2);
        assert_eq!(node.health(), NodeHealth::Saturated);
        node.degrade(4.0);
        assert_eq!(node.health(), NodeHealth::Degraded, "degradation outranks");
        let mut out = Vec::new();
        node.drain(0.0, &mut out);
        assert_eq!(out.len() + node.shed, 16, "offered == completed + shed");
        node.observe_backlog(2);
        assert_eq!(node.health(), NodeHealth::Degraded, "still throttled");
    }

    #[test]
    fn power_reflects_profile_and_utilization() {
        let mut node = FleetNode::boot(0, NodeProfile::Orin, 16).unwrap();
        let idle_w = node.power_w(1.0);
        for f in 0..64u64 {
            node.offer(0, f, 0, 0.0, 0);
        }
        let mut out = Vec::new();
        node.drain(0.0, &mut out);
        let span = node.core.makespan().max(1e-6);
        assert!(
            node.power_w(span) > idle_w,
            "busy units must draw above the idle floor"
        );
    }
}
