//! Cluster front door: consistent-hash placement of client streams onto
//! fleet nodes.
//!
//! Each node contributes `replicas` points to a hash ring; a stream's
//! home node is the first ring point at or after the stream's own hash.
//! Consistent hashing keeps assignments stable as the fleet grows — a
//! node added or removed remaps only the streams adjacent to its points,
//! not the whole population — which matters because remapping a live
//! stream costs a drain-and-switch migration.
//!
//! Migrations are *overrides* layered on the ring: the ring stays the
//! durable home map, and [`StreamRouter::migrate`] records the exception.
//! Capacity-aware target selection ([`StreamRouter::pick_target`]) picks
//! the node whose projected load (backlog over planned capacity) stays
//! lowest after absorbing the moved share, preferring healthy nodes.

// Per-arrival stream routing.
#![deny(clippy::unwrap_used)]

use crate::util::hash::{mix64, BuildMix64};
use std::collections::HashMap;

/// SplitMix64 finalizer: cheap, well-mixed 64-bit hash for ring points
/// and stream keys. Deterministic across runs and platforms.
#[inline]
pub fn hash64(x: u64) -> u64 {
    mix64(x)
}

/// Consistent-hash stream→node map with a migration override layer.
///
/// The ring is stored as two flat arrays (point hashes, point owners)
/// rather than a `Vec<(u64, usize)>`: `node_for` runs once per frame
/// arrival in the fleet executor, and the binary search over a dense
/// `&[u64]` touches half the cache lines of the tupled layout.
pub struct StreamRouter {
    /// Sorted ring point hashes.
    points: Vec<u64>,
    /// Owning node of each ring point, parallel to `points`.
    owners: Vec<u32>,
    nodes: usize,
    /// Streams moved off their ring home by a migration.
    overrides: HashMap<usize, usize, BuildMix64>,
}

impl StreamRouter {
    /// Ring over `nodes` nodes with `replicas` points each. More replicas
    /// smooth the per-node share at the cost of a bigger binary search.
    pub fn new(nodes: usize, replicas: usize) -> StreamRouter {
        let nodes = nodes.max(1);
        let replicas = replicas.max(1);
        let mut ring = Vec::with_capacity(nodes * replicas);
        for node in 0..nodes {
            for r in 0..replicas {
                ring.push((hash64((node as u64) << 32 | r as u64), node as u32));
            }
        }
        ring.sort_unstable();
        let (points, owners) = ring.into_iter().unzip();
        StreamRouter {
            points,
            owners,
            nodes,
            overrides: HashMap::default(),
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    #[inline]
    fn stream_hash(stream: usize) -> u64 {
        hash64(stream as u64 ^ 0xfeed_beef_cafe_f00d)
    }

    /// The stream's ring home, ignoring overrides.
    #[inline]
    pub fn home(&self, stream: usize) -> usize {
        let h = Self::stream_hash(stream);
        // First ring point strictly after the stream hash, wrapping.
        let mut i = self.points.partition_point(|&p| p <= h);
        if i == self.points.len() {
            i = 0;
        }
        self.owners[i] as usize
    }

    /// Where the stream is served right now (override wins over home).
    /// Per-arrival hot path: skips the override map entirely while no
    /// migrations are in force (the common steady state).
    #[inline]
    pub fn node_for(&self, stream: usize) -> usize {
        if self.overrides.is_empty() {
            return self.home(stream);
        }
        match self.overrides.get(&stream) {
            Some(&n) => n,
            None => self.home(stream),
        }
    }

    /// Record a migration. Moving a stream back to its ring home clears
    /// the override (the ring is already right).
    pub fn migrate(&mut self, stream: usize, to: usize) {
        if self.home(stream) == to {
            self.overrides.remove(&stream);
        } else {
            self.overrides.insert(stream, to);
        }
    }

    /// Number of streams currently routed away from their ring home.
    pub fn overridden(&self) -> usize {
        self.overrides.len()
    }

    /// Current node of every stream in `streams`.
    pub fn assignments(&self, streams: usize) -> Vec<usize> {
        (0..streams).map(|s| self.node_for(s)).collect()
    }

    /// Capacity-aware rebalancing target: among nodes other than `from`,
    /// pick the one with the lowest projected load after absorbing
    /// `moved_load` (load = backlog frames / planned capacity fps, i.e.
    /// seconds of queued work). Healthy nodes are preferred over degraded
    /// ones; returns `None` for a single-node fleet.
    ///
    /// `loads[i]` = (current load seconds, degraded) for node `i`.
    pub fn pick_target(&self, from: usize, loads: &[(f64, bool)], moved_load: f64) -> Option<usize> {
        let mut best: Option<(bool, f64, usize)> = None;
        for (i, &(load, degraded)) in loads.iter().enumerate() {
            if i == from {
                continue;
            }
            let cand = (degraded, load + moved_load, i);
            let better = match &best {
                None => true,
                // healthy beats degraded; then lowest projected load;
                // then lowest index for determinism
                Some(b) => cand.0 < b.0 || (cand.0 == b.0 && cand.1 < b.1),
            };
            if better {
                best = Some(cand);
            }
        }
        best.map(|(_, _, i)| i)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn assignment_is_deterministic_and_total() {
        let r1 = StreamRouter::new(8, 64);
        let r2 = StreamRouter::new(8, 64);
        for s in 0..4096 {
            let n = r1.node_for(s);
            assert!(n < 8);
            assert_eq!(n, r2.node_for(s));
        }
    }

    #[test]
    fn load_spreads_across_nodes() {
        let r = StreamRouter::new(8, 64);
        let mut counts = vec![0usize; 8];
        for s in 0..4096 {
            counts[r.node_for(s)] += 1;
        }
        for (n, &c) in counts.iter().enumerate() {
            // perfect would be 512; consistent hashing with 64 replicas
            // stays within a loose factor
            assert!(c > 128 && c < 1536, "node {n} got {c} of 4096 streams");
        }
    }

    #[test]
    fn growing_the_fleet_remaps_only_a_slice() {
        let small = StreamRouter::new(4, 64);
        let big = StreamRouter::new(5, 64);
        let moved = (0..4096)
            .filter(|&s| small.node_for(s) != big.node_for(s))
            .count();
        // adding 1 of 5 nodes should move roughly 1/5 of streams, and
        // certainly not reshuffle everything
        assert!(moved > 0, "a new node must take some streams");
        assert!(moved < 2048, "consistent hashing must not reshuffle half: {moved}");
    }

    #[test]
    fn overrides_layer_over_the_ring_and_cancel_at_home() {
        let mut r = StreamRouter::new(4, 16);
        let s = 42;
        let home = r.home(s);
        let away = (home + 1) % 4;
        r.migrate(s, away);
        assert_eq!(r.node_for(s), away);
        assert_eq!(r.overridden(), 1);
        r.migrate(s, home);
        assert_eq!(r.node_for(s), home);
        assert_eq!(r.overridden(), 0, "moving home clears the override");
    }

    #[test]
    fn pick_target_prefers_healthy_then_least_loaded() {
        let r = StreamRouter::new(4, 16);
        let loads = [(9.0, false), (0.5, true), (0.2, false), (0.4, false)];
        // node 1 has least load but is degraded; node 2 wins
        assert_eq!(r.pick_target(0, &loads, 0.1), Some(2));
        // moving off node 2: node 3 (healthy, 0.4) beats degraded node 1
        assert_eq!(r.pick_target(2, &loads, 0.1), Some(3));
        // single-node fleet has nowhere to go
        assert_eq!(r.pick_target(0, &[(1.0, false)], 0.1), None);
    }
}
