//! Event-driven virtual-clock executor — the fleet's per-node serving
//! engine.
//!
//! [`crate::pipeline::driver::StreamCore`] prices a dispatch and then
//! *sleeps a worker thread* for the priced duration, which is perfect for
//! one node but caps a single process at a few dozen streams. A
//! [`VirtualCore`] keeps the identical hardware semantics — exclusive
//! engine units, PCCS memory contention between concurrently busy units,
//! reformat cost on occupant switches, route-policy fan-out with lossless
//! primary copies — but advances a *virtual clock* instead of sleeping:
//! admitting a frame immediately computes when its dispatch would start
//! and finish on the modeled SoC, so thousands of streams per process
//! cost a hash-map update and a heap push each. The replay rules are
//! seeded from [`crate::placement::score::evaluate`]'s dry run (per-unit
//! `free_at`, arrival-order contention approximation) and priced by the
//! same [`crate::pipeline::backend::SimBackend::dispatch_profile`] tables
//! the threaded arbiter charges, so a virtual node and a threaded node
//! predict the same throughput.
//!
//! Client-visible semantics the fleet layer builds on:
//!
//! * **in-order delivery** — each stream's frames are *released* in
//!   admission order (a per-stream reorder stage holds a frame that
//!   finished early until its predecessors finish), so per-client frame
//!   order is preserved no matter how the route policy interleaves
//!   units;
//! * **delivery gate** — a stream adopted from another node carries a
//!   barrier time ([`VirtualCore::adopt_stream`]): nothing is released
//!   before the old node's last release, which is exactly the
//!   drain-and-switch handoff contract of the serve loop's re-planner,
//!   lifted to cross-node migration;
//! * **conservation** — every admitted frame is eventually released
//!   (admission sheds happen *before* [`VirtualCore::admit`]), so
//!   `offered == released + shed` holds fleet-wide.

// Virtual-clock executor hot path.
#![deny(clippy::unwrap_used)]

use crate::error::{Error, Result};
use crate::hw::{EngineKind, SocSpec};
use crate::obs::stages::{StageAccum, StageStamps};
use crate::pipeline::backend::{InferenceBackend, SimBackend};
use crate::pipeline::engines::DispatchProfile;
use crate::pipeline::router::RoutePolicy;
use crate::pipeline::spec::PipelineSpec;
use crate::placement::score::primary_instances;
use crate::sim::timeline::Span;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::Arc;

/// One frame released to its client, on the virtual clock.
#[derive(Debug, Clone, Copy)]
pub struct Delivery {
    /// Source client stream (fleet-global index).
    pub stream: usize,
    /// Frame sequence number within the stream.
    pub frame_id: u64,
    /// QoS class of the stream (fleet rollups cut percentiles per class).
    pub class: usize,
    /// Release time, virtual (model) seconds.
    pub t: f64,
    /// Offer-to-release latency, seconds (includes batch fill waits,
    /// queueing behind the unit, contention stretch, and any migration
    /// barrier).
    pub latency_s: f64,
}

/// Min-heap entry ordered by release time (finite, non-negative, so the
/// bit pattern orders like the float), tie-broken by (stream, frame) for
/// deterministic pops.
struct Queued(Delivery);

impl Queued {
    fn key(&self) -> (u64, usize, u64) {
        (self.0.t.to_bits(), self.0.stream, self.0.frame_id)
    }
}

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        other.key().cmp(&self.key())
    }
}

/// One routed copy waiting in an instance's batch buffer.
struct PendingCopy {
    stream: usize,
    frame_id: u64,
    class: usize,
    /// When the client offered the frame (latency epoch).
    offered_t: f64,
    /// When this copy was admitted (dispatch may not start earlier).
    admit_t: f64,
}

/// Per-unit virtual state — the executor-side mirror of the scorer's
/// `UnitState` and the arbiter's per-unit lease.
struct VirtualUnit {
    label: String,
    kind: EngineKind,
    index: usize,
    free_at: f64,
    last_start: f64,
    /// Bandwidth demand of the dispatch currently occupying the unit.
    busy_bw: f64,
    occupant: Option<usize>,
    busy: f64,
    dispatches: usize,
    transitions: usize,
}

/// Public per-unit accounting snapshot.
#[derive(Debug, Clone)]
pub struct UnitBusy {
    pub label: String,
    pub kind: EngineKind,
    pub index: usize,
    pub busy_seconds: f64,
    pub dispatches: usize,
    pub transitions: usize,
}

/// Per-stream in-order release stage.
struct StreamState {
    /// Release clock: no frame of this stream is released earlier than a
    /// previously released one (or the adoption barrier).
    gate: f64,
    /// Admitted frame ids in admission order, awaiting release.
    pending: VecDeque<u64>,
    /// Finished frames not yet at the head of `pending`.
    done: HashMap<u64, (f64, f64, usize)>,
}

/// The event-driven virtual-clock executor for one node's pipeline spec.
pub struct VirtualCore {
    route: RoutePolicy,
    primary: Vec<bool>,
    profiles: Vec<DispatchProfile>,
    max_batch: Vec<usize>,
    unit_of: Vec<usize>,
    units: Vec<VirtualUnit>,
    pending: Vec<Vec<PendingCopy>>,
    rr_next: usize,
    /// Degradation multiplier on every priced duration (>= 1 = throttled).
    slowdown: f64,
    /// Priced per-frame cost of the spec's k-space recon front-end
    /// (`0` for phantom sources): an admitted frame's copies cannot start
    /// dispatch before its reconstruction is done, though the offer time
    /// (latency epoch) is unchanged.
    recon_s: f64,
    streams: HashMap<usize, StreamState>,
    ready: BinaryHeap<Queued>,
    admitted: usize,
    released: usize,
    /// Record a [`Span`] per dispatch for trace export (off by default —
    /// an open-ended fleet run would otherwise grow unbounded).
    record_spans: bool,
    spans: Vec<Span>,
    /// Virtual frame-lifecycle stage stamps fold in here when attached.
    stages: Option<Arc<StageAccum>>,
}

impl VirtualCore {
    /// Build the executor for `spec` priced on `soc`. Fails on specs the
    /// sim cannot price (unknown artifact, engine outside the SoC) —
    /// the same fail-fast contract as the threaded core.
    pub fn new(spec: &PipelineSpec, soc: &SocSpec) -> Result<VirtualCore> {
        if spec.instances.is_empty() {
            return Err(Error::Pipeline(
                "virtual core needs at least one instance".into(),
            ));
        }
        // Unscaled backend: profile durations are model seconds, which is
        // the virtual clock's own axis (time_scale only paces real sleeps).
        let backend = SimBackend::new(soc.clone());
        let profiles: Vec<DispatchProfile> = spec
            .instances
            .iter()
            .map(|inst| {
                backend.dispatch_profile(inst)?.ok_or_else(|| {
                    Error::Pipeline(format!(
                        "sim backend produced no dispatch profile for `{}`",
                        inst.label
                    ))
                })
            })
            .collect::<Result<_>>()?;

        // Dedup physical units exactly like the serving arbiter.
        let mut units: Vec<VirtualUnit> = Vec::new();
        let mut unit_of: Vec<usize> = Vec::with_capacity(spec.instances.len());
        for inst in &spec.instances {
            let key = (inst.engine, inst.engine_index);
            let idx = match units.iter().position(|u| (u.kind, u.index) == key) {
                Some(i) => i,
                None => {
                    units.push(VirtualUnit {
                        label: inst.engine.unit_label(inst.engine_index),
                        kind: inst.engine,
                        index: inst.engine_index,
                        free_at: 0.0,
                        last_start: 0.0,
                        busy_bw: 0.0,
                        occupant: None,
                        busy: 0.0,
                        dispatches: 0,
                        transitions: 0,
                    });
                    units.len() - 1
                }
            };
            unit_of.push(idx);
        }

        let n = spec.instances.len();
        Ok(VirtualCore {
            route: spec.route,
            primary: primary_instances(spec.route, n),
            profiles,
            max_batch: spec
                .instances
                .iter()
                .map(|i| i.batch.max_batch.max(1))
                .collect(),
            unit_of,
            units,
            pending: (0..n).map(|_| Vec::new()).collect(),
            rr_next: 0,
            slowdown: 1.0,
            recon_s: spec.source.recon_seconds(),
            streams: HashMap::new(),
            ready: BinaryHeap::new(),
            admitted: 0,
            released: 0,
            record_spans: false,
            spans: Vec::new(),
            stages: None,
        })
    }

    /// Attach observability: a stage accumulator for per-frame lifecycle
    /// stamps and/or per-dispatch [`Span`] recording for trace export.
    pub fn set_observer(&mut self, stages: Option<Arc<StageAccum>>, record_spans: bool) {
        self.stages = stages;
        self.record_spans = record_spans;
    }

    /// Take the recorded dispatch spans (empty unless
    /// [`VirtualCore::set_observer`] enabled recording). Span times are
    /// virtual seconds; `frame` is the batch's first frame id.
    pub fn take_spans(&mut self) -> Vec<Span> {
        std::mem::take(&mut self.spans)
    }

    /// Degradation injection: multiply every subsequently priced duration
    /// (thermal throttle / clock cap). Applies to dispatches priced from
    /// now on; in-flight work keeps its already-computed finish.
    pub fn set_slowdown(&mut self, factor: f64) {
        self.slowdown = factor.max(1.0);
    }

    pub fn slowdown(&self) -> f64 {
        self.slowdown
    }

    /// Unique frames admitted so far.
    pub fn admitted(&self) -> usize {
        self.admitted
    }

    /// Unique frames released (popped) so far.
    pub fn released(&self) -> usize {
        self.released
    }

    /// Frames admitted but not yet released as of the last
    /// [`VirtualCore::pop_ready`] clock — the node's in-flight backlog.
    pub fn backlog(&self) -> usize {
        self.admitted - self.released
    }

    /// Latest virtual instant any unit is busy until.
    pub fn makespan(&self) -> f64 {
        self.units.iter().map(|u| u.free_at).fold(0.0f64, f64::max)
    }

    /// Per-unit busy accounting (for utilization and power rollups).
    pub fn unit_stats(&self) -> Vec<UnitBusy> {
        self.units
            .iter()
            .map(|u| UnitBusy {
                label: u.label.clone(),
                kind: u.kind,
                index: u.index,
                busy_seconds: u.busy,
                dispatches: u.dispatches,
                transitions: u.transitions,
            })
            .collect()
    }

    /// Admit one frame at virtual time `t`. Routing, batching, unit
    /// queueing, contention and the release stage all happen eagerly; the
    /// resulting deliveries surface from [`VirtualCore::pop_ready`] once
    /// the clock passes their release times.
    pub fn admit(&mut self, stream: usize, frame_id: u64, class: usize, t: f64) {
        self.admitted += 1;
        self.streams
            .entry(stream)
            .or_insert_with(|| StreamState {
                gate: 0.0,
                pending: VecDeque::new(),
                done: HashMap::new(),
            })
            .pending
            .push_back(frame_id);

        let n = self.pending.len();
        let mut targets = [usize::MAX; 2];
        let mut fanout_all = false;
        match self.route {
            RoutePolicy::Fanout => fanout_all = true,
            RoutePolicy::RoundRobin => {
                targets[0] = self.rr_next % n;
                self.rr_next = self.rr_next.wrapping_add(1);
            }
            RoutePolicy::ByStream => targets[0] = stream % n,
            RoutePolicy::RrFanoutLast => {
                if n == 1 {
                    targets[0] = 0;
                } else {
                    targets[0] = self.rr_next % (n - 1);
                    self.rr_next = self.rr_next.wrapping_add(1);
                    targets[1] = n - 1;
                }
            }
        }
        let enqueue = |core: &mut VirtualCore, i: usize| {
            core.pending[i].push(PendingCopy {
                stream,
                frame_id,
                class,
                offered_t: t,
                // recon happens between offer and dispatch eligibility
                admit_t: t + core.recon_s,
            });
            if core.pending[i].len() >= core.max_batch[i] {
                core.dispatch(i, 0.0);
            }
        };
        if fanout_all {
            for i in 0..n {
                enqueue(self, i);
            }
        } else {
            for &i in targets.iter().filter(|&&i| i != usize::MAX) {
                enqueue(self, i);
            }
        }
    }

    /// Dispatch instance `i`'s pending batch (no-op when empty). `floor`
    /// is the earliest virtual instant the batch may start — flush-driven
    /// dispatches pass the flush time so a partial batch that *waited*
    /// for the flush is priced as having waited.
    fn dispatch(&mut self, i: usize, floor: f64) {
        let batch = std::mem::take(&mut self.pending[i]);
        if batch.is_empty() {
            return;
        }
        let admitted_t = batch.iter().fold(floor, |m, c| m.max(c.admit_t));
        let u = self.unit_of[i];
        let start = self.units[u].free_at.max(admitted_t);
        // PCCS: other units whose current dispatch spans `start` pull on
        // the shared DRAM (arrival-order approximation, as in the scorer).
        let corunner_bw: f64 = self
            .units
            .iter()
            .enumerate()
            .filter(|(j, o)| *j != u && o.last_start <= start && start < o.free_at)
            .map(|(_, o)| o.busy_bw)
            .sum();
        let p = &self.profiles[i];
        let switched = self.units[u].occupant.is_some() && self.units[u].occupant != Some(i);
        let trans = if switched {
            p.transition.as_secs_f64() * self.slowdown
        } else {
            0.0
        };
        let exec = p.dispatch_duration(batch.len()).as_secs_f64()
            * p.slowdown(corunner_bw)
            * self.slowdown;
        let end = start + trans + exec;
        let bw = p.bw_demand;

        let unit = &mut self.units[u];
        if switched {
            unit.transitions += 1;
        }
        unit.occupant = Some(i);
        unit.last_start = start;
        unit.busy_bw = bw;
        unit.busy += trans + exec;
        unit.dispatches += 1;
        unit.free_at = end;

        if self.record_spans {
            let (kind, uidx) = (self.units[u].kind, self.units[u].index);
            let frame = batch.first().map(|c| c.frame_id as usize).unwrap_or(0);
            if switched && trans > 0.0 {
                self.spans.push(Span {
                    engine: kind,
                    unit: uidx,
                    instance: i,
                    frame,
                    t0: start,
                    t1: start + trans,
                    is_transition: true,
                });
            }
            self.spans.push(Span {
                engine: kind,
                unit: uidx,
                instance: i,
                frame,
                t0: start + trans,
                t1: end,
                is_transition: false,
            });
        }

        // Virtual stage stamps: the same lifecycle schema the threaded
        // driver records, computed from the priced dispatch — one record
        // per primary (lossless) frame copy.
        if let Some(acc) = &self.stages {
            if self.primary[i] {
                for c in &batch {
                    let mut st = StageStamps::default();
                    st.queue_exit_s = (admitted_t - c.offered_t).max(0.0);
                    st.engine_start_s = (start - c.offered_t).max(st.queue_exit_s);
                    st.exec_start_s = (start + trans - c.offered_t).max(st.engine_start_s);
                    st.exec_end_s = (end - c.offered_t).max(st.exec_start_s);
                    st.writeout_s = st.exec_end_s;
                    acc.record(&st);
                }
            }
        }

        // Only the lossless primary copy finishes a frame; droppable
        // fanout copies charge busy time and contention above but never
        // gate release (mirroring the scorer and the serving driver).
        if self.primary[i] {
            for c in &batch {
                if let Some(st) = self.streams.get_mut(&c.stream) {
                    st.done.insert(c.frame_id, (end, c.offered_t, c.class));
                    Self::release_ready(st, c.stream, &mut self.ready);
                }
            }
        }
    }

    /// Release the stream's head-of-line frames that have finished, in
    /// admission order, monotone on the release gate.
    fn release_ready(st: &mut StreamState, stream: usize, ready: &mut BinaryHeap<Queued>) {
        while let Some(&front) = st.pending.front() {
            match st.done.remove(&front) {
                Some((finish_t, offered_t, class)) => {
                    st.pending.pop_front();
                    let t = finish_t.max(st.gate);
                    st.gate = t;
                    ready.push(Queued(Delivery {
                        stream,
                        frame_id: front,
                        class,
                        t,
                        latency_s: t - offered_t,
                    }));
                }
                None => break,
            }
        }
    }

    /// Force every instance's partial batch out (checkpoint / drain /
    /// migration boundary). Batches start no earlier than `floor`.
    pub fn flush(&mut self, floor: f64) {
        for i in 0..self.pending.len() {
            self.dispatch(i, floor);
        }
    }

    /// Pop every delivery released by virtual time `t` (monotone calls
    /// expected) into `out`.
    pub fn pop_ready(&mut self, t: f64, out: &mut Vec<Delivery>) {
        while let Some(q) = self.ready.peek() {
            if q.0.t > t {
                break;
            }
            let Some(q) = self.ready.pop() else {
                break;
            };
            self.released += 1;
            out.push(q.0);
        }
    }

    /// Flush and pop everything (end of run). `floor` should be the last
    /// arrival time so flushed stragglers cannot start in the past.
    pub fn drain(&mut self, floor: f64, out: &mut Vec<Delivery>) {
        self.flush(floor);
        self.pop_ready(f64::INFINITY, out);
    }

    /// Hand a stream off to another node: drop its release state and
    /// return the barrier (its last release time) the adopting node must
    /// honor. Call after [`VirtualCore::flush`] so every admitted frame
    /// of the stream has been released to the heap; frames still riding
    /// this node's heap remain this node's completions.
    pub fn retire_stream(&mut self, stream: usize) -> f64 {
        match self.streams.remove(&stream) {
            Some(st) => {
                debug_assert!(
                    st.pending.is_empty(),
                    "retire_stream before the stream drained"
                );
                st.gate
            }
            None => 0.0,
        }
    }

    /// Accept a stream migrating in: its first release waits for
    /// `barrier` (the source node's last release) — the drain-and-switch
    /// handoff guarantee across nodes.
    pub fn adopt_stream(&mut self, stream: usize, barrier: f64) {
        self.streams.insert(
            stream,
            StreamState {
                gate: barrier,
                pending: VecDeque::new(),
                done: HashMap::new(),
            },
        );
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::hw::{orin, EngineKind};
    use crate::pipeline::spec::InstanceSpec;

    fn rr_pair() -> PipelineSpec {
        PipelineSpec {
            instances: vec![
                InstanceSpec::new("g0", "gen_cropping").on_engine_unit(EngineKind::Dla, 0),
                InstanceSpec::new("g1", "gen_cropping").on_engine_unit(EngineKind::Dla, 1),
            ],
            route: RoutePolicy::RoundRobin,
            ..PipelineSpec::default()
        }
    }

    #[test]
    fn conserves_and_orders_across_interleaved_units() {
        let mut core = VirtualCore::new(&rr_pair(), &orin()).unwrap();
        for f in 0..64u64 {
            core.admit(7, f, 0, f as f64 * 0.001);
        }
        let mut out = Vec::new();
        core.drain(0.064, &mut out);
        assert_eq!(out.len(), 64, "every admitted frame is released");
        assert_eq!(core.backlog(), 0);
        // in-order release despite round-robin across two DLA units
        let mut last = None;
        let mut last_t = 0.0;
        for d in &out {
            assert_eq!(d.stream, 7);
            if let Some(prev) = last {
                assert!(d.frame_id > prev, "{} after {}", d.frame_id, prev);
            }
            assert!(d.t >= last_t, "release times are monotone per stream");
            last = Some(d.frame_id);
            last_t = d.t;
            assert!(d.latency_s >= 0.0);
        }
    }

    #[test]
    fn kspace_recon_delays_dispatch_but_not_the_latency_epoch() {
        use crate::pipeline::spec::{ReconMode, SourceSpec};
        let mut plain = VirtualCore::new(&rr_pair(), &orin()).unwrap();
        let ks_spec = PipelineSpec {
            source: SourceSpec::kspace(4, ReconMode::Grappa),
            ..rr_pair()
        };
        let recon_s = ks_spec.source.recon_seconds();
        assert!(recon_s > 0.0);
        let mut ks = VirtualCore::new(&ks_spec, &orin()).unwrap();
        for f in 0..8u64 {
            plain.admit(0, f, 0, f as f64 * 0.001);
            ks.admit(0, f, 0, f as f64 * 0.001);
        }
        let (mut a, mut b) = (Vec::new(), Vec::new());
        plain.drain(1.0, &mut a);
        ks.drain(1.0, &mut b);
        assert_eq!(a.len(), b.len());
        for (pa, pb) in a.iter().zip(&b) {
            // recon shifts every completion by at least its cost, and the
            // latency ledger (epoch = offer time) charges the wait
            assert!(pb.t >= pa.t + recon_s * 0.99, "{} vs {}", pb.t, pa.t);
            assert!(pb.latency_s >= pa.latency_s + recon_s * 0.99);
        }
    }

    #[test]
    fn slowdown_stretches_the_virtual_clock() {
        let mut fast = VirtualCore::new(&rr_pair(), &orin()).unwrap();
        let mut slow = VirtualCore::new(&rr_pair(), &orin()).unwrap();
        slow.set_slowdown(8.0);
        for f in 0..32u64 {
            fast.admit(0, f, 0, 0.0);
            slow.admit(0, f, 0, 0.0);
        }
        let (mut a, mut b) = (Vec::new(), Vec::new());
        fast.drain(0.0, &mut a);
        slow.drain(0.0, &mut b);
        assert!(
            slow.makespan() > 4.0 * fast.makespan(),
            "8x throttle must show up in the makespan: {} vs {}",
            slow.makespan(),
            fast.makespan()
        );
        // backlog visibility: at the fast core's makespan, the slow core
        // still holds most frames
        assert_eq!(a.last().unwrap().frame_id, 31);
        assert_eq!(b.last().unwrap().frame_id, 31);
    }

    #[test]
    fn adoption_barrier_holds_release_order_across_nodes() {
        let soc = orin();
        let mut src = VirtualCore::new(&rr_pair(), &soc).unwrap();
        let mut dst = VirtualCore::new(&rr_pair(), &soc).unwrap();
        src.set_slowdown(20.0); // saturated source: releases land late
        for f in 0..8u64 {
            src.admit(3, f, 0, 0.0);
        }
        src.flush(0.0);
        let barrier = src.retire_stream(3);
        assert!(barrier > 0.0);
        dst.adopt_stream(3, barrier);
        // frames 8.. arrive "immediately" on the fast destination
        for f in 8..16u64 {
            dst.admit(3, f, 0, 0.01);
        }
        let mut out = Vec::new();
        src.pop_ready(f64::INFINITY, &mut out);
        dst.drain(0.01, &mut out);
        out.sort_by(|a, b| {
            (a.t.to_bits(), a.frame_id).cmp(&(b.t.to_bits(), b.frame_id))
        });
        let ids: Vec<u64> = out.iter().map(|d| d.frame_id).collect();
        assert_eq!(ids, (0..16).collect::<Vec<_>>(), "barrier preserves order");
        assert!(out[8].t >= barrier, "destination released before the barrier");
    }

    #[test]
    fn batch_fill_dispatches_and_flush_covers_stragglers() {
        let mut spec = rr_pair();
        for inst in &mut spec.instances {
            inst.batch.max_batch = 4;
        }
        let mut core = VirtualCore::new(&spec, &orin()).unwrap();
        // 6 frames: RR gives 3 per instance — neither fills a batch of 4
        for f in 0..6u64 {
            core.admit(0, f, 0, 0.0);
        }
        let mut out = Vec::new();
        core.pop_ready(f64::INFINITY, &mut out);
        assert!(out.is_empty(), "partial batches wait for a flush");
        core.drain(0.5, &mut out);
        assert_eq!(out.len(), 6);
        assert!(out.iter().all(|d| d.t >= 0.5), "flush floor prices the wait");
    }

    #[test]
    fn droppable_fanout_tail_charges_busy_but_never_gates() {
        let mut spec = rr_pair();
        spec.instances.push(InstanceSpec::new("tail", "gen_original"));
        spec.route = RoutePolicy::RrFanoutLast;
        let mut core = VirtualCore::new(&spec, &orin()).unwrap();
        for f in 0..16u64 {
            core.admit(0, f, 0, 0.0);
        }
        let mut out = Vec::new();
        core.drain(0.0, &mut out);
        assert_eq!(out.len(), 16, "one release per unique frame");
        let gpu_busy: f64 = core
            .unit_stats()
            .iter()
            .filter(|u| u.kind == EngineKind::Gpu)
            .map(|u| u.busy_seconds)
            .sum();
        assert!(gpu_busy > 0.0, "the tail still charges its unit");
    }

    #[test]
    fn observer_records_spans_and_virtual_stage_stamps() {
        let mut core = VirtualCore::new(&rr_pair(), &orin()).unwrap();
        let acc = Arc::new(StageAccum::default());
        core.set_observer(Some(Arc::clone(&acc)), true);
        for f in 0..16u64 {
            core.admit(0, f, 0, f as f64 * 0.001);
        }
        let mut out = Vec::new();
        core.drain(0.016, &mut out);
        assert_eq!(out.len(), 16);
        let spans = core.take_spans();
        let dispatches: usize = core.unit_stats().iter().map(|u| u.dispatches).sum();
        assert_eq!(
            spans.iter().filter(|s| !s.is_transition).count(),
            dispatches,
            "span/dispatch conservation"
        );
        // exclusive units: spans on one unit never overlap
        for u in core.unit_stats() {
            let mut mine: Vec<_> = spans
                .iter()
                .filter(|s| s.engine == u.kind && s.unit == u.index)
                .collect();
            mine.sort_by(|a, b| a.t0.total_cmp(&b.t0));
            for w in mine.windows(2) {
                assert!(w[0].t1 <= w[1].t0 + 1e-9, "{:?} overlaps {:?}", w[0], w[1]);
            }
        }
        // virtual stage stamps: one per released frame, all monotone
        assert_eq!(acc.frames(), 16);
        assert_eq!(acc.non_monotone(), 0);
        assert!(core.take_spans().is_empty(), "take_spans drains");
        // recording off by default
        let mut plain = VirtualCore::new(&rr_pair(), &orin()).unwrap();
        plain.admit(0, 0, 0, 0.0);
        plain.flush(0.0);
        assert!(plain.take_spans().is_empty());
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut core = VirtualCore::new(&rr_pair(), &orin()).unwrap();
            for f in 0..40u64 {
                core.admit(f as usize % 3, f / 3, 0, f as f64 * 0.002);
            }
            let mut out = Vec::new();
            core.drain(0.08, &mut out);
            out.iter().map(|d| (d.stream, d.frame_id, d.t.to_bits())).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
