//! Fleet-level re-planning: decide *which streams move where* when a
//! node saturates or degrades.
//!
//! This is the cluster analogue of the single-node
//! [`Replanner`](crate::serve::replan::Replanner): the serve re-planner
//! swaps the *spec under* a node's streams, the migration controller
//! moves *streams between* nodes. Both fire at checkpoints and both hand
//! off with drain-and-switch semantics — the mechanics of the handoff
//! itself (flush, barrier, adopt) live in the virtual core
//! ([`crate::fleet::vclock::VirtualCore::retire_stream`] /
//! [`adopt_stream`](crate::fleet::vclock::VirtualCore::adopt_stream));
//! this module only picks the moves.

// Cross-node migration choreography.
#![deny(clippy::unwrap_used)]

use crate::config::json::{num, obj, s, Json};
use crate::fleet::router::StreamRouter;

/// When and how aggressively the fleet rebalances.
#[derive(Debug, Clone)]
pub struct MigrationPolicy {
    /// Master switch — `false` freezes streams on their ring homes (the
    /// no-migration baseline the integration test compares against).
    pub enabled: bool,
    /// A node whose backlog reaches this many frames is saturated and
    /// becomes a migration source.
    pub backlog_threshold: usize,
    /// Upper bound on streams moved per checkpoint (a full evacuation in
    /// one step would dogpile the target).
    pub max_moves_per_check: usize,
    /// Checkpoints to sit out after any move (lets the moved load land
    /// before re-measuring).
    pub cooldown_checks: usize,
    /// Testing hook: force a move attempt every N checkpoints even when
    /// no node is saturated.
    pub force_every_checks: Option<usize>,
}

impl Default for MigrationPolicy {
    fn default() -> Self {
        MigrationPolicy {
            enabled: true,
            backlog_threshold: 64,
            max_moves_per_check: 4,
            cooldown_checks: 2,
            force_every_checks: None,
        }
    }
}

impl MigrationPolicy {
    /// Baseline: never migrate.
    pub fn disabled() -> MigrationPolicy {
        MigrationPolicy {
            enabled: false,
            ..MigrationPolicy::default()
        }
    }
}

/// One recorded stream migration.
#[derive(Debug, Clone)]
pub struct MigrationEvent {
    /// Virtual time of the checkpoint that decided the move.
    pub at_seconds: f64,
    pub stream: usize,
    pub from_node: usize,
    pub to_node: usize,
    /// Why the source was drained ("saturated", "degraded", "forced").
    pub reason: String,
}

impl MigrationEvent {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("at_seconds", num(self.at_seconds)),
            ("stream", num(self.stream as f64)),
            ("from_node", num(self.from_node as f64)),
            ("to_node", num(self.to_node as f64)),
            ("reason", s(&self.reason)),
        ])
    }
}

/// A checkpoint snapshot of one node, as the controller sees it.
#[derive(Debug, Clone)]
pub struct NodeLoad {
    pub node: usize,
    /// Frames admitted but not yet released.
    pub backlog: usize,
    /// Planned capacity (the placement eval's predicted fps) — converts
    /// backlog frames into seconds of queued work.
    pub capacity_fps: f64,
    /// Degradation injected (health != healthy).
    pub degraded: bool,
    /// Streams currently on this node with their recent offered-frame
    /// counts (the movable load shares).
    pub streams: Vec<(usize, usize)>,
}

impl NodeLoad {
    /// Seconds of queued work at planned capacity.
    pub fn load_seconds(&self) -> f64 {
        self.backlog as f64 / self.capacity_fps.max(1e-9)
    }
}

/// A move the fleet loop should execute.
#[derive(Debug, Clone, Copy)]
pub struct Move {
    pub stream: usize,
    pub from: usize,
    pub to: usize,
    pub forced: bool,
    pub degraded_source: bool,
}

/// Stateful migration decision-maker (cooldown + forced cadence).
pub struct MigrationController {
    policy: MigrationPolicy,
    checks: usize,
    cooldown: usize,
}

impl MigrationController {
    pub fn new(policy: MigrationPolicy) -> MigrationController {
        MigrationController {
            policy,
            checks: 0,
            cooldown: 0,
        }
    }

    pub fn policy(&self) -> &MigrationPolicy {
        &self.policy
    }

    /// Decide this checkpoint's moves. `loads` must cover every node;
    /// the router supplies capacity-aware target selection.
    pub fn consider(&mut self, loads: &[NodeLoad], router: &StreamRouter) -> Vec<Move> {
        if !self.policy.enabled || loads.len() < 2 {
            return Vec::new();
        }
        self.checks += 1;
        let forced = match self.policy.force_every_checks {
            Some(n) if n > 0 => self.checks % n == 0,
            _ => false,
        };
        if self.cooldown > 0 {
            self.cooldown -= 1;
            if !forced {
                return Vec::new();
            }
        }

        // Source: the most loaded node (seconds of queued work), required
        // to be saturated or degraded unless this is a forced check.
        let mut source: Option<&NodeLoad> = None;
        for l in loads {
            let hot = l.backlog >= self.policy.backlog_threshold || l.degraded;
            if !hot && !forced {
                continue;
            }
            if l.streams.is_empty() {
                continue;
            }
            let better = match source {
                None => true,
                Some(s) => l.load_seconds() > s.load_seconds(),
            };
            if better {
                source = Some(l);
            }
        }
        let src = match source {
            Some(s) => s,
            None => return Vec::new(),
        };

        let load_by_node: Vec<(f64, bool)> =
            loads.iter().map(|l| (l.load_seconds(), l.degraded)).collect();
        let total_offered: usize = src.streams.iter().map(|(_, n)| n).sum();

        // Move the busiest streams first: each carries the biggest slice
        // of the source's queued work to the target.
        let mut ranked = src.streams.clone();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let cap = if forced && self.policy.max_moves_per_check == 0 {
            1
        } else {
            self.policy.max_moves_per_check.max(1)
        };

        let mut moves = Vec::new();
        for &(stream, offered) in ranked.iter().take(cap) {
            let share = if total_offered > 0 {
                offered as f64 / total_offered as f64
            } else {
                1.0 / src.streams.len() as f64
            };
            let moved_load = src.load_seconds() * share;
            match router.pick_target(src.node, &load_by_node, moved_load) {
                Some(to) if to != src.node => moves.push(Move {
                    stream,
                    from: src.node,
                    to,
                    forced,
                    degraded_source: src.degraded,
                }),
                _ => break,
            }
        }
        if !moves.is_empty() {
            self.cooldown = self.policy.cooldown_checks;
        }
        moves
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn load(node: usize, backlog: usize, degraded: bool, streams: Vec<(usize, usize)>) -> NodeLoad {
        NodeLoad {
            node,
            backlog,
            capacity_fps: 100.0,
            degraded,
            streams,
        }
    }

    #[test]
    fn idle_fleet_never_moves() {
        let router = StreamRouter::new(2, 16);
        let mut c = MigrationController::new(MigrationPolicy::default());
        let loads = vec![
            load(0, 3, false, vec![(0, 3)]),
            load(1, 2, false, vec![(1, 2)]),
        ];
        for _ in 0..10 {
            assert!(c.consider(&loads, &router).is_empty());
        }
    }

    #[test]
    fn saturated_node_evacuates_busiest_streams_first() {
        let router = StreamRouter::new(3, 16);
        let mut c = MigrationController::new(MigrationPolicy {
            backlog_threshold: 50,
            max_moves_per_check: 2,
            ..MigrationPolicy::default()
        });
        let loads = vec![
            load(0, 200, false, vec![(10, 5), (11, 90), (12, 40)]),
            load(1, 5, false, vec![(1, 5)]),
            load(2, 1, false, vec![(2, 1)]),
        ];
        let moves = c.consider(&loads, &router);
        assert_eq!(moves.len(), 2);
        assert_eq!(moves[0].stream, 11, "busiest stream moves first");
        assert_eq!(moves[1].stream, 12);
        assert!(moves.iter().all(|m| m.from == 0 && m.to != 0));
    }

    #[test]
    fn cooldown_suppresses_back_to_back_moves_but_not_forced() {
        let router = StreamRouter::new(2, 16);
        let mut c = MigrationController::new(MigrationPolicy {
            backlog_threshold: 10,
            cooldown_checks: 3,
            force_every_checks: Some(4),
            ..MigrationPolicy::default()
        });
        let loads = vec![
            load(0, 100, false, vec![(0, 50), (1, 50)]),
            load(1, 0, false, vec![]),
        ];
        assert!(!c.consider(&loads, &router).is_empty(), "check 1 moves");
        assert!(c.consider(&loads, &router).is_empty(), "check 2 cools down");
        assert!(c.consider(&loads, &router).is_empty(), "check 3 cools down");
        // check 4 is forced (4 % 4 == 0): fires despite remaining cooldown
        let forced = c.consider(&loads, &router);
        assert!(!forced.is_empty());
        assert!(forced[0].forced);
    }

    #[test]
    fn degraded_node_is_a_source_even_with_small_backlog() {
        let router = StreamRouter::new(2, 16);
        let mut c = MigrationController::new(MigrationPolicy {
            backlog_threshold: 1000,
            ..MigrationPolicy::default()
        });
        let loads = vec![
            load(0, 8, true, vec![(0, 8)]),
            load(1, 8, false, vec![(1, 8)]),
        ];
        let moves = c.consider(&loads, &router);
        assert_eq!(moves.len(), 1);
        assert!(moves[0].degraded_source);
        assert_eq!(moves[0].to, 1);
    }

    #[test]
    fn disabled_policy_is_inert_and_event_json_parses() {
        let router = StreamRouter::new(2, 16);
        let mut c = MigrationController::new(MigrationPolicy::disabled());
        let loads = vec![
            load(0, 10_000, true, vec![(0, 100)]),
            load(1, 0, false, vec![]),
        ];
        assert!(c.consider(&loads, &router).is_empty());
        let ev = MigrationEvent {
            at_seconds: 1.5,
            stream: 7,
            from_node: 0,
            to_node: 1,
            reason: "saturated".into(),
        };
        crate::config::json::Json::parse(&ev.to_json().to_compact()).unwrap();
    }
}
