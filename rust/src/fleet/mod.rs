//! Fleet layer: many simulated Jetson nodes behind one front door.
//!
//! Everything below `fleet/` answers ROADMAP open item 1 — what a
//! *deployment* of the paper's single-SoC pipeline looks like. N nodes
//! (mixed Xavier/Orin profiles) each plan-on-boot with the placement
//! planner and serve on the event-driven virtual-clock executor
//! ([`vclock::VirtualCore`]); a consistent-hash front door
//! ([`router::StreamRouter`]) pins client streams to nodes; a migration
//! controller ([`migrate::MigrationController`]) drains streams off
//! saturated or degraded nodes with the same drain-and-switch handoff
//! guarantee the single-node re-planner gives (no frame lost, duplicated,
//! or reordered across a move); and [`report::FleetReport`] rolls
//! per-node telemetry — including power draw and FPS-per-watt — into one
//! cluster summary.
//!
//! The whole fleet runs on *virtual time* in a single thread:
//! [`run_fleet`] replays the client arrival schedule, advances each
//! node's virtual core at checkpoints, and never sleeps — which is what
//! makes thousands of concurrent streams per process cheap. The threaded
//! `StreamCore` path remains the engine for single-node `run`/`serve`.


// Serving hot path: no unwraps outside tests (see util::lock::relock).
#![deny(clippy::unwrap_used)]
pub mod migrate;
pub mod node;
pub mod report;
pub mod router;
pub mod vclock;

pub use migrate::{MigrationEvent, MigrationPolicy};
pub use node::{FleetNode, NodeHealth, NodeProfile};
pub use report::{ClassLatency, FleetReport, FleetWindow, NodeReport};
pub use router::StreamRouter;
pub use vclock::{Delivery, VirtualCore};

use crate::config::json::{num, obj};
use crate::error::{Error, Result};
use crate::fleet::migrate::{MigrationController, NodeLoad};
use crate::obs::{ObsEvent, ObsHub};
use crate::serve::clients::{schedule, ClientSpec};
use crate::sim::timeline::Timeline;
use crate::util::stats::Summary;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Injected node degradation (thermal throttle / clock cap) at a virtual
/// instant — the chaos knob the property tests and the CI smoke turn.
#[derive(Debug, Clone, Copy)]
pub struct DegradationEvent {
    /// Virtual time the throttle lands.
    pub at_seconds: f64,
    /// Target node id.
    pub node: usize,
    /// Duration multiplier on every dispatch priced afterwards (≥ 1;
    /// exactly 1 restores full speed).
    pub slowdown: f64,
}

/// Everything a fleet run needs.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// One SoC profile per node; the vector's length is the fleet size.
    pub profiles: Vec<NodeProfile>,
    /// Client load (stream index = position in this vector).
    pub clients: Vec<ClientSpec>,
    /// Display names per QoS class index (missing ⇒ `class<N>`).
    pub class_names: Vec<String>,
    /// Arrival-schedule seed (same seed ⇒ identical run).
    pub seed: u64,
    /// Offered frames between fleet checkpoints (flush, health, window,
    /// migration decision). 0 ⇒ the default cadence.
    pub check_every: usize,
    /// Per-node admission cap in backlog frames (0 = unlimited; admitted
    /// frames are never dropped, so sheds are the only loss).
    pub max_backlog: usize,
    pub migration: MigrationPolicy,
    pub degradations: Vec<DegradationEvent>,
    /// Frame window the plan-on-boot placement search replays.
    pub plan_frames: usize,
    /// Cap on the retained delivery log (counters are exact regardless).
    pub delivery_capacity: usize,
    /// Ring points per node in the consistent-hash front door.
    pub router_replicas: usize,
    /// Observability hub: when set, every node's virtual core folds
    /// frame-lifecycle stage stamps into `hub.stages`, migrations /
    /// degradations land in the structured event log, and each fleet
    /// checkpoint appends a metrics snapshot.
    pub obs: Option<Arc<ObsHub>>,
    /// Record per-dispatch execution spans on every node's virtual core
    /// (feeds [`FleetReport::timelines`] / Chrome trace export). Off by
    /// default: the span log grows with dispatch count.
    pub record_spans: bool,
}

impl FleetOptions {
    pub fn new(profiles: Vec<NodeProfile>) -> FleetOptions {
        FleetOptions {
            profiles,
            clients: Vec::new(),
            class_names: Vec::new(),
            seed: 7,
            check_every: 256,
            max_backlog: 0,
            migration: MigrationPolicy::default(),
            degradations: Vec::new(),
            plan_frames: 24,
            delivery_capacity: 1 << 20,
            router_replicas: 64,
            obs: None,
            record_spans: false,
        }
    }
}

/// Run a fleet to completion on the virtual clock and roll up the report.
pub fn run_fleet(opts: &FleetOptions) -> Result<FleetReport> {
    let wall_start = Instant::now();
    if opts.profiles.is_empty() {
        return Err(Error::Pipeline("fleet needs at least one node".into()));
    }
    if opts.clients.is_empty() {
        return Err(Error::Pipeline("fleet needs at least one client".into()));
    }

    // Plan-on-boot, one planner run per distinct profile — nodes sharing
    // a SoC generation share a placement.
    let mut planned: HashMap<&'static str, (crate::pipeline::spec::PipelineSpec, f64)> =
        HashMap::new();
    let mut nodes: Vec<FleetNode> = Vec::with_capacity(opts.profiles.len());
    for (id, &profile) in opts.profiles.iter().enumerate() {
        let (spec, capacity) = match planned.get(profile.name()) {
            Some(hit) => hit.clone(),
            None => {
                let booted = FleetNode::boot(id, profile, opts.plan_frames)?;
                let entry = (booted.spec.clone(), booted.capacity_fps);
                planned.insert(profile.name(), entry.clone());
                nodes.push(booted);
                continue;
            }
        };
        nodes.push(FleetNode::from_spec(id, profile, spec, capacity)?);
    }
    let n_nodes = nodes.len();

    let hub = opts.obs.clone();
    for node in nodes.iter_mut() {
        node.core.set_observer(
            hub.as_ref().map(|h| Arc::clone(&h.stages)),
            opts.record_spans,
        );
    }

    let mut router = StreamRouter::new(n_nodes, opts.router_replicas);
    let mut controller = MigrationController::new(opts.migration.clone());
    let arrivals = schedule(&opts.clients, opts.seed)?;
    let check_every = if opts.check_every == 0 { 256 } else { opts.check_every };

    let mut degradations = opts.degradations.clone();
    degradations.sort_by(|a, b| {
        a.at_seconds
            .partial_cmp(&b.at_seconds)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut next_degradation = 0usize;

    // Rolling state.
    let mut deliveries: Vec<Delivery> = Vec::new();
    let mut log_truncated = 0usize;
    let mut windows: Vec<FleetWindow> = Vec::new();
    let mut migrations: Vec<MigrationEvent> = Vec::new();
    let mut latency_all = Summary::new();
    let mut latency_class: HashMap<usize, (usize, Summary)> = HashMap::new();
    let mut recent_offered: HashMap<usize, usize> = HashMap::new();
    let mut window_t0 = 0.0f64;
    let mut window_offered = 0usize;
    let mut since_check = 0usize;
    let mut offered_total = 0usize;
    let mut shed_prev = 0usize;
    let mut last_t = 0.0f64;
    let mut virtual_end = 0.0f64;

    let checkpoint = |t: f64,
                          nodes: &mut Vec<FleetNode>,
                          router: &mut StreamRouter,
                          controller: &mut MigrationController,
                          recent_offered: &mut HashMap<usize, usize>,
                          window_t0: &mut f64,
                          window_offered: &mut usize,
                          shed_prev: &mut usize,
                          deliveries: &mut Vec<Delivery>,
                          log_truncated: &mut usize,
                          windows: &mut Vec<FleetWindow>,
                          migrations: &mut Vec<MigrationEvent>,
                          latency_all: &mut Summary,
                          latency_class: &mut HashMap<usize, (usize, Summary)>,
                          virtual_end: &mut f64,
                          drain: bool| {
        // 1. Advance every node to t (flush partial batches, pop
        //    releases due by t); attribute releases per node.
        let mut popped: Vec<Delivery> = Vec::new();
        let mut node_completed = vec![0usize; nodes.len()];
        for node in nodes.iter_mut() {
            let before = popped.len();
            if drain {
                node.drain(t, &mut popped);
            } else {
                node.advance_to(t, &mut popped);
            }
            node_completed[node.id] += popped.len() - before;
        }
        let mut win_lat = Summary::new();
        let mut t1 = t;
        for d in &popped {
            win_lat.add(d.latency_s);
            latency_all.add(d.latency_s);
            let entry = latency_class.entry(d.class).or_insert_with(|| (0, Summary::new()));
            entry.0 += 1;
            entry.1.add(d.latency_s);
            if d.t > t1 {
                t1 = d.t;
            }
        }
        if t1 > *virtual_end {
            *virtual_end = t1;
        }

        // 2. Window rollup (shed attributed to the window it happened in).
        let shed_now: usize = nodes.iter().map(|n| n.shed).sum();
        let span = (t1 - *window_t0).max(f64::MIN_POSITIVE);
        windows.push(FleetWindow {
            t0: *window_t0,
            t1,
            offered: *window_offered,
            completed: popped.len(),
            shed: shed_now - *shed_prev,
            fps: popped.len() as f64 / span,
            latency_ms_p99: win_lat.percentile(99.0) * 1e3,
            node_completed,
        });
        *window_t0 = t1;
        *window_offered = 0;
        *shed_prev = shed_now;

        // Checkpoint-aligned metrics snapshot (taken on drain too, so the
        // final JSONL line reflects the fully drained fleet).
        if let Some(h) = &hub {
            let backlog: usize = nodes.iter().map(|n| n.core.backlog()).sum();
            h.registry
                .gauge("fleet_backlog_frames", "admitted, not yet released (fleet-wide)")
                .set(backlog as f64);
            h.registry
                .counter("fleet_checkpoints_total", "fleet checkpoints taken")
                .inc();
            let shed_win = windows.last().map(|w| w.shed).unwrap_or(0);
            if shed_win > 0 {
                h.push_event(ObsEvent::shed_burst(
                    t,
                    None,
                    format!("fleet shed {shed_win} this window"),
                    obj(vec![("shed", num(shed_win as f64))]),
                ));
            }
            h.snapshot_at(t);
        }

        // 3. Retain the delivery log (capped).
        for d in popped {
            if deliveries.len() < opts.delivery_capacity {
                deliveries.push(d);
            } else {
                *log_truncated += 1;
            }
        }

        if drain {
            return;
        }

        // 4. Health + migration decisions on the post-flush state.
        for node in nodes.iter_mut() {
            node.observe_backlog(controller.policy().backlog_threshold);
        }
        let loads: Vec<NodeLoad> = nodes
            .iter()
            .map(|node| NodeLoad {
                node: node.id,
                backlog: node.core.backlog(),
                capacity_fps: node.capacity_fps,
                degraded: node.health() == NodeHealth::Degraded,
                streams: recent_offered
                    .iter()
                    .filter(|(s, _)| router.node_for(**s) == node.id)
                    .map(|(&s, &n)| (s, n))
                    .collect(),
            })
            .collect();
        for mv in controller.consider(&loads, router) {
            // Drain-and-switch handoff: the source already flushed at
            // this checkpoint, so the stream's admitted frames all have
            // release times; the barrier carries its last release to the
            // target so cross-node order is preserved.
            let barrier = nodes[mv.from].core.retire_stream(mv.stream);
            nodes[mv.to].core.adopt_stream(mv.stream, barrier);
            nodes[mv.from].migrations_out += 1;
            nodes[mv.to].migrations_in += 1;
            router.migrate(mv.stream, mv.to);
            migrations.push(MigrationEvent {
                at_seconds: t,
                stream: mv.stream,
                from_node: mv.from,
                to_node: mv.to,
                reason: if mv.degraded_source {
                    "degraded".into()
                } else if mv.forced {
                    "forced".into()
                } else {
                    "saturated".into()
                },
            });
            if let (Some(h), Some(ev)) = (&hub, migrations.last()) {
                h.push_event(ObsEvent::migration(
                    ev.at_seconds,
                    ev.from_node,
                    format!(
                        "stream {} -> node {} ({})",
                        ev.stream, ev.to_node, ev.reason
                    ),
                    ev.to_json(),
                ));
            }
        }
        recent_offered.clear();
    };

    // Replay the arrival schedule on the virtual clock.
    for a in &arrivals {
        while next_degradation < degradations.len()
            && degradations[next_degradation].at_seconds <= a.t
        {
            let d = degradations[next_degradation];
            if d.node < n_nodes {
                nodes[d.node].degrade(d.slowdown);
                if let Some(h) = &hub {
                    h.push_event(ObsEvent::degradation(
                        d.at_seconds,
                        d.node,
                        format!("slowdown x{}", d.slowdown),
                        obj(vec![("slowdown", num(d.slowdown))]),
                    ));
                }
            }
            next_degradation += 1;
        }
        let stream = a.client;
        let class = opts.clients[stream].class;
        let node = router.node_for(stream);
        nodes[node].offer(stream, a.seq, class, a.t, opts.max_backlog);
        *recent_offered.entry(stream).or_insert(0) += 1;
        offered_total += 1;
        window_offered += 1;
        since_check += 1;
        last_t = a.t;
        if since_check >= check_every {
            since_check = 0;
            checkpoint(
                a.t,
                &mut nodes,
                &mut router,
                &mut controller,
                &mut recent_offered,
                &mut window_t0,
                &mut window_offered,
                &mut shed_prev,
                &mut deliveries,
                &mut log_truncated,
                &mut windows,
                &mut migrations,
                &mut latency_all,
                &mut latency_class,
                &mut virtual_end,
                false,
            );
        }
    }
    // Final drain: everything still in flight releases (floor = last
    // arrival so stragglers cannot start in the past).
    checkpoint(
        last_t,
        &mut nodes,
        &mut router,
        &mut controller,
        &mut recent_offered,
        &mut window_t0,
        &mut window_offered,
        &mut shed_prev,
        &mut deliveries,
        &mut log_truncated,
        &mut windows,
        &mut migrations,
        &mut latency_all,
        &mut latency_class,
        &mut virtual_end,
        true,
    );

    // Rollup. Drain each node's recorded span log first (needs `&mut`,
    // before the shared borrows below).
    let timelines: Vec<(usize, Timeline)> = nodes
        .iter_mut()
        .map(|n| {
            (
                n.id,
                Timeline {
                    spans: n.core.take_spans(),
                },
            )
        })
        .collect();
    let virtual_seconds = virtual_end.max(f64::MIN_POSITIVE);
    let completed_total: usize = nodes.iter().map(|n| n.completed).sum();
    let shed_total: usize = nodes.iter().map(|n| n.shed).sum();
    debug_assert_eq!(offered_total, completed_total + shed_total);
    let node_reports: Vec<NodeReport> = nodes
        .iter()
        .map(|node| {
            let busy: Vec<(String, f64)> = node
                .unit_stats()
                .iter()
                .map(|u| (u.label.clone(), (u.busy_seconds / virtual_seconds).min(1.0)))
                .collect();
            let power_w = node.power_w(virtual_seconds);
            let fps = node.completed as f64 / virtual_seconds;
            NodeReport {
                node: node.id,
                profile: node.profile.name().into(),
                capacity_fps: node.capacity_fps,
                health: node.health().name().into(),
                offered: node.offered,
                completed: node.completed,
                shed: node.shed,
                fps,
                engine_busy: busy,
                power_w,
                fps_per_watt: fps / power_w.max(f64::MIN_POSITIVE),
                energy_per_frame_j: crate::cost::power::PowerModel::energy_per_frame(
                    power_w, fps,
                ),
                migrations_in: node.migrations_in,
                migrations_out: node.migrations_out,
            }
        })
        .collect();
    let class_name = |c: usize| {
        opts.class_names
            .get(c)
            .cloned()
            .unwrap_or_else(|| format!("class{c}"))
    };
    let mut class_ids: Vec<usize> = latency_class.keys().copied().collect();
    class_ids.sort_unstable();
    let classes: Vec<ClassLatency> = class_ids
        .into_iter()
        .map(|c| {
            let (completed, lat) = &latency_class[&c];
            ClassLatency {
                name: class_name(c),
                completed: *completed,
                latency_ms_p50: lat.percentile(50.0) * 1e3,
                latency_ms_p95: lat.percentile(95.0) * 1e3,
                latency_ms_p99: lat.percentile(99.0) * 1e3,
            }
        })
        .collect();

    Ok(FleetReport {
        nodes: node_reports,
        windows,
        classes,
        migrations,
        offered: offered_total,
        completed: completed_total,
        shed: shed_total,
        streams: opts.clients.len(),
        fps: completed_total as f64 / virtual_seconds,
        latency_ms_p50: latency_all.percentile(50.0) * 1e3,
        latency_ms_p95: latency_all.percentile(95.0) * 1e3,
        latency_ms_p99: latency_all.percentile(99.0) * 1e3,
        virtual_seconds,
        wall_seconds: wall_start.elapsed().as_secs_f64(),
        deliveries,
        deliveries_truncated: log_truncated,
        stages: hub.as_ref().map(|h| h.stages.breakdown()),
        timelines,
    })
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::serve::clients::ArrivalProcess;

    fn small_opts() -> FleetOptions {
        let mut opts = FleetOptions::new(vec![NodeProfile::Orin, NodeProfile::Xavier]);
        opts.check_every = 32;
        opts.plan_frames = 16;
        for i in 0..4 {
            opts.clients.push(ClientSpec::new(
                format!("c{i}"),
                40,
                ArrivalProcess::Poisson { rate_fps: 200.0 },
            ));
        }
        opts
    }

    #[test]
    fn fleet_conserves_frames_end_to_end() {
        let rep = run_fleet(&small_opts()).unwrap();
        assert_eq!(rep.offered, 160);
        assert_eq!(rep.offered, rep.completed + rep.shed);
        assert_eq!(rep.shed, 0, "unlimited backlog never sheds");
        assert_eq!(rep.nodes.len(), 2);
        assert_eq!(rep.streams, 4);
        assert!(rep.fps > 0.0 && rep.latency_ms_p99.is_finite());
        // windowed ledger sums to the run ledger
        let w_off: usize = rep.windows.iter().map(|w| w.offered).sum();
        let w_done: usize = rep.windows.iter().map(|w| w.completed).sum();
        let w_shed: usize = rep.windows.iter().map(|w| w.shed).sum();
        assert_eq!(w_off, rep.offered);
        assert_eq!(w_done, rep.completed);
        assert_eq!(w_shed, rep.shed);
        // power satellite: every node reports a positive draw and a
        // finite efficiency
        for n in &rep.nodes {
            assert!(n.power_w > 0.0);
            assert!(n.fps_per_watt >= 0.0 && n.fps_per_watt.is_finite());
        }
        crate::config::json::Json::parse(&rep.to_json().to_compact()).unwrap();
    }

    #[test]
    fn degradation_and_forced_migration_keep_conservation() {
        let mut opts = small_opts();
        opts.migration.force_every_checks = Some(1);
        opts.degradations.push(DegradationEvent {
            at_seconds: 0.02,
            node: 0,
            slowdown: 10.0,
        });
        let rep = run_fleet(&opts).unwrap();
        assert_eq!(rep.offered, rep.completed + rep.shed);
        assert!(!rep.migrations.is_empty(), "forced cadence must move streams");
        let moved_in: usize = rep.nodes.iter().map(|n| n.migrations_in).sum();
        let moved_out: usize = rep.nodes.iter().map(|n| n.migrations_out).sum();
        assert_eq!(moved_in, moved_out);
        assert_eq!(moved_in, rep.migrations.len());
    }

    #[test]
    fn backlog_cap_sheds_but_ledger_balances() {
        let mut opts = small_opts();
        opts.max_backlog = 8;
        opts.clients = vec![ClientSpec::new(
            "burst",
            300,
            ArrivalProcess::Burst {
                burst_fps: 5000.0,
                burst_len: 100,
                idle_seconds: 0.001,
            },
        )];
        let rep = run_fleet(&opts).unwrap();
        assert!(rep.shed > 0, "a 5000 fps burst into an 8-frame cap must shed");
        assert_eq!(rep.offered, rep.completed + rep.shed);
    }

    #[test]
    fn observed_fleet_records_stages_events_and_timelines() {
        let mut opts = small_opts();
        opts.migration.force_every_checks = Some(1);
        opts.degradations.push(DegradationEvent {
            at_seconds: 0.02,
            node: 0,
            slowdown: 10.0,
        });
        let hub = Arc::new(ObsHub::new());
        opts.obs = Some(Arc::clone(&hub));
        opts.record_spans = true;
        let rep = run_fleet(&opts).unwrap();
        assert_eq!(rep.offered, rep.completed + rep.shed);
        // every delivered frame folded its virtual stage stamps, monotone
        let st = rep.stages.as_ref().expect("observed run carries stages");
        assert_eq!(st.frames as usize, rep.completed);
        assert_eq!(st.non_monotone, 0);
        // structured event log mirrors the report's own ledgers
        use crate::obs::EventKind;
        assert_eq!(hub.events_of(EventKind::Migration), rep.migrations.len());
        assert_eq!(hub.events_of(EventKind::Degradation), 1);
        // checkpoint-aligned snapshots: at least one per fleet checkpoint
        assert!(hub.snapshot_count() > 0);
        // span log drained into per-node timelines
        assert_eq!(rep.timelines.len(), rep.nodes.len());
        let spans: usize = rep.timelines.iter().map(|(_, tl)| tl.spans.len()).sum();
        assert!(spans > 0, "record_spans must capture dispatches");
        // unobserved runs stay clean
        let plain = run_fleet(&small_opts()).unwrap();
        assert!(plain.stages.is_none());
        assert!(plain.timelines.iter().all(|(_, tl)| tl.spans.is_empty()));
    }

    #[test]
    fn empty_fleet_or_load_is_rejected() {
        assert!(run_fleet(&FleetOptions::new(vec![])).is_err());
        let opts = FleetOptions::new(vec![NodeProfile::Orin]);
        assert!(run_fleet(&opts).is_err(), "no clients is an error");
    }
}
