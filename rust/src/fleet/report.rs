//! Fleet rollup: per-node serve telemetry aggregated into one report.
//!
//! The single-node serve report answers "how is this SoC doing"; the
//! fleet report answers "how is the *deployment* doing" — aggregate FPS
//! across nodes, per-QoS-class latency percentiles over every delivery,
//! per-node engine busy fractions fed through each profile's power rails
//! (so rankings can be FPS-per-watt, the metric that actually sizes an
//! edge fleet), and the migration event log that explains any step
//! changes in the windowed series.

// Fleet report assembly.
#![deny(clippy::unwrap_used)]

use crate::config::json::{arr, num, obj, s, Json};
use crate::fleet::migrate::MigrationEvent;
use crate::fleet::vclock::Delivery;
use crate::obs::StageBreakdown;
use crate::sim::timeline::Timeline;

/// One node's end-of-run summary.
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub node: usize,
    /// SoC profile name ("orin" / "xavier").
    pub profile: String,
    /// Planner-predicted capacity at boot, fps.
    pub capacity_fps: f64,
    /// Final health ("healthy" / "saturated" / "degraded").
    pub health: String,
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    /// Completions per virtual second of fleet run.
    pub fps: f64,
    /// Busy fraction per physical unit over the run.
    pub engine_busy: Vec<(String, f64)>,
    /// Estimated average draw (busy fractions × profile rails), watts.
    pub power_w: f64,
    /// Delivered throughput per watt — the fleet ranking metric.
    pub fps_per_watt: f64,
    /// Joules per delivered frame.
    pub energy_per_frame_j: f64,
    pub migrations_in: usize,
    pub migrations_out: usize,
}

impl NodeReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("node", num(self.node as f64)),
            ("profile", s(&self.profile)),
            ("capacity_fps", num(self.capacity_fps)),
            ("health", s(&self.health)),
            ("offered", num(self.offered as f64)),
            ("completed", num(self.completed as f64)),
            ("shed", num(self.shed as f64)),
            ("fps", num(self.fps)),
            ("power_w", num(self.power_w)),
            ("fps_per_watt", num(self.fps_per_watt)),
            ("energy_per_frame_j", num(self.energy_per_frame_j)),
            ("migrations_in", num(self.migrations_in as f64)),
            ("migrations_out", num(self.migrations_out as f64)),
            (
                "engines",
                arr(self
                    .engine_busy
                    .iter()
                    .map(|(label, busy)| {
                        obj(vec![("unit", s(label)), ("busy_frac", num(*busy))])
                    })
                    .collect()),
            ),
        ])
    }
}

/// One fleet-wide checkpoint window on the virtual clock.
#[derive(Debug, Clone)]
pub struct FleetWindow {
    pub t0: f64,
    pub t1: f64,
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    /// Fleet deliveries per virtual second in this window.
    pub fps: f64,
    pub latency_ms_p99: f64,
    /// Deliveries per node in this window (indexed by node id).
    pub node_completed: Vec<usize>,
}

impl FleetWindow {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("t0", num(self.t0)),
            ("t1", num(self.t1)),
            ("offered", num(self.offered as f64)),
            ("completed", num(self.completed as f64)),
            ("shed", num(self.shed as f64)),
            ("fps", num(self.fps)),
            ("latency_ms_p99", num(self.latency_ms_p99)),
            (
                "node_completed",
                arr(self.node_completed.iter().map(|&n| num(n as f64)).collect()),
            ),
        ])
    }
}

/// Latency rollup for one QoS class over the whole run.
#[derive(Debug, Clone)]
pub struct ClassLatency {
    pub name: String,
    pub completed: usize,
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
    pub latency_ms_p99: f64,
}

impl ClassLatency {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("class", s(&self.name)),
            ("completed", num(self.completed as f64)),
            ("latency_ms_p50", num(self.latency_ms_p50)),
            ("latency_ms_p95", num(self.latency_ms_p95)),
            ("latency_ms_p99", num(self.latency_ms_p99)),
        ])
    }
}

/// The full fleet run summary.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub nodes: Vec<NodeReport>,
    pub windows: Vec<FleetWindow>,
    pub classes: Vec<ClassLatency>,
    pub migrations: Vec<MigrationEvent>,
    /// Whole-run conservation ledger: `offered == completed + shed`.
    pub offered: usize,
    pub completed: usize,
    pub shed: usize,
    /// Client streams served.
    pub streams: usize,
    /// Aggregate fleet throughput over the serving span, virtual fps.
    pub fps: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p95: f64,
    pub latency_ms_p99: f64,
    /// Virtual span of the run (last release time).
    pub virtual_seconds: f64,
    /// Host wall time spent simulating (the executor's own cost).
    pub wall_seconds: f64,
    /// Retained delivery log (oldest first, capped by the run options).
    pub deliveries: Vec<Delivery>,
    /// Deliveries dropped from the log by the cap (counters unaffected).
    pub deliveries_truncated: usize,
    /// Fleet-wide frame-lifecycle stage breakdown — present when the run
    /// carried a [`crate::obs::ObsHub`] (see `FleetOptions::obs`).
    pub stages: Option<StageBreakdown>,
    /// Per-node virtual execution spans `(node_id, timeline)` — populated
    /// when `FleetOptions::record_spans` is on. Not serialized (the span
    /// log can dwarf the report); the Chrome trace exporter consumes it.
    pub timelines: Vec<(usize, Timeline)>,
}

impl FleetReport {
    /// Nodes ranked by FPS-per-watt, best first (ties by node id).
    pub fn ranking(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.nodes.len()).collect();
        order.sort_by(|&a, &b| {
            self.nodes[b]
                .fps_per_watt
                .partial_cmp(&self.nodes[a].fps_per_watt)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(self.nodes[a].node.cmp(&self.nodes[b].node))
        });
        order
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("offered", num(self.offered as f64)),
            ("completed", num(self.completed as f64)),
            ("shed", num(self.shed as f64)),
            ("streams", num(self.streams as f64)),
            ("fps", num(self.fps)),
            ("latency_ms_p50", num(self.latency_ms_p50)),
            ("latency_ms_p95", num(self.latency_ms_p95)),
            ("latency_ms_p99", num(self.latency_ms_p99)),
            ("virtual_seconds", num(self.virtual_seconds)),
            ("wall_seconds", num(self.wall_seconds)),
            ("migration_count", num(self.migrations.len() as f64)),
            (
                "ranking",
                arr(self.ranking().iter().map(|&i| num(i as f64)).collect()),
            ),
            (
                "nodes",
                arr(self.nodes.iter().map(|n| n.to_json()).collect()),
            ),
            (
                "windows",
                arr(self.windows.iter().map(|w| w.to_json()).collect()),
            ),
            (
                "classes",
                arr(self.classes.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "migrations",
                arr(self.migrations.iter().map(|m| m.to_json()).collect()),
            ),
            (
                "deliveries_truncated",
                num(self.deliveries_truncated as f64),
            ),
        ];
        if let Some(st) = &self.stages {
            pairs.push(("stages", st.to_json()));
        }
        obj(pairs)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn node(id: usize, fpw: f64) -> NodeReport {
        NodeReport {
            node: id,
            profile: "orin".into(),
            capacity_fps: 150.0,
            health: "healthy".into(),
            offered: 100,
            completed: 100,
            shed: 0,
            fps: 90.0,
            engine_busy: vec![("GPU".into(), 0.5)],
            power_w: 10.0,
            fps_per_watt: fpw,
            energy_per_frame_j: 0.11,
            migrations_in: 0,
            migrations_out: 0,
        }
    }

    #[test]
    fn ranking_orders_by_fps_per_watt() {
        let rep = FleetReport {
            nodes: vec![node(0, 5.0), node(1, 9.0), node(2, 7.0)],
            windows: vec![],
            classes: vec![],
            migrations: vec![],
            offered: 300,
            completed: 300,
            shed: 0,
            streams: 3,
            fps: 270.0,
            latency_ms_p50: 5.0,
            latency_ms_p95: 9.0,
            latency_ms_p99: 11.0,
            virtual_seconds: 1.1,
            wall_seconds: 0.01,
            deliveries: vec![],
            deliveries_truncated: 0,
            stages: None,
            timelines: vec![],
        };
        assert_eq!(rep.ranking(), vec![1, 2, 0]);
        let txt = rep.to_json().to_compact();
        let doc = crate::config::json::Json::parse(&txt).unwrap();
        assert_eq!(doc.get("migration_count").unwrap().as_f64(), Some(0.0));
        assert!(doc.get("nodes").unwrap().as_arr().is_some());
    }
}
