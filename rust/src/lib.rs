//! # edgepipe
//!
//! Reproduction of *"Edge GPU Aware Multiple AI Model Pipeline for
//! Accelerated MRI Reconstruction and Analysis"* (Abdul Majeed, Meribout,
//! Mohammed Sali — CS.AR 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! The paper runs a Pix2Pix CT→MRI GAN and a YOLOv8 stroke detector
//! concurrently on an NVIDIA Jetson's GPU + DLA, makes the GAN fully
//! DLA-compatible by replacing deconvolution padding (Cropping / VALID-conv
//! surgery), and schedules the two models HaX-CoNN-style so both engines
//! stay busy (~150 FPS each).
//!
//! ## Serving entry point
//!
//! Pipelines are described declaratively and launched through the
//! composable [`session::Session`] API — any number of model instances,
//! any routing/batching mix, on a pluggable
//! [`pipeline::backend::InferenceBackend`]:
//!
//! ```no_run
//! use edgepipe::pipeline::router::RoutePolicy;
//! use edgepipe::pipeline::spec::InstanceSpec;
//! use edgepipe::session::Session;
//!
//! let report = Session::builder()
//!     .instance(InstanceSpec::new("gan", "gen_cropping").scored(true))
//!     .instance(InstanceSpec::new("yolo", "yolo_lite"))
//!     .route(RoutePolicy::Fanout)
//!     .frames(256)
//!     .build()?   // fail-fast: spec + backend validated before any thread spawns
//!     .run()?;
//! println!("total {:.1} fps ({} dropped)", report.total_fps(), report.dropped);
//! # Ok::<(), edgepipe::Error>(())
//! ```
//!
//! The default backend executes AOT-compiled PJRT artifacts
//! ([`pipeline::backend::PjrtBackend`]); swap in
//! [`pipeline::backend::SimBackend`] to drive the identical coordinator
//! from the calibrated latency model with no artifacts on disk. The old
//! `Workload` enum arms survive as presets that lower into specs
//! (`Workload::GanPlusYolo.spec(variant)`, or
//! `Session::builder().workload(...)`).
//!
//! ## Frame data path (zero-copy, engine-arbitrated)
//!
//! Pixel planes travel the pipeline as [`pipeline::plane::FramePlane`]s
//! behind `Arc`. Routing a frame to several instances (fanout) bumps
//! refcounts instead of copying the W×H plane; the synthetic source
//! recycles its sealed plane buffers through a
//! [`pipeline::plane::PlanePool`] instead of re-allocating them per
//! frame. A plane is
//! copied exactly once per inference — when a backend writes its output
//! tensor out — and never on route, enqueue, or batch (the sim backend
//! even echoes the input plane by refcount). Ground truth rides only the
//! copies headed to fidelity-scoring instances. Workers drain the batcher
//! and execute each batch as **one** dispatch through
//! [`pipeline::backend::ModelRunner::execute_batch`], so `max_batch > 1`
//! reduces dispatch count and amortizes per-dispatch launch overhead and
//! weight traffic (priced by
//! [`pipeline::backend::SimBackend::batch_latency`]; stacked into a
//! single PJRT transfer + execute on the real path).
//!
//! Every dispatch executes under an exclusive lease on its instance's
//! physical engine unit (GPU, DLA0, DLA1) from the run's shared
//! [`pipeline::engines::EngineArbiter`] — engine placement is enforced in
//! serving, not just in the simulator: same-unit instances serialize,
//! split placements run concurrently under the PCCS memory-contention
//! slowdown, occupant switches pay the reformat cost, and the recorded
//! serving timeline yields the per-engine utilization/idle-gap statistics
//! on [`pipeline::driver::PipelineReport`]. The `hotpath` bench records
//! this contract (and the per-engine utilization figures) in a
//! machine-readable `BENCH_hotpath.json`.
//!
//! ## The k-space acquisition front-end
//!
//! The paper's pipeline starts from an already-reconstructed image;
//! accelerated MRI starts earlier, at undersampled k-space. The spec's
//! [`pipeline::spec::SourceSpec`] selects the acquisition front door:
//! `Phantom` (the default synthetic slices) or `Kspace`, which weights
//! each slice by SoS-normalized multi-coil sensitivity maps, transforms
//! it per coil with the dependency-free radix-2 [`imaging::fft::Fft2`],
//! keeps every R-th phase-encode row plus a wrapped auto-calibration
//! band ([`imaging::kspace::Acquisition`]), and reconstructs the image
//! the model chain consumes — zero-filled, or GRAPPA missing-row
//! synthesis via [`imaging::grappa::GrappaKernel`]. The source scores
//! each reconstruction against the fully-sampled slice through the same
//! [`pipeline::metrics::FidelitySink`] the serving workers use, so the
//! report's `recon` section is directly comparable to the per-instance
//! fidelity columns; the placement planner prices the per-frame recon
//! cost ([`pipeline::spec::SourceSpec::recon_seconds`]) into admission
//! pacing and the latency budget, and the fleet virtual clock delays
//! dispatch eligibility by the same figure. `tests/prop_kspace.rs` pins
//! the FFT against its scalar oracle bit-exactly and the GRAPPA >
//! zero-filled fidelity ordering at R = 2 and 4.
//!
//! ## Batch run vs serve loop
//!
//! There are two ways to drive the coordinator. A **batch run**
//! ([`session::Session::run`]) streams a fixed frame count through one
//! spec and exits — the benchmarking shape. The **serve loop**
//! ([`serve::serve`]) is the deployment shape: an open-ended front-end
//! fed by concurrent synthetic client streams (Poisson / burst / ramp
//! arrival processes, per-client frame budgets), guarded by per-class
//! QoS admission control (token-bucket rate limits plus deadline-aware
//! shedding — refusals surface as `shed`, never as the pipeline's
//! overload `dropped`), and observed through rolling telemetry windows
//! (windowed FPS, p50/p95/p99 latency, per-engine busy fractions cut
//! from the arbiter's live timeline). Both drive the same
//! `StreamCore` — every line of routing, backpressure, batching and
//! engine-arbitration semantics is shared.
//!
//! The serve loop is also where the [`placement`] planner becomes
//! load-bearing at *runtime*: a [`serve::replan`] controller watches the
//! windows, re-invokes the placement search against the observed load
//! when engines idle or backlog builds, and swaps the winning spec in at
//! a frame boundary via a drain-and-switch handoff (the old core
//! completes every admitted frame before the new one takes over; switch
//! events are recorded in the merged serving timeline and the report).
//!
//! ## Single node vs fleet
//!
//! Everything above is *one* SoC. The [`fleet`] layer scales the same
//! pieces to a cluster: N simulated Jetson nodes (mixed Xavier/Orin
//! profiles), each running plan-on-boot placement and serving its
//! planned spec, behind a consistent-hash front door that pins client
//! streams to nodes ([`fleet::router::StreamRouter`]). Because a real
//! thread-per-worker core caps a process at a few dozen streams, fleet
//! nodes serve on an **event-driven virtual-clock executor**
//! ([`fleet::vclock::VirtualCore`]): the same pricing tables and
//! replay rules as the placement scorer's dry run (exclusive units,
//! PCCS contention, occupant-switch reformat costs, route fan-out with
//! lossless primaries), but advanced by events instead of sleeps — so
//! thousands of concurrent streams cost a heap push each and the whole
//! cluster runs single-threaded in virtual time. The threaded
//! `StreamCore` path remains the engine for single-node `run`/`serve`;
//! the two paths read one hardware model and predict the same
//! throughput. On top of that executor, [`fleet::migrate`] lifts the
//! serve loop's drain-and-switch handoff to *cross-node stream
//! migration* (flush the source, carry a release barrier to the
//! target — no frame lost, duplicated, or reordered), and
//! [`fleet::report`] rolls per-node telemetry, power draw from
//! [`cost::power`], and FPS-per-watt rankings into one cluster report
//! (the `fleet` CLI subcommand and `report fleet` section).
//!
//! ## Planning vs serving
//!
//! Placement does not have to be hand-written: the [`placement`] planner
//! *searches* the space of pipeline configurations (GAN surgery variant,
//! engine unit per instance, `max_batch`, route policy) and returns the
//! spec predicted to maximize throughput under a per-frame latency budget
//! and a no-GPU-fallback constraint. The flow is **plan → spec →
//! session**: `placement::plan(request)` prices candidates in virtual
//! time over the same cost model the serving arbiter charges (no backend
//! runs during planning), the winning [`pipeline::spec::PipelineSpec`]
//! travels as JSON (`PipelineSpec::to_json` reloads through the existing
//! [`config`] parser — the `plan --emit-spec` CLI path) or directly via
//! [`session::PipelineBuilder::auto_place`], and serving then *enforces*
//! what planning predicted. Planning is prediction, serving is
//! enforcement; both read one hardware model, so they cannot drift.
//!
//! ## Performance: parallel kernels and the bench baseline
//!
//! The imaging kernels (sobel, gaussian/canny, median, histogram
//! equalization, DCT, SSIM/MSE) are restructured for data parallelism
//! and autovectorization: flat row slices with the clamped-border
//! handling hoisted out of the interior loop, Huang's sliding-histogram
//! median instead of a per-pixel partial sort, and a fused summed-area
//! table for SSIM. Row/band iteration runs across threads under the
//! default-on **`parallel`** feature via the dependency-free scoped-thread
//! helpers in [`util::parallel`] (`EDGEPIPE_THREADS=N` pins the thread
//! count; the feature disabled, or `EDGEPIPE_THREADS=1`, degenerates the
//! same code path to pure serial loops). Outputs are deterministic either
//! way: per-pixel kernels write disjoint bands and preserve the scalar
//! reference's exact f32 accumulation order (bit-identical), and the
//! SSIM/MSE reductions fold band partials in band order. The original
//! scalar loops live on in [`imaging::reference`] as equivalence oracles
//! (`tests/prop_imaging.rs`) and bench baselines.
//!
//! The `hotpath` bench times each optimized kernel against its scalar
//! reference on 512×512 frames (`img_*` cases: per-megapixel throughput
//! plus a recorded `speedup_vs_scalar`) alongside the routing/dispatch/
//! serve cases, and writes `BENCH_hotpath.json`. CI's `bench-smoke` job
//! re-runs it in short mode and **fails on regression** against the
//! committed baseline (normalized by single-threaded anchor cases so
//! runner speed cancels out; parallel-dependent cases get a looser
//! bound). To refresh the baseline after an intentional perf change, run
//! `EDGEPIPE_BENCH_SMOKE=1 cargo bench --no-default-features --features
//! parallel --bench hotpath` on the CI runner class (or take the job's
//! artifact) and commit the regenerated `rust/BENCH_hotpath.json`.
//!
//! ## Observability: tracing vs telemetry vs reports
//!
//! Three observation surfaces, three jobs. **Telemetry**
//! ([`serve::telemetry`]) is the control input: rolling completion
//! windows the re-plan controller and fleet health checks consume
//! online — windowed, ring-buffered, lossy by design. **Reports**
//! ([`pipeline::driver::PipelineReport`], [`serve::ServeReport`],
//! [`fleet::report::FleetReport`]) are end-of-run aggregates:
//! percentiles and utilization tables that summarize but cannot show
//! *when* anything happened. **Tracing** ([`obs`]) is the artifact
//! surface: every frame carries cumulative [`obs::StageStamps`]
//! (source → admission → batcher queue → engine wait → reformat →
//! dispatch → write-out) folded into lock-free per-stage histograms; a
//! metrics [`obs::Registry`] (counters/gauges/histograms, O(1) relaxed
//! atomics on the hot path) renders Prometheus-style text or
//! checkpoint-aligned JSONL snapshots (`--metrics-out`) interleaved
//! with a structured event log (replans, migrations, degradations,
//! shed bursts); and `--trace-out` serializes the engine-unit span
//! timelines — one [`sim::timeline::Span`] schema shared by the
//! arbiter, the fleet virtual clock, and the placement scorer — into
//! Chrome/Perfetto trace JSON via [`obs::ChromeTrace`]. All of it is
//! opt-in per run (`ObsHub` absent ⇒ zero overhead) and the traced hot
//! path is bench-gated to stay within a few percent of untraced.
//!
//! ## Static analysis & invariants
//!
//! The guarantees above — the per-frame loop never panics or allocates,
//! locks are acquired in one declared global order, every counter a
//! report struct grows reaches its JSON writer, model-time and
//! wall-clock units never mix silently, every `parallel` code path has
//! a serial twin — are invariants the type system cannot express. The
//! [`analysis`] module is a dependency-free static analyzer
//! (`edgepipe-lint`, run as `cargo run --bin lint -- rust/src` and in
//! CI) that machine-checks all six over the crate's own token stream,
//! driven by the checked-in policy manifests in [`analysis::hotpath`].
//! Intentional exceptions carry an inline `// lint:allow(rule-name)`
//! with a justification. The companion [`util::lock`] helpers
//! (`relock`, `cv_wait`) give the serving path poison-tolerant locking,
//! so a panicked worker cannot cascade into every thread that later
//! touches the same mutex, and the hot-path modules deny
//! `clippy::unwrap_used` outright.
//!
//! ## Layers
//!
//! * [`analysis`] — the `edgepipe-lint` static analyzer: lexer, rule
//!   passes, and the invariant manifests they enforce;
//! * [`graph`] — layer-graph IR with shape inference and the paper's
//!   model-surgery passes;
//! * [`models`] — Pix2Pix (all three variants), a YOLOv8-style detector and
//!   the reference backbones, built layer-for-layer at paper scale;
//! * [`dla`] — the DLA compatibility rule engine and a TensorRT-like
//!   subgraph planner with GPU fallback;
//! * [`cost`] + [`hw`] — calibrated per-layer latency, memory-contention
//!   and power models for Jetson AGX Xavier / Orin;
//! * [`sched`] — naive, Jedi-like and HaX-CoNN schedulers;
//! * [`sim`] — a discrete-event SoC simulator producing Nsight-like
//!   timelines (the hardware substitute — see DESIGN.md);
//! * [`runtime`] — PJRT execution of AOT-compiled JAX/Pallas artifacts
//!   (HLO text + weights), Python never on the request path;
//! * [`obs`] — the unified observability layer: frame-stage stamps and
//!   histograms, the metrics registry with Prometheus/JSONL exposition,
//!   the structured event log, and Chrome/Perfetto trace export;
//! * [`pipeline`] — the streaming coordinator (sources → batcher → router →
//!   instance workers → sinks) plus the declarative [`pipeline::spec`],
//!   pluggable [`pipeline::backend`], and the exclusive-engine
//!   [`pipeline::engines`] arbiter;
//! * [`placement`] — the auto-placement planner: candidate enumeration
//!   with DLA-fallback pruning, virtual-time scoring, and the ranked
//!   search behind the `plan` CLI and `PipelineBuilder::auto_place`;
//! * [`session`] — the `PipelineBuilder` → `Session` facade that binds
//!   spec to backend with fail-fast validation;
//! * [`serve`] — the long-running serving front-end: synthetic client
//!   load generation, QoS admission control, rolling telemetry windows,
//!   and online re-planning with drain-and-switch spec handoff;
//! * [`fleet`] — the multi-node cluster layer: virtual-clock node
//!   executors, consistent-hash stream routing, cross-node stream
//!   migration, and the FPS-per-watt fleet rollup;
//! * [`imaging`], [`postproc`] — phantoms, PSNR/SSIM/MSE, the Table I
//!   classical algorithms, YOLO decode + NMS;
//! * [`report`] — regenerates every table and figure of the paper.

pub mod analysis;
pub mod config;
pub mod cost;
pub mod dla;
pub mod error;
pub mod fleet;
pub mod graph;
pub mod hw;
pub mod imaging;
pub mod models;
pub mod obs;
pub mod pipeline;
pub mod placement;
pub mod postproc;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod session;
pub mod sim;
pub mod util;

pub use error::{Error, Result};
pub use session::{PipelineBuilder, Session};
