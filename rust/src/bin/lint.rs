//! `edgepipe-lint` CLI: run the project-invariant analyzer over a
//! source tree.
//!
//! ```text
//! cargo run --bin lint -- rust/src        # CI invocation (repo root)
//! cargo run --bin lint -- src             # from inside rust/
//! ```
//!
//! Prints one `file:line: [rule] message` per finding and exits 1 when
//! any finding survives the `// lint:allow(rule)` escape hatches, 2 on
//! I/O errors, 0 on a clean tree.

use std::path::Path;
use std::process::ExitCode;

use edgepipe::analysis;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let root = args.first().map(String::as_str).unwrap_or("rust/src");
    if args.iter().any(|a| a == "--help" || a == "-h") {
        eprintln!("usage: lint [PATH]   (default PATH: rust/src)");
        eprintln!("rules: {}", rule_list());
        return ExitCode::SUCCESS;
    }
    let path = Path::new(root);
    if !path.exists() {
        eprintln!("lint: path not found: {root}");
        return ExitCode::from(2);
    }
    match analysis::analyze_tree(path) {
        Ok(diags) if diags.is_empty() => {
            println!("lint: clean ({})", rule_list());
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for d in &diags {
                println!("{d}");
            }
            println!("lint: {} finding(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("lint: io error walking {root}: {e}");
            ExitCode::from(2)
        }
    }
}

fn rule_list() -> String {
    let names: Vec<&str> = analysis::Rule::all().iter().map(|r| r.name()).collect();
    names.join(", ")
}
