//! YOLO post-processing: anchor-free decode + NMS.
//!
//! The detector head emits, per scale, a `(S, S, 4*reg_max + classes)` map
//! (DFL box distances + class logits). Decoding integrates the DFL bins
//! into left/top/right/bottom distances per cell, converts to boxes, and
//! non-maximum suppression keeps the best detections — all in rust on the
//! L3 path (the paper's diagnostic output).

use crate::util::stats::Summary;

/// A detection in pixel coordinates.
#[derive(Debug, Clone, PartialEq)]
pub struct Detection {
    pub x0: f32,
    pub y0: f32,
    pub x1: f32,
    pub y1: f32,
    pub score: f32,
    pub class: usize,
}

impl Detection {
    pub fn area(&self) -> f32 {
        (self.x1 - self.x0).max(0.0) * (self.y1 - self.y0).max(0.0)
    }
}

/// Intersection-over-union of two boxes.
pub fn iou(a: &Detection, b: &Detection) -> f32 {
    let ix0 = a.x0.max(b.x0);
    let iy0 = a.y0.max(b.y0);
    let ix1 = a.x1.min(b.x1);
    let iy1 = a.y1.min(b.y1);
    let inter = (ix1 - ix0).max(0.0) * (iy1 - iy0).max(0.0);
    let union = a.area() + b.area() - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Decode one scale's head output.
///
/// `map` is `(s, s, 4*reg_max + classes)` row-major; `stride` is the pixel
/// stride of this scale (8/16/32). Returns raw candidates above
/// `conf_threshold`.
pub fn decode_scale(
    map: &[f32],
    s: usize,
    reg_max: usize,
    classes: usize,
    stride: f32,
    conf_threshold: f32,
) -> Vec<Detection> {
    let ch = 4 * reg_max + classes;
    assert_eq!(map.len(), s * s * ch, "head map size mismatch");
    let mut out = Vec::new();
    for gy in 0..s {
        for gx in 0..s {
            let base = (gy * s + gx) * ch;
            let cell = &map[base..base + ch];
            // class scores
            let (mut best_c, mut best_s) = (0usize, f32::NEG_INFINITY);
            for (c, &logit) in cell[4 * reg_max..].iter().enumerate() {
                if logit > best_s {
                    best_s = logit;
                    best_c = c;
                }
            }
            let score = sigmoid(best_s);
            if score < conf_threshold {
                continue;
            }
            // DFL: softmax-weighted expectation over bins for each side
            let mut dist = [0f32; 4];
            for (side, d) in dist.iter_mut().enumerate() {
                let bins = &cell[side * reg_max..(side + 1) * reg_max];
                let mx = bins.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = bins.iter().map(|&b| (b - mx).exp()).collect();
                let z: f32 = exps.iter().sum();
                *d = exps
                    .iter()
                    .enumerate()
                    .map(|(i, e)| i as f32 * e / z)
                    .sum();
            }
            let cx = (gx as f32 + 0.5) * stride;
            let cy = (gy as f32 + 0.5) * stride;
            out.push(Detection {
                x0: cx - dist[0] * stride,
                y0: cy - dist[1] * stride,
                x1: cx + dist[2] * stride,
                y1: cy + dist[3] * stride,
                score,
                class: best_c,
            });
        }
    }
    out
}

/// Greedy non-maximum suppression (per class).
pub fn nms(mut dets: Vec<Detection>, iou_threshold: f32) -> Vec<Detection> {
    dets.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    let mut keep: Vec<Detection> = Vec::new();
    for d in dets {
        if keep
            .iter()
            .all(|k| k.class != d.class || iou(k, &d) < iou_threshold)
        {
            keep.push(d);
        }
    }
    keep
}

/// Full postprocess over the three scales of the lite detector.
pub fn postprocess(
    scales: &[(Vec<f32>, usize, f32)], // (map, s, stride)
    reg_max: usize,
    classes: usize,
    conf_threshold: f32,
    iou_threshold: f32,
) -> Vec<Detection> {
    let mut all = Vec::new();
    for (map, s, stride) in scales {
        all.extend(decode_scale(map, *s, reg_max, classes, *stride, conf_threshold));
    }
    nms(all, iou_threshold)
}

/// Summarize detection confidences (for reports).
pub fn confidence_summary(dets: &[Detection]) -> Summary {
    let mut s = Summary::new();
    for d in dets {
        s.add(d.score as f64);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boxed(x0: f32, y0: f32, x1: f32, y1: f32, score: f32, class: usize) -> Detection {
        Detection { x0, y0, x1, y1, score, class }
    }

    #[test]
    fn iou_identity_and_disjoint() {
        let a = boxed(0.0, 0.0, 10.0, 10.0, 1.0, 0);
        assert!((iou(&a, &a) - 1.0).abs() < 1e-6);
        let b = boxed(20.0, 20.0, 30.0, 30.0, 1.0, 0);
        assert_eq!(iou(&a, &b), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        let a = boxed(0.0, 0.0, 10.0, 10.0, 1.0, 0);
        let b = boxed(5.0, 0.0, 15.0, 10.0, 1.0, 0);
        // inter 50, union 150
        assert!((iou(&a, &b) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn nms_suppresses_overlaps() {
        let dets = vec![
            boxed(0.0, 0.0, 10.0, 10.0, 0.9, 0),
            boxed(1.0, 1.0, 11.0, 11.0, 0.8, 0),
            boxed(20.0, 20.0, 30.0, 30.0, 0.7, 0),
        ];
        let kept = nms(dets, 0.5);
        assert_eq!(kept.len(), 2);
        assert_eq!(kept[0].score, 0.9);
        assert_eq!(kept[1].score, 0.7);
    }

    #[test]
    fn nms_is_per_class() {
        let dets = vec![
            boxed(0.0, 0.0, 10.0, 10.0, 0.9, 0),
            boxed(1.0, 1.0, 11.0, 11.0, 0.8, 1),
        ];
        assert_eq!(nms(dets, 0.5).len(), 2);
    }

    #[test]
    fn decode_finds_confident_cell() {
        let (s, reg_max, classes) = (4usize, 4usize, 1usize);
        let ch = 4 * reg_max + classes;
        let mut map = vec![0f32; s * s * ch];
        // cell (1, 2): strong class logit, uniform DFL bins
        let base = (2 * s + 1) * ch;
        map[base + 4 * reg_max] = 6.0; // sigmoid ~ 0.997
        // threshold 0.6: zero-logit cells (sigmoid 0.5) are filtered
        let dets = decode_scale(&map, s, reg_max, classes, 8.0, 0.6);
        assert_eq!(dets.len(), 1);
        let d = &dets[0];
        // centre of cell (1,2) at stride 8 = (12, 20)
        assert!((0.5 * (d.x0 + d.x1) - 12.0).abs() < 1e-3);
        assert!((0.5 * (d.y0 + d.y1) - 20.0).abs() < 1e-3);
        assert!(d.score > 0.99);
    }

    #[test]
    fn decode_threshold_filters_all_when_uniform() {
        let (s, reg_max, classes) = (2usize, 2usize, 2usize);
        let map = vec![0f32; s * s * (4 * reg_max + classes)];
        // all logits 0 -> score 0.5; threshold 0.6 filters everything
        assert!(decode_scale(&map, s, reg_max, classes, 8.0, 0.6).is_empty());
    }

    #[test]
    fn postprocess_merges_scales() {
        let (reg_max, classes) = (2usize, 1usize);
        let ch = 4 * reg_max + classes;
        let mut m1 = vec![0f32; 4 * ch];
        m1[4 * reg_max] = 5.0;
        let mut m2 = vec![0f32; ch];
        m2[4 * reg_max] = 5.0;
        let dets = postprocess(
            &[(m1, 2, 8.0), (m2, 1, 16.0)],
            reg_max,
            classes,
            0.5,
            0.5,
        );
        assert!(!dets.is_empty());
    }
}
