//! Artifact loading and execution.
//!
//! An [`Artifact`] is one compiled model: the PJRT executable built from
//! `<name>.hlo.txt` plus the device-resident weight literals from
//! `<name>.weights.bin`. `run_image` feeds a single NHWC frame and returns
//! the flattened outputs — the call the L3 hot path makes per frame.

use super::client::RuntimeClient;
use super::weights::WeightsFile;
use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One loaded model.
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Device-resident weight buffers in parameter order (uploaded once at
    /// load time — the request path only transfers the frame).
    weights: Vec<xla::PjRtBuffer>,
    client: xla::PjRtClient,
    /// Input image shape (N, H, W, C) from the meta side-car.
    pub input_shape: [usize; 4],
}

/// One named output tensor, flattened.
#[derive(Debug, Clone)]
pub struct OutputTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Artifact {
    /// Load `<dir>/<name>.{hlo.txt,weights.bin,meta.json}` and compile.
    pub fn load(client: &RuntimeClient, dir: &Path, name: &str) -> Result<Self> {
        let hlo = dir.join(format!("{name}.hlo.txt"));
        let wpath = dir.join(format!("{name}.weights.bin"));
        let meta_path = dir.join(format!("{name}.meta.json"));
        if !hlo.exists() {
            return Err(Error::Runtime(format!(
                "artifact `{name}` missing: {} (run `make artifacts`)",
                hlo.display()
            )));
        }
        let exe = client.compile_hlo_text(&hlo)?;
        let wfile = WeightsFile::load(&wpath)?;
        let mut weights = Vec::with_capacity(wfile.tensors.len());
        for t in &wfile.tensors {
            let buf = client
                .client
                .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)
                .map_err(|e| Error::Xla(e.to_string()))?;
            weights.push(buf);
        }

        // meta.json: {"input": [1, H, W, C], ...}
        let meta_text = std::fs::read_to_string(&meta_path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", meta_path.display())))?;
        let meta = crate::config::json::Json::parse(&meta_text)
            .map_err(|e| Error::Runtime(format!("meta.json: {e}")))?;
        let dims: Vec<usize> = meta
            .get("input")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Runtime("meta.json missing `input`".into()))?
            .iter()
            .filter_map(|v| v.as_u64().map(|d| d as usize))
            .collect();
        if dims.len() != 4 {
            return Err(Error::Runtime(format!("input rank {} != 4", dims.len())));
        }

        Ok(Artifact {
            name: name.to_string(),
            exe,
            weights,
            client: client.client.clone(),
            input_shape: [dims[0], dims[1], dims[2], dims[3]],
        })
    }

    /// Execute on one flattened NHWC frame. Returns every output tensor
    /// (the AOT export always lowers with `return_tuple=True`).
    pub fn run_image(&self, frame: &[f32]) -> Result<Vec<OutputTensor>> {
        let expect: usize = self.input_shape.iter().product();
        if frame.len() != expect {
            return Err(Error::Runtime(format!(
                "frame has {} elements, artifact `{}` expects {:?}",
                frame.len(),
                self.name,
                self.input_shape
            )));
        }
        let input = self
            .client
            .buffer_from_host_buffer::<f32>(frame, &self.input_shape, None)
            .map_err(|e| Error::Xla(e.to_string()))?;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
        args.push(&input);
        for w in &self.weights {
            args.push(w);
        }
        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| Error::Xla(format!("execute `{}`: {e}", self.name)))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Xla(e.to_string()))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| Error::Xla(e.to_string()))?;
        let mut outs = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape().map_err(|e| Error::Xla(e.to_string()))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| Error::Xla(e.to_string()))?;
            outs.push(OutputTensor { dims, data });
        }
        Ok(outs)
    }

    /// Execute `n` frames stacked along the leading batch dimension in
    /// **one** dispatch: a single host buffer, a single device transfer,
    /// a single execute. Only valid when the artifact was compiled with
    /// batch `n` (`input_shape[0] == n`); the pipeline backend zero-pads
    /// partial batches up to `n` before calling this.
    pub fn run_images_stacked(&self, stacked: &[f32], n: usize) -> Result<Vec<OutputTensor>> {
        if self.input_shape[0] != n {
            return Err(Error::Runtime(format!(
                "artifact `{}` compiled for batch {}, got a stack of {n}",
                self.name, self.input_shape[0]
            )));
        }
        // shape check (n * H * W * C) and execution are shared with the
        // single-frame path
        self.run_image(stacked)
    }

    pub fn weight_count(&self) -> usize {
        self.weights.len()
    }
}

/// All artifacts of one deployment, loaded once at startup.
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    artifacts: HashMap<String, Artifact>,
}

impl ArtifactRegistry {
    /// Load the named artifacts from `dir`.
    pub fn load(client: &RuntimeClient, dir: &Path, names: &[&str]) -> Result<Self> {
        let mut artifacts = HashMap::new();
        for &name in names {
            artifacts.insert(name.to_string(), Artifact::load(client, dir, name)?);
        }
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact `{name}` not loaded")))
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }
}
