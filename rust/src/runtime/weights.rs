//! `weights.bin` parser.
//!
//! Format written by `python/compile/aot.py::write_weights_bin`
//! (little-endian):
//!
//! ```text
//! magic  b"EPW1"
//! count  u32
//! per tensor: rank u32, dims u32*rank, data f32*prod(dims)
//! ```

use crate::error::{Error, Result};
use std::path::Path;

/// One weight tensor.
#[derive(Debug, Clone)]
pub struct WeightTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl WeightTensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// A parsed weights file.
#[derive(Debug, Clone)]
pub struct WeightsFile {
    pub tensors: Vec<WeightTensor>,
}

impl WeightsFile {
    /// Parse from raw bytes.
    pub fn parse(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > bytes.len() {
                return Err(Error::Runtime("weights.bin truncated".into()));
            }
            let s = &bytes[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let read_u32 = |pos: &mut usize| -> Result<u32> {
            let b = take(pos, 4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        };

        if take(&mut pos, 4)? != b"EPW1" {
            return Err(Error::Runtime("weights.bin: bad magic".into()));
        }
        let count = read_u32(&mut pos)? as usize;
        if count > 1_000_000 {
            return Err(Error::Runtime(format!("weights.bin: absurd count {count}")));
        }
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let rank = read_u32(&mut pos)? as usize;
            if rank > 8 {
                return Err(Error::Runtime(format!("weights.bin: rank {rank} > 8")));
            }
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims.push(read_u32(&mut pos)? as usize);
            }
            let numel: usize = dims.iter().product();
            let raw = take(&mut pos, numel * 4)?;
            let mut data = Vec::with_capacity(numel);
            for chunk in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
            }
            tensors.push(WeightTensor { dims, data });
        }
        if pos != bytes.len() {
            return Err(Error::Runtime(format!(
                "weights.bin: {} trailing bytes",
                bytes.len() - pos
            )));
        }
        Ok(WeightsFile { tensors })
    }

    /// Load from disk.
    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read(path)?)
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// Serialize back to bytes (round-trip support / tests).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"EPW1");
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            out.extend_from_slice(&(t.dims.len() as u32).to_le_bytes());
            for &d in &t.dims {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> WeightsFile {
        WeightsFile {
            tensors: vec![
                WeightTensor {
                    dims: vec![2, 3],
                    data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                },
                WeightTensor {
                    dims: vec![4],
                    data: vec![0.5; 4],
                },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let w = sample();
        let bytes = w.to_bytes();
        let back = WeightsFile::parse(&bytes).unwrap();
        assert_eq!(back.tensors.len(), 2);
        assert_eq!(back.tensors[0].dims, vec![2, 3]);
        assert_eq!(back.tensors[0].data, w.tensors[0].data);
        assert_eq!(back.param_count(), 10);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        assert!(WeightsFile::parse(&bytes).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let bytes = sample().to_bytes();
        assert!(WeightsFile::parse(&bytes[..bytes.len() - 2]).is_err());
        assert!(WeightsFile::parse(&bytes[..6]).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = sample().to_bytes();
        bytes.push(0);
        assert!(WeightsFile::parse(&bytes).is_err());
    }

    #[test]
    fn scalar_tensor() {
        let w = WeightsFile {
            tensors: vec![WeightTensor {
                dims: vec![],
                data: vec![42.0],
            }],
        };
        let back = WeightsFile::parse(&w.to_bytes()).unwrap();
        assert_eq!(back.tensors[0].numel(), 1);
        assert_eq!(back.tensors[0].data, vec![42.0]);
    }
}
