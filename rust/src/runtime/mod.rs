//! PJRT runtime — loads and executes the AOT-compiled JAX/Pallas artifacts.
//!
//! `python/compile/aot.py` lowers each model ONCE to HLO text plus a
//! `*.weights.bin` side-car; this module loads them through the `xla`
//! crate (`PjRtClient` → `HloModuleProto::from_text_file` → compile →
//! execute). Python is never on the request path: after `make artifacts`
//! the rust binary is self-contained.

pub mod artifact;
pub mod client;
pub mod weights;

pub use artifact::{Artifact, ArtifactRegistry};
pub use client::RuntimeClient;
pub use weights::WeightsFile;
