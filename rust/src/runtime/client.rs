//! PJRT client wrapper.
//!
//! Thin, panic-free wrapper over `xla::PjRtClient` that converts errors
//! into the library error type and centralizes the CPU-client setup used
//! by every executor. One client is shared per process (compilations and
//! buffers are tied to it).

use crate::error::{Error, Result};

/// A process-wide PJRT client handle.
pub struct RuntimeClient {
    pub client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| Error::Xla(e.to_string()))?;
        Ok(RuntimeClient { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Compile HLO text into an executable.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 path".into()))?,
        )
        .map_err(|e| Error::Xla(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| Error::Xla(format!("compile {}: {e}", path.display())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_boots() {
        let c = RuntimeClient::cpu().unwrap();
        assert_eq!(c.platform().to_lowercase(), "cpu");
        assert!(c.device_count() >= 1);
    }
}
