//! Discrete-event simulation of concurrent model execution on the SoC.
//!
//! This is the hardware substitute (DESIGN.md §2): it plays the role the
//! physical Jetson + DeepStream + Nsight stack plays in the paper. Model
//! instances stream frames through their scheduled engine segments; the two
//! engines are exclusive resources with FIFO queues; DLA-incompatible
//! layers inside DLA segments bounce to the GPU (fallback) exactly as the
//! TensorRT engine plan would; transitions pay reformat costs; concurrent
//! engine activity suffers PCCS memory contention. The produced
//! [`timeline::Timeline`] is the Nsight-equivalent artifact behind
//! Figs 13/14.

pub mod soc_sim;
pub mod timeline;

pub use soc_sim::{simulate, SimConfig, SimResult};
pub use timeline::{Span, Timeline};
