//! The discrete-event SoC simulator.
//!
//! Executes a [`Schedule`] over model graphs on the two-engine SoC model:
//! instances stream `frames` frames through their engine segments with
//! bounded pipelining, engines are exclusive FIFO resources, DLA fallback
//! sub-segments land on the GPU, inter-engine handoffs pay the reformat
//! cost, and concurrently-active engines slow each other down per the PCCS
//! contention model.

use super::timeline::{Span, Timeline};
use crate::cost::contention::{bandwidth_demand, memory_intensity, slowdown};
use crate::cost::flops::{aggregate_cost, node_cost};
use crate::cost::latency::layer_latency;
use crate::dla::rules::DlaVersion;
use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::hw::{EngineKind, SocSpec};
use crate::sched::{expand_fallback, Schedule};
use crate::util::stats::Summary;

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub soc: SocSpec,
    pub version: DlaVersion,
    /// Frames per instance.
    pub frames: usize,
    /// Maximum frames of one instance in flight (pipeline depth).
    pub max_inflight: usize,
    /// Record the full span timeline (disable for long benchmark runs).
    pub record_timeline: bool,
}

impl SimConfig {
    pub fn new(soc: SocSpec, frames: usize) -> Self {
        SimConfig {
            soc,
            version: DlaVersion::V2,
            frames,
            max_inflight: 4,
            record_timeline: true,
        }
    }
}

/// One executable step of an instance (post fallback expansion).
#[derive(Debug, Clone)]
struct Step {
    engine: EngineKind,
    /// Isolated duration, seconds.
    duration: f64,
    /// Aggregate cost (for contention estimates).
    intensity: f64,
    bw_demand: f64,
    /// Transition latency paid before this step when the previous step ran
    /// elsewhere.
    transition_in: f64,
}

/// Per-instance results.
#[derive(Debug, Clone)]
pub struct InstanceResult {
    pub label: String,
    pub frames: usize,
    pub fps: f64,
    /// Per-frame end-to-end latency statistics, seconds.
    pub latency: Summary,
    /// Engine where this instance spends most of its execution time —
    /// the column the paper's tables put it in.
    pub home_engine: EngineKind,
}

/// Full simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub instances: Vec<InstanceResult>,
    pub timeline: Timeline,
    pub makespan: f64,
}

impl SimResult {
    /// FPS of the instance whose home engine is `e` (paper table columns).
    pub fn fps_of_home(&self, e: EngineKind) -> Option<f64> {
        self.instances
            .iter()
            .find(|i| i.home_engine == e)
            .map(|i| i.fps)
    }
}

/// Run the simulation.
pub fn simulate(
    models: &[&Graph],
    schedule: &Schedule,
    cfg: &SimConfig,
) -> Result<SimResult> {
    // ---- Compile instances into step chains ----
    let mut chains: Vec<Vec<Step>> = Vec::new();
    let mut home_engines: Vec<EngineKind> = Vec::new();
    for inst in &schedule.instances {
        let graph = models
            .get(inst.model)
            .ok_or_else(|| Error::Sim(format!("instance `{}` references model {}", inst.label, inst.model)))?;
        inst.validate(graph.compute_layers().len())?;
        let mut steps: Vec<Step> = Vec::new();
        let mut prev_engine: Option<EngineKind> = None;
        let mut prev_bytes = 0usize;
        for seg in &inst.segments {
            for (engine, nodes) in expand_fallback(graph, seg, cfg.version) {
                let spec = cfg.soc.engine(engine);
                let agg = aggregate_cost(graph, &nodes);
                let duration: f64 = nodes
                    .iter()
                    .map(|&id| layer_latency(&node_cost(graph, id), spec))
                    .sum();
                let transition_in = match prev_engine {
                    Some(pe) if pe != engine => cfg.soc.transition.latency(prev_bytes),
                    _ => 0.0,
                };
                steps.push(Step {
                    engine,
                    duration,
                    intensity: memory_intensity(&agg, spec),
                    bw_demand: bandwidth_demand(&agg, spec),
                    transition_in,
                });
                prev_engine = Some(engine);
                prev_bytes = nodes
                    .last()
                    .map(|&id| graph.node(id).shape.bytes())
                    .unwrap_or(0);
            }
        }
        // Home engine: where the instance spends the most time (the
        // paper's table columns group instances by dominant engine).
        let mut tg = 0.0;
        let mut td = 0.0;
        for st in &steps {
            match st.engine {
                EngineKind::Gpu => tg += st.duration,
                _ => td += st.duration,
            }
        }
        home_engines.push(if tg >= td { EngineKind::Gpu } else { EngineKind::Dla });
        chains.push(steps);
    }

    // ---- Event-driven execution ----
    #[derive(Clone, Copy)]
    struct Pending {
        instance: usize,
        frame: usize,
        step: usize,
        ready: f64,
    }

    let n_inst = chains.len();
    let mut engine_free: [f64; 2] = [0.0, 0.0]; // [gpu, dla]
    let mut engine_cur: [(f64, f64, f64); 2] = [(0.0, 0.0, 0.0); 2]; // (t0, t1, bw) of job running
    let eidx = |e: EngineKind| match e {
        EngineKind::Gpu => 0usize,
        EngineKind::Dla => 1usize,
        _ => unreachable!("sim engines are GPU/DLA"),
    };

    // step completion times per (instance, frame, step); frames processed
    // in order per stage.
    let mut done_step: Vec<Vec<f64>> = chains
        .iter()
        .map(|c| vec![0.0f64; c.len()])
        .collect(); // last completion per stage
    let mut frame_done: Vec<Vec<f64>> = (0..n_inst)
        .map(|_| Vec::with_capacity(cfg.frames.min(1 << 20)))
        .collect();
    let mut timeline = Timeline::default();
    let mut pending: Vec<Pending> = Vec::new();

    // Seed: the first `max_inflight` frames of every instance (admission
    // control; further frames are admitted as frames complete).
    for i in 0..n_inst {
        if !chains[i].is_empty() {
            for f in 0..cfg.max_inflight.min(cfg.frames) {
                pending.push(Pending { instance: i, frame: f, step: 0, ready: 0.0 });
            }
        }
    }

    while let Some(best_idx) = {
        // Pick the dispatchable job with the earliest feasible start;
        // tie-break by (frame, step, instance) to keep FIFO order.
        let mut best: Option<(usize, (f64, usize, usize, usize))> = None;
        for (idx, p) in pending.iter().enumerate() {
            let st = &chains[p.instance][p.step];
            let e = eidx(st.engine);
            let start = p.ready.max(engine_free[e]);
            let key = (start, p.frame, p.step, p.instance);
            if best.map(|(_, bk)| key < bk).unwrap_or(true) {
                best = Some((idx, key));
            }
        }
        best.map(|(i, _)| i)
    } {
        let p = pending.swap_remove(best_idx);
        let st = &chains[p.instance][p.step];
        let e = eidx(st.engine);
        let other = 1 - e;
        // The reformat/fence of an engine handoff occupies the destination
        // engine before the compute starts (this is what punishes the
        // fragmented fallback plans — Fig 13).
        let start = p.ready.max(engine_free[e]);
        let exec_start = start + st.transition_in;

        // Contention: if the other engine is executing, stretch.
        let (ot0, ot1, obw) = engine_cur[other];
        let factor = if exec_start >= ot0 && exec_start < ot1 {
            slowdown(&cfg.soc, st.intensity, obw)
        } else {
            1.0
        };
        let duration = st.duration * factor;
        let end = exec_start + duration;
        engine_free[e] = end;
        engine_cur[e] = (exec_start, end, st.bw_demand);

        if cfg.record_timeline {
            if st.transition_in > 0.0 {
                timeline.push(Span {
                    engine: st.engine,
                    unit: 0,
                    instance: p.instance,
                    frame: p.frame,
                    t0: start,
                    t1: exec_start,
                    is_transition: true,
                });
            }
            timeline.push(Span {
                engine: st.engine,
                unit: 0,
                instance: p.instance,
                frame: p.frame,
                t0: exec_start,
                t1: end,
                is_transition: false,
            });
        }

        done_step[p.instance][p.step] = end;
        // Schedule the next step of this frame.
        if p.step + 1 < chains[p.instance].len() {
            let ready = end;
            // Stage FIFO kept via the dispatch tie-break.
            pending.push(Pending {
                instance: p.instance,
                frame: p.frame,
                step: p.step + 1,
                ready,
            });
        } else {
            frame_done[p.instance].push(end);
            // Backpressure admission: frame f's completion admits frame
            // f + max_inflight.
            let next_frame = p.frame + cfg.max_inflight;
            if next_frame < cfg.frames {
                pending.push(Pending {
                    instance: p.instance,
                    frame: next_frame,
                    step: 0,
                    ready: end,
                });
            }
        }
    }

    let makespan = timeline.makespan().max(
        frame_done
            .iter()
            .flat_map(|v| v.iter().copied())
            .fold(0.0, f64::max),
    );

    // ---- Aggregate ----
    let mut instances = Vec::new();
    for (i, inst) in schedule.instances.iter().enumerate() {
        let mut latency = Summary::new();
        // Approximate per-frame latency: completion spacing converges to
        // the period; report chain latency = completion - admission is
        // tracked implicitly (completion diffs).
        let dones = &frame_done[i];
        for w in dones.windows(2) {
            latency.add(w[1] - w[0]);
        }
        let last = dones.last().copied().unwrap_or(0.0);
        let first = dones.first().copied().unwrap_or(0.0);
        // Steady-state FPS: exclude the first frame (pipeline fill).
        let fps = if dones.len() > 1 && last > first {
            (dones.len() - 1) as f64 / (last - first)
        } else if last > 0.0 {
            1.0 / last
        } else {
            0.0
        };
        instances.push(InstanceResult {
            label: inst.label.clone(),
            frames: dones.len(),
            fps,
            latency,
            home_engine: home_engines[i],
        });
    }

    Ok(SimResult {
        instances,
        timeline,
        makespan,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GanVariant;
    use crate::hw::orin;
    use crate::models::pix2pix::{generator, Pix2PixConfig};
    use crate::sched::naive;

    fn gan(v: GanVariant) -> Graph {
        generator(&Pix2PixConfig::paper(), v).unwrap()
    }

    #[test]
    fn standalone_gpu_matches_latency_model() {
        let g = gan(GanVariant::Original);
        let sched = naive::standalone(&g, EngineKind::Gpu);
        let cfg = SimConfig::new(orin(), 32);
        let r = simulate(&[&g], &sched, &cfg).unwrap();
        let fps = r.instances[0].fps;
        // Must agree with the analytic single-engine number (~170).
        assert!((150.0..195.0).contains(&fps), "fps {fps}");
    }

    #[test]
    fn standalone_dla_original_uses_gpu_fallback_fig10() {
        let g = gan(GanVariant::Original);
        let sched = naive::standalone(&g, EngineKind::Dla);
        // trtexec-style standalone profiling is single-stream.
        let mut cfg = SimConfig::new(orin(), 32);
        cfg.max_inflight = 1;
        let r = simulate(&[&g], &sched, &cfg).unwrap();
        let gpu_util = r.timeline.engine_stats(EngineKind::Gpu).utilization;
        // Fig 10: the original model keeps the GPU significantly busy
        // (paper measures ~20%; our simulator is coarser, accept a band).
        assert!(
            (0.05..0.8).contains(&gpu_util),
            "gpu utilization {gpu_util}"
        );
    }

    #[test]
    fn standalone_dla_modified_zero_gpu_fig10() {
        let g = gan(GanVariant::Cropping);
        let sched = naive::standalone(&g, EngineKind::Dla);
        let cfg = SimConfig::new(orin(), 32);
        let r = simulate(&[&g], &sched, &cfg).unwrap();
        let gpu_util = r.timeline.engine_stats(EngineKind::Gpu).utilization;
        assert_eq!(gpu_util, 0.0, "modified model must never touch the GPU");
    }

    #[test]
    fn makespan_monotone_in_frames() {
        let g = gan(GanVariant::Cropping);
        let sched = naive::standalone(&g, EngineKind::Dla);
        let r1 = simulate(&[&g], &sched, &SimConfig::new(orin(), 8)).unwrap();
        let r2 = simulate(&[&g], &sched, &SimConfig::new(orin(), 32)).unwrap();
        assert!(r2.makespan > r1.makespan);
        assert_eq!(r2.instances[0].frames, 32);
    }

    #[test]
    fn timeline_spans_do_not_overlap_per_engine() {
        let g = gan(GanVariant::Original);
        let sched = naive::standalone(&g, EngineKind::Dla);
        let r = simulate(&[&g], &sched, &SimConfig::new(orin(), 16)).unwrap();
        for engine in [EngineKind::Gpu, EngineKind::Dla] {
            let mut spans: Vec<_> = r
                .timeline
                .spans
                .iter()
                .filter(|s| s.engine == engine && !s.is_transition)
                .collect();
            spans.sort_by(|a, b| a.t0.partial_cmp(&b.t0).unwrap());
            for w in spans.windows(2) {
                assert!(
                    w[1].t0 >= w[0].t1 - 1e-12,
                    "overlap on {engine}: {:?} vs {:?}",
                    w[0],
                    w[1]
                );
            }
        }
    }
}
