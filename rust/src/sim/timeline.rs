//! Execution timelines — the Nsight Systems substitute.
//!
//! Every simulated engine occupation is recorded as a [`Span`]; the
//! [`Timeline`] derives the quantities the paper reads off its Nsight
//! screenshots (Figs 10/13/14): per-engine utilization, idle-gap
//! statistics and block fragmentation, and renders an ASCII timing diagram
//! plus a JSON export.

use crate::config::json::{arr, num, obj, s, Json};
use crate::hw::EngineKind;
use crate::util::stats::Summary;

/// One contiguous engine occupation.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub engine: EngineKind,
    /// Physical unit of the engine class (`0` for the GPU, `0`/`1` for the
    /// two DLA cores). The discrete-event sim models a single merged DLA
    /// and always records unit `0`; the serving-path arbiter records the
    /// actual pinned unit.
    pub unit: usize,
    /// Instance index within the workload.
    pub instance: usize,
    pub frame: usize,
    pub t0: f64,
    pub t1: f64,
    /// True for transition/reformat time rather than layer execution.
    pub is_transition: bool,
}

/// A complete simulation trace.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub spans: Vec<Span>,
}

/// Idle/fragmentation statistics for one engine (what Fig 13's "more idle
/// time between the DLA instances and smaller blocks" refers to).
#[derive(Debug, Clone)]
pub struct EngineStats {
    pub engine: EngineKind,
    pub busy: f64,
    pub span_count: usize,
    pub utilization: f64,
    /// Gap statistics between consecutive busy spans.
    pub idle_gaps: Summary,
    /// Mean busy-block length.
    pub mean_block: f64,
}

impl Timeline {
    pub fn push(&mut self, span: Span) {
        debug_assert!(span.t1 >= span.t0);
        self.spans.push(span);
    }

    /// End of the last span.
    pub fn makespan(&self) -> f64 {
        self.spans.iter().map(|s| s.t1).fold(0.0, f64::max)
    }

    /// `(first span start, last span end)` over the whole trace — the
    /// busy window a serving-side utilization should be computed over
    /// (the trace origin may predate the first dispatch, e.g. backend
    /// open/compile time).
    pub fn span_window(&self) -> Option<(f64, f64)> {
        if self.spans.is_empty() {
            return None;
        }
        let t0 = self.spans.iter().map(|s| s.t0).fold(f64::INFINITY, f64::min);
        let t1 = self.spans.iter().map(|s| s.t1).fold(0.0, f64::max);
        Some((t0, t1))
    }

    /// Compute-only spans of one engine (optionally one unit), time-sorted.
    fn engine_spans(&self, engine: EngineKind, unit: Option<usize>) -> Vec<&Span> {
        let mut v: Vec<&Span> = self
            .spans
            .iter()
            .filter(|s| {
                s.engine == engine && !s.is_transition && unit.map(|u| s.unit == u).unwrap_or(true)
            })
            .collect();
        v.sort_by(|a, b| a.t0.partial_cmp(&b.t0).unwrap());
        v
    }

    /// Engine statistics over the trace (utilization relative to the
    /// trace makespan), aggregated across all units of the class.
    pub fn engine_stats(&self, engine: EngineKind) -> EngineStats {
        self.stats_of(engine, None)
    }

    /// Statistics for one physical unit of an engine class (`DLA0` vs
    /// `DLA1` — the per-core view the serving arbiter reports).
    pub fn unit_stats(&self, engine: EngineKind, unit: usize) -> EngineStats {
        self.stats_of(engine, Some(unit))
    }

    fn stats_of(&self, engine: EngineKind, unit: Option<usize>) -> EngineStats {
        let spans = self.engine_spans(engine, unit);
        let busy: f64 = spans.iter().map(|s| s.t1 - s.t0).sum();
        let total = self.makespan().max(f64::MIN_POSITIVE);
        let mut gaps = Summary::new();
        for w in spans.windows(2) {
            let gap = (w[1].t0 - w[0].t1).max(0.0);
            if gap > 0.0 {
                gaps.add(gap);
            }
        }
        EngineStats {
            engine,
            busy,
            span_count: spans.len(),
            utilization: busy / total,
            idle_gaps: gaps,
            mean_block: if spans.is_empty() { 0.0 } else { busy / spans.len() as f64 },
        }
    }

    /// ASCII timing diagram (one row per engine, `width` character bins) —
    /// the textual stand-in for the paper's Nsight figures.
    pub fn ascii(&self, width: usize) -> String {
        let total = self.makespan();
        if total <= 0.0 {
            return String::new();
        }
        let mut out = String::new();
        for engine in [EngineKind::Gpu, EngineKind::Dla] {
            let mut row = vec![' '; width];
            for span in self.spans.iter().filter(|s| s.engine == engine) {
                let a = ((span.t0 / total) * width as f64) as usize;
                let b = (((span.t1 / total) * width as f64).ceil() as usize).min(width);
                let ch = if span.is_transition {
                    '.'
                } else {
                    char::from_digit(span.instance as u32 % 10, 10).unwrap_or('#')
                };
                for slot in row.iter_mut().take(b).skip(a) {
                    *slot = ch;
                }
            }
            out.push_str(&format!("{:>4} |{}|\n", engine.name(), row.iter().collect::<String>()));
        }
        out
    }

    /// JSON export (chrome-trace-like), for offline inspection.
    pub fn to_json(&self) -> Json {
        arr(self
            .spans
            .iter()
            .map(|sp| {
                obj(vec![
                    ("engine", s(sp.engine.name())),
                    ("unit", num(sp.unit as f64)),
                    ("instance", num(sp.instance as f64)),
                    ("frame", num(sp.frame as f64)),
                    ("t0", num(sp.t0)),
                    ("t1", num(sp.t1)),
                    ("transition", Json::Bool(sp.is_transition)),
                ])
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(e: EngineKind, i: usize, t0: f64, t1: f64) -> Span {
        unit_span(e, 0, i, t0, t1)
    }

    fn unit_span(e: EngineKind, unit: usize, i: usize, t0: f64, t1: f64) -> Span {
        Span {
            engine: e,
            unit,
            instance: i,
            frame: 0,
            t0,
            t1,
            is_transition: false,
        }
    }

    #[test]
    fn makespan_and_utilization() {
        let mut t = Timeline::default();
        t.push(span(EngineKind::Gpu, 0, 0.0, 1.0));
        t.push(span(EngineKind::Gpu, 0, 2.0, 3.0));
        t.push(span(EngineKind::Dla, 1, 0.0, 4.0));
        assert_eq!(t.makespan(), 4.0);
        let g = t.engine_stats(EngineKind::Gpu);
        assert!((g.utilization - 0.5).abs() < 1e-9);
        assert_eq!(g.span_count, 2);
        let d = t.engine_stats(EngineKind::Dla);
        assert!((d.utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn idle_gap_statistics() {
        let mut t = Timeline::default();
        t.push(span(EngineKind::Dla, 0, 0.0, 1.0));
        t.push(span(EngineKind::Dla, 0, 1.5, 2.5));
        t.push(span(EngineKind::Dla, 0, 4.0, 5.0));
        let st = t.engine_stats(EngineKind::Dla);
        assert_eq!(st.idle_gaps.count(), 2);
        assert!((st.idle_gaps.mean() - 1.0).abs() < 1e-9);
        assert!((st.mean_block - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transitions_excluded_from_stats() {
        let mut t = Timeline::default();
        t.push(span(EngineKind::Gpu, 0, 0.0, 1.0));
        t.push(Span {
            engine: EngineKind::Gpu,
            unit: 0,
            instance: 0,
            frame: 0,
            t0: 1.0,
            t1: 2.0,
            is_transition: true,
        });
        let g = t.engine_stats(EngineKind::Gpu);
        assert_eq!(g.span_count, 1);
        assert!((g.busy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unit_stats_separate_the_two_dla_cores() {
        let mut t = Timeline::default();
        t.push(unit_span(EngineKind::Dla, 0, 0, 0.0, 1.0));
        t.push(unit_span(EngineKind::Dla, 0, 0, 1.0, 2.0));
        t.push(unit_span(EngineKind::Dla, 1, 1, 0.0, 4.0));
        let d0 = t.unit_stats(EngineKind::Dla, 0);
        let d1 = t.unit_stats(EngineKind::Dla, 1);
        assert_eq!(d0.span_count, 2);
        assert!((d0.busy - 2.0).abs() < 1e-12);
        assert_eq!(d1.span_count, 1);
        assert!((d1.utilization - 1.0).abs() < 1e-9);
        // the merged per-class view still aggregates both cores
        assert_eq!(t.engine_stats(EngineKind::Dla).span_count, 3);
    }

    #[test]
    fn span_window_covers_first_to_last() {
        let mut t = Timeline::default();
        assert!(t.span_window().is_none());
        t.push(span(EngineKind::Gpu, 0, 2.0, 3.0));
        t.push(span(EngineKind::Dla, 1, 1.0, 2.5));
        assert_eq!(t.span_window(), Some((1.0, 3.0)));
    }

    #[test]
    fn ascii_has_two_rows() {
        let mut t = Timeline::default();
        t.push(span(EngineKind::Gpu, 0, 0.0, 1.0));
        t.push(span(EngineKind::Dla, 1, 0.5, 1.5));
        let a = t.ascii(40);
        assert_eq!(a.lines().count(), 2);
        assert!(a.contains("GPU"));
        assert!(a.contains("DLA"));
    }

    #[test]
    fn json_roundtrip() {
        let mut t = Timeline::default();
        t.push(span(EngineKind::Gpu, 2, 0.0, 1.0));
        let j = t.to_json().to_compact();
        let back = crate::config::json::Json::parse(&j).unwrap();
        assert_eq!(back.as_arr().unwrap().len(), 1);
    }
}
