//! Declarative pipeline description — the open replacement for the four
//! hardcoded `Workload` enum arms.
//!
//! A [`PipelineSpec`] names an arbitrary set of model [`InstanceSpec`]s
//! (any mix of GAN variants, the detector, and future models), how frames
//! are routed between them, and the stream/backpressure shape. It is pure
//! data: *what* to run. *How* it executes is the
//! [`super::backend::InferenceBackend`] the session binds it to, and the
//! entry point that does the binding is [`crate::session::Session`].
//! The old `Workload` arms survive as presets that lower into specs
//! (`Workload::GanPlusYolo.spec(variant)`).

use super::batcher::BatchPolicy;
use super::router::RoutePolicy;
use crate::config::json::{arr, num, obj, s, Json};
use crate::config::GanVariant;
use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::hw::EngineKind;
use crate::models::pix2pix::{generator, Pix2PixConfig};
use crate::models::yolov8::yolo_lite;

/// Builder for one catalog entry's layer graph (used by the sim backend to
/// price per-frame latency).
pub type ArtifactGraphFn = fn() -> Result<Graph>;

fn gen_original_graph() -> Result<Graph> {
    generator(&Pix2PixConfig::paper(), GanVariant::Original)
}
fn gen_cropping_graph() -> Result<Graph> {
    generator(&Pix2PixConfig::paper(), GanVariant::Cropping)
}
fn gen_convolution_graph() -> Result<Graph> {
    generator(&Pix2PixConfig::paper(), GanVariant::Convolution)
}
fn yolo_lite_graph() -> Result<Graph> {
    yolo_lite()
}

/// The artifact catalog: every name the AOT export pipeline emits
/// (`python/compile/aot.py`), paired with its layer-graph builder. Single
/// source of truth — the JSON config loader validates names against it and
/// [`super::backend::SimBackend`] prices latency from it, so a typo fails
/// with a clear message instead of a missing-file error three layers down,
/// and the two views cannot drift.
pub const ARTIFACT_CATALOG: [(&str, ArtifactGraphFn); 4] = [
    ("gen_original", gen_original_graph),
    ("gen_cropping", gen_cropping_graph),
    ("gen_convolution", gen_convolution_graph),
    ("yolo_lite", yolo_lite_graph),
];

/// Largest accepted `max_batch`. The sim runner precomputes a
/// per-batch-size latency table and the batcher pre-sizes its buffers
/// from the policy, so an unbounded configured value must not be able to
/// turn into unbounded work/allocation.
pub const MAX_BATCH_LIMIT: usize = 1024;

/// Slice side length of the k-space acquisition front-end — fixed to the
/// phantom generator's default size, so `source: kspace` feeds the model
/// chain frames of the exact shape `source: phantom` does.
pub const KSPACE_SLICE: usize = 64;

/// How an undersampled k-space acquisition is reconstructed into the
/// image the GAN→YOLO chain consumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReconMode {
    /// Zero-filled inverse FFT baseline (missing rows left at zero,
    /// scaled by `n / sampled_rows` to restore the DC amplitude).
    ZeroFilled,
    /// GRAPPA: per-offset kernel fit over the ACS band, missing rows
    /// synthesized from their sampled neighbours before the inverse FFT.
    Grappa,
}

impl ReconMode {
    /// Parse a config/CLI recon-mode name.
    pub fn parse(text: &str) -> Result<ReconMode> {
        match text {
            "zero-filled" => Ok(ReconMode::ZeroFilled),
            "grappa" => Ok(ReconMode::Grappa),
            other => Err(Error::Config(format!(
                "unknown recon mode `{other}` (known: zero-filled, grappa)"
            ))),
        }
    }

    /// Canonical config/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            ReconMode::ZeroFilled => "zero-filled",
            ReconMode::Grappa => "grappa",
        }
    }
}

/// Where a pipeline's frames come from — sources are pluggable the way
/// backends are. `Phantom` is the paper's starting point (already-formed
/// images); `Kspace` prepends the accelerated-MRI acquisition front-end:
/// multi-coil k-space synthesis, R-fold row undersampling with an ACS
/// band, and an in-pipeline reconstruction stage whose output feeds the
/// model chain (and whose PSNR/SSIM against the fully-sampled ground
/// truth reports through the same fidelity path as the GAN's).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum SourceSpec {
    /// Paired CT/MRI phantom generator (the default).
    #[default]
    Phantom,
    /// Undersampled multi-coil k-space acquisition of the phantom slice.
    Kspace {
        /// Acceleration factor R: every R-th phase-encode row is sampled.
        accel: usize,
        /// Auto-calibration band width (fully-sampled rows around DC).
        acs_lines: usize,
        /// Synthetic receive-coil count.
        coils: usize,
        /// Pre-model reconstruction mode.
        recon: ReconMode,
    },
}

impl SourceSpec {
    /// A GRAPPA k-space source with the standard calibration shape
    /// (16 ACS lines, 4 coils).
    pub fn kspace(accel: usize, recon: ReconMode) -> SourceSpec {
        SourceSpec::Kspace {
            accel,
            acs_lines: 16,
            coils: 4,
            recon,
        }
    }

    /// Canonical kind name (`phantom` / `kspace`).
    pub fn kind(&self) -> &'static str {
        match self {
            SourceSpec::Phantom => "phantom",
            SourceSpec::Kspace { .. } => "kspace",
        }
    }

    /// Config-schema JSON (the `source: {...}` object); inverse of
    /// [`Self::from_json`].
    pub fn to_json(&self) -> Json {
        match self {
            SourceSpec::Phantom => obj(vec![("kind", s("phantom"))]),
            SourceSpec::Kspace {
                accel,
                acs_lines,
                coils,
                recon,
            } => obj(vec![
                ("kind", s("kspace")),
                ("accel", num(*accel as f64)),
                ("acs_lines", num(*acs_lines as f64)),
                ("coils", num(*coils as f64)),
                ("recon", s(recon.name())),
            ]),
        }
    }

    /// Parse the `source: {...}` config object. Unknown kinds and missing
    /// or malformed fields fail with field-level messages.
    pub fn from_json(value: &Json) -> Result<SourceSpec> {
        let kind = value
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Config("source needs a string `kind` field".into()))?;
        match kind {
            "phantom" => Ok(SourceSpec::Phantom),
            "kspace" => {
                let field = |name: &str| -> Result<usize> {
                    value
                        .get(name)
                        .and_then(Json::as_u64)
                        .map(|v| v as usize)
                        .ok_or_else(|| {
                            Error::Config(format!(
                                "kspace source needs a non-negative integer `{name}`"
                            ))
                        })
                };
                let recon = value
                    .get("recon")
                    .and_then(Json::as_str)
                    .ok_or_else(|| {
                        Error::Config("kspace source needs a string `recon` field".into())
                    })?;
                Ok(SourceSpec::Kspace {
                    accel: field("accel")?,
                    acs_lines: field("acs_lines")?,
                    coils: field("coils")?,
                    recon: ReconMode::parse(recon)?,
                })
            }
            other => Err(Error::Config(format!(
                "unknown source kind `{other}` (known: phantom, kspace)"
            ))),
        }
    }

    /// Structural validation of the acquisition geometry (the imaging
    /// layer re-checks at construction; this catches it at spec level
    /// with config-grade messages).
    pub fn validate(&self) -> Result<()> {
        match self {
            SourceSpec::Phantom => Ok(()),
            SourceSpec::Kspace {
                accel,
                acs_lines,
                coils,
                ..
            } => {
                if *accel == 0 || KSPACE_SLICE % *accel != 0 {
                    return Err(Error::Config(format!(
                        "accel {accel} must be >= 1 and divide the {KSPACE_SLICE}-row slice"
                    )));
                }
                if *acs_lines > KSPACE_SLICE {
                    return Err(Error::Config(format!(
                        "acs_lines {acs_lines} exceeds the {KSPACE_SLICE} phase-encode rows"
                    )));
                }
                if *accel > 1 && *acs_lines < accel + 2 {
                    return Err(Error::Config(format!(
                        "acs_lines {acs_lines} too narrow to calibrate at R={accel} \
                         (need at least {})",
                        accel + 2
                    )));
                }
                if *coils == 0 || *coils > 8 {
                    return Err(Error::Config(format!(
                        "coils {coils} out of range 1..=8"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Closed-form per-frame reconstruction cost estimate in seconds —
    /// the dispatch-profile analogue for the acquisition front-end, so
    /// the placement scorer and the fleet virtual clock price the recon
    /// stage instead of treating accelerated sources as free. Counts the
    /// per-coil inverse FFT + RSS combine, plus the GRAPPA per-offset
    /// normal-equation fit and missing-row synthesis, at an effective
    /// 2 GFLOP/s edge-CPU throughput.
    pub fn recon_seconds(&self) -> f64 {
        const EDGE_FLOPS_PER_S: f64 = 2.0e9;
        match self {
            SourceSpec::Phantom => 0.0,
            SourceSpec::Kspace {
                accel,
                acs_lines,
                coils,
                recon,
            } => {
                if *accel <= 1 {
                    // Fully sampled: the bit-exact copy fast path.
                    return 0.0;
                }
                let n = KSPACE_SLICE as f64;
                let c = *coils as f64;
                let r = *accel as f64;
                // Per-coil forward synthesis + inverse recon FFT
                // (~5 n² log2(n²) flops each) and the RSS combine.
                let mut flops = 2.0 * c * 5.0 * n * n * (n * n).log2() + 4.0 * c * n * n;
                if matches!(recon, ReconMode::Grappa) {
                    let dim = 6.0 * c;
                    let acs = *acs_lines as f64;
                    // Fit: Gram/RHS accumulation over ~acs·n samples plus
                    // the dense solve, once per offset.
                    flops += (r - 1.0) * (acs * n * dim * dim * 8.0 + dim * dim * dim * 8.0);
                    // Apply: every missing row re-synthesized per coil.
                    flops += n * (1.0 - 1.0 / r) * n * c * dim * 8.0;
                }
                flops / EDGE_FLOPS_PER_S
            }
        }
    }
}

/// Comma-separated catalog names (for error messages).
pub fn known_artifact_names() -> String {
    ARTIFACT_CATALOG
        .iter()
        .map(|(name, _)| *name)
        .collect::<Vec<_>>()
        .join(", ")
}

/// Reject artifact names outside the compiled-in catalog.
pub fn check_artifact_name(name: &str) -> Result<()> {
    artifact_graph_fn(name).map(|_| ())
}

/// Layer graph for a catalog artifact (errors on unknown names).
pub fn artifact_graph(name: &str) -> Result<Graph> {
    artifact_graph_fn(name)?()
}

fn artifact_graph_fn(name: &str) -> Result<ArtifactGraphFn> {
    ARTIFACT_CATALOG
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, f)| *f)
        .ok_or_else(|| {
            Error::Config(format!(
                "unknown artifact `{name}` (known: {})",
                known_artifact_names()
            ))
        })
}

/// One model instance of a pipeline: which artifact it serves, where it is
/// placed, and how it batches.
#[derive(Debug, Clone)]
pub struct InstanceSpec {
    /// Display / metrics label; must be unique within a spec.
    pub label: String,
    /// AOT artifact name (e.g. `gen_cropping`, `yolo_lite`).
    pub artifact: String,
    /// Engine placement. Placement is *load-bearing* in the serving path:
    /// the driver routes every dispatch through the shared
    /// [`super::engines::EngineArbiter`], so instances pinned to the same
    /// physical unit serialize, split placements run concurrently (with
    /// PCCS contention), and occupant switches pay the reformat cost.
    /// [`super::backend::SimBackend`] additionally prices per-dispatch
    /// latency from it; the PJRT path executes on the CPU client but still
    /// serializes under the same engine token.
    pub engine: EngineKind,
    /// Physical unit of `engine` this instance is pinned to (the Jetson
    /// testbeds carry two DLA cores — `EngineKind::units`). `0` unless
    /// explicitly split, e.g. the dual-GAN deployment's DLA0/DLA1 pair.
    pub engine_index: usize,
    /// Per-instance dynamic batching policy. Batches reach the backend as
    /// a single [`super::backend::ModelRunner::execute_batch`] dispatch,
    /// so `max_batch > 1` reduces dispatch count (and amortizes launch
    /// overhead / weight traffic), it does not just group bookkeeping.
    pub batch: BatchPolicy,
    /// Score reconstruction fidelity (PSNR/SSIM) against the frame's
    /// ground truth (GAN-style instances).
    pub score_fidelity: bool,
}

impl InstanceSpec {
    /// A GPU-placed, batch-1, unscored instance; chain the builder-style
    /// methods to adjust.
    pub fn new(label: impl Into<String>, artifact: impl Into<String>) -> Self {
        InstanceSpec {
            label: label.into(),
            artifact: artifact.into(),
            engine: EngineKind::Gpu,
            engine_index: 0,
            batch: BatchPolicy::default(),
            score_fidelity: false,
        }
    }

    /// Pin the instance to an engine (unit 0).
    pub fn on_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self.engine_index = 0;
        self
    }

    /// Pin the instance to a specific physical unit of an engine class
    /// (e.g. `on_engine_unit(EngineKind::Dla, 1)` for the second DLA core).
    pub fn on_engine_unit(mut self, engine: EngineKind, index: usize) -> Self {
        self.engine = engine;
        self.engine_index = index;
        self
    }

    /// Set the dynamic batching policy.
    pub fn with_batch(mut self, batch: BatchPolicy) -> Self {
        self.batch = batch;
        self
    }

    /// Enable/disable online fidelity scoring.
    pub fn scored(mut self, yes: bool) -> Self {
        self.score_fidelity = yes;
        self
    }

    /// Config-schema JSON for this instance — exactly the shape the
    /// [`crate::config`] `instances: [...]` parser accepts, so emitted
    /// specs reload through the existing loader. Single writer: the
    /// config provenance serializer delegates here.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("label", s(&self.label)),
            ("artifact", s(&self.artifact)),
            ("engine", s(&self.engine.name().to_ascii_lowercase())),
            ("engine_index", num(self.engine_index as f64)),
            ("max_batch", num(self.batch.max_batch as f64)),
            (
                "batch_timeout_us",
                num(self.batch.timeout.as_micros() as f64),
            ),
            ("score_fidelity", Json::Bool(self.score_fidelity)),
        ])
    }
}

/// A full declarative pipeline: instances, routing, and stream shape.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub instances: Vec<InstanceSpec>,
    /// How frames map to instances.
    pub route: RoutePolicy,
    /// Number of CT frames to stream through the pipeline.
    pub frames: usize,
    /// Number of concurrent input streams (client-server scheme > 1).
    pub streams: usize,
    /// Maximum in-flight frames per instance before backpressure.
    pub queue_depth: usize,
    /// RNG seed for workload generation.
    pub seed: u64,
    /// Where frames come from (phantom generator or the undersampled
    /// k-space acquisition front-end).
    pub source: SourceSpec,
}

impl Default for PipelineSpec {
    fn default() -> Self {
        PipelineSpec {
            instances: Vec::new(),
            route: RoutePolicy::Fanout,
            frames: 256,
            streams: 1,
            queue_depth: 4,
            seed: 0xED6E,
            source: SourceSpec::Phantom,
        }
    }
}

impl PipelineSpec {
    /// Serialize to a config-schema JSON document (`route`, stream shape,
    /// and the `instances: [...]` array): the writer half of the config
    /// loader, so `plan --emit-spec` output reloads through
    /// [`crate::config::PipelineConfig::from_json_str`] unchanged —
    /// see [`Self::from_json_str`] for the inverse.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("route", s(self.route.name())),
            ("frames", num(self.frames as f64)),
            ("streams", num(self.streams as f64)),
            ("queue_depth", num(self.queue_depth as f64)),
            ("seed", num(self.seed as f64)),
            // Always written (even for the default phantom source) so an
            // emitted spec names its source explicitly and the roundtrip
            // is byte-deterministic.
            ("source", self.source.to_json()),
            (
                "instances",
                arr(self.instances.iter().map(|i| i.to_json()).collect()),
            ),
        ])
    }

    /// Reload a spec emitted by [`Self::to_json`] through the existing
    /// config parser (round trip: spec → JSON → spec).
    pub fn from_json_str(text: &str) -> Result<PipelineSpec> {
        Ok(crate::config::PipelineConfig::from_json_str(text)?.spec())
    }

    /// Fail-fast structural validation (instance set, labels, counts).
    pub fn validate(&self) -> Result<()> {
        if self.instances.is_empty() {
            return Err(Error::Pipeline(
                "pipeline spec has no instances (add at least one)".into(),
            ));
        }
        for (i, inst) in self.instances.iter().enumerate() {
            if inst.label.is_empty() {
                return Err(Error::Pipeline(format!("instance {i} has an empty label")));
            }
            if inst.artifact.is_empty() {
                return Err(Error::Pipeline(format!(
                    "instance `{}` has an empty artifact name",
                    inst.label
                )));
            }
            if inst.batch.max_batch == 0 {
                return Err(Error::Pipeline(format!(
                    "instance `{}`: max_batch must be > 0",
                    inst.label
                )));
            }
            if inst.batch.max_batch > MAX_BATCH_LIMIT {
                return Err(Error::Pipeline(format!(
                    "instance `{}`: max_batch {} exceeds the supported maximum {MAX_BATCH_LIMIT}",
                    inst.label, inst.batch.max_batch
                )));
            }
            if inst.engine_index >= inst.engine.units() {
                return Err(Error::Pipeline(format!(
                    "instance `{}`: engine index {} out of range for {} ({} unit(s))",
                    inst.label,
                    inst.engine_index,
                    inst.engine,
                    inst.engine.units()
                )));
            }
            if self.instances[..i].iter().any(|o| o.label == inst.label) {
                return Err(Error::Pipeline(format!(
                    "duplicate instance label `{}`",
                    inst.label
                )));
            }
        }
        if self.frames == 0 {
            return Err(Error::Pipeline("frames must be > 0".into()));
        }
        if self.streams == 0 {
            return Err(Error::Pipeline("streams must be > 0".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Pipeline("queue_depth must be > 0".into()));
        }
        self.source.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_instance_spec() -> PipelineSpec {
        PipelineSpec {
            instances: vec![
                InstanceSpec::new("gan", "gen_cropping").scored(true),
                InstanceSpec::new("yolo", "yolo_lite").on_engine(EngineKind::Dla),
            ],
            ..PipelineSpec::default()
        }
    }

    #[test]
    fn valid_spec_passes() {
        two_instance_spec().validate().unwrap();
    }

    #[test]
    fn empty_instances_rejected() {
        let err = PipelineSpec::default().validate().unwrap_err();
        assert!(err.to_string().contains("no instances"));
    }

    #[test]
    fn duplicate_labels_rejected() {
        let mut spec = two_instance_spec();
        spec.instances[1].label = "gan".into();
        let err = spec.validate().unwrap_err();
        assert!(err.to_string().contains("duplicate instance label"));
    }

    #[test]
    fn zero_counts_rejected() {
        let mut spec = two_instance_spec();
        spec.frames = 0;
        assert!(spec.validate().is_err());
        let mut spec = two_instance_spec();
        spec.queue_depth = 0;
        assert!(spec.validate().is_err());
        let mut spec = two_instance_spec();
        spec.instances[0].batch.max_batch = 0;
        assert!(spec.validate().is_err());
    }

    #[test]
    fn absurd_max_batch_rejected() {
        let mut spec = two_instance_spec();
        spec.instances[0].batch.max_batch = 100_000_000;
        let err = spec.validate().unwrap_err();
        assert!(err.to_string().contains("exceeds the supported maximum"));
        spec.instances[0].batch.max_batch = MAX_BATCH_LIMIT;
        spec.validate().unwrap();
    }

    #[test]
    fn engine_index_bounds_enforced() {
        let mut spec = two_instance_spec();
        spec.instances[1] = spec.instances[1].clone().on_engine_unit(EngineKind::Dla, 1);
        spec.validate().unwrap();
        spec.instances[1].engine_index = 2; // Jetson has two DLA cores
        let err = spec.validate().unwrap_err();
        assert!(err.to_string().contains("engine index 2 out of range"));
        let mut spec = two_instance_spec();
        spec.instances[0] = spec.instances[0].clone().on_engine_unit(EngineKind::Gpu, 1);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn artifact_catalog_is_enforced() {
        check_artifact_name("gen_cropping").unwrap();
        check_artifact_name("yolo_lite").unwrap();
        let err = check_artifact_name("resnet999").unwrap_err();
        assert!(err.to_string().contains("unknown artifact"));
        assert!(err.to_string().contains("gen_original"));
    }

    #[test]
    fn spec_json_roundtrips_through_the_config_parser() {
        use crate::hw::EngineKind;
        let mut spec = two_instance_spec();
        spec.instances[1] = spec.instances[1].clone().on_engine_unit(EngineKind::Dla, 1);
        spec.instances[0].batch.max_batch = 8;
        spec.route = RoutePolicy::RrFanoutLast;
        spec.frames = 96;
        spec.streams = 2;
        spec.seed = 42;
        spec.source = SourceSpec::Kspace {
            accel: 4,
            acs_lines: 16,
            coils: 4,
            recon: ReconMode::Grappa,
        };
        let text = spec.to_json().to_pretty();
        let back = PipelineSpec::from_json_str(&text).unwrap();
        assert_eq!(back.instances.len(), 2);
        assert_eq!(back.route, RoutePolicy::RrFanoutLast);
        assert_eq!(back.frames, 96);
        assert_eq!(back.streams, 2);
        assert_eq!(back.seed, 42);
        assert_eq!(back.source, spec.source);
        assert_eq!(back.instances[0].batch.max_batch, 8);
        assert_eq!(back.instances[1].engine, EngineKind::Dla);
        assert_eq!(back.instances[1].engine_index, 1);
        assert!(back.instances[0].score_fidelity);
        // the writer is deterministic: a second trip is byte-identical
        assert_eq!(back.to_json().to_pretty(), back.to_json().to_pretty());
        assert_eq!(
            PipelineSpec::from_json_str(&back.to_json().to_pretty())
                .unwrap()
                .to_json()
                .to_pretty(),
            back.to_json().to_pretty()
        );
    }

    #[test]
    fn source_spec_json_roundtrips_and_rejects_unknowns() {
        for src in [
            SourceSpec::Phantom,
            SourceSpec::kspace(2, ReconMode::ZeroFilled),
            SourceSpec::Kspace {
                accel: 8,
                acs_lines: 24,
                coils: 6,
                recon: ReconMode::Grappa,
            },
        ] {
            let back = SourceSpec::from_json(&src.to_json()).unwrap();
            assert_eq!(back, src);
        }
        let err = SourceSpec::from_json(&Json::parse(r#"{"kind":"dicom"}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown source kind `dicom`"), "{err}");
        assert!(err.contains("phantom, kspace"), "{err}");
        let err = SourceSpec::from_json(
            &Json::parse(r#"{"kind":"kspace","accel":4,"acs_lines":16,"coils":4,"recon":"cnn"}"#)
                .unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown recon mode `cnn`"), "{err}");
        let err = SourceSpec::from_json(&Json::parse(r#"{"kind":"kspace","accel":4}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("acs_lines"), "{err}");
    }

    #[test]
    fn kspace_source_geometry_is_validated() {
        let mut spec = two_instance_spec();
        spec.source = SourceSpec::kspace(4, ReconMode::Grappa);
        spec.validate().unwrap();
        spec.source = SourceSpec::kspace(3, ReconMode::Grappa); // 64 % 3 != 0
        assert!(spec.validate().is_err());
        spec.source = SourceSpec::Kspace {
            accel: 8,
            acs_lines: 4, // narrower than R+2
            coils: 4,
            recon: ReconMode::Grappa,
        };
        assert!(spec.validate().is_err());
        spec.source = SourceSpec::Kspace {
            accel: 4,
            acs_lines: 16,
            coils: 9, // out of range
            recon: ReconMode::ZeroFilled,
        };
        assert!(spec.validate().is_err());
    }

    #[test]
    fn recon_pricing_orders_modes_sensibly() {
        assert_eq!(SourceSpec::Phantom.recon_seconds(), 0.0);
        assert_eq!(SourceSpec::kspace(1, ReconMode::Grappa).recon_seconds(), 0.0);
        let zf = SourceSpec::kspace(4, ReconMode::ZeroFilled).recon_seconds();
        let gr = SourceSpec::kspace(4, ReconMode::Grappa).recon_seconds();
        assert!(zf > 0.0 && gr > zf, "zf {zf} vs grappa {gr}");
        // More offsets to fit at higher R: GRAPPA cost grows with R.
        let gr8 = SourceSpec::kspace(8, ReconMode::Grappa).recon_seconds();
        assert!(gr8 > gr);
        // Sub-second per frame at every supported geometry.
        assert!(gr8 < 1.0);
    }

    #[test]
    fn every_catalog_entry_builds_a_graph() {
        // the catalog is one table: any name that parses must also price
        for (name, _) in ARTIFACT_CATALOG {
            let g = artifact_graph(name).unwrap();
            assert!(!g.compute_layers().is_empty(), "{name}");
        }
    }
}
