//! Declarative pipeline description — the open replacement for the four
//! hardcoded `Workload` enum arms.
//!
//! A [`PipelineSpec`] names an arbitrary set of model [`InstanceSpec`]s
//! (any mix of GAN variants, the detector, and future models), how frames
//! are routed between them, and the stream/backpressure shape. It is pure
//! data: *what* to run. *How* it executes is the
//! [`super::backend::InferenceBackend`] the session binds it to, and the
//! entry point that does the binding is [`crate::session::Session`].
//! The old `Workload` arms survive as presets that lower into specs
//! (`Workload::GanPlusYolo.spec(variant)`).

use super::batcher::BatchPolicy;
use super::router::RoutePolicy;
use crate::config::json::{arr, num, obj, s, Json};
use crate::config::GanVariant;
use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::hw::EngineKind;
use crate::models::pix2pix::{generator, Pix2PixConfig};
use crate::models::yolov8::yolo_lite;

/// Builder for one catalog entry's layer graph (used by the sim backend to
/// price per-frame latency).
pub type ArtifactGraphFn = fn() -> Result<Graph>;

fn gen_original_graph() -> Result<Graph> {
    generator(&Pix2PixConfig::paper(), GanVariant::Original)
}
fn gen_cropping_graph() -> Result<Graph> {
    generator(&Pix2PixConfig::paper(), GanVariant::Cropping)
}
fn gen_convolution_graph() -> Result<Graph> {
    generator(&Pix2PixConfig::paper(), GanVariant::Convolution)
}
fn yolo_lite_graph() -> Result<Graph> {
    yolo_lite()
}

/// The artifact catalog: every name the AOT export pipeline emits
/// (`python/compile/aot.py`), paired with its layer-graph builder. Single
/// source of truth — the JSON config loader validates names against it and
/// [`super::backend::SimBackend`] prices latency from it, so a typo fails
/// with a clear message instead of a missing-file error three layers down,
/// and the two views cannot drift.
pub const ARTIFACT_CATALOG: [(&str, ArtifactGraphFn); 4] = [
    ("gen_original", gen_original_graph),
    ("gen_cropping", gen_cropping_graph),
    ("gen_convolution", gen_convolution_graph),
    ("yolo_lite", yolo_lite_graph),
];

/// Largest accepted `max_batch`. The sim runner precomputes a
/// per-batch-size latency table and the batcher pre-sizes its buffers
/// from the policy, so an unbounded configured value must not be able to
/// turn into unbounded work/allocation.
pub const MAX_BATCH_LIMIT: usize = 1024;

/// Comma-separated catalog names (for error messages).
pub fn known_artifact_names() -> String {
    ARTIFACT_CATALOG
        .iter()
        .map(|(name, _)| *name)
        .collect::<Vec<_>>()
        .join(", ")
}

/// Reject artifact names outside the compiled-in catalog.
pub fn check_artifact_name(name: &str) -> Result<()> {
    artifact_graph_fn(name).map(|_| ())
}

/// Layer graph for a catalog artifact (errors on unknown names).
pub fn artifact_graph(name: &str) -> Result<Graph> {
    artifact_graph_fn(name)?()
}

fn artifact_graph_fn(name: &str) -> Result<ArtifactGraphFn> {
    ARTIFACT_CATALOG
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, f)| *f)
        .ok_or_else(|| {
            Error::Config(format!(
                "unknown artifact `{name}` (known: {})",
                known_artifact_names()
            ))
        })
}

/// One model instance of a pipeline: which artifact it serves, where it is
/// placed, and how it batches.
#[derive(Debug, Clone)]
pub struct InstanceSpec {
    /// Display / metrics label; must be unique within a spec.
    pub label: String,
    /// AOT artifact name (e.g. `gen_cropping`, `yolo_lite`).
    pub artifact: String,
    /// Engine placement. Placement is *load-bearing* in the serving path:
    /// the driver routes every dispatch through the shared
    /// [`super::engines::EngineArbiter`], so instances pinned to the same
    /// physical unit serialize, split placements run concurrently (with
    /// PCCS contention), and occupant switches pay the reformat cost.
    /// [`super::backend::SimBackend`] additionally prices per-dispatch
    /// latency from it; the PJRT path executes on the CPU client but still
    /// serializes under the same engine token.
    pub engine: EngineKind,
    /// Physical unit of `engine` this instance is pinned to (the Jetson
    /// testbeds carry two DLA cores — `EngineKind::units`). `0` unless
    /// explicitly split, e.g. the dual-GAN deployment's DLA0/DLA1 pair.
    pub engine_index: usize,
    /// Per-instance dynamic batching policy. Batches reach the backend as
    /// a single [`super::backend::ModelRunner::execute_batch`] dispatch,
    /// so `max_batch > 1` reduces dispatch count (and amortizes launch
    /// overhead / weight traffic), it does not just group bookkeeping.
    pub batch: BatchPolicy,
    /// Score reconstruction fidelity (PSNR/SSIM) against the frame's
    /// ground truth (GAN-style instances).
    pub score_fidelity: bool,
}

impl InstanceSpec {
    /// A GPU-placed, batch-1, unscored instance; chain the builder-style
    /// methods to adjust.
    pub fn new(label: impl Into<String>, artifact: impl Into<String>) -> Self {
        InstanceSpec {
            label: label.into(),
            artifact: artifact.into(),
            engine: EngineKind::Gpu,
            engine_index: 0,
            batch: BatchPolicy::default(),
            score_fidelity: false,
        }
    }

    /// Pin the instance to an engine (unit 0).
    pub fn on_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self.engine_index = 0;
        self
    }

    /// Pin the instance to a specific physical unit of an engine class
    /// (e.g. `on_engine_unit(EngineKind::Dla, 1)` for the second DLA core).
    pub fn on_engine_unit(mut self, engine: EngineKind, index: usize) -> Self {
        self.engine = engine;
        self.engine_index = index;
        self
    }

    /// Set the dynamic batching policy.
    pub fn with_batch(mut self, batch: BatchPolicy) -> Self {
        self.batch = batch;
        self
    }

    /// Enable/disable online fidelity scoring.
    pub fn scored(mut self, yes: bool) -> Self {
        self.score_fidelity = yes;
        self
    }

    /// Config-schema JSON for this instance — exactly the shape the
    /// [`crate::config`] `instances: [...]` parser accepts, so emitted
    /// specs reload through the existing loader. Single writer: the
    /// config provenance serializer delegates here.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("label", s(&self.label)),
            ("artifact", s(&self.artifact)),
            ("engine", s(&self.engine.name().to_ascii_lowercase())),
            ("engine_index", num(self.engine_index as f64)),
            ("max_batch", num(self.batch.max_batch as f64)),
            (
                "batch_timeout_us",
                num(self.batch.timeout.as_micros() as f64),
            ),
            ("score_fidelity", Json::Bool(self.score_fidelity)),
        ])
    }
}

/// A full declarative pipeline: instances, routing, and stream shape.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    pub instances: Vec<InstanceSpec>,
    /// How frames map to instances.
    pub route: RoutePolicy,
    /// Number of CT frames to stream through the pipeline.
    pub frames: usize,
    /// Number of concurrent input streams (client-server scheme > 1).
    pub streams: usize,
    /// Maximum in-flight frames per instance before backpressure.
    pub queue_depth: usize,
    /// RNG seed for workload generation.
    pub seed: u64,
}

impl Default for PipelineSpec {
    fn default() -> Self {
        PipelineSpec {
            instances: Vec::new(),
            route: RoutePolicy::Fanout,
            frames: 256,
            streams: 1,
            queue_depth: 4,
            seed: 0xED6E,
        }
    }
}

impl PipelineSpec {
    /// Serialize to a config-schema JSON document (`route`, stream shape,
    /// and the `instances: [...]` array): the writer half of the config
    /// loader, so `plan --emit-spec` output reloads through
    /// [`crate::config::PipelineConfig::from_json_str`] unchanged —
    /// see [`Self::from_json_str`] for the inverse.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("route", s(self.route.name())),
            ("frames", num(self.frames as f64)),
            ("streams", num(self.streams as f64)),
            ("queue_depth", num(self.queue_depth as f64)),
            ("seed", num(self.seed as f64)),
            (
                "instances",
                arr(self.instances.iter().map(|i| i.to_json()).collect()),
            ),
        ])
    }

    /// Reload a spec emitted by [`Self::to_json`] through the existing
    /// config parser (round trip: spec → JSON → spec).
    pub fn from_json_str(text: &str) -> Result<PipelineSpec> {
        Ok(crate::config::PipelineConfig::from_json_str(text)?.spec())
    }

    /// Fail-fast structural validation (instance set, labels, counts).
    pub fn validate(&self) -> Result<()> {
        if self.instances.is_empty() {
            return Err(Error::Pipeline(
                "pipeline spec has no instances (add at least one)".into(),
            ));
        }
        for (i, inst) in self.instances.iter().enumerate() {
            if inst.label.is_empty() {
                return Err(Error::Pipeline(format!("instance {i} has an empty label")));
            }
            if inst.artifact.is_empty() {
                return Err(Error::Pipeline(format!(
                    "instance `{}` has an empty artifact name",
                    inst.label
                )));
            }
            if inst.batch.max_batch == 0 {
                return Err(Error::Pipeline(format!(
                    "instance `{}`: max_batch must be > 0",
                    inst.label
                )));
            }
            if inst.batch.max_batch > MAX_BATCH_LIMIT {
                return Err(Error::Pipeline(format!(
                    "instance `{}`: max_batch {} exceeds the supported maximum {MAX_BATCH_LIMIT}",
                    inst.label, inst.batch.max_batch
                )));
            }
            if inst.engine_index >= inst.engine.units() {
                return Err(Error::Pipeline(format!(
                    "instance `{}`: engine index {} out of range for {} ({} unit(s))",
                    inst.label,
                    inst.engine_index,
                    inst.engine,
                    inst.engine.units()
                )));
            }
            if self.instances[..i].iter().any(|o| o.label == inst.label) {
                return Err(Error::Pipeline(format!(
                    "duplicate instance label `{}`",
                    inst.label
                )));
            }
        }
        if self.frames == 0 {
            return Err(Error::Pipeline("frames must be > 0".into()));
        }
        if self.streams == 0 {
            return Err(Error::Pipeline("streams must be > 0".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Pipeline("queue_depth must be > 0".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_instance_spec() -> PipelineSpec {
        PipelineSpec {
            instances: vec![
                InstanceSpec::new("gan", "gen_cropping").scored(true),
                InstanceSpec::new("yolo", "yolo_lite").on_engine(EngineKind::Dla),
            ],
            ..PipelineSpec::default()
        }
    }

    #[test]
    fn valid_spec_passes() {
        two_instance_spec().validate().unwrap();
    }

    #[test]
    fn empty_instances_rejected() {
        let err = PipelineSpec::default().validate().unwrap_err();
        assert!(err.to_string().contains("no instances"));
    }

    #[test]
    fn duplicate_labels_rejected() {
        let mut spec = two_instance_spec();
        spec.instances[1].label = "gan".into();
        let err = spec.validate().unwrap_err();
        assert!(err.to_string().contains("duplicate instance label"));
    }

    #[test]
    fn zero_counts_rejected() {
        let mut spec = two_instance_spec();
        spec.frames = 0;
        assert!(spec.validate().is_err());
        let mut spec = two_instance_spec();
        spec.queue_depth = 0;
        assert!(spec.validate().is_err());
        let mut spec = two_instance_spec();
        spec.instances[0].batch.max_batch = 0;
        assert!(spec.validate().is_err());
    }

    #[test]
    fn absurd_max_batch_rejected() {
        let mut spec = two_instance_spec();
        spec.instances[0].batch.max_batch = 100_000_000;
        let err = spec.validate().unwrap_err();
        assert!(err.to_string().contains("exceeds the supported maximum"));
        spec.instances[0].batch.max_batch = MAX_BATCH_LIMIT;
        spec.validate().unwrap();
    }

    #[test]
    fn engine_index_bounds_enforced() {
        let mut spec = two_instance_spec();
        spec.instances[1] = spec.instances[1].clone().on_engine_unit(EngineKind::Dla, 1);
        spec.validate().unwrap();
        spec.instances[1].engine_index = 2; // Jetson has two DLA cores
        let err = spec.validate().unwrap_err();
        assert!(err.to_string().contains("engine index 2 out of range"));
        let mut spec = two_instance_spec();
        spec.instances[0] = spec.instances[0].clone().on_engine_unit(EngineKind::Gpu, 1);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn artifact_catalog_is_enforced() {
        check_artifact_name("gen_cropping").unwrap();
        check_artifact_name("yolo_lite").unwrap();
        let err = check_artifact_name("resnet999").unwrap_err();
        assert!(err.to_string().contains("unknown artifact"));
        assert!(err.to_string().contains("gen_original"));
    }

    #[test]
    fn spec_json_roundtrips_through_the_config_parser() {
        use crate::hw::EngineKind;
        let mut spec = two_instance_spec();
        spec.instances[1] = spec.instances[1].clone().on_engine_unit(EngineKind::Dla, 1);
        spec.instances[0].batch.max_batch = 8;
        spec.route = RoutePolicy::RrFanoutLast;
        spec.frames = 96;
        spec.streams = 2;
        spec.seed = 42;
        let text = spec.to_json().to_pretty();
        let back = PipelineSpec::from_json_str(&text).unwrap();
        assert_eq!(back.instances.len(), 2);
        assert_eq!(back.route, RoutePolicy::RrFanoutLast);
        assert_eq!(back.frames, 96);
        assert_eq!(back.streams, 2);
        assert_eq!(back.seed, 42);
        assert_eq!(back.instances[0].batch.max_batch, 8);
        assert_eq!(back.instances[1].engine, EngineKind::Dla);
        assert_eq!(back.instances[1].engine_index, 1);
        assert!(back.instances[0].score_fidelity);
        // the writer is deterministic: a second trip is byte-identical
        assert_eq!(back.to_json().to_pretty(), back.to_json().to_pretty());
        assert_eq!(
            PipelineSpec::from_json_str(&back.to_json().to_pretty())
                .unwrap()
                .to_json()
                .to_pretty(),
            back.to_json().to_pretty()
        );
    }

    #[test]
    fn every_catalog_entry_builds_a_graph() {
        // the catalog is one table: any name that parses must also price
        for (name, _) in ARTIFACT_CATALOG {
            let g = artifact_graph(name).unwrap();
            assert!(!g.compute_layers().is_empty(), "{name}");
        }
    }
}
