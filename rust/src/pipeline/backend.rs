//! Pluggable inference backends.
//!
//! The coordinator is generic over *how* an instance executes a frame:
//!
//! * [`PjrtBackend`] — the real serving path: PJRT execution of the
//!   AOT-compiled JAX/Pallas artifacts (HLO text + weights on disk);
//! * [`SimBackend`] — a deterministic stand-in priced by the calibrated
//!   roofline latency model ([`crate::cost`]), so the full pipeline
//!   (router, batcher, backpressure, metrics) can be driven, tested and
//!   benchmarked with **no artifacts on disk** and no `make artifacts`.
//!
//! Backends are shared across worker threads (`Send + Sync`); all
//! per-thread state (PJRT handles are not `Send`) lives in the
//! [`ModelRunner`] each worker opens after the thread boundary.

use super::frame::Frame;
use super::spec::{artifact_graph, InstanceSpec};
use crate::cost::latency::LatencyModel;
use crate::error::{Error, Result};
use crate::hw::{EngineKind, SocSpec};
#[cfg(feature = "pjrt")]
use crate::runtime::{Artifact, RuntimeClient};
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
use std::time::Duration;

/// Per-worker model executor, constructed on the worker thread via
/// [`InferenceBackend::open`].
pub trait ModelRunner {
    /// Run one frame through the model; returns the primary output tensor
    /// flattened (the reconstruction for GAN-style models).
    fn run(&mut self, frame: &Frame) -> Result<Vec<f32>>;
}

/// Where and how pipeline instances execute.
pub trait InferenceBackend: Send + Sync {
    /// Short backend identifier (`pjrt`, `sim`).
    fn name(&self) -> &'static str;

    /// Fail-fast check that `spec` is servable. Called by the session
    /// builder before any worker thread spawns, so a missing artifact or an
    /// unmodelable placement errors at build time, not mid-stream.
    fn prepare(&self, spec: &InstanceSpec) -> Result<()>;

    /// Open a per-worker runner for `spec` (called on the worker thread).
    fn open(&self, spec: &InstanceSpec) -> Result<Box<dyn ModelRunner>>;
}

// ---------------------------------------------------------------------------
// PJRT backend (the real serving path)
// ---------------------------------------------------------------------------

/// Executes AOT artifacts through PJRT. Each worker owns a private client +
/// compiled executable — the same isolation a per-engine TensorRT context
/// gives on the Jetson. Gated behind the default-on `pjrt` cargo feature
/// (the `xla` bindings need the native XLA extension; build with
/// `--no-default-features` to serve from [`SimBackend`] alone).
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    artifact_dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Self {
        PjrtBackend {
            artifact_dir: artifact_dir.into(),
        }
    }

    pub fn artifact_dir(&self) -> &std::path::Path {
        &self.artifact_dir
    }
}

#[cfg(feature = "pjrt")]
impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, spec: &InstanceSpec) -> Result<()> {
        let hlo = self.artifact_dir.join(format!("{}.hlo.txt", spec.artifact));
        if !hlo.exists() {
            return Err(Error::Runtime(format!(
                "artifact `{}` missing: {} (run `make artifacts`)",
                spec.artifact,
                hlo.display()
            )));
        }
        Ok(())
    }

    fn open(&self, spec: &InstanceSpec) -> Result<Box<dyn ModelRunner>> {
        let client = RuntimeClient::cpu()?;
        let artifact = Artifact::load(&client, &self.artifact_dir, &spec.artifact)?;
        Ok(Box::new(PjrtRunner { artifact }))
    }
}

#[cfg(feature = "pjrt")]
struct PjrtRunner {
    artifact: Artifact,
}

#[cfg(feature = "pjrt")]
impl ModelRunner for PjrtRunner {
    fn run(&mut self, frame: &Frame) -> Result<Vec<f32>> {
        let outputs = self.artifact.run_image(&frame.data)?;
        let first = outputs.into_iter().next().ok_or_else(|| {
            Error::Runtime(format!("artifact `{}` produced no outputs", self.artifact.name))
        })?;
        Ok(first.data)
    }
}

// ---------------------------------------------------------------------------
// Sim backend (deterministic, artifact-free)
// ---------------------------------------------------------------------------

/// Deterministic latency-model backend. Each known artifact maps to its
/// layer graph; a frame "executes" by sleeping that graph's roofline
/// latency on the instance's engine (scaled by `time_scale`) and echoing
/// the input as the output tensor — deterministic content, finite PSNR
/// against synthetic ground truth, no PJRT anywhere.
pub struct SimBackend {
    soc: SocSpec,
    time_scale: f64,
}

impl SimBackend {
    pub fn new(soc: SocSpec) -> Self {
        SimBackend {
            soc,
            time_scale: 1.0,
        }
    }

    /// Scale modeled latencies; `0.0` skips sleeping entirely, which turns
    /// a session run into a pure coordinator-overhead measurement (used by
    /// the `hotpath` bench and CI tests).
    pub fn with_time_scale(mut self, scale: f64) -> Self {
        self.time_scale = scale.max(0.0);
        self
    }

    /// Modeled single-frame latency for `spec` on this SoC, seconds. The
    /// artifact → graph mapping is the shared [`super::spec::ARTIFACT_CATALOG`].
    pub fn frame_latency(&self, spec: &InstanceSpec) -> Result<f64> {
        match spec.engine {
            EngineKind::Gpu | EngineKind::Dla | EngineKind::Cpu => {}
            other => {
                return Err(Error::Config(format!(
                    "sim backend: engine {other} is not part of SoC `{}`",
                    self.soc.name
                )))
            }
        }
        let g = artifact_graph(&spec.artifact)?;
        Ok(LatencyModel::new(self.soc.clone()).graph_latency(&g, spec.engine))
    }
}

impl InferenceBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn prepare(&self, spec: &InstanceSpec) -> Result<()> {
        self.frame_latency(spec).map(|_| ())
    }

    fn open(&self, spec: &InstanceSpec) -> Result<Box<dyn ModelRunner>> {
        let secs = self.frame_latency(spec)? * self.time_scale;
        Ok(Box::new(SimRunner {
            sleep: Duration::from_secs_f64(secs),
        }))
    }
}

struct SimRunner {
    sleep: Duration,
}

impl ModelRunner for SimRunner {
    fn run(&mut self, frame: &Frame) -> Result<Vec<f32>> {
        if !self.sleep.is_zero() {
            std::thread::sleep(self.sleep);
        }
        Ok(frame.data.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{orin, xavier};
    use std::time::Instant;

    fn inst(artifact: &str, engine: EngineKind) -> InstanceSpec {
        InstanceSpec::new("t", artifact).on_engine(engine)
    }

    #[test]
    fn sim_prices_known_artifacts() {
        let b = SimBackend::new(orin());
        let gan = b.frame_latency(&inst("gen_cropping", EngineKind::Gpu)).unwrap();
        let yolo = b.frame_latency(&inst("yolo_lite", EngineKind::Gpu)).unwrap();
        assert!(gan > 0.0 && yolo > 0.0);
        // the reduced 64x64 detector is far cheaper than the paper-scale GAN
        assert!(yolo < gan);
        // DLA-placed GAN is slower than GPU-placed on the same SoC
        let dla = b.frame_latency(&inst("gen_cropping", EngineKind::Dla)).unwrap();
        assert!(dla > gan);
    }

    #[test]
    fn sim_rejects_unknown_artifact_and_engine() {
        let b = SimBackend::new(orin());
        let err = b.prepare(&inst("nope", EngineKind::Gpu)).unwrap_err();
        assert!(err.to_string().contains("unknown artifact"));
        let err = b.prepare(&inst("gen_cropping", EngineKind::Fpga)).unwrap_err();
        assert!(err.to_string().contains("not part of SoC"));
    }

    #[test]
    fn sim_runner_is_deterministic_identity() {
        let b = SimBackend::new(orin()).with_time_scale(0.0);
        let spec = inst("yolo_lite", EngineKind::Gpu);
        let mut r = b.open(&spec).unwrap();
        let frame = Frame {
            id: 0,
            stream: 0,
            data: vec![0.25, -0.5, 1.0],
            width: 0,
            height: 0,
            gt_mri: None,
            admitted: Instant::now(),
        };
        assert_eq!(r.run(&frame).unwrap(), frame.data);
        assert_eq!(r.run(&frame).unwrap(), frame.data);
    }

    #[test]
    fn time_scale_zero_skips_sleep() {
        let b = SimBackend::new(xavier()).with_time_scale(0.0);
        let spec = inst("gen_original", EngineKind::Gpu);
        let mut r = b.open(&spec).unwrap();
        let frame = Frame {
            id: 0,
            stream: 0,
            data: vec![0.0; 16],
            width: 4,
            height: 4,
            gt_mri: None,
            admitted: Instant::now(),
        };
        let t0 = Instant::now();
        for _ in 0..64 {
            r.run(&frame).unwrap();
        }
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_prepare_fails_fast_on_missing_artifact() {
        let b = PjrtBackend::new("/nonexistent");
        let err = b.prepare(&inst("gen_cropping", EngineKind::Gpu)).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
