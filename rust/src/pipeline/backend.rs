//! Pluggable inference backends.
//!
//! The coordinator is generic over *how* an instance executes a frame:
//!
//! * [`PjrtBackend`] — the real serving path: PJRT execution of the
//!   AOT-compiled JAX/Pallas artifacts (HLO text + weights on disk);
//! * [`SimBackend`] — a deterministic stand-in priced by the calibrated
//!   roofline latency model ([`crate::cost`]), so the full pipeline
//!   (router, batcher, backpressure, metrics) can be driven, tested and
//!   benchmarked with **no artifacts on disk** and no `make artifacts`.
//!
//! Backends are shared across worker threads (`Send + Sync`); all
//! per-thread state (PJRT handles are not `Send`) lives in the
//! [`ModelRunner`] each worker opens after the thread boundary.
//!
//! Execution is batched end-to-end: the worker hands the batcher's whole
//! output to [`ModelRunner::execute_batch`], which is **one** dispatch —
//! the sim amortizes per-dispatch launch overhead and weight traffic
//! across the batch ([`SimBackend::batch_latency`]), and the PJRT path
//! stacks the frames into a single device transfer + execute when the
//! compiled batch dimension matches. Outputs are `Arc`-shared
//! [`FramePlane`]s: the sim echoes the input plane with a refcount bump
//! (zero copy), and a plane is only ever materialised when a backend
//! writes a fresh tensor out.

use super::engines::DispatchProfile;
use super::frame::Frame;
use super::plane::FramePlane;
use super::spec::{artifact_graph, InstanceSpec};
use crate::cost::contention::{bandwidth_demand, memory_intensity};
use crate::cost::flops::{aggregate_cost, layer_param_bytes, node_cost, LayerCost};
use crate::cost::latency::batched_layer_latency;
use crate::error::{Error, Result};
use crate::graph::Graph;
use crate::hw::{EngineKind, SocSpec};
#[cfg(feature = "pjrt")]
use crate::runtime::{Artifact, RuntimeClient};
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// What a model emits per frame: the primary output tensor flattened (the
/// reconstruction for GAN-style models), shareable without copying.
pub type Output = Arc<FramePlane>;

/// Per-worker model executor, constructed on the worker thread via
/// [`InferenceBackend::open`].
pub trait ModelRunner {
    /// Run one frame through the model.
    fn run(&mut self, frame: &Frame) -> Result<Output>;

    /// Execute `frames` as **one** batched dispatch where the backend
    /// supports it, preserving order. The default falls back to per-frame
    /// execution, so `run` remains the only method a backend must provide.
    fn execute_batch(&mut self, frames: &[Frame]) -> Result<Vec<Output>> {
        frames.iter().map(|f| self.run(f)).collect()
    }

    /// Produce the batch's outputs **without modeling time**: called when
    /// an external [`super::engines::EngineArbiter`] holds the engine for
    /// the priced duration instead (the backend supplied a
    /// [`DispatchProfile`]). Backends whose `execute_batch` sleeps to
    /// model latency must override this with the sleep-free variant; real
    /// backends (whose execution *is* the time) keep the default.
    fn execute_batch_untimed(&mut self, frames: &[Frame]) -> Result<Vec<Output>> {
        self.execute_batch(frames)
    }
}

/// Where and how pipeline instances execute.
pub trait InferenceBackend: Send + Sync {
    /// Short backend identifier (`pjrt`, `sim`).
    fn name(&self) -> &'static str;

    /// Fail-fast check that `spec` is servable. Called by the session
    /// builder before any worker thread spawns, so a missing artifact or an
    /// unmodelable placement errors at build time, not mid-stream.
    fn prepare(&self, spec: &InstanceSpec) -> Result<()>;

    /// Open a per-worker runner for `spec` (called on the worker thread).
    fn open(&self, spec: &InstanceSpec) -> Result<Box<dyn ModelRunner>>;

    /// Modeled engine-occupancy profile of one batched dispatch, when the
    /// backend prices execution instead of performing it. `Some` makes the
    /// driver hold the instance's engine for the priced duration (via the
    /// shared [`super::engines::EngineArbiter`]) and call
    /// [`ModelRunner::execute_batch_untimed`]; `None` (the default, real
    /// backends) makes the arbiter hold the engine around the real
    /// dispatch and measure it.
    fn dispatch_profile(&self, spec: &InstanceSpec) -> Result<Option<DispatchProfile>> {
        let _ = spec;
        Ok(None)
    }
}

// ---------------------------------------------------------------------------
// PJRT backend (the real serving path)
// ---------------------------------------------------------------------------

/// Executes AOT artifacts through PJRT. Each worker owns a private client +
/// compiled executable — the same isolation a per-engine TensorRT context
/// gives on the Jetson. Gated behind the default-on `pjrt` cargo feature
/// (the `xla` bindings need the native XLA extension; build with
/// `--no-default-features` to serve from [`SimBackend`] alone).
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    artifact_dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Self {
        PjrtBackend {
            artifact_dir: artifact_dir.into(),
        }
    }

    pub fn artifact_dir(&self) -> &std::path::Path {
        &self.artifact_dir
    }
}

#[cfg(feature = "pjrt")]
impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, spec: &InstanceSpec) -> Result<()> {
        let hlo = self.artifact_dir.join(format!("{}.hlo.txt", spec.artifact));
        if !hlo.exists() {
            return Err(Error::Runtime(format!(
                "artifact `{}` missing: {} (run `make artifacts`)",
                spec.artifact,
                hlo.display()
            )));
        }
        Ok(())
    }

    fn open(&self, spec: &InstanceSpec) -> Result<Box<dyn ModelRunner>> {
        let client = RuntimeClient::cpu()?;
        let artifact = Artifact::load(&client, &self.artifact_dir, &spec.artifact)?;
        Ok(Box::new(PjrtRunner { artifact }))
    }
}

#[cfg(feature = "pjrt")]
struct PjrtRunner {
    artifact: Artifact,
}

#[cfg(feature = "pjrt")]
impl PjrtRunner {
    fn first_output(
        &self,
        outputs: Vec<crate::runtime::artifact::OutputTensor>,
    ) -> Result<Vec<f32>> {
        outputs
            .into_iter()
            .next()
            .map(|t| t.data)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "artifact `{}` produced no outputs",
                    self.artifact.name
                ))
            })
    }

    /// One stacked dispatch of up to `input_shape[0]` frames: a single
    /// host buffer, zero-padded when the chunk is partial (a batcher
    /// timeout flush), one execute, pad outputs discarded.
    fn dispatch_stacked(&mut self, chunk: &[Frame]) -> Result<Vec<Output>> {
        let nb = self.artifact.input_shape[0];
        let per: usize = self.artifact.input_shape[1..].iter().product();
        debug_assert!(!chunk.is_empty() && chunk.len() <= nb);
        for f in chunk {
            if f.data.len() != per {
                return Err(Error::Runtime(format!(
                    "frame {} has {} elements, artifact `{}` expects {per} per frame",
                    f.id,
                    f.data.len(),
                    self.artifact.name
                )));
            }
        }
        let mut stacked = vec![0.0f32; per * nb];
        for (slot, f) in stacked.chunks_mut(per).zip(chunk.iter()) {
            slot.copy_from_slice(&f.data);
        }
        let outputs = self.artifact.run_images_stacked(&stacked, nb)?;
        let first = self.first_output(outputs)?;
        if first.len() % nb != 0 {
            return Err(Error::Runtime(format!(
                "artifact `{}`: stacked output of {} elements not divisible by batch {nb}",
                self.artifact.name,
                first.len()
            )));
        }
        let out_per = first.len() / nb;
        Ok(first
            .chunks(out_per)
            .take(chunk.len())
            .map(|c| FramePlane::from_vec(c.to_vec()))
            .collect())
    }
}

#[cfg(feature = "pjrt")]
impl ModelRunner for PjrtRunner {
    fn run(&mut self, frame: &Frame) -> Result<Output> {
        if self.artifact.input_shape[0] != 1 {
            // batch-compiled artifact: pad a single frame through the
            // stacked path rather than hand `run_image` a short buffer
            let mut outs = self.dispatch_stacked(std::slice::from_ref(frame))?;
            return outs.pop().ok_or_else(|| {
                Error::Runtime(format!(
                    "artifact `{}` produced no outputs",
                    self.artifact.name
                ))
            });
        }
        let outputs = self.artifact.run_image(&frame.data)?;
        Ok(FramePlane::from_vec(self.first_output(outputs)?))
    }

    /// Batched execution against the compiled leading batch dimension
    /// `nb = input_shape[0]`: the batch is cut into `nb`-sized chunks,
    /// each a **single** stacked transfer + execute (the tail chunk is
    /// zero-padded, its pad outputs discarded). Batch-1 artifacts — all
    /// the current AOT exports — keep per-frame dispatch; recompile with a
    /// batch dimension to light up stacking.
    fn execute_batch(&mut self, frames: &[Frame]) -> Result<Vec<Output>> {
        let nb = self.artifact.input_shape[0];
        if nb <= 1 {
            return frames.iter().map(|f| self.run(f)).collect();
        }
        let mut outs = Vec::with_capacity(frames.len());
        for chunk in frames.chunks(nb) {
            outs.extend(self.dispatch_stacked(chunk)?);
        }
        Ok(outs)
    }
}

// ---------------------------------------------------------------------------
// Sim backend (deterministic, artifact-free)
// ---------------------------------------------------------------------------

/// Deterministic latency-model backend. Each known artifact maps to its
/// layer graph; a frame "executes" by sleeping that graph's roofline
/// latency on the instance's engine (scaled by `time_scale`) and echoing
/// the input plane as the output (an `Arc` refcount bump — deterministic
/// content, finite PSNR against synthetic ground truth, no PJRT and no
/// pixel copies anywhere).
pub struct SimBackend {
    soc: SocSpec,
    time_scale: f64,
}

impl SimBackend {
    pub fn new(soc: SocSpec) -> Self {
        SimBackend {
            soc,
            time_scale: 1.0,
        }
    }

    /// Scale modeled latencies; `0.0` skips sleeping entirely, which turns
    /// a session run into a pure coordinator-overhead measurement (used by
    /// the `hotpath` bench and CI tests).
    pub fn with_time_scale(mut self, scale: f64) -> Self {
        self.time_scale = scale.max(0.0);
        self
    }

    /// Modeled single-frame latency for `spec` on this SoC, seconds. The
    /// artifact → graph mapping is the shared [`super::spec::ARTIFACT_CATALOG`].
    pub fn frame_latency(&self, spec: &InstanceSpec) -> Result<f64> {
        self.batch_latency(spec, 1)
    }

    /// Modeled latency of ONE batched dispatch of `n` frames, seconds:
    /// the sum of [`batched_layer_latency`] over the artifact's layer
    /// graph — compute and activation traffic scale with `n`, the kernel
    /// launch and the weight fetch are paid once per layer per dispatch.
    /// Hence `batch_latency(spec, n) < n * frame_latency(spec)` strictly
    /// (the margin is what a real batched dispatch saves), and `n == 1`
    /// reduces exactly to the [`crate::cost::latency::LatencyModel`]
    /// roofline.
    pub fn batch_latency(&self, spec: &InstanceSpec, n: usize) -> Result<f64> {
        self.check_engine(spec)?;
        let g = artifact_graph(&spec.artifact)?;
        Ok(self.table_dispatch_latency(&layer_table(&g), spec.engine, n))
    }

    fn check_engine(&self, spec: &InstanceSpec) -> Result<()> {
        match spec.engine {
            EngineKind::Gpu | EngineKind::Dla | EngineKind::Cpu => Ok(()),
            other => Err(Error::Config(format!(
                "sim backend: engine {other} is not part of SoC `{}`",
                self.soc.name
            ))),
        }
    }

    /// Dispatch latency of `n` stacked frames over a precomputed
    /// [`layer_table`] (lets `open` price every batch size from one graph
    /// walk).
    fn table_dispatch_latency(
        &self,
        table: &[(LayerCost, f64)],
        engine: EngineKind,
        n: usize,
    ) -> f64 {
        let engine = self.soc.engine(engine);
        table
            .iter()
            .map(|(cost, param_bytes)| batched_layer_latency(cost, *param_bytes, engine, n))
            .sum()
    }

    /// Time-scaled per-batch-size dispatch durations for `spec`'s policy
    /// plus the marginal per-extra-frame cost — the ONE pricing table both
    /// the standalone [`SimRunner`] and the arbiter's
    /// [`DispatchProfile`] are built from, so the two paths cannot drift.
    fn sleep_table(
        &self,
        table: &[(LayerCost, f64)],
        spec: &InstanceSpec,
    ) -> (Vec<Duration>, Duration) {
        let max_batch = spec.batch.max_batch.max(1);
        let mut sleep_for = Vec::with_capacity(max_batch);
        for n in 1..=max_batch {
            let secs = self.table_dispatch_latency(table, spec.engine, n) * self.time_scale;
            sleep_for.push(Duration::from_secs_f64(secs));
        }
        let marginal = if max_batch >= 2 {
            sleep_for[max_batch - 1].saturating_sub(sleep_for[max_batch - 2])
        } else {
            sleep_for[0]
        };
        (sleep_for, marginal)
    }
}

impl InferenceBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn prepare(&self, spec: &InstanceSpec) -> Result<()> {
        self.frame_latency(spec).map(|_| ())
    }

    fn open(&self, spec: &InstanceSpec) -> Result<Box<dyn ModelRunner>> {
        // The runner's precomputed dispatch-latency table (one entry per
        // batch size the instance's policy can produce, bounded by the
        // spec-validation cap on `max_batch`) IS the dispatch profile's —
        // one pricing source, so standalone runs and arbitrated serving
        // cannot drift. The hot path just indexes it.
        let p = self
            .dispatch_profile(spec)?
            .expect("SimBackend::dispatch_profile always prices");
        Ok(Box::new(SimRunner {
            sleep_for: p.sleep_for,
            marginal: p.marginal,
        }))
    }

    /// The sim is model-priced: hand the arbiter the per-batch-size
    /// latency table plus the PCCS inputs (aggregate memory intensity and
    /// bandwidth demand of the artifact's graph on the pinned engine —
    /// the same per-segment aggregation [`crate::sim::soc_sim`] uses) and
    /// the engine-switch reformat cost priced at the model's input tensor.
    fn dispatch_profile(&self, spec: &InstanceSpec) -> Result<Option<DispatchProfile>> {
        self.check_engine(spec)?;
        let g = artifact_graph(&spec.artifact)?;
        let (sleep_for, marginal) = self.sleep_table(&layer_table(&g), spec);
        let engine = self.soc.engine(spec.engine);
        let layers = g.compute_layers();
        let agg = aggregate_cost(&g, &layers);
        let io_bytes = layers
            .first()
            .map(|&id| {
                g.input_shapes(id)
                    .iter()
                    .map(|s| s.bytes())
                    .max()
                    .unwrap_or(0)
            })
            .unwrap_or(0);
        Ok(Some(DispatchProfile {
            sleep_for,
            marginal,
            intensity: memory_intensity(&agg, engine),
            bw_demand: bandwidth_demand(&agg, engine),
            dram_bw: self.soc.dram_bw,
            gamma: self.soc.contention_gamma,
            transition: Duration::from_secs_f64(
                self.soc.transition.latency(io_bytes) * self.time_scale,
            ),
        }))
    }
}

/// Per-layer `(cost, param_bytes)` pairs for a built graph — everything
/// the batched roofline needs, independent of batch size.
fn layer_table(g: &Graph) -> Vec<(LayerCost, f64)> {
    g.compute_layers()
        .into_iter()
        .map(|id| {
            let param_bytes = layer_param_bytes(&g.node(id).kind, &g.input_shapes(id));
            (node_cost(g, id), param_bytes)
        })
        .collect()
}

struct SimRunner {
    /// Modeled wall time of one batched dispatch of `i + 1` frames.
    sleep_for: Vec<Duration>,
    /// Per-extra-frame cost beyond the precomputed table (defensive; the
    /// batcher never exceeds `max_batch`).
    marginal: Duration,
}

impl SimRunner {
    fn dispatch_sleep(&self, n: usize) -> Duration {
        let table = &self.sleep_for;
        if n <= table.len() {
            table[n - 1]
        } else {
            table[table.len() - 1] + self.marginal * (n - table.len()) as u32
        }
    }
}

impl ModelRunner for SimRunner {
    fn run(&mut self, frame: &Frame) -> Result<Output> {
        let d = self.dispatch_sleep(1);
        if !d.is_zero() {
            std::thread::sleep(d);
        }
        Ok(Arc::clone(&frame.data))
    }

    fn execute_batch(&mut self, frames: &[Frame]) -> Result<Vec<Output>> {
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        let d = self.dispatch_sleep(frames.len());
        if !d.is_zero() {
            std::thread::sleep(d);
        }
        Ok(frames.iter().map(|f| Arc::clone(&f.data)).collect())
    }

    /// The sleep is the model; when the arbiter prices the dispatch, just
    /// echo the planes.
    fn execute_batch_untimed(&mut self, frames: &[Frame]) -> Result<Vec<Output>> {
        Ok(frames.iter().map(|f| Arc::clone(&f.data)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{orin, xavier};
    use crate::pipeline::batcher::BatchPolicy;
    use std::time::Instant;

    fn inst(artifact: &str, engine: EngineKind) -> InstanceSpec {
        InstanceSpec::new("t", artifact).on_engine(engine)
    }

    fn frame_with(data: Vec<f32>) -> Frame {
        Frame {
            id: 0,
            stream: 0,
            data: FramePlane::from_vec(data),
            width: 0,
            height: 0,
            gt_mri: None,
            admitted: Instant::now(),
            stamps: Default::default(),
        }
    }

    #[test]
    fn sim_prices_known_artifacts() {
        let b = SimBackend::new(orin());
        let gan = b.frame_latency(&inst("gen_cropping", EngineKind::Gpu)).unwrap();
        let yolo = b.frame_latency(&inst("yolo_lite", EngineKind::Gpu)).unwrap();
        assert!(gan > 0.0 && yolo > 0.0);
        // the reduced 64x64 detector is far cheaper than the paper-scale GAN
        assert!(yolo < gan);
        // DLA-placed GAN is slower than GPU-placed on the same SoC
        let dla = b.frame_latency(&inst("gen_cropping", EngineKind::Dla)).unwrap();
        assert!(dla > gan);
    }

    #[test]
    fn batched_dispatch_amortizes_launch_and_weights() {
        let b = SimBackend::new(orin());
        for artifact in ["gen_cropping", "yolo_lite"] {
            let spec = inst(artifact, EngineKind::Gpu);
            let one = b.frame_latency(&spec).unwrap();
            let four = b.batch_latency(&spec, 4).unwrap();
            // strictly cheaper than 4 independent dispatches (3 launch sets
            // + 3 weight re-reads saved), but never cheaper than the work
            // of 1
            assert!(
                four < 4.0 * one,
                "{artifact}: batch4 {four} !< 4x single {one}"
            );
            assert!(four > one, "{artifact}: batch must cost more than one");
            // n = 1 reduces exactly to the roofline single-frame latency
            assert!((b.batch_latency(&spec, 1).unwrap() - one).abs() < 1e-12);
        }
    }

    #[test]
    fn sim_rejects_unknown_artifact_and_engine() {
        let b = SimBackend::new(orin());
        let err = b.prepare(&inst("nope", EngineKind::Gpu)).unwrap_err();
        assert!(err.to_string().contains("unknown artifact"));
        let err = b.prepare(&inst("gen_cropping", EngineKind::Fpga)).unwrap_err();
        assert!(err.to_string().contains("not part of SoC"));
    }

    #[test]
    fn sim_runner_echoes_input_plane_zero_copy() {
        let b = SimBackend::new(orin()).with_time_scale(0.0);
        let spec = inst("yolo_lite", EngineKind::Gpu);
        let mut r = b.open(&spec).unwrap();
        let frame = frame_with(vec![0.25, -0.5, 1.0]);
        let out = r.run(&frame).unwrap();
        // deterministic identity, via refcount bump rather than memcpy
        assert!(Arc::ptr_eq(&out, &frame.data));
        assert_eq!(r.run(&frame).unwrap(), frame.data);
    }

    #[test]
    fn execute_batch_preserves_order_and_shares_planes() {
        let b = SimBackend::new(orin()).with_time_scale(0.0);
        let spec = inst("yolo_lite", EngineKind::Gpu).with_batch(BatchPolicy {
            max_batch: 4,
            timeout: Duration::from_micros(500),
        });
        let mut r = b.open(&spec).unwrap();
        let frames: Vec<Frame> = (0..3).map(|i| frame_with(vec![i as f32; 4])).collect();
        let outs = r.execute_batch(&frames).unwrap();
        assert_eq!(outs.len(), 3);
        for (f, o) in frames.iter().zip(outs.iter()) {
            assert!(Arc::ptr_eq(o, &f.data));
        }
        assert!(r.execute_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn dispatch_profile_prices_like_batch_latency() {
        let b = SimBackend::new(orin());
        let spec = inst("gen_cropping", EngineKind::Dla).with_batch(BatchPolicy {
            max_batch: 4,
            timeout: Duration::from_micros(500),
        });
        let p = b.dispatch_profile(&spec).unwrap().expect("sim is modeled");
        let one = p.dispatch_duration(1).as_secs_f64();
        let four = p.dispatch_duration(4).as_secs_f64();
        assert!((one - b.frame_latency(&spec).unwrap()).abs() < 1e-8);
        assert!((four - b.batch_latency(&spec, 4).unwrap()).abs() < 1e-8);
        assert!(four < 4.0 * one && four > one);
        // beyond the table: marginal extrapolation stays monotone
        assert!(p.dispatch_duration(6) > p.dispatch_duration(4));
        // PCCS inputs are sane for a conv-heavy graph
        assert_eq!(p.slowdown(0.0), 1.0);
        assert!(p.slowdown(100.0e9) > 1.0);
    }

    #[test]
    fn untimed_execution_echoes_without_sleeping() {
        // time_scale 1.0: a modeled batch of 8 originals costs ≥ 40 ms of
        // sleep; the untimed path must skip all of it.
        let b = SimBackend::new(orin());
        let spec = inst("gen_original", EngineKind::Gpu);
        let mut r = b.open(&spec).unwrap();
        let frames: Vec<Frame> = (0..8).map(|i| frame_with(vec![i as f32; 4])).collect();
        let t0 = Instant::now();
        let outs = r.execute_batch_untimed(&frames).unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(20),
            "untimed dispatch slept ({:?})",
            t0.elapsed()
        );
        assert_eq!(outs.len(), 8);
        for (f, o) in frames.iter().zip(outs.iter()) {
            assert!(Arc::ptr_eq(o, &f.data));
        }
    }

    #[test]
    fn time_scale_zero_skips_sleep() {
        let b = SimBackend::new(xavier()).with_time_scale(0.0);
        let spec = inst("gen_original", EngineKind::Gpu);
        let mut r = b.open(&spec).unwrap();
        let frame = frame_with(vec![0.0; 16]);
        let t0 = Instant::now();
        for _ in 0..64 {
            r.run(&frame).unwrap();
        }
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_prepare_fails_fast_on_missing_artifact() {
        let b = PjrtBackend::new("/nonexistent");
        let err = b.prepare(&inst("gen_cropping", EngineKind::Gpu)).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
