//! The serving-path engine arbiter.
//!
//! The paper's whole scheduling argument (Figs 10–14) rests on the GPU and
//! the two DLA cores being **exclusive** resources: two instances pinned to
//! the same engine serialize, instances on different engines run
//! concurrently but slow each other down through the shared DRAM (the PCCS
//! model of [`crate::cost::contention`]), and moving a tensor between
//! engines pays the TensorRT reformat penalty. The discrete-event
//! [`crate::sim`] models all of that; before this module existed the
//! *serving* driver modeled none of it — `InstanceSpec::engine` was
//! write-only and every worker free-ran on its own thread.
//!
//! [`EngineArbiter`] closes that gap. The driver creates one arbiter per
//! run; every worker routes each batched dispatch through
//! [`EngineArbiter::dispatch`], which:
//!
//! 1. acquires the instance's engine **unit** (GPU, DLA0, DLA1, ...) as an
//!    exclusive FIFO resource (ticket lock — contenders run in arrival
//!    order, no barging);
//! 2. charges the engine-switch reformat cost when the unit's occupant
//!    changes between dispatches (model-priced backends only);
//! 3. stretches the priced duration by the PCCS slowdown derived from the
//!    bandwidth demand of whatever is concurrently occupying *other*
//!    units (same formula the sim uses);
//! 4. records the occupation as [`Span`]s on a serving
//!    [`crate::sim::timeline::Timeline`], from which
//!    [`EngineArbiter::engine_snapshots`] derives the per-engine
//!    utilization / idle-gap numbers the paper reads off its Nsight
//!    screenshots.
//!
//! Model-priced backends (the sim) supply a [`DispatchProfile`] and the
//! arbiter *holds the unit for the priced duration* — the runner itself no
//! longer sleeps. Real backends (PJRT) supply no profile; the arbiter
//! simply holds the unit around the real dispatch, so placement serializes
//! identically in both modes.

// The dispatch path runs once per batched inference: it must neither
// allocate nor panic (a panic in Lease::drop would abort the process).
#![deny(clippy::unwrap_used)]

use crate::error::Error;
use crate::hw::EngineKind;
use crate::obs::stages::DispatchStamps;
use crate::sim::timeline::{Span, Timeline};
use crate::util::lock::{cv_wait, relock};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::spec::InstanceSpec;

/// Modeled occupancy of one batched dispatch — everything the arbiter
/// needs to price an engine hold without knowing the backend. Produced by
/// [`super::backend::InferenceBackend::dispatch_profile`] (the sim prices
/// it from the artifact's layer graph; real backends return `None` and are
/// measured instead).
#[derive(Debug, Clone)]
pub struct DispatchProfile {
    /// Wall time of one dispatch of `i + 1` frames (already time-scaled).
    pub(crate) sleep_for: Vec<Duration>,
    /// Per-extra-frame cost beyond the precomputed table.
    pub(crate) marginal: Duration,
    /// Memory-boundedness of the whole dispatch in `[0, 1]` (PCCS
    /// `self_intensity`): compute-bound dispatches hide contention,
    /// streaming ones feel it fully.
    pub(crate) intensity: f64,
    /// DRAM bandwidth this dispatch pulls while executing, bytes/s.
    pub(crate) bw_demand: f64,
    /// Shared DRAM capability the co-runner pressure normalizes against.
    pub(crate) dram_bw: f64,
    /// PCCS contention sensitivity (γ).
    pub(crate) gamma: f64,
    /// Reformat/fence cost charged when the engine's occupant switches
    /// between dispatches (already time-scaled).
    pub(crate) transition: Duration,
}

impl DispatchProfile {
    /// Priced duration of one dispatch of `n` frames (no contention).
    pub fn dispatch_duration(&self, n: usize) -> Duration {
        let n = n.max(1);
        if self.sleep_for.is_empty() {
            return self.marginal * n as u32;
        }
        if n <= self.sleep_for.len() {
            self.sleep_for[n - 1]
        } else {
            self.sleep_for[self.sleep_for.len() - 1]
                + self.marginal * (n - self.sleep_for.len()) as u32
        }
    }

    /// PCCS slowdown factor (≥ 1) given the co-runners' aggregate
    /// bandwidth demand — delegates to the sim's shared
    /// [`crate::cost::contention::slowdown_parts`] formula.
    pub fn slowdown(&self, corunner_bw: f64) -> f64 {
        crate::cost::contention::slowdown_parts(
            self.gamma,
            self.dram_bw,
            self.intensity,
            corunner_bw,
        )
    }
}

/// Per-engine serving statistics derived from the arbiter's timeline —
/// the Nsight-style numbers of the paper's Figs 10/13 (utilization, idle
/// gaps, block fragmentation), per physical unit.
#[derive(Debug, Clone)]
pub struct EngineSnapshot {
    /// Unit label (`GPU`, `DLA0`, `DLA1`, ...).
    pub label: String,
    pub kind: EngineKind,
    pub unit: usize,
    /// Busy fraction of the serving window (first to last span).
    pub utilization: f64,
    pub busy_seconds: f64,
    /// Number of compute occupations (batched dispatches).
    pub dispatches: usize,
    pub mean_block_ms: f64,
    pub idle_gap_ms_mean: f64,
    pub idle_gap_ms_p99: f64,
    pub idle_gap_count: usize,
}

/// FIFO ticket state of one physical engine unit.
#[derive(Debug, Default)]
struct UnitState {
    next_ticket: u64,
    serving: u64,
    /// Instance index of the current/most recent occupant (engine-switch
    /// detection).
    occupant: Option<usize>,
    /// Bandwidth demand of the dispatch currently holding the unit
    /// (`0.0` when idle or measured rather than modeled).
    busy_bw: f64,
}

#[derive(Debug)]
struct Unit {
    label: String,
    kind: EngineKind,
    index: usize,
    state: Mutex<UnitState>,
    cv: Condvar,
}

/// Holds one granted FIFO ticket; advances the queue on drop so the unit
/// is released on every exit path, panics included.
struct Lease<'a> {
    unit: &'a Unit,
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        // relock: if a worker panicked while holding this unit's state,
        // the queue must still advance — a second panic here would turn
        // one dead worker into a process abort (panic-in-drop) and wedge
        // every co-pinned worker behind a never-served ticket.
        let mut st = relock(&self.unit.state);
        st.serving += 1;
        st.busy_bw = 0.0;
        self.unit.cv.notify_all();
    }
}

/// Shared, exclusive-FIFO model of the SoC's physical engines for the
/// serving path. See the module docs for the contract.
#[derive(Debug)]
pub struct EngineArbiter {
    units: Vec<Unit>,
    /// `instance index -> unit index` placement map.
    unit_of: Vec<usize>,
    epoch: Instant,
    timeline: Mutex<Timeline>,
}

impl EngineArbiter {
    /// Build an arbiter over the distinct engine units the instances are
    /// pinned to (`InstanceSpec::{engine, engine_index}`).
    pub fn new(instances: &[InstanceSpec]) -> Self {
        let mut units: Vec<Unit> = Vec::new();
        let mut unit_of = Vec::with_capacity(instances.len());
        for inst in instances {
            let key = (inst.engine, inst.engine_index);
            let idx = match units.iter().position(|u| (u.kind, u.index) == key) {
                Some(i) => i,
                None => {
                    units.push(Unit {
                        label: inst.engine.unit_label(inst.engine_index),
                        kind: inst.engine,
                        index: inst.engine_index,
                        state: Mutex::new(UnitState::default()),
                        cv: Condvar::new(),
                    });
                    units.len() - 1
                }
            };
            unit_of.push(idx);
        }
        EngineArbiter {
            units,
            unit_of,
            epoch: Instant::now(),
            timeline: Mutex::new(Timeline::default()),
        }
    }

    /// Serving clock: seconds since arbiter creation (span timebase).
    /// Public so the serve front-end can align this core's timeline with
    /// its own epoch when merging phases across a re-plan handoff.
    pub fn clock_seconds(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    fn now(&self) -> f64 {
        self.clock_seconds()
    }

    /// Number of distinct physical engine units under arbitration.
    pub fn unit_count(&self) -> usize {
        self.units.len()
    }

    /// Execute one batched dispatch of `instance` under its engine's
    /// exclusive FIFO lease.
    ///
    /// With a [`DispatchProfile`] the unit is held for the priced duration
    /// (occupant-switch reformat + PCCS-stretched batch cost) — `run`
    /// must produce the outputs *without* modeling time itself
    /// ([`super::backend::ModelRunner::execute_batch_untimed`]). Without a
    /// profile, `run` is the real dispatch and the hold is measured.
    /// Errors from `run` release the unit and propagate; nothing is
    /// recorded for failed dispatches.
    pub fn dispatch<T>(
        &self,
        instance: usize,
        frame: u64,
        batch: usize,
        profile: Option<&DispatchProfile>,
        run: impl FnOnce() -> crate::error::Result<T>,
    ) -> crate::error::Result<T> {
        self.dispatch_stamped(instance, frame, batch, profile, run)
            .map(|(out, _)| out)
    }

    /// [`EngineArbiter::dispatch`] plus a [`DispatchStamps`] receipt —
    /// the engine-wait / reformat / execution durations actually charged,
    /// which the stream worker seals into each frame's
    /// [`crate::obs::StageStamps`]. Same cost either way: the receipt is
    /// three stack floats computed from clock reads already taken.
    pub fn dispatch_stamped<T>(
        &self,
        instance: usize,
        frame: u64,
        batch: usize,
        profile: Option<&DispatchProfile>,
        run: impl FnOnce() -> crate::error::Result<T>,
    ) -> crate::error::Result<(T, DispatchStamps)> {
        let t_enter = self.now();
        let unit = self
            .unit_of
            .get(instance)
            .and_then(|&u| self.units.get(u))
            .ok_or_else(|| Error::Pipeline(String::from("dispatch for an unplaced instance")))?;

        // ---- acquire (FIFO ticket) ----
        let switched = {
            let mut st = relock(&unit.state);
            let ticket = st.next_ticket;
            st.next_ticket += 1;
            while st.serving != ticket {
                st = cv_wait(&unit.cv, st);
            }
            let switched = st.occupant.is_some() && st.occupant != Some(instance);
            st.occupant = Some(instance);
            st.busy_bw = profile.map(|p| p.bw_demand).unwrap_or(0.0);
            switched
        };
        // Release on every exit path — including a panic unwinding out of
        // `run` — or the unit's ticket queue wedges and every co-pinned
        // worker (and the driver's join) hangs forever.
        let lease = Lease { unit };

        // ---- occupy ----
        let t0 = self.now();
        let result = run();
        // At most two spans per dispatch (optional reformat transition +
        // the execution) — tracked in two locals so the per-frame path
        // never touches the heap.
        let mut trans_span: Option<Span> = None;
        let mut exec_span: Option<Span> = None;
        let mut stamps = DispatchStamps::default();
        if result.is_ok() {
            let trans_s = match profile {
                Some(p) => {
                    // Concurrent occupancy of *other* units pulls on the
                    // shared DRAM: stretch this dispatch per PCCS.
                    let corunner_bw: f64 = self
                        .units
                        .iter()
                        .filter(|u| !std::ptr::eq(*u, unit))
                        .map(|u| relock(&u.state).busy_bw)
                        .sum();
                    let trans = if switched { p.transition } else { Duration::ZERO };
                    let exec = p.dispatch_duration(batch).mul_f64(p.slowdown(corunner_bw));
                    let total = trans + exec;
                    if !total.is_zero() {
                        std::thread::sleep(total);
                    }
                    trans.as_secs_f64()
                }
                None => 0.0,
            };
            let t1 = self.now();
            let exec_start = (t0 + trans_s).min(t1);
            stamps = DispatchStamps {
                wait_s: (t0 - t_enter).max(0.0),
                reformat_s: (exec_start - t0).max(0.0),
                exec_s: (t1 - exec_start).max(0.0),
            };
            if trans_s > 0.0 {
                trans_span = Some(Span {
                    engine: unit.kind,
                    unit: unit.index,
                    instance,
                    frame: frame as usize,
                    t0,
                    t1: exec_start,
                    is_transition: true,
                });
            }
            exec_span = Some(Span {
                engine: unit.kind,
                unit: unit.index,
                instance,
                frame: frame as usize,
                t0: exec_start,
                t1,
                is_transition: false,
            });
        }

        // ---- release ----
        drop(lease);
        if trans_span.is_some() || exec_span.is_some() {
            let mut tl = relock(&self.timeline);
            if let Some(sp) = trans_span {
                tl.push(sp);
            }
            if let Some(sp) = exec_span {
                tl.push(sp);
            }
        }
        result.map(|out| (out, stamps))
    }

    /// Copy of the serving timeline recorded so far.
    pub fn timeline(&self) -> Timeline {
        relock(&self.timeline).clone()
    }

    /// Spans recorded from index `from` on — the serve loop's incremental
    /// checkpoint read. Spans are pushed at dispatch *completion*, so the
    /// tail since the last read contains every span overlapping the time
    /// window since then; re-cloning the whole ever-growing trace per
    /// checkpoint would make long-running serving quadratic.
    pub fn spans_from(&self, from: usize) -> Vec<Span> {
        let tl = relock(&self.timeline);
        tl.spans.get(from..).map(|s| s.to_vec()).unwrap_or_default()
    }

    /// Per-unit utilization / idle-gap statistics over the serving window
    /// (first span start to last span end — backend open/compile time
    /// before the first dispatch does not dilute utilization).
    pub fn engine_snapshots(&self) -> Vec<EngineSnapshot> {
        let tl = relock(&self.timeline);
        let window = tl.span_window().map(|(a, b)| (b - a).max(f64::MIN_POSITIVE));
        self.units
            .iter()
            .map(|u| {
                let st = tl.unit_stats(u.kind, u.index);
                let utilization = window.map(|w| (st.busy / w).min(1.0)).unwrap_or(0.0);
                EngineSnapshot {
                    label: u.label.clone(),
                    kind: u.kind,
                    unit: u.index,
                    utilization,
                    busy_seconds: st.busy,
                    dispatches: st.span_count,
                    mean_block_ms: st.mean_block * 1e3,
                    idle_gap_ms_mean: st.idle_gaps.mean() * 1e3,
                    idle_gap_ms_p99: st.idle_gaps.p99() * 1e3,
                    idle_gap_count: st.idle_gaps.count(),
                }
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    fn spec(label: &str, engine: EngineKind, index: usize) -> InstanceSpec {
        InstanceSpec::new(label, "gen_cropping").on_engine_unit(engine, index)
    }

    fn profile(ms: u64, transition_ms: u64) -> DispatchProfile {
        DispatchProfile {
            sleep_for: vec![Duration::from_millis(ms)],
            marginal: Duration::from_millis(ms),
            intensity: 0.5,
            bw_demand: 50.0e9,
            dram_bw: 200.0e9,
            gamma: 0.5,
            transition: Duration::from_millis(transition_ms),
        }
    }

    #[test]
    fn units_are_deduplicated_and_mapped() {
        let arb = EngineArbiter::new(&[
            spec("a", EngineKind::Dla, 0),
            spec("b", EngineKind::Dla, 0),
            spec("c", EngineKind::Dla, 1),
            spec("d", EngineKind::Gpu, 0),
        ]);
        assert_eq!(arb.unit_count(), 3);
        assert_eq!(arb.unit_of, vec![0, 0, 1, 2]);
    }

    #[test]
    fn same_unit_dispatches_serialize_without_overlap() {
        let arb = std::sync::Arc::new(EngineArbiter::new(&[
            spec("a", EngineKind::Dla, 0),
            spec("b", EngineKind::Dla, 0),
        ]));
        let p = profile(2, 0);
        std::thread::scope(|s| {
            for inst in 0..2 {
                let arb = std::sync::Arc::clone(&arb);
                let p = p.clone();
                s.spawn(move || {
                    for f in 0..4u64 {
                        arb.dispatch(inst, f, 1, Some(&p), || Ok(())).unwrap();
                    }
                });
            }
        });
        let tl = arb.timeline();
        let mut spans: Vec<_> = tl.spans.iter().filter(|sp| !sp.is_transition).collect();
        assert_eq!(spans.len(), 8);
        spans.sort_by(|a, b| a.t0.partial_cmp(&b.t0).unwrap());
        for w in spans.windows(2) {
            assert!(
                w[1].t0 >= w[0].t1 - 1e-9,
                "exclusive unit overlapped: {:?} vs {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn occupant_switch_pays_transition_once_per_switch() {
        let arb = EngineArbiter::new(&[
            spec("a", EngineKind::Dla, 0),
            spec("b", EngineKind::Dla, 0),
        ]);
        let p = profile(1, 2);
        arb.dispatch(0, 0, 1, Some(&p), || Ok(())).unwrap();
        arb.dispatch(0, 1, 1, Some(&p), || Ok(())).unwrap(); // same occupant: free
        arb.dispatch(1, 2, 1, Some(&p), || Ok(())).unwrap(); // switch: pays
        arb.dispatch(0, 3, 1, Some(&p), || Ok(())).unwrap(); // switch back: pays
        let tl = arb.timeline();
        let transitions = tl.spans.iter().filter(|sp| sp.is_transition).count();
        assert_eq!(transitions, 2);
    }

    #[test]
    fn split_units_run_concurrently() {
        let arb = std::sync::Arc::new(EngineArbiter::new(&[
            spec("a", EngineKind::Dla, 0),
            spec("b", EngineKind::Dla, 1),
        ]));
        // intensity 0 => no contention stretch; 8 ms of work per unit
        let p = DispatchProfile {
            intensity: 0.0,
            ..profile(4, 0)
        };
        std::thread::scope(|s| {
            for inst in 0..2 {
                let arb = std::sync::Arc::clone(&arb);
                let p = p.clone();
                s.spawn(move || {
                    for f in 0..2u64 {
                        arb.dispatch(inst, f, 1, Some(&p), || Ok(())).unwrap();
                    }
                });
            }
        });
        // Concurrency is structural: the two units' busy windows overlap
        // (sleeps run in parallel), unlike same-unit dispatches.
        let tl = arb.timeline();
        let window_of = |unit: usize| {
            let spans: Vec<_> = tl.spans.iter().filter(|sp| sp.unit == unit).collect();
            let a = spans.iter().map(|sp| sp.t0).fold(f64::INFINITY, f64::min);
            let b = spans.iter().map(|sp| sp.t1).fold(0.0, f64::max);
            (a, b)
        };
        let (a0, b0) = window_of(0);
        let (a1, b1) = window_of(1);
        assert!(
            b0.min(b1) > a0.max(a1),
            "split units must overlap in time: unit0 [{a0}, {b0}] vs unit1 [{a1}, {b1}]"
        );
        let snaps = arb.engine_snapshots();
        assert_eq!(snaps.len(), 2);
        for s in &snaps {
            assert!(s.utilization > 0.0 && s.utilization <= 1.0);
            assert_eq!(s.dispatches, 2);
        }
    }

    #[test]
    fn slowdown_is_one_without_corunners_and_saturates() {
        let p = profile(1, 0);
        assert_eq!(p.slowdown(0.0), 1.0);
        let s1 = p.slowdown(50.0e9);
        let s2 = p.slowdown(150.0e9);
        let s3 = p.slowdown(1e15); // saturates at dram_bw
        assert!(1.0 < s1 && s1 < s2);
        assert!(s2 < s3 + 1e-12);
        assert!(s3 <= 1.0 + p.gamma * p.intensity + 1e-12);
    }

    #[test]
    fn failed_dispatch_releases_unit_and_records_nothing() {
        let arb = EngineArbiter::new(&[spec("a", EngineKind::Gpu, 0)]);
        let p = profile(1, 0);
        let err = arb
            .dispatch(0, 0, 1, Some(&p), || {
                Err::<(), _>(crate::error::Error::Pipeline("boom".into()))
            })
            .unwrap_err();
        assert!(err.to_string().contains("boom"));
        assert!(arb.timeline().spans.is_empty());
        // unit is free again: next dispatch succeeds
        arb.dispatch(0, 1, 1, Some(&p), || Ok(())).unwrap();
        assert_eq!(arb.timeline().spans.len(), 1);
    }

    #[test]
    fn panicking_dispatch_releases_the_unit() {
        let arb = EngineArbiter::new(&[spec("a", EngineKind::Gpu, 0)]);
        let p = profile(1, 0);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            arb.dispatch(0, 0, 1, Some(&p), || -> crate::error::Result<()> {
                panic!("backend blew up")
            })
        }));
        assert!(res.is_err());
        // the ticket queue must have advanced: the unit is serviceable,
        // not wedged (a co-pinned worker would otherwise hang forever)
        arb.dispatch(0, 1, 1, Some(&p), || Ok(())).unwrap();
        assert_eq!(arb.timeline().spans.len(), 1);
    }

    #[test]
    fn unplaced_instance_dispatch_is_an_error_not_a_panic() {
        // Regression: this used to index `units[unit_of[instance]]` and
        // panic the worker thread on an out-of-range instance; the driver
        // then hung at join behind the dead worker's queue.
        let arb = EngineArbiter::new(&[spec("a", EngineKind::Gpu, 0)]);
        let p = profile(1, 0);
        let err = arb.dispatch(7, 0, 1, Some(&p), || Ok(())).unwrap_err();
        assert!(err.to_string().contains("unplaced"), "got: {err}");
        assert!(arb.timeline().spans.is_empty());
        // the arbiter stays serviceable after the refused dispatch
        arb.dispatch(0, 0, 1, Some(&p), || Ok(())).unwrap();
        assert_eq!(arb.timeline().spans.len(), 1);
    }

    #[test]
    fn measured_dispatch_records_real_duration() {
        let arb = EngineArbiter::new(&[spec("a", EngineKind::Gpu, 0)]);
        arb.dispatch(0, 7, 1, None, || {
            std::thread::sleep(Duration::from_millis(3));
            Ok(())
        })
        .unwrap();
        let tl = arb.timeline();
        assert_eq!(tl.spans.len(), 1);
        let sp = &tl.spans[0];
        assert!(!sp.is_transition);
        assert_eq!(sp.frame, 7);
        assert!(sp.t1 - sp.t0 >= 0.003);
    }
}
