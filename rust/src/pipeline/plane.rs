//! Shared pixel planes and the recycling buffer pool.
//!
//! A [`FramePlane`] is one immutable W×H `f32` plane behind an `Arc`:
//! routing a frame to several instances (fanout) and batching are refcount
//! bumps, never O(W×H) memcpys. The [`PlanePool`] closes the allocation
//! loop: when the *last* `Arc` to a pooled plane drops (after every worker
//! is done with the frame), its buffer parks on the pool shelf and the
//! source picks it up for the next frame — sealed plane buffers are
//! allocated once and recycled, not re-allocated per frame.
//!
//! Invariants:
//!
//! * a plane is immutable once sealed — sharing is always safe;
//! * a plane is copied at most once per inference: when a backend writes a
//!   fresh output tensor out. Routing, queueing and batching never copy;
//! * dropping the pool while planes are in flight is fine — their buffers
//!   are simply freed instead of parked (the shelf link is a `Weak`).

// Plane recycling runs once per sourced frame on the producer thread.
#![deny(clippy::unwrap_used)]

use crate::util::lock::relock;
use std::sync::{Arc, Mutex, Weak};

/// How many free buffers a pool shelf retains before excess buffers are
/// dropped (bounds worst-case memory when consumers stall).
const DEFAULT_RETAIN: usize = 64;

#[derive(Debug)]
struct Shelf {
    free: Mutex<Vec<Vec<f32>>>,
    retain: usize,
}

/// One immutable, shareable pixel plane. Dereferences to `[f32]`.
#[derive(Debug)]
pub struct FramePlane {
    data: Vec<f32>,
    /// Pool to return the buffer to on final drop (`None` = plain heap).
    shelf: Option<Weak<Shelf>>,
}

impl FramePlane {
    /// Wrap an owned buffer into a shared plane with no pool backing.
    pub fn from_vec(data: Vec<f32>) -> Arc<FramePlane> {
        Arc::new(FramePlane { data, shelf: None })
    }

    /// The raw pixel slice (also available through `Deref`).
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }
}

impl std::ops::Deref for FramePlane {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl AsRef<[f32]> for FramePlane {
    fn as_ref(&self) -> &[f32] {
        &self.data
    }
}

impl PartialEq for FramePlane {
    fn eq(&self, other: &Self) -> bool {
        self.data == other.data
    }
}

impl Drop for FramePlane {
    fn drop(&mut self) {
        if let Some(weak) = self.shelf.take() {
            if let Some(shelf) = weak.upgrade() {
                // relock, not lock().ok(): a poisoned shelf must still
                // recycle buffers (and never panic inside Drop).
                let mut free = relock(&shelf.free);
                if free.len() < shelf.retain {
                    free.push(std::mem::take(&mut self.data));
                }
            }
        }
    }
}

/// Recycling allocator for [`FramePlane`] buffers. Cloning is cheap and
/// shares the shelf, so every source in a pipeline can draw from (and
/// return to) the same pool across threads.
#[derive(Debug, Clone)]
pub struct PlanePool {
    shelf: Arc<Shelf>,
}

impl Default for PlanePool {
    fn default() -> Self {
        PlanePool::with_retain(DEFAULT_RETAIN)
    }
}

impl PlanePool {
    /// Pool retaining up to `retain` free buffers.
    pub fn with_retain(retain: usize) -> Self {
        PlanePool {
            shelf: Arc::new(Shelf {
                free: Mutex::new(Vec::new()),
                retain,
            }),
        }
    }

    /// An empty buffer with capacity for `len` elements — recycled from the
    /// shelf when one is parked, freshly allocated otherwise. Fill it and
    /// [`seal`](PlanePool::seal) it into a plane.
    pub fn acquire(&self, len: usize) -> Vec<f32> {
        let recycled = relock(&self.shelf.free).pop();
        let mut buf = recycled.unwrap_or_default();
        buf.clear();
        buf.reserve(len);
        buf
    }

    /// Freeze a filled buffer into a shared plane whose backing buffer
    /// returns to this pool when the last `Arc` drops.
    pub fn seal(&self, data: Vec<f32>) -> Arc<FramePlane> {
        Arc::new(FramePlane {
            data,
            shelf: Some(Arc::downgrade(&self.shelf)),
        })
    }

    /// Number of free buffers currently parked (introspection for tests
    /// and benches).
    pub fn parked(&self) -> usize {
        relock(&self.shelf.free).len()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn plane_derefs_to_pixels() {
        let p = FramePlane::from_vec(vec![1.0, 2.0, 3.0]);
        assert_eq!(p.len(), 3);
        assert_eq!(p[1], 2.0);
        assert_eq!(p.as_slice(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn sealed_buffer_returns_to_pool_on_final_drop() {
        let pool = PlanePool::default();
        let plane = pool.seal(vec![0.5; 16]);
        let copy = Arc::clone(&plane);
        drop(plane);
        assert_eq!(pool.parked(), 0, "live clone must keep the buffer out");
        drop(copy);
        assert_eq!(pool.parked(), 1, "final drop must park the buffer");
        // the recycled buffer keeps its capacity
        let buf = pool.acquire(16);
        assert!(buf.capacity() >= 16);
        assert!(buf.is_empty());
        assert_eq!(pool.parked(), 0);
    }

    #[test]
    fn retain_bounds_the_shelf() {
        let pool = PlanePool::with_retain(2);
        for _ in 0..5 {
            drop(pool.seal(vec![0.0; 8]));
        }
        assert_eq!(pool.parked(), 2);
    }

    #[test]
    fn pool_drop_before_planes_is_safe() {
        let pool = PlanePool::default();
        let plane = pool.seal(vec![1.0; 4]);
        drop(pool);
        drop(plane); // shelf is gone; buffer is freed, no panic
    }

    #[test]
    fn unpooled_planes_compare_by_content() {
        let a = FramePlane::from_vec(vec![1.0, 2.0]);
        let b = FramePlane::from_vec(vec![1.0, 2.0]);
        let c = FramePlane::from_vec(vec![3.0]);
        assert_eq!(*a, *b);
        assert_ne!(*a, *c);
    }
}
