//! Pipeline metrics aggregation (thread-safe).
//!
//! Three loss-like events are deliberately kept distinct, because they
//! mean different things operationally:
//!
//! * **`dropped`** (per instance) — a droppable fanout copy hit a full
//!   queue and was shed by *backpressure overload* inside the pipeline;
//! * **`shed`** (run-global) — a frame was refused *before routing* by
//!   QoS admission control ([`crate::serve::admission`]): it never
//!   entered any queue, so charging it to an instance would be wrong;
//! * a **disconnected** worker queue is neither: the target leaves the
//!   routing rotation and the worker's own error surfaces at join.

// Per-frame counter path: a panic here kills a worker and wedges the run.
#![deny(clippy::unwrap_used)]

use crate::util::lock::relock;
use crate::util::stats::Summary;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Per-instance counters.
#[derive(Debug, Default)]
struct InstanceCounters {
    frames: usize,
    latency: Summary,
    /// Online reconstruction fidelity vs ground truth (GAN instances).
    psnr: Summary,
    ssim_pct: Summary,
    dropped: usize,
    /// Fidelity samples skipped (shape mismatch, missing ground truth,
    /// unscorable images) — surfaced so silent skips are visible.
    fidelity_skipped: usize,
}

/// One sink for online fidelity (PSNR / SSIM-percent) samples. The batch
/// driver's worker loop, the serve loop, and the k-space recon front-end
/// all score through [`crate::pipeline::driver::record_fidelity`] into
/// some implementor of this trait — [`Metrics`] (per-instance GAN-output
/// fidelity) and [`crate::pipeline::source::ReconStats`] (recon-stage
/// fidelity) — instead of each owning a private scoring path.
pub trait FidelitySink: Send + Sync {
    /// Record one scored sample for `slot` (the instance index; sinks
    /// that are not instance-addressed ignore it).
    fn fidelity(&self, slot: usize, psnr: f64, ssim_pct: f64);
    /// Record a sample that could not be scored (mismatched shapes,
    /// missing ground truth, degenerate images).
    fn fidelity_skipped(&self, slot: usize);
}

impl FidelitySink for Metrics {
    fn fidelity(&self, slot: usize, psnr: f64, ssim_pct: f64) {
        self.record_fidelity(slot, psnr, ssim_pct);
    }

    fn fidelity_skipped(&self, slot: usize) {
        self.record_fidelity_skipped(slot);
    }
}

/// Shared metrics hub.
#[derive(Debug)]
pub struct Metrics {
    /// Serving-clock origin: set at **first frame admission**, not at
    /// construction, so backend open/compile time (PJRT can take seconds)
    /// does not deflate reported FPS.
    serving_start: OnceLock<Instant>,
    instances: Vec<Mutex<InstanceCounters>>,
    labels: Vec<String>,
    /// Frames refused by admission control before routing (run-global —
    /// a shed frame never reached an instance). Distinct from the
    /// per-instance overload `dropped` counter; see the module docs.
    shed: AtomicUsize,
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct InstanceSnapshot {
    pub label: String,
    pub frames: usize,
    pub fps: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p99: f64,
    pub latency_ms_mean: f64,
    pub psnr_mean: f64,
    pub ssim_pct_mean: f64,
    pub dropped: usize,
    pub fidelity_skipped: usize,
}

impl Metrics {
    pub fn new(labels: &[String]) -> Self {
        Metrics {
            serving_start: OnceLock::new(),
            instances: labels.iter().map(|_| Mutex::new(Default::default())).collect(),
            labels: labels.to_vec(),
            shed: AtomicUsize::new(0),
        }
    }

    /// Start the serving clock (idempotent). The driver calls this when
    /// the first frame is admitted; FPS and `wall_seconds` are computed
    /// over serving time only.
    pub fn start_serving(&self) {
        self.serving_start.get_or_init(Instant::now);
    }

    pub fn record_frame(&self, instance: usize, latency_s: f64) {
        // Out-of-range instance indexes (impossible via the driver, which
        // sizes the vec from the spec) drop the sample, never the worker.
        if let Some(slot) = self.instances.get(instance) {
            let mut c = relock(slot);
            c.frames += 1;
            c.latency.add(latency_s);
        }
    }

    pub fn record_fidelity(&self, instance: usize, psnr: f64, ssim_pct: f64) {
        if let Some(slot) = self.instances.get(instance) {
            let mut c = relock(slot);
            if psnr.is_finite() {
                c.psnr.add(psnr);
            }
            c.ssim_pct.add(ssim_pct);
        }
    }

    /// A droppable fanout copy shed by *overload* (full queue) inside the
    /// pipeline — charged to the instance whose queue was full.
    pub fn record_drop(&self, instance: usize) {
        if let Some(slot) = self.instances.get(instance) {
            relock(slot).dropped += 1;
        }
    }

    /// A frame refused by *admission control* before routing — counted
    /// globally, never against an instance (it reached none).
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Total admission-shed frames (see [`Self::record_shed`]).
    pub fn shed_total(&self) -> usize {
        self.shed.load(Ordering::Relaxed)
    }

    /// A fidelity sample that could not be scored (mismatched shapes,
    /// missing ground truth, degenerate images).
    pub fn record_fidelity_skipped(&self, instance: usize) {
        if let Some(slot) = self.instances.get(instance) {
            relock(slot).fidelity_skipped += 1;
        }
    }

    /// Per-instance completed-frame counts — the cheap live read the
    /// serve loop polls at checkpoints (no summary buffers are cloned).
    pub fn frames_completed(&self) -> Vec<usize> {
        self.instances.iter().map(|c| relock(c).frames).collect()
    }

    /// Sum of completed frames over the instances selected by `mask` —
    /// the allocation-free form of [`Self::frames_completed`] for the
    /// serve checkpoint loop (which only ever wants the primary-path
    /// total). Extra mask entries beyond the instance count are ignored.
    pub fn frames_completed_masked(&self, mask: &[bool]) -> usize {
        self.instances
            .iter()
            .zip(mask)
            .filter(|(_, &m)| m)
            .map(|(c, _)| relock(c).frames)
            .sum()
    }

    /// Serving seconds since first frame admission (`0.0` before any
    /// frame was admitted).
    pub fn elapsed(&self) -> f64 {
        self.serving_start
            .get()
            .map(|s| s.elapsed().as_secs_f64())
            .unwrap_or(0.0)
    }

    pub fn snapshot(&self) -> Vec<InstanceSnapshot> {
        let elapsed = self.elapsed();
        self.instances
            .iter()
            .zip(self.labels.iter())
            .map(|(c, label)| {
                let c = relock(c);
                InstanceSnapshot {
                    label: label.clone(),
                    frames: c.frames,
                    fps: if elapsed > 0.0 {
                        c.frames as f64 / elapsed
                    } else {
                        0.0
                    },
                    latency_ms_p50: c.latency.p50() * 1e3,
                    latency_ms_p99: c.latency.p99() * 1e3,
                    latency_ms_mean: c.latency.mean() * 1e3,
                    psnr_mean: c.psnr.mean(),
                    ssim_pct_mean: c.ssim_pct.mean(),
                    dropped: c.dropped,
                    fidelity_skipped: c.fidelity_skipped,
                }
            })
            .collect()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new(&["gan".to_string(), "yolo".to_string()]);
        m.start_serving();
        m.record_frame(0, 0.010);
        m.record_frame(0, 0.020);
        m.record_frame(1, 0.005);
        m.record_fidelity(0, 25.0, 80.0);
        m.record_drop(1);
        m.record_fidelity_skipped(0);
        let snap = m.snapshot();
        assert_eq!(snap[0].frames, 2);
        assert!(snap[0].latency_ms_mean > 9.0 && snap[0].latency_ms_mean < 21.0);
        assert_eq!(snap[0].psnr_mean, 25.0);
        assert_eq!(snap[1].dropped, 1);
        assert_eq!(snap[0].fidelity_skipped, 1);
        assert_eq!(snap[1].fidelity_skipped, 0);
        assert!(snap[0].fps > 0.0);
    }

    #[test]
    fn infinite_psnr_ignored() {
        let m = Metrics::new(&["g".to_string()]);
        m.record_fidelity(0, f64::INFINITY, 100.0);
        m.record_fidelity(0, 30.0, 90.0);
        assert_eq!(m.snapshot()[0].psnr_mean, 30.0);
    }

    #[test]
    fn serving_clock_starts_at_first_admission_not_construction() {
        let m = Metrics::new(&["g".to_string()]);
        // "backend open" time before any frame is admitted
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert_eq!(m.elapsed(), 0.0, "clock must not run before admission");
        m.start_serving();
        m.start_serving(); // idempotent
        for _ in 0..10 {
            m.record_frame(0, 0.001);
        }
        let snap = m.snapshot();
        // FPS over serving time only: 10 frames in far less than the 50 ms
        // of pre-serving setup
        assert!(m.elapsed() < 0.045, "elapsed {} includes setup", m.elapsed());
        assert!(snap[0].fps > 10.0 / 0.045, "fps {} deflated by setup", snap[0].fps);
    }

    #[test]
    fn shed_overload_and_disconnect_counters_are_distinct() {
        // Three loss-like events, three distinct fates: admission shed is
        // global, overload drop is per-instance, and a disconnected worker
        // increments NEITHER (its error surfaces at join instead).
        let m = Metrics::new(&["gan".to_string(), "yolo".to_string()]);
        m.record_shed(); // admission control refused a frame pre-routing
        m.record_shed();
        m.record_drop(1); // yolo's queue was full: overload shed
        // a disconnect has no recording call at all — nothing to assert in
        // but the absence: totals must not move beyond the two above
        assert_eq!(m.shed_total(), 2);
        let snap = m.snapshot();
        assert_eq!(snap[0].dropped, 0);
        assert_eq!(snap[1].dropped, 1);
        let dropped_total: usize = snap.iter().map(|s| s.dropped).sum();
        assert_eq!(dropped_total, 1, "shed must not leak into dropped");
    }

    #[test]
    fn snapshot_before_serving_is_finite_zero_fps() {
        let m = Metrics::new(&["g".to_string()]);
        m.record_frame(0, 0.001);
        let snap = m.snapshot();
        assert_eq!(snap[0].fps, 0.0);
        assert!(snap[0].fps.is_finite());
    }
}
