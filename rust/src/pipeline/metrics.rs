//! Pipeline metrics aggregation (thread-safe).

use crate::util::stats::Summary;
use std::sync::Mutex;
use std::time::Instant;

/// Per-instance counters.
#[derive(Debug, Default)]
struct InstanceCounters {
    frames: usize,
    latency: Summary,
    /// Online reconstruction fidelity vs ground truth (GAN instances).
    psnr: Summary,
    ssim_pct: Summary,
    dropped: usize,
}

/// Shared metrics hub.
#[derive(Debug)]
pub struct Metrics {
    start: Instant,
    instances: Vec<Mutex<InstanceCounters>>,
    labels: Vec<String>,
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct InstanceSnapshot {
    pub label: String,
    pub frames: usize,
    pub fps: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p99: f64,
    pub latency_ms_mean: f64,
    pub psnr_mean: f64,
    pub ssim_pct_mean: f64,
    pub dropped: usize,
}

impl Metrics {
    pub fn new(labels: &[String]) -> Self {
        Metrics {
            start: Instant::now(),
            instances: labels.iter().map(|_| Mutex::new(Default::default())).collect(),
            labels: labels.to_vec(),
        }
    }

    pub fn record_frame(&self, instance: usize, latency_s: f64) {
        let mut c = self.instances[instance].lock().unwrap();
        c.frames += 1;
        c.latency.add(latency_s);
    }

    pub fn record_fidelity(&self, instance: usize, psnr: f64, ssim_pct: f64) {
        let mut c = self.instances[instance].lock().unwrap();
        if psnr.is_finite() {
            c.psnr.add(psnr);
        }
        c.ssim_pct.add(ssim_pct);
    }

    pub fn record_drop(&self, instance: usize) {
        self.instances[instance].lock().unwrap().dropped += 1;
    }

    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn snapshot(&self) -> Vec<InstanceSnapshot> {
        let elapsed = self.elapsed().max(f64::MIN_POSITIVE);
        self.instances
            .iter()
            .zip(self.labels.iter())
            .map(|(c, label)| {
                let c = c.lock().unwrap();
                InstanceSnapshot {
                    label: label.clone(),
                    frames: c.frames,
                    fps: c.frames as f64 / elapsed,
                    latency_ms_p50: c.latency.p50() * 1e3,
                    latency_ms_p99: c.latency.p99() * 1e3,
                    latency_ms_mean: c.latency.mean() * 1e3,
                    psnr_mean: c.psnr.mean(),
                    ssim_pct_mean: c.ssim_pct.mean(),
                    dropped: c.dropped,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new(&["gan".to_string(), "yolo".to_string()]);
        m.record_frame(0, 0.010);
        m.record_frame(0, 0.020);
        m.record_frame(1, 0.005);
        m.record_fidelity(0, 25.0, 80.0);
        m.record_drop(1);
        let snap = m.snapshot();
        assert_eq!(snap[0].frames, 2);
        assert!(snap[0].latency_ms_mean > 9.0 && snap[0].latency_ms_mean < 21.0);
        assert_eq!(snap[0].psnr_mean, 25.0);
        assert_eq!(snap[1].dropped, 1);
        assert!(snap[0].fps > 0.0);
    }

    #[test]
    fn infinite_psnr_ignored() {
        let m = Metrics::new(&["g".to_string()]);
        m.record_fidelity(0, f64::INFINITY, 100.0);
        m.record_fidelity(0, 30.0, 90.0);
        assert_eq!(m.snapshot()[0].psnr_mean, 30.0);
    }
}
