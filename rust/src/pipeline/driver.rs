//! End-to-end pipeline driver.
//!
//! Wires sources → router → bounded per-instance queues (backpressure) →
//! per-instance worker threads executing through a pluggable
//! [`InferenceBackend`] → metrics. With the [`super::backend::PjrtBackend`]
//! this is the real serving path: every frame is reconstructed/diagnosed by
//! the AOT-compiled JAX/Pallas models, Python nowhere in sight. With the
//! [`super::backend::SimBackend`] the identical coordinator runs against
//! the calibrated latency model — no artifacts required.
//!
//! ## Frame data path (zero-copy)
//!
//! Per-frame memory traffic is what eats the paper's 150 FPS margin, so
//! the hot path never copies a pixel plane:
//!
//! * **source** — [`super::source::PhantomSource`] fills buffers drawn
//!   from a shared [`super::plane::PlanePool`] and seals them into
//!   `Arc`-shared [`super::plane::FramePlane`]s; once the workers release
//!   a frame, its buffers park back on the pool and are reused, so the
//!   sealed planes are recycled instead of re-allocated per frame;
//! * **route** — fanout materialises each target's copy with
//!   `Frame::clone`: refcount bumps, zero pixel copies. Ground truth only
//!   rides the copies headed to fidelity-scoring instances; everyone else
//!   gets `gt_mri: None`;
//! * **dispatch** — workers hand each batch from
//!   [`super::batcher::next_batch`] to
//!   [`super::backend::ModelRunner::execute_batch`] as **one** dispatch,
//!   so `max_batch > 1` genuinely reduces dispatch count (the sim prices
//!   the amortized launch/weight traffic; PJRT stacks the frames into a
//!   single transfer + execute);
//! * **write-out** — the only place a plane is ever materialised is a
//!   backend writing a fresh output tensor (the sim even skips that by
//!   echoing the input plane with a refcount bump).
//!
//! The public entry point is [`crate::session::Session`]; [`run_pipeline`]
//! survives as a thin compatibility wrapper that lowers a
//! [`PipelineConfig`] through the session builder.
//!
//! Note on engines: the testbed has no physical DLA, so the PJRT "engines"
//! all execute on the CPU client; the *scheduling structure* (which
//! instance runs where, queue topology, backpressure) is identical to the
//! paper's deployment and the timing claims are made by [`crate::sim`].

use super::backend::InferenceBackend;
use super::batcher::next_batch;
use super::frame::Frame;
use super::metrics::{InstanceSnapshot, Metrics};
use super::plane::PlanePool;
use super::router::Router;
use super::source::PhantomSource;
use super::spec::PipelineSpec;
use crate::config::json::{arr, num, obj, s, Json};
use crate::config::PipelineConfig;
use crate::error::{Error, Result};
use crate::imaging::metrics::fidelity;
use crate::imaging::Image;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;

/// Online fidelity (PSNR/SSIM) is sampled rather than computed per frame:
/// SSIM costs ~1 ms/frame on this core (~8% of GAN inference) and the mean
/// converges with a fraction of the frames (perf pass, EXPERIMENTS.md
/// §Perf iteration 2).
const SCORE_EVERY: u64 = 4;

/// Final pipeline report.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub instances: Vec<InstanceSnapshot>,
    pub wall_seconds: f64,
    pub total_frames: usize,
    /// Total frame copies shed on overload/disconnect across all instances
    /// (per-instance counts are on each [`InstanceSnapshot`]).
    pub dropped: usize,
}

impl PipelineReport {
    pub fn total_fps(&self) -> f64 {
        self.instances.iter().map(|i| i.fps).sum()
    }

    /// JSON form for experiment provenance records and `report` output.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("wall_seconds", num(self.wall_seconds)),
            ("total_frames", num(self.total_frames as f64)),
            ("dropped", num(self.dropped as f64)),
            ("total_fps", num(self.total_fps())),
            (
                "instances",
                arr(self
                    .instances
                    .iter()
                    .map(|i| {
                        obj(vec![
                            ("label", s(&i.label)),
                            ("frames", num(i.frames as f64)),
                            ("fps", num(i.fps)),
                            ("latency_ms_p50", num(i.latency_ms_p50)),
                            ("latency_ms_p99", num(i.latency_ms_p99)),
                            ("latency_ms_mean", num(i.latency_ms_mean)),
                            ("psnr_mean", num(i.psnr_mean)),
                            ("ssim_pct_mean", num(i.ssim_pct_mean)),
                            ("dropped", num(i.dropped as f64)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

/// Run a [`PipelineConfig`] to completion and report (compatibility
/// wrapper: lowers the config through [`crate::session::PipelineBuilder`]
/// onto the default PJRT backend).
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineReport> {
    crate::session::PipelineBuilder::from_config(cfg).build()?.run()
}

/// Execute `spec` on `backend`: the coordinator core behind
/// [`crate::session::Session::run`].
pub(crate) fn execute(
    spec: &PipelineSpec,
    backend: &Arc<dyn InferenceBackend>,
) -> Result<PipelineReport> {
    spec.validate()?;

    let labels: Vec<String> = spec.instances.iter().map(|i| i.label.clone()).collect();
    let metrics = Arc::new(Metrics::new(&labels));
    let dropped_total = Arc::new(AtomicUsize::new(0));

    // Per-instance bounded queues: the backpressure boundary.
    let mut senders: Vec<SyncSender<Frame>> = Vec::new();
    let mut receivers: Vec<Receiver<Frame>> = Vec::new();
    for _ in &spec.instances {
        let (tx, rx) = sync_channel::<Frame>(spec.queue_depth);
        senders.push(tx);
        receivers.push(rx);
    }

    // Workers: one thread per instance (the two-engine analogue). All
    // non-`Send` executor state (e.g. PJRT handles) is created inside the
    // thread by `backend.open` — the same isolation a per-engine TensorRT
    // context gives on the Jetson. Each batch the batcher yields goes to
    // the backend as ONE dispatch.
    let mut handles = Vec::new();
    for (idx, (inst, rx)) in spec.instances.iter().zip(receivers.into_iter()).enumerate() {
        let metrics = Arc::clone(&metrics);
        let backend = Arc::clone(backend);
        let inst = inst.clone();
        let handle = std::thread::Builder::new()
            .name(format!("worker-{}", inst.label))
            .spawn(move || -> Result<()> {
                let mut runner = backend.open(&inst)?;
                while let Some(batch) = next_batch(&rx, inst.batch) {
                    let outs = runner.execute_batch(&batch)?;
                    if outs.len() != batch.len() {
                        // a silent mismatch would leak frames out of the
                        // produced = processed + dropped conservation
                        return Err(Error::Pipeline(format!(
                            "instance `{}`: backend returned {} outputs for a batch of {}",
                            inst.label,
                            outs.len(),
                            batch.len()
                        )));
                    }
                    for (frame, out) in batch.iter().zip(outs.iter()) {
                        let latency = frame.admitted.elapsed().as_secs_f64();
                        metrics.record_frame(idx, latency);
                        if inst.score_fidelity && frame.id % SCORE_EVERY == 0 {
                            if let Some(gt) = &frame.gt_mri {
                                record_fidelity(&metrics, idx, frame, gt, out);
                            }
                        }
                    }
                }
                Ok(())
            })
            .map_err(|e| Error::Pipeline(format!("spawn worker: {e}")))?;
        handles.push(handle);
    }

    // Source + router on the main thread. All sources draw from (and
    // return to) one plane pool, so frame synthesis recycles the buffers
    // the workers release.
    let mut router = Router::new(spec.route, spec.instances.len());
    let scoring: Vec<bool> = spec.instances.iter().map(|i| i.score_fidelity).collect();
    let pool = PlanePool::default();
    let per_stream = spec.frames / spec.streams.max(1);
    let mut sources: Vec<PhantomSource> = (0..spec.streams)
        .map(|st| {
            PhantomSource::new(
                crate::imaging::phantom::PhantomConfig::default(),
                spec.seed,
                st,
                per_stream,
            )
            .with_pool(pool.clone())
        })
        .collect();
    let mut total_frames = 0usize;
    'outer: loop {
        let mut all_done = true;
        for src in sources.iter_mut() {
            if let Some(frame) = src.next() {
                all_done = false;
                total_frames += 1;
                let targets = router.route(&frame);
                let copies = targets.len();
                let mut frame = Some(frame);
                for (copy, target) in targets.enumerate() {
                    // Last copy moves the frame; earlier copies clone it —
                    // an Arc refcount bump per plane, never a pixel copy.
                    let mut f = if copy + 1 == copies {
                        frame.take().expect("one frame per routed copy")
                    } else {
                        frame.as_ref().expect("one frame per routed copy").clone()
                    };
                    // Ground truth is only consumed by fidelity scoring:
                    // don't carry the plane through other queues.
                    if !scoring[target] {
                        f.gt_mri = None;
                    }
                    if copy == 0 {
                        // The primary copy is lossless: block under
                        // backpressure (the paper's pipeline drops nothing
                        // on its main reconstruction path).
                        if senders[target].send(f).is_err() {
                            // Worker gone — its error surfaces at join.
                            break 'outer;
                        }
                    } else {
                        // Fanout copies beyond the primary shed load
                        // instead of stalling the whole pipeline.
                        match senders[target].try_send(f) {
                            Ok(()) => {}
                            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                                dropped_total.fetch_add(1, Ordering::Relaxed);
                                metrics.record_drop(target);
                            }
                        }
                    }
                }
            }
        }
        if all_done {
            break;
        }
    }
    drop(senders);
    for h in handles {
        h.join()
            .map_err(|_| Error::Pipeline("worker panicked".into()))??;
    }

    Ok(PipelineReport {
        instances: metrics.snapshot(),
        wall_seconds: metrics.elapsed(),
        total_frames,
        dropped: dropped_total.load(Ordering::Relaxed),
    })
}

fn record_fidelity(metrics: &Metrics, idx: usize, frame: &Frame, gt: &[f32], out: &[f32]) {
    if gt.len() != frame.numel() || out.len() != frame.numel() {
        return;
    }
    // [-1, 1] model range -> [0, 1] image range
    let to01 = |x: f32| (x + 1.0) / 2.0;
    let a = Image::from_mapped(frame.width, frame.height, gt, to01);
    let b = Image::from_mapped(frame.width, frame.height, out, to01);
    if let (Ok(a), Ok(b)) = (a, b) {
        if let Ok(f) = fidelity(&a, &b) {
            metrics.record_fidelity(idx, f.psnr, f.ssim_pct);
        }
    }
}
