//! End-to-end pipeline driver.
//!
//! Wires sources → router → bounded per-instance queues (backpressure) →
//! per-instance worker threads executing through a pluggable
//! [`InferenceBackend`] → metrics. With the [`super::backend::PjrtBackend`]
//! this is the real serving path: every frame is reconstructed/diagnosed by
//! the AOT-compiled JAX/Pallas models, Python nowhere in sight. With the
//! [`super::backend::SimBackend`] the identical coordinator runs against
//! the calibrated latency model — no artifacts required.
//!
//! ## Frame data path (zero-copy)
//!
//! Per-frame memory traffic is what eats the paper's 150 FPS margin, so
//! the hot path never copies a pixel plane:
//!
//! * **source** — a [`super::source::FrameSource`] (phantom, or the
//!   k-space recon front-end selected by the spec's
//!   [`super::spec::SourceSpec`]) fills buffers drawn
//!   from a shared [`super::plane::PlanePool`] and seals them into
//!   `Arc`-shared [`super::plane::FramePlane`]s; once the workers release
//!   a frame, its buffers park back on the pool and are reused, so the
//!   sealed planes are recycled instead of re-allocated per frame;
//! * **route** — fanout materialises each target's copy with
//!   `Frame::clone`: refcount bumps, zero pixel copies. Ground truth only
//!   rides the copies headed to fidelity-scoring instances; everyone else
//!   gets `gt_mri: None`;
//! * **dispatch** — workers hand each batch from
//!   [`super::batcher::collect_batch`] to
//!   [`super::backend::ModelRunner::execute_batch`] as **one** dispatch,
//!   so `max_batch > 1` genuinely reduces dispatch count (the sim prices
//!   the amortized launch/weight traffic; PJRT stacks the frames into a
//!   single transfer + execute);
//! * **write-out** — the only place a plane is ever materialised is a
//!   backend writing a fresh output tensor (the sim even skips that by
//!   echoing the input plane with a refcount bump).
//!
//! The public entry point is [`crate::session::Session`]; [`run_pipeline`]
//! survives as a thin compatibility wrapper that lowers a
//! [`PipelineConfig`] through the session builder.
//!
//! ## Batch run vs serve loop
//!
//! The coordinator proper is the [`StreamCore`]: workers + queues +
//! router + arbiter for ONE spec, with frame **admission** decoupled from
//! frame **generation**. [`execute`] is the fixed-frame batch path (drive
//! `spec.frames` phantom frames through a core and exit); the
//! long-running [`crate::serve`] front-end drives the same core from
//! client arrival processes with QoS admission control, and re-plans
//! online by draining one core ([`StreamCore::finish`] — every admitted
//! frame completes) and standing the next one up on the new spec.
//!
//! ## Engines are exclusive in serving, not just in sim
//!
//! Every worker routes each batched dispatch through the run's shared
//! [`super::engines::EngineArbiter`], which models the SoC's physical
//! engine units (GPU, DLA0, DLA1) as exclusive FIFO resources: instances
//! pinned to the same unit serialize, split placements run concurrently
//! but pay the PCCS memory-contention slowdown, and occupant switches pay
//! the reformat cost — the same hardware model [`crate::sim`] uses, now
//! enforced on the serving path. Model-priced backends (the sim) hold the
//! engine for the priced duration; the PJRT backend (whose "engines" all
//! execute on the CPU client — the testbed has no physical DLA) holds the
//! engine token around its real dispatch, so placement serializes
//! identically. The arbiter records a serving
//! [`crate::sim::timeline::Timeline`], from which [`PipelineReport`]
//! derives per-engine utilization and idle-gap statistics.

// Per-frame routing/worker hot path: panics here wedge the stream.
#![deny(clippy::unwrap_used)]

use super::backend::InferenceBackend;
use super::batcher::{collect_batch_into, BatchEnd};
use super::engines::{EngineArbiter, EngineSnapshot};
use super::frame::Frame;
use super::metrics::{FidelitySink, InstanceSnapshot, Metrics};
use super::plane::PlanePool;
use super::router::Router;
use super::source::{FrameSource, ReconReport, ReconStats};
use super::spec::{PipelineSpec, SourceSpec};
use crate::config::json::{arr, num, obj, s, Json};
use crate::config::PipelineConfig;
use crate::error::{Error, Result};
use crate::imaging::metrics::fidelity;
use crate::imaging::Image;
use crate::obs::stages::{StageAccum, StageBreakdown};
use crate::sim::timeline::Timeline;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Online fidelity (PSNR/SSIM) is sampled rather than computed per frame:
/// SSIM costs ~1 ms/frame on this core (~8% of GAN inference) and the mean
/// converges with a fraction of the frames (perf pass, EXPERIMENTS.md
/// §Perf iteration 2).
const SCORE_EVERY: u64 = 4;

/// Whether this frame's reconstruction is fidelity-sampled (see
/// [`SCORE_EVERY`]).
pub(crate) fn should_score(frame_id: u64) -> bool {
    frame_id % SCORE_EVERY == 0
}

/// Final pipeline report.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub instances: Vec<InstanceSnapshot>,
    /// Per-engine-unit serving statistics (utilization, idle gaps) from
    /// the arbiter's timeline — the Nsight-style Figs 10/13 numbers.
    pub engines: Vec<EngineSnapshot>,
    /// The serving timeline itself (spans per engine unit / instance /
    /// frame); not serialized into [`Self::to_json`].
    pub timeline: Timeline,
    /// Serving wall time: first frame admission to teardown.
    pub wall_seconds: f64,
    pub total_frames: usize,
    /// Total frame copies shed on *overload* (full queue) across all
    /// instances (per-instance counts are on each [`InstanceSnapshot`]).
    pub dropped: usize,
    /// Frames refused by QoS *admission control* before routing (the
    /// serve front-end's counter — `0` for fixed-frame batch runs).
    /// Distinct from `dropped`; see [`super::metrics`] module docs.
    pub shed: usize,
    /// Frame-lifecycle stage latency breakdown, present only when the run
    /// was observed (an [`crate::obs::ObsHub`] stage accumulator was
    /// attached — `--trace-out`/`--metrics-out` or
    /// [`crate::session::Session::run_observed`]).
    pub stages: Option<StageBreakdown>,
    /// K-space recon front-end summary (recon time, PSNR/SSIM vs the
    /// fully-sampled slice), present only when the spec's source is
    /// `kspace`.
    pub recon: Option<ReconReport>,
}

impl PipelineReport {
    pub fn total_fps(&self) -> f64 {
        self.instances.iter().map(|i| i.fps).sum()
    }

    /// JSON form for experiment provenance records and `report` output.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("wall_seconds", num(self.wall_seconds)),
            ("total_frames", num(self.total_frames as f64)),
            ("dropped", num(self.dropped as f64)),
            ("shed", num(self.shed as f64)),
            ("total_fps", num(self.total_fps())),
            (
                "instances",
                arr(self
                    .instances
                    .iter()
                    .map(|i| {
                        obj(vec![
                            ("label", s(&i.label)),
                            ("frames", num(i.frames as f64)),
                            ("fps", num(i.fps)),
                            ("latency_ms_p50", num(i.latency_ms_p50)),
                            ("latency_ms_p99", num(i.latency_ms_p99)),
                            ("latency_ms_mean", num(i.latency_ms_mean)),
                            ("psnr_mean", num(i.psnr_mean)),
                            ("ssim_pct_mean", num(i.ssim_pct_mean)),
                            ("dropped", num(i.dropped as f64)),
                            ("fidelity_skipped", num(i.fidelity_skipped as f64)),
                        ])
                    })
                    .collect()),
            ),
            (
                "engines",
                arr(self
                    .engines
                    .iter()
                    .map(|e| {
                        obj(vec![
                            ("engine", s(&e.label)),
                            ("utilization", num(e.utilization)),
                            ("busy_seconds", num(e.busy_seconds)),
                            ("dispatches", num(e.dispatches as f64)),
                            ("mean_block_ms", num(e.mean_block_ms)),
                            ("idle_gap_ms_mean", num(e.idle_gap_ms_mean)),
                            ("idle_gap_ms_p99", num(e.idle_gap_ms_p99)),
                            ("idle_gap_count", num(e.idle_gap_count as f64)),
                        ])
                    })
                    .collect()),
            ),
        ];
        if let Some(st) = &self.stages {
            pairs.push(("stages", st.to_json()));
        }
        if let Some(r) = &self.recon {
            pairs.push(("recon", r.to_json()));
        }
        obj(pairs)
    }
}

/// Run a [`PipelineConfig`] to completion and report (compatibility
/// wrapper: lowers the config through [`crate::session::PipelineBuilder`]
/// onto the default PJRT backend).
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineReport> {
    crate::session::PipelineBuilder::from_config(cfg).build()?.run()
}

/// Observer of per-frame completions on the serving hot path. The serve
/// front-end's rolling telemetry ([`crate::serve::telemetry::Telemetry`])
/// implements this; batch runs pass `None` and pay nothing.
pub trait CompletionSink: Send + Sync {
    /// One frame finished executing on `instance` (its index in the
    /// spec), `latency_s` after admission.
    fn completed(&self, instance: usize, stream: usize, frame_id: u64, latency_s: f64);
}

/// The reusable streaming core: workers, queues, router, metrics and the
/// engine arbiter of one running [`PipelineSpec`], with frame admission
/// decoupled from frame *generation*.
///
/// [`execute`] (the fixed-frame batch path) drives it from phantom
/// sources until a requested count is reached; the long-running
/// [`crate::serve`] front-end drives it from client arrival processes,
/// admits through QoS control, and performs drain-and-switch re-planning
/// by [`StreamCore::finish`]ing one core and starting the next — the two
/// paths share every line of routing/backpressure/dispatch semantics.
pub(crate) struct StreamCore {
    metrics: Arc<Metrics>,
    arbiter: Arc<EngineArbiter>,
    dropped_total: Arc<AtomicUsize>,
    senders: Vec<SyncSender<Frame>>,
    handles: Vec<JoinHandle<Result<()>>>,
    router: Router,
    scoring: Vec<bool>,
    /// A `true` entry is a live worker queue; a disconnected (crashed)
    /// fanout target is taken out of the rotation instead of being
    /// counted as load shedding — its error surfaces at join.
    alive: Vec<bool>,
    submitted: usize,
}

impl StreamCore {
    /// Validate `spec`, spawn one worker per instance, and stand the
    /// queues up. Frames flow once the caller starts [`Self::submit`]ing.
    pub fn new(
        spec: &PipelineSpec,
        backend: &Arc<dyn InferenceBackend>,
        sink: Option<Arc<dyn CompletionSink>>,
        stages: Option<Arc<StageAccum>>,
    ) -> Result<StreamCore> {
        spec.validate()?;

        let labels: Vec<String> = spec.instances.iter().map(|i| i.label.clone()).collect();
        let metrics = Arc::new(Metrics::new(&labels));
        let arbiter = Arc::new(EngineArbiter::new(&spec.instances));
        let dropped_total = Arc::new(AtomicUsize::new(0));

        // Per-instance bounded queues: the backpressure boundary.
        let mut senders: Vec<SyncSender<Frame>> = Vec::new();
        let mut receivers = Vec::new();
        for _ in &spec.instances {
            let (tx, rx) = sync_channel::<Frame>(spec.queue_depth);
            senders.push(tx);
            receivers.push(rx);
        }

        // Workers: one thread per instance. All non-`Send` executor state
        // (e.g. PJRT handles) is created inside the thread by
        // `backend.open` — the same isolation a per-engine TensorRT
        // context gives on the Jetson. Each batch the batcher yields goes
        // to the backend as ONE dispatch, executed under the instance's
        // exclusive engine lease from the shared arbiter (pinning two
        // instances to one unit serializes them; split placements contend
        // through shared DRAM).
        let mut handles = Vec::new();
        for (idx, (inst, rx)) in spec.instances.iter().zip(receivers.into_iter()).enumerate() {
            let metrics = Arc::clone(&metrics);
            let backend = Arc::clone(backend);
            let arbiter = Arc::clone(&arbiter);
            let sink = sink.clone();
            let stages = stages.clone();
            let inst = inst.clone();
            let handle = std::thread::Builder::new()
                .name(format!("worker-{}", inst.label))
                .spawn(move || -> Result<()> {
                    let mut runner = backend.open(&inst)?;
                    let profile = backend.dispatch_profile(&inst)?;
                    let modeled = profile.is_some();
                    // One batch buffer for the worker's whole life: the
                    // batcher clears and refills it, so the steady-state
                    // loop allocates nothing per batch.
                    let mut batch: Vec<Frame> = Vec::with_capacity(inst.batch.max_batch.max(1));
                    while let Some(end) = collect_batch_into(&rx, inst.batch, &mut batch) {
                        let (outs, receipt) = arbiter.dispatch_stamped(
                            idx,
                            batch[0].id,
                            batch.len(),
                            profile.as_ref(),
                            || {
                                if modeled {
                                    // the arbiter holds the engine for the
                                    // priced duration; don't model time
                                    // twice
                                    runner.execute_batch_untimed(&batch)
                                } else {
                                    runner.execute_batch(&batch)
                                }
                            },
                        )?;
                        if outs.len() != batch.len() {
                            // a silent mismatch would leak frames out of
                            // the produced = processed + dropped
                            // conservation
                            return Err(Error::Pipeline(format!(
                                "instance `{}`: backend returned {} outputs for a batch of {}",
                                inst.label,
                                outs.len(),
                                batch.len()
                            )));
                        }
                        // One clock read for the whole batch's dispatch-end
                        // stamp, taken only when a stage accumulator is
                        // attached — the untraced path pays nothing here.
                        let sealed_at = stages.as_ref().map(|_| std::time::Instant::now());
                        for (frame, out) in batch.iter_mut().zip(outs.iter()) {
                            let latency = frame.admitted.elapsed().as_secs_f64();
                            metrics.record_frame(idx, latency);
                            if let Some(sink) = &sink {
                                sink.completed(idx, frame.stream, frame.id, latency);
                            }
                            if inst.score_fidelity && should_score(frame.id) {
                                match &frame.gt_mri {
                                    Some(gt) => {
                                        record_fidelity(metrics.as_ref(), idx, frame, gt, out)
                                    }
                                    None => metrics.record_fidelity_skipped(idx),
                                }
                            }
                            if let (Some(acc), Some(done)) = (&stages, sealed_at) {
                                frame.stamps.seal_dispatch(
                                    done.duration_since(frame.admitted).as_secs_f64(),
                                    &receipt,
                                );
                                frame
                                    .stamps
                                    .mark_writeout(frame.admitted.elapsed().as_secs_f64());
                                acc.record(&frame.stamps);
                            }
                        }
                        // Release the frames now (their planes park back
                        // on the pool) rather than when the next batch
                        // arrives.
                        batch.clear();
                        if end == BatchEnd::Disconnected {
                            // A disconnect is end-of-stream (the channel
                            // was drained before it was reported), NOT a
                            // quiet queue: exit now instead of paying one
                            // more blocking recv to learn the same thing.
                            break;
                        }
                    }
                    Ok(())
                })
                .map_err(|e| Error::Pipeline(format!("spawn worker: {e}")))?;
            handles.push(handle);
        }

        Ok(StreamCore {
            metrics,
            arbiter,
            dropped_total,
            senders,
            handles,
            router: Router::new(spec.route, spec.instances.len()),
            scoring: spec.instances.iter().map(|i| i.score_fidelity).collect(),
            alive: vec![true; spec.instances.len()],
            submitted: 0,
        })
    }

    /// Route one admitted frame into the worker queues. Returns `false`
    /// when the *primary* worker is gone (stop producing; its error
    /// surfaces at [`Self::finish`]).
    pub fn submit(&mut self, frame: Frame) -> bool {
        self.submitted += 1;
        self.metrics.start_serving();
        let targets = self.router.route(&frame);
        let copies = targets.len();
        let mut frame = Some(frame);
        for (copy, target) in targets.enumerate() {
            // The router is sized to the instance count, so every target
            // is in range; checked access keeps the producer alive even
            // if that ever breaks, instead of panicking mid-stream.
            let (Some(sender), Some(&scored), Some(alive)) = (
                self.senders.get(target),
                self.scoring.get(target),
                self.alive.get_mut(target),
            ) else {
                continue;
            };
            let mut f = match frame.take() {
                // Last copy moves the frame...
                Some(cur) if copy + 1 == copies => cur,
                Some(cur) => {
                    // ...earlier copies clone it: an Arc refcount bump
                    // per plane, never a pixel copy.
                    // lint:allow(hot-path-alloc) — Frame::clone only bumps Arc refcounts
                    let f = cur.clone();
                    frame = Some(cur);
                    f
                }
                // One frame per routed copy by construction; end routing
                // rather than panic if that invariant ever breaks.
                None => break,
            };
            // Ground truth is only consumed by fidelity scoring: don't
            // carry the plane through other queues.
            if !scored {
                f.gt_mri = None;
            }
            if copy == 0 {
                // The primary copy is lossless: block under backpressure
                // (the paper's pipeline drops nothing on its main
                // reconstruction path).
                if sender.send(f).is_err() {
                    return false;
                }
            } else if *alive {
                // Fanout copies beyond the primary shed load instead of
                // stalling the whole pipeline. Only a full queue is
                // genuine shedding — a disconnect is a crashed worker,
                // not overload.
                match sender.try_send(f) {
                    Ok(()) => {}
                    Err(TrySendError::Full(_)) => {
                        self.dropped_total.fetch_add(1, Ordering::Relaxed);
                        self.metrics.record_drop(target);
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        *alive = false;
                    }
                }
            }
        }
        true
    }

    /// Count an admission-control shed against this core's metrics (the
    /// frame never entered a queue — see [`super::metrics`] on why this is
    /// not `dropped`).
    pub fn record_shed(&self) {
        self.metrics.record_shed();
    }

    /// Frames submitted so far.
    pub fn submitted(&self) -> usize {
        self.submitted
    }

    /// Overload drops (full-queue copy discards) so far — the live
    /// counterpart of [`PipelineReport::dropped`], read at serve
    /// checkpoints to attribute drops to telemetry windows.
    pub fn dropped_so_far(&self) -> usize {
        self.dropped_total.load(Ordering::Relaxed)
    }

    /// Live per-instance completed-frame counts (serve checkpoint read).
    pub fn completed_frames(&self) -> Vec<usize> {
        self.metrics.frames_completed()
    }

    /// Unique (primary-path) frames completed so far, given the spec's
    /// precomputed primary mask — the serve checkpoint read, with no
    /// per-checkpoint `Vec`s.
    pub fn primary_completed(&self, primary_mask: &[bool]) -> usize {
        self.metrics.frames_completed_masked(primary_mask)
    }

    /// The core's engine arbiter (live timeline access for windowed
    /// telemetry).
    pub fn arbiter(&self) -> &EngineArbiter {
        &self.arbiter
    }

    /// Drain and tear down: close the queues, let the workers finish
    /// every admitted frame, join them (propagating worker errors), and
    /// report. This is the "drain" half of the serve front-end's
    /// drain-and-switch handoff — nothing admitted is lost.
    pub fn finish(self) -> Result<PipelineReport> {
        let StreamCore {
            metrics,
            arbiter,
            dropped_total,
            senders,
            handles,
            submitted,
            ..
        } = self;
        drop(senders);
        for h in handles {
            h.join()
                .map_err(|_| Error::Pipeline("worker panicked".into()))??;
        }
        Ok(PipelineReport {
            instances: metrics.snapshot(),
            engines: arbiter.engine_snapshots(),
            timeline: arbiter.timeline(),
            wall_seconds: metrics.elapsed(),
            total_frames: submitted,
            dropped: dropped_total.load(Ordering::Relaxed),
            shed: metrics.shed_total(),
            stages: None,
            recon: None,
        })
    }
}

/// Execute `spec` on `backend`: the fixed-frame batch path behind
/// [`crate::session::Session::run`] — stand a [`StreamCore`] up, stream
/// exactly `spec.frames` frames from the spec's source (phantom, or the
/// k-space recon front-end) through it, drain, and report.
pub(crate) fn execute(
    spec: &PipelineSpec,
    backend: &Arc<dyn InferenceBackend>,
) -> Result<PipelineReport> {
    execute_observed(spec, backend, None)
}

/// [`execute`] with an optional frame-lifecycle stage accumulator: every
/// completed frame copy's [`crate::obs::StageStamps`] fold into `stages`,
/// and the report carries the resulting [`StageBreakdown`].
pub(crate) fn execute_observed(
    spec: &PipelineSpec,
    backend: &Arc<dyn InferenceBackend>,
    stages: Option<Arc<StageAccum>>,
) -> Result<PipelineReport> {
    let mut core = StreamCore::new(spec, backend, None, stages.clone())?;

    // Sources on the calling thread. All sources draw from (and return
    // to) one plane pool, so frame synthesis recycles the buffers the
    // workers release. The requested frame count is distributed exactly:
    // the first `frames % streams` streams carry one extra frame, so an
    // indivisible count never silently under-produces. A kspace source
    // additionally shares one recon accumulator across all streams, which
    // the report folds into `recon`.
    let pool = PlanePool::default();
    let recon_stats = match &spec.source {
        SourceSpec::Kspace { .. } => Some(Arc::new(ReconStats::default())),
        SourceSpec::Phantom => None,
    };
    let base = spec.frames / spec.streams;
    let extra = spec.frames % spec.streams;
    let mut sources: Vec<FrameSource> = (0..spec.streams)
        .map(|st| {
            FrameSource::for_spec(
                &spec.source,
                spec.seed,
                st,
                base + usize::from(st < extra),
                pool.clone(),
                recon_stats.clone(),
            )
        })
        .collect::<Result<Vec<_>>>()?;
    'outer: loop {
        let mut all_done = true;
        for src in sources.iter_mut() {
            if let Some(frame) = src.next() {
                all_done = false;
                if !core.submit(frame) {
                    // Primary worker gone — stop producing; its error
                    // surfaces at finish.
                    break 'outer;
                }
            }
        }
        if all_done {
            break;
        }
    }
    let mut rep = core.finish()?;
    rep.stages = stages.map(|acc| acc.breakdown());
    rep.recon = recon_stats.and_then(|st| st.report(&spec.source));
    Ok(rep)
}

/// Score one sampled frame's reconstruction fidelity into any
/// [`FidelitySink`] — the worker loop scores GAN output into [`Metrics`],
/// the k-space source scores recon output into
/// [`super::source::ReconStats`], both through this one path. Unscorable
/// samples (gt/output shape mismatch, unbuildable images) are *counted*
/// via [`FidelitySink::fidelity_skipped`] instead of vanishing silently.
pub(crate) fn record_fidelity(
    sink: &dyn FidelitySink,
    idx: usize,
    frame: &Frame,
    gt: &[f32],
    out: &[f32],
) {
    if gt.len() != frame.numel() || out.len() != frame.numel() {
        sink.fidelity_skipped(idx);
        return;
    }
    // [-1, 1] model range -> [0, 1] image range
    let to01 = |x: f32| (x + 1.0) / 2.0;
    let a = Image::from_mapped(frame.width, frame.height, gt, to01);
    let b = Image::from_mapped(frame.width, frame.height, out, to01);
    if let (Ok(a), Ok(b)) = (a, b) {
        if let Ok(f) = fidelity(&a, &b) {
            sink.fidelity(idx, f.psnr, f.ssim_pct);
            return;
        }
    }
    sink.fidelity_skipped(idx);
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::pipeline::backend::{ModelRunner, Output};
    use crate::pipeline::plane::FramePlane;
    use crate::pipeline::router::RoutePolicy;
    use crate::pipeline::spec::InstanceSpec;
    use std::time::Instant;

    /// Echoes input planes instantly; instances labelled `fail_label`
    /// error on every dispatch (a crashed worker).
    struct EchoOrFail {
        fail_label: &'static str,
    }

    impl InferenceBackend for EchoOrFail {
        fn name(&self) -> &'static str {
            "echo-or-fail"
        }

        fn prepare(&self, _spec: &InstanceSpec) -> Result<()> {
            Ok(())
        }

        fn open(&self, spec: &InstanceSpec) -> Result<Box<dyn ModelRunner>> {
            Ok(Box::new(EchoRunner {
                fail: spec.label == self.fail_label,
            }))
        }
    }

    struct EchoRunner {
        fail: bool,
    }

    impl ModelRunner for EchoRunner {
        fn run(&mut self, frame: &Frame) -> Result<Output> {
            if self.fail {
                return Err(Error::Runtime("backend exploded".into()));
            }
            Ok(Arc::clone(&frame.data))
        }
    }

    fn echo_backend(fail_label: &'static str) -> Arc<dyn InferenceBackend> {
        Arc::new(EchoOrFail { fail_label })
    }

    fn frame_8x8() -> Frame {
        Frame {
            id: 0,
            stream: 0,
            data: FramePlane::from_vec(vec![0.1; 64]),
            width: 8,
            height: 8,
            gt_mri: None,
            admitted: Instant::now(),
            stamps: Default::default(),
        }
    }

    #[test]
    fn score_every_samples_one_in_four() {
        assert_eq!((0..32u64).filter(|&id| should_score(id)).count(), 8);
        assert!(should_score(0));
        assert!(!should_score(1));
        assert!(should_score(SCORE_EVERY));
    }

    #[test]
    fn fidelity_mismatch_counts_skip_instead_of_vanishing() {
        let m = Metrics::new(&["g".to_string()]);
        let frame = frame_8x8();
        let gt: Vec<f32> = (0..64).map(|i| (i as f32 / 64.0) * 2.0 - 1.0).collect();
        record_fidelity(&m, 0, &frame, &gt, &[0.0; 10]); // short output
        record_fidelity(&m, 0, &frame, &gt[..10], &gt); // short ground truth
        let snap = m.snapshot();
        assert_eq!(snap[0].fidelity_skipped, 2);
        assert_eq!(snap[0].psnr_mean, 0.0);
    }

    #[test]
    fn fidelity_matched_shapes_score_normally() {
        let m = Metrics::new(&["g".to_string()]);
        let frame = frame_8x8();
        let gt: Vec<f32> = (0..64).map(|i| (i as f32 / 64.0) * 2.0 - 1.0).collect();
        let out: Vec<f32> = gt.iter().map(|v| (v * 0.8).clamp(-1.0, 1.0)).collect();
        record_fidelity(&m, 0, &frame, &gt, &out);
        let snap = m.snapshot();
        assert_eq!(snap[0].fidelity_skipped, 0);
        assert!(snap[0].psnr_mean > 0.0 && snap[0].psnr_mean.is_finite());
    }

    #[test]
    fn crashed_fanout_worker_surfaces_its_error_at_join() {
        // The non-primary worker dies on its first dispatch: the source
        // must stop routing to it (not count the dead queue as load
        // shedding) and the run must report the worker's own error.
        let spec = PipelineSpec {
            instances: vec![
                InstanceSpec::new("good", "gen_cropping"),
                InstanceSpec::new("bad", "yolo_lite"),
            ],
            route: RoutePolicy::Fanout,
            frames: 12,
            queue_depth: 2,
            ..PipelineSpec::default()
        };
        let err = execute(&spec, &echo_backend("bad")).unwrap_err();
        assert!(
            err.to_string().contains("backend exploded"),
            "worker error must not be masked: {err}"
        );
    }

    #[test]
    fn indivisible_frame_count_is_fully_produced() {
        let spec = PipelineSpec {
            instances: vec![InstanceSpec::new("gan", "gen_cropping")],
            route: RoutePolicy::Fanout,
            frames: 100,
            streams: 3, // 100 = 34 + 33 + 33, not 3 x 33
            ..PipelineSpec::default()
        };
        let rep = execute(&spec, &echo_backend("")).unwrap();
        assert_eq!(rep.total_frames, 100);
        assert_eq!(rep.instances[0].frames, 100);
        assert_eq!(rep.dropped, 0);
    }

    #[test]
    fn observed_run_reports_monotone_stage_breakdown() {
        let spec = PipelineSpec {
            instances: vec![
                InstanceSpec::new("gan", "gen_cropping"),
                InstanceSpec::new("det", "yolo_lite"),
            ],
            route: RoutePolicy::Fanout,
            frames: 24,
            ..PipelineSpec::default()
        };
        let acc = Arc::new(StageAccum::default());
        let rep = execute_observed(&spec, &echo_backend(""), Some(Arc::clone(&acc))).unwrap();
        // fanout x 2 instances: one stamp record per completed frame copy
        assert_eq!(acc.frames(), 48);
        assert_eq!(acc.non_monotone(), 0, "stage stamps must be monotone");
        let st = rep.stages.expect("observed run must carry a breakdown");
        assert_eq!(st.frames, 48);
        let txt = rep.to_json().to_compact();
        assert!(txt.contains("\"stages\""), "breakdown missing from: {txt}");
        // unobserved runs pay nothing and report nothing
        let plain = execute(&spec, &echo_backend("")).unwrap();
        assert!(plain.stages.is_none());
        assert!(!plain.to_json().to_compact().contains("\"stages\""));
    }

    #[test]
    fn empty_report_serializes_to_finite_json() {
        // all-default accumulators (no frames, no gaps) must not leak
        // ±inf/NaN into the report JSON
        let m = Metrics::new(&["a".to_string()]);
        let rep = PipelineReport {
            instances: m.snapshot(),
            engines: Vec::new(),
            timeline: Timeline::default(),
            wall_seconds: m.elapsed(),
            total_frames: 0,
            dropped: 0,
            shed: 0,
            stages: None,
            recon: None,
        };
        let txt = rep.to_json().to_compact();
        Json::parse(&txt).unwrap();
        assert!(
            !txt.contains("null"),
            "non-finite number degraded to null in: {txt}"
        );
    }
}
