//! End-to-end pipeline driver.
//!
//! Wires sources → router → bounded per-instance queues (backpressure) →
//! engine worker threads executing PJRT artifacts → metrics. This is the
//! real serving path: every frame is reconstructed/diagnosed by the
//! AOT-compiled JAX/Pallas models, Python nowhere in sight.
//!
//! Note on engines: the testbed has no physical DLA, so both "engines"
//! execute on the CPU PJRT client; the *scheduling structure* (which
//! instance runs where, queue topology, backpressure) is identical to the
//! paper's deployment and the timing claims are made by [`crate::sim`].

use super::batcher::{next_batch, BatchPolicy};
use super::frame::Frame;
use super::metrics::{InstanceSnapshot, Metrics};
use super::router::{RoutePolicy, Router};
use super::source::PhantomSource;
use crate::config::{PipelineConfig, Workload};
use crate::error::{Error, Result};
use crate::imaging::metrics::fidelity;
use crate::imaging::Image;
use crate::runtime::{Artifact, RuntimeClient};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Duration;

/// Online fidelity (PSNR/SSIM) is sampled rather than computed per frame:
/// SSIM costs ~1 ms/frame on this core (~8% of GAN inference) and the mean
/// converges with a fraction of the frames (perf pass, EXPERIMENTS.md
/// §Perf iteration 2).
const SCORE_EVERY: u64 = 4;

/// A model instance bound to an artifact.
struct InstanceSpec {
    label: String,
    artifact: String,
    /// Score reconstruction fidelity against the frame's ground truth.
    score_fidelity: bool,
}

/// Final pipeline report.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub instances: Vec<InstanceSnapshot>,
    pub wall_seconds: f64,
    pub total_frames: usize,
    pub dropped: usize,
}

impl PipelineReport {
    pub fn total_fps(&self) -> f64 {
        self.instances.iter().map(|i| i.fps).sum()
    }
}

fn instance_specs(workload: Workload, variant: &str) -> Vec<InstanceSpec> {
    let gan = format!("gen_{variant}");
    match workload {
        Workload::GanStandalone => vec![InstanceSpec {
            label: "gan".into(),
            artifact: gan,
            score_fidelity: true,
        }],
        Workload::GanPlusYoloNaive | Workload::GanPlusYolo => vec![
            InstanceSpec {
                label: "gan".into(),
                artifact: gan,
                score_fidelity: true,
            },
            InstanceSpec {
                label: "yolo".into(),
                artifact: "yolo_lite".into(),
                score_fidelity: false,
            },
        ],
        Workload::TwoGans => vec![
            InstanceSpec {
                label: "gan-inst1".into(),
                artifact: gan.clone(),
                score_fidelity: true,
            },
            InstanceSpec {
                label: "gan-inst2".into(),
                artifact: gan,
                score_fidelity: true,
            },
        ],
    }
}

fn route_policy(workload: Workload, streams: usize) -> RoutePolicy {
    match workload {
        Workload::TwoGans => {
            if streams > 1 {
                RoutePolicy::ByStream
            } else {
                RoutePolicy::RoundRobin
            }
        }
        _ => RoutePolicy::Fanout,
    }
}

/// Run the configured pipeline to completion and report.
pub fn run_pipeline(cfg: &PipelineConfig) -> Result<PipelineReport> {
    let specs = instance_specs(cfg.workload, cfg.variant.name());
    // Fail fast on missing artifacts before spawning anything.
    for spec in &specs {
        let hlo = std::path::Path::new(&cfg.artifact_dir)
            .join(format!("{}.hlo.txt", spec.artifact));
        if !hlo.exists() {
            return Err(Error::Runtime(format!(
                "artifact `{}` missing: {} (run `make artifacts`)",
                spec.artifact,
                hlo.display()
            )));
        }
    }

    let labels: Vec<String> = specs.iter().map(|s| s.label.clone()).collect();
    let metrics = Arc::new(Metrics::new(&labels));
    let dropped_total = Arc::new(AtomicUsize::new(0));

    // Per-instance bounded queues: the backpressure boundary.
    let mut senders: Vec<SyncSender<Frame>> = Vec::new();
    let mut receivers: Vec<Receiver<Frame>> = Vec::new();
    for _ in &specs {
        let (tx, rx) = sync_channel::<Frame>(cfg.queue_depth);
        senders.push(tx);
        receivers.push(rx);
    }

    // Workers: one thread per instance (the two-engine analogue). PJRT
    // handles are not Send (Rc internals), so each worker owns a private
    // client + compiled artifact — the same isolation a per-engine
    // TensorRT context gives on the Jetson.
    let mut handles = Vec::new();
    for (idx, (spec, rx)) in specs.iter().zip(receivers.into_iter()).enumerate() {
        let metrics = Arc::clone(&metrics);
        let artifact_name = spec.artifact.clone();
        let artifact_dir = cfg.artifact_dir.clone();
        let score = spec.score_fidelity;
        let policy = BatchPolicy {
            max_batch: cfg.max_batch,
            timeout: Duration::from_micros(cfg.batch_timeout_us),
        };
        let handle = std::thread::Builder::new()
            .name(format!("worker-{}", spec.label))
            .spawn(move || -> Result<()> {
                let client = RuntimeClient::cpu()?;
                let artifact = Artifact::load(
                    &client,
                    std::path::Path::new(&artifact_dir),
                    &artifact_name,
                )?;
                while let Some(batch) = next_batch(&rx, policy) {
                    for frame in batch {
                        let outputs = artifact.run_image(&frame.data)?;
                        let latency = frame.admitted.elapsed().as_secs_f64();
                        metrics.record_frame(idx, latency);
                        if score && frame.id % SCORE_EVERY == 0 {
                            if let (Some(gt), Some(out)) = (&frame.gt_mri, outputs.first()) {
                                record_fidelity(&metrics, idx, &frame, gt, &out.data);
                            }
                        }
                    }
                }
                Ok(())
            })
            .map_err(|e| Error::Pipeline(format!("spawn worker: {e}")))?;
        handles.push(handle);
    }

    // Source + router on the main thread (frames are cheap to make).
    let mut router = Router::new(route_policy(cfg.workload, cfg.streams), specs.len());
    let per_stream = cfg.frames / cfg.streams.max(1);
    let mut sources: Vec<PhantomSource> = (0..cfg.streams)
        .map(|s| {
            PhantomSource::new(
                crate::imaging::phantom::PhantomConfig::default(),
                cfg.seed,
                s,
                per_stream,
            )
        })
        .collect();
    let mut total_frames = 0usize;
    'outer: loop {
        let mut all_done = true;
        for src in sources.iter_mut() {
            if let Some(frame) = src.next() {
                all_done = false;
                total_frames += 1;
                for target in router.route(&frame) {
                    // Blocking send with drop-on-overload for non-primary
                    // copies keeps the pipeline moving (backpressure).
                    match senders[target].try_send(frame.clone()) {
                        Ok(()) => {}
                        Err(TrySendError::Full(f)) => {
                            // Block: the paper's pipeline is lossless.
                            if senders[target].send(f).is_err() {
                                break 'outer;
                            }
                        }
                        Err(TrySendError::Disconnected(_)) => {
                            dropped_total.fetch_add(1, Ordering::Relaxed);
                            metrics.record_drop(target);
                        }
                    }
                }
            }
        }
        if all_done {
            break;
        }
    }
    drop(senders);
    for h in handles {
        h.join()
            .map_err(|_| Error::Pipeline("worker panicked".into()))??;
    }

    Ok(PipelineReport {
        instances: metrics.snapshot(),
        wall_seconds: metrics.elapsed(),
        total_frames,
        dropped: dropped_total.load(Ordering::Relaxed),
    })
}

fn record_fidelity(metrics: &Metrics, idx: usize, frame: &Frame, gt: &[f32], out: &[f32]) {
    let to01 = |v: &[f32]| -> Vec<f32> { v.iter().map(|&x| (x + 1.0) / 2.0).collect() };
    if gt.len() != frame.numel() || out.len() != frame.numel() {
        return;
    }
    let a = Image::from_data(frame.width, frame.height, to01(gt));
    let b = Image::from_data(frame.width, frame.height, to01(out));
    if let (Ok(a), Ok(b)) = (a, b) {
        if let Ok(f) = fidelity(&a, &b) {
            metrics.record_fidelity(idx, f.psnr, f.ssim_pct);
        }
    }
}
