//! The streaming pipeline coordinator (Layer 3 proper).
//!
//! DeepStream-equivalent: CT frames flow from [`source`]s through the
//! [`batcher`] and [`router`] into per-model engine workers that execute
//! the AOT-compiled artifacts via PJRT, with bounded queues providing
//! backpressure and [`metrics`] aggregating throughput/latency. Both of
//! the paper's deployment schemes run on this machinery:
//!
//! * **standalone** (Fig 1 A): one CT stream, GAN + YOLO concurrently;
//! * **client-server** (Fig 1 B): several hospital streams multiplexed.

pub mod batcher;
pub mod driver;
pub mod frame;
pub mod metrics;
pub mod router;
pub mod source;

pub use driver::{run_pipeline, PipelineReport};
pub use frame::Frame;
