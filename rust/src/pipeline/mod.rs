//! The streaming pipeline coordinator (Layer 3 proper).
//!
//! DeepStream-equivalent: CT frames flow from [`source`]s through the
//! [`batcher`] and [`router`] into per-instance workers that execute
//! whole batches in one dispatch through a pluggable [`backend`] (PJRT
//! artifacts or the deterministic latency-model sim), with bounded queues
//! providing backpressure and [`metrics`] aggregating throughput/latency.
//! Pixel planes are `Arc`-shared [`plane::FramePlane`]s recycled through a
//! [`plane::PlanePool`] — routing and batching never copy pixels. What runs is described
//! declaratively by a [`spec::PipelineSpec`] — any number of instances,
//! not just the historical four `Workload` arms — and launched through
//! [`crate::session::Session`]. Engine placement is enforced, not
//! decorative: every dispatch executes under an exclusive per-unit lease
//! from the shared [`engines::EngineArbiter`] (GPU, DLA0, DLA1 as FIFO
//! resources with PCCS contention and reformat costs), which also records
//! the serving timeline behind the per-engine utilization stats in
//! [`driver::PipelineReport`]. The paper's deployment schemes all run on
//! this machinery:
//!
//! * **standalone** (Fig 1 A): one CT stream, GAN + YOLO concurrently;
//! * **client-server** (Fig 1 B): several hospital streams multiplexed;
//! * **dual-GAN** (Fig 13): two DLA-resident GANs splitting the load,
//!   one per DLA core, next to the GPU detector.

pub mod backend;
pub mod batcher;
pub mod driver;
pub mod engines;
pub mod frame;
pub mod metrics;
pub mod plane;
pub mod router;
pub mod source;
pub mod spec;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use backend::{InferenceBackend, ModelRunner, Output, SimBackend};
pub use batcher::BatchEnd;
pub use driver::{run_pipeline, CompletionSink, PipelineReport};
pub use engines::{DispatchProfile, EngineArbiter, EngineSnapshot};
pub use frame::Frame;
pub use metrics::FidelitySink;
pub use plane::{FramePlane, PlanePool};
pub use source::{FrameSource, KspaceSource, PhantomSource, ReconReport, ReconStats};
pub use spec::{InstanceSpec, PipelineSpec, ReconMode, SourceSpec, KSPACE_SLICE};
