//! Frame sources.
//!
//! [`PhantomSource`] synthesizes paired CT/MRI phantoms (the stand-in for
//! the CT scanner feed — DESIGN.md §2) so the pipeline can be driven and
//! *scored* without external data. [`KspaceSource`] prepends the
//! accelerated-MRI acquisition front-end: each phantom slice is acquired
//! as undersampled multi-coil k-space
//! ([`crate::imaging::kspace::Acquisition`]) and reconstructed in-pipeline
//! (zero-filled or GRAPPA) before the model chain sees it, with recon
//! time and PSNR/SSIM-vs-fully-sampled accumulating into a shared
//! [`ReconStats`] through the same [`FidelitySink`] scoring path the
//! workers use. [`FrameSource`] dispatches over the two behind one
//! iterator, built from a spec's [`SourceSpec`] by
//! [`FrameSource::for_spec`]. Sources are plain iterators; the driver
//! moves them onto their own thread.
//!
//! Plane buffers are drawn from a [`PlanePool`]: once the pipeline's
//! workers release a frame, its buffers park on the pool shelf and the
//! next `next()` call reuses them, so the sealed CT/MRI planes are
//! recycled rather than re-allocated per frame (the phantom generator's
//! internal scratch in [`paired_sample`] still allocates). The driver
//! shares one pool across all sources ([`PhantomSource::with_pool`]).

use super::frame::Frame;
use super::metrics::FidelitySink;
use super::plane::PlanePool;
use super::spec::{ReconMode, SourceSpec, KSPACE_SLICE};
use crate::config::json::{num, obj, s, Json};
use crate::error::{Error, Result};
use crate::imaging::kspace::Acquisition;
use crate::imaging::phantom::{paired_sample, PhantomConfig};
use crate::obs::stages::StageStamps;
use crate::util::lock::relock;
use crate::util::rng::Rng;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Synthetic CT stream with ground truth attached.
pub struct PhantomSource {
    cfg: PhantomConfig,
    rng: Rng,
    stream: usize,
    next_id: u64,
    remaining: usize,
    pool: PlanePool,
}

impl PhantomSource {
    pub fn new(cfg: PhantomConfig, seed: u64, stream: usize, frames: usize) -> Self {
        PhantomSource {
            cfg,
            rng: Rng::new(seed ^ (stream as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            stream,
            next_id: 0,
            remaining: frames,
            pool: PlanePool::default(),
        }
    }

    /// Draw plane buffers from (and return them to) a shared pool instead
    /// of this source's private one.
    pub fn with_pool(mut self, pool: PlanePool) -> Self {
        self.pool = pool;
        self
    }
}

impl Iterator for PhantomSource {
    type Item = Frame;

    fn next(&mut self) -> Option<Frame> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let s = paired_sample(&self.cfg, &mut self.rng);
        // scale [0,1] -> [-1,1] (model input convention), into recycled
        // buffers
        let mut data = self.pool.acquire(s.ct.data.len());
        data.extend(s.ct.data.iter().map(|&v| v * 2.0 - 1.0));
        let mut gt = self.pool.acquire(s.mri.data.len());
        gt.extend(s.mri.data.iter().map(|&v| v * 2.0 - 1.0));
        let frame = Frame {
            id: self.next_id,
            stream: self.stream,
            data: self.pool.seal(data),
            width: s.ct.width,
            height: s.ct.height,
            gt_mri: Some(self.pool.seal(gt)),
            admitted: Instant::now(),
            stamps: StageStamps::default(),
        };
        self.next_id += 1;
        Some(frame)
    }
}

/// Synthetic CT stream acquired through the accelerated-MRI k-space
/// front-end: each phantom slice becomes undersampled multi-coil k-space
/// and is reconstructed (zero-filled or GRAPPA) *before* it enters the
/// model chain, so the downstream GAN sees recon output, not the clean
/// slice. Recon wall time and fidelity-vs-fully-sampled accumulate into
/// the shared [`ReconStats`] when one is attached.
pub struct KspaceSource {
    cfg: PhantomConfig,
    rng: Rng,
    stream: usize,
    next_id: u64,
    remaining: usize,
    pool: PlanePool,
    acq: Acquisition,
    recon: ReconMode,
    recon_buf: Vec<f32>,
    stats: Option<Arc<ReconStats>>,
}

impl KspaceSource {
    /// Build a k-space source for one stream. `source` must be a
    /// [`SourceSpec::Kspace`]; geometry is validated up front so the
    /// per-frame path cannot fail on sizes.
    pub fn new(source: &SourceSpec, seed: u64, stream: usize, frames: usize) -> Result<Self> {
        let SourceSpec::Kspace { accel, acs_lines, coils, recon } = source else {
            return Err(Error::Config(
                "KspaceSource needs a `kspace` source spec".into(),
            ));
        };
        source.validate()?;
        let acq = Acquisition::new(KSPACE_SLICE, *accel, *acs_lines, *coils)?;
        Ok(KspaceSource {
            cfg: PhantomConfig {
                size: KSPACE_SLICE,
                ..PhantomConfig::default()
            },
            rng: Rng::new(seed ^ (stream as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            stream,
            next_id: 0,
            remaining: frames,
            pool: PlanePool::default(),
            acq,
            recon: *recon,
            recon_buf: vec![0.0; KSPACE_SLICE * KSPACE_SLICE],
            stats: None,
        })
    }

    /// Draw plane buffers from (and return them to) a shared pool instead
    /// of this source's private one.
    pub fn with_pool(mut self, pool: PlanePool) -> Self {
        self.pool = pool;
        self
    }

    /// Attach the shared recon accumulator (the driver hands the same one
    /// to every stream; the run report aggregates across all of them).
    pub fn with_stats(mut self, stats: Option<Arc<ReconStats>>) -> Self {
        self.stats = stats;
        self
    }
}

impl Iterator for KspaceSource {
    type Item = Frame;

    fn next(&mut self) -> Option<Frame> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let s = paired_sample(&self.cfg, &mut self.rng);
        let t0 = Instant::now();
        // Geometry was validated at construction, so these cannot fail on
        // sizes; a GRAPPA fit can still be singular on degenerate data —
        // end the stream rather than panic.
        self.acq.acquire(&s.ct).ok()?;
        match self.recon {
            ReconMode::ZeroFilled => self.acq.recon_zero_filled(&mut self.recon_buf).ok()?,
            ReconMode::Grappa => self.acq.recon_grappa(&mut self.recon_buf).ok()?,
        }
        let recon_s = t0.elapsed().as_secs_f64();
        // scale [0,1] -> [-1,1] (model input convention), into recycled
        // buffers — the frame carries the *reconstruction*, not the clean
        // slice, while the MRI ground truth is untouched so downstream
        // GAN fidelity stays comparable with the phantom source.
        let mut data = self.pool.acquire(self.recon_buf.len());
        data.extend(self.recon_buf.iter().map(|&v| v * 2.0 - 1.0));
        let mut gt = self.pool.acquire(s.mri.data.len());
        gt.extend(s.mri.data.iter().map(|&v| v * 2.0 - 1.0));
        let n = self.acq.size();
        let frame = Frame {
            id: self.next_id,
            stream: self.stream,
            data: self.pool.seal(data),
            width: n,
            height: n,
            gt_mri: Some(self.pool.seal(gt)),
            admitted: Instant::now(),
            stamps: StageStamps::default(),
        };
        if let Some(stats) = &self.stats {
            stats.record_frame(recon_s);
            if super::driver::should_score(frame.id) {
                // model-range view of the fully-sampled slice, scored
                // through the same helper the pipeline workers use
                let gt_model: Vec<f32> = self
                    .acq
                    .ground_truth()
                    .iter()
                    .map(|&v| v * 2.0 - 1.0)
                    .collect();
                super::driver::record_fidelity(stats.as_ref(), 0, &frame, &gt_model, &frame.data);
            }
        }
        self.next_id += 1;
        Some(frame)
    }
}

#[derive(Debug, Default)]
struct ReconAccum {
    frames: usize,
    scored: usize,
    skipped: usize,
    psnr_count: usize,
    psnr_sum: f64,
    ssim_pct_sum: f64,
    recon_s_total: f64,
}

/// Thread-safe accumulator for the recon front-end: per-frame recon wall
/// time plus the [`FidelitySink`] samples scored against the
/// fully-sampled ground truth. One instance is shared by every stream of
/// a run; [`ReconStats::report`] folds it into the run report.
#[derive(Debug, Default)]
pub struct ReconStats {
    inner: Mutex<ReconAccum>,
}

impl ReconStats {
    /// Charge one reconstructed frame's wall time.
    pub fn record_frame(&self, recon_s: f64) {
        let mut a = relock(&self.inner);
        a.frames += 1;
        a.recon_s_total += recon_s;
    }

    /// Fold the accumulated counters into a report. Returns `None` for a
    /// phantom source (there is no recon stage to report on).
    pub fn report(&self, source: &SourceSpec) -> Option<ReconReport> {
        let SourceSpec::Kspace { accel, acs_lines, coils, recon } = source else {
            return None;
        };
        let a = relock(&self.inner);
        Some(ReconReport {
            recon: recon.name().to_string(),
            accel: *accel,
            acs_lines: *acs_lines,
            coils: *coils,
            frames: a.frames,
            scored: a.scored,
            skipped: a.skipped,
            psnr_mean: a.psnr_sum / a.psnr_count.max(1) as f64,
            ssim_pct_mean: a.ssim_pct_sum / a.scored.max(1) as f64,
            recon_ms_per_frame: a.recon_s_total / a.frames.max(1) as f64 * 1e3,
        })
    }
}

impl FidelitySink for ReconStats {
    fn fidelity(&self, _slot: usize, psnr: f64, ssim_pct: f64) {
        let mut a = relock(&self.inner);
        a.scored += 1;
        a.ssim_pct_sum += ssim_pct;
        // an exact recon (R=1 fast path) has infinite PSNR; keep it out
        // of the mean the same way Metrics does
        if psnr.is_finite() {
            a.psnr_count += 1;
            a.psnr_sum += psnr;
        }
    }

    fn fidelity_skipped(&self, _slot: usize) {
        relock(&self.inner).skipped += 1;
    }
}

/// Per-run summary of the k-space recon front-end, attached to batch and
/// serve reports when the source is `kspace`.
#[derive(Debug, Clone, PartialEq)]
pub struct ReconReport {
    pub recon: String,
    pub accel: usize,
    pub acs_lines: usize,
    pub coils: usize,
    pub frames: usize,
    pub scored: usize,
    pub skipped: usize,
    pub psnr_mean: f64,
    pub ssim_pct_mean: f64,
    pub recon_ms_per_frame: f64,
}

impl ReconReport {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("recon", s(&self.recon)),
            ("accel", num(self.accel as f64)),
            ("acs_lines", num(self.acs_lines as f64)),
            ("coils", num(self.coils as f64)),
            ("frames", num(self.frames as f64)),
            ("scored", num(self.scored as f64)),
            ("skipped", num(self.skipped as f64)),
            ("psnr_mean", num(self.psnr_mean)),
            ("ssim_pct_mean", num(self.ssim_pct_mean)),
            ("recon_ms_per_frame", num(self.recon_ms_per_frame)),
        ])
    }
}

/// The pluggable front door: one iterator over whichever acquisition mode
/// the spec's [`SourceSpec`] selects. The driver and serve loop build
/// streams exclusively through [`FrameSource::for_spec`], so adding an
/// acquisition mode means adding a variant here — not editing every
/// stream-construction site.
pub enum FrameSource {
    Phantom(PhantomSource),
    Kspace(Box<KspaceSource>),
}

impl FrameSource {
    /// Build the source `spec.source` asks for, for one stream. `stats`
    /// is the shared recon accumulator (ignored by phantom sources).
    pub fn for_spec(
        source: &SourceSpec,
        seed: u64,
        stream: usize,
        frames: usize,
        pool: PlanePool,
        stats: Option<Arc<ReconStats>>,
    ) -> Result<FrameSource> {
        match source {
            SourceSpec::Phantom => Ok(FrameSource::Phantom(
                PhantomSource::new(PhantomConfig::default(), seed, stream, frames)
                    .with_pool(pool),
            )),
            SourceSpec::Kspace { .. } => Ok(FrameSource::Kspace(Box::new(
                KspaceSource::new(source, seed, stream, frames)?
                    .with_pool(pool)
                    .with_stats(stats),
            ))),
        }
    }
}

impl Iterator for FrameSource {
    type Item = Frame;

    fn next(&mut self) -> Option<Frame> {
        match self {
            FrameSource::Phantom(src) => src.next(),
            FrameSource::Kspace(src) => src.next(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_requested_frames() {
        let src = PhantomSource::new(PhantomConfig::default(), 1, 0, 5);
        let frames: Vec<Frame> = src.collect();
        assert_eq!(frames.len(), 5);
        assert_eq!(frames[0].width, 64);
        assert_eq!(frames[4].id, 4);
        assert!(frames[0].gt_mri.is_some());
    }

    #[test]
    fn frames_scaled_to_tanh_range() {
        let mut src = PhantomSource::new(PhantomConfig::default(), 2, 0, 1);
        let f = src.next().unwrap();
        let mn = f.data.iter().copied().fold(f32::INFINITY, f32::min);
        let mx = f.data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!(mn >= -1.0 && mx <= 1.0);
        assert!(mx > 0.5, "skull should be bright");
    }

    #[test]
    fn streams_differ() {
        let a: Vec<Frame> = PhantomSource::new(PhantomConfig::default(), 1, 0, 2).collect();
        let b: Vec<Frame> = PhantomSource::new(PhantomConfig::default(), 1, 1, 2).collect();
        assert_ne!(a[0].data, b[0].data);
    }

    #[test]
    fn shared_pool_recycles_released_planes() {
        let pool = PlanePool::default();
        let mut src = PhantomSource::new(PhantomConfig::default(), 3, 0, 4)
            .with_pool(pool.clone());
        let f0 = src.next().unwrap();
        assert_eq!(pool.parked(), 0);
        drop(f0); // releases data + gt planes
        assert_eq!(pool.parked(), 2);
        let _f1 = src.next().unwrap(); // reuses both buffers
        assert_eq!(pool.parked(), 0);
    }

    #[test]
    fn kspace_source_produces_scored_frames() {
        let spec = SourceSpec::kspace(4, ReconMode::Grappa);
        let stats = Arc::new(ReconStats::default());
        let src = KspaceSource::new(&spec, 7, 0, 5)
            .unwrap()
            .with_stats(Some(stats.clone()));
        let frames: Vec<Frame> = src.collect();
        assert_eq!(frames.len(), 5);
        assert_eq!(frames[0].width, KSPACE_SLICE);
        assert!(frames[0].gt_mri.is_some());
        let mn = frames[0].data.iter().copied().fold(f32::INFINITY, f32::min);
        let mx = frames[0].data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!(mn >= -1.0 && mx <= 1.0, "recon frames must stay in model range");
        let rep = stats.report(&spec).unwrap();
        assert_eq!(rep.frames, 5);
        // SCORE_EVERY = 4 gates fidelity: frames 0 and 4 score
        assert_eq!(rep.scored + rep.skipped, 2);
        assert!(rep.recon_ms_per_frame > 0.0);
        assert_eq!(rep.recon, "grappa");
        assert_eq!(rep.accel, 4);
    }

    #[test]
    fn kspace_source_rejects_phantom_spec() {
        assert!(KspaceSource::new(&SourceSpec::Phantom, 1, 0, 1).is_err());
    }

    #[test]
    fn frame_source_dispatches_on_spec() {
        let pool = PlanePool::default();
        let ph = FrameSource::for_spec(&SourceSpec::Phantom, 1, 0, 2, pool.clone(), None)
            .unwrap();
        assert!(matches!(ph, FrameSource::Phantom(_)));
        assert_eq!(ph.count(), 2);
        let ks = FrameSource::for_spec(
            &SourceSpec::kspace(2, ReconMode::ZeroFilled),
            1,
            0,
            2,
            pool,
            None,
        )
        .unwrap();
        assert!(matches!(ks, FrameSource::Kspace(_)));
        assert_eq!(ks.count(), 2);
    }

    #[test]
    fn recon_report_handles_infinite_psnr_and_empty_runs() {
        let stats = ReconStats::default();
        let spec = SourceSpec::kspace(1, ReconMode::ZeroFilled);
        // empty run: no NaNs in the report
        let rep = stats.report(&spec).unwrap();
        assert_eq!(rep.frames, 0);
        assert!(rep.psnr_mean.is_finite() && rep.recon_ms_per_frame == 0.0);
        // R=1 exact recon scores infinite PSNR — kept out of the mean
        stats.fidelity(0, f64::INFINITY, 100.0);
        stats.fidelity(0, 30.0, 90.0);
        stats.fidelity_skipped(0);
        let rep = stats.report(&spec).unwrap();
        assert_eq!(rep.scored, 2);
        assert_eq!(rep.skipped, 1);
        assert_eq!(rep.psnr_mean, 30.0);
        assert_eq!(rep.ssim_pct_mean, 95.0);
        // phantom source has no recon stage to report
        assert!(stats.report(&SourceSpec::Phantom).is_none());
    }

    #[test]
    fn recon_report_serializes_every_counter() {
        let rep = ReconReport {
            recon: "grappa".to_string(),
            accel: 4,
            acs_lines: 16,
            coils: 4,
            frames: 8,
            scored: 2,
            skipped: 0,
            psnr_mean: 31.5,
            ssim_pct_mean: 88.0,
            recon_ms_per_frame: 9.4,
        };
        let j = rep.to_json();
        for key in [
            "recon",
            "accel",
            "acs_lines",
            "coils",
            "frames",
            "scored",
            "skipped",
            "psnr_mean",
            "ssim_pct_mean",
            "recon_ms_per_frame",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("accel").and_then(Json::as_u64), Some(4));
    }
}
