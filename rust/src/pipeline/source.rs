//! Frame sources.
//!
//! [`PhantomSource`] synthesizes paired CT/MRI phantoms (the stand-in for
//! the CT scanner feed — DESIGN.md §2) so the pipeline can be driven and
//! *scored* without external data. Sources are plain iterators; the driver
//! moves them onto their own thread.
//!
//! Plane buffers are drawn from a [`PlanePool`]: once the pipeline's
//! workers release a frame, its buffers park on the pool shelf and the
//! next `next()` call reuses them, so the sealed CT/MRI planes are
//! recycled rather than re-allocated per frame (the phantom generator's
//! internal scratch in [`paired_sample`] still allocates). The driver
//! shares one pool across all sources ([`PhantomSource::with_pool`]).

use super::frame::Frame;
use super::plane::PlanePool;
use crate::obs::stages::StageStamps;
use crate::imaging::phantom::{paired_sample, PhantomConfig};
use crate::util::rng::Rng;
use std::time::Instant;

/// Synthetic CT stream with ground truth attached.
pub struct PhantomSource {
    cfg: PhantomConfig,
    rng: Rng,
    stream: usize,
    next_id: u64,
    remaining: usize,
    pool: PlanePool,
}

impl PhantomSource {
    pub fn new(cfg: PhantomConfig, seed: u64, stream: usize, frames: usize) -> Self {
        PhantomSource {
            cfg,
            rng: Rng::new(seed ^ (stream as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            stream,
            next_id: 0,
            remaining: frames,
            pool: PlanePool::default(),
        }
    }

    /// Draw plane buffers from (and return them to) a shared pool instead
    /// of this source's private one.
    pub fn with_pool(mut self, pool: PlanePool) -> Self {
        self.pool = pool;
        self
    }
}

impl Iterator for PhantomSource {
    type Item = Frame;

    fn next(&mut self) -> Option<Frame> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let s = paired_sample(&self.cfg, &mut self.rng);
        // scale [0,1] -> [-1,1] (model input convention), into recycled
        // buffers
        let mut data = self.pool.acquire(s.ct.data.len());
        data.extend(s.ct.data.iter().map(|&v| v * 2.0 - 1.0));
        let mut gt = self.pool.acquire(s.mri.data.len());
        gt.extend(s.mri.data.iter().map(|&v| v * 2.0 - 1.0));
        let frame = Frame {
            id: self.next_id,
            stream: self.stream,
            data: self.pool.seal(data),
            width: s.ct.width,
            height: s.ct.height,
            gt_mri: Some(self.pool.seal(gt)),
            admitted: Instant::now(),
            stamps: StageStamps::default(),
        };
        self.next_id += 1;
        Some(frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_requested_frames() {
        let src = PhantomSource::new(PhantomConfig::default(), 1, 0, 5);
        let frames: Vec<Frame> = src.collect();
        assert_eq!(frames.len(), 5);
        assert_eq!(frames[0].width, 64);
        assert_eq!(frames[4].id, 4);
        assert!(frames[0].gt_mri.is_some());
    }

    #[test]
    fn frames_scaled_to_tanh_range() {
        let mut src = PhantomSource::new(PhantomConfig::default(), 2, 0, 1);
        let f = src.next().unwrap();
        let mn = f.data.iter().copied().fold(f32::INFINITY, f32::min);
        let mx = f.data.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        assert!(mn >= -1.0 && mx <= 1.0);
        assert!(mx > 0.5, "skull should be bright");
    }

    #[test]
    fn streams_differ() {
        let a: Vec<Frame> = PhantomSource::new(PhantomConfig::default(), 1, 0, 2).collect();
        let b: Vec<Frame> = PhantomSource::new(PhantomConfig::default(), 1, 1, 2).collect();
        assert_ne!(a[0].data, b[0].data);
    }

    #[test]
    fn shared_pool_recycles_released_planes() {
        let pool = PlanePool::default();
        let mut src = PhantomSource::new(PhantomConfig::default(), 3, 0, 4)
            .with_pool(pool.clone());
        let f0 = src.next().unwrap();
        assert_eq!(pool.parked(), 0);
        drop(f0); // releases data + gt planes
        assert_eq!(pool.parked(), 2);
        let _f1 = src.next().unwrap(); // reuses both buffers
        assert_eq!(pool.parked(), 0);
    }
}
