//! Dynamic batcher.
//!
//! Collects frames up to `max_batch` or until `timeout` elapses after the
//! first frame (the vLLM/DeepStream policy). The paper's pipelines are
//! latency-oriented batch-1, but the client-server scheme benefits from
//! small batches under multi-stream load — the worker hands the whole
//! batch to [`super::backend::ModelRunner::execute_batch`] as one
//! dispatch.

// Per-batch collection loop: runs for every dispatched batch.
#![deny(clippy::unwrap_used)]

use super::frame::Frame;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 1,
            timeout: Duration::from_micros(500),
        }
    }
}

/// Why a batch stopped filling before reaching `max_batch` — the deadline
/// path and a disconnected source are *different events* (an empty-but-open
/// queue means "no load right now"; a disconnect means "the stream is
/// over") and callers that account for load shedding must not conflate
/// them with each other or with overload drops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchEnd {
    /// The batch reached `max_batch` frames.
    Filled,
    /// The fill window expired with the channel still open (a quiet or
    /// zero-capacity queue) — more frames may arrive later.
    Deadline,
    /// The sender side hung up mid-fill; the partial batch flushes
    /// immediately and the next call will observe end-of-stream.
    Disconnected,
}

/// Pull the next batch from `rx`, reporting *why* it closed. Returns
/// `None` when the channel is closed and drained.
///
/// The wait strategy is a single deadline fixed when the first frame
/// arrives, with exactly one `recv_timeout` per additional frame for the
/// *remaining* window — no periodic re-polling, no drift accumulation, no
/// busy-spin. A disconnect mid-batch flushes the partial batch
/// immediately instead of waiting out the window; the disconnect itself
/// surfaces as `None` on the next call, once the channel is drained.
pub fn collect_batch(rx: &Receiver<Frame>, policy: BatchPolicy) -> Option<(Vec<Frame>, BatchEnd)> {
    let mut batch = Vec::with_capacity(policy.max_batch.max(1));
    collect_batch_into(rx, policy, &mut batch).map(|end| (batch, end))
}

/// [`collect_batch`] into a caller-owned buffer: the worker loop keeps one
/// `Vec` alive for its whole life instead of allocating per batch (the
/// buffer is cleared first, so any frames still in it are dropped here).
pub fn collect_batch_into(
    rx: &Receiver<Frame>,
    policy: BatchPolicy,
    batch: &mut Vec<Frame>,
) -> Option<BatchEnd> {
    batch.clear();
    // Block for the first frame.
    let first = rx.recv().ok()?;
    batch.push(first);
    if policy.max_batch <= 1 {
        // Queue-exit stage stamp: one clock read, no allocation.
        if let Some(f) = batch.last_mut() {
            f.stamps.mark_queue_exit(f.admitted.elapsed().as_secs_f64());
        }
        return Some(BatchEnd::Filled);
    }
    let deadline = Instant::now() + policy.timeout;
    let mut end = BatchEnd::Filled;
    while batch.len() < policy.max_batch {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            end = BatchEnd::Deadline;
            break;
        }
        match rx.recv_timeout(remaining) {
            Ok(f) => batch.push(f),
            Err(RecvTimeoutError::Timeout) => {
                end = BatchEnd::Deadline;
                break;
            }
            Err(RecvTimeoutError::Disconnected) => {
                end = BatchEnd::Disconnected;
                break;
            }
        }
    }
    // Queue-exit stage stamp for the whole batch: one clock read, no
    // allocation (`duration_since` saturates to zero, so stamps stay
    // monotone even against clock edge cases).
    let exit = Instant::now();
    for f in batch.iter_mut() {
        f.stamps
            .mark_queue_exit(exit.duration_since(f.admitted).as_secs_f64());
    }
    Some(end)
}

/// [`collect_batch`] without the close reason (the worker hot path only
/// needs the frames; loss accounting happens at routing/admission, not
/// here).
pub fn next_batch(rx: &Receiver<Frame>, policy: BatchPolicy) -> Option<Vec<Frame>> {
    collect_batch(rx, policy).map(|(batch, _)| batch)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::pipeline::plane::FramePlane;
    use std::sync::mpsc::sync_channel;
    use std::time::Instant as StdInstant;

    fn frame(id: u64) -> Frame {
        Frame {
            id,
            stream: 0,
            data: FramePlane::from_vec(Vec::new()),
            width: 0,
            height: 0,
            gt_mri: None,
            admitted: StdInstant::now(),
            stamps: Default::default(),
        }
    }

    #[test]
    fn batch_of_one_returns_immediately() {
        let (tx, rx) = sync_channel(4);
        tx.send(frame(0)).unwrap();
        let b = next_batch(&rx, BatchPolicy::default()).unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = sync_channel(8);
        for i in 0..5 {
            tx.send(frame(i)).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            timeout: Duration::from_millis(50),
        };
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b[3].id, 3);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let (tx, rx) = sync_channel(8);
        tx.send(frame(0)).unwrap();
        tx.send(frame(1)).unwrap();
        let policy = BatchPolicy {
            max_batch: 16,
            timeout: Duration::from_millis(10),
        };
        let t0 = StdInstant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn timeout_expiry_waits_the_window_once() {
        let (tx, rx) = sync_channel(8);
        tx.send(frame(0)).unwrap();
        let timeout = Duration::from_millis(25);
        let policy = BatchPolicy {
            max_batch: 4,
            timeout,
        };
        let t0 = StdInstant::now();
        let b = next_batch(&rx, policy).unwrap();
        let waited = t0.elapsed();
        assert_eq!(b.len(), 1);
        // waited out the window exactly once: no early return, no
        // repeated re-arming of the timeout
        assert!(waited >= timeout, "returned after {waited:?} < {timeout:?}");
        assert!(
            waited < timeout * 20,
            "deadline drifted: waited {waited:?} for a {timeout:?} window"
        );
        drop(tx);
    }

    #[test]
    fn zero_timeout_returns_first_frame_immediately() {
        let (tx, rx) = sync_channel(8);
        tx.send(frame(0)).unwrap();
        tx.send(frame(1)).unwrap();
        let policy = BatchPolicy {
            max_batch: 4,
            timeout: Duration::ZERO,
        };
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn disconnect_mid_batch_flushes_partial_promptly() {
        let (tx, rx) = sync_channel(8);
        tx.send(frame(0)).unwrap();
        let sender = std::thread::spawn(move || {
            tx.send(frame(1)).unwrap();
            // dropping the only sender disconnects the channel while the
            // batcher still wants two more frames
        });
        let policy = BatchPolicy {
            max_batch: 4,
            timeout: Duration::from_secs(5),
        };
        let t0 = StdInstant::now();
        let b = next_batch(&rx, policy).unwrap();
        sender.join().unwrap();
        assert_eq!(b.len(), 2, "partial batch must flush on disconnect");
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "disconnect must not wait out the 5s window"
        );
        // drained + disconnected channel ends the stream
        assert!(next_batch(&rx, policy).is_none());
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = sync_channel::<Frame>(1);
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn deadline_and_disconnect_report_distinct_ends() {
        let policy = BatchPolicy {
            max_batch: 4,
            timeout: Duration::from_millis(10),
        };
        // Quiet-but-open queue: the window expires -> Deadline.
        let (tx, rx) = sync_channel(8);
        tx.send(frame(0)).unwrap();
        let (b, end) = collect_batch(&rx, policy).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(end, BatchEnd::Deadline, "open queue must report a deadline expiry");
        drop(tx);
        // Hung-up source: the partial batch flushes as Disconnected.
        let (tx, rx) = sync_channel(8);
        tx.send(frame(0)).unwrap();
        drop(tx);
        let (b, end) = collect_batch(&rx, policy).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(end, BatchEnd::Disconnected, "a dropped sender is not a quiet queue");
        assert!(collect_batch(&rx, policy).is_none());
        // Full batch: Filled, regardless of what happens to the sender.
        let (tx, rx) = sync_channel(8);
        for i in 0..4 {
            tx.send(frame(i)).unwrap();
        }
        let (b, end) = collect_batch(&rx, policy).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(end, BatchEnd::Filled);
    }
}
