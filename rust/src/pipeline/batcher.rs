//! Dynamic batcher.
//!
//! Collects frames up to `max_batch` or until `timeout` elapses after the
//! first frame (the vLLM/DeepStream policy). The paper's pipelines are
//! latency-oriented batch-1, but the client-server scheme benefits from
//! small batches under multi-stream load.

use super::frame::Frame;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 1,
            timeout: Duration::from_micros(500),
        }
    }
}

/// Pull the next batch from `rx`. Returns `None` when the channel is
/// closed and drained.
pub fn next_batch(rx: &Receiver<Frame>, policy: BatchPolicy) -> Option<Vec<Frame>> {
    // Block for the first frame.
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    if policy.max_batch <= 1 {
        return Some(batch);
    }
    let deadline = Instant::now() + policy.timeout;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(f) => batch.push(f),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;
    use std::time::Instant as StdInstant;

    fn frame(id: u64) -> Frame {
        Frame {
            id,
            stream: 0,
            data: vec![],
            width: 0,
            height: 0,
            gt_mri: None,
            admitted: StdInstant::now(),
        }
    }

    #[test]
    fn batch_of_one_returns_immediately() {
        let (tx, rx) = sync_channel(4);
        tx.send(frame(0)).unwrap();
        let b = next_batch(&rx, BatchPolicy::default()).unwrap();
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = sync_channel(8);
        for i in 0..5 {
            tx.send(frame(i)).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            timeout: Duration::from_millis(50),
        };
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b.len(), 4);
        assert_eq!(b[3].id, 3);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let (tx, rx) = sync_channel(8);
        tx.send(frame(0)).unwrap();
        tx.send(frame(1)).unwrap();
        let policy = BatchPolicy {
            max_batch: 16,
            timeout: Duration::from_millis(10),
        };
        let t0 = StdInstant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = sync_channel::<Frame>(1);
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }
}
