//! Frame and frame metadata.

use std::time::Instant;

/// One CT slice travelling through the pipeline.
#[derive(Debug, Clone)]
pub struct Frame {
    /// Monotonic id within its stream.
    pub id: u64,
    /// Source stream (client-server scheme has several).
    pub stream: usize,
    /// Flattened NHWC pixels in [-1, 1] (model input scaling).
    pub data: Vec<f32>,
    pub width: usize,
    pub height: usize,
    /// Ground-truth MRI in [-1, 1] when the source is synthetic (enables
    /// online PSNR/SSIM without stopping the pipeline).
    pub gt_mri: Option<Vec<f32>>,
    /// Admission timestamp for end-to-end latency.
    pub admitted: Instant,
}

impl Frame {
    pub fn numel(&self) -> usize {
        self.width * self.height
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel() {
        let f = Frame {
            id: 0,
            stream: 0,
            data: vec![0.0; 64 * 64],
            width: 64,
            height: 64,
            gt_mri: None,
            admitted: Instant::now(),
        };
        assert_eq!(f.numel(), 4096);
    }
}
