//! Frame and frame metadata.
//!
//! Pixel planes are [`FramePlane`]s behind `Arc`: cloning a `Frame` (the
//! router's fanout path) bumps two refcounts and copies a few words of
//! metadata — it never touches pixel memory. See [`super::plane`] for the
//! sharing/recycling invariants.

use super::plane::FramePlane;
use crate::obs::stages::StageStamps;
use std::sync::Arc;
use std::time::Instant;

/// One CT slice travelling through the pipeline.
#[derive(Debug, Clone)]
pub struct Frame {
    /// Monotonic id within its stream.
    pub id: u64,
    /// Source stream (client-server scheme has several).
    pub stream: usize,
    /// Flattened NHWC pixels in [-1, 1] (model input scaling), shared —
    /// routed copies of this frame alias the same plane.
    pub data: Arc<FramePlane>,
    pub width: usize,
    pub height: usize,
    /// Ground-truth MRI in [-1, 1] when the source is synthetic (enables
    /// online PSNR/SSIM without stopping the pipeline). The driver strips
    /// it from copies routed to instances that do not score fidelity.
    pub gt_mri: Option<Arc<FramePlane>>,
    /// Admission timestamp for end-to-end latency.
    pub admitted: Instant,
    /// Cumulative stage-crossing times since admission (`Copy`, a few
    /// words): queue exit is stamped by the batcher, the engine stamps
    /// are sealed by the worker from the dispatch receipt. Folded into
    /// the run's [`crate::obs::StageAccum`] when observability is on.
    pub stamps: StageStamps,
}

impl Frame {
    pub fn numel(&self) -> usize {
        self.width * self.height
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel() {
        let f = Frame {
            id: 0,
            stream: 0,
            data: FramePlane::from_vec(vec![0.0; 64 * 64]),
            width: 64,
            height: 64,
            gt_mri: None,
            admitted: Instant::now(),
            stamps: StageStamps::default(),
        };
        assert_eq!(f.numel(), 4096);
    }

    #[test]
    fn clone_shares_planes_zero_copy() {
        let f = Frame {
            id: 1,
            stream: 0,
            data: FramePlane::from_vec(vec![0.25; 16]),
            width: 4,
            height: 4,
            gt_mri: Some(FramePlane::from_vec(vec![0.75; 16])),
            admitted: Instant::now(),
            stamps: StageStamps::default(),
        };
        let g = f.clone();
        assert!(Arc::ptr_eq(&f.data, &g.data), "pixel plane must be shared");
        assert!(Arc::ptr_eq(f.gt_mri.as_ref().unwrap(), g.gt_mri.as_ref().unwrap()));
        assert_eq!(Arc::strong_count(&f.data), 2);
        drop(g);
        assert_eq!(Arc::strong_count(&f.data), 1);
    }
}
