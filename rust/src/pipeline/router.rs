//! Frame routing.
//!
//! Decides which model instance(s) process each incoming frame:
//!
//! * `Fanout` — every frame goes to every instance (the paper's
//!   standalone scheme: the same CT slice is reconstructed by the GAN
//!   *and* diagnosed by YOLO);
//! * `RoundRobin` — frames alternate across instances (the two-GAN
//!   multi-stream reconstruction workload);
//! * `ByStream` — stream *s* maps to instance *s mod n* (client-server).

use super::frame::Frame;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    Fanout,
    RoundRobin,
    ByStream,
}

/// Stateful router.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    instances: usize,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy, instances: usize) -> Self {
        assert!(instances > 0);
        Router {
            policy,
            instances,
            rr_next: 0,
        }
    }

    /// Instances that must process this frame.
    pub fn route(&mut self, frame: &Frame) -> Vec<usize> {
        match self.policy {
            RoutePolicy::Fanout => (0..self.instances).collect(),
            RoutePolicy::RoundRobin => {
                let i = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.instances;
                vec![i]
            }
            RoutePolicy::ByStream => vec![frame.stream % self.instances],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn frame(stream: usize) -> Frame {
        Frame {
            id: 0,
            stream,
            data: vec![],
            width: 0,
            height: 0,
            gt_mri: None,
            admitted: Instant::now(),
        }
    }

    #[test]
    fn fanout_hits_all() {
        let mut r = Router::new(RoutePolicy::Fanout, 3);
        assert_eq!(r.route(&frame(0)), vec![0, 1, 2]);
    }

    #[test]
    fn round_robin_alternates() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2);
        assert_eq!(r.route(&frame(0)), vec![0]);
        assert_eq!(r.route(&frame(0)), vec![1]);
        assert_eq!(r.route(&frame(0)), vec![0]);
    }

    #[test]
    fn by_stream_is_stable() {
        let mut r = Router::new(RoutePolicy::ByStream, 2);
        assert_eq!(r.route(&frame(0)), vec![0]);
        assert_eq!(r.route(&frame(1)), vec![1]);
        assert_eq!(r.route(&frame(5)), vec![1]);
        assert_eq!(r.route(&frame(0)), vec![0]);
    }

    #[test]
    #[should_panic]
    fn zero_instances_rejected() {
        Router::new(RoutePolicy::Fanout, 0);
    }
}
