//! Frame routing.
//!
//! Decides which model instance(s) process each incoming frame:
//!
//! * `Fanout` — every frame goes to every instance (the paper's
//!   standalone scheme: the same CT slice is reconstructed by the GAN
//!   *and* diagnosed by YOLO);
//! * `RoundRobin` — frames alternate across instances (the two-GAN
//!   multi-stream reconstruction workload);
//! * `ByStream` — stream *s* maps to instance *s mod n* (client-server);
//! * `RrFanoutLast` — frames round-robin across all instances but the
//!   last, which receives **every** frame (the dual-GAN deployment: two
//!   DLA-resident GANs share the reconstruction load while the GPU
//!   detector sees the full stream).
//!
//! `route` is on the per-frame hot path, so it returns the allocation-free
//! [`RouteTargets`] iterator instead of a `Vec` (the `hotpath` bench's
//! `route_*` cases track this). Routing is also zero-copy on pixels: the
//! driver materialises each target's copy with `Frame::clone`, which only
//! bumps the shared [`super::plane::FramePlane`] refcounts.

// Per-frame route selection: allocation- and panic-free by contract.
#![deny(clippy::unwrap_used)]

use super::frame::Frame;
use crate::error::{Error, Result};

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    Fanout,
    RoundRobin,
    ByStream,
    /// Round-robin across instances `0..n-1`; instance `n-1` additionally
    /// receives every frame (droppable fanout copy).
    RrFanoutLast,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fanout" => Ok(RoutePolicy::Fanout),
            "round-robin" | "roundrobin" | "rr" => Ok(RoutePolicy::RoundRobin),
            "by-stream" | "bystream" => Ok(RoutePolicy::ByStream),
            "rr+fanout" | "round-robin+fanout" => Ok(RoutePolicy::RrFanoutLast),
            other => Err(Error::Config(format!(
                "unknown route policy `{other}` (known: fanout, round-robin, by-stream, \
                 rr+fanout)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::Fanout => "fanout",
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::ByStream => "by-stream",
            RoutePolicy::RrFanoutLast => "rr+fanout",
        }
    }

    /// Copies of each frame this policy materialises across `instances`
    /// targets — the single source of truth for fan-out arity (the serve
    /// loop's completions-to-unique-frames conversion reads this; it must
    /// agree with what [`Router::route`] yields).
    pub fn copies_per_frame(&self, instances: usize) -> usize {
        match self {
            RoutePolicy::Fanout => instances.max(1),
            RoutePolicy::RoundRobin | RoutePolicy::ByStream => 1,
            RoutePolicy::RrFanoutLast => {
                if instances > 1 {
                    2
                } else {
                    1
                }
            }
        }
    }
}

/// Allocation-free set of instance indices one frame routes to. The first
/// yielded index is the *primary* copy (lossless under backpressure); the
/// driver treats later fanout copies as droppable on overload.
#[derive(Debug, Clone)]
pub enum RouteTargets {
    /// Every instance, in order (fanout).
    All(std::ops::Range<usize>),
    /// Exactly one instance.
    One(std::iter::Once<usize>),
    /// Exactly two instances: a round-robin primary plus a broadcast tail.
    Two(std::array::IntoIter<usize, 2>),
}

impl Iterator for RouteTargets {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        match self {
            RouteTargets::All(r) => r.next(),
            RouteTargets::One(o) => o.next(),
            RouteTargets::Two(t) => t.next(),
        }
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        match self {
            RouteTargets::All(r) => r.size_hint(),
            RouteTargets::One(o) => o.size_hint(),
            RouteTargets::Two(t) => t.size_hint(),
        }
    }
}

impl ExactSizeIterator for RouteTargets {}

/// Stateful router.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    instances: usize,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy, instances: usize) -> Self {
        assert!(instances > 0);
        Router {
            policy,
            instances,
            rr_next: 0,
        }
    }

    /// Instances that must process this frame (no per-call allocation).
    pub fn route(&mut self, frame: &Frame) -> RouteTargets {
        match self.policy {
            RoutePolicy::Fanout => RouteTargets::All(0..self.instances),
            RoutePolicy::RoundRobin => {
                // Conditional wrap instead of `%`: integer division is the
                // single most expensive op left on this per-frame path.
                let i = self.rr_next;
                self.rr_next = i + 1;
                if self.rr_next == self.instances {
                    self.rr_next = 0;
                }
                RouteTargets::One(std::iter::once(i))
            }
            RoutePolicy::ByStream => {
                let i = if frame.stream < self.instances {
                    frame.stream
                } else {
                    frame.stream % self.instances
                };
                RouteTargets::One(std::iter::once(i))
            }
            RoutePolicy::RrFanoutLast => {
                if self.instances == 1 {
                    return RouteTargets::One(std::iter::once(0));
                }
                let shards = self.instances - 1;
                let i = self.rr_next;
                self.rr_next = i + 1;
                if self.rr_next == shards {
                    self.rr_next = 0;
                }
                RouteTargets::Two([i, self.instances - 1].into_iter())
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn frame(stream: usize) -> Frame {
        Frame {
            id: 0,
            stream,
            data: crate::pipeline::plane::FramePlane::from_vec(Vec::new()),
            width: 0,
            height: 0,
            gt_mri: None,
            admitted: Instant::now(),
            stamps: Default::default(),
        }
    }

    fn targets(r: &mut Router, f: &Frame) -> Vec<usize> {
        r.route(f).collect()
    }

    #[test]
    fn fanout_hits_all() {
        let mut r = Router::new(RoutePolicy::Fanout, 3);
        let t = r.route(&frame(0));
        assert_eq!(t.len(), 3);
        assert_eq!(t.collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn round_robin_alternates() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2);
        assert_eq!(targets(&mut r, &frame(0)), vec![0]);
        assert_eq!(targets(&mut r, &frame(0)), vec![1]);
        assert_eq!(targets(&mut r, &frame(0)), vec![0]);
    }

    #[test]
    fn by_stream_is_stable() {
        let mut r = Router::new(RoutePolicy::ByStream, 2);
        assert_eq!(targets(&mut r, &frame(0)), vec![0]);
        assert_eq!(targets(&mut r, &frame(1)), vec![1]);
        assert_eq!(targets(&mut r, &frame(5)), vec![1]);
        assert_eq!(targets(&mut r, &frame(0)), vec![0]);
    }

    #[test]
    fn single_target_len_is_one() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 4);
        assert_eq!(r.route(&frame(0)).len(), 1);
    }

    #[test]
    fn rr_fanout_last_shards_and_broadcasts() {
        // three instances: frames alternate 0/1, instance 2 sees everything
        let mut r = Router::new(RoutePolicy::RrFanoutLast, 3);
        assert_eq!(targets(&mut r, &frame(0)), vec![0, 2]);
        assert_eq!(targets(&mut r, &frame(0)), vec![1, 2]);
        assert_eq!(targets(&mut r, &frame(0)), vec![0, 2]);
        let t = r.route(&frame(0));
        assert_eq!(t.len(), 2);
        // degenerate single instance: plain unicast
        let mut r1 = Router::new(RoutePolicy::RrFanoutLast, 1);
        assert_eq!(targets(&mut r1, &frame(0)), vec![0]);
        // two instances: shard 0 is always primary, 1 is the broadcast tail
        let mut r2 = Router::new(RoutePolicy::RrFanoutLast, 2);
        assert_eq!(targets(&mut r2, &frame(0)), vec![0, 1]);
        assert_eq!(targets(&mut r2, &frame(0)), vec![0, 1]);
    }

    #[test]
    fn copies_per_frame_agrees_with_route() {
        // the declared arity must match what the router actually yields
        for policy in [
            RoutePolicy::Fanout,
            RoutePolicy::RoundRobin,
            RoutePolicy::ByStream,
            RoutePolicy::RrFanoutLast,
        ] {
            for n in 1..=4 {
                let mut r = Router::new(policy, n);
                assert_eq!(
                    r.route(&frame(0)).len(),
                    policy.copies_per_frame(n),
                    "{policy:?} x {n}"
                );
            }
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [
            RoutePolicy::Fanout,
            RoutePolicy::RoundRobin,
            RoutePolicy::ByStream,
            RoutePolicy::RrFanoutLast,
        ] {
            assert_eq!(RoutePolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert!(RoutePolicy::parse("hash").is_err());
    }

    #[test]
    #[should_panic]
    fn zero_instances_rejected() {
        Router::new(RoutePolicy::Fanout, 0);
    }
}
