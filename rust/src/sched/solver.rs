//! Transition-point search.
//!
//! HaX-CoNN formulates schedule synthesis as a SAT problem solved by Z3;
//! the search space here (one or two transition points per instance) is
//! small enough for exact enumeration with pruning, which doubles as the
//! optimality certificate. `search_pairs` is exhaustive; `search_sandwich`
//! uses a coarse-grid pass followed by local refinement (a bounded
//! branch-and-bound) to keep the 4-dimensional search fast.

use super::haxconn::SteadyState;

/// Result of a 2-point search.
#[derive(Debug, Clone, Copy)]
pub struct PairEval {
    pub a: usize,
    pub b: usize,
    pub state: SteadyState,
}

/// Exhaustively search `(a, b) ∈ [0, n]²` minimising the period.
pub fn search_pairs(n: usize, eval: &dyn Fn(usize, usize) -> SteadyState) -> PairEval {
    search_pairs_bounded(n, n, eval)
}

/// Exhaustively search `(a, b) ∈ [0, amax] × [0, bmax]`.
pub fn search_pairs_bounded(
    amax: usize,
    bmax: usize,
    eval: &dyn Fn(usize, usize) -> SteadyState,
) -> PairEval {
    let mut best: Option<PairEval> = None;
    for a in 0..=amax {
        for b in 0..=bmax {
            let state = eval(a, b);
            if best.map(|x| state.period < x.state.period).unwrap_or(true) {
                best = Some(PairEval { a, b, state });
            }
        }
    }
    best.expect("non-empty search space")
}

/// Result of a 4-point (two-sandwich) search.
#[derive(Debug, Clone, Copy)]
pub struct SandwichEval {
    pub p1: usize,
    pub p2: usize,
    pub q1: usize,
    pub q2: usize,
    pub state: SteadyState,
}

/// Search `(p1 ≤ p2) × (q1 ≤ q2)` minimising the period: coarse grid then
/// local refinement around the incumbent.
pub fn search_sandwich(
    n: usize,
    m: usize,
    eval: &dyn Fn(usize, usize, usize, usize) -> SteadyState,
) -> SandwichEval {
    let pstep = (n / 24).max(1);
    let qstep = (m / 24).max(1);
    let mut best: Option<SandwichEval> = None;
    let consider = |p1: usize, p2: usize, q1: usize, q2: usize, best: &mut Option<SandwichEval>| {
        if p1 > p2 || q1 > q2 || p2 > n || q2 > m {
            return;
        }
        let state = eval(p1, p2, q1, q2);
        if best.map(|x| state.period < x.state.period).unwrap_or(true) {
            *best = Some(SandwichEval { p1, p2, q1, q2, state });
        }
    };

    // Coarse pass.
    let mut p1 = 0;
    while p1 <= n {
        let mut p2 = p1;
        while p2 <= n {
            let mut q1 = 0;
            while q1 <= m {
                let mut q2 = q1;
                while q2 <= m {
                    consider(p1, p2, q1, q2, &mut best);
                    q2 += qstep;
                }
                q1 += qstep;
            }
            p2 += pstep;
        }
        p1 += pstep;
    }

    // Local refinement around the incumbent (±step in every dimension).
    let inc = best.expect("non-empty search space");
    let r = |c: usize, step: usize, hi: usize| -> (usize, usize) {
        (c.saturating_sub(step), (c + step).min(hi))
    };
    let (p1l, p1h) = r(inc.p1, pstep, n);
    let (p2l, p2h) = r(inc.p2, pstep, n);
    let (q1l, q1h) = r(inc.q1, qstep, m);
    let (q2l, q2h) = r(inc.q2, qstep, m);
    for p1 in p1l..=p1h {
        for p2 in p2l..=p2h {
            for q1 in q1l..=q1h {
                for q2 in q2l..=q2h {
                    consider(p1, p2, q1, q2, &mut best);
                }
            }
        }
    }
    best.expect("non-empty search space")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_state(period: f64) -> SteadyState {
        SteadyState {
            busy_gpu: period,
            busy_dla: period,
            period,
            transitions: 0.0,
        }
    }

    #[test]
    fn pairs_finds_global_minimum() {
        // Known convex-ish objective: minimized at a=3, b=7.
        let eval = |a: usize, b: usize| {
            fake_state(((a as f64 - 3.0).powi(2) + (b as f64 - 7.0).powi(2)) + 1.0)
        };
        let best = search_pairs(10, &eval);
        assert_eq!((best.a, best.b), (3, 7));
    }

    #[test]
    fn sandwich_respects_ordering_constraints() {
        let eval = |p1: usize, p2: usize, q1: usize, q2: usize| {
            assert!(p1 <= p2 && q1 <= q2);
            fake_state((p1 + p2 + q1 + q2) as f64 + 1.0)
        };
        let best = search_sandwich(20, 30, &eval);
        assert_eq!((best.p1, best.p2, best.q1, best.q2), (0, 0, 0, 0));
    }

    #[test]
    fn sandwich_refinement_improves_on_grid() {
        // Minimum at p1=5,p2=6,q1=7,q2=8 — off the coarse grid for n,m
        // large enough; refinement must still find a near-optimal point.
        let target = (5.0, 6.0, 7.0, 8.0);
        let eval = move |p1: usize, p2: usize, q1: usize, q2: usize| {
            let d = (p1 as f64 - target.0).powi(2)
                + (p2 as f64 - target.1).powi(2)
                + (q1 as f64 - target.2).powi(2)
                + (q2 as f64 - target.3).powi(2);
            fake_state(d + 1.0)
        };
        let best = search_sandwich(100, 100, &eval);
        assert!(best.state.period < 20.0, "period {}", best.state.period);
    }
}
