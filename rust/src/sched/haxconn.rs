//! HaX-CoNN-style partitioned scheduling.
//!
//! Two model instances run concurrently, each split at transition points,
//! phase-shifted so that while one instance uses the GPU the other uses the
//! DLA (paper Fig 4). The paper derives the points "by aligning the
//! execution times of the GPU and DLA"; we do exactly that: an exhaustive
//! search over transition points minimising the steady-state period
//!
//! ```text
//! P = max(busy_GPU, busy_DLA)        (per frame-pair, contention-adjusted)
//! ```
//!
//! DLA-incompatible layers inside a DLA range cost GPU time + transitions
//! (fallback), which is what makes the original Pix2Pix unbalanceable and
//! reproduces Tables III–VI.

use super::solver::{search_pairs_bounded, search_sandwich, PairEval};
use super::{InstanceSchedule, Schedule, SegmentPlan, DEFAULT_MIN_ISLAND};
use crate::cost::contention::{memory_intensity, slowdown};
use crate::cost::flops::node_cost;
use crate::cost::latency::layer_latency;
use crate::dla::planner::assign_engines;
use crate::dla::rules::{check_layer, DlaVersion};
use crate::error::Result;
use crate::graph::Graph;
use crate::hw::{EngineKind, SocSpec};

/// Per-model prefix tables for O(1) range cost queries.
#[derive(Debug, Clone)]
pub struct CostTables {
    /// GPU latency prefix over compute layers.
    gpu: Vec<f64>,
    /// Native-DLA latency prefix (compatible layers only).
    dla_native: Vec<f64>,
    /// GPU fallback latency prefix (incompatible layers at GPU speed).
    dla_fb_gpu: Vec<f64>,
    /// Engine-flip count prefix inside DLA ranges (fallback transitions).
    fb_flips: Vec<f64>,
    /// Bytes prefix (for contention bandwidth estimates).
    bytes: Vec<f64>,
    /// Mean memory intensity on each engine (coarse, graph-wide).
    intensity_gpu: f64,
    intensity_dla: f64,
    pub n_layers: usize,
}

impl CostTables {
    pub fn build(graph: &Graph, soc: &SocSpec, version: DlaVersion) -> Self {
        let layers = graph.compute_layers();
        let n = layers.len();
        let mut gpu = vec![0.0; n + 1];
        let mut dla_native = vec![0.0; n + 1];
        let mut dla_fb_gpu = vec![0.0; n + 1];
        let mut fb_flips = vec![0.0; n + 1];
        let mut bytes = vec![0.0; n + 1];
        let mut int_g = 0.0;
        let mut int_d = 0.0;
        // Effective per-layer engine under DLA assignment (fallback with
        // TensorRT-style island merging), computed globally.
        let flags: Vec<bool> = layers
            .iter()
            .map(|&id| {
                let node = graph.node(id);
                check_layer(&node.kind, &graph.input_shapes(id), version).is_supported()
            })
            .collect();
        let effective = assign_engines(&flags, DEFAULT_MIN_ISLAND);
        let mut prev_fb = false;
        for (i, &id) in layers.iter().enumerate() {
            let cost = node_cost(graph, id);
            let on_dla = effective[i] == EngineKind::Dla;
            gpu[i + 1] = gpu[i] + layer_latency(&cost, &soc.gpu);
            dla_native[i + 1] =
                dla_native[i] + if on_dla { layer_latency(&cost, &soc.dla) } else { 0.0 };
            dla_fb_gpu[i + 1] =
                dla_fb_gpu[i] + if on_dla { 0.0 } else { layer_latency(&cost, &soc.gpu) };
            let flip = if i == 0 { !on_dla } else { prev_fb != !on_dla };
            fb_flips[i + 1] = fb_flips[i] + if flip { 1.0 } else { 0.0 };
            prev_fb = !on_dla;
            bytes[i + 1] = bytes[i] + cost.bytes;
            int_g += memory_intensity(&cost, &soc.gpu);
            int_d += memory_intensity(&cost, &soc.dla);
        }
        CostTables {
            gpu,
            dla_native,
            dla_fb_gpu,
            fb_flips,
            bytes,
            intensity_gpu: if n > 0 { int_g / n as f64 } else { 0.0 },
            intensity_dla: if n > 0 { int_d / n as f64 } else { 0.0 },
            n_layers: n,
        }
    }

    /// GPU time of layer range `[a, b)` when assigned to the GPU.
    pub fn gpu_time(&self, a: usize, b: usize) -> f64 {
        self.gpu[b] - self.gpu[a]
    }

    /// (DLA busy, GPU fallback busy, fallback transition count) of range
    /// `[a, b)` when assigned to the DLA.
    pub fn dla_time(&self, a: usize, b: usize) -> (f64, f64, f64) {
        (
            self.dla_native[b] - self.dla_native[a],
            self.dla_fb_gpu[b] - self.dla_fb_gpu[a],
            self.fb_flips[b] - self.fb_flips[a],
        )
    }

    pub fn bytes_range(&self, a: usize, b: usize) -> f64 {
        self.bytes[b] - self.bytes[a]
    }
}

/// Steady-state evaluation of a candidate concurrent schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SteadyState {
    /// GPU busy seconds per frame-round (one frame of every instance).
    pub busy_gpu: f64,
    /// DLA busy seconds per frame-round.
    pub busy_dla: f64,
    /// Contention-adjusted period, seconds.
    pub period: f64,
    /// Inter-engine transitions per round (schedule + fallback).
    pub transitions: f64,
}

/// Evaluate the steady state of instance assignments expressed as
/// `(tables, segments)` pairs.
pub fn steady_state(
    parts: &[(&CostTables, &[SegmentPlan])],
    soc: &SocSpec,
) -> SteadyState {
    let mut busy_gpu = 0.0;
    let mut busy_dla = 0.0;
    let mut transitions = 0.0;
    let mut int_g_acc = 0.0;
    let mut int_d_acc = 0.0;
    for (t, segs) in parts {
        for (i, s) in segs.iter().enumerate() {
            match s.engine {
                EngineKind::Gpu => {
                    busy_gpu += t.gpu_time(s.start, s.end);
                    int_g_acc += t.intensity_gpu * t.gpu_time(s.start, s.end);
                }
                EngineKind::Dla => {
                    let (d, g, f) = t.dla_time(s.start, s.end);
                    busy_dla += d;
                    busy_gpu += g;
                    transitions += f;
                    int_d_acc += t.intensity_dla * d;
                    int_g_acc += t.intensity_gpu * g;
                }
                other => panic!("engine {other} not schedulable"),
            }
            if i + 1 < segs.len() {
                transitions += 1.0;
            }
        }
    }
    // Contention: each engine's busy time inflated by the co-runner's
    // bandwidth pressure (PCCS).
    let int_g = if busy_gpu > 0.0 { int_g_acc / busy_gpu } else { 0.0 };
    let int_d = if busy_dla > 0.0 { int_d_acc / busy_dla } else { 0.0 };
    let bw_g = soc.gpu.mem_bw * int_g; // coarse demand estimate
    let bw_d = soc.dla.mem_bw * int_d;
    // Each transition occupies its destination engine for the reformat;
    // on average half land on each engine.
    let trans_each = 0.5 * transitions * soc.transition.fixed;
    let busy_gpu_adj = busy_gpu * slowdown(soc, int_g, bw_d) + trans_each;
    let busy_dla_adj = busy_dla * slowdown(soc, int_d, bw_g) + trans_each;
    let period = busy_gpu_adj.max(busy_dla_adj);
    SteadyState {
        busy_gpu: busy_gpu_adj,
        busy_dla: busy_dla_adj,
        period,
        transitions,
    }
}

/// Schedule two instances of the same GAN (paper §VI.D.1, Tables III/IV):
/// instance 1 = DLA `[0,p1)` + GPU `[p1,n)`; instance 2 = GPU `[0,p2)` +
/// DLA `[p2,n)`. Returns the schedule and its steady state.
pub fn two_gans(
    gan: &Graph,
    soc: &SocSpec,
    version: DlaVersion,
) -> Result<(Schedule, SteadyState)> {
    let t = CostTables::build(gan, soc, version);
    let n = t.n_layers;
    let eval = |p1: usize, p2: usize| -> SteadyState {
        let inst1 = two_part(EngineKind::Dla, EngineKind::Gpu, p1, n);
        let inst2 = two_part(EngineKind::Gpu, EngineKind::Dla, p2, n);
        steady_state(&[(&t, &inst1[..]), (&t, &inst2[..])], soc)
    };
    // The paper's structural prior (Fig 4 / Table III): instance 1 opens
    // with a small DLA prefix and is GPU-dominant; instance 2 opens on the
    // GPU and hands the tail to the DLA. Bound the search accordingly.
    let best: PairEval = search_pairs_bounded(n / 3, n.saturating_sub(n / 4), &eval);
    let (p1, p2) = (best.a, best.b);
    let schedule = Schedule {
        instances: vec![
            InstanceSchedule {
                model: 0,
                label: "gan-inst1".to_string(),
                segments: two_part(EngineKind::Dla, EngineKind::Gpu, p1, n),
            },
            InstanceSchedule {
                model: 0,
                label: "gan-inst2".to_string(),
                segments: two_part(EngineKind::Gpu, EngineKind::Dla, p2, n),
            },
        ],
    };
    for inst in &schedule.instances {
        inst.validate(n)?;
    }
    Ok((schedule, best.state))
}

/// Schedule a GAN + detector pair (paper §VI.D.2, Tables V/VI): the GAN is
/// split DLA `[0,p1)` / GPU `[p1,p2)` / DLA `[p2,n)` (the Table V shape)
/// and the detector complementarily GPU `[0,q1)` / DLA `[q1,q2)` /
/// GPU `[q2,m)`.
pub fn gan_plus_yolo(
    gan: &Graph,
    yolo: &Graph,
    soc: &SocSpec,
    version: DlaVersion,
) -> Result<(Schedule, SteadyState)> {
    let tg = CostTables::build(gan, soc, version);
    let ty = CostTables::build(yolo, soc, version);
    let (n, m) = (tg.n_layers, ty.n_layers);
    let eval = |p1: usize, p2: usize, q1: usize, q2: usize| -> SteadyState {
        let gan_segs = sandwich_segments(EngineKind::Dla, EngineKind::Gpu, p1, p2, n);
        let yolo_segs = sandwich_segments(EngineKind::Gpu, EngineKind::Dla, q1, q2, m);
        steady_state(&[(&tg, &gan_segs[..]), (&ty, &yolo_segs[..])], soc)
    };
    let best = search_sandwich(n, m, &eval);
    let (p1, p2, q1, q2) = (best.p1, best.p2, best.q1, best.q2);
    let schedule = Schedule {
        instances: vec![
            InstanceSchedule {
                model: 0,
                label: "gan".to_string(),
                segments: sandwich_segments(EngineKind::Dla, EngineKind::Gpu, p1, p2, n),
            },
            InstanceSchedule {
                model: 1,
                label: "yolo".to_string(),
                segments: sandwich_segments(EngineKind::Gpu, EngineKind::Dla, q1, q2, m),
            },
        ],
    };
    schedule.instances[0].validate(n)?;
    schedule.instances[1].validate(m)?;
    Ok((schedule, best.state))
}

/// Build `first[0,p) / second[p,n)` segments, dropping empty ranges.
pub fn two_part(first: EngineKind, second: EngineKind, p: usize, n: usize) -> Vec<SegmentPlan> {
    let mut v = Vec::new();
    if p > 0 {
        v.push(SegmentPlan { engine: first, start: 0, end: p });
    }
    if n > p {
        v.push(SegmentPlan { engine: second, start: p, end: n });
    }
    v
}

/// Build `outer[0,a) / inner[a,b) / outer[b,n)` segments, dropping empty
/// ranges.
pub fn sandwich_segments(
    outer: EngineKind,
    inner: EngineKind,
    a: usize,
    b: usize,
    n: usize,
) -> Vec<SegmentPlan> {
    let mut v = Vec::new();
    if a > 0 {
        v.push(SegmentPlan { engine: outer, start: 0, end: a });
    }
    if b > a {
        v.push(SegmentPlan { engine: inner, start: a, end: b });
    }
    if n > b {
        v.push(SegmentPlan { engine: outer, start: b, end: n });
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GanVariant;
    use crate::hw::orin;
    use crate::models::pix2pix::{generator, Pix2PixConfig};
    use crate::models::yolov8::{yolov8, YoloConfig};

    fn gan(v: GanVariant) -> Graph {
        generator(&Pix2PixConfig::paper(), v).unwrap()
    }

    #[test]
    fn two_gans_modified_balanced_table4() {
        let soc = orin();
        for v in [GanVariant::Cropping, GanVariant::Convolution] {
            let (sched, ss) = two_gans(&gan(v), &soc, DlaVersion::V2).unwrap();
            assert_eq!(sched.instances.len(), 2);
            // Modified variants must balance the engines within ~20%.
            let ratio = ss.busy_gpu / ss.busy_dla;
            assert!(
                (0.65..1.55).contains(&ratio),
                "{v:?} busy ratio {ratio:.2} unbalanced"
            );
        }
    }

    #[test]
    fn two_gans_original_unbalanced_table4() {
        let soc = orin();
        let (_, ss_orig) = two_gans(&gan(GanVariant::Original), &soc, DlaVersion::V2).unwrap();
        let (_, ss_crop) = two_gans(&gan(GanVariant::Cropping), &soc, DlaVersion::V2).unwrap();
        // Original cannot use the DLA effectively: its DLA busy share is
        // lower than the cropping variant's (DLA starvation, Table IV).
        assert!(
            ss_orig.busy_dla / ss_orig.busy_gpu < ss_crop.busy_dla / ss_crop.busy_gpu,
            "original should be DLA-starved: orig {:.2} vs crop {:.2}",
            ss_orig.busy_dla / ss_orig.busy_gpu,
            ss_crop.busy_dla / ss_crop.busy_gpu
        );
        // And it pays far more transitions (fragmentation, Fig 13).
        assert!(ss_orig.transitions > 4.0 * ss_crop.transitions);
    }

    #[test]
    fn crop_partition_later_than_original_table3() {
        // Table III: GPU→DLA at 14 (original) vs 53 (crop) vs 48 (conv):
        // the compatible models hand much more of the tail to the DLA...
        // expressed relative to model length, the original's DLA tail
        // share must be *smaller*.
        let soc = orin();
        let (s_orig, _) = two_gans(&gan(GanVariant::Original), &soc, DlaVersion::V2).unwrap();
        let (s_crop, _) = two_gans(&gan(GanVariant::Cropping), &soc, DlaVersion::V2).unwrap();
        let tail = |s: &Schedule, n: usize| {
            let (_, g2d) = s.instances[1].partition_points();
            g2d.map(|p| (n - p) as f64 / n as f64).unwrap_or(0.0)
        };
        let n_o = gan(GanVariant::Original).compute_layers().len();
        let n_c = gan(GanVariant::Cropping).compute_layers().len();
        let t_o = tail(&s_orig, n_o);
        let t_c = tail(&s_crop, n_c);
        assert!(
            t_c >= t_o,
            "crop DLA tail share {t_c:.2} should be >= original {t_o:.2}"
        );
    }

    #[test]
    fn gan_plus_yolo_balanced_table6() {
        let soc = orin();
        let yolo = yolov8(&YoloConfig::nano()).unwrap();
        let (sched, ss) = gan_plus_yolo(&gan(GanVariant::Cropping), &yolo, &soc, DlaVersion::V2)
            .unwrap();
        assert_eq!(sched.instances.len(), 2);
        let ratio = ss.busy_gpu / ss.busy_dla;
        assert!((0.6..1.6).contains(&ratio), "busy ratio {ratio:.2}");
        // ~150 FPS class: period per round between 4 and 9 ms.
        assert!(
            (0.004..0.009).contains(&ss.period),
            "period {:.2} ms",
            ss.period * 1e3
        );
    }

    #[test]
    fn steady_state_transitions_counted() {
        let soc = orin();
        let g = gan(GanVariant::Cropping);
        let t = CostTables::build(&g, &soc, DlaVersion::V2);
        let n = t.n_layers;
        let one = [SegmentPlan { engine: EngineKind::Dla, start: 0, end: n }];
        let ss_one = steady_state(&[(&t, &one[..])], &soc);
        assert_eq!(ss_one.transitions, 0.0);
        let two = [
            SegmentPlan { engine: EngineKind::Dla, start: 0, end: n / 2 },
            SegmentPlan { engine: EngineKind::Gpu, start: n / 2, end: n },
        ];
        let ss_two = steady_state(&[(&t, &two[..])], &soc);
        assert_eq!(ss_two.transitions, 1.0);
    }

    #[test]
    fn cost_tables_prefix_consistency() {
        let soc = orin();
        let g = gan(GanVariant::Original);
        let t = CostTables::build(&g, &soc, DlaVersion::V2);
        let n = t.n_layers;
        // range additivity
        let whole = t.gpu_time(0, n);
        let split = t.gpu_time(0, n / 3) + t.gpu_time(n / 3, n);
        assert!((whole - split).abs() < 1e-12);
        // original model has fallback inside full DLA range (island
        // merging collapses the decoder into one big GPU run)
        let (_d, g_fb, flips) = t.dla_time(0, n);
        assert!(g_fb > 0.0);
        assert!(flips >= 1.0);
    }
}
