//! Naive static scheduling — the client-server scheme (paper Fig 3,
//! Figs 11/12): the GAN is pinned to the DLA (falling back per layer where
//! incompatible) and the YOLO detector is pinned to the GPU.

use super::{InstanceSchedule, Schedule, SegmentPlan};
use crate::graph::Graph;
use crate::hw::EngineKind;

/// GAN on DLA + detector on GPU.
pub fn gan_dla_yolo_gpu(gan: &Graph, yolo: &Graph) -> Schedule {
    Schedule {
        instances: vec![
            InstanceSchedule {
                model: 0,
                label: "gan-dla".to_string(),
                segments: vec![SegmentPlan {
                    engine: EngineKind::Dla,
                    start: 0,
                    end: gan.compute_layers().len(),
                }],
            },
            InstanceSchedule {
                model: 1,
                label: "yolo-gpu".to_string(),
                segments: vec![SegmentPlan {
                    engine: EngineKind::Gpu,
                    start: 0,
                    end: yolo.compute_layers().len(),
                }],
            },
        ],
    }
}

/// A single model alone on one engine (standalone profiling, Figs 8–10).
pub fn standalone(model: &Graph, engine: EngineKind) -> Schedule {
    Schedule {
        instances: vec![InstanceSchedule {
            model: 0,
            label: format!("{}-{}", model.name, engine.name().to_lowercase()),
            segments: vec![SegmentPlan {
                engine,
                start: 0,
                end: model.compute_layers().len(),
            }],
        }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GanVariant;
    use crate::models::pix2pix::{generator, Pix2PixConfig};
    use crate::models::yolov8::{yolov8, YoloConfig};

    #[test]
    fn naive_schedule_pins_models() {
        let gan = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        let yolo = yolov8(&YoloConfig::nano()).unwrap();
        let s = gan_dla_yolo_gpu(&gan, &yolo);
        assert_eq!(s.instances.len(), 2);
        s.instances[0].validate(gan.compute_layers().len()).unwrap();
        s.instances[1].validate(yolo.compute_layers().len()).unwrap();
        assert_eq!(s.instances[0].segments[0].engine, EngineKind::Dla);
        assert_eq!(s.instances[1].segments[0].engine, EngineKind::Gpu);
    }

    #[test]
    fn standalone_schedule() {
        let gan = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
        let s = standalone(&gan, EngineKind::Dla);
        assert_eq!(s.instances.len(), 1);
        s.instances[0].validate(gan.compute_layers().len()).unwrap();
    }
}
