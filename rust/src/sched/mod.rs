//! Scheduling of concurrent models across the GPU and DLA.
//!
//! * [`naive`] — each model statically pinned to one engine (the paper's
//!   client-server scheme, Figs 11/12);
//! * [`haxconn`] — HaX-CoNN-style partitioned streaming schedules
//!   (standalone scheme, Tables III–VI): each instance is split at one or
//!   two transition points and the instances swap engines so both stay
//!   busy. The paper derives these "by aligning the execution times of the
//!   GPU and DLA"; [`solver`] performs that alignment as a branch-and-bound
//!   search over transition points (substituting HaX-CoNN's Z3 use — see
//!   DESIGN.md).
//!
//! All schedules share the [`Schedule`] representation consumed by the
//! discrete-event simulator in [`crate::sim`].

pub mod haxconn;
pub mod jedi;
pub mod naive;
pub mod solver;

use crate::dla::rules::{check_layer, DlaVersion};
use crate::error::{Error, Result};
use crate::graph::{Graph, NodeId};
use crate::hw::EngineKind;

/// A contiguous run of compute layers of one model on one engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentPlan {
    pub engine: EngineKind,
    /// Half-open range into `graph.compute_layers()`.
    pub start: usize,
    pub end: usize,
}

/// The schedule of one model instance.
#[derive(Debug, Clone)]
pub struct InstanceSchedule {
    /// Index into the workload's model list.
    pub model: usize,
    /// Human-readable instance label ("gan-dla", "yolo", ...).
    pub label: String,
    /// Ordered engine segments covering all compute layers exactly once.
    pub segments: Vec<SegmentPlan>,
}

impl InstanceSchedule {
    /// Partition points in the paper's Table III/V format:
    /// (DLA→GPU layer, GPU→DLA layer), if present.
    pub fn partition_points(&self) -> (Option<usize>, Option<usize>) {
        let mut dla_to_gpu = None;
        let mut gpu_to_dla = None;
        for w in self.segments.windows(2) {
            match (w[0].engine, w[1].engine) {
                (EngineKind::Dla, EngineKind::Gpu) if dla_to_gpu.is_none() => {
                    dla_to_gpu = Some(w[1].start)
                }
                (EngineKind::Gpu, EngineKind::Dla) if gpu_to_dla.is_none() => {
                    gpu_to_dla = Some(w[1].start)
                }
                _ => {}
            }
        }
        (dla_to_gpu, gpu_to_dla)
    }

    /// Check the segments tile `[0, n_layers)` in order.
    pub fn validate(&self, n_layers: usize) -> Result<()> {
        if self.segments.is_empty() {
            return Err(Error::Sched(format!("instance `{}` has no segments", self.label)));
        }
        let mut expect = 0usize;
        for s in &self.segments {
            if s.start != expect || s.end <= s.start {
                return Err(Error::Sched(format!(
                    "instance `{}`: segment [{}, {}) does not tile at {}",
                    self.label, s.start, s.end, expect
                )));
            }
            expect = s.end;
        }
        if expect != n_layers {
            return Err(Error::Sched(format!(
                "instance `{}`: segments cover {} of {} layers",
                self.label, expect, n_layers
            )));
        }
        Ok(())
    }
}

/// A complete concurrent schedule: the models plus one entry per instance.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub instances: Vec<InstanceSchedule>,
}

/// Default TensorRT-like minimum DLA subgraph size used when expanding
/// fallback (tiny compatible islands between incompatible layers stay on
/// the GPU to avoid transition churn).
pub const DEFAULT_MIN_ISLAND: usize = 3;

/// Expand one instance segment into execution *steps*, honouring DLA
/// fallback: layers inside a DLA segment that the DLA cannot run are
/// re-routed to the GPU (what the TensorRT engine plan would do), splitting
/// the segment; compatible islands shorter than [`DEFAULT_MIN_ISLAND`] are
/// merged into the surrounding GPU run. GPU segments never split.
pub fn expand_fallback(
    graph: &Graph,
    segment: &SegmentPlan,
    version: DlaVersion,
) -> Vec<(EngineKind, Vec<NodeId>)> {
    expand_fallback_with(graph, segment, version, DEFAULT_MIN_ISLAND)
}

/// [`expand_fallback`] with explicit `min_island`.
pub fn expand_fallback_with(
    graph: &Graph,
    segment: &SegmentPlan,
    version: DlaVersion,
    min_island: usize,
) -> Vec<(EngineKind, Vec<NodeId>)> {
    let layers = graph.compute_layers();
    let ids = &layers[segment.start..segment.end];
    if segment.engine != EngineKind::Dla {
        return vec![(segment.engine, ids.to_vec())];
    }
    let flags: Vec<bool> = ids
        .iter()
        .map(|&id| {
            let node = graph.node(id);
            check_layer(&node.kind, &graph.input_shapes(id), version).is_supported()
        })
        .collect();
    let engines = crate::dla::planner::assign_engines(&flags, min_island);
    let mut out: Vec<(EngineKind, Vec<NodeId>)> = Vec::new();
    for (&id, &engine) in ids.iter().zip(engines.iter()) {
        match out.last_mut() {
            Some((e, v)) if *e == engine => v.push(id),
            _ => out.push((engine, vec![id])),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GanVariant;
    use crate::models::pix2pix::{generator, Pix2PixConfig};

    #[test]
    fn validate_tiling() {
        let inst = InstanceSchedule {
            model: 0,
            label: "t".into(),
            segments: vec![
                SegmentPlan { engine: EngineKind::Dla, start: 0, end: 4 },
                SegmentPlan { engine: EngineKind::Gpu, start: 4, end: 10 },
            ],
        };
        inst.validate(10).unwrap();
        assert!(inst.validate(11).is_err());
        let bad = InstanceSchedule {
            model: 0,
            label: "b".into(),
            segments: vec![SegmentPlan { engine: EngineKind::Gpu, start: 1, end: 10 }],
        };
        assert!(bad.validate(10).is_err());
    }

    #[test]
    fn partition_points_extraction() {
        let inst = InstanceSchedule {
            model: 0,
            label: "t".into(),
            segments: vec![
                SegmentPlan { engine: EngineKind::Dla, start: 0, end: 4 },
                SegmentPlan { engine: EngineKind::Gpu, start: 4, end: 14 },
                SegmentPlan { engine: EngineKind::Dla, start: 14, end: 50 },
            ],
        };
        assert_eq!(inst.partition_points(), (Some(4), Some(14)));
    }

    #[test]
    fn fallback_expansion_splits_original_dla_segment() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        let n = g.compute_layers().len();
        let seg = SegmentPlan { engine: EngineKind::Dla, start: 0, end: n };
        let steps = expand_fallback(&g, &seg, DlaVersion::V2);
        assert!(steps.len() >= 2, "padded deconvs split the segment");
        assert!(steps.iter().any(|(e, _)| *e == EngineKind::Gpu));
        // Without island merging the segment shatters much further.
        let raw = expand_fallback_with(&g, &seg, DlaVersion::V2, 1);
        assert!(raw.len() > 10, "raw fallback fragments: {}", raw.len());
        assert!(raw.len() > steps.len());
        // coverage preserved in order
        let flat: Vec<_> = steps.iter().flat_map(|(_, v)| v.clone()).collect();
        assert_eq!(flat, g.compute_layers());
    }

    #[test]
    fn fallback_expansion_noop_for_clean_model() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
        let n = g.compute_layers().len();
        let seg = SegmentPlan { engine: EngineKind::Dla, start: 0, end: n };
        let steps = expand_fallback(&g, &seg, DlaVersion::V2);
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].0, EngineKind::Dla);
    }

    #[test]
    fn gpu_segments_never_split() {
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        let n = g.compute_layers().len();
        let seg = SegmentPlan { engine: EngineKind::Gpu, start: 0, end: n };
        let steps = expand_fallback(&g, &seg, DlaVersion::V2);
        assert_eq!(steps.len(), 1);
    }
}
