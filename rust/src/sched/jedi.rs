//! Jedi-style scheduling (Jeong et al. [7]): a *single* model's layers are
//! distributed across the GPU and DLA as a two-stage pipeline so that
//! successive frames overlap — the per-model analogue of HaX-CoNN's
//! cross-model swapping. The split point balances stage times.

use super::haxconn::CostTables;
use super::{InstanceSchedule, Schedule, SegmentPlan};
use crate::dla::rules::DlaVersion;
use crate::error::Result;
use crate::graph::Graph;
use crate::hw::{EngineKind, SocSpec};

/// Pipeline one model across both engines: DLA `[0, p)` + GPU `[p, n)`,
/// with `p` chosen to minimize the pipeline period
/// `max(t_dla(0..p), t_gpu(p..n))` (stage balance).
pub fn pipelined(graph: &Graph, soc: &SocSpec, version: DlaVersion) -> Result<(Schedule, f64)> {
    let t = CostTables::build(graph, soc, version);
    let n = t.n_layers;
    let mut best = (0usize, f64::INFINITY);
    for p in 0..=n {
        let (dla, fb_gpu, flips) = t.dla_time(0, p);
        let gpu = t.gpu_time(p, n) + fb_gpu;
        let period = dla.max(gpu) + flips * soc.transition.fixed;
        if period < best.1 {
            best = (p, period);
        }
    }
    let (p, period) = best;
    let mut segments = Vec::new();
    if p > 0 {
        segments.push(SegmentPlan { engine: EngineKind::Dla, start: 0, end: p });
    }
    if n > p {
        segments.push(SegmentPlan { engine: EngineKind::Gpu, start: p, end: n });
    }
    let sched = Schedule {
        instances: vec![InstanceSchedule {
            model: 0,
            label: "jedi-pipelined".to_string(),
            segments,
        }],
    };
    sched.instances[0].validate(n)?;
    Ok((sched, period))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GanVariant;
    use crate::cost::latency::LatencyModel;
    use crate::hw::orin;
    use crate::models::pix2pix::{generator, Pix2PixConfig};
    use crate::sim::{simulate, SimConfig};

    #[test]
    fn jedi_beats_single_engine_for_compatible_model() {
        // Pipelining across both engines must outperform either engine
        // alone in steady-state throughput.
        let soc = orin();
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
        let (sched, period) = pipelined(&g, &soc, DlaVersion::V2).unwrap();
        let m = LatencyModel::new(soc.clone());
        let t_gpu = m.graph_latency(&g, crate::hw::EngineKind::Gpu);
        let t_dla = m.graph_latency(&g, crate::hw::EngineKind::Dla);
        assert!(period < t_gpu.min(t_dla), "pipeline must beat both engines");

        // And the simulator agrees (pipelined throughput > GPU-only).
        let r = simulate(&[&g], &sched, &SimConfig::new(soc.clone(), 96)).unwrap();
        assert!(r.instances[0].fps > 1.0 / t_gpu, "fps {}", r.instances[0].fps);
    }

    #[test]
    fn jedi_split_point_nontrivial() {
        let soc = orin();
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Cropping).unwrap();
        let (sched, _) = pipelined(&g, &soc, DlaVersion::V2).unwrap();
        let (d2g, _) = sched.instances[0].partition_points();
        let n = g.compute_layers().len();
        let p = d2g.unwrap_or(0);
        assert!(p > 0 && p < n, "split {p} of {n} should be interior");
    }

    #[test]
    fn jedi_handles_incompatible_model() {
        // Original model: the DLA stage contains fallback; the schedule
        // still validates and simulates.
        let soc = orin();
        let g = generator(&Pix2PixConfig::paper(), GanVariant::Original).unwrap();
        let (sched, _) = pipelined(&g, &soc, DlaVersion::V2).unwrap();
        let r = simulate(&[&g], &sched, &SimConfig::new(soc, 32)).unwrap();
        assert_eq!(r.instances[0].frames, 32);
    }
}
