//! DLA layer-support rules.
//!
//! Encodes the TensorRT "Working with DLA — supported layers and
//! restrictions" constraints the paper quotes (§III.A.2 and §II.B/C):
//!
//! * only FP16 and INT8 tensors;
//! * deconvolution: **padding must be zero**, no dilated/grouped
//!   deconvolution (the rule that breaks stock Pix2Pix);
//! * kernel sizes must be within 1–32 for (de)convolution;
//! * stride bounds, channel bounds;
//! * pooling window limited (≤ 8 per side for DLA), dilation unsupported;
//! * several ops unsupported outright (Softmax only in FP16, dense layers
//!   unsupported, dynamic shapes rejected).
//!
//! Each rule yields a [`Verdict`] with the reason so reports can explain
//! *why* a model falls back (the diagnostics `trtexec --verbose` prints).

use crate::graph::layer::LayerKind;
use crate::graph::shape::{DType, Shape};

/// Compatibility verdict for one layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Verdict {
    /// Runs natively on the DLA.
    Supported,
    /// Must fall back to the GPU; the string explains which restriction
    /// fired.
    Fallback(String),
}

impl Verdict {
    pub fn is_supported(&self) -> bool {
        matches!(self, Verdict::Supported)
    }
}

/// Version of the DLA rule set (Xavier = v1 is slightly stricter; the
/// restrictions exercised by the paper's models are identical).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DlaVersion {
    V1,
    V2,
}

/// Check one layer against the DLA rule set.
pub fn check_layer(kind: &LayerKind, inputs: &[Shape], version: DlaVersion) -> Verdict {
    use LayerKind::*;

    // Global dtype rule: FP16/INT8 only.
    for s in inputs {
        if !matches!(s.dtype, DType::F16 | DType::I8) {
            return Verdict::Fallback(format!(
                "dtype {} unsupported on DLA (FP16/INT8 only)",
                s.dtype.name()
            ));
        }
    }

    match kind {
        Input { .. } | Output => Verdict::Supported, // markers, no compute
        Conv2d {
            kernel,
            stride,
            dilation,
            groups,
            out_c,
            ..
        } => {
            if !(1..=32).contains(kernel) {
                return Verdict::Fallback(format!("conv kernel {kernel} outside 1..=32"));
            }
            if !(1..=8).contains(stride) {
                return Verdict::Fallback(format!("conv stride {stride} outside 1..=8"));
            }
            if *dilation > 1 && *kernel > 1 && version == DlaVersion::V1 && *dilation > 2 {
                return Verdict::Fallback(format!("conv dilation {dilation} unsupported"));
            }
            if *dilation > 32 {
                return Verdict::Fallback(format!("conv dilation {dilation} outside 1..=32"));
            }
            if *groups > 1 && inputs.first().map(|s| s.c != *groups).unwrap_or(false) {
                // depthwise OK on v2, arbitrary groups not
                if version == DlaVersion::V1 {
                    return Verdict::Fallback(format!("grouped conv ({groups}) unsupported"));
                }
            }
            if *out_c > 8192 {
                return Verdict::Fallback(format!("conv output channels {out_c} > 8192"));
            }
            Verdict::Supported
        }
        ConvTranspose2d {
            kernel,
            stride,
            padding,
            ..
        } => {
            // THE rule of the paper: deconv padding must be zero.
            if *padding != 0 {
                return Verdict::Fallback(format!(
                    "deconvolution padding must be zero (got {padding})"
                ));
            }
            if !(1..=32).contains(kernel) {
                return Verdict::Fallback(format!("deconv kernel {kernel} outside 1..=32"));
            }
            if !(1..=32).contains(stride) {
                return Verdict::Fallback(format!("deconv stride {stride} outside 1..=32"));
            }
            Verdict::Supported
        }
        BatchNorm => Verdict::Supported, // fused scale ops supported
        InstanceNorm => Verdict::Fallback("instance normalization unsupported on DLA".into()),
        ReLU | LeakyReLU { .. } | Sigmoid | Tanh => Verdict::Supported,
        SiLU => {
            // SiLU = x*sigmoid(x): DLA v2 supports it as a fused pointwise
            // op; v1 must fall back.
            if version == DlaVersion::V1 {
                Verdict::Fallback("SiLU unsupported on DLA v1".into())
            } else {
                Verdict::Supported
            }
        }
        Softmax => {
            // FP16-only op per the paper's quoted restriction list.
            if inputs.first().map(|s| s.dtype) == Some(DType::F16) {
                // Supported only on v2 (ORIN); v1 falls back.
                if version == DlaVersion::V1 {
                    Verdict::Fallback("Softmax unsupported on DLA v1".into())
                } else {
                    Verdict::Supported
                }
            } else {
                Verdict::Fallback("Softmax requires FP16 on DLA".into())
            }
        }
        Concat => Verdict::Supported, // channel concat supported (not batch axis)
        Add => Verdict::Supported,
        Crop { .. } => Verdict::Supported, // expressible as DLA slice
        ZeroPad { .. } => Verdict::Supported, // folded into conv padding
        MaxPool { kernel, stride } | AvgPool { kernel, stride } => {
            if !(1..=8).contains(kernel) {
                return Verdict::Fallback(format!("pool window {kernel} outside 1..=8"));
            }
            if !(1..=16).contains(stride) {
                return Verdict::Fallback(format!("pool stride {stride} outside 1..=16"));
            }
            Verdict::Supported
        }
        GlobalAvgPool => {
            // Adaptive pooling is the classic DLA incompatibility ([20]);
            // a fixed-window average pool is the known workaround.
            Verdict::Fallback("adaptive/global pooling unsupported on DLA".into())
        }
        Upsample { factor } => {
            if *factor <= 32 {
                Verdict::Supported // nearest-neighbour resize supported
            } else {
                Verdict::Fallback(format!("upsample factor {factor} too large"))
            }
        }
        SliceChannels { .. } => Verdict::Supported, // FP16 slice supported
        Dense { .. } => Verdict::Fallback("fully-connected layers unsupported on DLA".into()),
        Dropout { .. } | Identity => Verdict::Supported, // no-ops
        Cast { to } => match to {
            DType::F16 | DType::I8 => Verdict::Supported,
            other => Verdict::Fallback(format!("cast to {} unsupported", other.name())),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::shape::Shape;

    fn f16(c: usize, hw: usize) -> Shape {
        Shape::new(c, hw, hw, DType::F16)
    }

    #[test]
    fn padded_deconv_falls_back() {
        let v = check_layer(&LayerKind::deconv(64, 4, 2, 1), &[f16(64, 8)], DlaVersion::V2);
        match v {
            Verdict::Fallback(reason) => assert!(reason.contains("padding must be zero")),
            _ => panic!("padded deconv must fall back"),
        }
    }

    #[test]
    fn unpadded_deconv_supported() {
        let v = check_layer(&LayerKind::deconv(64, 4, 2, 0), &[f16(64, 8)], DlaVersion::V2);
        assert!(v.is_supported());
    }

    #[test]
    fn kernel_size_limits() {
        assert!(!check_layer(&LayerKind::conv(8, 33, 1, 0), &[f16(8, 64)], DlaVersion::V2)
            .is_supported());
        assert!(check_layer(&LayerKind::conv(8, 32, 1, 0), &[f16(8, 64)], DlaVersion::V2)
            .is_supported());
    }

    #[test]
    fn fp32_falls_back() {
        let s = Shape::new(8, 8, 8, DType::F32);
        let v = check_layer(&LayerKind::ReLU, &[s], DlaVersion::V2);
        assert!(!v.is_supported());
    }

    #[test]
    fn dense_and_global_pool_fall_back() {
        assert!(!check_layer(
            &LayerKind::Dense { out_features: 10 },
            &[f16(512, 1)],
            DlaVersion::V2
        )
        .is_supported());
        assert!(!check_layer(&LayerKind::GlobalAvgPool, &[f16(512, 7)], DlaVersion::V2)
            .is_supported());
    }

    #[test]
    fn silu_version_dependent() {
        assert!(check_layer(&LayerKind::SiLU, &[f16(8, 8)], DlaVersion::V2).is_supported());
        assert!(!check_layer(&LayerKind::SiLU, &[f16(8, 8)], DlaVersion::V1).is_supported());
    }

    #[test]
    fn crop_is_supported() {
        // The entire point of the paper's substitution.
        assert!(check_layer(
            &LayerKind::Crop { border: 1 },
            &[f16(64, 18)],
            DlaVersion::V2
        )
        .is_supported());
        assert!(check_layer(
            &LayerKind::conv_nobias(64, 3, 1, 0),
            &[f16(64, 18)],
            DlaVersion::V2
        )
        .is_supported());
    }

    #[test]
    fn pool_window_limit() {
        assert!(!check_layer(
            &LayerKind::MaxPool { kernel: 9, stride: 1 },
            &[f16(8, 32)],
            DlaVersion::V2
        )
        .is_supported());
    }
}
